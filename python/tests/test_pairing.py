"""Pairing-schedule invariants (paper §2.1, §5)."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import pairing


ALL_KINDS = list(pairing.SCHEDULES)


@pytest.mark.parametrize("kind", ALL_KINDS)
@pytest.mark.parametrize("n", [2, 3, 4, 5, 7, 8, 15, 16, 31, 64, 100, 257])
def test_partition(kind, n):
    """Every stage pairing is a disjoint partition of 0..n-1."""
    for st_ in pairing.make_schedule(kind, n, 6, seed=1):
        st_.validate(n)
        assert st_.num_pairs == n // 2
        assert (st_.leftover is None) == (n % 2 == 0)


@pytest.mark.parametrize("kind", ALL_KINDS)
def test_perm_inverse(kind):
    for st_ in pairing.make_schedule(kind, 33, 4, seed=5):
        p, inv = st_.perm(), st_.inverse_perm()
        assert np.array_equal(p[inv], np.arange(33))
        assert np.array_equal(inv[p], np.arange(33))


def test_butterfly_matches_fft_layout():
    """Power-of-two butterfly = classical radix-2 butterfly strides."""
    n = 8
    s0 = pairing.butterfly_stage(n, 0)
    assert list(s0.left) == [0, 2, 4, 6] and list(s0.right) == [1, 3, 5, 7]
    s1 = pairing.butterfly_stage(n, 1)
    assert list(s1.left) == [0, 1, 4, 5] and list(s1.right) == [2, 3, 6, 7]
    s2 = pairing.butterfly_stage(n, 2)
    assert list(s2.left) == [0, 1, 2, 3] and list(s2.right) == [4, 5, 6, 7]


def test_butterfly_wraps_strides():
    """Stages beyond log2(n) reuse strides cyclically."""
    n = 16
    a = pairing.butterfly_stage(n, 0)
    b = pairing.butterfly_stage(n, 4)  # 4 % log2(16) == 0
    assert np.array_equal(a.perm(), b.perm())


def test_shift_rotates():
    a = pairing.shift_stage(6, 0)
    b = pairing.shift_stage(6, 1)
    assert not np.array_equal(a.perm(), b.perm())
    assert list(a.left) == [0, 2, 4]
    assert list(b.left) == [1, 3, 5]


def test_random_seeded_deterministic():
    a = pairing.make_schedule("random", 40, 5, seed=9)
    b = pairing.make_schedule("random", 40, 5, seed=9)
    c = pairing.make_schedule("random", 40, 5, seed=10)
    assert pairing.schedule_fingerprint(a) == pairing.schedule_fingerprint(b)
    assert pairing.schedule_fingerprint(a) != pairing.schedule_fingerprint(c)


def test_random_stages_differ():
    sched = pairing.make_schedule("random", 64, 3, seed=0)
    fps = {s.perm().tobytes() for s in sched}
    assert len(fps) == 3


def test_default_num_stages():
    assert pairing.default_num_stages(256) == 8
    assert pairing.default_num_stages(4096) == 12
    assert pairing.default_num_stages(2) == 1


def test_odd_n_leftover_rotates_for_shift():
    """The unpaired coordinate should not always be the same one (§5)."""
    leftovers = {pairing.shift_stage(9, l).leftover for l in range(9)}
    assert len(leftovers) > 1


@settings(max_examples=40, deadline=None)
@given(
    kind=st.sampled_from(ALL_KINDS),
    n=st.integers(min_value=2, max_value=300),
    L=st.integers(min_value=1, max_value=8),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_partition_property(kind, n, L, seed):
    for st_ in pairing.make_schedule(kind, n, L, seed=seed):
        st_.validate(n)


def test_bad_inputs():
    with pytest.raises(ValueError):
        pairing.make_schedule("nope", 8, 2)
    with pytest.raises(ValueError):
        pairing.butterfly_stage(1, 0)
    with pytest.raises(ValueError):
        pairing.shift_stage(0, 0)
