"""Full SPM operator: custom-VJP vs autodiff-of-oracle, operator properties
from paper §2, §5, §8.4."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import pairing, spm
from compile.kernels import ref


def ref_params(params, L):
    return {
        "d_in": params["d_in"], "d_out": params["d_out"], "bias": params["bias"],
        "mix": [params["mix"][l] for l in range(L)],
        "lone": [params["lone"][l] for l in range(L)],
    }


def make(n, variant, schedule="butterfly", L=None, remat=False, seed=0):
    spec = spm.default_spec(n, variant=variant, schedule=schedule, num_stages=L)
    if remat:
        spec = spm.SPMSpec(**{**spec.__dict__, "remat": True})
    params = spm.init_spm_params(jax.random.PRNGKey(seed), spec)
    return spec, params


@pytest.mark.parametrize("variant", ["rotation", "general"])
@pytest.mark.parametrize("n,schedule", [(8, "butterfly"), (33, "shift"), (64, "random")])
def test_forward_matches_oracle(variant, n, schedule):
    spec, params = make(n, variant, schedule)
    x = jax.random.normal(jax.random.PRNGKey(1), (9, n))
    y = spm.spm_apply(spec, params, x)
    yr = ref.spm_fwd(ref_params(params, spec.num_stages), x, spec.stages, variant)
    np.testing.assert_allclose(y, yr, rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("variant", ["rotation", "general"])
@pytest.mark.parametrize("n", [8, 32])
def test_custom_vjp_matches_autodiff_of_oracle(variant, n):
    spec, params = make(n, variant, "shift")
    x = jax.random.normal(jax.random.PRNGKey(2), (6, n))

    def loss_spm(p, xx):
        return jnp.sum(jnp.tanh(spm.spm_apply(spec, p, xx)))

    def loss_ref(p, xx):
        return jnp.sum(jnp.tanh(
            ref.spm_fwd(ref_params(p, spec.num_stages), xx, spec.stages, variant)))

    gp1, gx1 = jax.grad(loss_spm, argnums=(0, 1))(params, x)
    gp2, gx2 = jax.grad(loss_ref, argnums=(0, 1))(params, x)
    np.testing.assert_allclose(gx1, gx2, rtol=1e-4, atol=1e-5)
    for k in ("d_in", "d_out", "bias", "mix"):
        np.testing.assert_allclose(gp1[k], gp2[k], rtol=1e-4, atol=1e-5,
                                   err_msg=f"leaf {k}")


def test_general_remat_matches_stored():
    """remat=True recomputes the trace; gradients must be identical."""
    n = 16
    spec_s, params = make(n, "general")
    spec_r = spm.SPMSpec(n=n, num_stages=spec_s.num_stages, variant="general",
                         schedule="butterfly", remat=True)
    x = jax.random.normal(jax.random.PRNGKey(3), (5, n))

    def loss(spec):
        return jax.grad(lambda p: jnp.sum(spm.spm_apply(spec, p, x) ** 2))(params)

    g1, g2 = loss(spec_s), loss(spec_r)
    for k in g1:
        np.testing.assert_allclose(g1[k], g2[k], rtol=1e-5, atol=1e-6)


def test_rotation_norm_preservation_full_operator():
    """§8.4: with D_in = D_out = I and b = 0, ||SPM(x)|| == ||x||."""
    spec, params = make(128, "rotation")
    x = jax.random.normal(jax.random.PRNGKey(4), (20, 128))
    y = spm.spm_apply(spec, params, x)
    np.testing.assert_allclose(
        jnp.linalg.norm(y, axis=1), jnp.linalg.norm(x, axis=1), rtol=1e-4)


def test_rotation_materialized_matrix_is_orthogonal():
    spec, params = make(32, "rotation")
    W = ref.spm_materialize(ref_params(params, spec.num_stages), 32,
                            spec.stages, "rotation")
    np.testing.assert_allclose(W @ W.T, jnp.eye(32), atol=1e-4)
    # operator norm == 1 (||B_l||_2 = 1 composed, §8.4)
    s = jnp.linalg.svd(W, compute_uv=False)
    np.testing.assert_allclose(s, jnp.ones(32), atol=1e-4)


def test_linearity():
    """SPM minus bias is linear: f(ax+by) = a f(x) + b f(y)."""
    spec, params = make(64, "general")
    key = jax.random.PRNGKey(5)
    x, y = jax.random.normal(key, (2, 3, 64))
    f = lambda v: spm.spm_apply(spec, params, v) - params["bias"]
    lhs = f(2.5 * x - 1.5 * y)
    rhs = 2.5 * f(x) - 1.5 * f(y)
    np.testing.assert_allclose(lhs, rhs, rtol=1e-3, atol=1e-4)


def test_materialize_dense_equivalence():
    """Materialized W applied densely == SPM applied directly."""
    spec, params = make(24, "general", "random")
    x = jax.random.normal(jax.random.PRNGKey(6), (7, 24))
    W = ref.spm_materialize(ref_params(params, spec.num_stages), 24,
                            spec.stages, "general")
    np.testing.assert_allclose(
        spm.spm_apply(spec, params, x), x @ W.T + params["bias"],
        rtol=1e-3, atol=1e-4)


def test_param_count_formula():
    """Paper §5: parameters are O(nL), vs n^2 dense."""
    for n, variant in [(256, "rotation"), (256, "general"), (33, "general")]:
        spec, params = make(n, variant)
        total = sum(int(np.prod(v.shape)) for v in params.values())
        # lone params are carried but only count when odd-n general
        expected = spec.param_count()
        carried = total - expected
        assert carried >= 0 and carried <= spec.num_stages  # unused lone slots
        assert expected < n * n  # strictly below dense for all tested n


def test_odd_n_all_variants():
    for variant in ("rotation", "general"):
        spec, params = make(17, variant, "shift")
        x = jax.random.normal(jax.random.PRNGKey(7), (4, 17))
        y = spm.spm_apply(spec, params, x)
        assert y.shape == (4, 17)
        assert bool(jnp.all(jnp.isfinite(y)))


def test_apply_nd():
    spec, params = make(16, "general")
    x = jax.random.normal(jax.random.PRNGKey(8), (2, 5, 16))
    y = spm.spm_apply_nd(spec, params, x)
    assert y.shape == (2, 5, 16)
    y2 = spm.spm_apply(spec, params, x.reshape(10, 16)).reshape(2, 5, 16)
    np.testing.assert_allclose(y, y2, rtol=1e-6)


def test_shape_errors():
    spec, params = make(16, "general")
    with pytest.raises(ValueError):
        spm.spm_apply(spec, params, jnp.zeros((4, 8)))
    with pytest.raises(ValueError):
        spm.SPMSpec(n=8, num_stages=2, variant="bogus")
    with pytest.raises(ValueError):
        spm.SPMSpec(n=8, num_stages=2, schedule="bogus")
    with pytest.raises(ValueError):
        spm.SPMSpec(n=1, num_stages=2)


@settings(max_examples=15, deadline=None)
@given(
    n=st.integers(min_value=2, max_value=80),
    L=st.integers(min_value=1, max_value=6),
    variant=st.sampled_from(["rotation", "general"]),
    schedule=st.sampled_from(list(pairing.SCHEDULES)),
    seed=st.integers(min_value=0, max_value=999),
)
def test_forward_property(n, L, variant, schedule, seed):
    spec = spm.SPMSpec(n=n, num_stages=L, variant=variant, schedule=schedule,
                       seed=seed % 3)
    params = spm.init_spm_params(jax.random.PRNGKey(seed), spec)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (3, n))
    y = spm.spm_apply(spec, params, x)
    yr = ref.spm_fwd(ref_params(params, L), x, spec.stages, variant)
    np.testing.assert_allclose(y, yr, rtol=2e-4, atol=2e-5)
