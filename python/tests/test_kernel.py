"""L1 Pallas stage kernels vs the pure-jnp oracle — the CORE correctness
signal for the compiled hot path (kernel outputs flow into every artifact).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels import spm_stage as K


def rand(key, *shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


# ---------------------------------------------------------------------------
# Rotation variant
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,P", [(1, 1), (3, 5), (16, 64), (100, 33), (257, 8)])
def test_rotation_fwd_matches_ref(B, P):
    xa, xb = rand(0, B, P), rand(1, B, P)
    theta = rand(2, P)
    ya, yb = K.stage_fwd_rotation(xa, xb, jnp.cos(theta), jnp.sin(theta))
    c, s = jnp.cos(theta), jnp.sin(theta)
    np.testing.assert_allclose(ya, c * xa - s * xb, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(yb, s * xa + c * xb, rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("B,P", [(4, 7), (64, 128)])
def test_rotation_bwd_inputs_is_transpose(B, P):
    """eq. (7)-(8): the input-gradient map is exactly B^T."""
    da, db = rand(3, B, P), rand(4, B, P)
    theta = rand(5, P)
    c, s = jnp.cos(theta), jnp.sin(theta)
    ga, gb = K.stage_bwd_rotation_inputs(da, db, c, s)
    np.testing.assert_allclose(ga, c * da + s * db, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(gb, -s * da + c * db, rtol=1e-5, atol=1e-6)


def test_rotation_bwd_adjoint_identity():
    """<Bx, d> == <x, B^T d> for every pair (transpose consistency)."""
    B, P = 32, 40
    xa, xb, da, db = rand(0, B, P), rand(1, B, P), rand(2, B, P), rand(3, B, P)
    theta = rand(4, P)
    c, s = jnp.cos(theta), jnp.sin(theta)
    ya, yb = K.stage_fwd_rotation(xa, xb, c, s)
    ga, gb = K.stage_bwd_rotation_inputs(da, db, c, s)
    lhs = jnp.sum(ya * da + yb * db)
    rhs = jnp.sum(xa * ga + xb * gb)
    np.testing.assert_allclose(lhs, rhs, rtol=1e-4)


def test_rotation_theta_grad_identity():
    """eq. (9) == delta2*y1 - delta1*y2 (the O(Bn)-memory rewrite)."""
    B, P = 16, 24
    xa, xb, da, db = rand(0, B, P), rand(1, B, P), rand(2, B, P), rand(3, B, P)
    theta = rand(4, P)
    c, s = jnp.cos(theta), jnp.sin(theta)
    ya, yb = K.stage_fwd_rotation(xa, xb, c, s)
    got = K.rotation_theta_grad(da, db, ya, yb)
    # literal eq. (9)
    want = jnp.sum(da * (-s * xa - c * xb) + db * (c * xa - s * xb), axis=0)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_rotation_norm_preserving():
    """Orthogonality: per-sample l2 norm is exactly preserved (§3.1)."""
    B, P = 8, 100
    xa, xb = rand(0, B, P), rand(1, B, P)
    theta = rand(2, P) * 3.0
    ya, yb = K.stage_fwd_rotation(xa, xb, jnp.cos(theta), jnp.sin(theta))
    before = jnp.sum(xa**2 + xb**2, axis=1)
    after = jnp.sum(ya**2 + yb**2, axis=1)
    np.testing.assert_allclose(before, after, rtol=1e-5)


# ---------------------------------------------------------------------------
# General variant
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,P", [(1, 1), (5, 9), (64, 64), (130, 17)])
def test_general_fwd_matches_ref(B, P):
    xa, xb = rand(0, B, P), rand(1, B, P)
    a, b, c, d = rand(2, P), rand(3, P), rand(4, P), rand(5, P)
    ya, yb = K.stage_fwd_general(xa, xb, a, b, c, d)
    np.testing.assert_allclose(ya, a * xa + b * xb, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(yb, c * xa + d * xb, rtol=1e-5, atol=1e-6)


def test_general_bwd_inputs():
    B, P = 12, 30
    da, db = rand(0, B, P), rand(1, B, P)
    a, b, c, d = rand(2, P), rand(3, P), rand(4, P), rand(5, P)
    ga, gb = K.stage_bwd_general_inputs(da, db, a, b, c, d)
    np.testing.assert_allclose(ga, a * da + c * db, rtol=1e-5, atol=1e-6)  # eq. 12
    np.testing.assert_allclose(gb, b * da + d * db, rtol=1e-5, atol=1e-6)  # eq. 13


def test_general_abcd_grad_matches_eq14():
    B, P = 20, 11
    xa, xb, da, db = rand(0, B, P), rand(1, B, P), rand(2, B, P), rand(3, B, P)
    g = K.general_abcd_grad(da, db, xa, xb)
    np.testing.assert_allclose(g[:, 0], jnp.sum(da * xa, 0), rtol=1e-5)
    np.testing.assert_allclose(g[:, 1], jnp.sum(da * xb, 0), rtol=1e-5)
    np.testing.assert_allclose(g[:, 2], jnp.sum(db * xa, 0), rtol=1e-5)
    np.testing.assert_allclose(g[:, 3], jnp.sum(db * xb, 0), rtol=1e-5)


def test_general_subsumes_rotation():
    """§3.2: the general block with (a,b,c,d)=(c,-s,s,c) equals rotation."""
    B, P = 9, 21
    xa, xb = rand(0, B, P), rand(1, B, P)
    theta = rand(2, P)
    c, s = jnp.cos(theta), jnp.sin(theta)
    ya_r, yb_r = K.stage_fwd_rotation(xa, xb, c, s)
    ya_g, yb_g = K.stage_fwd_general(xa, xb, c, -s, s, c)
    np.testing.assert_allclose(ya_r, ya_g, rtol=1e-6)
    np.testing.assert_allclose(yb_r, yb_g, rtol=1e-6)


# ---------------------------------------------------------------------------
# Blocking / padding behaviour
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("block_b", [1, 2, 3, 8])
def test_explicit_block_sizes_agree(block_b):
    """Batch tiling must never change the numbers (incl. ragged tails)."""
    B, P = 13, 6
    xa, xb = rand(0, B, P), rand(1, B, P)
    theta = rand(2, P)
    c, s = jnp.cos(theta), jnp.sin(theta)
    base = K.stage_fwd_rotation(xa, xb, c, s, block_b=B)
    tiled = K.stage_fwd_rotation(xa, xb, c, s, block_b=block_b)
    np.testing.assert_allclose(base[0], tiled[0], rtol=1e-6)
    np.testing.assert_allclose(base[1], tiled[1], rtol=1e-6)


def test_pick_block_b_vmem_budget():
    # huge P forces a small block; tiny P allows the 512 cap
    assert K.pick_block_b(1024, 2048) * 2048 * 4 * 4 <= 8 * 1024 * 1024
    assert K.pick_block_b(1024, 4) == 512
    assert K.pick_block_b(3, 4) == 3 or K.pick_block_b(3, 4) <= 3
    with pytest.raises(ValueError):
        K.pick_block_b(0, 4)


# ---------------------------------------------------------------------------
# Hypothesis sweep: shapes x variants (guide requirement)
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(
    B=st.integers(min_value=1, max_value=70),
    P=st.integers(min_value=1, max_value=70),
    seed=st.integers(min_value=0, max_value=1000),
    variant=st.sampled_from(["rotation", "general"]),
)
def test_kernel_vs_ref_property(B, P, seed, variant):
    k = jax.random.PRNGKey(seed)
    ks = jax.random.split(k, 6)
    xa = jax.random.normal(ks[0], (B, P))
    xb = jax.random.normal(ks[1], (B, P))
    if variant == "rotation":
        theta = jax.random.normal(ks[2], (P,))
        c, s = jnp.cos(theta), jnp.sin(theta)
        ya, yb = K.stage_fwd_rotation(xa, xb, c, s)
        np.testing.assert_allclose(ya, c * xa - s * xb, rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(yb, s * xa + c * xb, rtol=1e-4, atol=1e-5)
    else:
        a, b = jax.random.normal(ks[2], (P,)), jax.random.normal(ks[3], (P,))
        c_, d = jax.random.normal(ks[4], (P,)), jax.random.normal(ks[5], (P,))
        ya, yb = K.stage_fwd_general(xa, xb, a, b, c_, d)
        np.testing.assert_allclose(ya, a * xa + b * xb, rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(yb, c_ * xa + d * xb, rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# Full-stage (permute -> kernel -> unpermute) vs the oracle stage fns
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", [6, 16, 33])
@pytest.mark.parametrize("variant", ["rotation", "general"])
def test_full_stage_vs_oracle(n, variant):
    from compile import pairing, spm as spm_mod

    st_ = pairing.shift_stage(n, 1)
    B = 7
    z = rand(0, B, n)
    lv = st_.leftover
    if variant == "rotation":
        theta = rand(1, n // 2)
        spec = spm_mod.SPMSpec(n=n, num_stages=1, variant="rotation", schedule="shift")
        got = spm_mod._stage_fwd(spec, 1, st_, theta, jnp.ones((1,)), z)
        want = ref.stage_fwd_rotation(z, st_.left, st_.right, lv, theta, jnp.ones((1,)))
    else:
        abcd = rand(1, n // 2, 4)
        spec = spm_mod.SPMSpec(n=n, num_stages=1, variant="general", schedule="shift")
        got = spm_mod._stage_fwd(spec, 1, st_, abcd, jnp.full((1,), 1.3), z)
        want = ref.stage_fwd_general(z, st_.left, st_.right, lv, abcd, jnp.full((1,), 1.3))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# pallas path == jnp path (the AOT artifacts use the latter; see stage_impl)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("variant", ["rotation", "general"])
def test_pallas_and_jnp_impls_agree(variant, monkeypatch):
    B, P = 37, 129
    xa, xb = rand(0, B, P), rand(1, B, P)
    ps = [rand(2 + i, P) for i in range(4)]
    def run():
        if variant == "rotation":
            c, s = jnp.cos(ps[0]), jnp.sin(ps[0])
            return (*K.stage_fwd_rotation(xa, xb, c, s),
                    *K.stage_bwd_rotation_inputs(xa, xb, c, s))
        return (*K.stage_fwd_general(xa, xb, *ps),
                *K.stage_bwd_general_inputs(xa, xb, *ps))
    monkeypatch.setenv("SPM_STAGE_IMPL", "pallas")
    pal = run()
    monkeypatch.setenv("SPM_STAGE_IMPL", "jnp")
    jn = run()
    for a, b in zip(pal, jn):
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6)


def test_full_spm_agrees_across_impls(monkeypatch):
    from compile import spm as spm_mod
    spec = spm_mod.SPMSpec(n=64, num_stages=10, variant="general", schedule="butterfly")
    params = spm_mod.init_spm_params(jax.random.PRNGKey(3), spec)
    x = jax.random.normal(jax.random.PRNGKey(4), (16, 64))
    monkeypatch.setenv("SPM_STAGE_IMPL", "pallas")
    y_pal = spm_mod.spm_apply(spec, params, x)
    monkeypatch.setenv("SPM_STAGE_IMPL", "jnp")
    spm_mod._make_apply.cache_clear()  # retrace with the other impl
    y_jnp = spm_mod.spm_apply(spec, params, x)
    np.testing.assert_allclose(y_pal, y_jnp, rtol=1e-5, atol=1e-6)
    spm_mod._make_apply.cache_clear()
