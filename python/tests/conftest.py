import os
import sys

import jax

# tests import the build-time package directly
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

jax.config.update("jax_platform_name", "cpu")
jax.config.update("jax_enable_x64", False)
