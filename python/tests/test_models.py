"""Model zoo: shapes, GRU/attention semantics vs the paper's equations,
teacher determinism."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile import spm as spm_mod


def mixer(n, kind, **kw):
    return M.MixerCfg(n=n, kind=kind, **kw)


# ---------------------------------------------------------------------------
# Mixer
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", ["dense", "spm"])
def test_mixer_shapes(kind):
    cfg = mixer(32, kind)
    p = M.init_mixer(jax.random.PRNGKey(0), cfg)
    y = M.apply_mixer(cfg, p, jnp.ones((5, 32)))
    assert y.shape == (5, 32)


def test_mixer_param_count_near_linear():
    """§5: SPM param count grows ~nL, dense grows n^2."""
    for n in (64, 256, 1024):
        d = M.mixer_param_count(mixer(n, "dense"))
        s = M.mixer_param_count(mixer(n, "spm"))
        assert d == n * n + n
        assert s < d / 4


# ---------------------------------------------------------------------------
# Classifier
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", ["dense", "spm"])
def test_classifier(kind):
    cfg = M.ClassifierCfg(mixer=mixer(16, kind), num_classes=7)
    p = M.init_classifier(jax.random.PRNGKey(0), cfg)
    logits = M.apply_classifier(cfg, p, jnp.ones((3, 16)))
    assert logits.shape == (3, 7)
    assert bool(jnp.all(jnp.isfinite(logits)))


# ---------------------------------------------------------------------------
# Char LM
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", ["dense", "spm"])
def test_charlm(kind):
    cfg = M.CharLMCfg(mixer=mixer(32, kind, variant="rotation"), seq_len=10)
    p = M.init_charlm(jax.random.PRNGKey(0), cfg)
    toks = jnp.arange(20, dtype=jnp.int32).reshape(2, 10) % 256
    logits = M.apply_charlm(cfg, p, toks)
    assert logits.shape == (2, 10, 256)


# ---------------------------------------------------------------------------
# GRU (§6): dense flavour must equal the literal GRU equations
# ---------------------------------------------------------------------------

def test_gru_dense_matches_equations():
    n = 8
    cfg = M.GRUCfg(mixer=mixer(n, "dense"), num_classes=3)
    p = M.init_gru(jax.random.PRNGKey(1), cfg)
    B, T = 4, 5
    xs = jax.random.normal(jax.random.PRNGKey(2), (B, T, n))

    # literal eqs. (20)-(23)
    sig = jax.nn.sigmoid
    h = jnp.zeros((B, n))
    lin = lambda mp, v: v @ mp["w"].T + mp["b"]
    for t in range(T):
        x_t = xs[:, t, :]
        z = sig(lin(p["w_z"], x_t) + lin(p["u_z"], h) + p["b_z"])
        r = sig(lin(p["w_r"], x_t) + lin(p["u_r"], h) + p["b_r"])
        h_tilde = jnp.tanh(lin(p["w_h"], x_t) + lin(p["u_h"], r * h) + p["b_h"])
        h = (1 - z) * h + z * h_tilde
    want = h @ p["head_w"].T + p["head_b"]

    got = M.apply_gru(cfg, p, xs)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


def test_gru_spm_runs_and_differs_from_zero():
    cfg = M.GRUCfg(mixer=mixer(16, "spm", schedule="shift"), num_classes=3)
    p = M.init_gru(jax.random.PRNGKey(1), cfg)
    xs = jax.random.normal(jax.random.PRNGKey(2), (2, 4, 16))
    out = M.apply_gru(cfg, p, xs)
    assert out.shape == (2, 3)
    assert float(jnp.max(jnp.abs(out))) > 0


def test_gru_spm_gradients_flow_to_all_maps():
    """§6.4: gradients reach every SPM operator's parameters."""
    cfg = M.GRUCfg(mixer=mixer(8, "spm", schedule="shift"), num_classes=2)
    p = M.init_gru(jax.random.PRNGKey(1), cfg)
    xs = jax.random.normal(jax.random.PRNGKey(2), (3, 3, 8))
    g = jax.grad(lambda pp: jnp.sum(M.apply_gru(cfg, pp, xs) ** 2))(p)
    for name in M._GRU_MAPS:
        norm = sum(float(jnp.sum(jnp.abs(v))) for v in jax.tree.leaves(g[name]))
        assert norm > 0, f"no gradient reached {name}"


# ---------------------------------------------------------------------------
# Attention (§7): dense flavour must equal the literal equations
# ---------------------------------------------------------------------------

def test_attention_dense_matches_equations():
    d, h, B, T = 16, 2, 3, 6
    cfg = M.AttentionCfg(mixer=mixer(d, "dense"), num_heads=h)
    p = M.init_attention(jax.random.PRNGKey(3), cfg)
    x = jax.random.normal(jax.random.PRNGKey(4), (B, T, d))

    # literal eqs. (29)-(35), multi-head
    lin = lambda mp, v: v @ mp["w"].T + mp["b"]
    dh = d // h
    q = lin(p["w_q"], x.reshape(-1, d)).reshape(B, T, h, dh)
    k = lin(p["w_k"], x.reshape(-1, d)).reshape(B, T, h, dh)
    v = lin(p["w_v"], x.reshape(-1, d)).reshape(B, T, h, dh)
    want = jnp.zeros((B, T, d))
    outs = []
    for head in range(h):
        s = q[:, :, head] @ jnp.swapaxes(k[:, :, head], 1, 2) / jnp.sqrt(dh)
        a = jax.nn.softmax(s, axis=-1)
        outs.append(a @ v[:, :, head])
    ctx = jnp.stack(outs, axis=2).reshape(B * T, d)
    want = lin(p["w_o"], ctx).reshape(B, T, d)

    got = M.apply_attention(cfg, p, x)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_attention_softmax_backward_closed_form():
    """§7.4: autodiff through row-softmax equals the paper's closed form."""
    T = 5
    s = jax.random.normal(jax.random.PRNGKey(5), (T, T))
    ga = jax.random.normal(jax.random.PRNGKey(6), (T, T))
    a, vjp = jax.vjp(lambda z: jax.nn.softmax(z, axis=-1), s)
    (gs_auto,) = vjp(ga)
    # (G_S)_i = A_i (Ga_i - sum_j A_j Ga_j) rowwise
    inner = jnp.sum(a * ga, axis=-1, keepdims=True)
    gs_paper = a * (ga - inner)
    np.testing.assert_allclose(gs_auto, gs_paper, rtol=1e-5, atol=1e-6)


def test_attention_qk_grads_closed_form():
    """§7.5: G_Q = G_S K / sqrt(dh), G_K = G_S^T Q / sqrt(dh)."""
    T, dh = 4, 8
    q = jax.random.normal(jax.random.PRNGKey(7), (T, dh))
    k = jax.random.normal(jax.random.PRNGKey(8), (T, dh))
    gs = jax.random.normal(jax.random.PRNGKey(9), (T, T))
    f = lambda q_, k_: q_ @ k_.T / jnp.sqrt(dh)
    _, vjp = jax.vjp(f, q, k)
    gq_auto, gk_auto = vjp(gs)
    np.testing.assert_allclose(gq_auto, gs @ k / jnp.sqrt(dh), rtol=1e-5)
    np.testing.assert_allclose(gk_auto, gs.T @ q / jnp.sqrt(dh), rtol=1e-5)


def test_attention_spm_rotation_projections_norm():
    """§7.6: rotation projections preserve l2 norms of each row."""
    d = 32
    cfg = M.AttentionCfg(mixer=mixer(d, "spm", variant="rotation"), num_heads=4)
    p = M.init_attention(jax.random.PRNGKey(10), cfg)
    x = jax.random.normal(jax.random.PRNGKey(11), (2, 3, d))
    spec = cfg.mixer.spec()
    q = spm_mod.spm_apply(spec, p["w_q"], x.reshape(-1, d))
    np.testing.assert_allclose(
        jnp.linalg.norm(q, axis=1),
        jnp.linalg.norm(x.reshape(-1, d), axis=1), rtol=1e-4)


# ---------------------------------------------------------------------------
# Teacher (§9.1)
# ---------------------------------------------------------------------------

def test_teacher_labels_deterministic_and_multiclass():
    cfg = M.TeacherCfg(n=64, num_classes=10)
    p = M.init_teacher(jax.random.PRNGKey(42), cfg)
    x = jax.random.normal(jax.random.PRNGKey(43), (512, 64))
    y1 = M.teacher_labels(cfg, p, x)
    y2 = M.teacher_labels(cfg, p, x)
    assert jnp.array_equal(y1, y2)
    assert y1.dtype == jnp.int32
    # labels should use a healthy number of classes
    assert len(np.unique(np.asarray(y1))) >= 5


# ---------------------------------------------------------------------------
# Hybrid mixer (paper §11 future work: SPM + selective dense interaction)
# ---------------------------------------------------------------------------

def test_hybrid_mixer_shapes_and_decomposition():
    cfg = M.MixerCfg(n=32, kind="hybrid", hybrid_rank=4)
    p = M.init_mixer(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (6, 32))
    y = M.apply_mixer(cfg, p, x)
    assert y.shape == (6, 32)
    # hybrid = spm part + low-rank part, by construction
    spm_part = M.apply_mixer(dataclasses.replace(cfg, kind="spm"), p["spm"], x)
    lowrank = (x @ p["v"].T) @ p["u"].T
    np.testing.assert_allclose(y, spm_part + lowrank, rtol=1e-5, atol=1e-6)


def test_hybrid_param_count_near_linear():
    cfg = M.MixerCfg(n=1024, kind="hybrid", hybrid_rank=16)
    assert M.mixer_param_count(cfg) < 1024 * 1024 / 8  # far below dense


def test_hybrid_classifier_trains():
    from compile import train as T
    cfg = M.ClassifierCfg(mixer=M.MixerCfg(n=16, kind="hybrid", hybrid_rank=4),
                          num_classes=3)
    fns = T.make_flat_fns(lambda k: M.init_classifier(k, cfg),
                          lambda p, x: M.apply_classifier(cfg, p, x),
                          T.classifier_loss, T.AdamCfg(lr=5e-3))
    import jax.numpy as jnp
    x = jax.random.normal(jax.random.PRNGKey(2), (64, 16))
    y = jnp.argmax(x[:, :3], axis=1).astype(jnp.int32)
    params = fns["init"](0)
    nl = fns["nleaves"]
    m = tuple(jnp.zeros_like(p) for p in params)
    v = tuple(jnp.zeros_like(p) for p in params)
    step = jnp.array(0.0)
    train = jax.jit(fns["train"])
    first = None
    last = None
    for _ in range(50):
        out = train(*params, *m, *v, step, x, y)
        params, m, v, step = out[:nl], out[nl:2*nl], out[2*nl:3*nl], out[3*nl]
        if first is None:
            first = float(out[3*nl+1])
        last = float(out[3*nl+1])
    assert last < first * 0.7, (first, last)
