"""AOT round-trip: HLO text produced by aot.py must reload and execute in
XLA with identical numerics to direct-jit execution — this is the exact
interchange contract the rust runtime relies on."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax._src.lib import xla_client as xc

from compile import aot
from compile import model as M
from compile import train as T

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def roundtrip(fn, *args):
    """Lower fn -> HLO text -> reparse -> execute on the jax CPU client."""
    lowered = jax.jit(fn).lower(*(jax.ShapeDtypeStruct(a.shape, a.dtype) for a in args))
    text = aot.to_hlo_text(lowered)
    client = xc._xla.get_default_c_api_client() if hasattr(xc._xla, "get_default_c_api_client") else None
    # Re-parse the text through the XLA computation parser and execute via jax
    backend = jax.devices("cpu")[0].client
    comp = xc._xla.hlo_module_from_text(text) if hasattr(xc._xla, "hlo_module_from_text") else None
    if comp is None:
        pytest.skip("xla_client lacks hlo_module_from_text in this jaxlib")
    exe = backend.compile(xc._xla.XlaComputation(comp.as_serialized_hlo_module_proto()))
    outs = exe.execute_sharded(
        [jax.device_put(a) for a in args]
    )
    return [np.asarray(x[0]) for x in outs.disassemble_into_single_device_arrays()]


def test_hlo_text_is_parseable_and_deterministic():
    f = lambda x: (jnp.sin(x) * 2.0,)
    x = jax.ShapeDtypeStruct((4,), jnp.float32)
    t1 = aot.to_hlo_text(jax.jit(f).lower(x))
    t2 = aot.to_hlo_text(jax.jit(f).lower(x))
    assert t1 == t2
    assert "HloModule" in t1


def test_roundtrip_numerics_simple():
    f = lambda a, b: (a @ b + 1.0, jnp.sum(a))
    a = jnp.arange(6.0, dtype=jnp.float32).reshape(2, 3)
    b = jnp.ones((3, 2), jnp.float32)
    want = f(a, b)
    try:
        got = roundtrip(f, a, b)
    except pytest.skip.Exception:
        raise
    except Exception as e:  # pragma: no cover - depends on jaxlib internals
        pytest.skip(f"xla_client roundtrip unavailable: {e}")
    np.testing.assert_allclose(got[0], want[0], rtol=1e-5)
    np.testing.assert_allclose(got[1], want[1], rtol=1e-5)


# ---------------------------------------------------------------------------
# Manifest integrity (requires `make artifacts` for the "test" set)
# ---------------------------------------------------------------------------

needs_artifacts = pytest.mark.skipif(
    not os.path.exists(os.path.join(ARTIFACTS, "manifest.json")),
    reason="artifacts not built (run `make artifacts`)",
)


@needs_artifacts
def test_manifest_schema():
    with open(os.path.join(ARTIFACTS, "manifest.json")) as f:
        man = json.load(f)
    assert man["format_version"] == 1
    assert len(man["entries"]) >= 1
    for name, e in man["entries"].items():
        assert e["nleaves"] == len(e["leaves"])
        for kind, art in e["artifacts"].items():
            assert os.path.exists(os.path.join(ARTIFACTS, art["file"])), art["file"]
            assert art["inputs"] or kind == "init"
            assert art["outputs"]


@needs_artifacts
def test_manifest_train_signature_matches_convention():
    """train inputs = params + m + v + step + x + y; outputs mirror them."""
    with open(os.path.join(ARTIFACTS, "manifest.json")) as f:
        man = json.load(f)
    for name, e in man["entries"].items():
        if "train" not in e["artifacts"]:
            continue
        nl = e["nleaves"]
        ins = e["artifacts"]["train"]["inputs"]
        outs = e["artifacts"]["train"]["outputs"]
        assert len(ins) == 3 * nl + 3
        assert ins[3 * nl]["name"] == "step"
        assert ins[3 * nl + 1]["name"] == "x"
        assert len(outs) == 3 * nl + 3
        # param shapes should round-trip
        for i in range(nl):
            assert ins[i]["shape"] == outs[i]["shape"], (name, i)


@needs_artifacts
def test_hlo_files_nonempty_and_start_with_module():
    with open(os.path.join(ARTIFACTS, "manifest.json")) as f:
        man = json.load(f)
    some = 0
    for e in man["entries"].values():
        for art in e["artifacts"].values():
            p = os.path.join(ARTIFACTS, art["file"])
            with open(p) as fh:
                head = fh.read(64)
            assert "HloModule" in head
            some += 1
    assert some >= 4


def test_entry_registry_builds():
    aot.ENTRIES.clear()
    aot.register_all()
    names = [e.name for e in aot.ENTRIES]
    assert len(names) == len(set(names)), "duplicate artifact names"
    # every paper table has its entries
    for required in ("table1_dense_n256", "table1_spm_n2048",
                     "table2_spm_n4096", "charlm_dense_d4096",
                     "charlm_spm_d4096", "teacher_n1024"):
        assert required in names
    aot.ENTRIES.clear()
