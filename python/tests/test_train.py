"""Training graphs: Adam math, flat signatures, loss decrease."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile import train as T


def test_softmax_xent_matches_manual():
    logits = jnp.array([[2.0, 1.0, 0.1], [0.0, 0.0, 0.0]])
    labels = jnp.array([0, 2], dtype=jnp.int32)
    got = T.softmax_xent(logits, labels)
    p = jax.nn.softmax(logits)
    want = -(jnp.log(p[0, 0]) + jnp.log(p[1, 2])) / 2
    np.testing.assert_allclose(got, want, rtol=1e-6)


def test_accuracy():
    logits = jnp.array([[1.0, 0.0], [0.0, 1.0], [1.0, 0.0]])
    labels = jnp.array([0, 1, 1], dtype=jnp.int32)
    np.testing.assert_allclose(T.accuracy(logits, labels), 2.0 / 3.0, rtol=1e-6)


def test_adam_matches_manual_numpy():
    """One pytree Adam step vs a hand-rolled numpy Adam on the same grads."""
    cfg = T.AdamCfg(lr=0.01)
    p = {"a": jnp.array([1.0, 2.0]), "b": jnp.array([[3.0]])}
    g = {"a": jnp.array([0.5, -1.0]), "b": jnp.array([[2.0]])}
    m = T.zeros_like_tree(p)
    v = T.zeros_like_tree(p)
    new_p, new_m, new_v, t = T.adam_update(cfg, p, g, m, v, jnp.array(0.0))
    assert float(t) == 1.0
    for k in ("a", "b"):
        gm = 0.1 * np.asarray(g[k])          # (1-b1) g
        gv = 0.001 * np.asarray(g[k]) ** 2   # (1-b2) g^2
        mhat = gm / (1 - 0.9)
        vhat = gv / (1 - 0.999)
        want = np.asarray(p[k]) - 0.01 * mhat / (np.sqrt(vhat) + 1e-8)
        np.testing.assert_allclose(new_p[k], want, rtol=1e-5)
        np.testing.assert_allclose(new_m[k], gm, rtol=1e-6)
        np.testing.assert_allclose(new_v[k], gv, rtol=1e-6)


def test_adam_two_steps_bias_correction():
    cfg = T.AdamCfg(lr=0.1)
    p = {"w": jnp.array([0.0])}
    g = {"w": jnp.array([1.0])}
    m = T.zeros_like_tree(p)
    v = T.zeros_like_tree(p)
    p1, m1, v1, t1 = T.adam_update(cfg, p, g, m, v, jnp.array(0.0))
    p2, _, _, t2 = T.adam_update(cfg, p1, g, m1, v1, t1)
    assert float(t2) == 2.0
    # with constant unit gradient, both steps move ~ -lr
    np.testing.assert_allclose(float(p1["w"][0]), -0.1, atol=1e-6)
    np.testing.assert_allclose(float(p2["w"][0]), -0.2, atol=1e-4)


def test_leaf_names_deterministic():
    p = {"outer": {"z": jnp.zeros(1), "a": jnp.zeros(2)}, "b": jnp.zeros(3)}
    names = T.leaf_names(p)
    assert names == ["b", "outer.a", "outer.z"]  # tree_flatten sorts dict keys


@pytest.mark.parametrize("kind", ["dense", "spm"])
def test_flat_train_step_reduces_loss(kind):
    """A few flat-signature steps on a learnable toy problem."""
    n, C, B = 16, 4, 64
    cfg = M.ClassifierCfg(mixer=M.MixerCfg(n=n, kind=kind, schedule="shift"),
                          num_classes=C)
    fns = T.make_flat_fns(
        lambda key: M.init_classifier(key, cfg),
        lambda p, x: M.apply_classifier(cfg, p, x),
        T.classifier_loss, T.AdamCfg(lr=5e-3))

    # learnable rule: class = argmax over first C coords
    x = jax.random.normal(jax.random.PRNGKey(0), (B, n))
    y = jnp.argmax(x[:, :C], axis=1).astype(jnp.int32)

    params = fns["init"](0)
    nl = fns["nleaves"]
    m = tuple(jnp.zeros_like(p) for p in params)
    v = tuple(jnp.zeros_like(p) for p in params)
    step = jnp.array(0.0)

    train = jax.jit(fns["train"])
    losses = []
    for _ in range(60):
        out = train(*params, *m, *v, step, x, y)
        params, m, v = out[:nl], out[nl:2 * nl], out[2 * nl:3 * nl]
        step, loss = out[3 * nl], out[3 * nl + 1]
        losses.append(float(loss))
    assert losses[-1] < losses[0] * 0.7, losses[::10]

    ev = jax.jit(fns["eval"])
    loss, acc = ev(*params, x, y)
    assert float(acc) > 0.5


def test_flat_eval_matches_train_loss_at_same_params():
    n, C, B = 8, 3, 16
    cfg = M.ClassifierCfg(mixer=M.MixerCfg(n=n, kind="dense"), num_classes=C)
    fns = T.make_flat_fns(
        lambda key: M.init_classifier(key, cfg),
        lambda p, x: M.apply_classifier(cfg, p, x),
        T.classifier_loss, T.AdamCfg())
    params = fns["init"](3)
    nl = fns["nleaves"]
    x = jax.random.normal(jax.random.PRNGKey(1), (B, n))
    y = jnp.zeros((B,), jnp.int32)
    m = tuple(jnp.zeros_like(p) for p in params)
    v = tuple(jnp.zeros_like(p) for p in params)
    out = fns["train"](*params, *m, *v, jnp.array(0.0), x, y)
    train_loss = float(out[3 * nl + 1])
    eval_loss = float(fns["eval"](*params, x, y)[0])
    np.testing.assert_allclose(train_loss, eval_loss, rtol=1e-5)


def test_charlm_loss_is_nll_nats():
    V = 8
    logits = jnp.zeros((2, 3, V))  # uniform -> NLL = ln V
    targets = jnp.zeros((2, 3), jnp.int32)
    nll, metric = T.charlm_loss(logits, targets)
    np.testing.assert_allclose(nll, jnp.log(V), rtol=1e-6)
    np.testing.assert_allclose(metric, nll)
