"""Pure-jnp reference oracle for SPM (paper §2-§4).

Everything here is written in the most literal way possible — explicit
gathers, explicit per-pair 2x2 math following equations (2)-(19) of the
paper — so that the Pallas kernels and the rust implementation both have an
unambiguous ground truth to match.  No pallas, no custom_vjp, no cleverness.

Parameter conventions (shared across python and rust):

* rotation variant (paper §3.1): per stage, ``theta`` of shape ``(P,)``
  (``P = floor(n/2)`` pairs).
* general variant (paper §3.2): per stage, ``abcd`` of shape ``(P, 4)``
  laid out ``[a, b, c, d]``.
* odd-n leftover coordinate: mixed by a learned 1x1 scale, one scalar per
  stage (paper §5 option (ii)); shape ``(1,)`` (present even for even n,
  unused, to keep pytrees static).
* full operator: ``d_in (n,)``, ``d_out (n,)``, ``bias (n,)`` and the
  per-stage mixing parameters (paper §2.1).
"""

from __future__ import annotations

import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Per-stage forward (eqs. 5-6 / 10-11)
# ---------------------------------------------------------------------------

def stage_fwd_rotation(x, left, right, leftover, theta, lone_scale):
    """One rotation stage applied to ``x`` of shape (..., n)."""
    x1 = x[..., left]
    x2 = x[..., right]
    c = jnp.cos(theta)
    s = jnp.sin(theta)
    y1 = c * x1 - s * x2  # eq. (5)
    y2 = s * x1 + c * x2  # eq. (6)
    y = jnp.zeros_like(x)
    y = y.at[..., left].set(y1)
    y = y.at[..., right].set(y2)
    if leftover is not None:
        y = y.at[..., leftover].set(lone_scale[0] * x[..., leftover])
    return y


def stage_fwd_general(x, left, right, leftover, abcd, lone_scale):
    """One general 2x2 stage applied to ``x`` of shape (..., n)."""
    x1 = x[..., left]
    x2 = x[..., right]
    a, b, c, d = abcd[:, 0], abcd[:, 1], abcd[:, 2], abcd[:, 3]
    y1 = a * x1 + b * x2  # eq. (10)
    y2 = c * x1 + d * x2  # eq. (11)
    y = jnp.zeros_like(x)
    y = y.at[..., left].set(y1)
    y = y.at[..., right].set(y2)
    if leftover is not None:
        y = y.at[..., leftover].set(lone_scale[0] * x[..., leftover])
    return y


# ---------------------------------------------------------------------------
# Per-stage backward (eqs. 7-9 / 12-14), closed form per the paper
# ---------------------------------------------------------------------------

def stage_bwd_rotation(x, g, left, right, leftover, theta, lone_scale):
    """Returns (g_x, g_theta, g_lone) for one rotation stage.

    ``x`` is the *stage input*, ``g`` the gradient w.r.t. the stage output.
    Batch dims are summed into the parameter gradients (paper §4, batch
    setting).
    """
    x1 = x[..., left]
    x2 = x[..., right]
    d1 = g[..., left]
    d2 = g[..., right]
    c = jnp.cos(theta)
    s = jnp.sin(theta)
    gx1 = c * d1 + s * d2     # eq. (7)
    gx2 = -s * d1 + c * d2    # eq. (8)
    # eq. (9)
    gth = d1 * (-s * x1 - c * x2) + d2 * (c * x1 - s * x2)
    bdims = tuple(range(x.ndim - 1))
    g_theta = jnp.sum(gth, axis=bdims) if bdims else gth
    gx = jnp.zeros_like(x)
    gx = gx.at[..., left].set(gx1)
    gx = gx.at[..., right].set(gx2)
    g_lone = jnp.zeros((1,), x.dtype)
    if leftover is not None:
        gx = gx.at[..., leftover].set(lone_scale[0] * g[..., leftover])
        gl = g[..., leftover] * x[..., leftover]
        g_lone = (jnp.sum(gl) if bdims else gl).reshape(1)
    return gx, g_theta, g_lone


def stage_bwd_general(x, g, left, right, leftover, abcd, lone_scale):
    """Returns (g_x, g_abcd, g_lone) for one general stage."""
    x1 = x[..., left]
    x2 = x[..., right]
    d1 = g[..., left]
    d2 = g[..., right]
    a, b, c, d = abcd[:, 0], abcd[:, 1], abcd[:, 2], abcd[:, 3]
    gx1 = a * d1 + c * d2  # eq. (12)
    gx2 = b * d1 + d * d2  # eq. (13)
    bdims = tuple(range(x.ndim - 1))
    # eq. (14)
    ga = jnp.sum(d1 * x1, axis=bdims)
    gb = jnp.sum(d1 * x2, axis=bdims)
    gc = jnp.sum(d2 * x1, axis=bdims)
    gd = jnp.sum(d2 * x2, axis=bdims)
    g_abcd = jnp.stack([ga, gb, gc, gd], axis=-1)
    gx = jnp.zeros_like(x)
    gx = gx.at[..., left].set(gx1)
    gx = gx.at[..., right].set(gx2)
    g_lone = jnp.zeros((1,), x.dtype)
    if leftover is not None:
        gx = gx.at[..., leftover].set(lone_scale[0] * g[..., leftover])
        gl = g[..., leftover] * x[..., leftover]
        g_lone = (jnp.sum(gl) if bdims else gl).reshape(1)
    return gx, g_abcd, g_lone


# ---------------------------------------------------------------------------
# Full operator (eqs. 2-4) and its materialization
# ---------------------------------------------------------------------------

def spm_fwd(params, x, stages, variant):
    """Full SPM forward: y = D_out (prod_l B_l) D_in x + bias.

    ``params`` is a dict with keys ``d_in``, ``d_out``, ``bias``, ``mix``
    (list of per-stage theta/abcd), ``lone`` (list of per-stage 1x1 scales).
    ``stages`` is a list of StagePairing.  Returns ``y``.
    """
    z = params["d_in"] * x  # eq. (2)
    for l, st in enumerate(stages):  # eq. (3)
        lv = None if st.leftover is None else int(st.leftover)
        if variant == "rotation":
            z = stage_fwd_rotation(z, st.left, st.right, lv,
                                   params["mix"][l], params["lone"][l])
        else:
            z = stage_fwd_general(z, st.left, st.right, lv,
                                  params["mix"][l], params["lone"][l])
    return params["d_out"] * z + params["bias"]  # eq. (4)


def spm_materialize(params, n, stages, variant):
    """Materialize the full n x n matrix W with SPM(x) = W x + bias.

    Used by tests to check dense-equivalence and operator-norm properties
    (paper §8.4).  O(n^2 L) — test-only.
    """
    eye = jnp.eye(n, dtype=jnp.float32)
    cols = spm_fwd(params, eye, stages, variant) - params["bias"]
    # row k of `cols` is SPM(e_k) = W e_k = column k of W
    return jnp.transpose(cols)


def dense_fwd(w, b, x):
    """The dense comparator: y = x @ W^T + b (paper §1)."""
    return x @ w.T + b
