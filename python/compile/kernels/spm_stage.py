"""L1 Pallas kernels: the SPM stage hot-spot (paper §3).

Layout strategy (DESIGN.md §2 "Hardware adaptation"): the per-stage pairing
is compiled into a *static permutation outside the kernel*, so the kernel
itself never gathers.  It sees two contiguous half-tensors

    xa = z[:, left]   (B, P)
    xb = z[:, right]  (B, P)

and performs the pure elementwise 2x2 mix over ``(block_b, P)`` tiles:

    rotation (eqs. 5-6):   ya = cos*xa - sin*xb ;  yb = sin*xa + cos*xb
    general  (eqs. 10-11): ya = a*xa + b*xb     ;  yb = c*xa + d*xb

The grid walks the batch dimension; each grid step streams one
``(block_b, P)`` slab of each operand HBM->VMEM, mixes with 4-6 VPU FMAs
per element, and writes back.  VMEM footprint per step is
``(2 inputs + 2 outputs) * block_b * P * 4B + params`` — for the paper's
largest configuration (n=4096 => P=2048, block_b=256) that is ~8.4 MiB;
``block_b`` is chosen per width to stay under ~8 MiB (see ``pick_block_b``).

TPU note: the op is elementwise, so the MXU is idle by design — the roofline
is memory bandwidth, and the BlockSpec schedule above is exactly the
HBM<->VMEM streaming plan.  ``interpret=True`` everywhere: the CPU PJRT
client cannot execute Mosaic custom-calls, and interpret-mode lowers to
plain HLO that both pytest and the rust runtime can run.

Backward kernels implement the closed-form input gradients (eqs. 7-8 /
12-13).  Parameter gradients need a cross-batch reduction; the kernels emit
the elementwise integrand and the (jnp) wrapper reduces — XLA fuses the
reduction with the kernel output, so nothing is materialized beyond one
slab.  For the rotation variant the wrapper exploits the identity

    dL/dtheta = delta2 * y1 - delta1 * y2        (eq. 9 rewritten)

so the backward needs only the stage *outputs*, enabling O(Bn)-memory
backprop through the whole operator (see spm.py).
"""

from __future__ import annotations

import functools
import math
import os

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# VMEM budget per grid step (bytes); block_b is chosen to respect it.
_VMEM_BUDGET = 8 * 1024 * 1024


def stage_impl() -> str:
    """Which stage implementation to trace into the graph.

    * ``"pallas"`` (default) — the kernels below, interpret=True. This is
      the TPU-authoring path and what pytest verifies against the oracle.
    * ``"jnp"`` — identical math as plain jnp elementwise ops. Used by
      aot.py for the artifacts the rust runtime executes: the bundled
      xla_extension 0.5.1 runtime mis-executes deep compositions of the
      interpret-mode grid machinery at some (n, L) shapes (returns zeros;
      see EXPERIMENTS.md §Perf for the bisect), and the fused elementwise
      HLO is also faster on CPU. Numerics of the two paths are asserted
      equal in python/tests/test_kernel.py.
    """
    return os.environ.get("SPM_STAGE_IMPL", "pallas")


def pick_block_b(batch: int, num_pairs: int, n_operands: int = 4) -> int:
    """Largest power-of-two batch tile keeping the slab under the VMEM budget."""
    if batch <= 0:
        raise ValueError("batch must be positive")
    per_row = max(1, n_operands * num_pairs * 4)
    bb = _VMEM_BUDGET // per_row
    bb = 1 << max(0, int(math.floor(math.log2(bb)))) if bb >= 1 else 1
    return int(max(1, min(bb, batch, 512)))


def _pad_batch(arrs, block_b):
    b = arrs[0].shape[0]
    pb = (-b) % block_b
    if pb == 0:
        return arrs, b
    return [jnp.pad(a, ((0, pb), (0, 0))) for a in arrs], b


# ---------------------------------------------------------------------------
# Rotation variant (paper §3.1)
# ---------------------------------------------------------------------------

def _rot_fwd_kernel(cos_ref, sin_ref, xa_ref, xb_ref, ya_ref, yb_ref):
    c = cos_ref[...]
    s = sin_ref[...]
    xa = xa_ref[...]
    xb = xb_ref[...]
    ya_ref[...] = c * xa - s * xb  # eq. (5)
    yb_ref[...] = s * xa + c * xb  # eq. (6)


def _rot_bwd_kernel(cos_ref, sin_ref, da_ref, db_ref, ga_ref, gb_ref):
    c = cos_ref[...]
    s = sin_ref[...]
    da = da_ref[...]
    db = db_ref[...]
    ga_ref[...] = c * da + s * db   # eq. (7)
    gb_ref[...] = -s * da + c * db  # eq. (8)


# ---------------------------------------------------------------------------
# General 2x2 variant (paper §3.2)
# ---------------------------------------------------------------------------

def _gen_fwd_kernel(a_ref, b_ref, c_ref, d_ref, xa_ref, xb_ref, ya_ref, yb_ref):
    xa = xa_ref[...]
    xb = xb_ref[...]
    ya_ref[...] = a_ref[...] * xa + b_ref[...] * xb  # eq. (10)
    yb_ref[...] = c_ref[...] * xa + d_ref[...] * xb  # eq. (11)


def _gen_bwd_kernel(a_ref, b_ref, c_ref, d_ref, da_ref, db_ref, ga_ref, gb_ref):
    da = da_ref[...]
    db = db_ref[...]
    ga_ref[...] = a_ref[...] * da + c_ref[...] * db  # eq. (12)
    gb_ref[...] = b_ref[...] * da + d_ref[...] * db  # eq. (13)


# ---------------------------------------------------------------------------
# pallas_call wrappers
# ---------------------------------------------------------------------------

def _mix_call(kernel, params, halves, block_b=None):
    """Run an elementwise pair-mix kernel over (B, P) halves.

    ``params``: list of (P,) vectors broadcast to every batch tile.
    ``halves``: list of (B, P) arrays.
    Returns two (B, P) outputs.
    """
    P = halves[0].shape[1]
    if block_b is None:
        block_b = pick_block_b(halves[0].shape[0], P)
    halves, b0 = _pad_batch(list(halves), block_b)
    bpad = halves[0].shape[0]
    grid = (bpad // block_b,)
    # params live in one (1, P) row so TPU tiling stays 2D
    params = [p.reshape(1, P) for p in params]
    param_spec = pl.BlockSpec((1, P), lambda i: (0, 0))
    half_spec = pl.BlockSpec((block_b, P), lambda i: (i, 0))
    out_shape = [jax.ShapeDtypeStruct((bpad, P), halves[0].dtype)] * 2
    ya, yb = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[param_spec] * len(params) + [half_spec] * len(halves),
        out_specs=[half_spec, half_spec],
        out_shape=out_shape,
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(*params, *halves)
    return ya[:b0], yb[:b0]


def stage_fwd_rotation(xa, xb, cos, sin, block_b=None):
    """Forward rotation mix on contiguous halves: returns (ya, yb)."""
    if stage_impl() == "jnp":
        return cos * xa - sin * xb, sin * xa + cos * xb
    return _mix_call(_rot_fwd_kernel, [cos, sin], [xa, xb], block_b)


def stage_bwd_rotation_inputs(da, db, cos, sin, block_b=None):
    """Closed-form input gradients (eqs. 7-8): returns (gxa, gxb)."""
    if stage_impl() == "jnp":
        return cos * da + sin * db, -sin * da + cos * db
    return _mix_call(_rot_bwd_kernel, [cos, sin], [da, db], block_b)


def stage_fwd_general(xa, xb, a, b, c, d, block_b=None):
    """Forward general mix on contiguous halves: returns (ya, yb)."""
    if stage_impl() == "jnp":
        return a * xa + b * xb, c * xa + d * xb
    return _mix_call(_gen_fwd_kernel, [a, b, c, d], [xa, xb], block_b)


def stage_bwd_general_inputs(da, db, a, b, c, d, block_b=None):
    """Closed-form input gradients (eqs. 12-13): returns (gxa, gxb)."""
    if stage_impl() == "jnp":
        return a * da + c * db, b * da + d * db
    return _mix_call(_gen_bwd_kernel, [a, b, c, d], [da, db], block_b)


# ---------------------------------------------------------------------------
# Parameter-gradient integrands (reduced by the caller; XLA fuses)
# ---------------------------------------------------------------------------

def rotation_theta_grad(da, db, ya, yb):
    """eq. (9) via outputs: dL/dtheta_k = sum_batch (db*ya - da*yb)."""
    return jnp.sum(db * ya - da * yb, axis=0)


def general_abcd_grad(da, db, xa, xb):
    """eq. (14): per-pair [ga, gb, gc, gd] stacked on the last axis."""
    return jnp.stack(
        [
            jnp.sum(da * xa, axis=0),
            jnp.sum(da * xb, axis=0),
            jnp.sum(db * xa, axis=0),
            jnp.sum(db * xb, axis=0),
        ],
        axis=-1,
    )
