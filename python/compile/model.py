"""L2 model zoo: every network the paper trains or describes.

All models follow one convention:

    cfg     — frozen dataclass (static; hashable; goes in the manifest)
    init(key, cfg)            -> params (pytree of jnp arrays)
    apply(cfg, params, *ins)  -> outputs

Each model exists in a ``"dense"`` and an ``"spm"`` flavour; the only
difference is the implementation of its square linear maps, exactly the
paper's drop-in-replacement protocol (§2, §6.2, §7.2):

  * ``Classifier`` — the Table 1/2 student: mixer(n->n) -> ReLU -> head.
  * ``CharLM``     — the Table 3/4 char-level LM: embed -> mixer(d->d)
                     -> ReLU -> vocab head.
  * ``GRU``        — §6: gated recurrent unit whose six square maps
                     (W_z, U_z, W_r, U_r, W_h, U_h) are dense or SPM.
  * ``Attention``  — §7: scaled dot-product attention whose Q/K/V/O
                     projections are dense or SPM.

Rectangular maps (class heads, embeddings) stay dense in both flavours —
the paper only replaces square projections.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from . import spm as spm_mod


# ---------------------------------------------------------------------------
# The square linear map: dense or SPM
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MixerCfg:
    """Configuration of one square n->n linear map.

    Kinds:
      * ``"dense"``  — the paper's baseline, y = W x + b.
      * ``"spm"``    — the paper's operator (§2).
      * ``"hybrid"`` — paper §11 future work: SPM interleaved with a
        *selective* dense transformation. Implemented as
        ``y = SPM(x) + U V x`` with a rank-``hybrid_rank`` bottleneck
        (V: n→k, U: k→n), preserving near-linear cost O(nL + nk) while
        restoring a controlled amount of instantaneous global interaction.
    """

    n: int
    kind: str = "spm"  # "dense" | "spm" | "hybrid"
    variant: str = "general"
    schedule: str = "butterfly"
    num_stages: int | None = None  # default: log2(n)
    seed: int = 0
    hybrid_rank: int = 16

    def spec(self) -> spm_mod.SPMSpec:
        return spm_mod.default_spec(
            self.n, variant=self.variant, schedule=self.schedule,
            num_stages=self.num_stages, seed=self.seed,
        )

    def stages(self) -> int:
        return self.spec().num_stages


def init_mixer(key, cfg: MixerCfg):
    if cfg.kind == "dense":
        kw, _ = jax.random.split(key)
        scale = 1.0 / jnp.sqrt(cfg.n)
        return {
            "w": jax.random.normal(kw, (cfg.n, cfg.n)) * scale,
            "b": jnp.zeros((cfg.n,)),
        }
    if cfg.kind == "hybrid":
        k1, k2, k3 = jax.random.split(key, 3)
        r = cfg.hybrid_rank
        return {
            "spm": spm_mod.init_spm_params(k1, cfg.spec()),
            "u": jax.random.normal(k2, (cfg.n, r)) / jnp.sqrt(r),
            "v": jax.random.normal(k3, (r, cfg.n)) / jnp.sqrt(cfg.n),
        }
    return spm_mod.init_spm_params(key, cfg.spec())


def apply_mixer(cfg: MixerCfg, params, x):
    """x: (B, n) -> (B, n)."""
    if cfg.kind == "dense":
        return x @ params["w"].T + params["b"]
    if cfg.kind == "hybrid":
        structured = spm_mod.spm_apply(cfg.spec(), params["spm"], x)
        return structured + (x @ params["v"].T) @ params["u"].T
    return spm_mod.spm_apply(cfg.spec(), params, x)


def mixer_param_count(cfg: MixerCfg) -> int:
    if cfg.kind == "dense":
        return cfg.n * cfg.n + cfg.n
    if cfg.kind == "hybrid":
        return cfg.spec().param_count() + 2 * cfg.n * cfg.hybrid_rank
    return cfg.spec().param_count()


# ---------------------------------------------------------------------------
# Classifier (Tables 1 & 2 student)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ClassifierCfg:
    mixer: MixerCfg
    num_classes: int

    @property
    def n(self) -> int:
        return self.mixer.n


def init_classifier(key, cfg: ClassifierCfg):
    k1, k2 = jax.random.split(key)
    scale = 1.0 / jnp.sqrt(cfg.n)
    return {
        "mixer": init_mixer(k1, cfg.mixer),
        "head_w": jax.random.normal(k2, (cfg.num_classes, cfg.n)) * scale,
        "head_b": jnp.zeros((cfg.num_classes,)),
    }


def apply_classifier(cfg: ClassifierCfg, params, x):
    """x: (B, n) -> logits (B, C)."""
    h = jax.nn.relu(apply_mixer(cfg.mixer, params["mixer"], x))
    return h @ params["head_w"].T + params["head_b"]


# ---------------------------------------------------------------------------
# Char-level language model (Tables 3 & 4)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class CharLMCfg:
    mixer: MixerCfg
    vocab: int = 256
    seq_len: int = 128

    @property
    def d(self) -> int:
        return self.mixer.n


def init_charlm(key, cfg: CharLMCfg):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "embed": jax.random.normal(k1, (cfg.vocab, cfg.d)) * 0.02,
        "mixer": init_mixer(k2, cfg.mixer),
        "head_w": jax.random.normal(k3, (cfg.vocab, cfg.d)) / jnp.sqrt(cfg.d),
        "head_b": jnp.zeros((cfg.vocab,)),
    }


def apply_charlm(cfg: CharLMCfg, params, tokens):
    """tokens: (B, T) int32 -> logits (B, T, V).

    Matches the paper's §9.3 architecture: one large d x d projection
    (dense baseline vs SPM butterfly L=12) between embedding and head.
    """
    B, T = tokens.shape
    h = params["embed"][tokens]  # (B, T, d)
    h = apply_mixer(cfg.mixer, params["mixer"], h.reshape(B * T, cfg.d))
    h = jax.nn.relu(h).reshape(B, T, cfg.d)
    return h @ params["head_w"].T + params["head_b"]


# ---------------------------------------------------------------------------
# GRU (§6) — six square maps replaced wholesale
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class GRUCfg:
    mixer: MixerCfg  # template; each of the 6 maps gets its own params
    num_classes: int

    @property
    def n(self) -> int:
        return self.mixer.n


_GRU_MAPS = ("w_z", "u_z", "w_r", "u_r", "w_h", "u_h")


def init_gru(key, cfg: GRUCfg):
    keys = jax.random.split(key, len(_GRU_MAPS) + 2)
    params = {name: init_mixer(k, dataclasses.replace(cfg.mixer, seed=cfg.mixer.seed + i))
              for i, (name, k) in enumerate(zip(_GRU_MAPS, keys))}
    n = cfg.n
    params["b_z"] = jnp.zeros((n,))
    params["b_r"] = jnp.zeros((n,))
    params["b_h"] = jnp.zeros((n,))
    scale = 1.0 / jnp.sqrt(n)
    params["head_w"] = jax.random.normal(keys[-2], (cfg.num_classes, n)) * scale
    params["head_b"] = jnp.zeros((cfg.num_classes,))
    return params


def _gru_cell(cfg: GRUCfg, p, h_prev, x_t):
    """Eqs. (20)-(23) with every dense map swapped per §6.2."""
    mc = lambda i: dataclasses.replace(cfg.mixer, seed=cfg.mixer.seed + i)
    z = jax.nn.sigmoid(apply_mixer(mc(0), p["w_z"], x_t)
                       + apply_mixer(mc(1), p["u_z"], h_prev) + p["b_z"])
    r = jax.nn.sigmoid(apply_mixer(mc(2), p["w_r"], x_t)
                       + apply_mixer(mc(3), p["u_r"], h_prev) + p["b_r"])
    h_tilde = jnp.tanh(apply_mixer(mc(4), p["w_h"], x_t)
                       + apply_mixer(mc(5), p["u_h"], r * h_prev) + p["b_h"])
    return (1.0 - z) * h_prev + z * h_tilde


def apply_gru(cfg: GRUCfg, params, xs):
    """xs: (B, T, n) -> logits (B, C) from the final hidden state."""
    B, T, n = xs.shape
    h = jnp.zeros((B, n))
    # python loop (static unroll): keeps SPM pairings static per call site
    for t in range(T):
        h = _gru_cell(cfg, params, h, xs[:, t, :])
    return h @ params["head_w"].T + params["head_b"]


# ---------------------------------------------------------------------------
# Attention (§7) — Q/K/V/O projections replaced
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AttentionCfg:
    mixer: MixerCfg  # template for the four projections
    num_heads: int = 4

    @property
    def d(self) -> int:
        return self.mixer.n


_ATTN_MAPS = ("w_q", "w_k", "w_v", "w_o")


def init_attention(key, cfg: AttentionCfg):
    keys = jax.random.split(key, len(_ATTN_MAPS))
    return {name: init_mixer(k, dataclasses.replace(cfg.mixer, seed=cfg.mixer.seed + i))
            for i, (name, k) in enumerate(zip(_ATTN_MAPS, keys))}


def apply_attention(cfg: AttentionCfg, params, x):
    """x: (B, T, d) -> (B, T, d). Eqs. (29)-(35) with SPM projections."""
    B, T, d = x.shape
    h = cfg.num_heads
    dh = d // h
    mc = lambda i: dataclasses.replace(cfg.mixer, seed=cfg.mixer.seed + i)
    flat = x.reshape(B * T, d)
    q = apply_mixer(mc(0), params["w_q"], flat).reshape(B, T, h, dh)
    k = apply_mixer(mc(1), params["w_k"], flat).reshape(B, T, h, dh)
    v = apply_mixer(mc(2), params["w_v"], flat).reshape(B, T, h, dh)
    # (B, h, T, T) scores, eq. (32)
    s = jnp.einsum("bthd,bshd->bhts", q, k) / jnp.sqrt(dh)
    a = jax.nn.softmax(s, axis=-1)  # eq. (33)
    ctx = jnp.einsum("bhts,bshd->bthd", a, v).reshape(B * T, d)  # eq. (34)
    return apply_mixer(mc(3), params["w_o"], ctx).reshape(B, T, d)  # eq. (35)


# ---------------------------------------------------------------------------
# Compositional teacher (§9.1): SPM -> ReLU -> Dense -> argmax
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TeacherCfg:
    n: int
    num_classes: int = 10
    num_stages: int | None = None
    schedule: str = "butterfly"
    seed: int = 7


def _teacher_spec(cfg: TeacherCfg) -> spm_mod.SPMSpec:
    return spm_mod.default_spec(
        cfg.n, variant="general", schedule=cfg.schedule,
        num_stages=cfg.num_stages, seed=cfg.seed,
    )


def init_teacher(key, cfg: TeacherCfg):
    k1, k2, k3 = jax.random.split(key, 3)
    p = spm_mod.init_spm_params(k1, _teacher_spec(cfg))
    # a non-trivial teacher: random rotations + random diagonal emphasis
    p["d_in"] = 1.0 + 0.5 * jax.random.normal(k2, (cfg.n,))
    return {
        "spm": p,
        "w2": jax.random.normal(k3, (cfg.num_classes, cfg.n)) / jnp.sqrt(cfg.n),
    }


def teacher_logits(cfg: TeacherCfg, params, x):
    h = jax.nn.relu(spm_mod.spm_apply(_teacher_spec(cfg), params["spm"], x))
    return h @ params["w2"].T


def teacher_labels(cfg: TeacherCfg, params, x):
    """Hard labels, §9.1: argmax_k of the teacher logits."""
    return jnp.argmax(teacher_logits(cfg, params, x), axis=-1).astype(jnp.int32)
