"""AOT lowering driver: python runs ONCE, at build time, and never again.

For every experiment configuration this script lowers four flat-signature
functions (init / train / eval / forward, see train.py) to **HLO text** and
writes them to ``artifacts/`` together with ``manifest.json`` describing
each artifact's inputs/outputs and the parameter-leaf layout.

HLO *text* — not ``lowered.compile()`` output, not a serialized
``HloModuleProto`` — is the interchange format: jax >= 0.5 serializes protos
with 64-bit instruction ids that the ``xla`` crate's xla_extension 0.5.1
rejects (``proto.id() <= INT_MAX``); the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Outputs are lowered **untupled** (``return_tuple=False``) so the PJRT
runtime hands the rust side one buffer per output; parameters and optimizer
state stay resident on device across the whole training run.

Usage:
    python -m compile.aot --out-dir ../artifacts [--sets table1,charlm,...]
"""

from __future__ import annotations

import argparse
import dataclasses
import hashlib
import json
import os
import time

# AOT artifacts use the plain-jnp stage math: the xla_extension 0.5.1
# runtime the rust side links against mis-executes deep compositions of
# interpret-mode pallas grid loops at some (n, L) shapes (silent zeros),
# and the fused elementwise HLO is faster on CPU anyway. The pallas path
# remains the TPU-authoring path, pytest-verified against the oracle AND
# against this path.
os.environ.setdefault("SPM_STAGE_IMPL", "jnp")

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M
from . import train as T

jax.config.update("jax_platform_name", "cpu")

F32 = jnp.float32
I32 = jnp.int32


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=False
    )
    # print_large_constants=True is LOAD-BEARING: the default printer elides
    # big constant literals as `constant({...})` and the xla_extension 0.5.1
    # text parser silently materializes those as ZEROS — corrupting e.g. the
    # SPM pairing-permutation index arrays (diagnosed in EXPERIMENTS.md §Perf).
    return comp.as_hlo_text(print_large_constants=True)


def spec(shape, dtype=F32):
    return jax.ShapeDtypeStruct(shape, dtype)


# ---------------------------------------------------------------------------
# Entry registry
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Entry:
    """One model configuration -> up to four artifacts."""

    name: str
    sets: tuple[str, ...]
    init_fn: object
    apply_fn: object
    loss: object
    x_spec: jax.ShapeDtypeStruct
    y_spec: jax.ShapeDtypeStruct
    meta: dict
    adam: T.AdamCfg = dataclasses.field(default_factory=T.AdamCfg)
    emit: tuple[str, ...] = ("init", "train", "eval", "forward")


ENTRIES: list[Entry] = []


def classifier_entry(name, sets, n, num_classes, kind, batch,
                     variant="general", schedule="butterfly",
                     num_stages=None, seed=0, lr=1e-3, **extra):
    mixer = M.MixerCfg(n=n, kind=kind, variant=variant, schedule=schedule,
                       num_stages=num_stages, seed=seed)
    cfg = M.ClassifierCfg(mixer=mixer, num_classes=num_classes)
    meta = {
        "model": "classifier", "n": n, "num_classes": num_classes,
        "kind": kind, "batch": batch,
        "variant": variant, "schedule": schedule,
        "num_stages": mixer.stages() if kind == "spm" else 0,
        "fingerprint": mixer.spec().fingerprint() if kind == "spm" else "",
        "param_count": None,  # filled at build
        **extra,
    }
    ENTRIES.append(Entry(
        name=name, sets=tuple(sets),
        init_fn=lambda key: M.init_classifier(key, cfg),
        apply_fn=lambda p, x: M.apply_classifier(cfg, p, x),
        loss=T.classifier_loss,
        x_spec=spec((batch, n)), y_spec=spec((batch,), I32),
        meta=meta, adam=T.AdamCfg(lr=lr),
    ))


def charlm_entry(name, sets, d, kind, batch, seq_len,
                 variant="rotation", schedule="butterfly",
                 num_stages=None, seed=0, lr=1e-3):
    mixer = M.MixerCfg(n=d, kind=kind, variant=variant, schedule=schedule,
                       num_stages=num_stages, seed=seed)
    cfg = M.CharLMCfg(mixer=mixer, seq_len=seq_len)
    meta = {
        "model": "charlm", "n": d, "vocab": cfg.vocab,
        "kind": kind, "batch": batch, "seq_len": seq_len,
        "variant": variant, "schedule": schedule,
        "num_stages": mixer.stages() if kind == "spm" else 0,
        "fingerprint": mixer.spec().fingerprint() if kind == "spm" else "",
        "param_count": None,
    }
    ENTRIES.append(Entry(
        name=name, sets=tuple(sets),
        init_fn=lambda key: M.init_charlm(key, cfg),
        apply_fn=lambda p, x: M.apply_charlm(cfg, p, x),
        loss=T.charlm_loss,
        x_spec=spec((batch, seq_len), I32), y_spec=spec((batch, seq_len), I32),
        meta=meta, adam=T.AdamCfg(lr=lr),
    ))


def gru_entry(name, sets, n, num_classes, kind, batch, seq_len,
              variant="general", schedule="shift", num_stages=None, lr=1e-3):
    mixer = M.MixerCfg(n=n, kind=kind, variant=variant, schedule=schedule,
                       num_stages=num_stages)
    cfg = M.GRUCfg(mixer=mixer, num_classes=num_classes)
    meta = {
        "model": "gru", "n": n, "num_classes": num_classes, "kind": kind,
        "batch": batch, "seq_len": seq_len, "variant": variant,
        "schedule": schedule,
        "num_stages": mixer.stages() if kind == "spm" else 0,
        "fingerprint": mixer.spec().fingerprint() if kind == "spm" else "",
        "param_count": None,
    }
    ENTRIES.append(Entry(
        name=name, sets=tuple(sets),
        init_fn=lambda key: M.init_gru(key, cfg),
        apply_fn=lambda p, x: M.apply_gru(cfg, p, x),
        loss=T.classifier_loss,
        x_spec=spec((batch, seq_len, n)), y_spec=spec((batch,), I32),
        meta=meta, adam=T.AdamCfg(lr=lr),
    ))


def attention_entry(name, sets, d, kind, batch, seq_len, heads=4,
                    variant="rotation", schedule="butterfly",
                    num_stages=None, lr=1e-3):
    mixer = M.MixerCfg(n=d, kind=kind, variant=variant, schedule=schedule,
                       num_stages=num_stages)
    cfg = M.AttentionCfg(mixer=mixer, num_heads=heads)

    def mse(out, y):
        l = jnp.mean((out - y) ** 2)
        return l, l

    meta = {
        "model": "attention", "n": d, "heads": heads, "kind": kind,
        "batch": batch, "seq_len": seq_len, "variant": variant,
        "schedule": schedule,
        "num_stages": mixer.stages() if kind == "spm" else 0,
        "fingerprint": mixer.spec().fingerprint() if kind == "spm" else "",
        "param_count": None,
    }
    ENTRIES.append(Entry(
        name=name, sets=tuple(sets),
        init_fn=lambda key: M.init_attention(key, cfg),
        apply_fn=lambda p, x: M.apply_attention(cfg, p, x),
        loss=mse,
        x_spec=spec((batch, seq_len, d)), y_spec=spec((batch, seq_len, d)),
        meta=meta, adam=T.AdamCfg(lr=lr),
    ))


def teacher_entry(name, sets, n, num_classes, batch, schedule="butterfly", seed=7):
    """Teacher forward only: labels are generated on the rust side by
    calling this artifact (init once, forward per batch)."""
    cfg = M.TeacherCfg(n=n, num_classes=num_classes, schedule=schedule, seed=seed)
    meta = {
        "model": "teacher", "n": n, "num_classes": num_classes,
        "kind": "spm", "batch": batch, "variant": "general",
        "schedule": schedule, "num_stages": 0, "fingerprint": "",
        "param_count": None,
    }
    ENTRIES.append(Entry(
        name=name, sets=tuple(sets),
        init_fn=lambda key: M.init_teacher(key, cfg),
        apply_fn=lambda p, x: M.teacher_labels(cfg, p, x),
        loss=None,
        x_spec=spec((batch, n)), y_spec=None,
        meta=meta, emit=("init", "forward"),
    ))


def register_all():
    # --- Table 1: compositional teacher, width sweep (paper §9.1) ----------
    for n in (256, 512, 1024, 2048):
        sets = ("table1", f"table1_n{n}")
        teacher_entry(f"teacher_n{n}", sets, n, 10, 256)
        classifier_entry(f"table1_dense_n{n}", sets, n, 10, "dense", 256)
        classifier_entry(f"table1_spm_n{n}", sets, n, 10, "spm", 256,
                         variant="general", schedule="butterfly")
    # --- Table 2: AG-News proxy, hashed sparse features (paper §9.2) -------
    for n in (2048, 4096):
        sets = ("table2", f"table2_n{n}")
        classifier_entry(f"table2_dense_n{n}", sets, n, 4, "dense", 256)
        classifier_entry(f"table2_spm_n{n}", sets, n, 4, "spm", 256,
                         variant="general", schedule="butterfly", num_stages=12)
    # --- Tables 3/4: char-level LM (paper §9.3) -----------------------------
    charlm_entry("charlm_dense_d4096", ("charlm",), 4096, "dense", 32, 128)
    charlm_entry("charlm_spm_d4096", ("charlm",), 4096, "spm", 32, 128,
                 variant="rotation", schedule="butterfly", num_stages=12)
    # --- Small configs: tests, quickstart, demos ----------------------------
    classifier_entry("clf_dense_small", ("test",), 64, 10, "dense", 32)
    classifier_entry("clf_spm_small", ("test",), 64, 10, "spm", 32)
    teacher_entry("teacher_small", ("test",), 64, 10, 32)
    charlm_entry("charlm_dense_small", ("test",), 256, "dense", 8, 32)
    charlm_entry("charlm_spm_small", ("test",), 256, "spm", 8, 32,
                 variant="rotation", num_stages=8)
    gru_entry("gru_dense_small", ("gru", "test"), 64, 4, "dense", 32, 8)
    # keep the SPM GRU artifact small: interpret-mode pallas unrolls
    # T x 6 maps x L stages x (fwd+bwd) kernels and XLA compile time grows
    # superlinearly in the resulting HLO; T=4, L=3 keeps it tractable.
    gru_entry("gru_spm_small", ("gru", "test"), 64, 4, "spm", 32, 4, num_stages=3)
    attention_entry("attn_dense_small", ("attention", "test"), 64, "dense", 8, 32)
    attention_entry("attn_spm_small", ("attention", "test"), 64, "spm", 8, 32)
    # --- Ablations: depth / schedule / variant at n=1024 (DESIGN Abl-*) -----
    n = 1024
    for L in (1, 2, 5, 10, 20):
        classifier_entry(f"abl_depth_L{L}", ("ablation_depth",), n, 10, "spm",
                         256, variant="general", num_stages=L)
    for sched in ("butterfly", "shift", "random"):
        classifier_entry(f"abl_sched_{sched}", ("ablation_pairing",), n, 10,
                         "spm", 256, variant="general", schedule=sched)
    for var in ("rotation", "general"):
        classifier_entry(f"abl_variant_{var}", ("ablation_variant",), n, 10,
                         "spm", 256, variant=var)
    # paper §11 future work: hybrid SPM + low-rank dense correction
    classifier_entry("abl_hybrid_r16", ("ablation_hybrid", "hybrid"), n, 10,
                     "hybrid", 256, variant="general")
    if not any(e.name == "teacher_n1024" for e in ENTRIES):
        teacher_entry("teacher_n1024", ("ablation",), n, 10, 256)


# ---------------------------------------------------------------------------
# Lowering
# ---------------------------------------------------------------------------

def arg_descr(name, s):
    return {"name": name, "shape": list(s.shape), "dtype": str(s.dtype)}


def lower_entry(e: Entry, out_dir: str) -> dict:
    fns = T.make_flat_fns(e.init_fn, e.apply_fn, e.loss or (lambda o, y: (o, o)),
                          e.adam)
    n = fns["nleaves"]
    pspecs = [spec(s, d) for s, d in zip(fns["leaf_shapes"], fns["leaf_dtypes"])]
    record = {
        "name": e.name,
        "meta": {**e.meta, "param_count": int(sum(int(np.prod(s)) for s in fns["leaf_shapes"]))},
        "nleaves": n,
        "leaves": [
            {"name": nm, "shape": list(s), "dtype": d}
            for nm, s, d in zip(fns["leaf_names"], fns["leaf_shapes"], fns["leaf_dtypes"])
        ],
        "artifacts": {},
    }

    def emit(kind, fn, arg_specs, arg_names):
        t0 = time.time()
        lowered = jax.jit(fn).lower(*arg_specs)
        text = to_hlo_text(lowered)
        fname = f"{e.name}.{kind}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        out_shapes = jax.eval_shape(fn, *arg_specs)
        if not isinstance(out_shapes, (tuple, list)):
            out_shapes = (out_shapes,)
        record["artifacts"][kind] = {
            "file": fname,
            "inputs": [arg_descr(nm, s) for nm, s in zip(arg_names, arg_specs)],
            "outputs": [{"shape": list(o.shape), "dtype": str(o.dtype)} for o in out_shapes],
            "sha256": hashlib.sha256(text.encode()).hexdigest()[:16],
        }
        print(f"  [{e.name}.{kind}] {len(text)/1e6:.2f} MB HLO in {time.time()-t0:.1f}s")

    pnames = fns["leaf_names"]
    if "init" in e.emit:
        emit("init", fns["init"], [spec((), I32)], ["seed"])
    if "train" in e.emit:
        emit("train", fns["train"],
             pspecs + pspecs + pspecs + [spec((), F32), e.x_spec, e.y_spec],
             pnames + [f"m.{p}" for p in pnames] + [f"v.{p}" for p in pnames]
             + ["step", "x", "y"])
    if "eval" in e.emit:
        emit("eval", fns["eval"], pspecs + [e.x_spec, e.y_spec],
             pnames + ["x", "y"])
    if "forward" in e.emit:
        emit("forward", fns["forward"], pspecs + [e.x_spec], pnames + ["x"])
    return record


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--sets", default="all",
                    help="comma-separated artifact sets (e.g. test,table1) or 'all'")
    args = ap.parse_args()

    register_all()
    wanted = None if args.sets == "all" else set(args.sets.split(","))
    os.makedirs(args.out_dir, exist_ok=True)

    manifest_path = os.path.join(args.out_dir, "manifest.json")
    manifest = {"entries": {}}
    if os.path.exists(manifest_path):
        with open(manifest_path) as f:
            manifest = json.load(f)

    t0 = time.time()
    built = 0
    for e in ENTRIES:
        if wanted is not None and not (wanted & set(e.sets)):
            continue
        print(f"[aot] lowering {e.name} (sets={','.join(e.sets)})")
        manifest["entries"][e.name] = lower_entry(e, args.out_dir)
        built += 1

    manifest["format_version"] = 1
    with open(manifest_path, "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] built {built} entries in {time.time()-t0:.1f}s -> {manifest_path}")


if __name__ == "__main__":
    main()
