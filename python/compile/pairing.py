"""Pairing schedules for Stagewise Pairwise Mixers (paper §2.1, §5).

A *pairing schedule* assigns, for each stage ``l``, a partition of the
coordinate set ``{0..n-1}`` into ``floor(n/2)`` disjoint pairs (plus one
optional leftover coordinate when ``n`` is odd).  The paper deliberately does
NOT tie pairings to FFT/radix layouts — any per-stage partition is valid
(§5, §9.5) — so schedules are first-class objects here.

Representation
--------------
A stage pairing is stored as two index vectors ``left`` and ``right`` of
length ``P = n // 2`` (pair ``k`` mixes coordinates ``left[k]`` and
``right[k]``) plus an optional ``leftover`` index for odd ``n``.

For the kernel this is compiled into a *static permutation*
``perm = concat(left, right, [leftover])`` and its inverse, so that a stage
becomes two contiguous half-reads, an elementwise 2x2 mix, and one
inverse-permuted write — no gather inside the hot loop (DESIGN.md §2,
"Hardware adaptation").

The exact same schedule construction is mirrored in rust
(``rust/spm-core/src/spm/pairing.rs``); ``schedule_fingerprint`` lets the two
sides assert they agree (the fingerprint is recorded in the artifact
manifest).
"""

from __future__ import annotations

import dataclasses
import hashlib

import numpy as np

SCHEDULES = ("butterfly", "shift", "random")


@dataclasses.dataclass(frozen=True)
class StagePairing:
    """One stage's pairing: ``left[k]`` mixes with ``right[k]``."""

    left: np.ndarray  # (P,) int32
    right: np.ndarray  # (P,) int32
    leftover: int | None  # unpaired coordinate for odd n (paper §5)

    @property
    def num_pairs(self) -> int:
        return int(self.left.shape[0])

    def perm(self) -> np.ndarray:
        """Permutation sending x -> [x[left], x[right], x[leftover]?]."""
        parts = [self.left, self.right]
        if self.leftover is not None:
            parts.append(np.array([self.leftover], dtype=np.int32))
        return np.concatenate(parts).astype(np.int32)

    def inverse_perm(self) -> np.ndarray:
        p = self.perm()
        inv = np.empty_like(p)
        inv[p] = np.arange(p.shape[0], dtype=np.int32)
        return inv

    def validate(self, n: int) -> None:
        p = np.sort(self.perm())
        if not np.array_equal(p, np.arange(n, dtype=np.int32)):
            raise ValueError("pairing is not a partition of 0..n-1")


def butterfly_stage(n: int, stage: int) -> StagePairing:
    """FFT-style stride pairing: stage ``l`` mixes ``i`` with ``i + 2^l``.

    Defined for any even chunk; strides wrap modulo ``log2`` span.  This is
    the "butterfly-style pairing schedule" used for the paper's char-LM
    experiment (§9.3).  Requires ``n`` to be even; power-of-two ``n`` gives
    the classical butterfly, other even ``n`` fall back to stride pairing
    within the largest aligned prefix and shift pairing on the remainder.
    """
    if n < 2:
        raise ValueError("n must be >= 2")
    levels = max(1, int(np.floor(np.log2(n))))
    s = 1 << (stage % levels)
    left, right = [], []
    # aligned blocks of size 2s: within each block, i pairs with i+s
    nb = n // (2 * s)
    for b in range(nb):
        base = b * 2 * s
        for i in range(s):
            left.append(base + i)
            right.append(base + s + i)
    # non-power-of-two tail: pair the remaining coordinates adjacently
    tail = list(range(nb * 2 * s, n))
    for k in range(0, len(tail) - 1, 2):
        left.append(tail[k])
        right.append(tail[k + 1])
    leftover = tail[-1] if len(tail) % 2 == 1 else None
    return StagePairing(
        np.asarray(left, np.int32), np.asarray(right, np.int32), leftover
    )


def shift_stage(n: int, stage: int) -> StagePairing:
    """Rotating adjacent pairing: stage ``l`` pairs ``(2k+l, 2k+1+l) mod n``.

    Scales smoothly to arbitrary ``n`` (paper §5): coordinates are paired
    adjacently on a ring whose origin rotates by one each stage, so every
    coordinate interacts with a growing neighbourhood as stages compose.
    """
    if n < 2:
        raise ValueError("n must be >= 2")
    P = n // 2
    offs = stage % n
    idx = (np.arange(2 * P, dtype=np.int64) + offs) % n
    if n % 2 == 1:
        # drop the rotating leftover coordinate
        leftover = int((2 * P + offs) % n)
    else:
        leftover = None
    left = idx[0::2].astype(np.int32)
    right = idx[1::2].astype(np.int32)
    return StagePairing(left, right, leftover)


def random_stage(n: int, stage: int, seed: int = 0) -> StagePairing:
    """Seeded random disjoint pairing, independent per stage (paper §5)."""
    if n < 2:
        raise ValueError("n must be >= 2")
    rng = np.random.default_rng(np.uint64(seed) * np.uint64(0x9E3779B9) + np.uint64(stage))
    p = rng.permutation(n).astype(np.int32)
    P = n // 2
    leftover = int(p[-1]) if n % 2 == 1 else None
    return StagePairing(p[0:2 * P:2], p[1:2 * P:2], leftover)


def make_schedule(kind: str, n: int, num_stages: int, seed: int = 0) -> list[StagePairing]:
    """Build a full ``L``-stage schedule of the given kind."""
    if kind == "butterfly":
        stages = [butterfly_stage(n, l) for l in range(num_stages)]
    elif kind == "shift":
        stages = [shift_stage(n, l) for l in range(num_stages)]
    elif kind == "random":
        stages = [random_stage(n, l, seed) for l in range(num_stages)]
    else:
        raise ValueError(f"unknown schedule kind {kind!r}; want one of {SCHEDULES}")
    for st in stages:
        st.validate(n)
    return stages


def default_num_stages(n: int) -> int:
    """Paper §2.2: ``L = log2 n`` for best results at large n."""
    return max(1, int(round(np.log2(n))))


def schedule_fingerprint(stages: list[StagePairing]) -> str:
    """Stable FNV-1a-64 hash of a schedule.

    Mirrored bit-for-bit by ``rust/spm-core/src/pairing.rs`` so the manifest
    can carry the python-side fingerprint and the rust coordinator can verify
    that both languages constructed the identical schedule.
    """
    h = np.uint64(0xCBF29CE484222325)
    prime = np.uint64(0x100000001B3)
    with np.errstate(over="ignore"):
        def mix(v: int):
            nonlocal h
            for shift in (0, 8, 16, 24):
                h = (h ^ np.uint64((v >> shift) & 0xFF)) * prime

        for st in stages:
            for arr in (st.left, st.right):
                for v in arr.tolist():
                    mix(int(v))
            mix(0xFFFFFFFF if st.leftover is None else int(st.leftover))
    return f"{int(h):016x}"
