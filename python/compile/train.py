"""L2 training graphs: losses, in-graph Adam, and flat-signature steps.

The rust coordinator drives training through AOT-compiled *flat* functions:

    train_step(*param_leaves, *m_leaves, *v_leaves, step, x, y)
        -> (*param_leaves', *m_leaves', *v_leaves', step', loss, metric)

    eval_step(*param_leaves, x, y) -> (loss, metric)
    init(seed) -> (*param_leaves,)
    forward(*param_leaves, x) -> outputs

Leaves are ordered by ``jax.tree_util.tree_flatten`` of the params pytree;
the ordering plus every leaf's name/shape/dtype is recorded in the artifact
manifest so the two sides can never disagree silently.

The optimizer lives **inside the graph** (Adam, paper §9.4 "identical
optimizers ... identical training schedules"): the rust hot loop only
uploads a batch and swaps output buffers for input buffers — python is never
on the request path.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Losses / metrics
# ---------------------------------------------------------------------------

def softmax_xent(logits, labels):
    """Mean cross-entropy; labels are int class ids. logits: (..., C)."""
    logz = jax.nn.log_softmax(logits, axis=-1)
    ll = jnp.take_along_axis(logz, labels[..., None], axis=-1)[..., 0]
    return -jnp.mean(ll)


def accuracy(logits, labels):
    return jnp.mean((jnp.argmax(logits, axis=-1) == labels).astype(jnp.float32))


# ---------------------------------------------------------------------------
# Adam (in-graph)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AdamCfg:
    lr: float = 1e-3
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8


def adam_update(cfg: AdamCfg, params, grads, m, v, step):
    """One Adam step over arbitrary pytrees. ``step`` is an f32 scalar
    holding the *previous* step count; returns the incremented value."""
    t = step + 1.0
    bc1 = 1.0 - cfg.b1 ** t
    bc2 = 1.0 - cfg.b2 ** t
    new_m = jax.tree.map(lambda mi, gi: cfg.b1 * mi + (1 - cfg.b1) * gi, m, grads)
    new_v = jax.tree.map(lambda vi, gi: cfg.b2 * vi + (1 - cfg.b2) * gi * gi, v, grads)
    new_p = jax.tree.map(
        lambda pi, mi, vi: pi - cfg.lr * (mi / bc1) / (jnp.sqrt(vi / bc2) + cfg.eps),
        params, new_m, new_v,
    )
    return new_p, new_m, new_v, t


def zeros_like_tree(tree):
    return jax.tree.map(jnp.zeros_like, tree)


# ---------------------------------------------------------------------------
# Flat-signature step factories
# ---------------------------------------------------------------------------

def leaf_names(params) -> list[str]:
    """Deterministic dotted names for every leaf, matching tree_flatten order."""
    flat, _ = jax.tree_util.tree_flatten_with_path(params)
    names = []
    for path, _leaf in flat:
        parts = []
        for p in path:
            if isinstance(p, jax.tree_util.DictKey):
                parts.append(str(p.key))
            elif isinstance(p, jax.tree_util.SequenceKey):
                parts.append(str(p.idx))
            else:
                parts.append(str(p))
        names.append(".".join(parts) if parts else "param")
    return names


def make_flat_fns(init_fn, apply_fn, loss_and_metric, adam: AdamCfg):
    """Build the four flat-signature functions for one model.

    ``init_fn(key) -> params``;  ``apply_fn(params, x) -> outputs``;
    ``loss_and_metric(outputs, y) -> (loss, metric)``.

    Returns dict with 'init', 'train', 'eval', 'forward' callables plus the
    treedef/leaf metadata needed by the manifest.
    """
    params0 = jax.eval_shape(lambda s: init_fn(jax.random.PRNGKey(s)), 0)
    flat0, treedef = jax.tree_util.tree_flatten(params0)
    nleaves = len(flat0)

    def init(seed):
        params = init_fn(jax.random.PRNGKey(seed))
        return tuple(jax.tree_util.tree_flatten(params)[0])

    def unflatten(leaves):
        return jax.tree_util.tree_unflatten(treedef, list(leaves))

    def loss_fn(params, x, y):
        out = apply_fn(params, x)
        return loss_and_metric(out, y)

    def train(*args):
        p = unflatten(args[:nleaves])
        m = unflatten(args[nleaves:2 * nleaves])
        v = unflatten(args[2 * nleaves:3 * nleaves])
        step, x, y = args[3 * nleaves], args[3 * nleaves + 1], args[3 * nleaves + 2]
        (loss, metric), grads = jax.value_and_grad(
            lambda pp: loss_fn(pp, x, y), has_aux=True
        )(p)
        new_p, new_m, new_v, new_step = adam_update(adam, p, grads, m, v, step)
        return (
            *jax.tree_util.tree_flatten(new_p)[0],
            *jax.tree_util.tree_flatten(new_m)[0],
            *jax.tree_util.tree_flatten(new_v)[0],
            new_step, loss, metric,
        )

    def evaluate(*args):
        p = unflatten(args[:nleaves])
        x, y = args[nleaves], args[nleaves + 1]
        loss, metric = loss_fn(p, x, y)
        return loss, metric

    def forward(*args):
        p = unflatten(args[:nleaves])
        x = args[nleaves]
        return (apply_fn(p, x),)

    return {
        "init": init,
        "train": train,
        "eval": evaluate,
        "forward": forward,
        "nleaves": nleaves,
        "leaf_names": leaf_names(params0),
        "leaf_shapes": [tuple(l.shape) for l in flat0],
        "leaf_dtypes": [str(l.dtype) for l in flat0],
    }


def classifier_loss(logits, labels):
    return softmax_xent(logits, labels), accuracy(logits, labels)


def charlm_loss(logits, targets):
    """Next-char NLL in nats (metric = same loss; BPC = NLL/ln2 downstream)."""
    nll = softmax_xent(logits, targets)
    return nll, nll
