"""L2 SPM operator: the paper's drop-in replacement for dense linear layers.

``spm_apply`` implements  y = D_out (B_L ... B_1) D_in x + bias  (eq. 1)
as a ``jax.custom_vjp`` whose backward pass is the paper's exact closed form
(§4), built from the L1 Pallas stage kernels in ``kernels/spm_stage.py``.

Variants (paper §3):
  * ``"rotation"``  — one angle per pair, orthogonal by construction.
    Backward uses O(B n) memory: since each stage is orthogonal, the stage
    *inputs* are recomputed from the outputs (z_{l-1} = B_l^T z_l) while the
    adjoint is propagated, and the theta gradient is evaluated from outputs
    via  dL/dtheta = delta2*y1 - delta1*y2  (eq. 9 rewritten).  The leftover
    coordinate for odd n is passed through unchanged (paper §5 option (i)),
    keeping every stage exactly orthogonal/invertible.
  * ``"general"``   — four free scalars per pair.  Stage inputs are saved as
    residuals (or rematerialized when ``remat=True``); the leftover
    coordinate gets a learned 1x1 scale (paper §5 option (ii)).

The pairing schedule is static (see ``pairing.py``), so the half-gathers
``x[:, left]`` lower to constant-index gathers and the kernels themselves
stay gather-free.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from . import pairing as pairing_mod
from .kernels import spm_stage as K


@dataclasses.dataclass(frozen=True)
class SPMSpec:
    """Static configuration of one SPM operator."""

    n: int
    num_stages: int
    variant: str = "general"  # "rotation" | "general"
    schedule: str = "butterfly"  # "butterfly" | "shift" | "random"
    seed: int = 0
    remat: bool = False  # general variant: recompute fwd in bwd (O(Bn) mem)

    def __post_init__(self):
        if self.variant not in ("rotation", "general"):
            raise ValueError(f"unknown variant {self.variant!r}")
        if self.schedule not in pairing_mod.SCHEDULES:
            raise ValueError(f"unknown schedule {self.schedule!r}")
        if self.n < 2:
            raise ValueError("n must be >= 2")

    @functools.cached_property
    def stages(self):
        return pairing_mod.make_schedule(
            self.schedule, self.n, self.num_stages, self.seed
        )

    @property
    def num_pairs(self) -> int:
        return self.n // 2

    def fingerprint(self) -> str:
        return pairing_mod.schedule_fingerprint(self.stages)

    def param_count(self) -> int:
        per_stage = self.num_pairs * (1 if self.variant == "rotation" else 4)
        lone = self.num_stages if self.n % 2 == 1 and self.variant == "general" else 0
        return 3 * self.n + self.num_stages * per_stage + lone


def default_spec(n: int, variant: str = "general", schedule: str = "butterfly",
                 num_stages: int | None = None, seed: int = 0) -> SPMSpec:
    """Paper §2.2 default: L = log2(n) stages."""
    L = pairing_mod.default_num_stages(n) if num_stages is None else num_stages
    return SPMSpec(n=n, num_stages=L, variant=variant, schedule=schedule, seed=seed)


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------

def init_spm_params(key, spec: SPMSpec, dtype=jnp.float32):
    """Orthogonal-at-init parameters.

    Both variants start as a product of random planar rotations (exactly
    norm-preserving, paper §8.4), with identity diagonals and zero bias, so
    composition depth never amplifies or attenuates signals at init.
    """
    k_theta, = jax.random.split(key, 1)
    L, P, n = spec.num_stages, spec.num_pairs, spec.n
    theta = jax.random.uniform(k_theta, (L, P), dtype, -np.pi, np.pi)
    if spec.variant == "rotation":
        mix = theta
    else:
        c, s = jnp.cos(theta), jnp.sin(theta)
        mix = jnp.stack([c, -s, s, c], axis=-1)  # (L, P, 4) = rotation blocks
    return {
        "d_in": jnp.ones((n,), dtype),
        "d_out": jnp.ones((n,), dtype),
        "bias": jnp.zeros((n,), dtype),
        "mix": mix,
        "lone": jnp.ones((L, 1), dtype),
    }


# ---------------------------------------------------------------------------
# Stage application on full vectors (permute -> kernel -> inverse permute)
#
# Two layouts:
#  * GENERIC: gather by the pairing index arrays. Works for any schedule but
#    XLA-CPU executes large gathers with a scalar loop — measured ~0.8 s per
#    (4096, 4096) gather, which dominated the d=4096 char-LM step
#    (EXPERIMENTS.md §Perf).
#  * BUTTERFLY FAST PATH: for the butterfly schedule at power-of-two n the
#    stride-s pairing is exactly a (B, n/2s, 2, s) reshape — both halves are
#    strided slices and the inverse is a stack+reshape. No gather anywhere;
#    everything fuses into the elementwise mix.
# ---------------------------------------------------------------------------

def _is_pow2(n: int) -> bool:
    return n & (n - 1) == 0


def _butterfly_stride(spec: "SPMSpec", l: int) -> int:
    levels = max(1, int(np.floor(np.log2(spec.n))))
    return 1 << (l % levels)


def _fast_layout(spec: "SPMSpec", l: int) -> int | None:
    """Return the stage stride if the reshape fast path applies."""
    if spec.schedule == "butterfly" and _is_pow2(spec.n) and spec.n >= 2:
        return _butterfly_stride(spec, l)
    return None


def _halves(spec, l, st, z):
    s = _fast_layout(spec, l)
    if s is not None:
        B, n = z.shape
        z4 = z.reshape(B, n // (2 * s), 2, s)
        return (z4[:, :, 0, :].reshape(B, n // 2),
                z4[:, :, 1, :].reshape(B, n // 2))
    return z[:, st.left], z[:, st.right]


def _unhalves(spec, l, st, ya, yb, z_lone):
    s = _fast_layout(spec, l)
    if s is not None:
        B = ya.shape[0]
        n = spec.n
        nb = n // (2 * s)
        y4 = jnp.stack([ya.reshape(B, nb, s), yb.reshape(B, nb, s)], axis=2)
        return y4.reshape(B, n)
    parts = [ya, yb]
    if st.leftover is not None:
        parts.append(z_lone)
    cat = jnp.concatenate(parts, axis=1)
    return cat[:, st.inverse_perm()]


def _stage_fwd(spec, l, st, mix_l, lone_l, z):
    xa, xb = _halves(spec, l, st, z)
    if spec.variant == "rotation":
        ya, yb = K.stage_fwd_rotation(xa, xb, jnp.cos(mix_l), jnp.sin(mix_l))
        z_lone = z[:, st.leftover:st.leftover + 1] if st.leftover is not None else None
    else:
        ya, yb = K.stage_fwd_general(
            xa, xb, mix_l[:, 0], mix_l[:, 1], mix_l[:, 2], mix_l[:, 3]
        )
        z_lone = (lone_l[0] * z[:, st.leftover:st.leftover + 1]
                  if st.leftover is not None else None)
    return _unhalves(spec, l, st, ya, yb, z_lone)


def _stage_bwd_rotation_pair(spec, l, st, mix_l, z_out, g):
    """Rotation stage: propagate BOTH the adjoint and the recomputed input.

    z_{l-1} = B_l^T z_l and g_{l-1} = B_l^T g_l share the same transpose
    apply, so the two are stacked into one kernel launch.
    """
    c, s = jnp.cos(mix_l), jnp.sin(mix_l)
    both = jnp.concatenate([g, z_out], axis=0)
    da, db = _halves(spec, l, st, both)
    ga, gb = K.stage_bwd_rotation_inputs(da, db, c, s)
    lone = (both[:, st.leftover:st.leftover + 1]
            if st.leftover is not None else None)  # passthrough leftover
    back = _unhalves(spec, l, st, ga, gb, lone)
    B = g.shape[0]
    g_prev, z_prev = back[:B], back[B:]
    # theta grad from stage outputs (eq. 9 rewritten): d2*y1 - d1*y2
    ya, yb = _halves(spec, l, st, z_out)
    d1, d2 = _halves(spec, l, st, g)
    g_theta = jnp.sum(d2 * ya - d1 * yb, axis=0)
    return g_prev, z_prev, g_theta


def _stage_bwd_general(spec, l, st, mix_l, lone_l, z_in, g):
    xa, xb = _halves(spec, l, st, z_in)
    d1, d2 = _halves(spec, l, st, g)
    ga, gb = K.stage_bwd_general_inputs(
        d1, d2, mix_l[:, 0], mix_l[:, 1], mix_l[:, 2], mix_l[:, 3]
    )
    g_mix = K.general_abcd_grad(d1, d2, xa, xb)
    if st.leftover is not None:
        g_lone_in = lone_l[0] * g[:, st.leftover:st.leftover + 1]
        g_lone = jnp.sum(
            g[:, st.leftover] * z_in[:, st.leftover]
        ).reshape(1)
    else:
        g_lone_in, g_lone = None, jnp.zeros((1,), g.dtype)
    g_prev = _unhalves(spec, l, st, ga, gb, g_lone_in)
    return g_prev, g_mix, g_lone


# ---------------------------------------------------------------------------
# Full operator with custom VJP
# ---------------------------------------------------------------------------

def _forward(spec, params, x):
    """Returns (y, z_trace) where z_trace content depends on the variant."""
    z = params["d_in"] * x  # eq. (2)
    zs = [z]
    for l, st in enumerate(spec.stages):  # eq. (3)
        z = _stage_fwd(spec, l, st, params["mix"][l], params["lone"][l], z)
        zs.append(z)
    y = params["d_out"] * z + params["bias"]  # eq. (4)
    return y, zs


@functools.lru_cache(maxsize=None)
def _make_apply(spec: SPMSpec):
    @jax.custom_vjp
    def apply(params, x):
        return _forward(spec, params, x)[0]

    def fwd(params, x):
        y, zs = _forward(spec, params, x)
        if spec.variant == "rotation":
            res = (params, x, zs[-1])  # O(Bn): inputs recomputed in bwd
        elif spec.remat:
            res = (params, x, None)
        else:
            res = (params, x, zs)  # store all stage inputs/outputs
        return y, res

    def bwd(res, g_y):
        params, x, trace = res
        L = spec.num_stages
        if spec.variant == "rotation":
            z_last = trace
        elif trace is None:  # remat: rebuild the trace with a second forward
            z_last = None
            trace = _forward(spec, params, x)[1]
        # eqs. (15)-(17)
        g_bias = jnp.sum(g_y, axis=0)
        zL = z_last if spec.variant == "rotation" else trace[-1]
        g_dout = jnp.sum(g_y * zL, axis=0)
        g = params["d_out"] * g_y
        g_mix = []
        g_lone = []
        if spec.variant == "rotation":
            z = zL
            for l in range(L - 1, -1, -1):
                g, z, g_th = _stage_bwd_rotation_pair(
                    spec, l, spec.stages[l], params["mix"][l], z, g
                )
                g_mix.append(g_th)
                g_lone.append(jnp.zeros((1,), g.dtype))
            z0 = z
        else:
            for l in range(L - 1, -1, -1):
                g, g_m, g_l = _stage_bwd_general(
                    spec, l, spec.stages[l], params["mix"][l],
                    params["lone"][l], trace[l], g
                )
                g_mix.append(g_m)
                g_lone.append(g_l)
            z0 = trace[0]
        # eqs. (18)-(19)
        g_din = jnp.sum(g * x, axis=0)
        g_x = params["d_in"] * g
        g_params = {
            "d_in": g_din,
            "d_out": g_dout,
            "bias": g_bias,
            "mix": jnp.stack(g_mix[::-1], axis=0),
            "lone": jnp.stack(g_lone[::-1], axis=0),
        }
        return g_params, g_x

    apply.defvjp(fwd, bwd)
    return apply


def spm_apply(spec: SPMSpec, params, x):
    """Apply the SPM operator to ``x`` of shape (B, n) -> (B, n).

    Exact closed-form gradients (paper §4) flow to both ``params`` and ``x``.
    """
    if x.ndim != 2 or x.shape[1] != spec.n:
        raise ValueError(f"expected (B, {spec.n}) input, got {x.shape}")
    return _make_apply(spec)(params, x)


def spm_apply_nd(spec: SPMSpec, params, x):
    """Apply over the last axis of an arbitrary-rank input (e.g. (B,T,d))."""
    lead = x.shape[:-1]
    y = spm_apply(spec, params, x.reshape(-1, spec.n))
    return y.reshape(*lead, spec.n)
