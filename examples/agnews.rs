//! Table 2 reproduction (paper §9.2): AG-News-proxy text classification on
//! hashed sparse features, Dense vs SPM (L=12) at n in {2048, 4096}.
//!
//! Run: cargo run --release --example agnews -- [--widths 2048] [--steps 300] [--native]

use spm_coordinator::{experiments, RunConfig};
use spm_runtime::{drivers, Engine, Manifest};

fn main() -> spm_coordinator::error::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let get = |key: &str| args.iter().position(|a| a == key).and_then(|i| args.get(i + 1));
    let widths: Vec<usize> = get("--widths")
        .map(|s| s.split(',').map(|w| w.parse().unwrap()).collect())
        .unwrap_or_else(|| vec![2048]);
    let native = args.iter().any(|a| a == "--native");
    let mut cfg = RunConfig { steps: 200, eval_batches: 10, ..Default::default() };
    if let Some(s) = get("--steps") {
        cfg.steps = s.parse()?;
    }
    let report = if native {
        experiments::run_table2_native(&widths, &cfg)?
    } else {
        let engine = Engine::cpu()?;
        let man = Manifest::load(&cfg.artifacts)?;
        drivers::run_table2(&engine, &man, &widths, &cfg)?
    };
    println!("{report}");
    Ok(())
}
