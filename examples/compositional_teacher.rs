//! Table 1 reproduction (paper §9.1): compositional-teacher width sweep,
//! Dense vs SPM students, accuracy + wall-clock crossover.
//!
//! Run: cargo run --release --example compositional_teacher -- [--widths 256,512] [--steps 1200] [--native]
//! Defaults keep runtime modest; pass the paper's 1200 steps for the full row.

use spm_coordinator::{experiments, RunConfig};
use spm_runtime::{drivers, Engine, Manifest};

fn main() -> spm_coordinator::error::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let get = |key: &str| args.iter().position(|a| a == key).and_then(|i| args.get(i + 1));
    let widths: Vec<usize> = get("--widths")
        .map(|s| s.split(',').map(|w| w.parse().unwrap()).collect())
        .unwrap_or_else(|| vec![256, 512]);
    let native = args.iter().any(|a| a == "--native");
    let mut cfg = RunConfig { steps: 300, eval_batches: 10, ..Default::default() };
    if let Some(s) = get("--steps") {
        cfg.steps = s.parse()?;
    }
    let report = if native {
        experiments::run_table1_native(&widths, &cfg)?
    } else {
        let engine = Engine::cpu()?;
        let man = Manifest::load(&cfg.artifacts)?;
        drivers::run_table1(&engine, &man, &widths, &cfg)?
    };
    println!("{report}");
    Ok(())
}
