//! §7 demo + serving: attention with SPM Q/K/V/O projections (native,
//! exact closed-form backward incl. the §7.4 softmax Jacobian), served
//! two ways through the SAME deadline-batched engine: native replicas
//! of the attention model, then the router in front of a PJRT forward
//! executable.
//!
//! Run: cargo run --release --example attention_serve

use spm_core::models::api::{
    build_model, load_checkpoint, save_checkpoint, ModelCfg, ModelKind, Target,
};
use spm_core::ops::LinearCfg;
use spm_core::rng::Rng;
use spm_core::spm::Variant;
use spm_core::tensor::Mat;
use spm_coordinator::serve::{Lane, ServeEngine};
use spm_runtime::drivers::serve_demo;
use spm_runtime::{Engine, Manifest};

fn main() -> spm_coordinator::error::Result<()> {
    // --- native attention with SPM projections (§7) -------------------------
    let (d, heads, b, t) = (64usize, 4usize, 8usize, 16usize);
    let cfg = ModelCfg::new(ModelKind::Attention, LinearCfg::spm(d, Variant::Rotation))
        .with_heads(heads)
        .with_seq_len(t)
        .with_lr(3e-3)
        .with_seed(5);
    let mut attn = build_model(&cfg);
    println!("[attention] SPM projections, params: {}", attn.param_count());
    let mut rng = Rng::new(6);
    let x = Mat::from_vec(b, t * d, rng.normal_vec(b * t * d, 1.0));
    let target = x.clone(); // learn the identity map through attention
    for step in 0..40 {
        let (loss, _m) = attn.train_step(&x, &Target::Values(&target));
        if step % 10 == 0 {
            println!("[attention] step {step:>2}: mse {loss:.4}");
        }
    }

    // --- the trained attention model behind the serving engine --------------
    // replica 2 warm-starts from a checkpoint of replica 1, so both shards
    // serve the SAME trained weights
    let ckpt = std::env::temp_dir().join("spm_attention_serve.ckpt");
    save_checkpoint(attn.as_ref(), &ckpt)?;
    let mut replica = build_model(&cfg);
    load_checkpoint(replica.as_mut(), &ckpt)?;
    let _ = std::fs::remove_file(&ckpt);
    println!("\n[serve native] 64 sequence requests from 4 clients -> 2 attention replicas");
    // session API: start() -> per-client SubmitHandles -> shutdown drains
    let session = ServeEngine::native(attn)
        .with_replica(replica)
        .with_max_batch(8)
        .with_max_wait_us(300)
        .start()?;
    let width = session.width();
    let clients: Vec<_> = (0..4)
        .map(|c| {
            let handle = session.handle();
            std::thread::spawn(move || {
                let mut rng = Rng::new(1 + c as u64);
                for i in 0..16usize {
                    let lane = if i % 4 == 3 { Lane::Batch } else { Lane::Interactive };
                    let pending =
                        handle.submit_to(lane, rng.normal_vec(width, 1.0), None).expect("submit");
                    pending.wait().expect("serve");
                }
            })
        })
        .collect();
    for c in clients {
        c.join().expect("client thread");
    }
    let report = session.shutdown()?;
    println!("{report}");

    // --- batched serving router over a PJRT forward -------------------------
    let engine = Engine::cpu()?;
    let man = Manifest::load("artifacts")?;
    println!("\n[serve xla] routing 512 requests from 4 clients -> clf_spm_small forward");
    let report = serve_demo(&engine, &man, "clf_spm_small", 512, 4, 1)?;
    println!("{report}");
    println!("attention_serve OK");
    Ok(())
}
