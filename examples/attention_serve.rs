//! §7 demo + serving: attention with SPM Q/K/V/O projections (native,
//! exact closed-form backward incl. the §7.4 softmax Jacobian), then the
//! batched-request serving router in front of a PJRT forward executable.
//!
//! Run: cargo run --release --example attention_serve

use spm_core::models::attention::Attention;
use spm_core::ops::LinearCfg;
use spm_core::rng::Rng;
use spm_core::spm::Variant;
use spm_core::tensor::Mat;
use spm_runtime::drivers::serve_demo;
use spm_runtime::{Engine, Manifest};

fn main() -> spm_coordinator::error::Result<()> {
    // --- native attention with SPM projections (§7) -------------------------
    let (d, heads, b, t) = (64usize, 4usize, 8usize, 16usize);
    let mut attn = Attention::new(LinearCfg::spm(d, Variant::Rotation), heads, 3e-3, 5);
    println!("[attention] SPM projections, params: {}", attn.param_count());
    let mut rng = Rng::new(6);
    let x = Mat::from_vec(b * t, d, rng.normal_vec(b * t * d, 1.0));
    let target = x.clone(); // learn the identity map through attention
    for step in 0..40 {
        let loss = attn.train_step(&x, &target, b, t);
        if step % 10 == 0 {
            println!("[attention] step {step:>2}: mse {loss:.4}");
        }
    }

    // --- batched serving router over a PJRT forward -------------------------
    let engine = Engine::cpu()?;
    let man = Manifest::load("artifacts")?;
    println!("\n[serve] routing 512 requests from 4 clients -> clf_spm_small forward");
    let report = serve_demo(&engine, &man, "clf_spm_small", 512, 4, 1)?;
    println!("{report}");
    println!("attention_serve OK");
    Ok(())
}
