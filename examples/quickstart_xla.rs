//! Quickstart, PJRT half: train the AOT-compiled SPM classifier through
//! the XLA execution layer. Needs the XLA vendor set and `make
//! artifacts`; the native half (train + checkpoint + serve, no vendor
//! set) is examples/quickstart.rs, runnable from the default workspace.
//!
//! Run: cd rust/spm-runtime && cargo run --release --example quickstart_xla

use spm_core::rng::Rng;
use spm_core::tensor::Mat;
use spm_runtime::{Engine, HostTensor, Manifest, TrainSession};

fn main() -> spm_coordinator::error::Result<()> {
    // --- data: a learnable rule (label = argmax of first 10 coords) -------
    let (n, batch, classes) = (64usize, 32usize, 10usize);
    let mut rng = Rng::new(1);
    let make_batch = |rng: &mut Rng| {
        let x = Mat::from_vec(batch, n, rng.normal_vec(batch * n, 1.0));
        let y: Vec<u32> = (0..batch)
            .map(|i| {
                let row = &x.row(i)[..classes];
                (0..classes).max_by(|&a, &b| row[a].partial_cmp(&row[b]).unwrap()).unwrap() as u32
            })
            .collect();
        (x, y)
    };

    // --- PJRT path: AOT-compiled SPM classifier ---------------------------
    let engine = Engine::cpu()?;
    let manifest = Manifest::load("artifacts")?;
    let mut sess =
        TrainSession::new(&engine, &manifest, "clf_spm_small", &["init", "train", "eval"])?;
    sess.init(0)?;
    println!(
        "[xla] training clf_spm_small ({} param leaves) on {}",
        sess.entry.nleaves,
        engine.platform()
    );
    for step in 0..200 {
        let (x, y) = make_batch(&mut rng);
        let (loss, acc) =
            sess.train_step(&HostTensor::F32(x.data), &HostTensor::from_labels(&y))?;
        if step % 50 == 0 {
            println!("[xla] step {step:>3}: loss {loss:.3} acc {acc:.2}");
        }
    }
    let (x, y) = make_batch(&mut rng);
    let (loss, acc) = sess.eval(&HostTensor::F32(x.data), &HostTensor::from_labels(&y))?;
    println!("[xla] held-out: loss {loss:.3} acc {acc:.2}");
    println!("quickstart_xla OK");
    Ok(())
}
