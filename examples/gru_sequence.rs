//! §6 demo: GRU with all six square maps replaced by SPM operators,
//! trained with exact BPTT on a synthetic sequence-classification task —
//! native engine and the AOT/PJRT path side by side.
//!
//! Run: cargo run --release --example gru_sequence

use spm_core::models::gru::Gru;
use spm_core::ops::LinearCfg;
use spm_core::pairing::Schedule;
use spm_core::rng::Rng;
use spm_core::spm::Variant;
use spm_core::tensor::Mat;
use spm_runtime::{Engine, HostTensor, Manifest, TrainSession};

/// class = argmax over first C coords of the time-mean of the input
fn seq_batch(n: usize, c: usize, b: usize, t: usize, rng: &mut Rng) -> (Vec<Mat>, Vec<u32>) {
    let xs: Vec<Mat> = (0..t).map(|_| Mat::from_vec(b, n, rng.normal_vec(b * n, 1.0))).collect();
    let labels = (0..b)
        .map(|i| {
            let mut sums = vec![0.0f32; c];
            for x in &xs {
                for (j, s) in sums.iter_mut().enumerate() {
                    *s += x.at(i, j);
                }
            }
            (0..c).max_by(|&a, &b2| sums[a].partial_cmp(&sums[b2]).unwrap()).unwrap() as u32
        })
        .collect();
    (xs, labels)
}

fn main() -> spm_coordinator::error::Result<()> {
    let (n, c, b, t) = (64usize, 4usize, 32usize, 8usize);
    let mut rng = Rng::new(3);

    // --- native: dense vs SPM GRU ------------------------------------------
    for (name, cfg) in [
        ("dense", LinearCfg::dense(n)),
        ("spm-rotation", LinearCfg::spm(n, Variant::Rotation).with_schedule(Schedule::Shift)),
    ] {
        let mut gru = Gru::new(cfg, c, 3e-3, 11);
        println!("[native {name}] params: {}", gru.param_count());
        let (xs, y) = seq_batch(n, c, b, t, &mut rng);
        let mut loss = 0.0;
        let mut acc = 0.0;
        for step in 0..60 {
            let (l, a) = gru.train_step(&xs, &y);
            loss = l;
            acc = a;
            if step % 20 == 0 {
                println!("[native {name}] step {step:>2}: loss {l:.3} acc {a:.2}");
            }
        }
        println!("[native {name}] final: loss {loss:.3} acc {acc:.2}");
    }

    // --- PJRT: the AOT-lowered SPM GRU -------------------------------------
    let engine = Engine::cpu()?;
    let man = Manifest::load("artifacts")?;
    let mut sess = TrainSession::new(&engine, &man, "gru_spm_small", &["init", "train"])?;
    sess.init(0)?;
    println!("[xla gru_spm_small] {} param leaves", sess.entry.nleaves);
    let t = sess.entry.meta_usize("seq_len")?; // artifact seq length
    for step in 0..20 {
        let (xs, y) = seq_batch(n, c, b, t, &mut rng);
        // flatten (T x (B,n)) -> (B, T, n)
        let mut flat = vec![0.0f32; b * t * n];
        for (ti, x) in xs.iter().enumerate() {
            for bi in 0..b {
                let dst = (bi * t + ti) * n;
                flat[dst..dst + n].copy_from_slice(x.row(bi));
            }
        }
        let (loss, acc) = sess.train_step(&HostTensor::F32(flat), &HostTensor::from_labels(&y))?;
        if step % 5 == 0 {
            println!("[xla] step {step:>2}: loss {loss:.3} acc {acc:.2}");
        }
    }
    println!("gru_sequence OK");
    Ok(())
}
