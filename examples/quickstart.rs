//! Quickstart: the native stack in ~70 lines — no XLA vendor set needed.
//!
//! 1. build an SPM classifier through the unified `Model` factory,
//! 2. train it on a learnable rule,
//! 3. checkpoint it and warm-start a fresh copy from disk,
//! 4. serve both copies as replicas through the deadline-batched engine.
//!
//! Run: cargo run --release -p spm-coordinator --example quickstart
//!
//! (The PJRT/AOT half of the old quickstart lives in
//! examples/quickstart_xla.rs, built from rust/spm-runtime when the XLA
//! vendor set is available.)

use spm_core::models::api::{build_model, save_checkpoint, ModelCfg, ModelKind, Target};
use spm_core::ops::LinearCfg;
use spm_core::rng::Rng;
use spm_core::spm::Variant;
use spm_core::tensor::Mat;
use spm_coordinator::serve::{Lane, ServeEngine};
use spm_coordinator::ModelConfig;

fn main() -> spm_coordinator::error::Result<()> {
    // --- data: a learnable rule (label = argmax of first 10 coords) -------
    let (n, batch, classes) = (64usize, 32usize, 10usize);
    let mut rng = Rng::new(1);
    let make_batch = |rng: &mut Rng| {
        let x = Mat::from_vec(batch, n, rng.normal_vec(batch * n, 1.0));
        let y: Vec<u32> = (0..batch)
            .map(|i| {
                let row = &x.row(i)[..classes];
                (0..classes).max_by(|&a, &b| row[a].partial_cmp(&row[b]).unwrap()).unwrap() as u32
            })
            .collect();
        (x, y)
    };

    // --- build + train through the unified Model trait --------------------
    let cfg = ModelCfg::new(ModelKind::Mlp, LinearCfg::spm(n, Variant::General))
        .with_classes(classes)
        .with_seed(7);
    let mut model = build_model(&cfg);
    println!("[native] training {} ({} params)", model.kind().name(), model.param_count());
    for step in 0..200 {
        let (x, y) = make_batch(&mut rng);
        let (loss, acc) = model.train_step(&x, &Target::Labels(&y));
        if step % 50 == 0 {
            println!("[native] step {step:>3}: loss {loss:.3} acc {acc:.2}");
        }
    }
    let (x, y) = make_batch(&mut rng);
    let (loss, acc) = model.evaluate(&x, &Target::Labels(&y));
    println!("[native] held-out: loss {loss:.3} acc {acc:.2}");

    // --- checkpoint + warm start ------------------------------------------
    let ckpt = std::env::temp_dir().join("spm_quickstart.ckpt");
    save_checkpoint(model.as_ref(), &ckpt)?;
    println!("[ckpt] saved {}", ckpt.display());
    // the [model] config section can do the same from TOML; here we reuse
    // its builder directly
    let mcfg = ModelConfig {
        kind: ModelKind::Mlp,
        n,
        classes,
        checkpoint: ckpt.display().to_string(),
        ..Default::default()
    };
    // the checkpoint overwrites every parameter buffer; its arch
    // fingerprint guarantees the op config/pairing matches (here the
    // default butterfly schedule, which is seed-independent)
    let warm = mcfg.build(&spm_coordinator::OpConfig::default(), 0)?;
    let (wl, wa) = warm.evaluate(&x, &Target::Labels(&y));
    println!("[ckpt] warm-started replica: loss {wl:.3} acc {wa:.2}");
    assert_eq!((wl, wa), (loss, acc), "warm start must restore the exact model");

    // --- serve both copies as deadline-batched replicas --------------------
    // the session API: start() hands back cloneable SubmitHandles, each
    // client thread submits its own stream, shutdown() drains in-flight
    println!("\n[serve] 512 requests from 4 clients -> 2 replicas");
    let session = ServeEngine::native(model)
        .with_replica(warm)
        .with_max_batch(16)
        .with_max_wait_us(300)
        .start()?;
    let clients: Vec<_> = (0..4)
        .map(|c| {
            let handle = session.handle();
            std::thread::spawn(move || {
                let mut rng = Rng::new(3 ^ (c as u64) << 8);
                for i in 0..128usize {
                    // 3:1 interactive:batch, like a real mixed workload
                    let lane = if i % 4 == 3 { Lane::Batch } else { Lane::Interactive };
                    let features = rng.normal_vec(n, 1.0);
                    let pending = handle.submit_to(lane, features, None).expect("submit");
                    pending.wait().expect("serve");
                }
            })
        })
        .collect();
    for c in clients {
        c.join().expect("client thread");
    }
    let report = session.shutdown()?;
    println!("{report}");
    let _ = std::fs::remove_file(&ckpt);
    println!("quickstart OK");
    Ok(())
}
