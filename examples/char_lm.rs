//! Tables 3 & 4 reproduction (paper §9.3) — the end-to-end driver:
//! char-level language modeling on the Shakespeare-like corpus (~1 MB
//! train / 111 KB valid), d=4096 projection, T=128, B=32, eval every 200
//! steps over 10 valid batches, NLL (nats) + BPC.
//!
//! Run (full, matches the paper recipe but fewer steps by default):
//!   cargo run --release --example char_lm -- --entry charlm_spm_d4096 --steps 400 --eval-every 100
//! Quick CI profile:
//!   cargo run --release --example char_lm -- --small

use spm_coordinator::{experiments, RunConfig};
use spm_runtime::{drivers, Engine, Manifest};

fn main() -> spm_coordinator::error::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let get = |key: &str| args.iter().position(|a| a == key).and_then(|i| args.get(i + 1));
    let small = args.iter().any(|a| a == "--small");
    let entry = get("--entry").cloned().unwrap_or_else(|| {
        if small { "charlm_spm_small".into() } else { "charlm_spm_d4096".into() }
    });
    let mut cfg = RunConfig {
        steps: if small { 60 } else { 400 },
        eval_every: if small { 20 } else { 100 },
        eval_batches: 10,
        ..Default::default()
    };
    if let Some(s) = get("--steps") {
        cfg.steps = s.parse()?;
    }
    if let Some(s) = get("--eval-every") {
        cfg.eval_every = s.parse()?;
    }
    let engine = Engine::cpu()?;
    let man = Manifest::load(&cfg.artifacts)?;
    let rows = drivers::run_charlm(&engine, &man, &entry, &cfg)?;
    println!("{}", experiments::render_charlm_table(&format!("char-LM ({entry})"), &rows));
    Ok(())
}
