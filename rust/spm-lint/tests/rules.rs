//! Fixture corpus tests: every rule R1–R6 has a passing, a violating,
//! and a suppressed case (plus the meta-rule cases for bad suppressions
//! and the R2 DESIGN-§15 cross-check). The expected outputs here are
//! kept byte-aligned with `tools/spm_lint.py` run over the same
//! fixtures — the two implementations must never drift (DESIGN.md §18).

use std::path::PathBuf;

use spm_lint::{lint_tree, Finding};

fn lint(rel: &str) -> Vec<Finding> {
    let root = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures").join(rel);
    lint_tree(&root).0
}

fn assert_clean(rel: &str) {
    let f = lint(rel);
    assert!(
        f.is_empty(),
        "{rel} should be clean, got:\n{}",
        f.iter().map(|x| x.render()).collect::<Vec<_>>().join("\n")
    );
}

fn assert_fires(rel: &str, rule: &str, expect: &[(&str, usize)]) {
    let f = lint(rel);
    assert_eq!(
        f.len(),
        expect.len(),
        "{rel}: expected {} finding(s), got:\n{}",
        expect.len(),
        f.iter().map(|x| x.render()).collect::<Vec<_>>().join("\n")
    );
    for (found, (path, line)) in f.iter().zip(expect) {
        assert_eq!(found.rule, rule, "{rel}: wrong rule in {}", found.render());
        assert_eq!(&found.path, path, "{rel}: wrong path in {}", found.render());
        assert_eq!(found.line, *line, "{rel}: wrong line in {}", found.render());
    }
}

// R1 safety -----------------------------------------------------------------

#[test]
fn r1_safety_pass_fail_suppressed() {
    assert_clean("safety/pass");
    assert_fires("safety/fail", "safety", &[("a.rs", 2)]);
    assert_clean("safety/suppressed");
}

// R2 alloc ------------------------------------------------------------------

#[test]
fn r2_alloc_pass_fail_suppressed() {
    assert_clean("alloc/pass");
    assert_fires("alloc/fail", "alloc", &[("a.rs", 2)]);
    assert_clean("alloc/suppressed");
}

#[test]
fn r2_alloc_covers_zoo_kernels_in_linear_rs() {
    // lowrank_/blockshuffle_ prefixed fns in linear.rs are hot even
    // without an `_into` suffix (DESIGN.md §19); other fns stay cold
    assert_clean("alloc/zoo_pass");
    assert_fires(
        "alloc/zoo_fail",
        "alloc",
        &[("linear.rs", 2), ("linear.rs", 8)],
    );
}

#[test]
fn r2_alloc_suppression_must_be_backed_by_design_15() {
    // suppressed but the fn is absent from §15's exception list: the
    // cross-check fires as a (non-suppressible) consistency finding
    assert_fires("alloc/unlisted", "consistency", &[("a.rs", 3)]);
}

// R3 panic ------------------------------------------------------------------

#[test]
fn r3_panic_pass_fail_suppressed() {
    assert_clean("panic/pass");
    assert_fires(
        "panic/fail",
        "panic",
        &[("serve.rs", 2), ("serve.rs", 7), ("serve.rs", 9)],
    );
    assert_clean("panic/suppressed");
}

// R4 version ----------------------------------------------------------------

#[test]
fn r4_version_pass_fail_suppressed() {
    assert_clean("version/pass");
    assert_fires("version/fail", "version", &[("ops/linear.rs", 7)]);
    assert_clean("version/suppressed");
}

// R5 consistency ------------------------------------------------------------

#[test]
fn r5_design_ref_pass_fail_suppressed() {
    assert_clean("consistency/pass");
    assert_fires("consistency/fail", "consistency", &[("a.rs", 1)]);
    assert_clean("consistency/suppressed");
}

#[test]
fn r5_registry_magic_mismatch_fires_and_baselines() {
    assert_fires("consistency/registry_fail", "consistency", &[("registry/x.csv", 1)]);
    // the same drift parked behind a lint.baseline entry is clean, and
    // counts as suppressed rather than vanishing silently
    let (findings, suppressed) = lint_tree(
        &PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures/consistency/baseline"),
    );
    assert!(findings.is_empty(), "baselined fixture should be clean");
    assert!(suppressed >= 1, "the baseline should have eaten the finding");
}

#[test]
fn r5_gateway_wire_constants_must_be_used_on_both_sides() {
    assert_fires(
        "consistency/gateway_fail",
        "consistency",
        &[("gateway.rs", 2), ("gateway.rs", 2)],
    );
    let f = lint("consistency/gateway_fail");
    assert!(f[0].message.contains("OP_DROP"));
    assert!(f.iter().any(|x| x.message.contains("GatewayClient")));
    assert!(f.iter().any(|x| x.message.contains("server side")));
}

// R6 hygiene ----------------------------------------------------------------

#[test]
fn r6_hygiene_pass_fail_suppressed() {
    assert_clean("hygiene/pass");
    assert_fires("hygiene/fail", "hygiene", &[("a.rs", 1)]);
    assert_clean("hygiene/suppressed");
}

#[test]
fn r6_unbalanced_brackets_fire() {
    assert_fires("hygiene/unbalanced", "hygiene", &[("a.rs", 4)]);
    let f = lint("hygiene/unbalanced");
    assert!(f[0].message.contains("unbalanced"));
}

// suppression grammar -------------------------------------------------------

#[test]
fn bad_suppressions_are_findings_themselves() {
    let f = lint("suppress/fail");
    assert_eq!(f.len(), 2, "unknown rule + missing reason");
    assert!(f.iter().all(|x| x.rule == "suppress"));
    assert!(f.iter().any(|x| x.message.contains("unknown rule 'bogus'")));
    assert!(f.iter().any(|x| x.message.contains("carries no reason")));
    // meta-findings render under the LINT id, not an R number
    assert!(f[0].render().contains("LINT(suppress)"));
}
