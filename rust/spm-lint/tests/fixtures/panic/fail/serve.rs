pub fn run(v: Option<u32>) -> u32 {
    v.unwrap()
}

pub fn must(v: Option<u32>) -> u32 {
    if v.is_none() {
        panic!("no value");
    }
    v.expect("checked above")
}
