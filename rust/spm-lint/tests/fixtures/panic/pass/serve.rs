pub fn run(v: Option<u32>) -> u32 {
    v.unwrap_or(0)
}

#[cfg(test)]
mod tests {
    #[test]
    fn unwraps_freely_in_tests() {
        let v: Option<u32> = Some(1);
        assert_eq!(v.unwrap(), 1);
        assert_eq!(super::run(Some(3)), 3);
    }
}
