pub fn run() -> u32 {
    let v: Option<u32> = Some(3);
    // lint: allow(panic): fixture — value constructed two lines up
    v.unwrap()
}
