//! Buffer sizing rationale lives in DESIGN.md §9.

pub fn answer() -> u32 {
    42
}
