pub const OP_PING: u8 = 1;
pub const OP_DROP: u8 = 2;

pub struct GatewayClient;

impl GatewayClient {
    pub fn ping(&self) -> u8 {
        OP_PING
    }
}

pub fn serve_one(op: u8) -> bool {
    op == OP_PING
}
