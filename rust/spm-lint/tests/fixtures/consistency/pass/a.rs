//! Buffer sizing rationale lives in DESIGN.md §1.

pub fn answer() -> u32 {
    42
}
