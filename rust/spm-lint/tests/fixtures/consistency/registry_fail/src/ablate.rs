pub const REGISTRY_MAGIC: &str = "# fixture-registry v1";

pub fn magic() -> &'static str {
    REGISTRY_MAGIC
}
