// lint: allow(consistency): fixture — section lands with the next PR
// Buffer sizing rationale will live in DESIGN.md §9.

pub fn answer() -> u32 {
    42
}
