pub fn read_first(p: *const f32) -> f32 {
    // SAFETY: caller guarantees `p` points at a live, aligned f32.
    unsafe { *p }
}

/// # Safety
/// `p` must point at `len` initialized f32s.
pub unsafe fn sum(p: *const f32, len: usize) -> f32 {
    let mut acc = 0.0;
    for i in 0..len {
        // SAFETY: i < len, and the fn contract covers 0..len.
        acc += unsafe { *p.add(i) };
    }
    acc
}
