pub fn read_first(p: *const f32) -> f32 {
    // lint: allow(safety): fixture — bounds argued at the call site
    unsafe { *p }
}
