pub fn scale_into(out: &mut [f32], k: f32) {
    for v in out.iter_mut() {
        *v *= k;
    }
}

pub fn gather(xs: &[f32]) -> Vec<f32> {
    // not a hot path: allocation is fine outside `*_into` entry points
    xs.iter().map(|v| v * 2.0).collect()
}
