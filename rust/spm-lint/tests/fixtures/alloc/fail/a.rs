pub fn scale_into(out: &mut [f32], k: f32) {
    let tmp: Vec<f32> = Vec::new();
    for v in out.iter_mut() {
        *v *= k + tmp.len() as f32;
    }
}
