pub fn lowrank_prepare(xs: &[f32]) -> Vec<f32> {
    let buf: Vec<f32> = Vec::new();
    let _ = xs;
    buf
}

pub fn blockshuffle_gather(xs: &[f32], k: f32) -> f32 {
    let tmp = xs.to_vec();
    tmp.iter().sum::<f32>() * k
}

pub fn unrelated_helper(xs: &[f32]) -> Vec<f32> {
    // not a zoo kernel: allocation stays fine outside the hot prefixes
    xs.iter().map(|v| v * 2.0).collect()
}
