pub fn lowrank_forward_accum(t: &[f32], u: &[f32], out: &mut [f32]) {
    for (o, (a, b)) in out.iter_mut().zip(t.iter().zip(u)) {
        *o += a * b;
    }
}

pub fn blockshuffle_scatter(src: &[f32], perm: &[u32], out: &mut [f32]) {
    for (v, &p) in src.iter().zip(perm) {
        out[p as usize] = *v;
    }
}
