use std::collections::HashMap;

pub fn answer() -> u32 {
    41 + 1
}
