// lint: allow(hygiene): fixture — imported for a macro expansion the linter cannot see
use std::collections::HashMap;

pub fn answer() -> u32 {
    41 + 1
}
