use std::collections::HashMap;

pub fn count() -> usize {
    let m: HashMap<u32, u32> = HashMap::new();
    m.len()
}
