pub fn answer() -> u32 {
    let x = (41 + 1;
    x
}
