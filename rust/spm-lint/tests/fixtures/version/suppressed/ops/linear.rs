pub struct LinearOp {
    params: Vec<f32>,
    params_version: u64,
}

impl LinearOp {
    // lint: allow(version): fixture — the caller bumps the version
    pub fn params_mut(&mut self) -> &mut [f32] {
        &mut self.params
    }

    pub fn version(&self) -> u64 {
        self.params_version
    }
}
