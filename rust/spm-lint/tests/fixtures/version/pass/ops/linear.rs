pub struct LinearOp {
    params: Vec<f32>,
    params_version: u64,
}

impl LinearOp {
    pub fn params_mut(&mut self) -> &mut [f32] {
        self.params_version += 1;
        &mut self.params
    }

    pub fn params(&self) -> &[f32] {
        &self.params
    }
}
