// lint: allow(bogus): not a rule at all
pub fn seven() -> u32 {
    // lint: allow(alloc)
    7
}
