//! The committed tree must be lint-clean: the same assertion CI's lint
//! job makes with the binary, and the same one `./ci.sh --lint` makes
//! through the Python mirror in toolchain-less containers. Every
//! suppression the repo relies on is therefore exercised on every
//! `cargo test` run.

use std::path::PathBuf;

#[test]
fn committed_tree_is_lint_clean() {
    let root = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("../..");
    let (findings, _suppressed) = spm_lint::lint_tree(&root);
    assert!(
        findings.is_empty(),
        "the committed tree must be lint-clean; run `cargo run -p spm-lint` (or \
         `python3 tools/spm_lint.py`) and fix or suppress:\n{}",
        findings.iter().map(|f| f.render()).collect::<Vec<_>>().join("\n")
    );
}

#[test]
fn repo_has_sources_to_lint() {
    // guards against a silently-empty walk (wrong root, overzealous
    // skip list) making the selfcheck vacuous
    let root = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("../..");
    let tree = spm_lint::Tree::new(&root);
    assert!(tree.files.len() > 20, "walk found only {} .rs files", tree.files.len());
    assert!(tree.design.is_some(), "DESIGN.md should be discovered");
    assert!(!tree.registry.is_empty(), "registry/*.csv should be discovered");
}
