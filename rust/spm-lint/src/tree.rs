//! File discovery plus the repo-level artifacts R5 cross-checks
//! (DESIGN.md, registry/*.csv). Walk order is sorted so finding order —
//! and therefore LINT.json — is deterministic across machines.

use std::fs;
use std::path::Path;

use crate::lexer::{lex, Lexed};

/// Directories never descended into: build output, the Python tree,
/// bench artifacts, and the linter's own fixture corpus (fixtures are
/// violations on purpose; the fixture tests lint them with their own
/// roots).
pub const SKIP_DIRS: [&str; 6] =
    [".git", "target", "python", "artifacts", "fixtures", "node_modules"];

pub struct SourceFile {
    /// Root-relative path, forward slashes on every platform.
    pub path: String,
    pub text: String,
    pub lex: Lexed,
    pub lines: Vec<String>,
}

impl SourceFile {
    pub fn new(path: String, text: String) -> SourceFile {
        let lex = lex(&text);
        let lines = text.split('\n').map(str::to_owned).collect();
        SourceFile { path, text, lex, lines }
    }

    /// Final path component (`serve.rs` for `rust/.../serve.rs`).
    pub fn base(&self) -> &str {
        self.path.rsplit('/').next().unwrap_or(&self.path)
    }
}

/// Everything a rule may consult.
pub struct Tree {
    pub files: Vec<SourceFile>,
    pub design: Option<String>,
    /// `(rel path, first line)` per committed registry CSV.
    pub registry: Vec<(String, String)>,
}

fn rel_path(root: &Path, p: &Path) -> String {
    let r = p.strip_prefix(root).unwrap_or(p);
    let parts: Vec<String> =
        r.components().map(|c| c.as_os_str().to_string_lossy().into_owned()).collect();
    parts.join("/")
}

fn walk(root: &Path, dir: &Path, files: &mut Vec<SourceFile>) {
    let Ok(rd) = fs::read_dir(dir) else { return };
    let mut entries: Vec<_> = rd.flatten().collect();
    entries.sort_by_key(|e| e.file_name());
    let mut subdirs = Vec::new();
    for e in &entries {
        let p = e.path();
        let name = e.file_name().to_string_lossy().into_owned();
        if p.is_dir() {
            if !SKIP_DIRS.contains(&name.as_str()) {
                subdirs.push(p);
            }
        } else if name.ends_with(".rs") {
            if let Ok(text) = fs::read_to_string(&p) {
                files.push(SourceFile::new(rel_path(root, &p), text));
            }
        }
    }
    for d in subdirs {
        walk(root, &d, files);
    }
}

impl Tree {
    pub fn new(root: &Path) -> Tree {
        let mut files = Vec::new();
        walk(root, root, &mut files);
        // match the Python mirror's os.walk order: parent dir's files
        // first, then subdirectories, everything name-sorted — the walk
        // above already does exactly that, but sort by path for a
        // stable global order regardless of traversal shape
        files.sort_by(|a, b| a.path.cmp(&b.path));
        let design = fs::read_to_string(root.join("DESIGN.md")).ok();
        let mut registry = Vec::new();
        if let Ok(rd) = fs::read_dir(root.join("registry")) {
            let mut names: Vec<_> = rd.flatten().map(|e| e.file_name()).collect();
            names.sort();
            for name in names {
                let n = name.to_string_lossy().into_owned();
                if !n.ends_with(".csv") {
                    continue;
                }
                if let Ok(text) = fs::read_to_string(root.join("registry").join(&name)) {
                    let first = text.split('\n').next().unwrap_or("").to_owned();
                    registry.push((format!("registry/{n}"), first));
                }
            }
        }
        Tree { files, design, registry }
    }
}
