//! `spm-lint`: the repo-invariant static analysis pass (DESIGN.md §18).
//!
//! Dependency-free by design — a hand-rolled comment/string/char-literal
//! aware lexer (lexer.rs) plus byte-level scanning (scan.rs) stand in
//! for rustc, so the rules run anywhere, including containers with no
//! toolchain at all (there `./ci.sh --lint` falls back to the lockstep
//! Python mirror `tools/spm_lint.py`). The rules mechanize the
//! invariants every PR note used to check by hand:
//!
//! * R1 `safety` — every `unsafe` site carries a `// SAFETY:` comment.
//! * R2 `alloc` — no allocation constructs in the §15 hot paths.
//! * R3 `panic` — no unwrap/expect/panic in serving/training threads.
//! * R4 `version` — `&mut` params doors bump `params_version`.
//! * R5 `consistency` — gateway wire constants, schema stamps, the
//!   registry CSV magic, and `DESIGN.md §N` references all line up.
//! * R6 `hygiene` — bracket balance and unused `use` imports.

pub mod lexer;
pub mod report;
pub mod rules;
pub mod scan;
pub mod suppress;
pub mod tree;

use std::collections::{HashMap, HashSet};
use std::path::Path;

pub use report::{rule_id, to_json, Finding};
pub use tree::Tree;

/// Lint the tree rooted at `root`. Returns the active findings (sorted
/// by path, line, rule) and how many raw findings were suppressed by
/// inline comments or the baseline.
pub fn lint_tree(root: &Path) -> (Vec<Finding>, usize) {
    let tree = Tree::new(root);
    let mut findings: Vec<Finding> = Vec::new();
    let mut baseline = suppress::load_baseline(root, &mut findings);
    let mut supp_by_file: HashMap<String, HashMap<&'static str, HashSet<usize>>> = HashMap::new();
    for sf in &tree.files {
        let supp = suppress::suppressions(sf, &mut findings);
        rules::rule_safety(sf, &mut findings);
        rules::rule_alloc(sf, &tree, &mut findings, &supp);
        rules::rule_panic(sf, &mut findings);
        rules::rule_version(sf, &mut findings);
        rules::rule_consistency_gateway(sf, &mut findings);
        rules::rule_consistency_schema(sf, &mut findings);
        rules::rule_consistency_design(sf, &tree, &mut findings);
        rules::rule_hygiene_balance(sf, &mut findings);
        rules::rule_hygiene_unused_use(sf, &mut findings);
        supp_by_file.insert(sf.path.clone(), supp);
    }
    rules::rule_consistency_registry(&tree, &mut findings);
    let raw = findings.len();

    // inline suppressions: a `lint: allow(<rule>)` covers its own line
    // and the next one, in its own file (R2's DESIGN-§15 cross-check ran
    // inside rule_alloc and is deliberately not re-suppressible here)
    let active: Vec<Finding> = findings
        .into_iter()
        .filter(|f| {
            !supp_by_file
                .get(&f.path)
                .and_then(|by_rule| by_rule.get(f.rule))
                .is_some_and(|lines| lines.contains(&f.line))
        })
        .collect();

    // baseline pass: a (rule, path) entry eats every matching finding;
    // an entry that eats nothing is stale and is itself a finding
    let mut remaining = Vec::new();
    for f in active {
        let mut eaten = false;
        for e in baseline.iter_mut() {
            if e.rule == f.rule && e.path == f.path {
                e.hits += 1;
                eaten = true;
            }
        }
        if !eaten {
            remaining.push(f);
        }
    }
    for e in &baseline {
        if e.hits == 0 {
            remaining.push(Finding::new(
                "lint.baseline",
                e.lineno,
                "suppress",
                format!("stale baseline entry: {} {}", e.rule, e.path),
            ));
        }
    }
    remaining.sort_by(|a, b| {
        (a.path.as_str(), a.line, a.rule).cmp(&(b.path.as_str(), b.line, b.rule))
    });
    let suppressed = raw - remaining.len().min(raw);
    (remaining, suppressed)
}
