//! `spm-lint [--root DIR] [--json PATH]` — lint the repo tree, print
//! findings as `file:line: rule-id message`, optionally write LINT.json.
//! Exit status: 0 = clean, 1 = findings, 2 = usage/IO error.

use std::path::PathBuf;
use std::process::ExitCode;

fn main() -> ExitCode {
    let mut root = PathBuf::from(".");
    let mut json_path: Option<PathBuf> = None;
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut i = 0;
    while i < args.len() {
        match (args[i].as_str(), args.get(i + 1)) {
            ("--root", Some(v)) => {
                root = PathBuf::from(v);
                i += 2;
            }
            ("--json", Some(v)) => {
                json_path = Some(PathBuf::from(v));
                i += 2;
            }
            _ => {
                eprintln!("usage: spm-lint [--root DIR] [--json PATH]");
                return ExitCode::from(2);
            }
        }
    }
    let (active, _suppressed) = spm_lint::lint_tree(&root);
    for f in &active {
        println!("{}", f.render());
    }
    if let Some(p) = json_path {
        if let Err(e) = std::fs::write(&p, spm_lint::to_json(&active)) {
            eprintln!("spm-lint: writing {}: {e}", p.display());
            return ExitCode::from(2);
        }
    }
    if active.is_empty() {
        println!("spm-lint: clean");
        ExitCode::SUCCESS
    } else {
        println!("spm-lint: {} finding(s)", active.len());
        ExitCode::from(1)
    }
}
