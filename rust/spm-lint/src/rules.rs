//! The rule set R1–R6 (DESIGN.md §18). Each rule pushes `Finding`s; the
//! driver in lib.rs applies inline suppressions and the baseline
//! afterwards. Kept in lockstep with `tools/spm_lint.py` — when editing
//! a rule, edit BOTH.

use std::collections::{HashMap, HashSet};

use crate::report::Finding;
use crate::scan::{
    brace_span, find_tokens, find_word, fn_spans, impl_header_of, in_spans, line_of,
    match_tokens, read_ident, skip_ws, test_regions,
};
use crate::tree::{SourceFile, Tree};

// -------------------------------------------------------------------------
// R1 safety: every unsafe site carries a SAFETY comment
// -------------------------------------------------------------------------

fn is_attr(line: &str) -> bool {
    let t = line.trim();
    !t.is_empty() && (t.starts_with("#[") || t.starts_with("#!"))
}

pub fn rule_safety(sf: &SourceFile, findings: &mut Vec<Finding>) {
    let mask = &sf.lex.mask;
    // comment text by line: a block comment maps every line it covers
    let mut comment_lines: HashMap<usize, Vec<&str>> = HashMap::new();
    for (line, text) in &sf.lex.comments {
        comment_lines.entry(*line).or_default().push(text);
        for extra in 0..text.matches('\n').count() {
            comment_lines.entry(line + 1 + extra).or_default().push(text);
        }
    }
    let documented = |line: usize| -> bool {
        let says_safety =
            |t: &str| t.contains("SAFETY:") || t.contains("# Safety");
        if comment_lines.get(&line).is_some_and(|v| v.iter().any(|t| says_safety(t))) {
            return true;
        }
        // walk up through the contiguous block of comments and
        // attributes directly above
        let mut l = line.saturating_sub(1);
        while l >= 1 {
            if let Some(texts) = comment_lines.get(&l) {
                if texts.iter().any(|t| says_safety(t)) {
                    return true;
                }
                l -= 1;
                continue;
            }
            if l <= sf.lines.len() && is_attr(&sf.lines[l - 1]) {
                l -= 1;
                continue;
            }
            break;
        }
        false
    };
    for at in find_word(mask, "unsafe") {
        let line = line_of(mask, at);
        if !documented(line) {
            findings.push(Finding::new(
                &sf.path,
                line,
                "safety",
                "`unsafe` without an adjacent `// SAFETY:` (or `/// # Safety`) comment".to_owned(),
            ));
        }
    }
}

// -------------------------------------------------------------------------
// R2 alloc: no allocation constructs in hot-path functions
// -------------------------------------------------------------------------

const ALLOC_PATTERNS: [(&[&str], &str); 8] = [
    (&["Vec", "::", "new"], "Vec::new"),
    (&["vec", "!"], "vec!"),
    (&[".", "to_vec", "("], ".to_vec()"),
    (&[".", "clone", "(", ")"], ".clone()"),
    (&[".", "collect"], ".collect()"),
    (&["Box", "::", "new"], "Box::new"),
    (&["format", "!"], "format!"),
    (&["String", "::", "from"], "String::from"),
];

const KERNEL_PREFIXES: [&str; 4] = ["stage_", "fwd_", "bwd_", "lone_"];

/// Operator-zoo kernels in ops/linear.rs (DESIGN.md §19): hot by prefix
/// regardless of suffix, so a helper split out of a `*_into` kernel
/// stays under the zero-allocation contract.
const ZOO_PREFIXES: [&str; 2] = ["lowrank_", "blockshuffle_"];

/// `(fn name, body span)` for the DESIGN.md §15 hot paths: `*_into`
/// entry points everywhere, stage kernels in ops/backend*.rs, zoo
/// kernels in ops/linear.rs, and `NativeExecutor::forward` in serve.rs.
fn hot_functions(sf: &SourceFile) -> Vec<(String, (usize, usize))> {
    let mask = &sf.lex.mask;
    let base = sf.base();
    let tests = test_regions(mask);
    let mut out = Vec::new();
    for (name, sig_start, body) in fn_spans(mask) {
        if in_spans(sig_start, &tests) {
            continue;
        }
        let mut hot = name.ends_with("_into");
        if !hot && base.starts_with("backend") && KERNEL_PREFIXES.iter().any(|p| name.starts_with(p))
        {
            hot = true;
        }
        if !hot && base == "linear.rs" && ZOO_PREFIXES.iter().any(|p| name.starts_with(p)) {
            hot = true;
        }
        if !hot && base == "serve.rs" && name == "forward" {
            hot = impl_header_of(mask, sig_start).is_some_and(|h| h.contains("NativeExecutor"));
        }
        if hot {
            out.push((name, body));
        }
    }
    out
}

/// Suppressed hits are cross-checked against DESIGN.md §15: the
/// suppression is only honored when the hot function is named in the
/// §15 exception list (keeps the two in lockstep) — that secondary
/// finding is NOT itself suppressible.
pub fn rule_alloc(
    sf: &SourceFile,
    tree: &Tree,
    findings: &mut Vec<Finding>,
    supp: &HashMap<&'static str, HashSet<usize>>,
) {
    let mask = &sf.lex.mask;
    let design15 = tree.design.as_deref().map_or(String::new(), design_section_15);
    let empty = HashSet::new();
    let covered = supp.get("alloc").unwrap_or(&empty);
    for (name, (a, b)) in hot_functions(sf) {
        let body = &mask[a..b];
        for (toks, label) in ALLOC_PATTERNS {
            for hit in find_tokens(body, toks) {
                let line = line_of(mask, a + hit);
                if covered.contains(&line) {
                    if !design15.is_empty() && !design15.contains(&name) {
                        findings.push(Finding::new(
                            &sf.path,
                            line,
                            "consistency",
                            format!(
                                "alloc suppression in `{name}` not backed by the DESIGN.md §15 exception list"
                            ),
                        ));
                    }
                    continue;
                }
                findings.push(Finding::new(
                    &sf.path,
                    line,
                    "alloc",
                    format!("{label} in hot-path fn `{name}` (zero-allocation contract, DESIGN.md §15)"),
                ));
            }
        }
    }
}

/// The `## §15 ...` section of DESIGN.md, up to the next `## §` heading.
fn design_section_15(design: &str) -> String {
    let mut out = String::new();
    let mut inside = false;
    for line in design.split('\n') {
        if let Some(rest) = line.strip_prefix("## §") {
            if inside {
                break;
            }
            inside = rest.strip_prefix("15").is_some_and(|r| !r.starts_with(|c: char| c.is_ascii_digit()));
            if !inside {
                continue;
            }
        }
        if inside {
            out.push_str(line);
            out.push('\n');
        }
    }
    out
}

// -------------------------------------------------------------------------
// R3 panic: serving/gateway/train worker threads must be panic-free
// -------------------------------------------------------------------------

const PANIC_FILES: [&str; 3] = ["serve.rs", "gateway.rs", "train.rs"];
const PANIC_PATTERNS: [(&[&str], &str); 3] = [
    (&[".", "unwrap", "(", ")"], ".unwrap()"),
    (&[".", "expect", "("], ".expect("),
    (&["panic", "!"], "panic!"),
];

pub fn rule_panic(sf: &SourceFile, findings: &mut Vec<Finding>) {
    if !PANIC_FILES.contains(&sf.base()) {
        return;
    }
    if sf.path.contains("/tests/") {
        return; // integration-test crates may panic freely
    }
    let mask = &sf.lex.mask;
    let tests = test_regions(mask);
    for (toks, label) in PANIC_PATTERNS {
        for hit in find_tokens(mask, toks) {
            if in_spans(hit, &tests) {
                continue;
            }
            findings.push(Finding::new(
                &sf.path,
                line_of(mask, hit),
                "panic",
                format!(
                    "{label} in non-test serving/training code (a worker panic wedges the session, DESIGN.md §16)"
                ),
            ));
        }
    }
}

// -------------------------------------------------------------------------
// R4 version: &mut params doors must bump params_version
// -------------------------------------------------------------------------

pub fn rule_version(sf: &SourceFile, findings: &mut Vec<Finding>) {
    if !sf.path.ends_with("ops/linear.rs") {
        return;
    }
    let mask = &sf.lex.mask;
    let Some(&at) = find_tokens(mask, &["impl", "LinearOp"]).first() else { return };
    let end = match_tokens(mask, at, &["impl", "LinearOp"]).unwrap_or(at);
    let Some(j) = mask[end..].iter().position(|&c| c == b'{').map(|p| end + p) else { return };
    let (ia, ib) = brace_span(mask, j);
    let impl_body = &mask[ia..ib];
    for (name, sig_start, (a, b)) in fn_spans(impl_body) {
        let body = &impl_body[a..b];
        let hands_out = find_tokens(body, &["&", "mut", "self", ".", "params"]);
        let bumps = find_tokens(body, &["self", ".", "params_version", "+="]);
        if !hands_out.is_empty() && bumps.is_empty() {
            findings.push(Finding::new(
                &sf.path,
                line_of(mask, ia + sig_start),
                "version",
                format!(
                    "`{name}` hands out &mut params without bumping params_version (cache-invalidation contract, DESIGN.md §15)"
                ),
            ));
        }
    }
}

// -------------------------------------------------------------------------
// R5 consistency: cross-file contracts
// -------------------------------------------------------------------------

pub fn rule_consistency_gateway(sf: &SourceFile, findings: &mut Vec<Finding>) {
    if sf.base() != "gateway.rs" {
        return;
    }
    let mask = &sf.lex.mask;
    // const OP_* / ST_* : u8 definitions
    let mut consts: Vec<(String, usize)> = Vec::new();
    for at in find_word(mask, "const") {
        let i = skip_ws(mask, at + 5);
        let (name, end) = read_ident(mask, i);
        if !(name.starts_with("OP_") || name.starts_with("ST_")) {
            continue;
        }
        let i = skip_ws(mask, end);
        if mask.get(i) != Some(&b':') {
            continue;
        }
        let i = skip_ws(mask, i + 1);
        if match_tokens(mask, i, &["u8"]).is_none() {
            continue;
        }
        consts.push((name, at));
    }
    if consts.is_empty() {
        return;
    }
    let client = find_tokens(mask, &["impl", "GatewayClient"]).first().map(|&at| {
        let end = match_tokens(mask, at, &["impl", "GatewayClient"]).unwrap_or(at);
        let j = mask[end..].iter().position(|&c| c == b'{').map_or(mask.len(), |p| end + p);
        brace_span(mask, j)
    });
    let tests = test_regions(mask);
    for (name, def_at) in consts {
        let refs: Vec<usize> = find_word(mask, &name)
            .into_iter()
            .filter(|&o| !(def_at <= o && o <= def_at + 60) && !in_spans(o, &tests))
            .collect();
        let line = line_of(mask, def_at);
        if let Some(span) = client {
            if !refs.iter().any(|&o| in_spans(o, &[span])) {
                findings.push(Finding::new(
                    &sf.path,
                    line,
                    "consistency",
                    format!(
                        "wire constant `{name}` is not referenced by GatewayClient (server/client protocol drift)"
                    ),
                ));
            }
        }
        let in_server = refs.iter().any(|&o| match client {
            Some(span) => !in_spans(o, &[span]),
            None => true,
        });
        if !in_server {
            findings.push(Finding::new(
                &sf.path,
                line,
                "consistency",
                format!("wire constant `{name}` is not referenced by the gateway server side"),
            ));
        }
    }
}

pub fn rule_consistency_schema(sf: &SourceFile, findings: &mut Vec<Finding>) {
    if !sf.path.starts_with("benches/") {
        return;
    }
    for (line, contents) in &sf.lex.strings {
        if !find_word(contents.as_bytes(), "schema_version").is_empty() {
            findings.push(Finding::new(
                &sf.path,
                *line,
                "consistency",
                "hand-rolled schema_version stamp; go through bench_args::json_header".to_owned(),
            ));
        }
    }
}

pub fn rule_consistency_registry(tree: &Tree, findings: &mut Vec<Finding>) {
    let mut magic: Option<(String, String, usize)> = None; // (value, path, line)
    for sf in &tree.files {
        if !sf.path.ends_with("src/ablate.rs") {
            continue;
        }
        let text = sf.text.as_bytes();
        for at in find_word(text, "const") {
            let Some(end) =
                match_tokens(text, at, &["const", "REGISTRY_MAGIC", ":", "&", "str", "="])
            else {
                continue;
            };
            let i = skip_ws(text, end);
            if text.get(i) != Some(&b'"') {
                continue;
            }
            let Some(close) = text[i + 1..].iter().position(|&c| c == b'"').map(|p| i + 1 + p)
            else {
                continue;
            };
            let value = String::from_utf8_lossy(&text[i + 1..close]).into_owned();
            magic = Some((value, sf.path.clone(), line_of(text, at)));
            break;
        }
    }
    let Some((value, mpath, mline)) = magic else { return };
    for (path, first) in &tree.registry {
        if first != &value {
            findings.push(Finding::new(
                path,
                1,
                "consistency",
                format!(
                    "registry header {first:?} is not byte-equal to REGISTRY_MAGIC {value:?} ({mpath}:{mline})"
                ),
            ));
        }
    }
}

/// `DESIGN.md §N` (or `§§N`, or `§N-§M` ranges) references in comments
/// must resolve to real `## §N` sections.
pub fn rule_consistency_design(sf: &SourceFile, tree: &Tree, findings: &mut Vec<Finding>) {
    let Some(design) = tree.design.as_deref() else { return };
    let sections: HashSet<u32> = design
        .split('\n')
        .filter_map(|l| l.strip_prefix("## §"))
        .filter_map(|rest| {
            let digits: String = rest.chars().take_while(|c| c.is_ascii_digit()).collect();
            digits.parse().ok()
        })
        .collect();
    for (line, text) in &sf.lex.comments {
        for n in section_refs(text) {
            if !sections.contains(&n) {
                findings.push(Finding::new(
                    &sf.path,
                    *line,
                    "consistency",
                    format!("comment references DESIGN.md §{n}, which does not exist"),
                ));
            }
        }
    }
}

/// Section numbers referenced as `DESIGN.md §N[-§M]` in a comment.
fn section_refs(text: &str) -> Vec<u32> {
    let cs: Vec<char> = text.chars().collect();
    let mut out = Vec::new();
    let needle: Vec<char> = "DESIGN.md".chars().collect();
    let mut i = 0usize;
    while i + needle.len() <= cs.len() {
        if cs[i..i + needle.len()] != needle[..] {
            i += 1;
            continue;
        }
        let mut j = i + needle.len();
        let start = j;
        while j < cs.len() && cs[j].is_whitespace() {
            j += 1;
        }
        if j == start || j >= cs.len() || cs[j] != '§' {
            i += 1;
            continue;
        }
        j += 1;
        if j < cs.len() && cs[j] == '§' {
            j += 1;
        }
        let (first, after) = read_num(&cs, j);
        let Some(first) = first else {
            i += 1;
            continue;
        };
        out.push(first);
        j = after;
        // optional range tail: `- §M` / `–§M` / `-M`
        let mut k = j;
        while k < cs.len() && cs[k].is_whitespace() {
            k += 1;
        }
        if k < cs.len() && (cs[k] == '-' || cs[k] == '–') {
            k += 1;
            while k < cs.len() && cs[k].is_whitespace() {
                k += 1;
            }
            if k < cs.len() && cs[k] == '§' {
                k += 1;
            }
            let (second, after2) = read_num(&cs, k);
            if let Some(second) = second {
                out.push(second);
                j = after2;
            }
        }
        i = j;
    }
    out
}

fn read_num(cs: &[char], mut j: usize) -> (Option<u32>, usize) {
    let start = j;
    while j < cs.len() && cs[j].is_ascii_digit() {
        j += 1;
    }
    if j == start {
        return (None, j);
    }
    let s: String = cs[start..j].iter().collect();
    (s.parse().ok(), j)
}

// -------------------------------------------------------------------------
// R6 hygiene: bracket balance + unused `use`
// -------------------------------------------------------------------------

pub fn rule_hygiene_balance(sf: &SourceFile, findings: &mut Vec<Finding>) {
    let mask = &sf.lex.mask;
    let mut stack: Vec<(u8, usize)> = Vec::new();
    for (idx, &ch) in mask.iter().enumerate() {
        let open = matches!(ch, b'(' | b'[' | b'{');
        let close = matches!(ch, b')' | b']' | b'}');
        if open {
            stack.push((ch, idx));
        } else if close {
            let want = match ch {
                b')' => b'(',
                b']' => b'[',
                _ => b'{',
            };
            if stack.last().map(|&(c, _)| c) != Some(want) {
                findings.push(Finding::new(
                    &sf.path,
                    line_of(mask, idx),
                    "hygiene",
                    format!("unbalanced `{}`", ch as char),
                ));
                return;
            }
            stack.pop();
        }
    }
    if let Some(&(ch, idx)) = stack.last() {
        findings.push(Finding::new(
            &sf.path,
            line_of(mask, idx),
            "hygiene",
            format!("unclosed `{}`", ch as char),
        ));
    }
}

/// Traits routinely imported only for their methods / names the text
/// search cannot see a bare identifier for (documented, DESIGN.md §18).
/// Kept deliberately short — repo-local trait imports use an inline
/// hygiene suppression instead of growing this list.
const TRAIT_METHOD_ALLOW: [&str; 7] =
    ["Read", "Write", "BufRead", "Seek", "FromStr", "Context", "Display"];

/// One `use` statement found in the mask.
struct UseStmt {
    clause_start: usize,
    span_end: usize, // past the `;`
    is_pub: bool,
    clause: String,
}

fn use_statements(mask: &[u8]) -> Vec<UseStmt> {
    let mut out = Vec::new();
    for at in find_word(mask, "use") {
        let line_start = mask[..at].iter().rposition(|&c| c == b'\n').map_or(0, |p| p + 1);
        let prefix = String::from_utf8_lossy(&mask[line_start..at]).into_owned();
        let t = prefix.trim();
        let is_pub = if t.is_empty() {
            false
        } else if t == "pub" {
            true
        } else if let Some(rest) = t.strip_prefix("pub") {
            let r = rest.trim();
            if r.starts_with('(') && r.ends_with(')') {
                true
            } else {
                continue;
            }
        } else {
            continue;
        };
        let clause_start = skip_ws(mask, at + 3);
        let Some(semi) =
            mask[clause_start..].iter().position(|&c| c == b';').map(|p| clause_start + p)
        else {
            continue;
        };
        out.push(UseStmt {
            clause_start,
            span_end: semi + 1,
            is_pub,
            clause: String::from_utf8_lossy(&mask[clause_start..semi]).into_owned(),
        });
    }
    out
}

/// Leaf identifiers a `use` clause binds: the last path segment, the
/// `as` alias, every member of a `{...}` group (recursively); `*` globs
/// and `as _` bind nothing checkable.
fn use_leaves(clause: &str) -> Vec<String> {
    let clause = clause.trim();
    if clause.ends_with('}') {
        let Some(b) = clause.find('{') else { return Vec::new() };
        let inner = &clause[b + 1..clause.len() - 1];
        let prefix = clause[..b].trim_end_matches([':', ' ', '\t', '\n']);
        let mut parts: Vec<String> = Vec::new();
        let mut depth = 0i64;
        let mut cur = String::new();
        for ch in inner.chars() {
            if ch == '{' {
                depth += 1;
            } else if ch == '}' {
                depth -= 1;
            }
            if ch == ',' && depth == 0 {
                parts.push(std::mem::take(&mut cur));
            } else {
                cur.push(ch);
            }
        }
        parts.push(cur);
        let mut out = Vec::new();
        for p in parts {
            let pt = p.trim();
            if pt.is_empty() {
                continue;
            }
            if pt == "self" {
                let seg = prefix.rsplit("::").next().unwrap_or("").trim();
                if !seg.is_empty() {
                    out.push(seg.to_owned());
                }
            } else {
                out.extend(use_leaves(pt));
            }
        }
        return out;
    }
    if let Some(at) = clause.rfind(" as ") {
        let alias = clause[at + 4..].trim();
        return if alias == "_" { Vec::new() } else { vec![alias.to_owned()] };
    }
    let leaf = clause.rsplit("::").next().unwrap_or("").trim();
    if leaf == "*" || leaf == "self" || leaf.is_empty() {
        return Vec::new();
    }
    vec![leaf.to_owned()]
}

pub fn rule_hygiene_unused_use(sf: &SourceFile, findings: &mut Vec<Finding>) {
    let mask = &sf.lex.mask;
    let stmts = use_statements(mask);
    // the search corpus is the mask with every use clause blanked
    let mut rest = mask.clone();
    for st in &stmts {
        for slot in rest[st.clause_start..st.span_end].iter_mut() {
            if *slot != b'\n' {
                *slot = b' ';
            }
        }
    }
    for st in &stmts {
        if st.is_pub {
            continue; // pub use re-exports bind the public surface
        }
        let line = line_of(mask, st.clause_start);
        for name in use_leaves(&st.clause) {
            if TRAIT_METHOD_ALLOW.contains(&name.as_str()) {
                continue;
            }
            if find_word(&rest, &name).is_empty() {
                findings.push(Finding::new(
                    &sf.path,
                    line,
                    "hygiene",
                    format!("unused import `{name}`"),
                ));
            }
        }
    }
}
