//! The two suppression channels (DESIGN.md §18): inline
//! `lint: allow(<rule>): <reason>` comments covering their own line and
//! the next, and the repo-root `lint.baseline` file of
//! `<rule> <path> :: <reason>` entries. Reason-less or unknown-rule
//! suppressions are themselves findings (meta-rule `suppress`), and a
//! baseline entry that eats nothing is reported as stale.

use std::collections::{HashMap, HashSet};
use std::path::Path;

use crate::report::{Finding, RULES};
use crate::tree::SourceFile;

/// Inline suppression table for one file: rule -> covered lines.
pub fn suppressions(sf: &SourceFile, findings: &mut Vec<Finding>) -> HashMap<&'static str, HashSet<usize>> {
    let mut table: HashMap<&'static str, HashSet<usize>> = HashMap::new();
    for (line, text) in &sf.lex.comments {
        let Some(at) = text.find("lint:") else { continue };
        let rest = text[at + 5..].trim_start();
        let Some(body) = rest.strip_prefix("allow(") else { continue };
        let Some(close) = body.find(')') else { continue };
        let rule = &body[..close];
        if rule.is_empty() || !rule.bytes().all(|b| crate::scan::is_word(b)) {
            continue;
        }
        let mut reason = body[close + 1..].trim_start();
        reason = reason.strip_prefix(':').unwrap_or(reason);
        let reason = reason.split('\n').next().unwrap_or("").trim();
        let Some(known) = RULES.iter().copied().find(|r| *r == rule) else {
            findings.push(Finding::new(
                &sf.path,
                *line,
                "suppress",
                format!("unknown rule '{rule}' in suppression"),
            ));
            continue;
        };
        if reason.is_empty() {
            findings.push(Finding::new(
                &sf.path,
                *line,
                "suppress",
                format!("suppression for '{rule}' carries no reason"),
            ));
            continue;
        }
        let set = table.entry(known).or_default();
        set.insert(*line);
        set.insert(line + 1);
    }
    table
}

/// One parsed baseline entry.
pub struct BaselineEntry {
    pub rule: String,
    pub path: String,
    pub hits: usize,
    pub lineno: usize,
}

/// Parse `<root>/lint.baseline`. Malformed lines become findings.
pub fn load_baseline(root: &Path, findings: &mut Vec<Finding>) -> Vec<BaselineEntry> {
    let mut entries = Vec::new();
    let Ok(text) = std::fs::read_to_string(root.join("lint.baseline")) else {
        return entries;
    };
    for (i, raw) in text.split('\n').enumerate() {
        let s = raw.trim();
        if s.is_empty() || s.starts_with('#') {
            continue;
        }
        let lineno = i + 1;
        let (head, reason) = match s.split_once("::") {
            Some((h, r)) => (h, r.trim()),
            None => ("", ""),
        };
        let parts: Vec<&str> = head.split_whitespace().collect();
        if parts.len() != 2 || reason.is_empty() {
            findings.push(Finding::new(
                "lint.baseline",
                lineno,
                "suppress",
                "malformed baseline entry (want `<rule> <path> :: <reason>`)".to_owned(),
            ));
            continue;
        }
        if !RULES.contains(&parts[0]) {
            findings.push(Finding::new(
                "lint.baseline",
                lineno,
                "suppress",
                format!("unknown rule '{}'", parts[0]),
            ));
            continue;
        }
        entries.push(BaselineEntry {
            rule: parts[0].to_owned(),
            path: parts[1].to_owned(),
            hits: 0,
            lineno,
        });
    }
    entries
}
