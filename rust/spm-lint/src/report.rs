//! Findings and their two output shapes: the human `file:line: id(rule)
//! message` line and the machine LINT.json document.

/// Short rule names. `R1..R6` render from these; the meta-rule
/// `suppress` (bad suppression/baseline syntax) renders as `LINT`.
pub const RULES: [&str; 6] = ["safety", "alloc", "panic", "version", "consistency", "hygiene"];

pub fn rule_id(rule: &str) -> &'static str {
    match rule {
        "safety" => "R1",
        "alloc" => "R2",
        "panic" => "R3",
        "version" => "R4",
        "consistency" => "R5",
        "hygiene" => "R6",
        _ => "LINT",
    }
}

#[derive(Debug, Clone)]
pub struct Finding {
    pub path: String,
    pub line: usize,
    /// Short rule name (`panic`), not the `R3` id.
    pub rule: &'static str,
    pub message: String,
}

impl Finding {
    pub fn new(path: &str, line: usize, rule: &'static str, message: String) -> Finding {
        Finding { path: path.to_owned(), line, rule, message }
    }

    pub fn render(&self) -> String {
        format!("{}:{}: {}({}) {}", self.path, self.line, rule_id(self.rule), self.rule, self.message)
    }
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// The LINT.json document (same shape as the Python mirror's `--json`).
pub fn to_json(findings: &[Finding]) -> String {
    let mut out = String::new();
    out.push_str("{\n  \"tool\": \"spm-lint\",\n  \"schema_version\": 1,\n  \"findings\": [");
    for (i, f) in findings.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(&format!(
            "\n    {{\n      \"file\": \"{}\",\n      \"line\": {},\n      \"rule\": \"{}\",\n      \"message\": \"{}\"\n    }}",
            json_escape(&f.path),
            f.line,
            json_escape(f.rule),
            json_escape(&f.message)
        ));
    }
    if !findings.is_empty() {
        out.push_str("\n  ");
    }
    out.push_str("]\n}\n");
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_shape() {
        let f = Finding::new("a/b.rs", 7, "panic", "boom".to_owned());
        assert_eq!(f.render(), "a/b.rs:7: R3(panic) boom");
    }

    #[test]
    fn json_escapes_quotes() {
        let f = Finding::new("x.rs", 1, "hygiene", "unused import `\"q\"`".to_owned());
        let doc = to_json(&[f]);
        assert!(doc.contains("\\\"q\\\""));
        assert!(doc.contains("\"schema_version\": 1"));
    }
}
