//! Byte-level scanning helpers shared by the rules: word-boundary
//! search, whitespace-tolerant token-sequence matching (the stand-in for
//! the Python mirror's regexes), brace spans, fn/test/impl discovery.

pub fn is_word(b: u8) -> bool {
    b == b'_' || b.is_ascii_alphanumeric()
}

/// 1-based line number of byte `offset`.
pub fn line_of(mask: &[u8], offset: usize) -> usize {
    mask[..offset.min(mask.len())].iter().filter(|&&c| c == b'\n').count() + 1
}

/// Every occurrence of `word` with non-word bytes (or the buffer edge)
/// on both sides.
pub fn find_word(mask: &[u8], word: &str) -> Vec<usize> {
    let w = word.as_bytes();
    let mut out = Vec::new();
    if w.is_empty() || mask.len() < w.len() {
        return out;
    }
    for i in 0..=mask.len() - w.len() {
        if &mask[i..i + w.len()] != w {
            continue;
        }
        if i > 0 && is_word(mask[i - 1]) {
            continue;
        }
        let after = i + w.len();
        if after < mask.len() && is_word(mask[after]) {
            continue;
        }
        out.push(i);
    }
    out
}

pub fn skip_ws(mask: &[u8], mut i: usize) -> usize {
    while i < mask.len() && mask[i].is_ascii_whitespace() {
        i += 1;
    }
    i
}

/// Identifier starting at `i` (possibly empty) and the offset past it.
pub fn read_ident(mask: &[u8], i: usize) -> (String, usize) {
    let mut j = i;
    while j < mask.len() && is_word(mask[j]) {
        j += 1;
    }
    (String::from_utf8_lossy(&mask[i..j]).into_owned(), j)
}

/// Match a token sequence starting at `at`, any whitespace between
/// tokens. Identifier tokens (first byte a word byte) are matched with
/// word boundaries on both sides; punctuation tokens byte-for-byte.
/// Returns the offset just past the last token.
pub fn match_tokens(mask: &[u8], at: usize, toks: &[&str]) -> Option<usize> {
    let mut i = at;
    for (k, tok) in toks.iter().enumerate() {
        if k > 0 {
            i = skip_ws(mask, i);
        }
        let t = tok.as_bytes();
        if i + t.len() > mask.len() || &mask[i..i + t.len()] != t {
            return None;
        }
        if is_word(t[0]) {
            if i > 0 && is_word(mask[i - 1]) {
                return None;
            }
            let after = i + t.len();
            if after < mask.len() && is_word(mask[after]) {
                return None;
            }
        }
        i += t.len();
    }
    Some(i)
}

/// Start offsets of every match of the token sequence.
pub fn find_tokens(mask: &[u8], toks: &[&str]) -> Vec<usize> {
    let first = toks[0];
    let starts: Vec<usize> = if is_word(first.as_bytes()[0]) {
        find_word(mask, first)
    } else {
        let f = first.as_bytes();
        (0..mask.len().saturating_sub(f.len() - 1))
            .filter(|&i| &mask[i..i + f.len()] == f)
            .collect()
    };
    starts.into_iter().filter(|&i| match_tokens(mask, i, toks).is_some()).collect()
}

/// Byte span of a `{...}` block whose `{` sits at `open_idx`.
pub fn brace_span(mask: &[u8], open_idx: usize) -> (usize, usize) {
    let mut depth = 0i64;
    for (k, &c) in mask.iter().enumerate().skip(open_idx) {
        if c == b'{' {
            depth += 1;
        } else if c == b'}' {
            depth -= 1;
            if depth == 0 {
                return (open_idx, k + 1);
            }
        }
    }
    (open_idx, mask.len())
}

fn find_byte(mask: &[u8], from: usize, what: u8) -> Option<usize> {
    mask.iter().skip(from).position(|&c| c == what).map(|p| from + p)
}

/// `(name, sig_start, body_span)` for every `fn` with a body.
pub fn fn_spans(mask: &[u8]) -> Vec<(String, usize, (usize, usize))> {
    let mut out = Vec::new();
    for start in find_word(mask, "fn") {
        let at = skip_ws(mask, start + 2);
        let (name, end) = read_ident(mask, at);
        if name.is_empty() {
            continue;
        }
        let open = find_byte(mask, end, b'{');
        let semi = find_byte(mask, end, b';');
        let Some(j) = open else { continue };
        if let Some(s) = semi {
            if s < j {
                continue; // trait method declaration without a body
            }
        }
        out.push((name, start, brace_span(mask, j)));
    }
    out
}

/// Spans of `#[cfg(test)]`-gated items and `#[test]` fns.
pub fn test_regions(mask: &[u8]) -> Vec<(usize, usize)> {
    let mut spans = Vec::new();
    for toks in [
        &["#", "[", "cfg", "(", "test", ")", "]"] as &[&str],
        &["#", "[", "test", "]"],
    ] {
        for at in find_tokens(mask, toks) {
            let end = match_tokens(mask, at, toks).unwrap_or(at);
            if let Some(j) = find_byte(mask, end, b'{') {
                spans.push(brace_span(mask, j));
            }
        }
    }
    spans
}

pub fn in_spans(offset: usize, spans: &[(usize, usize)]) -> bool {
    spans.iter().any(|&(a, b)| a <= offset && offset < b)
}

/// Header text of the innermost `impl` block containing `offset`.
pub fn impl_header_of(mask: &[u8], offset: usize) -> Option<String> {
    let mut best = None;
    for start in find_word(mask, "impl") {
        if start > offset {
            break;
        }
        let Some(j) = find_byte(mask, start + 4, b'{') else { continue };
        let (a, b) = brace_span(mask, j);
        if a <= offset && offset < b {
            best = Some(String::from_utf8_lossy(&mask[start..j]).into_owned());
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn word_boundaries_hold() {
        let m = b"unsafety unsafe funsafe";
        assert_eq!(find_word(m, "unsafe"), vec![9]);
    }

    #[test]
    fn token_sequences_span_whitespace() {
        let m = b"x.lock()  .  unwrap ( ) ;";
        assert_eq!(find_tokens(m, &[".", "unwrap", "(", ")"]).len(), 1);
        assert!(find_tokens(m, &["Vec", "::", "new"]).is_empty());
    }

    #[test]
    fn fn_spans_skip_bodyless_decls() {
        let src = b"trait T { fn a(&self); }\nfn b() { 1 + 1; }\n";
        let fns = fn_spans(src);
        assert_eq!(fns.len(), 1, "the bodyless trait decl is skipped");
        assert_eq!(fns[0].0, "b");
    }
}
