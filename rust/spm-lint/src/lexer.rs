//! Comment/string/char-literal aware masking of Rust source — the whole
//! trick that lets the rules run on plain text without rustc. The mask
//! is the source with comment bodies and string/char-literal contents
//! blanked to spaces (newlines kept, so byte offsets and line numbers
//! survive); what was blanked is recorded so the comment-driven rules
//! (R1 SAFETY, suppressions, DESIGN-§ refs) and the string-driven ones
//! (R5 schema stamps) still see it. Kept in lockstep with the Python
//! mirror `tools/spm_lint.py` (DESIGN.md §18).

/// One lexed source file: `mask` is byte-for-byte the same length as the
/// input; `comments` / `strings` carry `(1-based start line, contents)`.
pub struct Lexed {
    pub mask: Vec<u8>,
    pub comments: Vec<(usize, String)>,
    pub strings: Vec<(usize, String)>,
}

fn blank(out: &mut [u8], a: usize, b: usize) {
    for slot in out[a..b].iter_mut() {
        if *slot != b'\n' {
            *slot = b' ';
        }
    }
}

fn count_newlines(b: &[u8], a: usize, z: usize) -> usize {
    b[a..z].iter().filter(|&&c| c == b'\n').count()
}

fn lossy(b: &[u8]) -> String {
    String::from_utf8_lossy(b).into_owned()
}

pub fn lex(src: &str) -> Lexed {
    let b = src.as_bytes();
    let n = b.len();
    let mut out = b.to_vec();
    let mut comments: Vec<(usize, String)> = Vec::new();
    let mut strings: Vec<(usize, String)> = Vec::new();
    let mut i = 0usize;
    let mut line = 1usize;

    while i < n {
        let c = b[i];
        if c == b'\n' {
            line += 1;
            i += 1;
            continue;
        }
        // line comment
        if c == b'/' && i + 1 < n && b[i + 1] == b'/' {
            let j = src[i..].find('\n').map_or(n, |k| i + k);
            comments.push((line, lossy(&b[i + 2..j])));
            blank(&mut out, i, j);
            i = j;
            continue;
        }
        // block comment (nested)
        if c == b'/' && i + 1 < n && b[i + 1] == b'*' {
            let (start, start_line) = (i, line);
            let mut depth = 1usize;
            i += 2;
            while i < n && depth > 0 {
                if b[i] == b'/' && i + 1 < n && b[i + 1] == b'*' {
                    depth += 1;
                    i += 2;
                } else if b[i] == b'*' && i + 1 < n && b[i + 1] == b'/' {
                    depth -= 1;
                    i += 2;
                } else {
                    if b[i] == b'\n' {
                        line += 1;
                    }
                    i += 1;
                }
            }
            let text_end = if i >= start + 4 { i - 2 } else { start + 2 };
            comments.push((start_line, lossy(&b[start + 2..text_end])));
            blank(&mut out, start, i);
            continue;
        }
        // raw (byte) string r"..." / r#"..."# / br#"..."#
        if c == b'r' || (c == b'b' && i + 1 < n && b[i + 1] == b'r') {
            let mut j = i + if c == b'r' { 1 } else { 2 };
            let mut hashes = 0usize;
            while j < n && b[j] == b'#' {
                hashes += 1;
                j += 1;
            }
            let two = &b[i..n.min(i + 2)];
            if j < n && b[j] == b'"' && (hashes > 0 || two == b"r\"" || two == b"br") {
                let mut close = Vec::with_capacity(hashes + 1);
                close.push(b'"');
                close.extend(std::iter::repeat(b'#').take(hashes));
                let mut k = j + 1;
                while k < n && !b[k..].starts_with(&close) {
                    k += 1;
                }
                let start_line = line;
                line += count_newlines(b, i, k);
                strings.push((start_line, lossy(&b[j + 1..k])));
                blank(&mut out, j + 1, k);
                i = k + close.len();
                continue;
            }
        }
        let mut i2 = i;
        let mut c2 = c;
        if c == b'b' && i + 1 < n && b[i + 1] == b'"' {
            i2 = i + 1;
            c2 = b'"';
        }
        // plain (byte) string, backslash escapes honored
        if c2 == b'"' {
            let mut j = i2 + 1;
            while j < n {
                if b[j] == b'\\' {
                    j += 2;
                    continue;
                }
                if b[j] == b'"' {
                    break;
                }
                j += 1;
            }
            let end = j.min(n);
            let start_line = line;
            line += count_newlines(b, i2, end);
            strings.push((start_line, lossy(&b[i2 + 1..end])));
            blank(&mut out, i2 + 1, end);
            i = end + 1;
            continue;
        }
        // char literal vs lifetime: 'x' or '\..' is a literal, 'ident
        // (no closing quote right after) is a lifetime
        if c == b'\'' {
            if i + 1 < n && b[i + 1] == b'\\' {
                let mut j = i + 2;
                while j < n && b[j] != b'\'' {
                    j += 1;
                }
                blank(&mut out, i + 1, j);
                i = j + 1;
                continue;
            }
            if i + 2 < n && b[i + 2] == b'\'' {
                blank(&mut out, i + 1, i + 2);
                i += 3;
                continue;
            }
            i += 1;
            continue;
        }
        i += 1;
    }
    Lexed { mask: out, comments, strings }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mask_of(s: &str) -> String {
        String::from_utf8(lex(s).mask).expect("ascii mask")
    }

    #[test]
    fn line_comment_is_blanked_and_recorded() {
        let lx = lex("let x = 1; // SAFETY: fine\nlet y = 2;\n");
        assert!(!String::from_utf8_lossy(&lx.mask).contains("SAFETY"));
        assert_eq!(lx.comments.len(), 1);
        assert_eq!(lx.comments[0].0, 1);
        assert!(lx.comments[0].1.contains("SAFETY: fine"));
    }

    #[test]
    fn nested_block_comment_keeps_line_numbers() {
        let src = "a\n/* x /* y */ z\nmore */\nb\n";
        let m = mask_of(src);
        assert_eq!(m.matches('\n').count(), src.matches('\n').count());
        assert!(m.contains('b'));
        assert!(!m.contains("more"));
    }

    #[test]
    fn strings_hide_code_lookalikes() {
        let m = mask_of("let s = \"unsafe { panic!() }\";\n");
        assert!(!m.contains("unsafe"));
        assert!(!m.contains("panic"));
    }

    #[test]
    fn raw_string_with_hashes_and_quote() {
        let lx = lex("let s = r#\"say \"hi\" // not a comment\"#; fn f() {}\n");
        let m = String::from_utf8_lossy(&lx.mask).into_owned();
        assert!(m.contains("fn f"));
        assert!(!m.contains("not a comment"));
        assert_eq!(lx.comments.len(), 0);
    }

    #[test]
    fn char_literal_brace_does_not_unbalance() {
        let m = mask_of("let c = '{'; let d = '\\n';\n");
        assert!(!m.contains('{'));
    }

    #[test]
    fn lifetimes_are_left_alone() {
        let m = mask_of("fn f<'a>(x: &'a str) -> &'a str { x }\n");
        assert!(m.contains("'a"));
    }
}
