//! Character-level corpus (substitution, DESIGN.md §6): the paper's §9.3
//! Shakespeare dataset (~1.0 MB train / 111 KB valid) is not shipped with
//! the image, so this module deterministically synthesizes a byte corpus
//! with the same statistics pipeline: a bundled public-domain Shakespeare
//! excerpt seeds an order-3 character Markov chain that is sampled out to
//! the paper's exact corpus sizes. The alphabet, line structure and
//! approximate entropy of the seed text are preserved, and the train/valid
//! split protocol matches the paper (contiguous split).

use spm_core::rng::Rng;

/// Public-domain seed text (Shakespeare excerpts).
pub const SEED_TEXT: &str = r#"To be, or not to be, that is the question:
Whether 'tis nobler in the mind to suffer
The slings and arrows of outrageous fortune,
Or to take arms against a sea of troubles
And by opposing end them. To die: to sleep;
No more; and by a sleep to say we end
The heart-ache and the thousand natural shocks
That flesh is heir to, 'tis a consummation
Devoutly to be wish'd. To die, to sleep;
To sleep: perchance to dream: ay, there's the rub;
For in that sleep of death what dreams may come
When we have shuffled off this mortal coil,
Must give us pause: there's the respect
That makes calamity of so long life;

All the world's a stage,
And all the men and women merely players:
They have their exits and their entrances;
And one man in his time plays many parts,
His acts being seven ages. At first the infant,
Mewling and puking in the nurse's arms.
And then the whining school-boy, with his satchel
And shining morning face, creeping like snail
Unwillingly to school. And then the lover,
Sighing like furnace, with a woeful ballad
Made to his mistress' eyebrow. Then a soldier,
Full of strange oaths and bearded like the pard,
Jealous in honour, sudden and quick in quarrel,
Seeking the bubble reputation
Even in the cannon's mouth.

Friends, Romans, countrymen, lend me your ears;
I come to bury Caesar, not to praise him.
The evil that men do lives after them;
The good is oft interred with their bones;
So let it be with Caesar. The noble Brutus
Hath told you Caesar was ambitious:
If it were so, it was a grievous fault,
And grievously hath Caesar answer'd it.
Here, under leave of Brutus and the rest--
For Brutus is an honourable man;
So are they all, all honourable men--
Come I to speak in Caesar's funeral.
He was my friend, faithful and just to me:
But Brutus says he was ambitious;
And Brutus is an honourable man.

Now is the winter of our discontent
Made glorious summer by this sun of York;
And all the clouds that lour'd upon our house
In the deep bosom of the ocean buried.
Now are our brows bound with victorious wreaths;
Our bruised arms hung up for monuments;
Our stern alarums changed to merry meetings,
Our dreadful marches to delightful measures.

Shall I compare thee to a summer's day?
Thou art more lovely and more temperate:
Rough winds do shake the darling buds of May,
And summer's lease hath all too short a date:
Sometime too hot the eye of heaven shines,
And often is his gold complexion dimm'd;
And every fair from fair sometime declines,
By chance or nature's changing course untrimm'd;
But thy eternal summer shall not fade
Nor lose possession of that fair thou owest;
Nor shall Death brag thou wander'st in his shade,
When in eternal lines to time thou growest:
So long as men can breathe or eyes can see,
So long lives this and this gives life to thee.
"#;

/// Paper §9.3 sizes: ~1.0 MB train, ~111 KB valid.
pub const TRAIN_BYTES: usize = 1_000_000;
pub const VALID_BYTES: usize = 111_000;

/// Order-3 character Markov chain over the seed text.
struct Markov {
    /// map 3-byte context -> candidate next bytes (with multiplicity)
    table: std::collections::HashMap<[u8; 3], Vec<u8>>,
}

impl Markov {
    fn train(text: &[u8]) -> Self {
        let mut table: std::collections::HashMap<[u8; 3], Vec<u8>> =
            std::collections::HashMap::new();
        for w in text.windows(4) {
            let ctx = [w[0], w[1], w[2]];
            table.entry(ctx).or_default().push(w[3]);
        }
        Markov { table }
    }

    fn sample(&self, len: usize, seed_ctx: [u8; 3], rng: &mut Rng, fallback: &[u8]) -> Vec<u8> {
        let mut out = Vec::with_capacity(len + 3);
        out.extend_from_slice(&seed_ctx);
        while out.len() < len {
            let ctx = [out[out.len() - 3], out[out.len() - 2], out[out.len() - 1]];
            match self.table.get(&ctx) {
                Some(cands) => out.push(cands[rng.below(cands.len())]),
                None => {
                    // restart from a random position in the seed text
                    let p = rng.below(fallback.len() - 3);
                    out.extend_from_slice(&fallback[p..p + 3]);
                }
            }
        }
        out.truncate(len);
        out
    }
}

/// The full corpus: `train` then `valid`, generated once, deterministic.
pub struct Corpus {
    pub train: Vec<u8>,
    pub valid: Vec<u8>,
}

impl Corpus {
    pub fn generate(seed: u64) -> Self {
        Self::generate_sized(seed, TRAIN_BYTES, VALID_BYTES)
    }

    /// Smaller corpora for tests/CI profiles.
    pub fn generate_sized(seed: u64, train_bytes: usize, valid_bytes: usize) -> Self {
        let seed_bytes = SEED_TEXT.as_bytes();
        let chain = Markov::train(seed_bytes);
        let mut rng = Rng::new(seed);
        let total = chain.sample(
            train_bytes + valid_bytes,
            [b'T', b'o', b' '],
            &mut rng,
            seed_bytes,
        );
        let (train, valid) = total.split_at(train_bytes);
        Corpus { train: train.to_vec(), valid: valid.to_vec() }
    }

    /// Sample a (B, T+1) batch of contiguous windows from a split; returns
    /// (inputs, targets) each B*T flat, where targets are inputs shifted by
    /// one byte (next-char prediction).
    pub fn sample_batch(
        split: &[u8],
        batch: usize,
        seq_len: usize,
        rng: &mut Rng,
    ) -> (Vec<u8>, Vec<u8>) {
        assert!(split.len() > seq_len + 1, "split too small");
        let mut inputs = Vec::with_capacity(batch * seq_len);
        let mut targets = Vec::with_capacity(batch * seq_len);
        for _ in 0..batch {
            let start = rng.below(split.len() - seq_len - 1);
            inputs.extend_from_slice(&split[start..start + seq_len]);
            targets.extend_from_slice(&split[start + 1..start + seq_len + 1]);
        }
        (inputs, targets)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let a = Corpus::generate_sized(1, 5000, 500);
        let b = Corpus::generate_sized(1, 5000, 500);
        assert_eq!(a.train, b.train);
        assert_eq!(a.valid, b.valid);
    }

    #[test]
    fn sizes_match_request() {
        let c = Corpus::generate_sized(2, 10_000, 1_000);
        assert_eq!(c.train.len(), 10_000);
        assert_eq!(c.valid.len(), 1_000);
    }

    #[test]
    fn alphabet_is_shakespearean() {
        // generated text should stay within the seed alphabet
        let c = Corpus::generate_sized(3, 20_000, 100);
        let seed_alpha: std::collections::HashSet<u8> =
            SEED_TEXT.bytes().collect();
        for &b in &c.train {
            assert!(seed_alpha.contains(&b), "byte {b} not in seed alphabet");
        }
    }

    #[test]
    fn text_is_not_trivially_periodic() {
        let c = Corpus::generate_sized(4, 10_000, 100);
        // entropy sanity: at least 20 distinct bytes and no 4-byte period
        let distinct: std::collections::HashSet<u8> = c.train.iter().copied().collect();
        assert!(distinct.len() >= 20);
        let periodic = c.train.windows(8).all(|w| w[0] == w[4]);
        assert!(!periodic);
    }

    #[test]
    fn batch_shapes_and_shift() {
        let c = Corpus::generate_sized(5, 5_000, 500);
        let mut rng = Rng::new(6);
        let (inp, tgt) = Corpus::sample_batch(&c.train, 4, 16, &mut rng);
        assert_eq!(inp.len(), 64);
        assert_eq!(tgt.len(), 64);
        // within each window, target[i] must equal input[i+1]
        for w in 0..4 {
            for i in 0..15 {
                assert_eq!(tgt[w * 16 + i], inp[w * 16 + i + 1]);
            }
        }
    }
}
