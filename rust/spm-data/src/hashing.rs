//! Feature hashing (paper §9.2 "hashed sparse features"): uni- and bi-gram
//! tokens are hashed into a fixed-width vector with a sign hash, then
//! l2-normalized. This is the standard hashing-trick text pipeline; the
//! dense/SPM first layer then consumes the resulting (B, n) rows.

/// FNV-1a 64-bit over bytes (stable across runs and platforms).
#[inline]
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xCBF29CE484222325;
    for &b in bytes {
        h = (h ^ b as u64).wrapping_mul(0x100000001B3);
    }
    h
}

/// Hash a token stream (already lowercased/split) into an `n`-dim vector:
/// unigrams + bigrams, sign hashing, l2 normalization.
pub fn hash_features(tokens: &[&str], n: usize) -> Vec<f32> {
    let mut v = vec![0.0f32; n];
    let mut add = |key: &[u8]| {
        let h = fnv1a(key);
        let idx = (h % n as u64) as usize;
        let sign = if (h >> 63) & 1 == 0 { 1.0 } else { -1.0 };
        v[idx] += sign;
    };
    for t in tokens {
        add(t.as_bytes());
    }
    for w in tokens.windows(2) {
        let mut key = Vec::with_capacity(w[0].len() + w[1].len() + 1);
        key.extend_from_slice(w[0].as_bytes());
        key.push(b'_');
        key.extend_from_slice(w[1].as_bytes());
        add(&key);
    }
    let norm: f32 = v.iter().map(|x| x * x).sum::<f32>().sqrt();
    if norm > 0.0 {
        for x in v.iter_mut() {
            *x /= norm;
        }
    }
    v
}

/// Hash a whitespace-separated document.
pub fn hash_document(doc: &str, n: usize) -> Vec<f32> {
    let tokens: Vec<&str> = doc.split_whitespace().collect();
    hash_features(&tokens, n)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let a = hash_document("the quick brown fox", 64);
        let b = hash_document("the quick brown fox", 64);
        assert_eq!(a, b);
    }

    #[test]
    fn different_docs_differ() {
        let a = hash_document("stocks rally on earnings", 128);
        let b = hash_document("striker scores late winner", 128);
        assert_ne!(a, b);
    }

    #[test]
    fn l2_normalized() {
        let v = hash_document("a b c d e f g", 256);
        let norm: f32 = v.iter().map(|x| x * x).sum::<f32>();
        assert!((norm - 1.0).abs() < 1e-5);
    }

    #[test]
    fn empty_doc_is_zero() {
        let v = hash_document("", 32);
        assert!(v.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn bigrams_matter() {
        let a = hash_document("new york", 512);
        let b = hash_document("york new", 512);
        assert_ne!(a, b); // same unigrams, different bigram
    }

    #[test]
    fn fnv_known_value() {
        // FNV-1a("") is the offset basis
        assert_eq!(fnv1a(b""), 0xCBF29CE484222325);
    }
}
