//! # spm-data
//!
//! Workload substrates for the SPM reproduction (DESIGN.md §6):
//!
//! * [`teacher`] — the §9.1 compositional teacher (SPM → ReLU → dense →
//!   argmax) generating hard-label classification data.
//! * [`hashing`] — feature hashing of token streams into fixed-width dense
//!   rows (the §9.2 "hashed sparse features" pipeline).
//! * [`agnews`] — a deterministic 4-class topical-text corpus standing in
//!   for AG News (same scale: 120k train / 7.6k test), see DESIGN.md for
//!   the substitution rationale.
//! * [`charcorpus`] — a ~1 MB Shakespeare-like byte corpus (seed excerpt +
//!   order-3 Markov extension) with the paper's train/valid split protocol.
//! * [`batch`] — a prefetching, backpressured batch pipeline (bounded
//!   channel + producer thread) used by the coordinator's training loops.

pub mod agnews;
pub mod batch;
pub mod charcorpus;
pub mod hashing;
pub mod teacher;
