//! Prefetching batch pipeline: a producer thread generates batches ahead of
//! the training loop through a bounded channel (backpressure = channel
//! capacity). This keeps data generation off the hot path — the coordinator
//! overlaps batch synthesis/hashing with device execution.

use std::sync::mpsc;
use std::thread::JoinHandle;

/// A generic prefetcher: `make(i)` produces the i-th batch on a worker
/// thread; `next()` pops in order. Dropping the prefetcher stops the worker.
pub struct Prefetcher<T: Send + 'static> {
    rx: mpsc::Receiver<T>,
    handle: Option<JoinHandle<()>>,
}

impl<T: Send + 'static> Prefetcher<T> {
    /// `total` batches, `depth` in flight at most.
    pub fn new(total: usize, depth: usize, make: impl Fn(usize) -> T + Send + 'static) -> Self {
        let (tx, rx) = mpsc::sync_channel(depth.max(1));
        let handle = std::thread::spawn(move || {
            for i in 0..total {
                let item = make(i);
                if tx.send(item).is_err() {
                    break; // consumer dropped
                }
            }
        });
        Prefetcher { rx, handle: Some(handle) }
    }

    /// Next batch (blocks if the producer is behind). None when exhausted.
    pub fn next(&mut self) -> Option<T> {
        self.rx.recv().ok()
    }
}

impl<T: Send + 'static> Drop for Prefetcher<T> {
    fn drop(&mut self) {
        // drain so the producer unblocks, then join
        while self.rx.try_recv().is_ok() {}
        drop(std::mem::replace(&mut self.rx, mpsc::sync_channel(1).1));
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Arc;

    #[test]
    fn yields_all_batches_in_order() {
        let mut p = Prefetcher::new(20, 4, |i| i * i);
        for i in 0..20 {
            assert_eq!(p.next(), Some(i * i));
        }
        assert_eq!(p.next(), None);
    }

    #[test]
    fn backpressure_limits_inflight() {
        let made = Arc::new(AtomicUsize::new(0));
        let made2 = made.clone();
        let mut p = Prefetcher::new(100, 2, move |i| {
            made2.fetch_add(1, Ordering::SeqCst);
            i
        });
        std::thread::sleep(std::time::Duration::from_millis(50));
        // producer can be at most depth + 1 ahead (one blocked in send)
        let ahead = made.load(Ordering::SeqCst);
        assert!(ahead <= 4, "produced {ahead} without consumption");
        let _ = p.next();
    }

    #[test]
    fn early_drop_stops_producer() {
        let p = Prefetcher::new(1_000_000, 2, |i| vec![i; 10]);
        drop(p); // must not hang
    }
}
