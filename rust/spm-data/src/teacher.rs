//! Compositional teacher (paper §9.1): hard labels from
//! ``x -> argmax(W2 ReLU(SPM(x)))`` with a structured SPM mixing stage.
//!
//! The student never sees the teacher's parameters — only (x, label) pairs —
//! so the experiment tests whether the student's hypothesis class can
//! *recover* the compositional structure (paper §8.3).

use spm_core::dense::Dense;
use spm_core::ops::LinearCfg;
use spm_core::pairing::Schedule;
use spm_core::rng::Rng;
use spm_core::spm::{Spm, SpmParams, SpmSpec, Variant};
use spm_core::tensor::Mat;

pub struct Teacher {
    pub n: usize,
    pub num_classes: usize,
    op: Spm,
    params: SpmParams,
    w2: Dense,
}

impl Teacher {
    /// Deterministic teacher for width `n` (matches the python teacher's
    /// structure; seeds are independent per width).
    pub fn new(n: usize, num_classes: usize, seed: u64) -> Self {
        let spec = SpmSpec::new(n, Variant::General)
            .with_schedule(Schedule::Butterfly)
            .with_seed(seed);
        let op = Spm::new(spec);
        let mut rng = Rng::new(seed ^ TEACHER_TAG);
        let mut params = op.init_params(&mut rng);
        // non-trivial diagonal emphasis, same shape as python's init_teacher
        for v in params.d_in.iter_mut() {
            *v = 1.0 + 0.5 * rng.normal();
        }
        let w2 = Dense::init(&mut rng, num_classes, n);
        Teacher { n, num_classes, op, params, w2 }
    }

    /// Teacher logits for a batch.
    pub fn logits(&self, x: &Mat) -> Mat {
        let mut h = self.op.forward(&self.params, x);
        for v in h.data.iter_mut() {
            *v = v.max(0.0);
        }
        self.w2.forward(&h)
    }

    /// Hard labels (argmax, §9.1).
    pub fn labels(&self, x: &Mat) -> Vec<u32> {
        let logits = self.logits(x);
        (0..logits.rows)
            .map(|i| {
                let row = logits.row(i);
                let mut best = 0;
                for j in 1..row.len() {
                    if row[j] > row[best] {
                        best = j;
                    }
                }
                best as u32
            })
            .collect()
    }

    /// Sample a labelled batch: x ~ N(0, I), y = teacher(x).
    pub fn sample(&self, batch: usize, rng: &mut Rng) -> (Mat, Vec<u32>) {
        let x = Mat::from_vec(batch, self.n, rng.normal_vec(batch * self.n, 1.0));
        let y = self.labels(&x);
        (x, y)
    }

    /// The LinearCfg a *matched* SPM student would use (same schedule
    /// family, its own parameters).
    pub fn student_cfg(&self) -> LinearCfg {
        LinearCfg::spm(self.n, Variant::General).with_schedule(Schedule::Butterfly)
    }
}

const TEACHER_TAG: u64 = 0x7EAC_4E85_0000_0001;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_labels() {
        let t1 = Teacher::new(32, 10, 7);
        let t2 = Teacher::new(32, 10, 7);
        let mut r1 = Rng::new(1);
        let mut r2 = Rng::new(1);
        let (x1, y1) = t1.sample(64, &mut r1);
        let (x2, y2) = t2.sample(64, &mut r2);
        assert_eq!(x1.data, x2.data);
        assert_eq!(y1, y2);
    }

    #[test]
    fn labels_use_many_classes() {
        let t = Teacher::new(64, 10, 3);
        let mut rng = Rng::new(2);
        let (_x, y) = t.sample(512, &mut rng);
        let mut seen = vec![false; 10];
        for &l in &y {
            seen[l as usize] = true;
        }
        assert!(seen.iter().filter(|&&b| b).count() >= 5, "{seen:?}");
    }

    #[test]
    fn different_seeds_different_teachers() {
        let ta = Teacher::new(32, 10, 1);
        let tb = Teacher::new(32, 10, 2);
        let mut rng = Rng::new(3);
        let x = Mat::from_vec(128, 32, rng.normal_vec(128 * 32, 1.0));
        assert_ne!(ta.labels(&x), tb.labels(&x));
    }
}
