//! AG-News-proxy corpus (substitution, DESIGN.md §6): the real AG News
//! dataset needs a network download, so this module generates a
//! deterministic 4-class topical corpus at the same scale (120,000 train /
//! 7,600 test) and feeds it through the *identical* hashing pipeline the
//! paper's §9.2 experiment uses. Class signal comes from per-class keyword
//! vocabularies mixed with shared filler words; document length and keyword
//! density are randomized so the task is learnable but not trivial.

use crate::hashing::hash_features;
use spm_core::rng::Rng;
use spm_core::tensor::Mat;

pub const NUM_CLASSES: usize = 4;
pub const TRAIN_SIZE: usize = 120_000;
pub const TEST_SIZE: usize = 7_600;

/// The four AG News categories.
pub const CLASS_NAMES: [&str; 4] = ["World", "Sports", "Business", "Sci/Tech"];

const WORLD: &[&str] = &[
    "government", "minister", "election", "treaty", "embassy", "border",
    "parliament", "diplomat", "sanctions", "summit", "protest", "ceasefire",
    "refugee", "coalition", "regime", "envoy", "militia", "province",
    "capital", "nation", "crisis", "talks", "accord", "war",
];
const SPORTS: &[&str] = &[
    "season", "coach", "striker", "playoff", "championship", "tournament",
    "goal", "inning", "quarterback", "league", "match", "stadium",
    "victory", "defeat", "transfer", "medal", "sprint", "racket",
    "penalty", "referee", "roster", "draft", "title", "cup",
];
const BUSINESS: &[&str] = &[
    "earnings", "shares", "profit", "merger", "acquisition", "investor",
    "stocks", "market", "quarterly", "revenue", "dividend", "bankruptcy",
    "regulator", "inflation", "forecast", "ipo", "hedge", "bond",
    "lending", "retail", "oil", "prices", "trade", "deficit",
];
const SCITECH: &[&str] = &[
    "software", "internet", "chip", "browser", "satellite", "genome",
    "biotech", "processor", "wireless", "startup", "algorithm", "robot",
    "spacecraft", "telescope", "vaccine", "encryption", "server", "gadget",
    "download", "network", "silicon", "quantum", "battery", "cloud",
];
const FILLER: &[&str] = &[
    "the", "a", "of", "to", "in", "on", "for", "with", "after", "over",
    "said", "new", "report", "announced", "today", "yesterday", "week",
    "year", "official", "group", "plan", "deal", "first", "latest", "major",
    "early", "late", "public", "move", "set",
];

fn class_vocab(c: usize) -> &'static [&'static str] {
    match c {
        0 => WORLD,
        1 => SPORTS,
        2 => BUSINESS,
        _ => SCITECH,
    }
}

/// Generate the `i`-th document of the given split as (tokens, label).
/// Documents are fully determined by (split_seed, i).
pub fn document(split_seed: u64, i: usize, rng_out: &mut Vec<&'static str>) -> u32 {
    let mut rng = Rng::new(split_seed.wrapping_mul(0x9E37_79B9_7F4A_7C15) ^ i as u64);
    let label = (rng.below(NUM_CLASSES)) as u32;
    let vocab = class_vocab(label as usize);
    let len = 18 + rng.below(22); // 18..40 tokens, headline-ish
    // keyword density 25-55%
    let density = 0.25 + 0.3 * rng.uniform();
    rng_out.clear();
    for _ in 0..len {
        if rng.uniform() < density {
            rng_out.push(vocab[rng.below(vocab.len())]);
        } else {
            rng_out.push(FILLER[rng.below(FILLER.len())]);
        }
    }
    label
}

/// Materialize `count` hashed documents starting at index `start`.
/// Returns (features (count, n), labels).
pub fn batch(split_seed: u64, start: usize, count: usize, n: usize) -> (Mat, Vec<u32>) {
    let mut x = Mat::zeros(count, n);
    let mut y = Vec::with_capacity(count);
    let mut toks: Vec<&'static str> = Vec::new();
    for r in 0..count {
        let label = document(split_seed, start + r, &mut toks);
        let feats = hash_features(&toks, n);
        x.row_mut(r).copy_from_slice(&feats);
        y.push(label);
    }
    (x, y)
}

pub const TRAIN_SEED: u64 = 11;
pub const TEST_SEED: u64 = 13;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_documents() {
        let mut a = Vec::new();
        let mut b = Vec::new();
        let la = document(TRAIN_SEED, 42, &mut a);
        let lb = document(TRAIN_SEED, 42, &mut b);
        assert_eq!(la, lb);
        assert_eq!(a, b);
    }

    #[test]
    fn all_classes_present() {
        let (_x, y) = batch(TRAIN_SEED, 0, 400, 128);
        let mut seen = [false; NUM_CLASSES];
        for &l in &y {
            seen[l as usize] = true;
        }
        assert!(seen.iter().all(|&s| s), "{seen:?}");
    }

    #[test]
    fn train_test_disjoint_streams() {
        let (xa, _) = batch(TRAIN_SEED, 0, 8, 64);
        let (xb, _) = batch(TEST_SEED, 0, 8, 64);
        assert_ne!(xa.data, xb.data);
    }

    #[test]
    fn linear_separability_signal_exists() {
        // nearest-centroid on hashed features should beat chance by a lot
        let n = 512;
        let (xtr, ytr) = batch(TRAIN_SEED, 0, 2000, n);
        let (xte, yte) = batch(TEST_SEED, 0, 500, n);
        let mut centroids = vec![vec![0.0f32; n]; NUM_CLASSES];
        let mut counts = [0usize; NUM_CLASSES];
        for i in 0..xtr.rows {
            let c = ytr[i] as usize;
            counts[c] += 1;
            for (cv, xv) in centroids[c].iter_mut().zip(xtr.row(i)) {
                *cv += xv;
            }
        }
        for (c, cnt) in centroids.iter_mut().zip(counts) {
            for v in c.iter_mut() {
                *v /= cnt.max(1) as f32;
            }
        }
        let mut correct = 0;
        for i in 0..xte.rows {
            let row = xte.row(i);
            let mut best = 0;
            let mut best_dot = f32::NEG_INFINITY;
            for (c, cent) in centroids.iter().enumerate() {
                let dot: f32 = row.iter().zip(cent).map(|(a, b)| a * b).sum();
                if dot > best_dot {
                    best_dot = dot;
                    best = c;
                }
            }
            if best as u32 == yte[i] {
                correct += 1;
            }
        }
        let acc = correct as f32 / xte.rows as f32;
        assert!(acc > 0.6, "nearest-centroid acc {acc}");
    }
}
