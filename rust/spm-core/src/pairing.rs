//! Pairing schedules (paper §2.1, §5) — the rust mirror of
//! ``python/compile/pairing.py``. The butterfly and shift constructions are
//! bit-for-bit identical across the two languages and are cross-checked via
//! the FNV-1a-64 `fingerprint` recorded in the artifact manifest. The
//! random schedule is seeded independently per language (numpy PCG vs
//! SplitMix64) and is only required to be a valid partition.

use crate::rng::Rng;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Schedule {
    Butterfly,
    Shift,
    Random,
}

impl Schedule {
    pub fn parse(s: &str) -> Option<Schedule> {
        match s {
            "butterfly" => Some(Schedule::Butterfly),
            "shift" => Some(Schedule::Shift),
            "random" => Some(Schedule::Random),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Schedule::Butterfly => "butterfly",
            Schedule::Shift => "shift",
            Schedule::Random => "random",
        }
    }
}

/// One stage's pairing: coordinate `left[k]` mixes with `right[k]`;
/// `leftover` is the unpaired coordinate for odd n (paper §5).
#[derive(Clone, Debug, PartialEq)]
pub struct StagePairing {
    pub left: Vec<u32>,
    pub right: Vec<u32>,
    pub leftover: Option<u32>,
}

impl StagePairing {
    pub fn num_pairs(&self) -> usize {
        self.left.len()
    }

    /// Check the pairing is a disjoint partition of 0..n-1.
    pub fn validate(&self, n: usize) -> Result<(), String> {
        let mut seen = vec![false; n];
        let mut mark = |v: u32| -> Result<(), String> {
            let i = v as usize;
            if i >= n {
                return Err(format!("index {i} out of range {n}"));
            }
            if seen[i] {
                return Err(format!("index {i} appears twice"));
            }
            seen[i] = true;
            Ok(())
        };
        for (&l, &r) in self.left.iter().zip(&self.right) {
            mark(l)?;
            mark(r)?;
        }
        if let Some(lv) = self.leftover {
            mark(lv)?;
        }
        if seen.iter().all(|&b| b) {
            Ok(())
        } else {
            Err("pairing does not cover 0..n-1".into())
        }
    }
}

/// FFT-style stride pairing: stage `l` mixes `i` with `i + 2^(l mod log2 n)`
/// within aligned blocks; non-power-of-two tails pair adjacently.
pub fn butterfly_stage(n: usize, stage: usize) -> StagePairing {
    assert!(n >= 2, "n must be >= 2");
    let levels = (usize::BITS - 1 - n.leading_zeros()).max(1) as usize; // floor(log2 n)
    let s = 1usize << (stage % levels);
    let mut left = Vec::with_capacity(n / 2);
    let mut right = Vec::with_capacity(n / 2);
    let nb = n / (2 * s);
    for b in 0..nb {
        let base = b * 2 * s;
        for i in 0..s {
            left.push((base + i) as u32);
            right.push((base + s + i) as u32);
        }
    }
    let tail: Vec<u32> = ((nb * 2 * s) as u32..n as u32).collect();
    let mut k = 0;
    while k + 1 < tail.len() {
        left.push(tail[k]);
        right.push(tail[k + 1]);
        k += 2;
    }
    let leftover = if tail.len() % 2 == 1 { tail.last().copied() } else { None };
    StagePairing { left, right, leftover }
}

/// Rotating adjacent pairing: stage `l` pairs `(2k+l, 2k+1+l) mod n`.
pub fn shift_stage(n: usize, stage: usize) -> StagePairing {
    assert!(n >= 2, "n must be >= 2");
    let p = n / 2;
    let offs = stage % n;
    let mut left = Vec::with_capacity(p);
    let mut right = Vec::with_capacity(p);
    for k in 0..p {
        left.push(((2 * k + offs) % n) as u32);
        right.push(((2 * k + 1 + offs) % n) as u32);
    }
    let leftover = if n % 2 == 1 { Some(((2 * p + offs) % n) as u32) } else { None };
    StagePairing { left, right, leftover }
}

/// Seeded random disjoint pairing, independent per stage.
pub fn random_stage(n: usize, stage: usize, seed: u64) -> StagePairing {
    assert!(n >= 2, "n must be >= 2");
    let mut rng = Rng::new(seed.wrapping_mul(0x9E3779B9).wrapping_add(stage as u64));
    let perm = rng.permutation(n);
    let p = n / 2;
    let left = (0..p).map(|k| perm[2 * k]).collect();
    let right = (0..p).map(|k| perm[2 * k + 1]).collect();
    let leftover = if n % 2 == 1 { Some(perm[n - 1]) } else { None };
    StagePairing { left, right, leftover }
}

pub fn make_schedule(kind: Schedule, n: usize, num_stages: usize, seed: u64) -> Vec<StagePairing> {
    (0..num_stages)
        .map(|l| match kind {
            Schedule::Butterfly => butterfly_stage(n, l),
            Schedule::Shift => shift_stage(n, l),
            Schedule::Random => random_stage(n, l, seed),
        })
        .collect()
}

/// Paper §2.2 default: L = round(log2 n).
pub fn default_num_stages(n: usize) -> usize {
    ((n as f64).log2().round() as usize).max(1)
}

/// The fixed deterministic input shuffle of the DYAD-style block-shuffle
/// operator (DESIGN.md §19): a seeded Fisher–Yates permutation of
/// `0..n-1`, derived exactly like [`random_stage`]'s per-stage streams
/// but on its own stream tag so a block-shuffle op and a random-schedule
/// SPM op at the same seed do not share draws. Part of the checkpoint
/// arch fingerprint — same (n, seed) must reproduce the same shuffle on
/// every build.
pub fn shuffle_permutation(n: usize, seed: u64) -> Vec<u32> {
    let mut rng = Rng::new(seed.wrapping_mul(0x9E3779B9).wrapping_add(0x5BD1E995));
    rng.permutation(n)
}

/// FNV-1a-64 fingerprint, bit-identical to python's `schedule_fingerprint`.
pub fn fingerprint(stages: &[StagePairing]) -> u64 {
    let mut h: u64 = 0xCBF29CE484222325;
    const PRIME: u64 = 0x100000001B3;
    let mut mix = |v: u32| {
        for shift in [0u32, 8, 16, 24] {
            h = (h ^ ((v >> shift) & 0xFF) as u64).wrapping_mul(PRIME);
        }
    };
    for st in stages {
        for &v in &st.left {
            mix(v);
        }
        for &v in &st.right {
            mix(v);
        }
        mix(st.leftover.unwrap_or(0xFFFF_FFFF));
    }
    h
}

pub fn fingerprint_hex(stages: &[StagePairing]) -> String {
    format!("{:016x}", fingerprint(stages))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::forall;

    #[test]
    fn butterfly_power_of_two_layout() {
        let s0 = butterfly_stage(8, 0);
        assert_eq!(s0.left, vec![0, 2, 4, 6]);
        assert_eq!(s0.right, vec![1, 3, 5, 7]);
        let s1 = butterfly_stage(8, 1);
        assert_eq!(s1.left, vec![0, 1, 4, 5]);
        assert_eq!(s1.right, vec![2, 3, 6, 7]);
        let s2 = butterfly_stage(8, 2);
        assert_eq!(s2.left, vec![0, 1, 2, 3]);
        assert_eq!(s2.right, vec![4, 5, 6, 7]);
    }

    #[test]
    fn all_schedules_partition() {
        for kind in [Schedule::Butterfly, Schedule::Shift, Schedule::Random] {
            for n in [2usize, 3, 5, 7, 8, 16, 33, 100, 257] {
                for st in make_schedule(kind, n, 6, 3) {
                    st.validate(n).unwrap();
                    assert_eq!(st.num_pairs(), n / 2);
                    assert_eq!(st.leftover.is_some(), n % 2 == 1);
                }
            }
        }
    }

    #[test]
    fn partition_property_random_sizes() {
        forall(200, 42, |rng| {
            let n = 2 + rng.below(300);
            let l = 1 + rng.below(8);
            let kind = [Schedule::Butterfly, Schedule::Shift, Schedule::Random][rng.below(3)];
            for st in make_schedule(kind, n, l, rng.next_u64()) {
                st.validate(n).map_err(|e| format!("{kind:?} n={n}: {e}"))?;
            }
            Ok(())
        });
    }

    #[test]
    fn butterfly_strides_wrap() {
        assert_eq!(butterfly_stage(16, 0), butterfly_stage(16, 4));
    }

    #[test]
    fn fingerprints_distinguish() {
        let a = make_schedule(Schedule::Butterfly, 64, 4, 0);
        let b = make_schedule(Schedule::Shift, 64, 4, 0);
        assert_ne!(fingerprint(&a), fingerprint(&b));
        assert_eq!(fingerprint(&a), fingerprint(&a.clone()));
    }

    #[test]
    fn default_stages() {
        assert_eq!(default_num_stages(256), 8);
        assert_eq!(default_num_stages(4096), 12);
        assert_eq!(default_num_stages(2), 1);
    }

    #[test]
    fn shuffle_permutation_is_a_seeded_bijection() {
        for n in [2usize, 3, 8, 97, 256] {
            for seed in [0u64, 1, 7, 0xDEAD_BEEF] {
                let p = shuffle_permutation(n, seed);
                assert_eq!(p.len(), n);
                let mut seen = vec![false; n];
                for &v in &p {
                    assert!(!std::mem::replace(&mut seen[v as usize], true), "dup {v}");
                }
                // deterministic across calls...
                assert_eq!(p, shuffle_permutation(n, seed));
            }
            // ...and seed-sensitive (n >= 3 leaves room to differ)
            if n >= 3 {
                assert_ne!(shuffle_permutation(n, 1), shuffle_permutation(n, 2), "n={n}");
            }
        }
    }

    // Golden fingerprints exported by python; regenerate with:
    //   python -c "from compile import pairing as p; \
    //     print(p.schedule_fingerprint(p.make_schedule('butterfly', 64, 6)))"
    #[test]
    fn fingerprint_matches_python() {
        for (kind, n, l, want) in [
            (Schedule::Butterfly, 64, 6, "1e90eb00afc2eb6d"),
            (Schedule::Butterfly, 33, 5, "e5b7355c64770515"),
            (Schedule::Butterfly, 256, 8, "2c9531d5172e0785"),
            (Schedule::Shift, 64, 6, "6c56c44d502b406d"),
            (Schedule::Shift, 33, 5, "ff3988a7bb9d49e5"),
            (Schedule::Shift, 256, 8, "5d730e51fba4c985"),
        ] {
            assert_eq!(
                fingerprint_hex(&make_schedule(kind, n, l, 0)),
                want,
                "{kind:?} n={n} L={l}"
            );
        }
    }
}
