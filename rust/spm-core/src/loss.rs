//! Losses with exact gradients: softmax cross-entropy (classification,
//! char-LM) and MSE (attention demo).

use crate::tensor::Mat;

/// Numerically-stable row softmax in place.
pub fn softmax_rows(m: &mut Mat) {
    for i in 0..m.rows {
        let row = m.row_mut(i);
        let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut z = 0.0;
        for v in row.iter_mut() {
            *v = (*v - mx).exp();
            z += *v;
        }
        for v in row.iter_mut() {
            *v /= z;
        }
    }
}

/// Mean softmax cross-entropy over rows with integer labels.
/// Returns (loss, accuracy, g_logits) where g_logits = (softmax - onehot)/B.
pub fn softmax_xent(logits: &Mat, labels: &[u32]) -> (f32, f32, Mat) {
    let mut g = Mat { rows: 0, cols: 0, data: Vec::new() };
    let (loss, acc) = softmax_xent_into(logits, labels, &mut g);
    (loss, acc, g)
}

/// [`softmax_xent`] writing the logit gradient into a caller-owned buffer
/// so steady-state training loops never allocate here.
pub fn softmax_xent_into(logits: &Mat, labels: &[u32], g: &mut Mat) -> (f32, f32) {
    assert_eq!(logits.rows, labels.len());
    let b = logits.rows as f32;
    g.rows = logits.rows;
    g.cols = logits.cols;
    g.data.clear();
    g.data.extend_from_slice(&logits.data);
    softmax_rows(g);
    let mut loss = 0.0;
    let mut correct = 0usize;
    for i in 0..logits.rows {
        let li = labels[i] as usize;
        let row = g.row(i);
        loss -= row[li].max(1e-30).ln();
        let argmax = row
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(j, _)| j)
            .unwrap();
        if argmax == li {
            correct += 1;
        }
    }
    for i in 0..g.rows {
        let li = labels[i] as usize;
        g.row_mut(i)[li] -= 1.0;
    }
    for v in g.data.iter_mut() {
        *v /= b;
    }
    (loss / b, correct as f32 / b)
}

/// Mean squared error: returns (loss, g_pred).
pub fn mse(pred: &Mat, target: &Mat) -> (f32, Mat) {
    let mut g = Mat { rows: 0, cols: 0, data: Vec::new() };
    let loss = mse_into(pred, target, &mut g);
    (loss, g)
}

/// [`mse`] writing the prediction gradient into a caller-owned buffer.
pub fn mse_into(pred: &Mat, target: &Mat, g: &mut Mat) -> f32 {
    assert_eq!(pred.data.len(), target.data.len());
    let n = pred.data.len() as f32;
    g.rows = pred.rows;
    g.cols = pred.cols;
    g.data.clear();
    g.data.extend_from_slice(&pred.data);
    let mut loss = 0.0;
    for (gv, t) in g.data.iter_mut().zip(&target.data) {
        let d = *gv - t;
        loss += d * d;
        *gv = 2.0 * d / n;
    }
    loss / n
}

/// Bits-per-character from an NLL in nats (paper §9.3 metric).
pub fn nats_to_bpc(nll: f32) -> f32 {
    nll / std::f32::consts::LN_2
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::numerical_grad;

    #[test]
    fn softmax_rows_sum_to_one() {
        let mut m = Mat::from_vec(2, 3, vec![1.0, 2.0, 3.0, -5.0, 0.0, 5.0]);
        softmax_rows(&mut m);
        for i in 0..2 {
            let s: f32 = m.row(i).iter().sum();
            assert!((s - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn xent_uniform_is_log_c() {
        let logits = Mat::zeros(4, 8);
        let labels = vec![0u32, 1, 2, 3];
        let (loss, _acc, _g) = softmax_xent(&logits, &labels);
        assert!((loss - (8.0f32).ln()).abs() < 1e-5);
    }

    #[test]
    fn xent_accuracy() {
        let logits = Mat::from_vec(3, 2, vec![2.0, 0.0, 0.0, 2.0, 2.0, 0.0]);
        let labels = vec![0u32, 1, 1];
        let (_l, acc, _g) = softmax_xent(&logits, &labels);
        assert!((acc - 2.0 / 3.0).abs() < 1e-6);
    }

    #[test]
    fn xent_grad_finite_difference() {
        let mut lv = vec![0.3f32, -0.2, 0.9, 0.1, 0.5, -0.7];
        let labels = vec![2u32, 0];
        let logits = Mat::from_vec(2, 3, lv.clone());
        let (_loss, _acc, g) = softmax_xent(&logits, &labels);
        for idx in 0..6 {
            let num = numerical_grad(&mut lv, idx, 1e-3, |v| {
                softmax_xent(&Mat::from_vec(2, 3, v.to_vec()), &labels).0
            });
            assert!((g.data[idx] - num).abs() < 1e-3, "g[{idx}] {} vs {num}", g.data[idx]);
        }
    }

    #[test]
    fn mse_grad_finite_difference() {
        let mut pv = vec![0.5f32, -1.0, 2.0, 0.0];
        let target = Mat::from_vec(2, 2, vec![0.0, 0.0, 1.0, 1.0]);
        let (_l, g) = mse(&Mat::from_vec(2, 2, pv.clone()), &target);
        for idx in 0..4 {
            let num = numerical_grad(&mut pv, idx, 1e-3, |v| {
                mse(&Mat::from_vec(2, 2, v.to_vec()), &target).0
            });
            assert!((g.data[idx] - num).abs() < 1e-3);
        }
    }

    #[test]
    fn bpc_conversion() {
        assert!((nats_to_bpc(std::f32::consts::LN_2) - 1.0).abs() < 1e-6);
    }
}
