//! The dense comparator (paper §1): y = x W^T + b with exact backward.
//! This is the baseline every experiment compares SPM against.

use crate::rng::Rng;
use crate::tensor::{add_bias, col_sum, matmul, matmul_nt, matmul_tn, Mat};

/// Dense linear layer, weights stored (out, in) row-major.
#[derive(Clone, Debug)]
pub struct Dense {
    pub w: Mat,
    pub b: Vec<f32>,
}

/// Gradients mirroring [`Dense`].
#[derive(Clone, Debug)]
pub struct DenseGrads {
    pub w: Mat,
    pub b: Vec<f32>,
}

impl Dense {
    /// Gaussian fan-in init (matches python/compile/model.py).
    pub fn init(rng: &mut Rng, out_dim: usize, in_dim: usize) -> Self {
        let scale = 1.0 / (in_dim as f32).sqrt();
        Dense {
            w: Mat::from_vec(out_dim, in_dim, rng.normal_vec(out_dim * in_dim, scale)),
            b: vec![0.0; out_dim],
        }
    }

    pub fn param_count(&self) -> usize {
        self.w.data.len() + self.b.len()
    }

    /// y = x W^T + b;  x: (B, in) -> (B, out).
    pub fn forward(&self, x: &Mat) -> Mat {
        let mut y = matmul_nt(x, &self.w);
        add_bias(&mut y, &self.b);
        y
    }

    /// Exact backward: returns (g_x, grads).
    pub fn backward(&self, x: &Mat, gy: &Mat) -> (Mat, DenseGrads) {
        let gx = matmul(gy, &self.w); // (B,out) x (out,in)
        let gw = matmul_tn(gy, x); // (out,B)^T-free x (B,in) -> (out,in)
        let gb = col_sum(gy);
        (gx, DenseGrads { w: gw, b: gb })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::numerical_grad;

    #[test]
    fn forward_shape_and_bias() {
        let mut rng = Rng::new(1);
        let mut l = Dense::init(&mut rng, 3, 5);
        l.b = vec![1.0, 2.0, 3.0];
        let x = Mat::zeros(2, 5);
        let y = l.forward(&x);
        assert_eq!((y.rows, y.cols), (2, 3));
        assert_eq!(y.row(0), &[1.0, 2.0, 3.0]);
    }

    #[test]
    fn backward_finite_difference() {
        let mut rng = Rng::new(2);
        let l = Dense::init(&mut rng, 4, 6);
        let mut xv = rng.normal_vec(3 * 6, 1.0);
        let x = Mat::from_vec(3, 6, xv.clone());
        let y = l.forward(&x);
        // loss = sum(y^2)/2 -> gy = y
        let (gx, grads) = l.backward(&x, &y);

        for idx in [0usize, 7, 17] {
            let num = numerical_grad(&mut xv, idx, 1e-2, |v| {
                let y = l.forward(&Mat::from_vec(3, 6, v.to_vec()));
                y.data.iter().map(|t| t * t * 0.5).sum()
            });
            assert!((gx.data[idx] - num).abs() < 2e-2 * 1.0f32.max(num.abs()),
                    "gx[{idx}] {} vs {num}", gx.data[idx]);
        }
        let mut wv = l.w.data.clone();
        for idx in [0usize, 5, 23] {
            let num = numerical_grad(&mut wv, idx, 1e-2, |v| {
                let l2 = Dense { w: Mat::from_vec(4, 6, v.to_vec()), b: l.b.clone() };
                let y = l2.forward(&x);
                y.data.iter().map(|t| t * t * 0.5).sum()
            });
            assert!((grads.w.data[idx] - num).abs() < 2e-2 * 1.0f32.max(num.abs()),
                    "gw[{idx}] {} vs {num}", grads.w.data[idx]);
        }
    }

    #[test]
    fn bias_grad_is_colsum() {
        let mut rng = Rng::new(3);
        let l = Dense::init(&mut rng, 2, 2);
        let x = Mat::from_vec(3, 2, rng.normal_vec(6, 1.0));
        let gy = Mat::from_vec(3, 2, vec![1.0; 6]);
        let (_gx, grads) = l.backward(&x, &gy);
        assert_eq!(grads.b, vec![3.0, 3.0]);
    }
}
