//! # spm-core
//!
//! Native CPU substrate for **Stagewise Pairwise Mixers** (SPM), the
//! structured linear operator of Farag, *"Rethinking Dense Linear
//! Transformations"* (2025). Implements the paper's exact closed-form
//! forward/backward for both block parameterizations, the dense comparator,
//! pairing schedules, optimizers, losses and the model zoo (classifier,
//! char-LM, GRU §6, attention §7), all dependency-free.
//!
//! Models consume linear maps exclusively through the planned [`ops`]
//! layer (`LinearOp` + `SpmPlan` + flat parameter buffers, DESIGN.md §3);
//! [`spm`] keeps the closed-form reference implementation the planned
//! path is tested against.
//!
//! The XLA/PJRT execution path lives in `spm-runtime`; this crate is the
//! reference/native engine the benches and property tests run against.

// The stage kernels and closed-form backwards index several slices in
// lockstep through a shared pair table (`z[i]`/`z[j]`/`g[i]`/`g[j]` at
// indices drawn from the schedule); rewriting them as iterator chains
// obscures the paper's equation numbering, so the range-loop style lint
// is off crate-wide. Everything else clippy flags is a hard error in CI
// (see ci.sh and the workflow's strict clippy step).
#![allow(clippy::needless_range_loop)]

pub mod dense;
pub mod loss;
pub mod models;
pub mod ops;
pub mod optim;
pub mod pairing;
pub mod parallel;
pub mod rng;
pub mod spm;
pub mod tensor;
pub mod testkit;

pub use dense::Dense;
pub use ops::{LinearCfg, LinearKind, LinearOp, LinearTrace, SpmExec, SpmPlan};
pub use pairing::Schedule;
pub use rng::Rng;
pub use spm::{Spm, SpmParams, SpmSpec, Variant};
pub use tensor::Mat;
