//! # spm-core
//!
//! Native CPU substrate for **Stagewise Pairwise Mixers** (SPM), the
//! structured linear operator of Farag, *"Rethinking Dense Linear
//! Transformations"* (2025). Implements the paper's exact closed-form
//! forward/backward for both block parameterizations, the dense comparator,
//! pairing schedules, optimizers, losses and the model zoo (classifier,
//! char-LM, GRU §6, attention §7), all dependency-free.
//!
//! The XLA/PJRT execution path lives in `spm-runtime`; this crate is the
//! reference/native engine the benches and property tests run against.
pub mod dense;
pub mod loss;
pub mod models;
pub mod optim;
pub mod pairing;
pub mod parallel;
pub mod rng;
pub mod spm;
pub mod tensor;
pub mod testkit;

pub use dense::Dense;
pub use pairing::Schedule;
pub use rng::Rng;
pub use spm::{Spm, SpmParams, SpmSpec, Variant};
pub use tensor::Mat;
