//! Optimizers over flat f32 slices. Every `ops::LinearOp` registers its
//! single contiguous parameter buffer as one "slot"; the optimizer owns
//! per-slot moment buffers and updates a whole op with ONE flat kernel
//! call (DESIGN.md §4). The Adam math is identical to the in-graph Adam in
//! python/compile/train.py so native and XLA training trajectories are
//! comparable.

/// The flat-slot optimizer contract `ops::LinearOp` builds against:
/// register a contiguous parameter buffer once, update it in one call.
pub trait Optimizer {
    /// Register a flat parameter buffer; returns its slot id.
    fn register(&mut self, len: usize) -> usize;
    /// Update one slot from its same-length flat gradient buffer.
    fn update(&mut self, slot: usize, params: &mut [f32], grads: &[f32]);
}

/// Plain SGD (stateless; the slot id is ignored).
#[derive(Clone, Debug)]
pub struct Sgd {
    pub lr: f32,
}

impl Sgd {
    pub fn step(&self, params: &mut [f32], grads: &[f32]) {
        debug_assert_eq!(params.len(), grads.len());
        for (p, g) in params.iter_mut().zip(grads) {
            *p -= self.lr * g;
        }
    }
}

impl Optimizer for Sgd {
    fn register(&mut self, _len: usize) -> usize {
        0
    }

    fn update(&mut self, _slot: usize, params: &mut [f32], grads: &[f32]) {
        self.step(params, grads);
    }
}

/// Heavy-ball momentum SGD: v = mu*v + g; p -= lr*v. One moment buffer per
/// slot — with flat `LinearOp` storage this is a single pass over the
/// whole op regardless of how many logical tensors it contains.
#[derive(Clone, Debug)]
pub struct SgdMomentum {
    pub lr: f32,
    pub momentum: f32,
    v: Vec<Vec<f32>>,
}

impl SgdMomentum {
    pub fn new(lr: f32, momentum: f32) -> Self {
        SgdMomentum { lr, momentum, v: Vec::new() }
    }

    pub fn num_slots(&self) -> usize {
        self.v.len()
    }
}

impl Optimizer for SgdMomentum {
    fn register(&mut self, len: usize) -> usize {
        self.v.push(vec![0.0; len]);
        self.v.len() - 1
    }

    fn update(&mut self, slot: usize, params: &mut [f32], grads: &[f32]) {
        debug_assert_eq!(params.len(), grads.len());
        let v = &mut self.v[slot];
        for i in 0..params.len() {
            v[i] = self.momentum * v[i] + grads[i];
            params[i] -= self.lr * v[i];
        }
    }
}

/// Adam with bias correction (Kingma & Ba), one instance per model.
#[derive(Clone, Debug)]
pub struct Adam {
    pub lr: f32,
    pub b1: f32,
    pub b2: f32,
    pub eps: f32,
    t: f32,
    m: Vec<Vec<f32>>,
    v: Vec<Vec<f32>>,
}

impl Adam {
    pub fn new(lr: f32) -> Self {
        Adam { lr, b1: 0.9, b2: 0.999, eps: 1e-8, t: 0.0, m: Vec::new(), v: Vec::new() }
    }

    /// Register a parameter tensor; returns its slot id.
    pub fn register(&mut self, len: usize) -> usize {
        self.m.push(vec![0.0; len]);
        self.v.push(vec![0.0; len]);
        self.m.len() - 1
    }

    pub fn num_slots(&self) -> usize {
        self.m.len()
    }

    /// Advance the shared step count; call once per minibatch, before
    /// updating the slots of that batch.
    pub fn next_step(&mut self) {
        self.t += 1.0;
    }

    pub fn step_count(&self) -> f32 {
        self.t
    }

    /// Update one slot with its gradient.
    pub fn update(&mut self, slot: usize, params: &mut [f32], grads: &[f32]) {
        debug_assert_eq!(params.len(), grads.len());
        let (b1, b2) = (self.b1, self.b2);
        let bc1 = 1.0 - b1.powf(self.t);
        let bc2 = 1.0 - b2.powf(self.t);
        let m = &mut self.m[slot];
        let v = &mut self.v[slot];
        for i in 0..params.len() {
            let g = grads[i];
            m[i] = b1 * m[i] + (1.0 - b1) * g;
            v[i] = b2 * v[i] + (1.0 - b2) * g * g;
            params[i] -= self.lr * (m[i] / bc1) / ((v[i] / bc2).sqrt() + self.eps);
        }
    }
}

impl Optimizer for Adam {
    fn register(&mut self, len: usize) -> usize {
        Adam::register(self, len)
    }

    fn update(&mut self, slot: usize, params: &mut [f32], grads: &[f32]) {
        Adam::update(self, slot, params, grads)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sgd_moves_against_gradient() {
        let mut p = vec![1.0f32, -1.0];
        Sgd { lr: 0.1 }.step(&mut p, &[2.0, -2.0]);
        assert_eq!(p, vec![0.8, -0.8]);
    }

    #[test]
    fn momentum_accumulates_velocity() {
        let mut opt = SgdMomentum::new(0.1, 0.5);
        let slot = opt.register(1);
        let mut p = vec![0.0f32];
        opt.update(slot, &mut p, &[1.0]); // v=1.0, p=-0.1
        assert!((p[0] + 0.1).abs() < 1e-6);
        opt.update(slot, &mut p, &[1.0]); // v=1.5, p=-0.25
        assert!((p[0] + 0.25).abs() < 1e-6);
    }

    #[test]
    fn momentum_converges_on_quadratic() {
        let mut opt = SgdMomentum::new(0.05, 0.9);
        let slot = opt.register(1);
        let mut p = vec![0.0f32];
        for _ in 0..300 {
            let g = vec![2.0 * (p[0] - 3.0)];
            opt.update(slot, &mut p, &g);
        }
        assert!((p[0] - 3.0).abs() < 0.05, "{}", p[0]);
    }

    #[test]
    fn trait_object_dispatch_works() {
        // the LinearOp-facing surface: any optimizer through the trait
        fn run(opt: &mut dyn Optimizer) -> f32 {
            let slot = opt.register(2);
            let mut p = vec![1.0f32, 1.0];
            opt.update(slot, &mut p, &[1.0, -1.0]);
            p[0]
        }
        assert!(run(&mut Sgd { lr: 0.1 }) < 1.0);
        assert!(run(&mut SgdMomentum::new(0.1, 0.9)) < 1.0);
        let mut adam = Adam::new(0.1);
        adam.next_step();
        assert!(run(&mut adam) < 1.0);
    }

    #[test]
    fn adam_first_step_matches_python_reference() {
        // mirrors python/tests/test_train.py::test_adam_matches_manual_numpy
        let mut adam = Adam::new(0.01);
        let slot = adam.register(2);
        let mut p = vec![1.0f32, 2.0];
        let g = vec![0.5f32, -1.0];
        adam.next_step();
        adam.update(slot, &mut p, &g);
        for (i, (&pi, &gi)) in p.iter().zip(&g).enumerate() {
            let m_hat = 0.1 * gi / (1.0 - 0.9f32);
            let v_hat = 0.001 * gi * gi / (1.0 - 0.999f32);
            let want = [1.0, 2.0][i] - 0.01 * m_hat / (v_hat.sqrt() + 1e-8);
            assert!((pi - want).abs() < 1e-5, "{pi} vs {want}");
        }
    }

    #[test]
    fn adam_converges_on_quadratic() {
        // minimize (p-3)^2
        let mut adam = Adam::new(0.1);
        let slot = adam.register(1);
        let mut p = vec![0.0f32];
        for _ in 0..500 {
            let g = vec![2.0 * (p[0] - 3.0)];
            adam.next_step();
            adam.update(slot, &mut p, &g);
        }
        assert!((p[0] - 3.0).abs() < 0.05, "{}", p[0]);
    }

    #[test]
    fn adam_constant_grad_step_size() {
        // with constant unit gradient, each early step moves ~lr
        let mut adam = Adam::new(0.1);
        let slot = adam.register(1);
        let mut p = vec![0.0f32];
        adam.next_step();
        adam.update(slot, &mut p, &[1.0]);
        assert!((p[0] + 0.1).abs() < 1e-5);
    }
}
