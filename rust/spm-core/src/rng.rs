//! Deterministic, dependency-free RNG (SplitMix64 + Box–Muller).
//!
//! Every stochastic component in the native substrate (init, data
//! generation, property tests) draws from this generator so runs are
//! reproducible from a single seed.

/// SplitMix64: tiny, fast, passes BigCrush for our purposes.
#[derive(Clone, Debug)]
pub struct Rng {
    state: u64,
    /// cached second normal from Box–Muller
    spare: Option<f32>,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng { state: seed.wrapping_add(0x9E3779B97F4A7C15), spare: None }
    }

    /// Derive an independent stream (for per-worker / per-stage seeding).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0xD1342543DE82EF95))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f32 {
        ((self.next_u64() >> 40) as f32) * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform_in(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box–Muller (cached pair).
    pub fn normal(&mut self) -> f32 {
        if let Some(s) = self.spare.take() {
            return s;
        }
        loop {
            let u1 = self.uniform();
            if u1 <= f32::MIN_POSITIVE {
                continue;
            }
            let u2 = self.uniform();
            let r = (-2.0 * u1.ln()).sqrt();
            let (s, c) = (2.0 * std::f32::consts::PI * u2).sin_cos();
            self.spare = Some(r * s);
            return r * c;
        }
    }

    pub fn normal_vec(&mut self, n: usize, std: f32) -> Vec<f32> {
        (0..n).map(|_| self.normal() * std).collect()
    }

    pub fn uniform_vec(&mut self, n: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..n).map(|_| self.uniform_in(lo, hi)).collect()
    }

    /// Fisher–Yates permutation of 0..n.
    pub fn permutation(&mut self, n: usize) -> Vec<u32> {
        let mut p: Vec<u32> = (0..n as u32).collect();
        for i in (1..n).rev() {
            let j = self.below(i + 1);
            p.swap(i, j);
        }
        p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn uniform_bounds() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(5);
        let xs: Vec<f32> = (0..50_000).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f32>() / xs.len() as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>()
            / xs.len() as f32;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn permutation_is_permutation() {
        let mut r = Rng::new(11);
        let p = r.permutation(257);
        let mut seen = vec![false; 257];
        for &v in &p {
            assert!(!seen[v as usize]);
            seen[v as usize] = true;
        }
    }

    #[test]
    fn fork_streams_independent() {
        let mut r = Rng::new(9);
        let mut a = r.fork(1);
        let mut b = r.fork(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
