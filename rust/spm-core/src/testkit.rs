//! Minimal property-testing harness (proptest is not in the offline vendor
//! set). `forall` runs a predicate over `cases` seeded random inputs and
//! reports the first failing seed so a failure is reproducible:
//!
//! ```text
//! forall(100, 7, |rng| { ... ; Ok(()) })
//! ```

use crate::ops::{block_for_budget, rank_for_budget, LinearCfg, LinearKind, LinearOp, SpmExec};
use crate::pairing::Schedule;
use crate::rng::Rng;
use crate::spm::Variant;

/// The variant axis every parity harness sweeps.
pub const ALL_VARIANTS: [Variant; 2] = [Variant::Rotation, Variant::General];

/// The pairing-schedule axis every parity harness sweeps.
pub const ALL_SCHEDULES: [Schedule; 3] = [Schedule::Butterfly, Schedule::Shift, Schedule::Random];

/// The stage-loop execution axis (DESIGN.md §12). `Simd` auto-downgrades
/// to the scalar fused path on builds/machines without the vectorized
/// backend, so sweeping this axis is always safe — it just tests the
/// fused path twice where AVX2 is unavailable.
pub const ALL_EXECS: [SpmExec; 3] = [SpmExec::RowWise, SpmExec::BatchFused, SpmExec::Simd];

/// Run `prop` for `cases` independent RNG streams derived from `seed`.
/// Panics with the failing case index + message on the first failure.
pub fn forall(cases: usize, seed: u64, prop: impl Fn(&mut Rng) -> Result<(), String>) {
    for case in 0..cases {
        let mut rng = Rng::new(seed.wrapping_mul(0x9E3779B97F4A7C15).wrapping_add(case as u64));
        if let Err(msg) = prop(&mut rng) {
            panic!("property failed at case {case} (seed {seed}): {msg}");
        }
    }
}

/// Assert two slices are elementwise close.
pub fn assert_close(got: &[f32], want: &[f32], tol: f32, what: &str) {
    assert_eq!(got.len(), want.len(), "{what}: length mismatch");
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        let scale = 1.0f32.max(w.abs()).max(g.abs());
        assert!(
            (g - w).abs() <= tol * scale,
            "{what}[{i}]: got {g}, want {w} (tol {tol})"
        );
    }
}

/// Relative-error helper for property bodies (returns Err instead of
/// panicking so `forall` can attach the case index).
pub fn check_close(got: &[f32], want: &[f32], tol: f32, what: &str) -> Result<(), String> {
    if got.len() != want.len() {
        return Err(format!("{what}: length {} vs {}", got.len(), want.len()));
    }
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        let scale = 1.0f32.max(w.abs()).max(g.abs());
        if (g - w).abs() > tol * scale || !g.is_finite() {
            return Err(format!("{what}[{i}]: got {g}, want {w} (tol {tol})"));
        }
    }
    Ok(())
}

/// Equal-parameter-budget config for comparing `kind` against an
/// existing (square) SPM op: picks the rank / block size whose parameter
/// count lands closest to `spm.param_count()` at the same width and
/// seed, so zoo comparisons measure STRUCTURE, not capacity. Dense,
/// Spm and Butterfly need no knob (dense is the upper baseline;
/// butterfly matches general SPM structurally), so their configs pass
/// through width + seed unchanged.
pub fn match_param_budget(spm: &LinearOp, kind: LinearKind) -> LinearCfg {
    let n = spm.n();
    let budget = spm.param_count();
    let seed = spm.plan().map_or(0, |p| p.spec.seed);
    let cfg = LinearCfg { kind, ..LinearCfg::dense(n) }.with_seed(seed);
    match kind {
        LinearKind::LowRank => cfg.with_rank(rank_for_budget(n, n, budget)),
        LinearKind::BlockShuffle => cfg.with_block(block_for_budget(n, budget)),
        _ => cfg,
    }
}

/// Central-difference numerical gradient of a scalar function w.r.t. one
/// coordinate of `params` — used by the finite-difference gradient checks.
pub fn numerical_grad(
    params: &mut [f32],
    idx: usize,
    eps: f32,
    mut f: impl FnMut(&[f32]) -> f32,
) -> f32 {
    let orig = params[idx];
    params[idx] = orig + eps;
    let up = f(params);
    params[idx] = orig - eps;
    let down = f(params);
    params[idx] = orig;
    (up - down) / (2.0 * eps)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_passes() {
        forall(10, 1, |rng| {
            let x = rng.uniform();
            if (0.0..1.0).contains(&x) {
                Ok(())
            } else {
                Err(format!("{x} out of range"))
            }
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn forall_reports_failure() {
        forall(10, 1, |rng| {
            if rng.uniform() < 2.0 {
                Err("always".into())
            } else {
                Ok(())
            }
        });
    }

    #[test]
    fn numerical_grad_of_square() {
        let mut p = vec![3.0f32];
        let g = numerical_grad(&mut p, 0, 1e-3, |v| v[0] * v[0]);
        assert!((g - 6.0).abs() < 1e-2);
        assert_eq!(p[0], 3.0); // restored
    }

    #[test]
    fn match_param_budget_tracks_the_spm_count() {
        let mut opt = crate::optim::Adam::new(1e-3);
        let mut rng = Rng::new(4);
        let spm = LinearOp::new(
            LinearCfg::spm(64, Variant::General).with_seed(5),
            &mut rng,
            &mut opt,
        );
        let budget = spm.param_count();
        for kind in [LinearKind::LowRank, LinearKind::BlockShuffle, LinearKind::Butterfly] {
            let cfg = match_param_budget(&spm, kind);
            assert_eq!(cfg.kind, kind);
            assert_eq!(cfg.seed, 5);
            let op = LinearOp::new(cfg, &mut Rng::new(4), &mut opt);
            // no OTHER admissible knob setting lands closer to the budget
            let gap = op.param_count().abs_diff(budget);
            match kind {
                LinearKind::LowRank => {
                    for r in 1..=64usize {
                        let alt = r * 64 + r * 64 + 64;
                        assert!(alt.abs_diff(budget) >= gap, "rank {r} beats the pick");
                    }
                }
                LinearKind::BlockShuffle => {
                    for bs in (1..=64usize).filter(|b| 64 % b == 0) {
                        let alt = 64 * bs + 64;
                        assert!(alt.abs_diff(budget) >= gap, "block {bs} beats the pick");
                    }
                }
                // butterfly shares general SPM's layout exactly
                _ => assert_eq!(op.param_count(), budget),
            }
        }
    }
}
