//! The unified model API (DESIGN.md §13): ONE trait over every network
//! in the zoo, so coordinators, serving engines, and checkpoints stop
//! caring which architecture they are holding.
//!
//! Before this layer the four models exposed four bespoke surfaces
//! (`logits(&Mat)` vs `logits(&[Mat])` vs `evaluate(&[u8], &[u8])` vs
//! `forward(&Mat, b, t)`), so every new workload needed hand-written
//! glue. [`Model`] normalizes them to a batched row interface: a request
//! row is a flat `d_in`-wide feature vector —
//!
//! * mlp: one `n`-wide input row;
//! * gru ([`super::gru::GruSeq`]): the whole sequence, timesteps
//!   concatenated `[x_1 | .. | x_T]` (`d_in = T * n`);
//! * charlm: one token, as an f32 byte value (`d_in = 1`, `d_out = 256`
//!   next-byte logits);
//! * attention ([`super::attention::AttnSeq`]): the flattened `(T, d)`
//!   sequence (`d_in = d_out = T * d`).
//!
//! The trait requires `Send` so serving replicas can move onto worker
//! threads; every native model is plain data and satisfies it for free.
//!
//! [`build_model`] is the one factory: a [`ModelCfg`] (lowered from the
//! coordinator's `[model]` config section) to a boxed [`Model`], with
//! the SPM exec path fanned out to every owned `LinearOp`.
//!
//! Checkpoints ([`save_checkpoint`] / [`load_checkpoint`]) are a
//! dependency-free binary dump of the flat parameter buffers exposed by
//! `visit_params`, with enough header to reject wrong-architecture and
//! corrupt files (format in DESIGN.md §13).

use std::io::{self, Read, Write};
use std::path::Path;

use crate::ops::{LinearCfg, LinearKind, LinearOp, SpmExec};
use crate::tensor::Mat;

use super::attention::AttnSeq;
use super::charlm::CharLM;
use super::gru::GruSeq;
use super::mlp::Classifier;

/// Which architecture a [`Model`] is.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ModelKind {
    Mlp,
    Gru,
    CharLm,
    Attention,
}

impl ModelKind {
    pub const ALL: [ModelKind; 4] =
        [ModelKind::Mlp, ModelKind::Gru, ModelKind::CharLm, ModelKind::Attention];

    pub fn parse(s: &str) -> Option<ModelKind> {
        match s {
            "mlp" => Some(ModelKind::Mlp),
            "gru" => Some(ModelKind::Gru),
            "charlm" => Some(ModelKind::CharLm),
            "attention" => Some(ModelKind::Attention),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            ModelKind::Mlp => "mlp",
            ModelKind::Gru => "gru",
            ModelKind::CharLm => "charlm",
            ModelKind::Attention => "attention",
        }
    }
}

/// Training/eval target for one batch of rows. Classifiers take class
/// labels; regression-style models (attention's identity/MSE objective)
/// take a value matrix shaped like their output.
pub enum Target<'a> {
    Labels(&'a [u32]),
    Values(&'a Mat),
}

impl Target<'_> {
    /// Rows this target covers (for batch-shape checks).
    pub fn rows(&self) -> usize {
        match self {
            Target::Labels(y) => y.len(),
            Target::Values(m) => m.rows,
        }
    }
}

/// Every network the repo trains or serves, behind one batched contract.
///
/// `train_step`/`evaluate` return `(loss, metric)` where the metric is
/// task accuracy for the classifiers (mlp, gru, charlm) and `0.0` where
/// no accuracy is defined (attention trains on MSE). Implementations
/// panic on a [`Target`] variant their objective cannot consume — the
/// mismatch is a caller bug, not a runtime condition.
///
/// Training decomposes into three phases so a data-parallel engine can
/// interpose between backward and the optimizer (DESIGN.md §14):
/// [`Model::accumulate_step`] (forward + backward, gradients SUM into
/// the model's flat gradient buffers), a gradient all-reduce over
/// [`Model::visit_grads`] / [`Model::visit_grads_mut`], then
/// [`Model::apply_step`] (one optimizer step consuming the accumulated
/// gradients). `train_step` is exactly `zero_grads` + `accumulate_step`
/// + `apply_step` — single-replica training and the R-replica engine
/// walk the same arithmetic.
pub trait Model: Send {
    fn kind(&self) -> ModelKind;
    /// Feature width of one request row.
    fn d_in(&self) -> usize;
    /// Output width of one request row.
    fn d_out(&self) -> usize;
    fn param_count(&self) -> usize;
    /// Batched inference: `(B, d_in)` -> `(B, d_out)`. Ragged B is fine —
    /// every path down to the fused stage kernels takes the true row
    /// count (no padding anywhere in the native stack).
    fn forward(&self, x: &Mat) -> Mat;
    /// [`Model::forward`] into a caller-owned output buffer. `&mut self`
    /// so models can route through their reusable activation scratch
    /// (DESIGN.md §15) and make steady-state serving allocation-free;
    /// the default delegates to the allocating `forward`.
    fn forward_into(&mut self, x: &Mat, out: &mut Mat) {
        *out = self.forward(x);
    }
    /// One optimizer step on the batch; returns `(loss, metric)`.
    fn train_step(&mut self, x: &Mat, target: &Target) -> (f32, f32) {
        self.zero_grads();
        let lm = self.accumulate_step(x, target);
        self.apply_step();
        lm
    }
    /// Forward + backward only: parameter gradients ACCUMULATE into the
    /// model's flat gradient buffers (repeated calls sum, exactly like
    /// `LinearOp::backward`); no optimizer state is touched. Returns
    /// this batch's `(loss, metric)`.
    fn accumulate_step(&mut self, x: &Mat, target: &Target) -> (f32, f32);
    /// One optimizer step consuming the accumulated gradients (advances
    /// the model's shared Adam step count), then clears them.
    fn apply_step(&mut self);
    /// Clear every gradient buffer [`Model::visit_grads`] enumerates.
    fn zero_grads(&mut self);
    /// `(loss, metric)` without updates.
    fn evaluate(&self, x: &Mat, target: &Target) -> (f32, f32);
    /// Select the SPM stage-loop exec path on EVERY owned `LinearOp`
    /// (dense ops ignore it; `Simd` downgrades where unavailable).
    fn set_exec(&mut self, exec: SpmExec);
    /// Visit every flat parameter buffer with a stable name, in a stable
    /// order — the checkpoint format and any future param-sync transport
    /// are built on exactly this enumeration.
    fn visit_params(&self, f: &mut dyn FnMut(&str, &[f32]));
    /// Mutable counterpart of [`Model::visit_params`] (same names, same
    /// order).
    fn visit_params_mut(&mut self, f: &mut dyn FnMut(&str, &mut [f32]));
    /// Visit every flat GRADIENT buffer — same names, same order, same
    /// lengths as [`Model::visit_params`]. This is the transport the
    /// data-parallel all-reduce runs over: a replica's accumulated
    /// gradients stream out here and the reduced sum streams back in
    /// through [`Model::visit_grads_mut`] before [`Model::apply_step`].
    fn visit_grads(&self, f: &mut dyn FnMut(&str, &[f32]));
    /// Mutable counterpart of [`Model::visit_grads`] (same names, same
    /// order).
    fn visit_grads_mut(&mut self, f: &mut dyn FnMut(&str, &mut [f32]));
    /// Visit every owned `LinearOp`, in a stable order — the checkpoint
    /// architecture fingerprint ([`arch_fingerprint`]) and any future
    /// op-level tooling are built on this enumeration.
    fn visit_ops(&self, f: &mut dyn FnMut(&LinearOp));
    /// Estimated forward FLOPs per request row — the equal-FLOP axis the
    /// ablation harness reports next to `param_count` (DESIGN.md §17).
    /// The default sums [`LinearOp::flops_per_row`] over
    /// [`Model::visit_ops`] (each op applied once per row); sequence
    /// models override it to scale their per-timestep ops by `seq_len`.
    /// Non-linear glue (activations, softmax, attention scores, embedding
    /// lookups) is not counted: this is the structured-vs-dense operator
    /// comparison, not a cycle model.
    fn flops_per_row(&self) -> u64 {
        let mut total = 0u64;
        self.visit_ops(&mut |op| total += op.flops_per_row());
        total
    }
}

/// Construction-time description of a model: the architecture, the
/// square mixer/projection op it is built around, and the head/sequence
/// shape knobs. Lowered from the coordinator's `[model]` config section.
#[derive(Clone, Copy, Debug)]
pub struct ModelCfg {
    pub kind: ModelKind,
    /// The square `LinearOp` config every SPM-replaceable map uses
    /// (width = the model's mixing dimension).
    pub op: LinearCfg,
    /// Head width for the classifiers (mlp, gru). charlm's head is
    /// always the byte vocabulary; attention has no head.
    pub classes: usize,
    /// Attention heads (must divide the width).
    pub heads: usize,
    /// Timesteps per request row (gru, attention).
    pub seq_len: usize,
    pub lr: f32,
    /// Model init seed (distinct from the op's pairing seed).
    pub seed: u64,
    /// SPM stage-loop exec path, fanned out via [`Model::set_exec`].
    pub exec: SpmExec,
}

impl ModelCfg {
    pub fn new(kind: ModelKind, op: LinearCfg) -> Self {
        ModelCfg {
            kind,
            op,
            classes: 10,
            heads: 4,
            seq_len: 8,
            lr: 1e-3,
            seed: 0,
            exec: SpmExec::default(),
        }
    }

    pub fn with_classes(mut self, c: usize) -> Self {
        self.classes = c;
        self
    }

    pub fn with_heads(mut self, h: usize) -> Self {
        self.heads = h;
        self
    }

    pub fn with_seq_len(mut self, t: usize) -> Self {
        self.seq_len = t;
        self
    }

    pub fn with_lr(mut self, lr: f32) -> Self {
        self.lr = lr;
        self
    }

    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    pub fn with_exec(mut self, exec: SpmExec) -> Self {
        self.exec = exec;
        self
    }
}

/// The one model factory: build any [`ModelKind`] from its config and
/// apply the configured exec path to every owned op.
pub fn build_model(cfg: &ModelCfg) -> Box<dyn Model> {
    let mut model: Box<dyn Model> = match cfg.kind {
        ModelKind::Mlp => Box::new(Classifier::new(cfg.op, cfg.classes, cfg.lr, cfg.seed)),
        ModelKind::Gru => Box::new(GruSeq::new(cfg.op, cfg.classes, cfg.seq_len, cfg.lr, cfg.seed)),
        ModelKind::CharLm => Box::new(CharLM::new(cfg.op, cfg.lr, cfg.seed)),
        ModelKind::Attention => {
            Box::new(AttnSeq::new(cfg.op, cfg.heads, cfg.seq_len, cfg.lr, cfg.seed))
        }
    };
    model.set_exec(cfg.exec);
    model
}

// ---------------------------------------------------------------------------
// Checkpoints: dependency-free binary dump of the flat param buffers.
//
// Layout (all integers little-endian, DESIGN.md §13):
//
//   magic   8  bytes  "SPMCKPT1"
//   kind    u32 len + utf-8 bytes of ModelKind::name()
//   d_in    u64
//   d_out   u64
//   arch    u64 fingerprint over the op topology (widths, kinds, and the
//           exact SPM pairing tables — see `arch_fingerprint`)
//   nbufs   u64
//   per buffer, in visit_params order:
//     name  u32 len + utf-8 bytes
//     count u64 (f32 elements)
//     data  count * 4 bytes (f32 LE)
//
// Loading checks magic, kind, d_in/d_out, and the arch fingerprint, then
// matches every buffer by position AND name AND length against the live
// model BEFORE its data is read — so a wrong architecture, wrong width,
// wrong pairing, or truncated/corrupt file is rejected without touching
// a parameter, and a corrupt length field can never provoke a giant
// allocation (buffer sizes are bounded by the model's own).
// ---------------------------------------------------------------------------

/// First 8 bytes of every native checkpoint.
pub const CKPT_MAGIC: [u8; 8] = *b"SPMCKPT1";

fn bad(msg: impl Into<String>) -> io::Error {
    io::Error::new(io::ErrorKind::InvalidData, msg.into())
}

fn collect_params(model: &dyn Model) -> Vec<(String, Vec<f32>)> {
    let mut out = Vec::new();
    model.visit_params(&mut |name, p| out.push((name.to_string(), p.to_vec())));
    out
}

fn fnv_mix(h: &mut u64, v: u64) {
    *h ^= v;
    *h = h.wrapping_mul(0x100_0000_01b3);
}

/// FNV-1a over the model's op topology: widths, op kinds, and each
/// kind's structural layout (DESIGN.md §19) — pairing tables and
/// leftover slots for SPM/butterfly ops, the rank for low-rank, the
/// block size AND shuffle permutation for block-shuffle. Buffer shapes
/// alone cannot tell two `schedule = "random"` pairings (or two
/// shuffles at different seeds) apart — the tables depend on the op
/// seed while every parameter length matches — so the checkpoint
/// stores this fingerprint and loading rejects a file whose parameters
/// would bind to different coordinates. Kind tags: dense=1, SPM=2
/// (byte-identical to the pre-zoo format, so old checkpoints still
/// load), lowrank=3, blockshuffle=4, butterfly=5 — a butterfly op
/// hashes differently from the structurally identical general-SPM op
/// on the butterfly schedule because the tag differs.
pub fn arch_fingerprint(model: &dyn Model) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    fnv_mix(&mut h, model.d_in() as u64);
    fnv_mix(&mut h, model.d_out() as u64);
    model.visit_ops(&mut |op| {
        fnv_mix(&mut h, op.d_in() as u64);
        fnv_mix(&mut h, op.d_out() as u64);
        let mix_plan = |h: &mut u64| {
            let plan = op.plan().expect("staged op has a plan");
            fnv_mix(h, plan.num_stages as u64);
            for l in 0..plan.num_stages {
                for &ij in plan.stage_pairs(l) {
                    fnv_mix(h, ij as u64);
                }
                fnv_mix(h, plan.stage_leftover(l).map_or(u64::MAX, |v| v as u64));
            }
        };
        match op.kind() {
            LinearKind::Dense => fnv_mix(&mut h, 1), // widths say it all
            LinearKind::Spm => {
                fnv_mix(&mut h, 2);
                mix_plan(&mut h);
            }
            LinearKind::LowRank => {
                fnv_mix(&mut h, 3);
                fnv_mix(&mut h, op.rank().expect("low-rank op has a rank") as u64);
            }
            LinearKind::BlockShuffle => {
                fnv_mix(&mut h, 4);
                fnv_mix(&mut h, op.block_size().expect("block-shuffle op has a block") as u64);
                for &p in op.shuffle().expect("block-shuffle op has a shuffle") {
                    fnv_mix(&mut h, p as u64);
                }
            }
            LinearKind::Butterfly => {
                fnv_mix(&mut h, 5);
                mix_plan(&mut h);
            }
        }
    });
    h
}

/// Serialize `model`'s parameters to `w`.
pub fn write_checkpoint(model: &dyn Model, w: &mut dyn Write) -> io::Result<()> {
    w.write_all(&CKPT_MAGIC)?;
    let kind = model.kind().name().as_bytes();
    w.write_all(&(kind.len() as u32).to_le_bytes())?;
    w.write_all(kind)?;
    w.write_all(&(model.d_in() as u64).to_le_bytes())?;
    w.write_all(&(model.d_out() as u64).to_le_bytes())?;
    w.write_all(&arch_fingerprint(model).to_le_bytes())?;
    let bufs = collect_params(model);
    w.write_all(&(bufs.len() as u64).to_le_bytes())?;
    for (name, data) in &bufs {
        w.write_all(&(name.len() as u32).to_le_bytes())?;
        w.write_all(name.as_bytes())?;
        w.write_all(&(data.len() as u64).to_le_bytes())?;
        for v in data {
            w.write_all(&v.to_le_bytes())?;
        }
    }
    Ok(())
}

fn read_u32(r: &mut dyn Read) -> io::Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_u64(r: &mut dyn Read) -> io::Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

fn read_name(r: &mut dyn Read, what: &str) -> io::Result<String> {
    let len = read_u32(r)? as usize;
    if len > 256 {
        return Err(bad(format!("checkpoint {what} name length {len} is implausible")));
    }
    let mut buf = vec![0u8; len];
    r.read_exact(&mut buf)?;
    String::from_utf8(buf).map_err(|_| bad(format!("checkpoint {what} name is not utf-8")))
}

/// Load a checkpoint from `r` into `model`. The model must already be
/// built with the SAME architecture (same `ModelKind`, widths, op
/// config AND pairing — see [`arch_fingerprint`]) — a checkpoint
/// restores parameters, it does not construct. Every buffer is
/// validated against the live model's name/length BEFORE its data is
/// read, so allocations are bounded by the model's own buffers and
/// nothing is written unless the whole file lines up.
pub fn read_checkpoint(model: &mut dyn Model, r: &mut dyn Read) -> io::Result<()> {
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)?;
    if magic != CKPT_MAGIC {
        return Err(bad("not an SPM checkpoint (bad magic)"));
    }
    let kind = read_name(r, "model kind")?;
    if kind != model.kind().name() {
        return Err(bad(format!(
            "checkpoint holds a '{kind}' model but the target is '{}'",
            model.kind().name()
        )));
    }
    let (d_in, d_out) = (read_u64(r)? as usize, read_u64(r)? as usize);
    if (d_in, d_out) != (model.d_in(), model.d_out()) {
        return Err(bad(format!(
            "checkpoint shape ({d_in} -> {d_out}) does not match the target model ({} -> {})",
            model.d_in(),
            model.d_out()
        )));
    }
    let arch = read_u64(r)?;
    if arch != arch_fingerprint(model) {
        return Err(bad(
            "checkpoint op layout does not match the target model (same shapes, different op \
             config or pairing — e.g. a random schedule under a different seed)",
        ));
    }
    let expected: Vec<(String, usize)> =
        collect_params(model).into_iter().map(|(n, d)| (n, d.len())).collect();
    let nbufs = read_u64(r)? as usize;
    if nbufs != expected.len() {
        return Err(bad(format!(
            "checkpoint has {nbufs} buffers, model has {}",
            expected.len()
        )));
    }
    let mut bufs = Vec::with_capacity(expected.len());
    for (want_name, want_len) in &expected {
        let name = read_name(r, "buffer")?;
        if &name != want_name {
            return Err(bad(format!(
                "checkpoint buffer {} is '{name}', expected '{want_name}'",
                bufs.len()
            )));
        }
        let count = read_u64(r)?;
        if count != *want_len as u64 {
            return Err(bad(format!(
                "checkpoint buffer '{name}' has {count} params, model has {want_len}"
            )));
        }
        let mut bytes = vec![0u8; want_len * 4];
        r.read_exact(&mut bytes)?;
        let data: Vec<f32> =
            bytes.chunks_exact(4).map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect();
        bufs.push(data);
    }

    let mut cursor = 0usize;
    model.visit_params_mut(&mut |_name, p| {
        p.copy_from_slice(&bufs[cursor]);
        cursor += 1;
    });
    Ok(())
}

/// [`write_checkpoint`] to a file path.
pub fn save_checkpoint(model: &dyn Model, path: impl AsRef<Path>) -> io::Result<()> {
    let mut w = io::BufWriter::new(std::fs::File::create(path)?);
    write_checkpoint(model, &mut w)?;
    w.flush()
}

/// [`read_checkpoint`] from a file path.
pub fn load_checkpoint(model: &mut dyn Model, path: impl AsRef<Path>) -> io::Result<()> {
    let mut r = io::BufReader::new(std::fs::File::open(path)?);
    read_checkpoint(model, &mut r)
}

/// A checkpoint parsed off disk but not yet bound to a model — the
/// hot-swap currency: the serving session parses and validates ONCE,
/// then every replica applies the same [`CkptData`] between batches.
///
/// Unlike [`read_checkpoint`] (which validates against a live model
/// while streaming), parsing here happens without a model in hand, so
/// allocation is bounded by the byte slice itself: a corrupt count
/// field can never claim more data than the slice holds.
#[derive(Debug, Clone)]
pub struct CkptData {
    pub kind: String,
    pub d_in: usize,
    pub d_out: usize,
    pub arch: u64,
    pub bufs: Vec<(String, Vec<f32>)>,
}

impl CkptData {
    /// Parse a complete `SPMCKPT1` image. Rejects bad magic, implausible
    /// buffer counts/lengths (anything the remaining bytes cannot hold),
    /// and trailing garbage after the last buffer.
    pub fn from_bytes(bytes: &[u8]) -> io::Result<CkptData> {
        let mut r: &[u8] = bytes;
        let mut magic = [0u8; 8];
        r.read_exact(&mut magic)?;
        if magic != CKPT_MAGIC {
            return Err(bad("not an SPM checkpoint (bad magic)"));
        }
        let kind = read_name(&mut r, "model kind")?;
        let d_in = read_u64(&mut r)? as usize;
        let d_out = read_u64(&mut r)? as usize;
        let arch = read_u64(&mut r)?;
        let nbufs = read_u64(&mut r)? as usize;
        // every buffer costs at least 12 header bytes (name len + count),
        // so a corrupt count cannot provoke a giant reservation
        if nbufs > r.len() / 12 {
            return Err(bad(format!(
                "checkpoint claims {nbufs} buffers but only {} bytes remain",
                r.len()
            )));
        }
        let mut bufs = Vec::with_capacity(nbufs);
        for _ in 0..nbufs {
            let name = read_name(&mut r, "buffer")?;
            let count = read_u64(&mut r)? as usize;
            if count.checked_mul(4).map_or(true, |b| b > r.len()) {
                return Err(bad(format!(
                    "checkpoint buffer '{name}' claims {count} params but only {} bytes remain",
                    r.len()
                )));
            }
            let (raw, rest) = r.split_at(count * 4);
            r = rest;
            let data: Vec<f32> = raw
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            bufs.push((name, data));
        }
        if !r.is_empty() {
            return Err(bad(format!("{} trailing bytes after the last checkpoint buffer", r.len())));
        }
        Ok(CkptData { kind, d_in, d_out, arch, bufs })
    }

    /// [`CkptData::from_bytes`] over a whole file.
    pub fn load(path: impl AsRef<Path>) -> io::Result<CkptData> {
        Self::from_bytes(&std::fs::read(path)?)
    }

    /// Validate against a live model without touching a parameter: kind,
    /// widths, arch fingerprint, and every buffer's name + length (by
    /// position, exactly as [`read_checkpoint`] does).
    pub fn check_model(&self, model: &dyn Model) -> io::Result<()> {
        if self.kind != model.kind().name() {
            return Err(bad(format!(
                "checkpoint holds a '{}' model but the target is '{}'",
                self.kind,
                model.kind().name()
            )));
        }
        if (self.d_in, self.d_out) != (model.d_in(), model.d_out()) {
            return Err(bad(format!(
                "checkpoint shape ({} -> {}) does not match the target model ({} -> {})",
                self.d_in,
                self.d_out,
                model.d_in(),
                model.d_out()
            )));
        }
        if self.arch != arch_fingerprint(model) {
            return Err(bad(
                "checkpoint arch fingerprint does not match the target model (same shapes, \
                 different op config or pairing — e.g. a random schedule under a different seed)",
            ));
        }
        let expected: Vec<(String, usize)> =
            collect_params(model).into_iter().map(|(n, d)| (n, d.len())).collect();
        if self.bufs.len() != expected.len() {
            return Err(bad(format!(
                "checkpoint has {} buffers, model has {}",
                self.bufs.len(),
                expected.len()
            )));
        }
        for (i, ((name, data), (want_name, want_len))) in
            self.bufs.iter().zip(&expected).enumerate()
        {
            if name != want_name {
                return Err(bad(format!(
                    "checkpoint buffer {i} is '{name}', expected '{want_name}'"
                )));
            }
            if data.len() != *want_len {
                return Err(bad(format!(
                    "checkpoint buffer '{name}' has {} params, model has {want_len}",
                    data.len()
                )));
            }
        }
        Ok(())
    }

    /// [`CkptData::check_model`], then copy every buffer into `model` —
    /// all-or-nothing: nothing is written unless the whole image lines
    /// up. Goes through `visit_params_mut`, so prepared-coefficient
    /// caches are invalidated exactly as for a streamed load.
    pub fn apply_to(&self, model: &mut dyn Model) -> io::Result<()> {
        self.check_model(&*model)?;
        let mut cursor = 0usize;
        model.visit_params_mut(&mut |_name, p| {
            p.copy_from_slice(&self.bufs[cursor].1);
            cursor += 1;
        });
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pairing::Schedule;
    use crate::rng::Rng;
    use crate::spm::Variant;

    fn small_cfg(kind: ModelKind) -> ModelCfg {
        // n = 8 everywhere; heads = 2 divides 8; short sequences keep the
        // round-trip sweep fast
        ModelCfg::new(kind, LinearCfg::spm(8, Variant::General))
            .with_classes(4)
            .with_heads(2)
            .with_seq_len(3)
            .with_seed(11)
    }

    fn input_for(model: &dyn Model, rows: usize, rng: &mut Rng) -> Mat {
        let d = model.d_in();
        match model.kind() {
            // tokens must be byte values, not N(0,1) floats
            ModelKind::CharLm => {
                Mat::from_vec(rows, d, (0..rows * d).map(|i| (i % 251) as f32).collect())
            }
            _ => Mat::from_vec(rows, d, rng.normal_vec(rows * d, 1.0)),
        }
    }

    fn target_for<'a>(model: &dyn Model, labels: &'a [u32], values: &'a Mat) -> Target<'a> {
        match model.kind() {
            ModelKind::Attention => Target::Values(values),
            _ => Target::Labels(labels),
        }
    }

    #[test]
    fn factory_builds_every_kind_with_consistent_shapes() {
        for kind in ModelKind::ALL {
            let model = build_model(&small_cfg(kind));
            assert_eq!(model.kind(), kind);
            assert!(model.param_count() > 0, "{kind:?}");
            let (want_in, want_out) = match kind {
                ModelKind::Mlp => (8, 4),
                ModelKind::Gru => (3 * 8, 4),
                ModelKind::CharLm => (1, 256),
                ModelKind::Attention => (3 * 8, 3 * 8),
            };
            assert_eq!((model.d_in(), model.d_out()), (want_in, want_out), "{kind:?}");
            let mut rng = Rng::new(kind as u64 + 1);
            let x = input_for(model.as_ref(), 5, &mut rng);
            let y = model.forward(&x);
            assert_eq!((y.rows, y.cols), (5, model.d_out()), "{kind:?}");
            assert!(y.data.iter().all(|v| v.is_finite()), "{kind:?}");
        }
    }

    #[test]
    fn every_kind_trains_and_evaluates_through_the_trait() {
        for kind in ModelKind::ALL {
            let mut model = build_model(&small_cfg(kind));
            let mut rng = Rng::new(31 + kind as u64);
            let x = input_for(model.as_ref(), 16, &mut rng);
            let labels: Vec<u32> = (0..16).map(|i| (i % 4) as u32).collect();
            let labels = if model.kind() == ModelKind::CharLm {
                labels.iter().map(|&l| l + 97).collect() // next-byte targets
            } else {
                labels
            };
            let values = x.clone();
            let (l0, _m0) = model.evaluate(&x, &target_for(model.as_ref(), &labels, &values));
            assert!(l0.is_finite(), "{kind:?}");
            let mut last = l0;
            for _ in 0..25 {
                last = model.train_step(&x, &target_for(model.as_ref(), &labels, &values)).0;
            }
            assert!(last.is_finite(), "{kind:?}");
            assert!(last < l0, "{kind:?}: loss did not decrease ({l0} -> {last})");
        }
    }

    #[test]
    fn visit_params_mut_covers_the_same_buffers_as_visit_params() {
        for kind in ModelKind::ALL {
            let mut model = build_model(&small_cfg(kind));
            let ro: Vec<(String, usize)> = collect_params(model.as_ref())
                .into_iter()
                .map(|(n, d)| (n, d.len()))
                .collect();
            let mut rw: Vec<(String, usize)> = Vec::new();
            model.visit_params_mut(&mut |n, p| rw.push((n.to_string(), p.len())));
            assert_eq!(ro, rw, "{kind:?}");
            let total: usize = ro.iter().map(|(_n, l)| l).sum();
            assert_eq!(total, model.param_count(), "{kind:?}: visit must cover every param");
        }
    }

    #[test]
    fn visit_grads_mirrors_visit_params_layout() {
        // the all-reduce transport contract: same names, same order,
        // same lengths as the parameter enumeration, on both views
        for kind in ModelKind::ALL {
            let mut model = build_model(&small_cfg(kind));
            let params: Vec<(String, usize)> = collect_params(model.as_ref())
                .into_iter()
                .map(|(n, d)| (n, d.len()))
                .collect();
            let mut ro: Vec<(String, usize)> = Vec::new();
            model.visit_grads(&mut |n, g| ro.push((n.to_string(), g.len())));
            assert_eq!(params, ro, "{kind:?}: visit_grads layout");
            let mut rw: Vec<(String, usize)> = Vec::new();
            model.visit_grads_mut(&mut |n, g| rw.push((n.to_string(), g.len())));
            assert_eq!(params, rw, "{kind:?}: visit_grads_mut layout");
        }
    }

    #[test]
    fn accumulate_then_apply_matches_train_step_exactly() {
        // the decomposition the data-parallel engine is built on:
        // zero + accumulate + apply must reproduce train_step bit for bit
        for kind in ModelKind::ALL {
            let cfg = small_cfg(kind);
            let mut rng = Rng::new(41 + kind as u64);
            let mut one = build_model(&cfg);
            let x = input_for(one.as_ref(), 9, &mut rng);
            let base = if kind == ModelKind::CharLm { 97 } else { 0 };
            let labels: Vec<u32> = (0..9).map(|i| base + (i % 4) as u32).collect();
            let values = x.clone();

            let (l1, m1) = one.train_step(&x, &target_for(one.as_ref(), &labels, &values));
            let mut two = build_model(&cfg);
            two.zero_grads();
            let (l2, m2) = two.accumulate_step(&x, &target_for(two.as_ref(), &labels, &values));
            two.apply_step();
            assert_eq!((l1, m1), (l2, m2), "{kind:?}: loss/metric");
            assert_eq!(
                collect_params(one.as_ref()),
                collect_params(two.as_ref()),
                "{kind:?}: post-step params must be identical"
            );
        }
    }

    #[test]
    fn accumulate_step_sums_and_zero_grads_clears() {
        for kind in ModelKind::ALL {
            let mut model = build_model(&small_cfg(kind));
            let mut rng = Rng::new(53);
            let x = input_for(model.as_ref(), 5, &mut rng);
            let base = if kind == ModelKind::CharLm { 97 } else { 0 };
            let labels: Vec<u32> = (0..5).map(|i| base + (i % 4) as u32).collect();
            let values = x.clone();
            model.zero_grads();
            model.accumulate_step(&x, &target_for(model.as_ref(), &labels, &values));
            let mut once: Vec<f32> = Vec::new();
            model.visit_grads(&mut |_n, g| once.extend_from_slice(g));
            assert!(once.iter().any(|&g| g != 0.0), "{kind:?}: no gradient flowed");
            model.accumulate_step(&x, &target_for(model.as_ref(), &labels, &values));
            let mut twice: Vec<f32> = Vec::new();
            model.visit_grads(&mut |_n, g| twice.extend_from_slice(g));
            for (t, o) in twice.iter().zip(&once) {
                // a + a is exact in f32, so the sum is exactly double
                assert_eq!(*t, 2.0 * o, "{kind:?}: accumulate must sum");
            }
            model.zero_grads();
            model.visit_grads(&mut |n, g| {
                assert!(g.iter().all(|&v| v == 0.0), "{kind:?}/{n}: zero_grads must clear")
            });
        }
    }

    #[test]
    fn visit_grads_mut_writes_feed_apply_step() {
        // external gradients loaded through visit_grads_mut must drive
        // the optimizer exactly like locally accumulated ones
        let cfg = small_cfg(ModelKind::Mlp);
        let mut rng = Rng::new(61);
        let x = input_for(build_model(&cfg).as_ref(), 6, &mut rng);
        let labels: Vec<u32> = (0..6).map(|i| (i % 4) as u32).collect();

        let mut local = build_model(&cfg);
        local.zero_grads();
        local.accumulate_step(&x, &Target::Labels(&labels));
        let mut flat: Vec<f32> = Vec::new();
        local.visit_grads(&mut |_n, g| flat.extend_from_slice(g));
        local.apply_step();

        let mut loaded = build_model(&cfg);
        let mut off = 0usize;
        loaded.visit_grads_mut(&mut |_n, g| {
            g.copy_from_slice(&flat[off..off + g.len()]);
            off += g.len();
        });
        assert_eq!(off, flat.len(), "write-back must cover every gradient");
        loaded.apply_step();
        assert_eq!(collect_params(local.as_ref()), collect_params(loaded.as_ref()));
    }

    #[test]
    fn checkpoint_round_trip_bit_identical_all_kinds() {
        for kind in ModelKind::ALL {
            let cfg = small_cfg(kind);
            let mut src = build_model(&cfg);
            // move params off init so the round trip proves a real restore
            let mut rng = Rng::new(77);
            src.visit_params_mut(&mut |_n, p| {
                for v in p.iter_mut() {
                    *v += 0.05 * rng.normal();
                }
            });
            let mut bytes = Vec::new();
            write_checkpoint(src.as_ref(), &mut bytes).unwrap();

            let mut dst = build_model(&cfg);
            read_checkpoint(dst.as_mut(), &mut bytes.as_slice()).unwrap();
            let a = collect_params(src.as_ref());
            let b = collect_params(dst.as_ref());
            assert_eq!(a, b, "{kind:?}: params must restore bit-identical");

            let mut xrng = Rng::new(5);
            let x = input_for(src.as_ref(), 3, &mut xrng);
            let ya = src.forward(&x);
            let yb = dst.forward(&x);
            assert_eq!(ya.data, yb.data, "{kind:?}: warm-started logits must be identical");
        }
    }

    #[test]
    fn checkpoint_file_round_trip() {
        let cfg = small_cfg(ModelKind::Mlp);
        let src = build_model(&cfg);
        let path = std::env::temp_dir().join("spm_test_api_ckpt.bin");
        save_checkpoint(src.as_ref(), &path).unwrap();
        let mut dst = build_model(&cfg);
        load_checkpoint(dst.as_mut(), &path).unwrap();
        assert_eq!(collect_params(src.as_ref()), collect_params(dst.as_ref()));
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn checkpoint_rejects_corrupt_header() {
        let cfg = small_cfg(ModelKind::Mlp);
        let src = build_model(&cfg);
        let mut bytes = Vec::new();
        write_checkpoint(src.as_ref(), &mut bytes).unwrap();

        // bad magic
        let mut broken = bytes.clone();
        broken[0] ^= 0xFF;
        let mut dst = build_model(&cfg);
        let err = read_checkpoint(dst.as_mut(), &mut broken.as_slice()).unwrap_err();
        assert!(err.to_string().contains("magic"), "{err}");

        // truncated mid-buffer
        let cut = &bytes[..bytes.len() / 2];
        let mut dst = build_model(&cfg);
        assert!(read_checkpoint(dst.as_mut(), &mut &cut[..]).is_err());

        // and the reject must leave the target untouched
        let fresh = collect_params(build_model(&cfg).as_ref());
        assert_eq!(collect_params(dst.as_ref()), fresh, "failed load must not mutate params");
    }

    #[test]
    fn checkpoint_rejects_wrong_architecture() {
        let mlp = build_model(&small_cfg(ModelKind::Mlp));
        let mut bytes = Vec::new();
        write_checkpoint(mlp.as_ref(), &mut bytes).unwrap();
        let mut gru = build_model(&small_cfg(ModelKind::Gru));
        let err = read_checkpoint(gru.as_mut(), &mut bytes.as_slice()).unwrap_err();
        assert!(err.to_string().contains("mlp"), "{err}");
    }

    #[test]
    fn checkpoint_rejects_pairing_mismatch() {
        // schedule = "random": every buffer shape matches, but the pairing
        // tables depend on the op seed — loading across seeds would bind
        // stage params to different (i, j) pairs, so it must be rejected
        let cfg_a = ModelCfg::new(
            ModelKind::Mlp,
            LinearCfg::spm(8, Variant::General).with_schedule(Schedule::Random).with_seed(1),
        )
        .with_classes(4);
        let cfg_b = ModelCfg {
            op: LinearCfg::spm(8, Variant::General).with_schedule(Schedule::Random).with_seed(2),
            ..cfg_a
        };
        let src = build_model(&cfg_a);
        let mut bytes = Vec::new();
        write_checkpoint(src.as_ref(), &mut bytes).unwrap();
        let mut dst = build_model(&cfg_b);
        assert_ne!(
            arch_fingerprint(src.as_ref()),
            arch_fingerprint(dst.as_ref()),
            "random pairings under different seeds must fingerprint differently"
        );
        let err = read_checkpoint(dst.as_mut(), &mut bytes.as_slice()).unwrap_err();
        assert!(err.to_string().contains("pairing"), "{err}");
        // same config -> same fingerprint -> loads fine
        let mut same = build_model(&cfg_a);
        read_checkpoint(same.as_mut(), &mut bytes.as_slice()).unwrap();
    }

    /// Satellite (zoo, DESIGN.md §19): every kind's structural layout is
    /// fingerprinted, so checkpoints can never migrate across kinds —
    /// even between a butterfly op and the general-SPM op on the
    /// butterfly schedule, whose parameter buffers are bit-identical.
    #[test]
    fn fingerprint_separates_every_zoo_kind() {
        let mut prints = Vec::new();
        for kind in LinearKind::ALL {
            let cfg = ModelCfg::new(
                ModelKind::Mlp,
                LinearCfg { kind, ..LinearCfg::spm(8, Variant::General) }.with_seed(1),
            )
            .with_classes(4);
            prints.push((kind, arch_fingerprint(build_model(&cfg).as_ref())));
        }
        for (i, (ka, fa)) in prints.iter().enumerate() {
            for (kb, fb) in &prints[i + 1..] {
                assert_ne!(fa, fb, "{} vs {} must fingerprint apart", ka.name(), kb.name());
            }
        }
    }

    #[test]
    fn checkpoint_rejects_butterfly_into_identical_spm() {
        // the hardest cross-kind case: same widths, same schedule, same
        // seed, bit-identical parameter buffers — only the kind differs
        let bfly_cfg = ModelCfg::new(ModelKind::Mlp, LinearCfg::butterfly(8).with_seed(3))
            .with_classes(4);
        let spm_cfg = ModelCfg {
            op: LinearCfg::spm(8, Variant::General)
                .with_schedule(Schedule::Butterfly)
                .with_seed(3),
            ..bfly_cfg
        };
        let src = build_model(&bfly_cfg);
        let mut bytes = Vec::new();
        write_checkpoint(src.as_ref(), &mut bytes).unwrap();
        let mut dst = build_model(&spm_cfg);
        assert_eq!(
            collect_params(src.as_ref()),
            collect_params(dst.as_ref()),
            "precondition: the two models must be parameter-identical"
        );
        assert_ne!(arch_fingerprint(src.as_ref()), arch_fingerprint(dst.as_ref()));
        let err = read_checkpoint(dst.as_mut(), &mut bytes.as_slice()).unwrap_err();
        assert!(err.to_string().contains("pairing"), "{err}");
        // and back into a butterfly model of the same config it loads
        let mut same = build_model(&bfly_cfg);
        read_checkpoint(same.as_mut(), &mut bytes.as_slice()).unwrap();
    }

    #[test]
    fn checkpoint_rejects_cross_rank_and_cross_shuffle() {
        // rank is fingerprinted: a rank-3 file must not bind to a rank-4 op
        let r3 = ModelCfg::new(ModelKind::Mlp, LinearCfg::lowrank(8).with_rank(3).with_seed(1))
            .with_classes(4);
        let r4 = ModelCfg { op: LinearCfg::lowrank(8).with_rank(4).with_seed(1), ..r3 };
        let src = build_model(&r3);
        let mut bytes = Vec::new();
        write_checkpoint(src.as_ref(), &mut bytes).unwrap();
        let mut dst = build_model(&r4);
        assert_ne!(arch_fingerprint(src.as_ref()), arch_fingerprint(dst.as_ref()));
        assert!(read_checkpoint(dst.as_mut(), &mut bytes.as_slice()).is_err());

        // the shuffle permutation is fingerprinted: same width, same
        // block, every buffer shape equal — only the seeded shuffle
        // differs, exactly the random-pairing trap for block-shuffle
        let s1 = ModelCfg::new(ModelKind::Mlp, LinearCfg::blockshuffle(8).with_block(4).with_seed(1))
            .with_classes(4);
        let s2 = ModelCfg { op: LinearCfg::blockshuffle(8).with_block(4).with_seed(2), ..s1 };
        let src = build_model(&s1);
        let mut bytes = Vec::new();
        write_checkpoint(src.as_ref(), &mut bytes).unwrap();
        let mut dst = build_model(&s2);
        assert_ne!(
            arch_fingerprint(src.as_ref()),
            arch_fingerprint(dst.as_ref()),
            "shuffles under different seeds must fingerprint differently"
        );
        let err = read_checkpoint(dst.as_mut(), &mut bytes.as_slice()).unwrap_err();
        assert!(err.to_string().contains("pairing"), "{err}");
        let mut same = build_model(&s1);
        read_checkpoint(same.as_mut(), &mut bytes.as_slice()).unwrap();
    }

    #[test]
    fn checkpoint_rejects_width_mismatch() {
        let small = build_model(&small_cfg(ModelKind::Mlp));
        let mut bytes = Vec::new();
        write_checkpoint(small.as_ref(), &mut bytes).unwrap();
        let wide_cfg = ModelCfg {
            op: LinearCfg::spm(16, Variant::General),
            ..small_cfg(ModelKind::Mlp)
        };
        let mut wide = build_model(&wide_cfg);
        let err = read_checkpoint(wide.as_mut(), &mut bytes.as_slice()).unwrap_err();
        assert!(err.to_string().contains("shape"), "{err}");
    }

    #[test]
    fn ckpt_data_round_trip_matches_streamed_load() {
        for kind in ModelKind::ALL {
            let cfg = small_cfg(kind);
            let mut src = build_model(&cfg);
            let mut rng = Rng::new(83);
            src.visit_params_mut(&mut |_n, p| {
                for v in p.iter_mut() {
                    *v += 0.05 * rng.normal();
                }
            });
            let mut bytes = Vec::new();
            write_checkpoint(src.as_ref(), &mut bytes).unwrap();

            let data = CkptData::from_bytes(&bytes).unwrap();
            assert_eq!(data.kind, kind.name(), "{kind:?}");
            assert_eq!((data.d_in, data.d_out), (src.d_in(), src.d_out()), "{kind:?}");
            assert_eq!(data.arch, arch_fingerprint(src.as_ref()), "{kind:?}");

            let mut dst = build_model(&cfg);
            data.check_model(dst.as_ref()).unwrap();
            data.apply_to(dst.as_mut()).unwrap();
            assert_eq!(
                collect_params(src.as_ref()),
                collect_params(dst.as_ref()),
                "{kind:?}: applied params must be bit-identical"
            );
        }
    }

    #[test]
    fn ckpt_data_rejects_corrupt_and_trailing_bytes() {
        let cfg = small_cfg(ModelKind::Mlp);
        let src = build_model(&cfg);
        let mut bytes = Vec::new();
        write_checkpoint(src.as_ref(), &mut bytes).unwrap();

        // bad magic
        let mut broken = bytes.clone();
        broken[0] ^= 0xFF;
        assert!(CkptData::from_bytes(&broken).unwrap_err().to_string().contains("magic"));

        // truncated mid-buffer
        assert!(CkptData::from_bytes(&bytes[..bytes.len() / 2]).is_err());

        // trailing garbage after the last buffer
        let mut padded = bytes.clone();
        padded.extend_from_slice(&[0u8; 3]);
        assert!(CkptData::from_bytes(&padded).unwrap_err().to_string().contains("trailing"));

        // a corrupt buffer count cannot claim more than the bytes hold
        let mut huge = bytes.clone();
        let nbufs_at = 8 + 4 + 3 + 8 + 8 + 8; // magic, kind len, "mlp", d_in, d_out, arch
        huge[nbufs_at..nbufs_at + 8].copy_from_slice(&u64::MAX.to_le_bytes());
        assert!(CkptData::from_bytes(&huge).unwrap_err().to_string().contains("buffers"));
    }

    #[test]
    fn ckpt_data_rejects_fingerprint_mismatch_without_writing() {
        let cfg_a = ModelCfg::new(
            ModelKind::Mlp,
            LinearCfg::spm(8, Variant::General).with_schedule(Schedule::Random).with_seed(1),
        )
        .with_classes(4);
        let cfg_b = ModelCfg {
            op: LinearCfg::spm(8, Variant::General).with_schedule(Schedule::Random).with_seed(2),
            ..cfg_a
        };
        let src = build_model(&cfg_a);
        let mut bytes = Vec::new();
        write_checkpoint(src.as_ref(), &mut bytes).unwrap();
        let data = CkptData::from_bytes(&bytes).unwrap();
        let mut dst = build_model(&cfg_b);
        let before = collect_params(dst.as_ref());
        let err = data.apply_to(dst.as_mut()).unwrap_err();
        assert!(err.to_string().contains("fingerprint"), "{err}");
        assert_eq!(collect_params(dst.as_ref()), before, "reject must not mutate params");
    }

    #[test]
    fn model_kind_parse_round_trips() {
        for kind in ModelKind::ALL {
            assert_eq!(ModelKind::parse(kind.name()), Some(kind));
        }
        assert_eq!(ModelKind::parse("transformer"), None);
    }
}
