//! Model zoo mirroring python/compile/model.py: every network the paper
//! trains, in both "dense" and "spm" flavours, with exact hand-derived
//! backward passes (no autodiff in the native engine). Every linear map —
//! square mixers AND rectangular heads — is constructed through the
//! planned [`crate::ops::LinearOp`] layer; no model wires `Dense` or
//! `SpmParams` directly.
pub mod attention;
pub mod charlm;
pub mod gru;
pub mod mlp;

pub use crate::ops::{LinearCfg, LinearKind, LinearOp, LinearTrace};
