//! Model zoo mirroring python/compile/model.py: every network the paper
//! trains, in both "dense" and "spm" flavours, with exact hand-derived
//! backward passes (no autodiff in the native engine). Every linear map —
//! square mixers AND rectangular heads — is constructed through the
//! planned [`crate::ops::LinearOp`] layer; no model wires `Dense` or
//! `SpmParams` directly.
//!
//! Every model also implements the unified [`api::Model`] trait
//! (DESIGN.md §13), so coordinators, the serving engine, and checkpoints
//! drive any of them through one batched interface; [`api::build_model`]
//! is the factory.
pub mod api;
pub mod attention;
pub mod charlm;
pub mod gru;
pub mod mlp;

pub use crate::ops::{LinearCfg, LinearKind, LinearOp, LinearTrace};
pub use api::{build_model, Model, ModelCfg, ModelKind, Target};
