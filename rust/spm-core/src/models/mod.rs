//! Model zoo mirroring python/compile/model.py: every network the paper
//! trains, in both "dense" and "spm" flavours, with exact hand-derived
//! backward passes (no autodiff in the native engine).
pub mod attention;
pub mod charlm;
pub mod gru;
pub mod mixer;
pub mod mlp;

pub use mixer::{Mixer, MixerCfg, MixerKind};
