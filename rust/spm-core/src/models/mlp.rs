//! The Table 1/2 student: LinearOp(n->n) -> ReLU -> LinearOp head ->
//! softmax-xent. Exact hand-derived backward; Adam owned by the model;
//! both linear maps update through the flat apply_grads kernel.

use crate::loss::{softmax_xent, softmax_xent_into};
use crate::ops::{LinearCfg, LinearOp, LinearTrace, SpmExec};
use crate::optim::Adam;
use crate::rng::Rng;
use crate::tensor::Mat;

use super::api::{Model, ModelKind, Target};

fn empty_mat() -> Mat {
    Mat { rows: 0, cols: 0, data: Vec::new() }
}

/// Reusable activation/trace buffers (DESIGN.md §15): owned by the model,
/// reshaped in place each step so repeated forward/train calls with a
/// stable batch shape allocate nothing.
struct Scratch {
    h_pre: Mat,
    h: Mat,
    mix_tr: LinearTrace,
    logits: Mat,
    head_tr: LinearTrace,
    glogits: Mat,
    gh: Mat,
    gx: Mat,
}

impl Scratch {
    fn new() -> Self {
        Scratch {
            h_pre: empty_mat(),
            h: empty_mat(),
            mix_tr: LinearTrace::Dense,
            logits: empty_mat(),
            head_tr: LinearTrace::Dense,
            glogits: empty_mat(),
            gh: empty_mat(),
            gx: empty_mat(),
        }
    }
}

pub struct Classifier {
    pub mixer: LinearOp,
    pub head: LinearOp,
    pub adam: Adam,
    scratch: Scratch,
}

impl Classifier {
    pub fn new(cfg: LinearCfg, num_classes: usize, lr: f32, seed: u64) -> Self {
        let mut adam = Adam::new(lr);
        let mut rng = Rng::new(seed);
        let mixer = LinearOp::new(cfg, &mut rng, &mut adam);
        let head = LinearOp::new(LinearCfg::dense_rect(num_classes, cfg.n()), &mut rng, &mut adam);
        Classifier { mixer, head, adam, scratch: Scratch::new() }
    }

    pub fn param_count(&self) -> usize {
        self.mixer.param_count() + self.head.param_count()
    }

    pub fn logits(&self, x: &Mat) -> Mat {
        let mut h = self.mixer.forward(x);
        for v in h.data.iter_mut() {
            *v = v.max(0.0);
        }
        self.head.forward(&h)
    }

    /// [`Classifier::logits`] through the model-owned scratch: zero
    /// steady-state allocations for a stable batch shape.
    fn logits_into(&mut self, x: &Mat, out: &mut Mat) {
        let s = &mut self.scratch;
        self.mixer.forward_into(x, &mut s.h);
        for v in s.h.data.iter_mut() {
            *v = v.max(0.0);
        }
        self.head.forward_into(&s.h, out);
    }

    /// Forward + backward only: gradients ACCUMULATE into the two ops'
    /// flat buffers, the optimizer does not fire (the data-parallel
    /// engine reduces across replicas before [`Classifier::apply_step`]).
    pub fn accumulate_step(&mut self, x: &Mat, y: &[u32]) -> (f32, f32) {
        // forward (all intermediates live in the model-owned scratch)
        let s = &mut self.scratch;
        self.mixer.forward_train_into(x, &mut s.h_pre, &mut s.mix_tr);
        s.h.rows = s.h_pre.rows;
        s.h.cols = s.h_pre.cols;
        s.h.data.clear();
        s.h.data.extend_from_slice(&s.h_pre.data);
        for v in s.h.data.iter_mut() {
            *v = v.max(0.0);
        }
        self.head.forward_train_into(&s.h, &mut s.logits, &mut s.head_tr);
        let (loss, acc) = softmax_xent_into(&s.logits, y, &mut s.glogits);

        // backward (gradients accumulate inside each op)
        self.head.backward_into(&s.h, &s.head_tr, &s.glogits, &mut s.gh);
        for (g, pre) in s.gh.data.iter_mut().zip(&s.h_pre.data) {
            if *pre <= 0.0 {
                *g = 0.0; // ReLU'
            }
        }
        self.mixer.backward_into(x, &s.mix_tr, &s.gh, &mut s.gx);
        (loss, acc)
    }

    /// One flat Adam step from the accumulated gradients, then clear them.
    pub fn apply_step(&mut self) {
        self.adam.next_step();
        self.mixer.apply_grads(&mut self.adam);
        self.head.apply_grads(&mut self.adam);
    }

    /// One training step; returns (loss, accuracy).
    pub fn train_step(&mut self, x: &Mat, y: &[u32]) -> (f32, f32) {
        self.mixer.zero_grads();
        self.head.zero_grads();
        let lm = self.accumulate_step(x, y);
        self.apply_step();
        lm
    }

    /// Evaluation: (loss, accuracy) without updates.
    pub fn evaluate(&self, x: &Mat, y: &[u32]) -> (f32, f32) {
        let logits = self.logits(x);
        let (loss, acc, _g) = softmax_xent(&logits, y);
        (loss, acc)
    }
}

impl Model for Classifier {
    fn kind(&self) -> ModelKind {
        ModelKind::Mlp
    }

    fn d_in(&self) -> usize {
        self.mixer.d_in()
    }

    fn d_out(&self) -> usize {
        self.head.d_out()
    }

    fn param_count(&self) -> usize {
        Classifier::param_count(self)
    }

    fn forward(&self, x: &Mat) -> Mat {
        self.logits(x)
    }

    fn forward_into(&mut self, x: &Mat, out: &mut Mat) {
        self.logits_into(x, out);
    }

    fn accumulate_step(&mut self, x: &Mat, target: &Target) -> (f32, f32) {
        let Target::Labels(y) = target else { panic!("mlp trains on class labels") };
        Classifier::accumulate_step(self, x, y)
    }

    fn apply_step(&mut self) {
        Classifier::apply_step(self)
    }

    fn zero_grads(&mut self) {
        self.mixer.zero_grads();
        self.head.zero_grads();
    }

    fn evaluate(&self, x: &Mat, target: &Target) -> (f32, f32) {
        let Target::Labels(y) = target else { panic!("mlp evaluates on class labels") };
        Classifier::evaluate(self, x, y)
    }

    fn set_exec(&mut self, exec: SpmExec) {
        self.mixer.set_exec(exec);
        self.head.set_exec(exec);
    }

    fn visit_params(&self, f: &mut dyn FnMut(&str, &[f32])) {
        f("mixer", self.mixer.params());
        f("head", self.head.params());
    }

    fn visit_params_mut(&mut self, f: &mut dyn FnMut(&str, &mut [f32])) {
        f("mixer", self.mixer.params_mut());
        f("head", self.head.params_mut());
    }

    fn visit_grads(&self, f: &mut dyn FnMut(&str, &[f32])) {
        f("mixer", self.mixer.grads());
        f("head", self.head.grads());
    }

    fn visit_grads_mut(&mut self, f: &mut dyn FnMut(&str, &mut [f32])) {
        f("mixer", self.mixer.grads_mut());
        f("head", self.head.grads_mut());
    }

    fn visit_ops(&self, f: &mut dyn FnMut(&LinearOp)) {
        f(&self.mixer);
        f(&self.head);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pairing::Schedule;
    use crate::spm::Variant;

    fn toy_problem(n: usize, c: usize, b: usize, seed: u64) -> (Mat, Vec<u32>) {
        let mut rng = Rng::new(seed);
        let x = Mat::from_vec(b, n, rng.normal_vec(b * n, 1.0));
        let y = (0..b)
            .map(|i| {
                let row = x.row(i);
                let mut best = 0;
                for j in 1..c {
                    if row[j] > row[best] {
                        best = j;
                    }
                }
                best as u32
            })
            .collect();
        (x, y)
    }

    #[test]
    fn dense_student_learns_argmax_rule() {
        let (x, y) = toy_problem(16, 4, 128, 1);
        let mut clf = Classifier::new(LinearCfg::dense(16), 4, 5e-3, 2);
        let first = clf.train_step(&x, &y).0;
        let mut last = first;
        for _ in 0..80 {
            last = clf.train_step(&x, &y).0;
        }
        assert!(last < first * 0.5, "{first} -> {last}");
        let (_l, acc) = clf.evaluate(&x, &y);
        assert!(acc > 0.6, "acc {acc}");
    }

    #[test]
    fn spm_student_learns_argmax_rule() {
        let (x, y) = toy_problem(16, 4, 128, 3);
        let cfg = LinearCfg::spm(16, Variant::General).with_schedule(Schedule::Shift);
        let mut clf = Classifier::new(cfg, 4, 5e-3, 4);
        let first = clf.train_step(&x, &y).0;
        let mut last = first;
        for _ in 0..120 {
            last = clf.train_step(&x, &y).0;
        }
        assert!(last < first * 0.6, "{first} -> {last}");
    }

    #[test]
    fn serving_forward_into_matches_forward() {
        let (x, _y) = toy_problem(16, 4, 32, 9);
        let cfg = LinearCfg::spm(16, Variant::General).with_schedule(Schedule::Shift);
        let mut clf = Classifier::new(cfg, 4, 1e-3, 10);
        let want = Model::forward(&clf, &x);
        let mut got = Mat::zeros(0, 0);
        clf.forward_into(&x, &mut got);
        assert_eq!(want, got);
        // second call reuses the scratch and must stay bit-identical
        clf.forward_into(&x, &mut got);
        assert_eq!(want, got);
    }

    #[test]
    fn eval_does_not_mutate() {
        let (x, y) = toy_problem(8, 3, 16, 5);
        let clf = Classifier::new(LinearCfg::dense(8), 3, 1e-3, 6);
        let (l1, a1) = clf.evaluate(&x, &y);
        let (l2, a2) = clf.evaluate(&x, &y);
        assert_eq!(l1, l2);
        assert_eq!(a1, a2);
    }

    #[test]
    fn no_direct_dense_wiring_head_is_linear_op() {
        // the head is a LinearOp (rectangular dense), not a bespoke layer
        let clf = Classifier::new(LinearCfg::dense(8), 3, 1e-3, 7);
        assert_eq!(clf.head.d_in(), 8);
        assert_eq!(clf.head.d_out(), 3);
        assert_eq!(clf.param_count(), (8 * 8 + 8) + (3 * 8 + 3));
    }
}
