//! The Table 1/2 student: mixer(n->n) -> ReLU -> dense head -> softmax-xent.
//! Exact hand-derived backward; Adam owned by the model.

use crate::dense::Dense;
use crate::loss::softmax_xent;
use crate::models::mixer::{Mixer, MixerCfg};
use crate::optim::Adam;
use crate::rng::Rng;
use crate::tensor::Mat;

pub struct Classifier {
    pub mixer: Mixer,
    pub head: Dense,
    head_slots: [usize; 2],
    pub adam: Adam,
}

impl Classifier {
    pub fn new(cfg: MixerCfg, num_classes: usize, lr: f32, seed: u64) -> Self {
        let mut adam = Adam::new(lr);
        let mut rng = Rng::new(seed);
        let mixer = Mixer::new(cfg, &mut rng, &mut adam);
        let head = Dense::init(&mut rng, num_classes, cfg.n);
        let head_slots = [adam.register(head.w.data.len()), adam.register(head.b.len())];
        Classifier { mixer, head, head_slots, adam }
    }

    pub fn param_count(&self) -> usize {
        self.mixer.param_count() + self.head.param_count()
    }

    pub fn logits(&self, x: &Mat) -> Mat {
        let mut h = self.mixer.forward(x);
        for v in h.data.iter_mut() {
            *v = v.max(0.0);
        }
        self.head.forward(&h)
    }

    /// One training step; returns (loss, accuracy).
    pub fn train_step(&mut self, x: &Mat, y: &[u32]) -> (f32, f32) {
        // forward
        let (h_pre, trace) = self.mixer.forward_trace(x);
        let mut h = h_pre.clone();
        for v in h.data.iter_mut() {
            *v = v.max(0.0);
        }
        let logits = self.head.forward(&h);
        let (loss, acc, glogits) = softmax_xent(&logits, y);

        // backward
        let (mut gh, head_grads) = self.head.backward(&h, &glogits);
        for (g, pre) in gh.data.iter_mut().zip(&h_pre.data) {
            if *pre <= 0.0 {
                *g = 0.0; // ReLU'
            }
        }
        let (_gx, mix_grads) = self.mixer.backward(x, &trace, &gh);

        // update
        self.adam.next_step();
        self.mixer.update(&mut self.adam, &mix_grads);
        self.adam.update(self.head_slots[0], &mut self.head.w.data, &head_grads.w.data);
        self.adam.update(self.head_slots[1], &mut self.head.b, &head_grads.b);
        (loss, acc)
    }

    /// Evaluation: (loss, accuracy) without updates.
    pub fn evaluate(&self, x: &Mat, y: &[u32]) -> (f32, f32) {
        let logits = self.logits(x);
        let (loss, acc, _g) = softmax_xent(&logits, y);
        (loss, acc)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::mixer::MixerKind;
    use crate::pairing::Schedule;
    use crate::spm::Variant;

    fn toy_problem(n: usize, c: usize, b: usize, seed: u64) -> (Mat, Vec<u32>) {
        let mut rng = Rng::new(seed);
        let x = Mat::from_vec(b, n, rng.normal_vec(b * n, 1.0));
        let y = (0..b)
            .map(|i| {
                let row = x.row(i);
                let mut best = 0;
                for j in 1..c {
                    if row[j] > row[best] {
                        best = j;
                    }
                }
                best as u32
            })
            .collect();
        (x, y)
    }

    #[test]
    fn dense_student_learns_argmax_rule() {
        let (x, y) = toy_problem(16, 4, 128, 1);
        let mut clf = Classifier::new(MixerCfg::dense(16), 4, 5e-3, 2);
        let first = clf.train_step(&x, &y).0;
        let mut last = first;
        for _ in 0..80 {
            last = clf.train_step(&x, &y).0;
        }
        assert!(last < first * 0.5, "{first} -> {last}");
        let (_l, acc) = clf.evaluate(&x, &y);
        assert!(acc > 0.6, "acc {acc}");
    }

    #[test]
    fn spm_student_learns_argmax_rule() {
        let (x, y) = toy_problem(16, 4, 128, 3);
        let cfg = MixerCfg {
            kind: MixerKind::Spm,
            ..MixerCfg::spm(16, Variant::General).with_schedule(Schedule::Shift)
        };
        let mut clf = Classifier::new(cfg, 4, 5e-3, 4);
        let first = clf.train_step(&x, &y).0;
        let mut last = first;
        for _ in 0..120 {
            last = clf.train_step(&x, &y).0;
        }
        assert!(last < first * 0.6, "{first} -> {last}");
    }

    #[test]
    fn eval_does_not_mutate() {
        let (x, y) = toy_problem(8, 3, 16, 5);
        let clf = Classifier::new(MixerCfg::dense(8), 3, 1e-3, 6);
        let (l1, a1) = clf.evaluate(&x, &y);
        let (l2, a2) = clf.evaluate(&x, &y);
        assert_eq!(l1, l2);
        assert_eq!(a1, a2);
    }
}
