//! Scaled dot-product attention with SPM-replaceable Q/K/V/O projections
//! (paper §7) and the paper's exact backward: the closed-form softmax
//! Jacobian of §7.4 and the Q/K gradients of §7.5. All four projections
//! are [`LinearOp`]s updated through the flat apply_grads kernel.

use crate::loss::mse;
use crate::ops::{LinearCfg, LinearOp, LinearTrace, SpmExec};
use crate::optim::Adam;
use crate::rng::Rng;
use crate::tensor::Mat;

use super::api::{Model, ModelKind, Target};

fn empty_mat() -> Mat {
    Mat { rows: 0, cols: 0, data: Vec::new() }
}

/// Reusable buffers for the trace-free forward (serving path, DESIGN.md
/// §15): Q/K/V/context plus ONE `(T, T)` scores matrix reused across
/// every (batch, head) pair — the forward only needs scores transiently.
struct Scratch {
    q: Mat,
    k: Mat,
    v: Mat,
    ctx: Mat,
    scores: Mat,
}

impl Scratch {
    fn new() -> Self {
        Scratch {
            q: empty_mat(),
            k: empty_mat(),
            v: empty_mat(),
            ctx: empty_mat(),
            scores: empty_mat(),
        }
    }
}

pub struct Attention {
    pub d: usize,
    pub heads: usize,
    pub maps: [LinearOp; 4], // q, k, v, o
    pub adam: Adam,
    scratch: Scratch,
}

struct FwdTrace {
    q: Mat,
    k: Mat,
    v: Mat,
    ctx: Mat,
    attn: Vec<Mat>, // per (batch*head): (T, T) post-softmax
    traces: [LinearTrace; 4],
    x_flat: Mat,
    b: usize,
    t: usize,
}

impl Attention {
    pub fn new(cfg: LinearCfg, heads: usize, lr: f32, seed: u64) -> Self {
        assert_eq!(cfg.n() % heads, 0, "d must divide heads");
        let mut adam = Adam::new(lr);
        let mut rng = Rng::new(seed);
        let maps = std::array::from_fn(|i| {
            LinearOp::new(cfg.with_seed(cfg.seed + i as u64), &mut rng, &mut adam)
        });
        Attention { d: cfg.n(), heads, maps, adam, scratch: Scratch::new() }
    }

    pub fn param_count(&self) -> usize {
        self.maps.iter().map(|m| m.param_count()).sum()
    }

    fn forward_inner(&self, x_flat: &Mat, b: usize, t: usize) -> (Mat, FwdTrace) {
        let d = self.d;
        let h = self.heads;
        let dh = d / h;
        let scale = 1.0 / (dh as f32).sqrt();
        let (q, t_q) = self.maps[0].forward_train(x_flat); // eq. (29)
        let (k, t_k) = self.maps[1].forward_train(x_flat); // eq. (30)
        let (v, t_v) = self.maps[2].forward_train(x_flat); // eq. (31)
        let mut ctx = Mat::zeros(b * t, d);
        let mut attn = Vec::with_capacity(b * h);
        for bi in 0..b {
            for hi in 0..h {
                let off = hi * dh;
                // scores S = Q K^T / sqrt(dh)  (eq. 32), per (batch, head)
                let mut a = Mat::zeros(t, t);
                for i in 0..t {
                    let qrow = &q.row(bi * t + i)[off..off + dh];
                    for j in 0..t {
                        let krow = &k.row(bi * t + j)[off..off + dh];
                        let mut s = 0.0;
                        for e in 0..dh {
                            s += qrow[e] * krow[e];
                        }
                        *a.at_mut(i, j) = s * scale;
                    }
                }
                crate::loss::softmax_rows(&mut a); // eq. (33)
                // H = A V  (eq. 34)
                for i in 0..t {
                    let arow = a.row(i);
                    let crow = &mut ctx.row_mut(bi * t + i)[off..off + dh];
                    for j in 0..t {
                        let aij = arow[j];
                        let vrow = &v.row(bi * t + j)[off..off + dh];
                        for e in 0..dh {
                            crow[e] += aij * vrow[e];
                        }
                    }
                }
                attn.push(a);
            }
        }
        let (y, t_o) = self.maps[3].forward_train(&ctx); // eq. (35)
        let trace = FwdTrace {
            q,
            k,
            v,
            ctx,
            attn,
            traces: [t_q, t_k, t_v, t_o],
            x_flat: x_flat.clone(),
            b,
            t,
        };
        (y, trace)
    }

    /// x: (B*T, d) flat rows; returns (B*T, d).
    pub fn forward(&self, x_flat: &Mat, b: usize, t: usize) -> Mat {
        self.forward_inner(x_flat, b, t).0
    }

    /// Trace-free [`Attention::forward`] through the model-owned scratch:
    /// zero steady-state allocations for a stable `(b, t)` shape. Same
    /// arithmetic order as [`Attention::forward_inner`], so serving and
    /// training forwards agree bit-for-bit.
    pub fn forward_only_into(&mut self, x_flat: &Mat, b: usize, t: usize, out: &mut Mat) {
        let d = self.d;
        let h = self.heads;
        let dh = d / h;
        let scale = 1.0 / (dh as f32).sqrt();
        let s = &mut self.scratch;
        self.maps[0].forward_into(x_flat, &mut s.q); // eq. (29)
        self.maps[1].forward_into(x_flat, &mut s.k); // eq. (30)
        self.maps[2].forward_into(x_flat, &mut s.v); // eq. (31)
        s.ctx.rows = b * t;
        s.ctx.cols = d;
        s.ctx.data.clear();
        s.ctx.data.resize(b * t * d, 0.0);
        for bi in 0..b {
            for hi in 0..h {
                let off = hi * dh;
                // scores S = Q K^T / sqrt(dh)  (eq. 32), per (batch, head)
                s.scores.rows = t;
                s.scores.cols = t;
                s.scores.data.clear();
                s.scores.data.resize(t * t, 0.0);
                for i in 0..t {
                    let qrow = &s.q.row(bi * t + i)[off..off + dh];
                    for j in 0..t {
                        let krow = &s.k.row(bi * t + j)[off..off + dh];
                        let mut acc = 0.0;
                        for e in 0..dh {
                            acc += qrow[e] * krow[e];
                        }
                        s.scores.data[i * t + j] = acc * scale;
                    }
                }
                crate::loss::softmax_rows(&mut s.scores); // eq. (33)
                // H = A V  (eq. 34)
                for i in 0..t {
                    let crow = &mut s.ctx.data[(bi * t + i) * d + off..(bi * t + i) * d + off + dh];
                    for j in 0..t {
                        let aij = s.scores.data[i * t + j];
                        let vrow = &s.v.data[(bi * t + j) * d + off..(bi * t + j) * d + off + dh];
                        for e in 0..dh {
                            crow[e] += aij * vrow[e];
                        }
                    }
                }
            }
        }
        self.maps[3].forward_into(&s.ctx, out); // eq. (35)
    }

    /// Forward + backward only: projection gradients accumulate in the
    /// four ops' flat buffers, the optimizer does not fire. Returns the
    /// MSE loss against `target` (B*T, d).
    pub fn accumulate_step(&mut self, x_flat: &Mat, target: &Mat, b: usize, t: usize) -> f32 {
        let (y, tr) = self.forward_inner(x_flat, b, t);
        let (loss, gy) = mse(&y, target);
        let gx = self.backward(&tr, &gy);
        let _ = gx;
        loss
    }

    /// One flat Adam step from the accumulated gradients, then clear them.
    pub fn apply_step(&mut self) {
        self.adam.next_step();
        for m in self.maps.iter_mut() {
            m.apply_grads(&mut self.adam);
        }
    }

    /// Clear the four projections' gradient accumulators.
    pub fn zero_grads(&mut self) {
        for m in self.maps.iter_mut() {
            m.zero_grads();
        }
    }

    /// One MSE training step against `target` (B*T, d); returns loss.
    pub fn train_step(&mut self, x_flat: &Mat, target: &Mat, b: usize, t: usize) -> f32 {
        self.zero_grads();
        let loss = self.accumulate_step(x_flat, target, b, t);
        self.apply_step();
        loss
    }

    /// MSE against `target` (B*T, d) without updates.
    pub fn evaluate(&self, x_flat: &Mat, target: &Mat, b: usize, t: usize) -> f32 {
        let y = self.forward(x_flat, b, t);
        mse(&y, target).0
    }

    /// Exact backward; ACCUMULATES into the projections' flat gradient
    /// buffers (no optimizer update — see [`Attention::apply_step`]) and
    /// returns g_x.
    fn backward(&mut self, tr: &FwdTrace, gy: &Mat) -> Mat {
        let d = self.d;
        let h = self.heads;
        let dh = d / h;
        let (b, t) = (tr.b, tr.t);
        let scale = 1.0 / (dh as f32).sqrt();

        // Y = O(ctx):  G_H = O^T(G_Y)    (§7.3)
        let g_ctx = self.maps[3].backward(&tr.ctx, &tr.traces[3], gy);

        let mut g_q = Mat::zeros(b * t, d);
        let mut g_k = Mat::zeros(b * t, d);
        let mut g_v = Mat::zeros(b * t, d);
        for bi in 0..b {
            for hi in 0..h {
                let off = hi * dh;
                let a = &tr.attn[bi * h + hi];
                // G_A = G_H V^T ; G_V = A^T G_H   (eqs. 36-37)
                let mut g_a = Mat::zeros(t, t);
                for i in 0..t {
                    let ghrow = &g_ctx.row(bi * t + i)[off..off + dh];
                    for j in 0..t {
                        let vrow = &tr.v.row(bi * t + j)[off..off + dh];
                        let mut s = 0.0;
                        for e in 0..dh {
                            s += ghrow[e] * vrow[e];
                        }
                        *g_a.at_mut(i, j) = s;
                    }
                }
                for j in 0..t {
                    let gvrow = &mut g_v.row_mut(bi * t + j)[off..off + dh];
                    for i in 0..t {
                        let aij = a.at(i, j);
                        let ghrow = &g_ctx.row(bi * t + i)[off..off + dh];
                        for e in 0..dh {
                            gvrow[e] += aij * ghrow[e];
                        }
                    }
                }
                // softmax Jacobian, closed form (§7.4):
                // (G_S)_i = A_i * (G_A_i - <A_i, G_A_i>)
                let mut g_s = Mat::zeros(t, t);
                for i in 0..t {
                    let arow = a.row(i);
                    let garow = g_a.row(i);
                    let inner: f32 = arow.iter().zip(garow).map(|(x, y)| x * y).sum();
                    let gsrow = g_s.row_mut(i);
                    for j in 0..t {
                        gsrow[j] = arow[j] * (garow[j] - inner);
                    }
                }
                // G_Q = G_S K / sqrt(dh); G_K = G_S^T Q / sqrt(dh)  (eqs. 38-39)
                for i in 0..t {
                    let gsrow = g_s.row(i);
                    let gqrow = &mut g_q.row_mut(bi * t + i)[off..off + dh];
                    for j in 0..t {
                        let gs = gsrow[j] * scale;
                        let krow = &tr.k.row(bi * t + j)[off..off + dh];
                        for e in 0..dh {
                            gqrow[e] += gs * krow[e];
                        }
                    }
                }
                for j in 0..t {
                    let gkrow = &mut g_k.row_mut(bi * t + j)[off..off + dh];
                    for i in 0..t {
                        let gs = g_s.at(i, j) * scale;
                        let qrow = &tr.q.row(bi * t + i)[off..off + dh];
                        for e in 0..dh {
                            gkrow[e] += gs * qrow[e];
                        }
                    }
                }
            }
        }

        // back through the three input projections; accumulate at x (§7.5)
        let gx_q = self.maps[0].backward(&tr.x_flat, &tr.traces[0], &g_q);
        let gx_k = self.maps[1].backward(&tr.x_flat, &tr.traces[1], &g_k);
        let gx_v = self.maps[2].backward(&tr.x_flat, &tr.traces[2], &g_v);
        let mut gx = gx_q;
        for i in 0..gx.data.len() {
            gx.data[i] += gx_k.data[i] + gx_v.data[i];
        }
        gx
    }
}

/// [`Model`]-shaped view of attention over fixed-length sequences: one
/// request row is the flattened `(T, d)` sequence, so
/// `d_in = d_out = seq_len * d`. A `(B, T*d)` row-major matrix has the
/// SAME memory layout as the `(B*T, d)` flat-rows matrix the attention
/// core consumes, so the reshapes are pure buffer reinterpretations.
pub struct AttnSeq {
    pub attn: Attention,
    pub seq_len: usize,
    // reusable `(B*T, d)` restride buffer for the serving path
    xf: Mat,
}

impl AttnSeq {
    pub fn new(cfg: LinearCfg, heads: usize, seq_len: usize, lr: f32, seed: u64) -> Self {
        assert!(seq_len >= 1, "seq_len must be >= 1");
        AttnSeq { attn: Attention::new(cfg, heads, lr, seed), seq_len, xf: empty_mat() }
    }

    /// `(B, T*d)` -> `(B*T, d)` (same data, different row stride).
    fn flat_rows(&self, x: &Mat) -> Mat {
        let d = self.attn.d;
        assert_eq!(x.cols, self.seq_len * d, "row must hold T={} steps of width {d}", self.seq_len);
        Mat::from_vec(x.rows * self.seq_len, d, x.data.clone())
    }
}

impl Model for AttnSeq {
    fn kind(&self) -> ModelKind {
        ModelKind::Attention
    }

    fn d_in(&self) -> usize {
        self.seq_len * self.attn.d
    }

    fn d_out(&self) -> usize {
        self.seq_len * self.attn.d
    }

    fn param_count(&self) -> usize {
        self.attn.param_count()
    }

    fn forward(&self, x: &Mat) -> Mat {
        let y = self.attn.forward(&self.flat_rows(x), x.rows, self.seq_len);
        Mat::from_vec(x.rows, self.seq_len * self.attn.d, y.data)
    }

    fn forward_into(&mut self, x: &Mat, out: &mut Mat) {
        let d = self.attn.d;
        assert_eq!(x.cols, self.seq_len * d, "row must hold T={} steps of width {d}", self.seq_len);
        // (B, T*d) and (B*T, d) share one row-major layout: restride into
        // the reusable buffer, run the trace-free core, restride back.
        self.xf.rows = x.rows * self.seq_len;
        self.xf.cols = d;
        self.xf.data.clear();
        self.xf.data.extend_from_slice(&x.data);
        self.attn.forward_only_into(&self.xf, x.rows, self.seq_len, out);
        out.rows = x.rows;
        out.cols = self.seq_len * d;
    }

    fn accumulate_step(&mut self, x: &Mat, target: &Target) -> (f32, f32) {
        let Target::Values(t) = target else { panic!("attention trains on value targets (MSE)") };
        let xf = self.flat_rows(x);
        let tf = self.flat_rows(t);
        let loss = self.attn.accumulate_step(&xf, &tf, x.rows, self.seq_len);
        (loss, 0.0)
    }

    fn apply_step(&mut self) {
        self.attn.apply_step()
    }

    fn zero_grads(&mut self) {
        self.attn.zero_grads()
    }

    fn evaluate(&self, x: &Mat, target: &Target) -> (f32, f32) {
        let Target::Values(t) = target else { panic!("attention evaluates on value targets") };
        let loss =
            self.attn.evaluate(&self.flat_rows(x), &self.flat_rows(t), x.rows, self.seq_len);
        (loss, 0.0)
    }

    fn set_exec(&mut self, exec: SpmExec) {
        for m in self.attn.maps.iter_mut() {
            m.set_exec(exec);
        }
    }

    fn visit_params(&self, f: &mut dyn FnMut(&str, &[f32])) {
        for (name, m) in ["q", "k", "v", "o"].iter().zip(&self.attn.maps) {
            f(name, m.params());
        }
    }

    fn visit_params_mut(&mut self, f: &mut dyn FnMut(&str, &mut [f32])) {
        for (name, m) in ["q", "k", "v", "o"].iter().zip(self.attn.maps.iter_mut()) {
            f(name, m.params_mut());
        }
    }

    fn visit_grads(&self, f: &mut dyn FnMut(&str, &[f32])) {
        for (name, m) in ["q", "k", "v", "o"].iter().zip(&self.attn.maps) {
            f(name, m.grads());
        }
    }

    fn visit_grads_mut(&mut self, f: &mut dyn FnMut(&str, &mut [f32])) {
        for (name, m) in ["q", "k", "v", "o"].iter().zip(self.attn.maps.iter_mut()) {
            f(name, m.grads_mut());
        }
    }

    fn visit_ops(&self, f: &mut dyn FnMut(&LinearOp)) {
        for m in &self.attn.maps {
            f(m);
        }
    }

    fn flops_per_row(&self) -> u64 {
        // q/k/v/o projections run once per token; the O(T^2 d) score
        // matmul is op-free and excluded per the trait contract
        let mut per_token = 0u64;
        for m in &self.attn.maps {
            per_token += m.flops_per_row();
        }
        self.seq_len as u64 * per_token
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spm::Variant;

    #[test]
    fn forward_shapes_and_rows_mix() {
        let cfg = LinearCfg::dense(16);
        let attn = Attention::new(cfg, 4, 1e-3, 1);
        let mut rng = Rng::new(2);
        let x = Mat::from_vec(2 * 5, 16, rng.normal_vec(2 * 5 * 16, 1.0));
        let y = attn.forward(&x, 2, 5);
        assert_eq!((y.rows, y.cols), (10, 16));
        assert!(y.data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn attention_rows_are_convex_combinations() {
        // with identity V projection impossible here, check softmax rows sum 1
        let cfg = LinearCfg::dense(8);
        let attn = Attention::new(cfg, 2, 1e-3, 3);
        let mut rng = Rng::new(4);
        let x = Mat::from_vec(3, 8, rng.normal_vec(24, 1.0));
        let (_, tr) = attn.forward_inner(&x, 1, 3);
        for a in &tr.attn {
            for i in 0..a.rows {
                let s: f32 = a.row(i).iter().sum();
                assert!((s - 1.0).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn learns_identity_mapping_dense() {
        let cfg = LinearCfg::dense(8);
        let mut attn = Attention::new(cfg, 2, 3e-3, 5);
        let mut rng = Rng::new(6);
        let x = Mat::from_vec(4 * 4, 8, rng.normal_vec(4 * 4 * 8, 1.0));
        let target = x.clone();
        let first = attn.train_step(&x, &target, 4, 4);
        let mut last = first;
        for _ in 0..80 {
            last = attn.train_step(&x, &target, 4, 4);
        }
        assert!(last < first * 0.5, "{first} -> {last}");
    }

    #[test]
    fn learns_identity_mapping_spm() {
        let cfg = LinearCfg::spm(8, Variant::Rotation);
        let mut attn = Attention::new(cfg, 2, 3e-3, 7);
        let mut rng = Rng::new(8);
        let x = Mat::from_vec(4 * 4, 8, rng.normal_vec(4 * 4 * 8, 1.0));
        let target = x.clone();
        let first = attn.train_step(&x, &target, 4, 4);
        let mut last = first;
        for _ in 0..80 {
            last = attn.train_step(&x, &target, 4, 4);
        }
        assert!(last < first * 0.7, "{first} -> {last}");
    }

    #[test]
    fn serving_forward_into_matches_forward() {
        let cfg = LinearCfg::spm(8, Variant::Rotation);
        let mut m = AttnSeq::new(cfg, 2, 3, 1e-3, 11);
        let mut rng = Rng::new(12);
        let x = Mat::from_vec(4, 3 * 8, rng.normal_vec(4 * 3 * 8, 1.0));
        let want = m.forward(&x);
        let mut got = Mat::zeros(0, 0);
        m.forward_into(&x, &mut got);
        assert_eq!(want, got);
        // second call reuses the scratch and must stay bit-identical
        m.forward_into(&x, &mut got);
        assert_eq!(want, got);
    }

    #[test]
    fn grad_check_via_descent() {
        // tiny-lr steps must monotonically-ish reduce a fresh MSE objective
        let cfg = LinearCfg::spm(8, Variant::General);
        let mut attn = Attention::new(cfg, 2, 1e-3, 9);
        let mut rng = Rng::new(10);
        let x = Mat::from_vec(6, 8, rng.normal_vec(48, 1.0));
        let target = Mat::from_vec(6, 8, rng.normal_vec(48, 0.5));
        let l0 = attn.train_step(&x, &target, 2, 3);
        let mut l = l0;
        for _ in 0..30 {
            l = attn.train_step(&x, &target, 2, 3);
        }
        assert!(l < l0, "{l0} -> {l}");
    }
}
