//! The square linear map every model is parameterized over: a dense matrix
//! or an SPM operator — the paper's drop-in-replacement point (§2, §6.2,
//! §7.2). Rectangular maps (heads, embeddings) stay dense in both flavours.

use crate::dense::{Dense, DenseGrads};
use crate::optim::Adam;
use crate::pairing::Schedule;
use crate::rng::Rng;
use crate::spm::{Spm, SpmGrads, SpmParams, SpmSpec, Trace, Variant};
use crate::tensor::Mat;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MixerKind {
    Dense,
    Spm,
}

#[derive(Clone, Copy, Debug)]
pub struct MixerCfg {
    pub n: usize,
    pub kind: MixerKind,
    pub variant: Variant,
    pub schedule: Schedule,
    /// None = paper default log2(n)
    pub num_stages: Option<usize>,
    pub seed: u64,
}

impl MixerCfg {
    pub fn dense(n: usize) -> Self {
        MixerCfg {
            n,
            kind: MixerKind::Dense,
            variant: Variant::General,
            schedule: Schedule::Butterfly,
            num_stages: None,
            seed: 0,
        }
    }

    pub fn spm(n: usize, variant: Variant) -> Self {
        MixerCfg { kind: MixerKind::Spm, ..Self::dense(n) }.with_variant(variant)
    }

    pub fn with_variant(mut self, v: Variant) -> Self {
        self.variant = v;
        self
    }

    pub fn with_schedule(mut self, s: Schedule) -> Self {
        self.schedule = s;
        self
    }

    pub fn with_stages(mut self, l: usize) -> Self {
        self.num_stages = Some(l);
        self
    }

    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    fn spec(&self) -> SpmSpec {
        let mut s = SpmSpec::new(self.n, self.variant)
            .with_schedule(self.schedule)
            .with_seed(self.seed);
        if let Some(l) = self.num_stages {
            s = s.with_stages(l);
        }
        s
    }
}

/// Residuals of one mixer forward.
pub enum MixTrace {
    Dense,
    Spm(Trace),
}

/// Gradients of one mixer.
pub enum MixGrads {
    Dense(DenseGrads),
    Spm(SpmGrads),
}

impl MixGrads {
    pub fn add_assign(&mut self, other: &MixGrads) {
        match (self, other) {
            (MixGrads::Dense(a), MixGrads::Dense(b)) => {
                for (x, y) in a.w.data.iter_mut().zip(&b.w.data) {
                    *x += y;
                }
                for (x, y) in a.b.iter_mut().zip(&b.b) {
                    *x += y;
                }
            }
            (MixGrads::Spm(a), MixGrads::Spm(b)) => {
                for (x, y) in a.d_in.iter_mut().zip(&b.d_in) {
                    *x += y;
                }
                for (x, y) in a.d_out.iter_mut().zip(&b.d_out) {
                    *x += y;
                }
                for (x, y) in a.bias.iter_mut().zip(&b.bias) {
                    *x += y;
                }
                for (ma, mb) in a.mix.iter_mut().zip(&b.mix) {
                    for (x, y) in ma.iter_mut().zip(mb) {
                        *x += y;
                    }
                }
                for (x, y) in a.lone.iter_mut().zip(&b.lone) {
                    *x += y;
                }
            }
            _ => panic!("mixing dense/spm gradients"),
        }
    }
}

/// A square linear map: dense or SPM, with registered Adam slots.
pub enum Mixer {
    Dense { layer: Dense, slots: [usize; 2] },
    Spm { op: Spm, params: SpmParams, slots: Vec<usize> },
}

impl Mixer {
    pub fn new(cfg: MixerCfg, rng: &mut Rng, adam: &mut Adam) -> Self {
        match cfg.kind {
            MixerKind::Dense => {
                let layer = Dense::init(rng, cfg.n, cfg.n);
                let slots = [adam.register(layer.w.data.len()), adam.register(layer.b.len())];
                Mixer::Dense { layer, slots }
            }
            MixerKind::Spm => {
                let op = Spm::new(cfg.spec());
                let params = op.init_params(rng);
                let mut slots = vec![
                    adam.register(params.d_in.len()),
                    adam.register(params.d_out.len()),
                    adam.register(params.bias.len()),
                ];
                for m in &params.mix {
                    slots.push(adam.register(m.len()));
                }
                slots.push(adam.register(params.lone.len()));
                Mixer::Spm { op, params, slots }
            }
        }
    }

    pub fn n(&self) -> usize {
        match self {
            Mixer::Dense { layer, .. } => layer.w.cols,
            Mixer::Spm { op, .. } => op.spec.n,
        }
    }

    pub fn param_count(&self) -> usize {
        match self {
            Mixer::Dense { layer, .. } => layer.param_count(),
            Mixer::Spm { op, params, .. } => op.param_count(params),
        }
    }

    pub fn forward(&self, x: &Mat) -> Mat {
        match self {
            Mixer::Dense { layer, .. } => layer.forward(x),
            Mixer::Spm { op, params, .. } => op.forward(params, x),
        }
    }

    pub fn forward_trace(&self, x: &Mat) -> (Mat, MixTrace) {
        match self {
            Mixer::Dense { layer, .. } => (layer.forward(x), MixTrace::Dense),
            Mixer::Spm { op, params, .. } => {
                let (y, t) = op.forward_trace(params, x);
                (y, MixTrace::Spm(t))
            }
        }
    }

    pub fn backward(&self, x: &Mat, trace: &MixTrace, gy: &Mat) -> (Mat, MixGrads) {
        match (self, trace) {
            (Mixer::Dense { layer, .. }, MixTrace::Dense) => {
                let (gx, g) = layer.backward(x, gy);
                (gx, MixGrads::Dense(g))
            }
            (Mixer::Spm { op, params, .. }, MixTrace::Spm(t)) => {
                let (gx, g) = op.backward(params, x, t, gy);
                (gx, MixGrads::Spm(g))
            }
            _ => panic!("trace/mixer kind mismatch"),
        }
    }

    /// Apply an Adam update from accumulated gradients.
    pub fn update(&mut self, adam: &mut Adam, grads: &MixGrads) {
        match (self, grads) {
            (Mixer::Dense { layer, slots }, MixGrads::Dense(g)) => {
                adam.update(slots[0], &mut layer.w.data, &g.w.data);
                adam.update(slots[1], &mut layer.b, &g.b);
            }
            (Mixer::Spm { params, slots, .. }, MixGrads::Spm(g)) => {
                adam.update(slots[0], &mut params.d_in, &g.d_in);
                adam.update(slots[1], &mut params.d_out, &g.d_out);
                adam.update(slots[2], &mut params.bias, &g.bias);
                for (i, m) in params.mix.iter_mut().enumerate() {
                    adam.update(slots[3 + i], m, &g.mix[i]);
                }
                let last = *slots.last().unwrap();
                adam.update(last, &mut params.lone, &g.lone);
            }
            _ => panic!("grads/mixer kind mismatch"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn both_kinds_round_trip() {
        for kind in [MixerKind::Dense, MixerKind::Spm] {
            let cfg = MixerCfg { kind, ..MixerCfg::spm(16, Variant::General) };
            let mut adam = Adam::new(1e-3);
            let mut rng = Rng::new(1);
            let mx = Mixer::new(cfg, &mut rng, &mut adam);
            let x = Mat::from_vec(4, 16, rng.normal_vec(64, 1.0));
            let (y, trace) = mx.forward_trace(&x);
            assert_eq!((y.rows, y.cols), (4, 16));
            let (gx, _g) = mx.backward(&x, &trace, &y);
            assert_eq!((gx.rows, gx.cols), (4, 16));
        }
    }

    #[test]
    fn update_changes_parameters_toward_lower_loss() {
        let cfg = MixerCfg::spm(8, Variant::General).with_schedule(Schedule::Shift);
        let mut adam = Adam::new(0.05);
        let mut rng = Rng::new(2);
        let mut mx = Mixer::new(cfg, &mut rng, &mut adam);
        let x = Mat::from_vec(16, 8, rng.normal_vec(128, 1.0));
        // target: zero output => loss = mean(y^2)
        let loss_of = |mx: &Mixer| {
            let y = mx.forward(&x);
            y.data.iter().map(|v| v * v).sum::<f32>() / y.data.len() as f32
        };
        let before = loss_of(&mx);
        for _ in 0..30 {
            let (y, trace) = mx.forward_trace(&x);
            let mut gy = y;
            let n = gy.data.len() as f32;
            for v in gy.data.iter_mut() {
                *v = 2.0 * *v / n;
            }
            let (_gx, grads) = mx.backward(&x, &trace, &gy);
            adam.next_step();
            mx.update(&mut adam, &grads);
        }
        let after = loss_of(&mx);
        assert!(after < before * 0.5, "{before} -> {after}");
    }

    #[test]
    fn spm_param_count_below_dense() {
        let mut adam = Adam::new(1e-3);
        let mut rng = Rng::new(3);
        let d = Mixer::new(MixerCfg::dense(128), &mut rng, &mut adam);
        let s = Mixer::new(MixerCfg::spm(128, Variant::General), &mut rng, &mut adam);
        assert!(s.param_count() < d.param_count() / 4);
    }
}
