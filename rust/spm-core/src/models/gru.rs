//! GRU with SPM-replaceable square maps (paper §6) and exact BPTT.
//!
//! All six maps W_z, U_z, W_r, U_r, W_h, U_h are [`LinearOp`]s (dense or
//! SPM, §6.2); the backward pass is the paper's §6.3-§6.4 chain: eqs.
//! (24)-(28) for the gate Jacobians composed with each op's exact
//! backward. BPTT gradient accumulation across timesteps falls out of the
//! ops' flat gradient buffers: `backward` sums in place, `apply_grads`
//! consumes the total.

use crate::loss::softmax_xent;
use crate::ops::{LinearCfg, LinearOp, LinearTrace, SpmExec};
use crate::optim::Adam;
use crate::rng::Rng;
use crate::tensor::{col_sum, Mat};

use super::api::{Model, ModelKind, Target};

fn sigmoid(v: f32) -> f32 {
    1.0 / (1.0 + (-v).exp())
}

fn ew(a: &Mat, b: &Mat, f: impl Fn(f32, f32) -> f32) -> Mat {
    let mut out = a.clone();
    for (o, bv) in out.data.iter_mut().zip(&b.data) {
        *o = f(*o, *bv);
    }
    out
}

struct StepTrace {
    h_prev: Mat,
    z: Mat,
    r: Mat,
    h_tilde: Mat,
    u: Mat, // r * h_prev
    x_t: Mat,
    traces: [LinearTrace; 6], // wz, uz, wr, ur, wh, uh
}

fn empty_mat() -> Mat {
    Mat { rows: 0, cols: 0, data: Vec::new() }
}

/// Reusable buffers for the trace-free forward cell (serving path,
/// DESIGN.md §15): `h` carries the hidden state across timesteps, the
/// rest are per-step intermediates reshaped in place.
struct FwdScratch {
    h: Mat,
    x_t: Mat,
    a: Mat, // W·x map output
    b: Mat, // U·h map output
    z: Mat,
    r: Mat,
    u: Mat,  // r * h_prev
    ht: Mat, // candidate h_tilde
}

impl FwdScratch {
    fn new() -> Self {
        FwdScratch {
            h: empty_mat(),
            x_t: empty_mat(),
            a: empty_mat(),
            b: empty_mat(),
            z: empty_mat(),
            r: empty_mat(),
            u: empty_mat(),
            ht: empty_mat(),
        }
    }
}

pub struct Gru {
    pub n: usize,
    pub maps: [LinearOp; 6], // wz, uz, wr, ur, wh, uh
    pub b_z: Vec<f32>,
    pub b_r: Vec<f32>,
    pub b_h: Vec<f32>,
    pub head: LinearOp,
    bias_slots: [usize; 3],
    // persistent gate-bias gradient accumulators (the biases are not
    // LinearOps, so BPTT accumulation and the data-parallel all-reduce
    // need their gradients to live on the model like the ops' do)
    gb_z: Vec<f32>,
    gb_r: Vec<f32>,
    gb_h: Vec<f32>,
    pub adam: Adam,
    fwd: FwdScratch,
}

impl Gru {
    pub fn new(cfg: LinearCfg, num_classes: usize, lr: f32, seed: u64) -> Self {
        let mut adam = Adam::new(lr);
        let mut rng = Rng::new(seed);
        let n = cfg.n();
        let maps = std::array::from_fn(|i| {
            LinearOp::new(cfg.with_seed(cfg.seed + i as u64), &mut rng, &mut adam)
        });
        let b_z = vec![0.0; n];
        let b_r = vec![0.0; n];
        let b_h = vec![0.0; n];
        let bias_slots = [adam.register(n), adam.register(n), adam.register(n)];
        let head = LinearOp::new(LinearCfg::dense_rect(num_classes, n), &mut rng, &mut adam);
        Gru {
            n,
            maps,
            b_z,
            b_r,
            b_h,
            head,
            bias_slots,
            gb_z: vec![0.0; n],
            gb_r: vec![0.0; n],
            gb_h: vec![0.0; n],
            adam,
            fwd: FwdScratch::new(),
        }
    }

    pub fn param_count(&self) -> usize {
        self.maps.iter().map(|m| m.param_count()).sum::<usize>()
            + 3 * self.n
            + self.head.param_count()
    }

    fn cell(&self, h_prev: &Mat, x_t: &Mat) -> (Mat, StepTrace) {
        let (wz_x, t0) = self.maps[0].forward_train(x_t);
        let (uz_h, t1) = self.maps[1].forward_train(h_prev);
        let mut z = ew(&wz_x, &uz_h, |a, b| a + b);
        for (v, b) in z.data.iter_mut().zip(self.b_z.iter().cycle()) {
            *v = sigmoid(*v + b); // eq. (20)
        }
        let (wr_x, t2) = self.maps[2].forward_train(x_t);
        let (ur_h, t3) = self.maps[3].forward_train(h_prev);
        let mut r = ew(&wr_x, &ur_h, |a, b| a + b);
        for (v, b) in r.data.iter_mut().zip(self.b_r.iter().cycle()) {
            *v = sigmoid(*v + b); // eq. (21)
        }
        let u = ew(&r, h_prev, |a, b| a * b);
        let (wh_x, t4) = self.maps[4].forward_train(x_t);
        let (uh_u, t5) = self.maps[5].forward_train(&u);
        let mut h_tilde = ew(&wh_x, &uh_u, |a, b| a + b);
        for (v, b) in h_tilde.data.iter_mut().zip(self.b_h.iter().cycle()) {
            *v = (*v + b).tanh(); // eq. (22)
        }
        // eq. (23)
        let mut h = h_prev.clone();
        for i in 0..h.data.len() {
            h.data[i] = (1.0 - z.data[i]) * h_prev.data[i] + z.data[i] * h_tilde.data[i];
        }
        let trace = StepTrace {
            h_prev: h_prev.clone(),
            z,
            r,
            h_tilde,
            u,
            x_t: x_t.clone(),
            traces: [t0, t1, t2, t3, t4, t5],
        };
        (h, trace)
    }

    /// Final-hidden-state classification logits. `xs` is T timestep
    /// matrices of shape (B, n).
    pub fn logits(&self, xs: &[Mat]) -> Mat {
        let b = xs[0].rows;
        let mut h = Mat::zeros(b, self.n);
        for x_t in xs {
            let (next, _) = self.cell(&h, x_t);
            h = next;
        }
        self.head.forward(&h)
    }

    /// One trace-free cell step: advances `self.fwd.h` reading
    /// `self.fwd.x_t`. Arithmetic order matches [`Gru::cell`] exactly so
    /// serving and training forwards agree bit-for-bit.
    fn step_forward_only(&mut self) {
        let s = &mut self.fwd;
        // eq. (20): z = sigmoid(W_z x + U_z h + b_z)
        self.maps[0].forward_into(&s.x_t, &mut s.a);
        self.maps[1].forward_into(&s.h, &mut s.b);
        s.z.rows = s.a.rows;
        s.z.cols = s.a.cols;
        s.z.data.clear();
        s.z.data.extend_from_slice(&s.a.data);
        for ((v, bv), bias) in s.z.data.iter_mut().zip(&s.b.data).zip(self.b_z.iter().cycle()) {
            *v = sigmoid(*v + bv + bias);
        }
        // eq. (21): r = sigmoid(W_r x + U_r h + b_r)
        self.maps[2].forward_into(&s.x_t, &mut s.a);
        self.maps[3].forward_into(&s.h, &mut s.b);
        s.r.rows = s.a.rows;
        s.r.cols = s.a.cols;
        s.r.data.clear();
        s.r.data.extend_from_slice(&s.a.data);
        for ((v, bv), bias) in s.r.data.iter_mut().zip(&s.b.data).zip(self.b_r.iter().cycle()) {
            *v = sigmoid(*v + bv + bias);
        }
        // u = r * h_prev
        s.u.rows = s.r.rows;
        s.u.cols = s.r.cols;
        s.u.data.clear();
        s.u.data.extend_from_slice(&s.r.data);
        for (v, hv) in s.u.data.iter_mut().zip(&s.h.data) {
            *v *= hv;
        }
        // eq. (22): h_tilde = tanh(W_h x + U_h u + b_h)
        self.maps[4].forward_into(&s.x_t, &mut s.a);
        self.maps[5].forward_into(&s.u, &mut s.b);
        s.ht.rows = s.a.rows;
        s.ht.cols = s.a.cols;
        s.ht.data.clear();
        s.ht.data.extend_from_slice(&s.a.data);
        for ((v, bv), bias) in s.ht.data.iter_mut().zip(&s.b.data).zip(self.b_h.iter().cycle()) {
            *v = (*v + bv + bias).tanh();
        }
        // eq. (23): h = (1 - z) * h_prev + z * h_tilde, in place
        for i in 0..s.h.data.len() {
            s.h.data[i] = (1.0 - s.z.data[i]) * s.h.data[i] + s.z.data[i] * s.ht.data[i];
        }
    }

    /// [`Gru::logits`] over `(B, T*n)` concatenated rows through the
    /// model-owned scratch: zero steady-state allocations for a stable
    /// batch shape (the serving hot path).
    pub fn logits_concat_into(&mut self, x: &Mat, seq_len: usize, out: &mut Mat) {
        let n = self.n;
        assert_eq!(x.cols, seq_len * n, "row must hold T={seq_len} timesteps of width {n}");
        {
            let s = &mut self.fwd;
            s.h.rows = x.rows;
            s.h.cols = n;
            s.h.data.clear();
            s.h.data.resize(x.rows * n, 0.0);
        }
        for t in 0..seq_len {
            let s = &mut self.fwd;
            s.x_t.rows = x.rows;
            s.x_t.cols = n;
            s.x_t.data.clear();
            for bi in 0..x.rows {
                s.x_t.data.extend_from_slice(&x.row(bi)[t * n..(t + 1) * n]);
            }
            self.step_forward_only();
        }
        self.head.forward_into(&self.fwd.h, out);
    }

    pub fn evaluate(&self, xs: &[Mat], y: &[u32]) -> (f32, f32) {
        let logits = self.logits(xs);
        let (l, a, _g) = softmax_xent(&logits, y);
        (l, a)
    }

    /// Forward + exact BPTT backward only: map gradients accumulate in
    /// each op's flat buffer and gate-bias gradients in the model's
    /// persistent accumulators; the optimizer does not fire.
    pub fn accumulate_step(&mut self, xs: &[Mat], y: &[u32]) -> (f32, f32) {
        let b = xs[0].rows;
        let mut h = Mat::zeros(b, self.n);
        let mut steps = Vec::with_capacity(xs.len());
        for x_t in xs {
            let (next, tr) = self.cell(&h, x_t);
            steps.push(tr);
            h = next;
        }
        let (logits, head_tr) = self.head.forward_train(&h);
        let (loss, acc, glogits) = softmax_xent(&logits, y);
        let mut g_h = self.head.backward(&h, &head_tr, &glogits);

        for st in steps.iter().rev() {
            // eqs. (24)-(26)
            let g_z = Mat::from_vec(
                b,
                self.n,
                (0..g_h.data.len())
                    .map(|i| g_h.data[i] * (st.h_tilde.data[i] - st.h_prev.data[i]))
                    .collect(),
            );
            let g_htilde = ew(&g_h, &st.z, |g, z| g * z);
            let mut g_hprev = Mat::from_vec(
                b,
                self.n,
                (0..g_h.data.len())
                    .map(|i| g_h.data[i] * (1.0 - st.z.data[i]))
                    .collect(),
            );
            // candidate: g_a = g_htilde * (1 - htilde^2)
            let g_a = ew(&g_htilde, &st.h_tilde, |g, t| g * (1.0 - t * t));
            for (s, v) in self.gb_h.iter_mut().zip(col_sum(&g_a)) {
                *s += v;
            }
            // map gradients accumulate inside each op's flat buffer
            let _gx_wh = self.maps[4].backward(&st.x_t, &st.traces[4], &g_a);
            let g_u = self.maps[5].backward(&st.u, &st.traces[5], &g_a);
            // u = r * h_prev
            let g_r = ew(&g_u, &st.h_prev, |g, h| g * h);
            for i in 0..g_hprev.data.len() {
                g_hprev.data[i] += g_u.data[i] * st.r.data[i];
            }
            // gates: eqs. (27)-(28)
            let g_sz = ew(&g_z, &st.z, |g, z| g * z * (1.0 - z));
            let g_sr = ew(&g_r, &st.r, |g, r| g * r * (1.0 - r));
            for (s, v) in self.gb_z.iter_mut().zip(col_sum(&g_sz)) {
                *s += v;
            }
            for (s, v) in self.gb_r.iter_mut().zip(col_sum(&g_sr)) {
                *s += v;
            }
            let _gx_wz = self.maps[0].backward(&st.x_t, &st.traces[0], &g_sz);
            let gh_uz = self.maps[1].backward(&st.h_prev, &st.traces[1], &g_sz);
            let _gx_wr = self.maps[2].backward(&st.x_t, &st.traces[2], &g_sr);
            let gh_ur = self.maps[3].backward(&st.h_prev, &st.traces[3], &g_sr);
            for i in 0..g_hprev.data.len() {
                g_hprev.data[i] += gh_uz.data[i] + gh_ur.data[i];
            }
            g_h = g_hprev;
        }
        (loss, acc)
    }

    /// One flat Adam step from the accumulated map + bias gradients,
    /// then clear them (same update order as the pre-split train_step).
    pub fn apply_step(&mut self) {
        self.adam.next_step();
        for m in self.maps.iter_mut() {
            m.apply_grads(&mut self.adam);
        }
        self.head.apply_grads(&mut self.adam);
        let [s0, s1, s2] = self.bias_slots;
        self.adam.update(s0, &mut self.b_z, &self.gb_z);
        self.adam.update(s1, &mut self.b_r, &self.gb_r);
        self.adam.update(s2, &mut self.b_h, &self.gb_h);
        self.gb_z.fill(0.0);
        self.gb_r.fill(0.0);
        self.gb_h.fill(0.0);
    }

    /// Clear every gradient accumulator (maps, head, gate biases).
    pub fn zero_grads(&mut self) {
        for m in self.maps.iter_mut() {
            m.zero_grads();
        }
        self.head.zero_grads();
        self.gb_z.fill(0.0);
        self.gb_r.fill(0.0);
        self.gb_h.fill(0.0);
    }

    /// One BPTT training step; returns (loss, accuracy).
    pub fn train_step(&mut self, xs: &[Mat], y: &[u32]) -> (f32, f32) {
        self.zero_grads();
        let lm = self.accumulate_step(xs, y);
        self.apply_step();
        lm
    }
}

/// [`Model`]-shaped view of the GRU sequence classifier: one request row
/// is the WHOLE sequence with timesteps concatenated
/// `[x_1 | x_2 | .. | x_T]`, so `d_in = seq_len * n` and the serving
/// engine can route flat feature rows to it like to any other model.
pub struct GruSeq {
    pub gru: Gru,
    pub seq_len: usize,
}

impl GruSeq {
    pub fn new(cfg: LinearCfg, classes: usize, seq_len: usize, lr: f32, seed: u64) -> Self {
        assert!(seq_len >= 1, "seq_len must be >= 1");
        GruSeq { gru: Gru::new(cfg, classes, lr, seed), seq_len }
    }

    /// `(B, T*n)` concatenated rows -> the T `(B, n)` timestep matrices
    /// the BPTT core consumes.
    fn split_steps(&self, x: &Mat) -> Vec<Mat> {
        let n = self.gru.n;
        assert_eq!(
            x.cols,
            self.seq_len * n,
            "row must hold T={} timesteps of width {n}",
            self.seq_len
        );
        (0..self.seq_len)
            .map(|t| Mat::from_fn(x.rows, n, |b, j| x.at(b, t * n + j)))
            .collect()
    }
}

impl Model for GruSeq {
    fn kind(&self) -> ModelKind {
        ModelKind::Gru
    }

    fn d_in(&self) -> usize {
        self.seq_len * self.gru.n
    }

    fn d_out(&self) -> usize {
        self.gru.head.d_out()
    }

    fn param_count(&self) -> usize {
        self.gru.param_count()
    }

    fn forward(&self, x: &Mat) -> Mat {
        self.gru.logits(&self.split_steps(x))
    }

    fn forward_into(&mut self, x: &Mat, out: &mut Mat) {
        self.gru.logits_concat_into(x, self.seq_len, out);
    }

    fn accumulate_step(&mut self, x: &Mat, target: &Target) -> (f32, f32) {
        let Target::Labels(y) = target else { panic!("gru trains on class labels") };
        let steps = self.split_steps(x);
        self.gru.accumulate_step(&steps, y)
    }

    fn apply_step(&mut self) {
        self.gru.apply_step()
    }

    fn zero_grads(&mut self) {
        self.gru.zero_grads()
    }

    fn evaluate(&self, x: &Mat, target: &Target) -> (f32, f32) {
        let Target::Labels(y) = target else { panic!("gru evaluates on class labels") };
        self.gru.evaluate(&self.split_steps(x), y)
    }

    fn set_exec(&mut self, exec: SpmExec) {
        for m in self.gru.maps.iter_mut() {
            m.set_exec(exec);
        }
        self.gru.head.set_exec(exec);
    }

    fn visit_params(&self, f: &mut dyn FnMut(&str, &[f32])) {
        for (name, m) in ["wz", "uz", "wr", "ur", "wh", "uh"].iter().zip(&self.gru.maps) {
            f(name, m.params());
        }
        f("b_z", &self.gru.b_z);
        f("b_r", &self.gru.b_r);
        f("b_h", &self.gru.b_h);
        f("head", self.gru.head.params());
    }

    fn visit_params_mut(&mut self, f: &mut dyn FnMut(&str, &mut [f32])) {
        let maps = self.gru.maps.iter_mut();
        for (name, m) in ["wz", "uz", "wr", "ur", "wh", "uh"].iter().zip(maps) {
            f(name, m.params_mut());
        }
        f("b_z", &mut self.gru.b_z);
        f("b_r", &mut self.gru.b_r);
        f("b_h", &mut self.gru.b_h);
        f("head", self.gru.head.params_mut());
    }

    fn visit_grads(&self, f: &mut dyn FnMut(&str, &[f32])) {
        for (name, m) in ["wz", "uz", "wr", "ur", "wh", "uh"].iter().zip(&self.gru.maps) {
            f(name, m.grads());
        }
        f("b_z", &self.gru.gb_z);
        f("b_r", &self.gru.gb_r);
        f("b_h", &self.gru.gb_h);
        f("head", self.gru.head.grads());
    }

    fn visit_grads_mut(&mut self, f: &mut dyn FnMut(&str, &mut [f32])) {
        let maps = self.gru.maps.iter_mut();
        for (name, m) in ["wz", "uz", "wr", "ur", "wh", "uh"].iter().zip(maps) {
            f(name, m.grads_mut());
        }
        f("b_z", &mut self.gru.gb_z);
        f("b_r", &mut self.gru.gb_r);
        f("b_h", &mut self.gru.gb_h);
        f("head", self.gru.head.grads_mut());
    }

    fn visit_ops(&self, f: &mut dyn FnMut(&LinearOp)) {
        for m in &self.gru.maps {
            f(m);
        }
        f(&self.gru.head);
    }

    fn flops_per_row(&self) -> u64 {
        // the six gate maps run once per timestep; the head reads out the
        // final hidden state once per row
        let mut gates = 0u64;
        for m in &self.gru.maps {
            gates += m.flops_per_row();
        }
        self.seq_len as u64 * gates + self.gru.head.flops_per_row()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pairing::Schedule;
    use crate::spm::Variant;

    /// learnable sequence task: class = argmax of the mean input over time
    fn seq_problem(n: usize, c: usize, b: usize, t: usize, seed: u64) -> (Vec<Mat>, Vec<u32>) {
        let mut rng = Rng::new(seed);
        let xs: Vec<Mat> =
            (0..t).map(|_| Mat::from_vec(b, n, rng.normal_vec(b * n, 1.0))).collect();
        let mut labels = Vec::with_capacity(b);
        for i in 0..b {
            let mut sums = vec![0.0f32; c];
            for x in &xs {
                for (j, s) in sums.iter_mut().enumerate() {
                    *s += x.at(i, j);
                }
            }
            let mut best = 0;
            for j in 1..c {
                if sums[j] > sums[best] {
                    best = j;
                }
            }
            labels.push(best as u32);
        }
        (xs, labels)
    }

    #[test]
    fn dense_gru_learns() {
        let (xs, y) = seq_problem(12, 3, 64, 4, 1);
        let mut gru = Gru::new(LinearCfg::dense(12), 3, 5e-3, 2);
        let first = gru.train_step(&xs, &y).0;
        let mut last = first;
        for _ in 0..60 {
            last = gru.train_step(&xs, &y).0;
        }
        assert!(last < first * 0.7, "{first} -> {last}");
    }

    #[test]
    fn spm_gru_learns() {
        let cfg = LinearCfg::spm(12, Variant::Rotation).with_schedule(Schedule::Shift);
        let (xs, y) = seq_problem(12, 3, 64, 4, 3);
        let mut gru = Gru::new(cfg, 3, 5e-3, 4);
        let first = gru.train_step(&xs, &y).0;
        let mut last = first;
        for _ in 0..60 {
            last = gru.train_step(&xs, &y).0;
        }
        assert!(last < first * 0.8, "{first} -> {last}");
    }

    #[test]
    fn serving_forward_into_matches_forward() {
        let cfg = LinearCfg::spm(8, Variant::General).with_schedule(Schedule::Shift);
        let mut m = GruSeq::new(cfg, 3, 4, 1e-3, 21);
        let mut rng = Rng::new(22);
        let x = Mat::from_vec(5, 4 * 8, rng.normal_vec(5 * 4 * 8, 1.0));
        let want = m.forward(&x);
        let mut got = Mat::zeros(0, 0);
        m.forward_into(&x, &mut got);
        assert_eq!(want, got);
        // second call reuses the scratch and must stay bit-identical
        m.forward_into(&x, &mut got);
        assert_eq!(want, got);
    }

    fn set_wz00(gru: &mut Gru, v: f32) -> f32 {
        // W_z is a dense LinearOp: flat layout [w (n*n) | b (n)], w[0] first
        let old = gru.maps[0].params()[0];
        gru.maps[0].params_mut()[0] = v;
        old
    }

    #[test]
    fn bptt_gradient_matches_finite_difference() {
        // End-to-end FD check through 3 timesteps of a dense GRU on W_z[0,0].
        // The analytic gradient is extracted by running one SGD-like probe:
        // loss(w + eps) - loss(w - eps) ≈ 2 eps * dL/dw.
        let (xs, y) = seq_problem(6, 2, 8, 3, 5);
        let mut gru = Gru::new(LinearCfg::dense(6), 2, 1e-3, 7);
        let eps = 1e-2f32;
        let orig = set_wz00(&mut gru, 0.0);
        set_wz00(&mut gru, orig); // restore; we only wanted to read it
        set_wz00(&mut gru, orig + eps);
        let up = gru.evaluate(&xs, &y).0;
        set_wz00(&mut gru, orig - eps);
        let down = gru.evaluate(&xs, &y).0;
        set_wz00(&mut gru, orig);
        let num = (up - down) / (2.0 * eps);
        // validate against a half-step FD (consistency of the loss surface)
        // and against descent direction: a tiny SGD move along -num must
        // reduce the loss.
        set_wz00(&mut gru, orig + eps / 2.0);
        let up2 = gru.evaluate(&xs, &y).0;
        set_wz00(&mut gru, orig - eps / 2.0);
        let down2 = gru.evaluate(&xs, &y).0;
        set_wz00(&mut gru, orig);
        let num2 = (up2 - down2) / eps;
        assert!((num - num2).abs() < 0.1 * (1.0f32.max(num.abs())),
                "FD unstable: {num} vs {num2}");
        let base = gru.evaluate(&xs, &y).0;
        set_wz00(&mut gru, orig - 0.05 * num.signum());
        let moved = gru.evaluate(&xs, &y).0;
        set_wz00(&mut gru, orig);
        if num.abs() > 1e-3 {
            assert!(moved <= base + 1e-4, "moving against FD grad increased loss");
        }
    }

    #[test]
    fn training_actually_descends_along_analytic_gradient() {
        // the real gradient check: one tiny-lr Adam step must reduce loss
        let (xs, y) = seq_problem(8, 2, 32, 3, 9);
        for cfg in [
            LinearCfg::dense(8),
            LinearCfg::spm(8, Variant::General).with_schedule(Schedule::Shift),
        ] {
            let mut gru = Gru::new(cfg, 2, 1e-3, 11);
            let l0 = gru.evaluate(&xs, &y).0;
            let mut l = l0;
            for _ in 0..20 {
                l = gru.train_step(&xs, &y).0;
            }
            assert!(l < l0, "loss did not decrease: {l0} -> {l}");
        }
    }

    #[test]
    fn bptt_accumulates_then_clears_map_grads() {
        let (xs, y) = seq_problem(6, 2, 8, 3, 13);
        let mut gru = Gru::new(LinearCfg::dense(6), 2, 1e-3, 15);
        gru.train_step(&xs, &y);
        // apply_grads cleared every op's accumulator
        for m in &gru.maps {
            assert!(m.grads().iter().all(|&g| g == 0.0));
        }
        assert!(gru.head.grads().iter().all(|&g| g == 0.0));
    }
}
