//! Char-level LM (paper §9.3): embed -> LinearOp(d->d) -> ReLU -> LinearOp
//! vocab head. Next-byte prediction with softmax-xent; NLL reported in
//! nats, BPC = NLL/ln2. Exact backward including the embedding
//! scatter-add. The embedding is a lookup table, not a linear map, so it
//! keeps its own flat Adam slot next to the two LinearOps.

use crate::loss::{softmax_xent, softmax_xent_into};
use crate::ops::{LinearCfg, LinearOp, LinearTrace, SpmExec};
use crate::optim::Adam;
use crate::rng::Rng;
use crate::tensor::Mat;

use super::api::{Model, ModelKind, Target};

pub const VOCAB: usize = 256;

fn empty_mat() -> Mat {
    Mat { rows: 0, cols: 0, data: Vec::new() }
}

/// Reusable activation/trace/token buffers (DESIGN.md §15), reshaped in
/// place each call so steady-state serving and training allocate nothing.
struct Scratch {
    tokens: Vec<u8>,
    targets: Vec<u8>,
    labels: Vec<u32>,
    h0: Mat,
    h_pre: Mat,
    h: Mat,
    mix_tr: LinearTrace,
    logits: Mat,
    head_tr: LinearTrace,
    glogits: Mat,
    gh: Mat,
    gx: Mat,
}

impl Scratch {
    fn new() -> Self {
        Scratch {
            tokens: Vec::new(),
            targets: Vec::new(),
            labels: Vec::new(),
            h0: empty_mat(),
            h_pre: empty_mat(),
            h: empty_mat(),
            mix_tr: LinearTrace::Dense,
            logits: empty_mat(),
            head_tr: LinearTrace::Dense,
            glogits: empty_mat(),
            gh: empty_mat(),
            gx: empty_mat(),
        }
    }
}

pub struct CharLM {
    pub d: usize,
    pub embed: Mat, // (VOCAB, d)
    pub mixer: LinearOp,
    pub head: LinearOp, // d -> VOCAB
    embed_slot: usize,
    // persistent embedding-gradient accumulator (the lookup table is not
    // a LinearOp, so the data-parallel all-reduce needs its gradient to
    // live on the model like the ops' flat buffers do)
    gembed: Vec<f32>,
    pub adam: Adam,
    scratch: Scratch,
}

impl CharLM {
    pub fn new(cfg: LinearCfg, lr: f32, seed: u64) -> Self {
        let mut adam = Adam::new(lr);
        let mut rng = Rng::new(seed);
        let d = cfg.n();
        let mixer = LinearOp::new(cfg, &mut rng, &mut adam);
        let embed = Mat::from_vec(VOCAB, d, rng.normal_vec(VOCAB * d, 0.02));
        let head = LinearOp::new(LinearCfg::dense_rect(VOCAB, d), &mut rng, &mut adam);
        let embed_slot = adam.register(embed.data.len());
        let gembed = vec![0.0; VOCAB * d];
        CharLM { d, embed, mixer, head, embed_slot, gembed, adam, scratch: Scratch::new() }
    }

    pub fn param_count(&self) -> usize {
        self.embed.data.len() + self.mixer.param_count() + self.head.param_count()
    }

    fn embed_tokens(&self, tokens: &[u8]) -> Mat {
        let mut h = empty_mat();
        embed_tokens_into(&self.embed, self.d, tokens, &mut h);
        h
    }

    /// Next-byte logits for a flat token stream: one row of `VOCAB`
    /// logits per input token (the model is per-token, so this IS the
    /// batched forward the serving engine drives).
    pub fn logits(&self, tokens: &[u8]) -> Mat {
        let h0 = self.embed_tokens(tokens);
        let mut h = self.mixer.forward(&h0);
        for v in h.data.iter_mut() {
            *v = v.max(0.0);
        }
        self.head.forward(&h)
    }

    /// [`CharLM::logits`] through the model-owned scratch: zero
    /// steady-state allocations for a stable token-stream length.
    pub fn logits_into(&mut self, tokens: &[u8], out: &mut Mat) {
        let s = &mut self.scratch;
        embed_tokens_into(&self.embed, self.d, tokens, &mut s.h0);
        self.mixer.forward_into(&s.h0, &mut s.h);
        for v in s.h.data.iter_mut() {
            *v = v.max(0.0);
        }
        self.head.forward_into(&s.h, out);
    }

    /// Mean NLL (nats) of next-byte prediction; inputs/targets are flat
    /// (B*T) token streams with `targets[i]` the byte following `inputs[i]`.
    pub fn evaluate(&self, inputs: &[u8], targets: &[u8]) -> f32 {
        let logits = self.logits(inputs);
        let labels: Vec<u32> = targets.iter().map(|&t| t as u32).collect();
        softmax_xent(&logits, &labels).0
    }

    /// Forward + backward only: op gradients accumulate in their flat
    /// buffers and the embedding scatter-add in the model's persistent
    /// accumulator; the optimizer does not fire.
    pub fn accumulate_step(&mut self, inputs: &[u8], targets: &[u8]) -> (f32, f32) {
        assert_eq!(inputs.len(), targets.len());
        // forward (all intermediates live in the model-owned scratch)
        let s = &mut self.scratch;
        embed_tokens_into(&self.embed, self.d, inputs, &mut s.h0);
        self.mixer.forward_train_into(&s.h0, &mut s.h_pre, &mut s.mix_tr);
        s.h.rows = s.h_pre.rows;
        s.h.cols = s.h_pre.cols;
        s.h.data.clear();
        s.h.data.extend_from_slice(&s.h_pre.data);
        for v in s.h.data.iter_mut() {
            *v = v.max(0.0);
        }
        self.head.forward_train_into(&s.h, &mut s.logits, &mut s.head_tr);
        s.labels.clear();
        s.labels.extend(targets.iter().map(|&t| t as u32));
        let (loss, acc) = softmax_xent_into(&s.logits, &s.labels, &mut s.glogits);

        self.head.backward_into(&s.h, &s.head_tr, &s.glogits, &mut s.gh);
        for (g, pre) in s.gh.data.iter_mut().zip(&s.h_pre.data) {
            if *pre <= 0.0 {
                *g = 0.0;
            }
        }
        self.mixer.backward_into(&s.h0, &s.mix_tr, &s.gh, &mut s.gx);

        // embedding scatter-add
        for (i, &t) in inputs.iter().enumerate() {
            let dst = &mut self.gembed[t as usize * self.d..(t as usize + 1) * self.d];
            for (dv, sv) in dst.iter_mut().zip(s.gx.row(i)) {
                *dv += sv;
            }
        }
        (loss, acc)
    }

    /// One flat Adam step from the accumulated gradients, then clear them.
    pub fn apply_step(&mut self) {
        self.adam.next_step();
        self.mixer.apply_grads(&mut self.adam);
        self.head.apply_grads(&mut self.adam);
        self.adam.update(self.embed_slot, &mut self.embed.data, &self.gembed);
        self.gembed.fill(0.0);
    }

    /// Clear every gradient accumulator (ops + embedding table).
    pub fn zero_grads(&mut self) {
        self.mixer.zero_grads();
        self.head.zero_grads();
        self.gembed.fill(0.0);
    }

    /// One training step over a flat (B*T) token batch; returns
    /// (mean NLL, next-byte accuracy).
    pub fn train_step(&mut self, inputs: &[u8], targets: &[u8]) -> (f32, f32) {
        self.zero_grads();
        let lm = self.accumulate_step(inputs, targets);
        self.apply_step();
        lm
    }
}

/// Token-stream embedding lookup into a caller-owned matrix (free
/// function so callers can borrow the table while holding model scratch).
fn embed_tokens_into(embed: &Mat, d: usize, tokens: &[u8], h: &mut Mat) {
    h.rows = tokens.len();
    h.cols = d;
    h.data.clear();
    h.data.resize(tokens.len() * d, 0.0);
    for (i, &t) in tokens.iter().enumerate() {
        h.row_mut(i).copy_from_slice(embed.row(t as usize));
    }
}

/// `(B, 1)` request rows of f32 byte values -> flat token stream. The
/// serving contract is all-f32 feature rows; values are rounded and
/// clamped into the byte vocabulary.
fn row_tokens(x: &Mat) -> Vec<u8> {
    let mut out = Vec::new();
    row_tokens_into(x, &mut out);
    out
}

/// [`row_tokens`] into a caller-owned buffer.
fn row_tokens_into(x: &Mat, out: &mut Vec<u8>) {
    assert_eq!(x.cols, 1, "charlm request rows carry exactly one token");
    out.clear();
    out.extend(x.data.iter().map(|&v| v.round().clamp(0.0, 255.0) as u8));
}

impl Model for CharLM {
    fn kind(&self) -> ModelKind {
        ModelKind::CharLm
    }

    fn d_in(&self) -> usize {
        1
    }

    fn d_out(&self) -> usize {
        VOCAB
    }

    fn param_count(&self) -> usize {
        CharLM::param_count(self)
    }

    fn forward(&self, x: &Mat) -> Mat {
        self.logits(&row_tokens(x))
    }

    fn forward_into(&mut self, x: &Mat, out: &mut Mat) {
        // move the token buffer out of scratch so `logits_into` can borrow
        // the rest of the model mutably; moved back below (no allocation)
        let mut tokens = std::mem::take(&mut self.scratch.tokens);
        row_tokens_into(x, &mut tokens);
        self.logits_into(&tokens, out);
        self.scratch.tokens = tokens;
    }

    fn accumulate_step(&mut self, x: &Mat, target: &Target) -> (f32, f32) {
        let Target::Labels(y) = target else { panic!("charlm trains on next-byte labels") };
        let mut inputs = std::mem::take(&mut self.scratch.tokens);
        row_tokens_into(x, &mut inputs);
        let mut targets = std::mem::take(&mut self.scratch.targets);
        targets.clear();
        targets.extend(y.iter().map(|&t| u8::try_from(t).expect("charlm labels must be bytes")));
        let lm = CharLM::accumulate_step(self, &inputs, &targets);
        self.scratch.tokens = inputs;
        self.scratch.targets = targets;
        lm
    }

    fn apply_step(&mut self) {
        CharLM::apply_step(self)
    }

    fn zero_grads(&mut self) {
        CharLM::zero_grads(self)
    }

    fn evaluate(&self, x: &Mat, target: &Target) -> (f32, f32) {
        let Target::Labels(y) = target else { panic!("charlm evaluates on next-byte labels") };
        let logits = self.logits(&row_tokens(x));
        let (loss, acc, _g) = softmax_xent(&logits, y);
        (loss, acc)
    }

    fn set_exec(&mut self, exec: SpmExec) {
        self.mixer.set_exec(exec);
        self.head.set_exec(exec);
    }

    fn visit_params(&self, f: &mut dyn FnMut(&str, &[f32])) {
        f("embed", &self.embed.data);
        f("mixer", self.mixer.params());
        f("head", self.head.params());
    }

    fn visit_params_mut(&mut self, f: &mut dyn FnMut(&str, &mut [f32])) {
        f("embed", &mut self.embed.data);
        f("mixer", self.mixer.params_mut());
        f("head", self.head.params_mut());
    }

    fn visit_grads(&self, f: &mut dyn FnMut(&str, &[f32])) {
        f("embed", &self.gembed);
        f("mixer", self.mixer.grads());
        f("head", self.head.grads());
    }

    fn visit_grads_mut(&mut self, f: &mut dyn FnMut(&str, &mut [f32])) {
        f("embed", &mut self.gembed);
        f("mixer", self.mixer.grads_mut());
        f("head", self.head.grads_mut());
    }

    fn visit_ops(&self, f: &mut dyn FnMut(&LinearOp)) {
        f(&self.mixer);
        f(&self.head);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spm::Variant;

    fn periodic_stream(len: usize) -> Vec<u8> {
        // a trivially learnable byte sequence: "abcabcabc..."
        (0..len).map(|i| b'a' + (i % 3) as u8).collect()
    }

    #[test]
    fn learns_periodic_sequence_dense() {
        let stream = periodic_stream(257);
        let inputs = &stream[..256];
        let targets = &stream[1..257];
        let mut lm = CharLM::new(LinearCfg::dense(16), 3e-3, 1);
        let first = lm.train_step(inputs, targets).0;
        let mut last = first;
        for _ in 0..60 {
            last = lm.train_step(inputs, targets).0;
        }
        assert!(last < first * 0.3, "{first} -> {last}");
    }

    #[test]
    fn learns_periodic_sequence_spm() {
        let stream = periodic_stream(257);
        let inputs = &stream[..256];
        let targets = &stream[1..257];
        let mut lm = CharLM::new(LinearCfg::spm(16, Variant::Rotation), 3e-3, 2);
        let first = lm.train_step(inputs, targets).0;
        let mut last = first;
        for _ in 0..60 {
            last = lm.train_step(inputs, targets).0;
        }
        assert!(last < first * 0.5, "{first} -> {last}");
    }

    #[test]
    fn serving_forward_into_matches_forward() {
        let mut lm = CharLM::new(LinearCfg::spm(16, Variant::Rotation), 1e-3, 5);
        let stream = periodic_stream(32);
        let x = Mat::from_vec(32, 1, stream.iter().map(|&b| b as f32).collect());
        let want = Model::forward(&lm, &x);
        let mut got = Mat::zeros(0, 0);
        lm.forward_into(&x, &mut got);
        assert_eq!(want, got);
        // second call reuses the scratch and must stay bit-identical
        lm.forward_into(&x, &mut got);
        assert_eq!(want, got);
    }

    #[test]
    fn eval_uniform_initial_loss_near_log_vocab() {
        let lm = CharLM::new(LinearCfg::dense(8), 1e-3, 3);
        let stream = periodic_stream(65);
        let nll = lm.evaluate(&stream[..64], &stream[1..65]);
        // small-init network ~ uniform distribution over 256 bytes
        assert!((nll - (256.0f32).ln()).abs() < 1.0, "nll {nll}");
    }
}
