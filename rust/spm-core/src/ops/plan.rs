//! The SPM execution plan (DESIGN.md §3): everything about an SPM operator
//! that does NOT change during training, computed once at construction.
//!
//! * a **stage-major pairing table** — one flat interleaved `[i, j]` index
//!   array covering all stages, so the hot loops walk contiguous memory
//!   instead of chasing per-stage `Vec<u32>` pairs;
//! * a [`ParamLayout`] mapping the operator's five logical parameter
//!   groups (`d_in`, `d_out`, `bias`, `mix[l]`, `lone`) into offsets of a
//!   single flat `Vec<f32>`, replacing the ragged `SpmParams` vectors of
//!   the reference path with one contiguous, SIMD-friendly buffer that an
//!   optimizer updates with a single flat kernel.
//!
//! `spm.rs` remains the closed-form reference implementation; the planned
//! path in `ops::linear` is tested against it (see the parity tests).

use std::ops::Range;

use crate::pairing;
use crate::rng::Rng;
use crate::spm::{SpmParams, SpmSpec, Variant};

/// Sentinel in the per-stage leftover table: "this stage has no leftover".
const NO_LEFTOVER: u32 = u32::MAX;

/// Cache budget for one batch-fused activation tile (DESIGN.md §11): the
/// fused stage kernels sweep all L stages over a `fused_rows x n` row
/// block, so the block must stay L2-resident while the pair tables and
/// 2x2 coefficients stream over it once per stage.
const FUSED_TILE_BYTES: usize = 256 * 1024;

/// Upper bound on rows per fused tile: past this the pair-table loads are
/// fully amortized and bigger tiles only delay the trace snapshots.
const FUSED_MAX_ROWS: usize = 256;

/// Pairs per SIMD lane group (DESIGN.md §12): the vectorized stage backend
/// processes this many pairs at once, gathering their `(i, j)` coordinates
/// from the lane-padded index tables below. Eight f32 lanes = one AVX2
/// register; the padding keeps every stage's group count integral so the
/// vector loop never needs a scalar tail.
pub const PAIR_LANES: usize = 8;

/// Offsets of the five parameter groups inside one flat buffer:
///
/// ```text
/// [ d_in (n) | d_out (n) | bias (n) | mix[0] .. mix[L-1] (stride each) | lone (L) ]
/// ```
///
/// `stride` is `n/2` scalars per stage (rotation: one theta per pair) or
/// `4 * (n/2)` (general: interleaved `[a, b, c, d]` per pair). The `lone`
/// group is always allocated (length L) to keep the scalar count identical
/// to the reference `SpmParams::num_scalars`; the rotation variant simply
/// never reads or writes it.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ParamLayout {
    pub n: usize,
    pub num_stages: usize,
    /// scalars per stage in the mix block
    pub mix_stride: usize,
    /// total flat length
    pub total: usize,
}

impl ParamLayout {
    pub fn new(n: usize, num_stages: usize, variant: Variant) -> ParamLayout {
        let p = n / 2;
        let mix_stride = match variant {
            Variant::Rotation => p,
            Variant::General => 4 * p,
        };
        ParamLayout {
            n,
            num_stages,
            mix_stride,
            total: 3 * n + num_stages * mix_stride + num_stages,
        }
    }

    #[inline]
    pub fn d_in(&self) -> Range<usize> {
        0..self.n
    }

    #[inline]
    pub fn d_out(&self) -> Range<usize> {
        self.n..2 * self.n
    }

    #[inline]
    pub fn bias(&self) -> Range<usize> {
        2 * self.n..3 * self.n
    }

    #[inline]
    pub fn mix(&self, l: usize) -> Range<usize> {
        debug_assert!(l < self.num_stages);
        let start = 3 * self.n + l * self.mix_stride;
        start..start + self.mix_stride
    }

    #[inline]
    pub fn lone(&self) -> Range<usize> {
        let start = 3 * self.n + self.num_stages * self.mix_stride;
        start..start + self.num_stages
    }
}

/// Precomputed SPM plan: spec + flattened stage-major pairing tables +
/// flat parameter layout. Built once; shared by every forward/backward.
#[derive(Clone, Debug)]
pub struct SpmPlan {
    pub n: usize,
    pub num_stages: usize,
    pub variant: Variant,
    pub spec: SpmSpec,
    pub layout: ParamLayout,
    /// stage-major interleaved pairs: stage `l`, pair `k` mixes coordinates
    /// `pairs[(l*p + k)*2]` and `pairs[(l*p + k)*2 + 1]` where `p = n/2`
    pairs: Vec<u32>,
    /// per-stage leftover coordinate for odd n (NO_LEFTOVER if none)
    leftover: Vec<u32>,
    /// Pairs per stage rounded up to a [`PAIR_LANES`] multiple — the
    /// per-stage stride of the lane-padded index tables below.
    pub lane_pairs: usize,
    /// Lane-padded stage-major `i` coordinates, SoA (one flat i32 table,
    /// stage `l` at `[l * lane_pairs, (l + 1) * lane_pairs)`), for the
    /// SIMD backend's gathers. Padded lanes hold coordinate 0: gathers on
    /// them stay in bounds and their results are never written back.
    lane_i: Vec<i32>,
    /// Lane-padded stage-major `j` coordinates (same shape as `lane_i`).
    lane_j: Vec<i32>,
    /// Rows per batch-fused tile (DESIGN.md §11): the largest row block
    /// whose f32 activations fit [`FUSED_TILE_BYTES`], clamped to
    /// `[1, FUSED_MAX_ROWS]`. The fused kernels walk the pair table
    /// pair-major over such a block, so this is the amortization window
    /// for the `(i, j)` index and coefficient loads.
    pub fused_rows: usize,
}

impl SpmPlan {
    pub fn new(spec: SpmSpec) -> SpmPlan {
        assert!(spec.n >= 2, "n must be >= 2");
        assert!(spec.num_stages >= 1, "need at least one stage");
        let stages = pairing::make_schedule(spec.schedule, spec.n, spec.num_stages, spec.seed);
        let p = spec.n / 2;
        let mut pairs = Vec::with_capacity(spec.num_stages * 2 * p);
        let mut leftover = Vec::with_capacity(spec.num_stages);
        let lane_pairs = p.div_ceil(PAIR_LANES) * PAIR_LANES;
        let mut lane_i = Vec::with_capacity(spec.num_stages * lane_pairs);
        let mut lane_j = Vec::with_capacity(spec.num_stages * lane_pairs);
        for st in &stages {
            assert_eq!(st.left.len(), p, "pairing must cover n/2 pairs");
            for k in 0..p {
                pairs.push(st.left[k]);
                pairs.push(st.right[k]);
                lane_i.push(st.left[k] as i32);
                lane_j.push(st.right[k] as i32);
            }
            lane_i.resize(lane_i.len() + (lane_pairs - p), 0);
            lane_j.resize(lane_j.len() + (lane_pairs - p), 0);
            leftover.push(st.leftover.unwrap_or(NO_LEFTOVER));
        }
        SpmPlan {
            n: spec.n,
            num_stages: spec.num_stages,
            variant: spec.variant,
            spec,
            layout: ParamLayout::new(spec.n, spec.num_stages, spec.variant),
            pairs,
            leftover,
            lane_pairs,
            lane_i,
            lane_j,
            fused_rows: (FUSED_TILE_BYTES / (4 * spec.n)).clamp(1, FUSED_MAX_ROWS),
        }
    }

    #[inline]
    pub fn num_pairs(&self) -> usize {
        self.n / 2
    }

    /// Interleaved `[i, j]` pairs of stage `l` (length `2 * n/2`).
    #[inline]
    pub fn stage_pairs(&self, l: usize) -> &[u32] {
        let w = 2 * self.num_pairs();
        &self.pairs[l * w..(l + 1) * w]
    }

    /// Lane-padded `(i, j)` index tables of stage `l` (each `lane_pairs`
    /// long, SoA): the first `num_pairs()` lanes are the stage's pairs in
    /// table order, the rest are the zero padding the SIMD gathers may
    /// read but never write back.
    #[inline]
    pub fn stage_lane_ij(&self, l: usize) -> (&[i32], &[i32]) {
        let r = l * self.lane_pairs..(l + 1) * self.lane_pairs;
        (&self.lane_i[r.clone()], &self.lane_j[r])
    }

    /// Leftover (unpaired) coordinate of stage `l` for odd n.
    #[inline]
    pub fn stage_leftover(&self, l: usize) -> Option<usize> {
        let v = self.leftover[l];
        if v == NO_LEFTOVER {
            None
        } else {
            Some(v as usize)
        }
    }

    /// Orthogonal-at-init flat parameters; draws the SAME rng sequence as
    /// the reference `Spm::init_params`, so equal seeds give bit-equal
    /// initializations on both paths.
    pub fn init_flat(&self, rng: &mut Rng) -> Vec<f32> {
        let lay = self.layout;
        let mut params = vec![0.0f32; lay.total];
        params[lay.d_in()].fill(1.0);
        params[lay.d_out()].fill(1.0);
        // bias stays zero
        let p = self.num_pairs();
        for l in 0..self.num_stages {
            let m = &mut params[lay.mix(l)];
            match self.variant {
                Variant::Rotation => {
                    for v in m.iter_mut() {
                        *v = rng.uniform_in(-std::f32::consts::PI, std::f32::consts::PI);
                    }
                }
                Variant::General => {
                    for k in 0..p {
                        let th = rng.uniform_in(-std::f32::consts::PI, std::f32::consts::PI);
                        let (s, c) = th.sin_cos();
                        m[4 * k] = c;
                        m[4 * k + 1] = -s;
                        m[4 * k + 2] = s;
                        m[4 * k + 3] = c;
                    }
                }
            }
        }
        params[lay.lone()].fill(1.0);
        params
    }

    /// Pack five ragged parameter groups into the flat layout. Works for
    /// both `SpmParams` and `SpmGrads` shapes (see [`SpmPlan::pack_params`]).
    pub fn pack(
        &self,
        d_in: &[f32],
        d_out: &[f32],
        bias: &[f32],
        mix: &[Vec<f32>],
        lone: &[f32],
    ) -> Vec<f32> {
        let lay = self.layout;
        assert_eq!(d_in.len(), lay.n);
        assert_eq!(d_out.len(), lay.n);
        assert_eq!(bias.len(), lay.n);
        assert_eq!(mix.len(), lay.num_stages);
        assert_eq!(lone.len(), lay.num_stages);
        let mut flat = vec![0.0f32; lay.total];
        flat[lay.d_in()].copy_from_slice(d_in);
        flat[lay.d_out()].copy_from_slice(d_out);
        flat[lay.bias()].copy_from_slice(bias);
        for (l, m) in mix.iter().enumerate() {
            assert_eq!(m.len(), lay.mix_stride, "mix[{l}] width");
            flat[lay.mix(l)].copy_from_slice(m);
        }
        flat[lay.lone()].copy_from_slice(lone);
        flat
    }

    /// Pack reference-path parameters into the flat layout.
    pub fn pack_params(&self, p: &SpmParams) -> Vec<f32> {
        self.pack(&p.d_in, &p.d_out, &p.bias, &p.mix, &p.lone)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pairing::{make_schedule, Schedule};
    use crate::spm::Spm;

    #[test]
    fn layout_groups_are_disjoint_and_total() {
        for (n, l, variant) in [
            (8usize, 3usize, Variant::Rotation),
            (9, 4, Variant::General),
            (64, 6, Variant::General),
        ] {
            let lay = ParamLayout::new(n, l, variant);
            let mut seen = vec![0u8; lay.total];
            let mut mark = |r: Range<usize>| {
                for i in r {
                    seen[i] += 1;
                }
            };
            mark(lay.d_in());
            mark(lay.d_out());
            mark(lay.bias());
            for s in 0..l {
                mark(lay.mix(s));
            }
            mark(lay.lone());
            assert!(seen.iter().all(|&c| c == 1), "n={n} L={l} {variant:?}");
        }
    }

    #[test]
    fn layout_total_matches_reference_num_scalars() {
        for (n, l, variant) in [(16usize, 4usize, Variant::Rotation), (33, 5, Variant::General)] {
            let spec = SpmSpec::new(n, variant).with_stages(l);
            let op = Spm::new(spec);
            let mut rng = Rng::new(3);
            let params = op.init_params(&mut rng);
            let lay = ParamLayout::new(n, l, variant);
            assert_eq!(lay.total, params.num_scalars(), "n={n} L={l} {variant:?}");
        }
    }

    #[test]
    fn plan_pairs_match_schedule() {
        for schedule in [Schedule::Butterfly, Schedule::Shift, Schedule::Random] {
            for n in [8usize, 17, 64] {
                let spec = SpmSpec::new(n, Variant::General)
                    .with_schedule(schedule)
                    .with_stages(5)
                    .with_seed(9);
                let plan = SpmPlan::new(spec);
                let stages = make_schedule(schedule, n, 5, 9);
                for (l, st) in stages.iter().enumerate() {
                    let pairs = plan.stage_pairs(l);
                    for k in 0..st.left.len() {
                        assert_eq!(pairs[2 * k], st.left[k]);
                        assert_eq!(pairs[2 * k + 1], st.right[k]);
                    }
                    assert_eq!(
                        plan.stage_leftover(l),
                        st.leftover.map(|v| v as usize),
                        "{schedule:?} n={n} l={l}"
                    );
                }
            }
        }
    }

    #[test]
    fn lane_tables_match_pairs_and_are_padded() {
        for schedule in [Schedule::Butterfly, Schedule::Shift, Schedule::Random] {
            // n=2 (single pair, all-padding tail), 17 (odd, leftover),
            // 64 (pair count already a lane multiple)
            for n in [2usize, 17, 64] {
                let spec = SpmSpec::new(n, Variant::General)
                    .with_schedule(schedule)
                    .with_stages(4)
                    .with_seed(5);
                let plan = SpmPlan::new(spec);
                let p = plan.num_pairs();
                assert_eq!(plan.lane_pairs % PAIR_LANES, 0, "n={n}");
                assert!(plan.lane_pairs >= p && plan.lane_pairs < p + PAIR_LANES, "n={n}");
                for l in 0..plan.num_stages {
                    let pairs = plan.stage_pairs(l);
                    let (li, lj) = plan.stage_lane_ij(l);
                    assert_eq!(li.len(), plan.lane_pairs);
                    assert_eq!(lj.len(), plan.lane_pairs);
                    for k in 0..p {
                        assert_eq!(li[k], pairs[2 * k] as i32, "{schedule:?} n={n} l={l}");
                        assert_eq!(lj[k], pairs[2 * k + 1] as i32, "{schedule:?} n={n} l={l}");
                    }
                    for k in p..plan.lane_pairs {
                        assert_eq!((li[k], lj[k]), (0, 0), "padding lane {k}");
                    }
                }
            }
        }
    }

    #[test]
    fn fused_rows_within_tile_budget() {
        for n in [2usize, 9, 256, 1024, 4096, 1 << 20] {
            let spec = SpmSpec::new(n, Variant::General).with_stages(2);
            let plan = SpmPlan::new(spec);
            assert!(plan.fused_rows >= 1, "n={n}");
            assert!(plan.fused_rows <= FUSED_MAX_ROWS, "n={n}");
            // either the tile fits the budget or we are at the floor of 1 row
            assert!(
                plan.fused_rows * n * 4 <= FUSED_TILE_BYTES || plan.fused_rows == 1,
                "n={n} tile {} bytes",
                plan.fused_rows * n * 4
            );
        }
    }

    #[test]
    fn init_flat_matches_packed_reference_init() {
        for variant in [Variant::Rotation, Variant::General] {
            let spec = SpmSpec::new(21, variant).with_schedule(Schedule::Shift).with_stages(4);
            let op = Spm::new(spec);
            let plan = SpmPlan::new(spec);
            let reference = op.init_params(&mut Rng::new(42));
            let flat = plan.init_flat(&mut Rng::new(42));
            assert_eq!(flat, plan.pack_params(&reference), "{variant:?}");
        }
    }
}
