//! Stage-kernel backends (DESIGN.md §12): the four batch-fused kernels of
//! DESIGN.md §11 — stage forward, the trace-snapshot stage forward, and
//! the general/rotation stage backwards — behind ONE trait, so the fused
//! drivers in `ops::linear` are backend-agnostic and a vectorized (or, in
//! the future, GPU/XLA-custom-call) implementation drops in without
//! touching the tiling, threading, or trace plumbing.
//!
//! Two implementations today:
//!
//! * [`ScalarBackend`] — the portable pair-major scalar kernels (the PR-2
//!   fused path, moved here verbatim). Always available; the compile-time
//!   fallback when the `simd` cargo feature is off or the target is not
//!   x86_64, and the runtime fallback when AVX2/FMA detection fails.
//! * `backend_simd::Avx2Backend` — pairs in lanes of
//!   [`PAIR_LANES`](super::plan::PAIR_LANES), `(i, j)` coordinates
//!   gathered through the plan's lane-padded stage-major index tables.
//!   Compiled behind `feature = "simd"` + x86_64; selected at runtime via
//!   [`simd_available`].
//!
//! Kernel coefficient access goes through a `prepare_into` scratch whose
//! layout is backend-private (scalar: interleaved `(cos, sin)` per
//! rotation pair; AVX2: lane-padded SoA tables for both variants), because
//! the flat parameter buffer's interleaved mix layout is what a scalar
//! loop wants but not what vector loads want. The scratch is rebuilt into
//! a caller-owned buffer — `LinearOp` caches it per op and invalidates on
//! its params-version counter (DESIGN.md §15), so steady-state calls with
//! unchanged parameters touch the allocator zero times.

// The kernel signatures pass the plan, parameter/scratch/gradient buffers
// and the tile blocks individually on purpose — bundling them into a
// context struct would hide which kernel touches what, which is the whole
// point of the trait boundary.
#![allow(clippy::too_many_arguments)]

use std::sync::atomic::{AtomicBool, Ordering};

use crate::spm::Variant;

use super::linear::SpmExec;
use super::plan::SpmPlan;

/// One stage-kernel implementation. Methods mirror the DESIGN.md §11
/// kernels exactly; `block`/`g`/`z`/`zin` are row-major `(rows x n)`
/// activation/adjoint slices of one fused tile, `grads` is the op's flat
/// gradient layout, and `scratch` is whatever [`StageBackend::prepare`]
/// built for this call's parameters.
pub trait StageBackend: Sync {
    /// Backend-private coefficient scratch, rebuilt into `out` from the
    /// flat parameter buffer and shared read-only by every thread. `out`
    /// is a caller-owned reusable buffer (cleared here, capacity kept):
    /// the steady-state path re-derives coefficients without allocating,
    /// and `LinearOp` caches the result per op under a params-version
    /// counter so unchanged parameters skip the rebuild entirely.
    fn prepare_into(&self, plan: &SpmPlan, params: &[f32], out: &mut Vec<f32>);

    /// Allocating convenience wrapper over [`StageBackend::prepare_into`]
    /// — one-shot callers (tests, foreign-parameter FD probes) that have
    /// no buffer to reuse.
    fn prepare(&self, plan: &SpmPlan, params: &[f32]) -> Vec<f32> {
        let mut out = Vec::new();
        self.prepare_into(plan, params, &mut out);
        out
    }

    /// Apply stage `l` in place to `block` (eqs. 5-6 / 10-11).
    fn stage_fwd_batch(
        &self,
        plan: &SpmPlan,
        params: &[f32],
        scratch: &[f32],
        l: usize,
        block: &mut [f32],
    );

    /// Trace-snapshot forward: apply stage `l` and capture the stage
    /// OUTPUT into `snap` (same shape as `block`) — the residual the
    /// general backward replays. Backends may fuse the copy into their
    /// write-back; the default runs the plain forward then snapshots.
    fn stage_fwd_batch_trace(
        &self,
        plan: &SpmPlan,
        params: &[f32],
        scratch: &[f32],
        l: usize,
        block: &mut [f32],
        snap: &mut [f32],
    ) {
        self.stage_fwd_batch(plan, params, scratch, l, block);
        snap.copy_from_slice(block);
    }

    /// Reverse one GENERAL stage (eqs. 12-14): propagate the adjoint
    /// `g` in place with `zin` the stage-input rows from the trace, and
    /// accumulate the per-pair coefficient gradients into `grads`.
    fn stage_bwd_batch(
        &self,
        plan: &SpmPlan,
        params: &[f32],
        scratch: &[f32],
        l: usize,
        g: &mut [f32],
        zin: &[f32],
        grads: &mut [f32],
    );

    /// Reverse one ROTATION stage (eqs. 7-9): transpose-apply to BOTH the
    /// adjoint `g` and the activation `z` (recomputing stage inputs), and
    /// accumulate the theta gradients into `grads`.
    fn stage_bwd_batch_rotation(
        &self,
        plan: &SpmPlan,
        scratch: &[f32],
        l: usize,
        g: &mut [f32],
        z: &mut [f32],
        grads: &mut [f32],
    );
}

/// Test hook: force [`simd_available`] to report false so the
/// `exec = "simd"` downgrade path is testable on machines that DO support
/// AVX2. Not for production use; see the downgrade tests in `ops::linear`.
static FORCE_SCALAR: AtomicBool = AtomicBool::new(false);

pub fn force_scalar(on: bool) {
    FORCE_SCALAR.store(on, Ordering::SeqCst);
}

/// Whether the vectorized backend is compiled into this build at all
/// (`simd` cargo feature on an x86_64 target).
pub const fn simd_compiled() -> bool {
    cfg!(all(feature = "simd", target_arch = "x86_64"))
}

/// Whether the vectorized backend can run RIGHT NOW: compiled in, AVX2 +
/// FMA detected at runtime, and not disabled by the test hook. This is the
/// check `LinearOp::set_exec` downgrades through, so `exec = "simd"`
/// configs stay portable across builds and machines.
pub fn simd_available() -> bool {
    if FORCE_SCALAR.load(Ordering::SeqCst) {
        return false;
    }
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    {
        std::arch::is_x86_feature_detected!("avx2") && std::arch::is_x86_feature_detected!("fma")
    }
    #[cfg(not(all(feature = "simd", target_arch = "x86_64")))]
    {
        false
    }
}

static SCALAR: ScalarBackend = ScalarBackend;

/// Resolve an execution mode to a backend. `SpmExec::Simd` re-checks
/// availability here (not just at `set_exec` time) so a kernel call can
/// never reach the vectorized path on hardware that lacks it.
pub fn backend_for(exec: SpmExec) -> &'static dyn StageBackend {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    {
        if exec == SpmExec::Simd && simd_available() {
            return &super::backend_simd::AVX2;
        }
    }
    #[cfg(not(all(feature = "simd", target_arch = "x86_64")))]
    {
        let _ = exec;
    }
    &SCALAR
}

/// Per-stage interleaved (cos, sin) tables for the rotation variant —
/// the scalar backend's `prepare` scratch AND the row-wise path's trig
/// table; rebuilt into a reusable buffer because the thetas change every
/// optimizer step while the buffer's capacity does not.
pub(crate) fn rotation_trig_into(plan: &SpmPlan, params: &[f32], cs: &mut Vec<f32>) {
    let lay = plan.layout;
    cs.clear();
    cs.reserve(2 * lay.num_stages * lay.mix_stride);
    for l in 0..lay.num_stages {
        for &t in &params[lay.mix(l)] {
            let (s, c) = t.sin_cos();
            cs.push(c);
            cs.push(s);
        }
    }
}

/// Allocating wrapper over [`rotation_trig_into`] for one-shot callers
/// (the legacy row-wise path keeps its per-call table).
pub(crate) fn rotation_trig(plan: &SpmPlan, params: &[f32]) -> Vec<f32> {
    let mut cs = Vec::new();
    rotation_trig_into(plan, params, &mut cs);
    cs
}

/// Forward lone-lane scale for odd-n general stages: one strided column
/// walk, shared by both backends (a single coordinate with no 2x2
/// coefficients gains nothing from vector lanes).
pub(crate) fn lone_fwd(plan: &SpmPlan, params: &[f32], l: usize, block: &mut [f32]) {
    if let Some(lv) = plan.stage_leftover(l) {
        let s = params[plan.layout.lone()][l];
        let mut off = 0;
        while off < block.len() {
            block[off + lv] *= s;
            off += plan.n;
        }
    }
}

/// Backward lone-lane scale/grad for odd-n general stages (shared).
pub(crate) fn lone_bwd(
    plan: &SpmPlan,
    params: &[f32],
    l: usize,
    g: &mut [f32],
    zin: &[f32],
    grads: &mut [f32],
) {
    if let Some(lv) = plan.stage_leftover(l) {
        let lay = plan.layout;
        let s = params[lay.lone()][l];
        let mut gl = 0.0f32;
        let mut off = 0;
        while off < g.len() {
            gl += g[off + lv] * zin[off + lv];
            g[off + lv] *= s;
            off += plan.n;
        }
        grads[lay.lone().start + l] += gl;
    }
}

/// The portable pair-major scalar kernels (DESIGN.md §11): `(i, j)` and
/// the 2x2 coefficients load once per pair and stream down columns `i`/`j`
/// of every row of the block.
pub struct ScalarBackend;

impl StageBackend for ScalarBackend {
    fn prepare_into(&self, plan: &SpmPlan, params: &[f32], out: &mut Vec<f32>) {
        match plan.variant {
            Variant::Rotation => rotation_trig_into(plan, params, out),
            // the general kernels read the interleaved mix block directly
            Variant::General => out.clear(),
        }
    }

    fn stage_fwd_batch(
        &self,
        plan: &SpmPlan,
        params: &[f32],
        scratch: &[f32],
        l: usize,
        block: &mut [f32],
    ) {
        let n = plan.n;
        let pairs = plan.stage_pairs(l);
        let p = pairs.len() / 2;
        match plan.variant {
            Variant::Rotation => {
                let cs = &scratch[2 * p * l..2 * p * (l + 1)];
                for k in 0..p {
                    let (i, j) = (pairs[2 * k] as usize, pairs[2 * k + 1] as usize);
                    let (c, s) = (cs[2 * k], cs[2 * k + 1]);
                    let mut off = 0;
                    while off < block.len() {
                        let x1 = block[off + i];
                        let x2 = block[off + j];
                        block[off + i] = c * x1 - s * x2; // eq. (5)
                        block[off + j] = s * x1 + c * x2; // eq. (6)
                        off += n;
                    }
                }
                // leftover passes through (keeps the stage orthogonal)
            }
            Variant::General => {
                let m = &params[plan.layout.mix(l)];
                for k in 0..p {
                    let (i, j) = (pairs[2 * k] as usize, pairs[2 * k + 1] as usize);
                    let (a, b, c, d) = (m[4 * k], m[4 * k + 1], m[4 * k + 2], m[4 * k + 3]);
                    let mut off = 0;
                    while off < block.len() {
                        let x1 = block[off + i];
                        let x2 = block[off + j];
                        block[off + i] = a * x1 + b * x2; // eq. (10)
                        block[off + j] = c * x1 + d * x2; // eq. (11)
                        off += n;
                    }
                }
                lone_fwd(plan, params, l, block);
            }
        }
    }

    fn stage_bwd_batch(
        &self,
        plan: &SpmPlan,
        params: &[f32],
        _scratch: &[f32],
        l: usize,
        g: &mut [f32],
        zin: &[f32],
        grads: &mut [f32],
    ) {
        let n = plan.n;
        let lay = plan.layout;
        let pairs = plan.stage_pairs(l);
        let p = pairs.len() / 2;
        let m = &params[lay.mix(l)];
        let o_mix = lay.mix(l).start;
        for k in 0..p {
            let (i, j) = (pairs[2 * k] as usize, pairs[2 * k + 1] as usize);
            let (a, b, c, d) = (m[4 * k], m[4 * k + 1], m[4 * k + 2], m[4 * k + 3]);
            let (mut ga, mut gb, mut gc, mut gd) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
            let mut off = 0;
            while off < g.len() {
                let (x1, x2) = (zin[off + i], zin[off + j]);
                let (d1, d2) = (g[off + i], g[off + j]);
                // eq. (14)
                ga += d1 * x1;
                gb += d1 * x2;
                gc += d2 * x1;
                gd += d2 * x2;
                // eqs. (12)-(13)
                g[off + i] = a * d1 + c * d2;
                g[off + j] = b * d1 + d * d2;
                off += n;
            }
            grads[o_mix + 4 * k] += ga;
            grads[o_mix + 4 * k + 1] += gb;
            grads[o_mix + 4 * k + 2] += gc;
            grads[o_mix + 4 * k + 3] += gd;
        }
        lone_bwd(plan, params, l, g, zin, grads);
    }

    fn stage_bwd_batch_rotation(
        &self,
        plan: &SpmPlan,
        scratch: &[f32],
        l: usize,
        g: &mut [f32],
        z: &mut [f32],
        grads: &mut [f32],
    ) {
        let n = plan.n;
        let pairs = plan.stage_pairs(l);
        let p = pairs.len() / 2;
        let cs = &scratch[2 * p * l..2 * p * (l + 1)];
        let o_mix = plan.layout.mix(l).start;
        for k in 0..p {
            let (i, j) = (pairs[2 * k] as usize, pairs[2 * k + 1] as usize);
            let (c, s) = (cs[2 * k], cs[2 * k + 1]);
            let mut gth = 0.0f32;
            let mut off = 0;
            while off < g.len() {
                let (y1, y2) = (z[off + i], z[off + j]);
                let (d1, d2) = (g[off + i], g[off + j]);
                gth += d2 * y1 - d1 * y2; // eq. (9) via outputs
                g[off + i] = c * d1 + s * d2; // eq. (7)
                g[off + j] = -s * d1 + c * d2; // eq. (8)
                z[off + i] = c * y1 + s * y2; // z_{l-1} = B^T z_l
                z[off + j] = -s * y1 + c * y2;
                off += n;
            }
            grads[o_mix + k] += gth;
        }
    }
}
