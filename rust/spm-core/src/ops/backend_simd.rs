//! AVX2 stage-kernel backend (DESIGN.md §12): pairs processed in lanes of
//! [`PAIR_LANES`], with the `(i, j)` coordinate loads amortized through
//! the plan's lane-padded stage-major index tables.
//!
//! Loop shape per stage: lane groups outer, rows inner — one group's two
//! index vectors and its 2x2 coefficient vectors load once and stream down
//! every row of the fused tile (the same amortization the scalar
//! pair-major loop gets per pair, times eight). Per row the pair
//! coordinates are read with `vgatherdps`; AVX2 has no scatter, so the
//! write-back extracts the result vectors through a stack array and
//! stores only the group's `valid` lanes — padded lanes (coordinate 0,
//! identity coefficients) are computed but never written, which is what
//! makes the zero padding safe even when a real pair in the same group
//! touches coordinate 0.
//!
//! `prepare_into` deinterleaves the flat mix parameters into lane-padded
//! SoA tables (general: `[a | b | c | d]` per stage; rotation:
//! `[cos | sin]`) so coefficient loads are plain vector loads; the table
//! lives in a reusable buffer that `LinearOp` caches against its
//! params-version counter, so steady-state kernel calls skip both the
//! allocation and the deinterleave. In the backwards the
//! per-pair coefficient gradients live in vector accumulators across the
//! row loop and fold into the flat gradient buffer once per group.
//!
//! SAFETY contract: every kernel is `#[target_feature(enable = "avx2",
//! enable = "fma")]`; this backend is only reachable through
//! `backend::backend_for`, which gates on `backend::simd_available()`
//! (compile-time feature + runtime AVX2/FMA detection).

// Same rationale as ops::backend: kernels take their buffers individually
// so the data flow stays visible at the unsafe boundary.
#![allow(clippy::too_many_arguments)]

use core::arch::x86_64::*;

use crate::spm::Variant;

use super::backend::{lone_bwd, lone_fwd, StageBackend};
use super::plan::{SpmPlan, PAIR_LANES};

/// The one (stateless) AVX2 backend instance.
pub static AVX2: Avx2Backend = Avx2Backend;

pub struct Avx2Backend;

impl StageBackend for Avx2Backend {
    /// Lane-padded SoA coefficient tables, rebuilt into the caller's
    /// reusable buffer. General: stage stride `4 * lane_pairs`, groups
    /// `[a | b | c | d]`; rotation: stride `2 * lane_pairs`, groups
    /// `[cos | sin]`. Padded lanes hold the identity (a = d = 1 /
    /// cos = 1) so their computed values are harmless even before the
    /// write-back skips them. This used to allocate and re-deinterleave
    /// on EVERY kernel call; `LinearOp`'s params-version cache now makes
    /// the rebuild a once-per-optimizer-step event.
    fn prepare_into(&self, plan: &SpmPlan, params: &[f32], out: &mut Vec<f32>) {
        let lp = plan.lane_pairs;
        let p = plan.num_pairs();
        let lay = plan.layout;
        out.clear();
        match plan.variant {
            Variant::General => {
                out.resize(plan.num_stages * 4 * lp, 0.0);
                for l in 0..plan.num_stages {
                    let m = &params[lay.mix(l)];
                    let st = &mut out[l * 4 * lp..(l + 1) * 4 * lp];
                    for k in 0..p {
                        st[k] = m[4 * k];
                        st[lp + k] = m[4 * k + 1];
                        st[2 * lp + k] = m[4 * k + 2];
                        st[3 * lp + k] = m[4 * k + 3];
                    }
                    for k in p..lp {
                        st[k] = 1.0; // a
                        st[3 * lp + k] = 1.0; // d
                    }
                }
            }
            Variant::Rotation => {
                out.resize(plan.num_stages * 2 * lp, 0.0);
                for l in 0..plan.num_stages {
                    let m = &params[lay.mix(l)];
                    let st = &mut out[l * 2 * lp..(l + 1) * 2 * lp];
                    for k in 0..p {
                        let (s, c) = m[k].sin_cos();
                        st[k] = c;
                        st[lp + k] = s;
                    }
                    for k in p..lp {
                        st[k] = 1.0; // cos
                    }
                }
            }
        }
    }

    fn stage_fwd_batch(
        &self,
        plan: &SpmPlan,
        params: &[f32],
        scratch: &[f32],
        l: usize,
        block: &mut [f32],
    ) {
        let lp = plan.lane_pairs;
        let p = plan.num_pairs();
        let (li, lj) = plan.stage_lane_ij(l);
        match plan.variant {
            // SAFETY: reachable only through `backend::backend_for`, which
            // gates on runtime AVX2+FMA detection. Bounds: `block` holds
            // whole rows of width `plan.n` (StageBackend contract), and
            // every lane of `li`/`lj` is < n — real pairs index a plan
            // permutation of 0..n, and `SpmPlan::build_lane_tables` pads
            // the ragged tail with index 0 (n >= 2), so every
            // `vgatherdps` lane, padded or not, reads inside the row.
            // `scratch` was sized by `prepare_into` to
            // `num_stages * 2 * lp` (trig SoA), so the per-stage slice
            // holds the `2 * lp` coefficients the kernel loads.
            Variant::Rotation => unsafe {
                fwd_rotation(plan.n, p, li, lj, &scratch[l * 2 * lp..], lp, block);
            },
            Variant::General => {
                // SAFETY: same dispatch gate and lane-table bounds
                // argument as the Rotation arm above; `scratch` was sized
                // to `num_stages * 4 * lp` ([a|b|c|d] SoA), so the
                // per-stage slice holds the `4 * lp` coefficients read.
                unsafe {
                    fwd_general(plan.n, p, li, lj, &scratch[l * 4 * lp..], lp, block);
                }
                lone_fwd(plan, params, l, block);
            }
        }
    }

    fn stage_bwd_batch(
        &self,
        plan: &SpmPlan,
        params: &[f32],
        scratch: &[f32],
        l: usize,
        g: &mut [f32],
        zin: &[f32],
        grads: &mut [f32],
    ) {
        let lp = plan.lane_pairs;
        let (li, lj) = plan.stage_lane_ij(l);
        let o_mix = plan.layout.mix(l).start;
        // SAFETY: same dispatch gate and lane-table bounds argument as
        // `stage_fwd_batch`: `g` and `zin` are same-shape row blocks of
        // width `plan.n`, every `li`/`lj` lane (zero-padded tail
        // included) is < n, and the `4 * lp` coefficient slice exists by
        // `prepare_into`'s sizing. The `gm` slice starts at this stage's
        // mix offset and the layout guarantees `4 * num_pairs` grad
        // slots there; the fold loop only writes `valid` real lanes.
        unsafe {
            bwd_general(
                plan.n,
                plan.num_pairs(),
                li,
                lj,
                &scratch[l * 4 * lp..],
                lp,
                g,
                zin,
                &mut grads[o_mix..],
            );
        }
        lone_bwd(plan, params, l, g, zin, grads);
    }

    fn stage_bwd_batch_rotation(
        &self,
        plan: &SpmPlan,
        scratch: &[f32],
        l: usize,
        g: &mut [f32],
        z: &mut [f32],
        grads: &mut [f32],
    ) {
        let lp = plan.lane_pairs;
        let (li, lj) = plan.stage_lane_ij(l);
        let o_mix = plan.layout.mix(l).start;
        // SAFETY: same dispatch gate and lane-table bounds argument as
        // `stage_fwd_batch`; `g` and `z` are same-shape row blocks of
        // width `plan.n`, the `2 * lp` trig slice exists by
        // `prepare_into`'s sizing, and `gm` holds `num_pairs` theta-grad
        // slots at this stage's mix offset — the fold writes only the
        // group's `valid` real lanes.
        unsafe {
            bwd_rotation(
                plan.n,
                plan.num_pairs(),
                li,
                lj,
                &scratch[l * 2 * lp..],
                lp,
                g,
                z,
                &mut grads[o_mix..],
            );
        }
    }
}

/// Lanes of the group starting at pair `k0` that are REAL pairs (the last
/// group of a stage may be partly padding).
#[inline(always)]
fn valid_lanes(p: usize, k0: usize) -> usize {
    PAIR_LANES.min(p - k0)
}

/// # Safety
/// Caller must ensure AVX2 + FMA are available, `block` holds whole rows
/// of width `n`, index lanes are < n (padding 0), and `soa` holds at
/// least `4 * lp` coefficients with `lp` a multiple of [`PAIR_LANES`].
#[target_feature(enable = "avx2", enable = "fma")]
unsafe fn fwd_general(
    n: usize,
    p: usize,
    li: &[i32],
    lj: &[i32],
    soa: &[f32],
    lp: usize,
    block: &mut [f32],
) {
    let mut y1a = [0.0f32; PAIR_LANES];
    let mut y2a = [0.0f32; PAIR_LANES];
    let mut k0 = 0;
    while k0 < p {
        let vi = _mm256_loadu_si256(li.as_ptr().add(k0) as *const __m256i);
        let vj = _mm256_loadu_si256(lj.as_ptr().add(k0) as *const __m256i);
        let va = _mm256_loadu_ps(soa.as_ptr().add(k0));
        let vb = _mm256_loadu_ps(soa.as_ptr().add(lp + k0));
        let vc = _mm256_loadu_ps(soa.as_ptr().add(2 * lp + k0));
        let vd = _mm256_loadu_ps(soa.as_ptr().add(3 * lp + k0));
        let valid = valid_lanes(p, k0);
        let mut off = 0;
        while off < block.len() {
            let base = block.as_ptr().add(off);
            let x1 = _mm256_i32gather_ps::<4>(base, vi);
            let x2 = _mm256_i32gather_ps::<4>(base, vj);
            let y1 = _mm256_fmadd_ps(va, x1, _mm256_mul_ps(vb, x2)); // eq. (10)
            let y2 = _mm256_fmadd_ps(vc, x1, _mm256_mul_ps(vd, x2)); // eq. (11)
            _mm256_storeu_ps(y1a.as_mut_ptr(), y1);
            _mm256_storeu_ps(y2a.as_mut_ptr(), y2);
            for lane in 0..valid {
                let i = *li.get_unchecked(k0 + lane) as usize;
                let j = *lj.get_unchecked(k0 + lane) as usize;
                *block.get_unchecked_mut(off + i) = y1a[lane];
                *block.get_unchecked_mut(off + j) = y2a[lane];
            }
            off += n;
        }
        k0 += PAIR_LANES;
    }
}

/// # Safety
/// Same contract as [`fwd_general`] with `soa` holding `2 * lp` trig
/// coefficients.
#[target_feature(enable = "avx2", enable = "fma")]
unsafe fn fwd_rotation(
    n: usize,
    p: usize,
    li: &[i32],
    lj: &[i32],
    soa: &[f32],
    lp: usize,
    block: &mut [f32],
) {
    let mut y1a = [0.0f32; PAIR_LANES];
    let mut y2a = [0.0f32; PAIR_LANES];
    let mut k0 = 0;
    while k0 < p {
        let vi = _mm256_loadu_si256(li.as_ptr().add(k0) as *const __m256i);
        let vj = _mm256_loadu_si256(lj.as_ptr().add(k0) as *const __m256i);
        let vc = _mm256_loadu_ps(soa.as_ptr().add(k0));
        let vs = _mm256_loadu_ps(soa.as_ptr().add(lp + k0));
        let valid = valid_lanes(p, k0);
        let mut off = 0;
        while off < block.len() {
            let base = block.as_ptr().add(off);
            let x1 = _mm256_i32gather_ps::<4>(base, vi);
            let x2 = _mm256_i32gather_ps::<4>(base, vj);
            let y1 = _mm256_fmsub_ps(vc, x1, _mm256_mul_ps(vs, x2)); // eq. (5)
            let y2 = _mm256_fmadd_ps(vs, x1, _mm256_mul_ps(vc, x2)); // eq. (6)
            _mm256_storeu_ps(y1a.as_mut_ptr(), y1);
            _mm256_storeu_ps(y2a.as_mut_ptr(), y2);
            for lane in 0..valid {
                let i = *li.get_unchecked(k0 + lane) as usize;
                let j = *lj.get_unchecked(k0 + lane) as usize;
                *block.get_unchecked_mut(off + i) = y1a[lane];
                *block.get_unchecked_mut(off + j) = y2a[lane];
            }
            off += n;
        }
        k0 += PAIR_LANES;
    }
}

/// # Safety
/// Same contract as [`fwd_general`]; `g` and `zin` are same-shape blocks
/// and `gm` is the stage's mix-gradient slice (interleaved
/// `[a, b, c, d]` per pair, at least `4 * p` long).
#[target_feature(enable = "avx2", enable = "fma")]
unsafe fn bwd_general(
    n: usize,
    p: usize,
    li: &[i32],
    lj: &[i32],
    soa: &[f32],
    lp: usize,
    g: &mut [f32],
    zin: &[f32],
    gm: &mut [f32],
) {
    let mut g1a = [0.0f32; PAIR_LANES];
    let mut g2a = [0.0f32; PAIR_LANES];
    let mut acc = [0.0f32; PAIR_LANES];
    let mut k0 = 0;
    while k0 < p {
        let vi = _mm256_loadu_si256(li.as_ptr().add(k0) as *const __m256i);
        let vj = _mm256_loadu_si256(lj.as_ptr().add(k0) as *const __m256i);
        let va = _mm256_loadu_ps(soa.as_ptr().add(k0));
        let vb = _mm256_loadu_ps(soa.as_ptr().add(lp + k0));
        let vc = _mm256_loadu_ps(soa.as_ptr().add(2 * lp + k0));
        let vd = _mm256_loadu_ps(soa.as_ptr().add(3 * lp + k0));
        let mut vga = _mm256_setzero_ps();
        let mut vgb = _mm256_setzero_ps();
        let mut vgc = _mm256_setzero_ps();
        let mut vgd = _mm256_setzero_ps();
        let valid = valid_lanes(p, k0);
        let mut off = 0;
        while off < g.len() {
            let zbase = zin.as_ptr().add(off);
            let gbase = g.as_ptr().add(off);
            let x1 = _mm256_i32gather_ps::<4>(zbase, vi);
            let x2 = _mm256_i32gather_ps::<4>(zbase, vj);
            let d1 = _mm256_i32gather_ps::<4>(gbase, vi);
            let d2 = _mm256_i32gather_ps::<4>(gbase, vj);
            // eq. (14): coefficient grads accumulate across rows in lanes
            vga = _mm256_fmadd_ps(d1, x1, vga);
            vgb = _mm256_fmadd_ps(d1, x2, vgb);
            vgc = _mm256_fmadd_ps(d2, x1, vgc);
            vgd = _mm256_fmadd_ps(d2, x2, vgd);
            // eqs. (12)-(13)
            let g1 = _mm256_fmadd_ps(va, d1, _mm256_mul_ps(vc, d2));
            let g2 = _mm256_fmadd_ps(vb, d1, _mm256_mul_ps(vd, d2));
            _mm256_storeu_ps(g1a.as_mut_ptr(), g1);
            _mm256_storeu_ps(g2a.as_mut_ptr(), g2);
            for lane in 0..valid {
                let i = *li.get_unchecked(k0 + lane) as usize;
                let j = *lj.get_unchecked(k0 + lane) as usize;
                *g.get_unchecked_mut(off + i) = g1a[lane];
                *g.get_unchecked_mut(off + j) = g2a[lane];
            }
            off += n;
        }
        // fold the lane accumulators into the interleaved flat grads
        for (vacc, slot) in [(vga, 0usize), (vgb, 1), (vgc, 2), (vgd, 3)] {
            _mm256_storeu_ps(acc.as_mut_ptr(), vacc);
            for lane in 0..valid {
                gm[4 * (k0 + lane) + slot] += acc[lane];
            }
        }
        k0 += PAIR_LANES;
    }
}

/// # Safety
/// Same contract as [`fwd_general`]; `g` and `z` are same-shape blocks and
/// `gm` is the stage's theta-gradient slice (at least `p` long).
#[target_feature(enable = "avx2", enable = "fma")]
unsafe fn bwd_rotation(
    n: usize,
    p: usize,
    li: &[i32],
    lj: &[i32],
    soa: &[f32],
    lp: usize,
    g: &mut [f32],
    z: &mut [f32],
    gm: &mut [f32],
) {
    let mut g1a = [0.0f32; PAIR_LANES];
    let mut g2a = [0.0f32; PAIR_LANES];
    let mut z1a = [0.0f32; PAIR_LANES];
    let mut z2a = [0.0f32; PAIR_LANES];
    let mut acc = [0.0f32; PAIR_LANES];
    let mut k0 = 0;
    while k0 < p {
        let vi = _mm256_loadu_si256(li.as_ptr().add(k0) as *const __m256i);
        let vj = _mm256_loadu_si256(lj.as_ptr().add(k0) as *const __m256i);
        let vc = _mm256_loadu_ps(soa.as_ptr().add(k0));
        let vs = _mm256_loadu_ps(soa.as_ptr().add(lp + k0));
        let mut vgth = _mm256_setzero_ps();
        let valid = valid_lanes(p, k0);
        let mut off = 0;
        while off < g.len() {
            let zbase = z.as_ptr().add(off);
            let gbase = g.as_ptr().add(off);
            let y1 = _mm256_i32gather_ps::<4>(zbase, vi);
            let y2 = _mm256_i32gather_ps::<4>(zbase, vj);
            let d1 = _mm256_i32gather_ps::<4>(gbase, vi);
            let d2 = _mm256_i32gather_ps::<4>(gbase, vj);
            // eq. (9) via outputs: gth += d2*y1 - d1*y2
            vgth = _mm256_add_ps(vgth, _mm256_fmsub_ps(d2, y1, _mm256_mul_ps(d1, y2)));
            // eqs. (7)-(8)
            let g1 = _mm256_fmadd_ps(vc, d1, _mm256_mul_ps(vs, d2));
            let g2 = _mm256_fmsub_ps(vc, d2, _mm256_mul_ps(vs, d1));
            // z_{l-1} = B^T z_l
            let z1 = _mm256_fmadd_ps(vc, y1, _mm256_mul_ps(vs, y2));
            let z2 = _mm256_fmsub_ps(vc, y2, _mm256_mul_ps(vs, y1));
            _mm256_storeu_ps(g1a.as_mut_ptr(), g1);
            _mm256_storeu_ps(g2a.as_mut_ptr(), g2);
            _mm256_storeu_ps(z1a.as_mut_ptr(), z1);
            _mm256_storeu_ps(z2a.as_mut_ptr(), z2);
            for lane in 0..valid {
                let i = *li.get_unchecked(k0 + lane) as usize;
                let j = *lj.get_unchecked(k0 + lane) as usize;
                *g.get_unchecked_mut(off + i) = g1a[lane];
                *g.get_unchecked_mut(off + j) = g2a[lane];
                *z.get_unchecked_mut(off + i) = z1a[lane];
                *z.get_unchecked_mut(off + j) = z2a[lane];
            }
            off += n;
        }
        _mm256_storeu_ps(acc.as_mut_ptr(), vgth);
        for lane in 0..valid {
            gm[k0 + lane] += acc[lane];
        }
        k0 += PAIR_LANES;
    }
}

#[cfg(test)]
mod tests {
    use super::super::backend::{ScalarBackend, StageBackend};
    use super::*;
    use crate::rng::Rng;
    use crate::spm::SpmSpec;
    use crate::testkit::{check_close, ALL_SCHEDULES};

    /// Kernel-level parity: every AVX2 kernel against the scalar backend
    /// on the same random blocks, widths chosen to hit full groups, a
    /// ragged last group, and the odd-n leftover lane. Skipped (not
    /// failed) on machines without AVX2/FMA — the CI simd matrix leg is
    /// where execution is guaranteed. Gates on raw hardware detection,
    /// NOT `simd_available()`, so a concurrently running downgrade test
    /// holding the force-scalar hook cannot skip this coverage.
    #[test]
    fn avx2_kernels_match_scalar() {
        if !std::arch::is_x86_feature_detected!("avx2")
            || !std::arch::is_x86_feature_detected!("fma")
        {
            eprintln!("avx2_kernels_match_scalar: AVX2/FMA not detected, skipping");
            return;
        }
        let rows = 5;
        for variant in [Variant::Rotation, Variant::General] {
            for sched in ALL_SCHEDULES {
                for n in [2usize, 9, 16, 33, 40] {
                    let spec = SpmSpec::new(n, variant)
                        .with_schedule(sched)
                        .with_stages(3)
                        .with_seed(11);
                    let plan = SpmPlan::new(spec);
                    let mut rng = Rng::new(n as u64);
                    let mut params = plan.init_flat(&mut rng);
                    for v in params.iter_mut() {
                        *v += 0.2 * rng.normal();
                    }
                    let scalar = ScalarBackend;
                    let s_scratch = scalar.prepare(&plan, &params);
                    let v_scratch = AVX2.prepare(&plan, &params);
                    let ctx = format!("{variant:?} {sched:?} n={n}");

                    for l in 0..plan.num_stages {
                        // forward
                        let block0: Vec<f32> = rng.normal_vec(rows * n, 1.0);
                        let mut bs = block0.clone();
                        let mut bv = block0.clone();
                        scalar.stage_fwd_batch(&plan, &params, &s_scratch, l, &mut bs);
                        AVX2.stage_fwd_batch(&plan, &params, &v_scratch, l, &mut bv);
                        check_close(&bv, &bs, 1e-5, &format!("{ctx} l={l} fwd")).unwrap();

                        // backward
                        let g0: Vec<f32> = rng.normal_vec(rows * n, 1.0);
                        let z0: Vec<f32> = rng.normal_vec(rows * n, 1.0);
                        let mut gs = g0.clone();
                        let mut gv = g0.clone();
                        let mut grs = vec![0.0f32; plan.layout.total];
                        let mut grv = vec![0.0f32; plan.layout.total];
                        match variant {
                            Variant::General => {
                                scalar.stage_bwd_batch(
                                    &plan, &params, &s_scratch, l, &mut gs, &z0, &mut grs,
                                );
                                AVX2.stage_bwd_batch(
                                    &plan, &params, &v_scratch, l, &mut gv, &z0, &mut grv,
                                );
                            }
                            Variant::Rotation => {
                                let mut zs = z0.clone();
                                let mut zv = z0.clone();
                                scalar.stage_bwd_batch_rotation(
                                    &plan, &s_scratch, l, &mut gs, &mut zs, &mut grs,
                                );
                                AVX2.stage_bwd_batch_rotation(
                                    &plan, &v_scratch, l, &mut gv, &mut zv, &mut grv,
                                );
                                check_close(&zv, &zs, 1e-5, &format!("{ctx} l={l} bwd z")).unwrap();
                            }
                        }
                        check_close(&gv, &gs, 1e-5, &format!("{ctx} l={l} bwd g")).unwrap();
                        check_close(&grv, &grs, 1e-4, &format!("{ctx} l={l} bwd grads")).unwrap();
                    }
                }
            }
        }
    }
}
