//! The planned operator subsystem (DESIGN.md §3): one uniform `LinearOp`
//! layer every model, the optimizer and the coordinator consume, backed by
//! precomputed `SpmPlan`s and flat parameter/gradient buffers.
pub mod linear;
pub mod plan;

pub use linear::{LinearCfg, LinearKind, LinearOp, LinearTrace, SpmExec};
pub use plan::{ParamLayout, SpmPlan};
