//! The planned operator subsystem (DESIGN.md §3): one uniform `LinearOp`
//! layer every model, the optimizer and the coordinator consume, backed by
//! precomputed `SpmPlan`s, flat parameter/gradient buffers, and pluggable
//! stage-kernel backends (DESIGN.md §12).
pub mod backend;
#[cfg(all(feature = "simd", target_arch = "x86_64"))]
pub mod backend_simd;
pub mod linear;
pub mod plan;
pub mod workspace;

pub use backend::{ScalarBackend, StageBackend};
pub use linear::{
    block_for_budget, rank_for_budget, spm_budget, LinearCfg, LinearKind, LinearOp, LinearTrace,
    SpmExec,
};
pub use plan::{ParamLayout, SpmPlan, PAIR_LANES};
pub use workspace::{BwdScratch, Prepared, Workspace};
