//! Reusable scratch buffers for the planned-operator hot paths
//! (DESIGN.md §15).
//!
//! Every `LinearOp` owns one [`Workspace`] plus one [`Prepared`] cache.
//! The contract:
//!
//! * **The op allocates, the call reuses.** All buffers here grow on first
//!   use (or when the batch shape grows) and are then recycled verbatim by
//!   every later `forward_into` / `forward_train_into` / `backward_into`
//!   call, so steady-state traffic through an op performs zero heap
//!   allocations on the fused and SIMD execution paths.
//! * **Per-thread scratch is indexed by chunk id.** The fused backward
//!   splits the batch into at most `parallel::num_threads()` row chunks;
//!   chunk `t` gets exclusive `&mut` access to `Workspace::bwd[t]` for the
//!   duration of the parallel region, so no locking is needed and the
//!   per-thread partial gradients are reduced afterwards in chunk order —
//!   preserving the bit-exact two-phase reduction the determinism tests
//!   pin down.
//! * **The prepared cache is invalidated by a params-version counter.**
//!   [`Prepared::version`] is compared against `LinearOp`'s counter, which
//!   is bumped by every parameter write (`params_mut`, `apply_grads`).
//!   The cache also keys on which backend built it (`simd`), because the
//!   scalar and AVX2 coefficient layouts differ.
//!
//! Buffers are cleared with `clear()` + `resize(_, 0.0)` rather than
//! reallocated: once capacity matches the steady-state shape, both calls
//! are allocation-free.

/// Per-chunk scratch for one fused backward region: the thread-local
/// parameter-gradient partial plus the gy/z tile staging buffers that the
/// tile sweep previously allocated per call.
#[derive(Default)]
pub struct BwdScratch {
    /// Thread-local parameter-gradient partial (`ParamLayout::total` long).
    pub grads: Vec<f32>,
    /// Staged gy tile (`fused_rows * n` at most).
    pub g: Vec<f32>,
    /// Staged pre-output activations tile (rotation backward only).
    pub z: Vec<f32>,
}

/// Reusable scratch owned by one `LinearOp`.
#[derive(Default)]
pub struct Workspace {
    /// Per-chunk backward scratch; grown to the number of row chunks the
    /// parallel split actually produces, never shrunk.
    pub bwd: Vec<BwdScratch>,
    /// Phase-two accumulator for the deterministic gradient reduction
    /// (`acc = Σ_t bwd[t].grads`, then `grads += acc`).
    pub acc: Vec<f32>,
}

impl Workspace {
    pub const fn new() -> Workspace {
        Workspace { bwd: Vec::new(), acc: Vec::new() }
    }
}

/// Cached backend-prepared coefficient table (trig pairs for rotation
/// plans, SoA mix lanes for the AVX2 backend), rebuilt only when the
/// owning op's parameters change or the resolved backend switches.
pub struct Prepared {
    /// Params version the table was built from; 0 means "never built"
    /// (ops start their counter at 1).
    pub version: u64,
    /// Whether the AVX2 backend built the table (its layout differs from
    /// the scalar one).
    pub simd: bool,
    /// The prepared coefficient table itself.
    pub buf: Vec<f32>,
}

impl Prepared {
    pub const fn empty() -> Prepared {
        Prepared { version: 0, simd: false, buf: Vec::new() }
    }
}
