//! The planned linear-operator layer (DESIGN.md §3): every model-facing
//! linear map — dense comparator or SPM — behind ONE uniform contract:
//!
//! ```text
//! forward / forward_train / backward / apply_grads / param_count
//! ```
//!
//! Parameters live in a single contiguous `Vec<f32>` per op (offsets from
//! [`ParamLayout`]); gradients accumulate into a same-shape flat buffer,
//! so BPTT-style multi-call accumulation is free and a whole op updates
//! with one flat optimizer kernel ([`crate::optim::Optimizer`]). The SPM
//! path executes against a precomputed [`SpmPlan`]; `spm.rs` keeps the
//! closed-form reference implementation this file is tested against.
//!
//! The flat `params()`/`params_mut()` buffers are also the substrate of
//! the model-level `visit_params` enumeration (DESIGN.md §13): the
//! unified `models::api::Model` trait checkpoints and restores every
//! network purely through these slices, and `models::api::Model::set_exec`
//! fans [`LinearOp::set_exec`] out across all ops a model owns. Forwards
//! take the TRUE batch row count on every exec path — ragged serving
//! micro-batches never pad.

use std::cell::{Ref, RefCell};

use crate::optim::Optimizer;
use crate::pairing::{self, Schedule};
use crate::parallel;
use crate::rng::Rng;
use crate::spm::{SpmSpec, Variant};
use crate::tensor::{self, Mat};

use super::backend::{self, rotation_trig, StageBackend};
use super::plan::SpmPlan;
use super::workspace::{BwdScratch, Prepared, Workspace};

/// Which operator family a [`LinearOp`] executes (the structured-operator
/// zoo, DESIGN.md §19): the dense comparator, SPM, and the three
/// published structured competitors the paper positions SPM against.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LinearKind {
    Dense,
    Spm,
    /// W = U·Vᵀ + b at rank r ("Compute Better Spent" low-rank baseline).
    LowRank,
    /// DYAD-style block-diagonal matmul composed with a fixed
    /// deterministic shuffle permutation of the inputs.
    BlockShuffle,
    /// log2(n) fixed-pairing stages: the SPM general machinery pinned to
    /// the butterfly schedule (the classic butterfly factorization).
    Butterfly,
}

impl LinearKind {
    /// Every kind, in parse/name order — config errors enumerate this.
    pub const ALL: [LinearKind; 5] = [
        LinearKind::Dense,
        LinearKind::Spm,
        LinearKind::LowRank,
        LinearKind::BlockShuffle,
        LinearKind::Butterfly,
    ];

    pub fn parse(s: &str) -> Option<LinearKind> {
        match s {
            "dense" => Some(LinearKind::Dense),
            "spm" => Some(LinearKind::Spm),
            "lowrank" => Some(LinearKind::LowRank),
            "blockshuffle" => Some(LinearKind::BlockShuffle),
            "butterfly" => Some(LinearKind::Butterfly),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            LinearKind::Dense => "dense",
            LinearKind::Spm => "spm",
            LinearKind::LowRank => "lowrank",
            LinearKind::BlockShuffle => "blockshuffle",
            LinearKind::Butterfly => "butterfly",
        }
    }
}

/// How an SPM op executes its stage loop (DESIGN.md §11). Dense ops
/// ignore this.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum SpmExec {
    /// One batch row at a time through all stages — the PR-1 path, kept
    /// for the bench's row-wise/batch-fused/reference comparison. Re-reads
    /// each stage's pair table and 2x2 coefficients once per row.
    RowWise,
    /// Pair-major batch-fused stage kernels over L2-sized row tiles
    /// (`SpmPlan::fused_rows`): indices and coefficients load once per
    /// pair and stream down the `i`/`j` columns of the whole tile.
    #[default]
    BatchFused,
    /// The fused tiling driven through the vectorized stage backend
    /// (DESIGN.md §12): pairs in lanes of eight, coordinates gathered via
    /// the plan's lane-padded index tables. Requires the `simd` cargo
    /// feature on x86_64 plus runtime AVX2/FMA detection;
    /// [`LinearOp::set_exec`] downgrades to [`SpmExec::BatchFused`] when
    /// unsupported, so `exec = "simd"` configs stay portable.
    Simd,
}

impl SpmExec {
    pub fn parse(s: &str) -> Option<SpmExec> {
        match s {
            "rowwise" => Some(SpmExec::RowWise),
            "fused" => Some(SpmExec::BatchFused),
            "simd" => Some(SpmExec::Simd),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            SpmExec::RowWise => "rowwise",
            SpmExec::BatchFused => "fused",
            SpmExec::Simd => "simd",
        }
    }
}

/// Construction-time description of a linear map. Square maps may be any
/// zoo kind; rectangular maps (heads, read-outs) are dense or low-rank —
/// the paper's drop-in-replacement boundary (§2, §6.2, §7.2).
#[derive(Clone, Copy, Debug)]
pub struct LinearCfg {
    pub d_out: usize,
    pub d_in: usize,
    pub kind: LinearKind,
    pub variant: Variant,
    pub schedule: Schedule,
    /// None = paper default log2(n)
    pub num_stages: Option<usize>,
    /// Low-rank factor width; None = matched to the default-SPM
    /// parameter budget at this width ([`rank_for_budget`]).
    pub rank: Option<usize>,
    /// Block-shuffle block size (must divide n); None = matched to the
    /// default-SPM parameter budget ([`block_for_budget`]).
    pub block: Option<usize>,
    pub seed: u64,
}

impl LinearCfg {
    pub fn dense(n: usize) -> Self {
        Self::dense_rect(n, n)
    }

    pub fn dense_rect(d_out: usize, d_in: usize) -> Self {
        LinearCfg {
            d_out,
            d_in,
            kind: LinearKind::Dense,
            variant: Variant::General,
            schedule: Schedule::Butterfly,
            num_stages: None,
            rank: None,
            block: None,
            seed: 0,
        }
    }

    pub fn spm(n: usize, variant: Variant) -> Self {
        LinearCfg { kind: LinearKind::Spm, ..Self::dense(n) }.with_variant(variant)
    }

    /// Square low-rank map; rank defaults to the equal-budget pick.
    pub fn lowrank(n: usize) -> Self {
        LinearCfg { kind: LinearKind::LowRank, ..Self::dense(n) }
    }

    /// DYAD-style block-diagonal + shuffle; block size defaults to the
    /// equal-budget pick.
    pub fn blockshuffle(n: usize) -> Self {
        LinearCfg { kind: LinearKind::BlockShuffle, ..Self::dense(n) }
    }

    /// Butterfly factorization: SPM general stages pinned to the
    /// butterfly pairing schedule.
    pub fn butterfly(n: usize) -> Self {
        LinearCfg { kind: LinearKind::Butterfly, ..Self::dense(n) }
    }

    pub fn with_variant(mut self, v: Variant) -> Self {
        self.variant = v;
        self
    }

    pub fn with_schedule(mut self, s: Schedule) -> Self {
        self.schedule = s;
        self
    }

    pub fn with_stages(mut self, l: usize) -> Self {
        self.num_stages = Some(l);
        self
    }

    pub fn with_rank(mut self, r: usize) -> Self {
        self.rank = Some(r);
        self
    }

    pub fn with_block(mut self, bs: usize) -> Self {
        self.block = Some(bs);
        self
    }

    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Width of a square map (models' mixing dimension).
    pub fn n(&self) -> usize {
        debug_assert_eq!(self.d_in, self.d_out, "n() is for square maps");
        self.d_in
    }

    pub fn spec(&self) -> SpmSpec {
        let mut s = SpmSpec::new(self.n(), self.variant)
            .with_schedule(self.schedule)
            .with_seed(self.seed);
        if let Some(l) = self.num_stages {
            s = s.with_stages(l);
        }
        s
    }

    /// The pinned spec a [`LinearKind::Butterfly`] op executes: general
    /// 2x2 mixes on the butterfly pairing schedule. The configured
    /// variant/schedule are ignored — the schedule IS the kind — while
    /// `num_stages` (depth) and `seed` pass through.
    pub fn butterfly_spec(&self) -> SpmSpec {
        let mut s = SpmSpec::new(self.n(), Variant::General)
            .with_schedule(Schedule::Butterfly)
            .with_seed(self.seed);
        if let Some(l) = self.num_stages {
            s = s.with_stages(l);
        }
        s
    }

    /// The rank this config resolves to (LowRank kinds): explicit, else
    /// matched to the default-SPM budget at this shape.
    pub fn resolved_rank(&self) -> usize {
        self.rank.unwrap_or_else(|| rank_for_budget(self.d_in, self.d_out, spm_budget(self.d_in)))
    }

    /// The block size this config resolves to (BlockShuffle kinds):
    /// explicit, else matched to the default-SPM budget at this width.
    pub fn resolved_block(&self) -> usize {
        self.block.unwrap_or_else(|| block_for_budget(self.n(), spm_budget(self.n())))
    }
}

/// Parameter count of a default SPM op (general variant, `log2(n)`
/// stages) at width `n` — the equal-parameter budget the zoo's low-rank
/// and block-shuffle kinds match when no explicit rank/block is given:
/// `3n` diagonals+bias, `4*(n/2)` mix coefficients per stage, one lone
/// scale per stage.
pub fn spm_budget(n: usize) -> usize {
    let l = pairing::default_num_stages(n);
    3 * n + l * (4 * (n / 2)) + l
}

/// The low-rank factor width whose parameter count
/// `r * (d_in + d_out) + d_out` lands closest to `budget`, clamped to
/// `[1, min(d_in, d_out)]`.
pub fn rank_for_budget(d_in: usize, d_out: usize, budget: usize) -> usize {
    let per_rank = d_in + d_out;
    let spend = budget.saturating_sub(d_out);
    // round to nearest: (spend + per_rank/2) / per_rank
    let r = (spend + per_rank / 2) / per_rank;
    r.clamp(1, d_in.min(d_out))
}

/// The divisor of `n` whose block-shuffle parameter count
/// `n * bs + n` lands closest to `budget` (ties prefer the smaller —
/// cheaper — block). Never returns `n` itself unless `n` is prime and
/// 1 is further away: a full-width block is just dense.
pub fn block_for_budget(n: usize, budget: usize) -> usize {
    let mut best = 1usize;
    let mut best_gap = usize::MAX;
    for bs in 1..=n {
        if n % bs != 0 {
            continue;
        }
        let params = n * bs + n;
        let gap = params.abs_diff(budget);
        if gap < best_gap {
            best = bs;
            best_gap = gap;
        }
    }
    best
}

/// Residuals of one `forward_train`, consumed by `backward`.
pub enum LinearTrace {
    /// dense / block-shuffle: backward only needs the layer input
    Dense,
    /// SPM rotation: final pre-`d_out` activation z_L (O(Bn));
    /// stage inputs are recomputed via the orthogonal transpose
    Rotation { z_last: Mat },
    /// SPM general / butterfly: every stage input z_0..z_L (O(BnL))
    General { zs: Vec<Mat> },
    /// low-rank: the (B, r) intermediate t = x·Vᵀ
    LowRank { t: Mat },
}

enum OpImpl {
    Dense,
    Spm(SpmPlan),
    /// W = U·Vᵀ + b. Params flat `[U (d_out x r) | V (r x d_in) | bias]`.
    /// The `RefCell` scratches hold the (B, r) intermediates the `&self`
    /// forward and the backward reuse across calls (DESIGN.md §15); they
    /// are refreshed on the calling thread and never cross threads.
    LowRank { rank: usize, t: RefCell<Mat>, gt: RefCell<Mat> },
    /// Block-diagonal matmul over shuffled inputs. Params flat
    /// `[blocks ((n/block) x block x block, row-major per block) | bias]`;
    /// `perm` is the fixed input shuffle: output block k consumes inputs
    /// `x[perm[k*block + j]]`.
    BlockShuffle { block: usize, perm: Vec<u32> },
    /// SPM general stages pinned to the butterfly pairing schedule —
    /// shares every SPM kernel, exec path, and the prepared cache.
    Butterfly(SpmPlan),
}

/// One planned linear operator with flat parameter/gradient storage.
pub struct LinearOp {
    imp: OpImpl,
    d_in: usize,
    d_out: usize,
    params: Vec<f32>,
    grads: Vec<f32>,
    slot: usize,
    exec: SpmExec,
    /// Monotone counter bumped by every parameter write (`params_mut`,
    /// `apply_grads`); [`Prepared`] caches invalidate against it
    /// (DESIGN.md §15). Starts at 1 so an empty cache (version 0) is
    /// always stale.
    params_version: u64,
    /// Cached backend-prepared coefficient table for the fused/SIMD
    /// paths. `RefCell` because `forward` takes `&self`; the cache is
    /// refreshed on the calling thread before any parallel region, and
    /// the parallel closures only ever see the inner `&[f32]`.
    prepared: RefCell<Prepared>,
    /// Reusable backward scratch (per-chunk partials + reduce
    /// accumulator).
    ws: Workspace,
}

impl LinearOp {
    /// Build + initialize; registers ONE flat optimizer slot covering the
    /// whole parameter buffer. Dense uses Gaussian fan-in init; SPM starts
    /// orthogonal (identical rng draws to the reference `Spm::init_params`).
    pub fn new<O: Optimizer>(cfg: LinearCfg, rng: &mut Rng, opt: &mut O) -> LinearOp {
        let (imp, params) = match cfg.kind {
            LinearKind::Dense => {
                let scale = 1.0 / (cfg.d_in as f32).sqrt();
                let mut params = rng.normal_vec(cfg.d_out * cfg.d_in, scale);
                params.resize(cfg.d_out * cfg.d_in + cfg.d_out, 0.0);
                (OpImpl::Dense, params)
            }
            LinearKind::Spm => {
                assert_eq!(cfg.d_in, cfg.d_out, "SPM ops are square");
                let plan = SpmPlan::new(cfg.spec());
                let params = plan.init_flat(rng);
                (OpImpl::Spm(plan), params)
            }
            LinearKind::LowRank => {
                let r = cfg.resolved_rank();
                assert!(r >= 1 && r <= cfg.d_in.min(cfg.d_out), "rank in [1, min(d_in, d_out)]");
                // U preserves output variance from the r-wide intermediate;
                // V is the usual fan-in init over d_in.
                let mut params = rng.normal_vec(cfg.d_out * r, 1.0 / (r as f32).sqrt());
                let v = rng.normal_vec(r * cfg.d_in, 1.0 / (cfg.d_in as f32).sqrt());
                params.extend_from_slice(&v);
                params.resize(cfg.d_out * r + r * cfg.d_in + cfg.d_out, 0.0);
                let imp = OpImpl::LowRank {
                    rank: r,
                    t: RefCell::new(Mat { rows: 0, cols: 0, data: Vec::new() }),
                    gt: RefCell::new(Mat { rows: 0, cols: 0, data: Vec::new() }),
                };
                (imp, params)
            }
            LinearKind::BlockShuffle => {
                assert_eq!(cfg.d_in, cfg.d_out, "block-shuffle ops are square");
                let n = cfg.n();
                let bs = cfg.resolved_block();
                assert!(bs >= 1 && n % bs == 0, "block size must divide n");
                let mut params = rng.normal_vec(n * bs, 1.0 / (bs as f32).sqrt());
                params.resize(n * bs + n, 0.0);
                let perm = pairing::shuffle_permutation(n, cfg.seed);
                (OpImpl::BlockShuffle { block: bs, perm }, params)
            }
            LinearKind::Butterfly => {
                assert_eq!(cfg.d_in, cfg.d_out, "butterfly ops are square");
                let plan = SpmPlan::new(cfg.butterfly_spec());
                let params = plan.init_flat(rng);
                (OpImpl::Butterfly(plan), params)
            }
        };
        let grads = vec![0.0; params.len()];
        let slot = opt.register(params.len());
        LinearOp {
            imp,
            d_in: cfg.d_in,
            d_out: cfg.d_out,
            params,
            grads,
            slot,
            exec: SpmExec::default(),
            params_version: 1,
            prepared: RefCell::new(Prepared::empty()),
            ws: Workspace::new(),
        }
    }

    /// Select the SPM stage-loop execution path (no-op for dense ops).
    /// `SpmExec::Simd` downgrades to the scalar fused path when the
    /// vectorized backend is not compiled in or not detected at runtime
    /// (DESIGN.md §12), so configs carrying `exec = "simd"` construct and
    /// run on every build; `exec()` reports what was actually selected.
    pub fn set_exec(&mut self, exec: SpmExec) {
        self.exec = match exec {
            SpmExec::Simd if !backend::simd_available() => SpmExec::BatchFused,
            e => e,
        };
    }

    pub fn exec(&self) -> SpmExec {
        self.exec
    }

    pub fn kind(&self) -> LinearKind {
        match self.imp {
            OpImpl::Dense => LinearKind::Dense,
            OpImpl::Spm(_) => LinearKind::Spm,
            OpImpl::LowRank { .. } => LinearKind::LowRank,
            OpImpl::BlockShuffle { .. } => LinearKind::BlockShuffle,
            OpImpl::Butterfly(_) => LinearKind::Butterfly,
        }
    }

    pub fn d_in(&self) -> usize {
        self.d_in
    }

    pub fn d_out(&self) -> usize {
        self.d_out
    }

    /// Width of a square map.
    pub fn n(&self) -> usize {
        debug_assert_eq!(self.d_in, self.d_out, "n() is for square maps");
        self.d_in
    }

    pub fn plan(&self) -> Option<&SpmPlan> {
        match &self.imp {
            OpImpl::Spm(plan) | OpImpl::Butterfly(plan) => Some(plan),
            _ => None,
        }
    }

    /// Factor width of a LowRank op; `None` for every other kind. Part of
    /// the checkpoint arch fingerprint (DESIGN.md §19).
    pub fn rank(&self) -> Option<usize> {
        match &self.imp {
            OpImpl::LowRank { rank, .. } => Some(*rank),
            _ => None,
        }
    }

    /// Block size of a BlockShuffle op; `None` for every other kind. Part
    /// of the checkpoint arch fingerprint (DESIGN.md §19).
    pub fn block_size(&self) -> Option<usize> {
        match &self.imp {
            OpImpl::BlockShuffle { block, .. } => Some(*block),
            _ => None,
        }
    }

    /// The fixed input-shuffle permutation of a BlockShuffle op; `None`
    /// for every other kind. Part of the checkpoint arch fingerprint
    /// (DESIGN.md §19).
    pub fn shuffle(&self) -> Option<&[u32]> {
        match &self.imp {
            OpImpl::BlockShuffle { perm, .. } => Some(perm),
            _ => None,
        }
    }

    /// Estimated forward FLOPs one input row costs through this op — the
    /// paper's equal-FLOP comparison axis, reported as an exact KPI by
    /// the ablation harness (DESIGN.md §17). ONE convention across all
    /// five kinds so ablate FLOP columns are directly comparable: count
    /// every multiply and every add, INCLUDING the bias add
    /// (DESIGN.md §19). A counting model, not a cycle model: it is
    /// exec-path-independent by construction (rowwise / fused / simd
    /// schedule the same arithmetic).
    ///
    /// - Dense: `2*d_in*d_out + d_out` (matmul multiply-adds + bias).
    /// - SPM / Butterfly: `3n` (d_in/d_out diagonal scalings + bias)
    ///   plus, per stage, 6 per pair (a 2x2 mix: 4 mults + 2 adds) and
    ///   1 for the odd-`n` leftover scaling.
    /// - LowRank: `2*r*(d_in + d_out) + d_out` (two thin matmuls +
    ///   bias).
    /// - BlockShuffle: `2*n*block + n` (each of the `n` outputs is a
    ///   `block`-wide dot product; the shuffle itself is free — it is a
    ///   gather, not arithmetic — plus bias).
    pub fn flops_per_row(&self) -> u64 {
        match &self.imp {
            OpImpl::Dense => (2 * self.d_in * self.d_out + self.d_out) as u64,
            OpImpl::Spm(plan) | OpImpl::Butterfly(plan) => {
                let n = self.d_in as u64;
                let pairs = n / 2;
                let lone = n % 2;
                3 * n + plan.num_stages as u64 * (6 * pairs + lone)
            }
            OpImpl::LowRank { rank, .. } => {
                (2 * rank * (self.d_in + self.d_out) + self.d_out) as u64
            }
            OpImpl::BlockShuffle { block, .. } => (2 * self.d_in * block + self.d_out) as u64,
        }
    }

    pub fn param_count(&self) -> usize {
        self.params.len()
    }

    pub fn params(&self) -> &[f32] {
        &self.params
    }

    /// Mutable parameter access. Bumps the params-version counter so the
    /// prepared-coefficient cache rebuilds on the next forward/backward —
    /// callers that only read should use [`LinearOp::params`].
    pub fn params_mut(&mut self) -> &mut [f32] {
        self.params_version += 1;
        &mut self.params
    }

    /// Accumulated (un-applied) gradients, same layout as `params`.
    pub fn grads(&self) -> &[f32] {
        &self.grads
    }

    /// Mutable view of the accumulated gradients — the write-back path
    /// for externally reduced gradients (the data-parallel TrainEngine
    /// loads the all-reduced sum here before one `apply_grads`).
    pub fn grads_mut(&mut self) -> &mut [f32] {
        &mut self.grads
    }

    pub fn zero_grads(&mut self) {
        self.grads.fill(0.0);
    }

    /// The optimizer slot this op registered at construction.
    pub fn slot(&self) -> usize {
        self.slot
    }

    /// y = op(x); x is (B, d_in) -> (B, d_out).
    pub fn forward(&self, x: &Mat) -> Mat {
        let mut y = Mat { rows: 0, cols: 0, data: Vec::new() };
        self.forward_into(x, &mut y);
        y
    }

    /// [`LinearOp::forward`] into a caller-owned output buffer, reusing
    /// the op's cached prepared-coefficient table: with a stable batch
    /// shape the fused/SIMD paths perform zero steady-state allocations
    /// (DESIGN.md §15). The row-wise path stays the allocating legacy
    /// bench comparator.
    pub fn forward_into(&self, x: &Mat, out: &mut Mat) {
        match &self.imp {
            OpImpl::Dense => {
                assert_eq!(x.cols, self.d_in, "input width");
                let wlen = self.d_out * self.d_in;
                tensor::matmul_nt_slice_into(x, &self.params[..wlen], self.d_out, out);
                tensor::add_bias(out, &self.params[wlen..]);
            }
            OpImpl::Spm(plan) | OpImpl::Butterfly(plan) => match self.exec {
                SpmExec::RowWise => *out = spm_forward_rowwise(plan, &self.params, x),
                e => {
                    assert_eq!(x.cols, plan.n, "input width");
                    let (be, simd) = resolved_backend(e);
                    let prep = refresh_prepared(
                        &self.prepared,
                        plan,
                        &self.params,
                        self.params_version,
                        be,
                        simd,
                    );
                    out.rows = x.rows;
                    out.cols = plan.n;
                    out.data.clear();
                    out.data.extend_from_slice(&x.data);
                    spm_forward_fused_inplace(plan, be, &self.params, &prep.buf, &mut out.data);
                }
            },
            OpImpl::LowRank { rank, t, .. } => {
                let mut t = t.borrow_mut();
                lowrank_forward_into(&self.params, self.d_in, self.d_out, *rank, x, &mut t, out);
            }
            OpImpl::BlockShuffle { block, perm } => {
                blockshuffle_forward_into(&self.params, self.d_in, *block, perm, x, out);
            }
        }
    }

    /// Forward with an explicit (flat) parameter buffer — used by the
    /// finite-difference tests; layout must match this op's. Always
    /// prepares coefficients fresh from `params` (the cache belongs to
    /// the op's OWN parameter buffer and must not serve nudged copies).
    pub fn forward_with(&self, params: &[f32], x: &Mat) -> Mat {
        assert_eq!(params.len(), self.params.len(), "param buffer length");
        match &self.imp {
            OpImpl::Dense => {
                assert_eq!(x.cols, self.d_in, "input width");
                let wlen = self.d_out * self.d_in;
                let mut y = tensor::matmul_nt_slice(x, &params[..wlen], self.d_out);
                tensor::add_bias(&mut y, &params[wlen..]);
                y
            }
            OpImpl::Spm(plan) | OpImpl::Butterfly(plan) => {
                spm_forward(plan, self.exec, params, x)
            }
            OpImpl::LowRank { rank, .. } => {
                let mut t = Mat { rows: 0, cols: 0, data: Vec::new() };
                let mut y = Mat { rows: 0, cols: 0, data: Vec::new() };
                lowrank_forward_into(params, self.d_in, self.d_out, *rank, x, &mut t, &mut y);
                y
            }
            OpImpl::BlockShuffle { block, perm } => {
                let mut y = Mat { rows: 0, cols: 0, data: Vec::new() };
                blockshuffle_forward_into(params, self.d_in, *block, perm, x, &mut y);
                y
            }
        }
    }

    /// Forward keeping the residuals `backward` needs.
    pub fn forward_train(&self, x: &Mat) -> (Mat, LinearTrace) {
        let mut y = Mat { rows: 0, cols: 0, data: Vec::new() };
        let mut trace = LinearTrace::Dense;
        self.forward_train_into(x, &mut y, &mut trace);
        (y, trace)
    }

    /// [`LinearOp::forward_train`] into caller-owned output AND trace
    /// buffers. Trace `Mat`s are reshaped in place when the variant
    /// matches (the steady-state training case), so repeated microbatches
    /// of the same shape allocate nothing on the fused/SIMD paths.
    pub fn forward_train_into(&self, x: &Mat, out: &mut Mat, trace: &mut LinearTrace) {
        match &self.imp {
            OpImpl::Dense => {
                self.forward_into(x, out);
                *trace = LinearTrace::Dense;
            }
            OpImpl::Spm(plan) | OpImpl::Butterfly(plan) => match self.exec {
                SpmExec::RowWise => {
                    let (y, tr) = spm_forward_trace_rowwise(plan, &self.params, x);
                    *out = y;
                    *trace = tr;
                }
                e => {
                    let (be, simd) = resolved_backend(e);
                    let prep = refresh_prepared(
                        &self.prepared,
                        plan,
                        &self.params,
                        self.params_version,
                        be,
                        simd,
                    );
                    spm_forward_trace_fused_into(plan, be, &self.params, &prep.buf, x, out, trace);
                }
            },
            OpImpl::LowRank { rank, .. } => {
                // The (B, r) intermediate IS the residual: stash it in the
                // trace's own Mat (reshaped in place when the variant
                // matches) so backward reads it without recomputing.
                if !matches!(trace, LinearTrace::LowRank { .. }) {
                    // lint: allow(alloc): one-time trace-variant switch, not steady state (DESIGN.md §15)
                    *trace = LinearTrace::LowRank { t: Mat { rows: 0, cols: 0, data: Vec::new() } };
                }
                let LinearTrace::LowRank { t } = trace else { unreachable!() };
                lowrank_forward_into(&self.params, self.d_in, self.d_out, *rank, x, t, out);
            }
            OpImpl::BlockShuffle { block, perm } => {
                blockshuffle_forward_into(&self.params, self.d_in, *block, perm, x, out);
                *trace = LinearTrace::Dense;
            }
        }
    }

    /// Exact backward. ACCUMULATES parameter gradients into the op's flat
    /// gradient buffer (so repeated calls sum, e.g. across BPTT steps) and
    /// returns g_x. `x` is the input that produced `trace`.
    pub fn backward(&mut self, x: &Mat, trace: &LinearTrace, gy: &Mat) -> Mat {
        let mut gx = Mat { rows: 0, cols: 0, data: Vec::new() };
        self.backward_into(x, trace, gy, &mut gx);
        gx
    }

    /// [`LinearOp::backward`] writing g_x into a caller-owned buffer. The
    /// fused/SIMD paths run entirely out of the op's [`Workspace`]
    /// (per-chunk partials, staged tiles, reduce accumulator), writing
    /// g_x rows in place — zero steady-state allocations — while keeping
    /// the exact two-phase chunk-ordered gradient reduction the
    /// bit-identity suites pin down.
    pub fn backward_into(&mut self, x: &Mat, trace: &LinearTrace, gy: &Mat, gx: &mut Mat) {
        assert_eq!(gy.rows, x.rows, "batch size");
        match (&self.imp, trace) {
            (OpImpl::LowRank { rank, gt, .. }, LinearTrace::LowRank { t }) => {
                let mut gt = gt.borrow_mut();
                lowrank_backward_into(
                    &self.params,
                    self.d_in,
                    self.d_out,
                    *rank,
                    x,
                    t,
                    gy,
                    &mut gt,
                    &mut self.grads,
                    gx,
                );
            }
            (OpImpl::BlockShuffle { block, perm }, LinearTrace::Dense) => {
                blockshuffle_backward_into(
                    &self.params,
                    self.d_in,
                    *block,
                    perm,
                    x,
                    gy,
                    &mut self.grads,
                    gx,
                );
            }
            (OpImpl::Dense, LinearTrace::Dense) => {
                assert_eq!(x.cols, self.d_in, "input width");
                assert_eq!(gy.cols, self.d_out, "adjoint width");
                let wlen = self.d_out * self.d_in;
                tensor::matmul_slice_into(gy, &self.params[..wlen], self.d_in, gx);
                let (gw, gb) = self.grads.split_at_mut(wlen);
                tensor::matmul_tn_accum(gy, x, gw);
                for r in 0..gy.rows {
                    for (b, v) in gb.iter_mut().zip(gy.row(r)) {
                        *b += v;
                    }
                }
            }
            // (butterfly plans are always General-variant, so a Rotation
            // trace can only come from a true SPM op)
            (OpImpl::Spm(plan), LinearTrace::Rotation { z_last }) => match self.exec {
                SpmExec::RowWise => {
                    let (gxm, partial) =
                        spm_backward_rotation_rowwise(plan, &self.params, x, z_last, gy);
                    for (g, p) in self.grads.iter_mut().zip(&partial) {
                        *g += p;
                    }
                    *gx = gxm;
                }
                e => {
                    let (be, simd) = resolved_backend(e);
                    let prep = refresh_prepared(
                        &self.prepared,
                        plan,
                        &self.params,
                        self.params_version,
                        be,
                        simd,
                    );
                    spm_backward_rotation_fused_into(
                        plan,
                        be,
                        &self.params,
                        &prep.buf,
                        x,
                        z_last,
                        gy,
                        &mut self.ws,
                        &mut self.grads,
                        gx,
                    );
                }
            },
            (OpImpl::Spm(plan) | OpImpl::Butterfly(plan), LinearTrace::General { zs }) => match self.exec {
                SpmExec::RowWise => {
                    let (gxm, partial) =
                        spm_backward_general_rowwise(plan, &self.params, x, zs, gy);
                    for (g, p) in self.grads.iter_mut().zip(&partial) {
                        *g += p;
                    }
                    *gx = gxm;
                }
                e => {
                    let (be, simd) = resolved_backend(e);
                    let prep = refresh_prepared(
                        &self.prepared,
                        plan,
                        &self.params,
                        self.params_version,
                        be,
                        simd,
                    );
                    spm_backward_general_fused_into(
                        plan,
                        be,
                        &self.params,
                        &prep.buf,
                        x,
                        zs,
                        gy,
                        &mut self.ws,
                        &mut self.grads,
                        gx,
                    );
                }
            },
            _ => panic!("trace/op kind mismatch"),
        }
    }

    /// Apply the accumulated gradients with ONE flat optimizer call, then
    /// clear the gradient buffer. Bumps the params-version counter: the
    /// update wrote new parameters, so cached prepared coefficients are
    /// stale.
    pub fn apply_grads<O: Optimizer>(&mut self, opt: &mut O) {
        opt.update(self.slot, &mut self.params, &self.grads);
        self.grads.fill(0.0);
        self.params_version += 1;
    }
}

/// Resolve a (non-row-wise) exec mode to its concrete stage backend plus
/// the cache tag recording whether the AVX2 backend was chosen — its
/// prepared-coefficient layout differs from the scalar one, so a cached
/// table from the other backend must not be served.
fn resolved_backend(exec: SpmExec) -> (&'static dyn StageBackend, bool) {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    {
        if exec == SpmExec::Simd && backend::simd_available() {
            return (&super::backend_simd::AVX2, true);
        }
    }
    let _ = exec;
    (backend::backend_for(SpmExec::BatchFused), false)
}

/// Refresh an op's [`Prepared`] cache if its params-version or backend
/// tag is stale, then hand back a shared borrow. Runs on the calling
/// thread BEFORE any parallel region; the returned guard only feeds
/// `&prep.buf` slices into the kernels.
fn refresh_prepared<'a>(
    cache: &'a RefCell<Prepared>,
    plan: &SpmPlan,
    params: &[f32],
    version: u64,
    be: &dyn StageBackend,
    simd: bool,
) -> Ref<'a, Prepared> {
    {
        let mut p = cache.borrow_mut();
        if p.version != version || p.simd != simd {
            be.prepare_into(plan, params, &mut p.buf);
            p.version = version;
            p.simd = simd;
        }
    }
    cache.borrow()
}

/// Apply stage `l` in place on one row (planned path, flat params).
#[inline]
fn stage_fwd(
    plan: &SpmPlan,
    params: &[f32],
    trig: &[f32],
    lone: &[f32],
    l: usize,
    row: &mut [f32],
) {
    let pairs = plan.stage_pairs(l);
    let p = pairs.len() / 2;
    match plan.variant {
        Variant::Rotation => {
            let cs = &trig[2 * p * l..2 * p * (l + 1)];
            for k in 0..p {
                let (i, j) = (pairs[2 * k] as usize, pairs[2 * k + 1] as usize);
                let (c, s) = (cs[2 * k], cs[2 * k + 1]);
                let x1 = row[i];
                let x2 = row[j];
                row[i] = c * x1 - s * x2; // eq. (5)
                row[j] = s * x1 + c * x2; // eq. (6)
            }
            // leftover passes through (keeps the stage orthogonal)
        }
        Variant::General => {
            let m = &params[plan.layout.mix(l)];
            for k in 0..p {
                let (i, j) = (pairs[2 * k] as usize, pairs[2 * k + 1] as usize);
                let (a, b, c, d) = (m[4 * k], m[4 * k + 1], m[4 * k + 2], m[4 * k + 3]);
                let x1 = row[i];
                let x2 = row[j];
                row[i] = a * x1 + b * x2; // eq. (10)
                row[j] = c * x1 + d * x2; // eq. (11)
            }
            if let Some(lv) = plan.stage_leftover(l) {
                row[lv] *= lone[l];
            }
        }
    }
}

/// `row[i] *= d[i]` over every row of a block — eq. (2) D_in.
#[inline]
fn scale_rows(block: &mut [f32], n: usize, d: &[f32]) {
    for row in block.chunks_mut(n) {
        for (v, di) in row.iter_mut().zip(d) {
            *v *= di;
        }
    }
}

/// `row[i] = row[i] * d_out[i] + bias[i]` over every row — eq. (4).
#[inline]
fn finish_rows(block: &mut [f32], n: usize, d_out: &[f32], bias: &[f32]) {
    for row in block.chunks_mut(n) {
        for ((v, do_), b) in row.iter_mut().zip(d_out).zip(bias) {
            *v = *v * do_ + b;
        }
    }
}

fn spm_forward(plan: &SpmPlan, exec: SpmExec, params: &[f32], x: &Mat) -> Mat {
    match exec {
        SpmExec::RowWise => spm_forward_rowwise(plan, params, x),
        _ => spm_forward_fused(plan, backend::backend_for(exec), params, x),
    }
}

/// Batch-fused forward for a FOREIGN parameter buffer (the FD tests'
/// `forward_with` path): prepares coefficients fresh, then runs the
/// shared in-place body.
fn spm_forward_fused(plan: &SpmPlan, be: &dyn StageBackend, params: &[f32], x: &Mat) -> Mat {
    assert_eq!(x.cols, plan.n, "input width");
    let scratch = be.prepare(plan, params);
    let mut z = x.clone();
    spm_forward_fused_inplace(plan, be, params, &scratch, &mut z.data);
    z
}

/// Batch-fused forward body: `data` already holds the input rows and is
/// transformed in place. Each thread owns a row block; inside it the
/// block is cut into `plan.fused_rows` tiles and every stage is applied
/// to a tile before moving on, so activations stay L2-resident across
/// the whole D_in -> stages -> D_out sweep. The per-stage kernel is
/// whatever [`StageBackend`] the exec mode resolved to (DESIGN.md §12);
/// `scratch` is that backend's prepared coefficient table.
fn spm_forward_fused_inplace(
    plan: &SpmPlan,
    be: &dyn StageBackend,
    params: &[f32],
    scratch: &[f32],
    data: &mut [f32],
) {
    let n = plan.n;
    let lay = plan.layout;
    let d_in = &params[lay.d_in()];
    let d_out = &params[lay.d_out()];
    let bias = &params[lay.bias()];
    let tile = plan.fused_rows * n;
    parallel::for_each_chunk(data, n, |_first, chunk| {
        for block in chunk.chunks_mut(tile) {
            scale_rows(block, n, d_in);
            for l in 0..plan.num_stages {
                be.stage_fwd_batch(plan, params, scratch, l, block); // eq. (3)
            }
            finish_rows(block, n, d_out, bias);
        }
    });
}

fn spm_forward_rowwise(plan: &SpmPlan, params: &[f32], x: &Mat) -> Mat {
    assert_eq!(x.cols, plan.n, "input width");
    let n = plan.n;
    let lay = plan.layout;
    let d_in = &params[lay.d_in()];
    let d_out = &params[lay.d_out()];
    let bias = &params[lay.bias()];
    let lone = &params[lay.lone()];
    let trig = match plan.variant {
        Variant::Rotation => rotation_trig(plan, params),
        Variant::General => Vec::new(),
    };
    let mut z = x.clone();
    parallel::for_each_chunk(&mut z.data, n, |_first, chunk| {
        for row in chunk.chunks_mut(n) {
            for (v, di) in row.iter_mut().zip(d_in) {
                *v *= di; // eq. (2)
            }
            for l in 0..plan.num_stages {
                stage_fwd(plan, params, &trig, lone, l, row); // eq. (3)
            }
            for ((v, do_), b) in row.iter_mut().zip(d_out).zip(bias) {
                *v = *v * do_ + b; // eq. (4)
            }
        }
    });
    z
}

/// Reshape a `Mat` in place (clear + zero-resize): allocation-free once
/// its capacity matches the steady-state shape.
fn reshape_mat(m: &mut Mat, rows: usize, cols: usize) {
    m.rows = rows;
    m.cols = cols;
    m.data.clear();
    m.data.resize(rows * cols, 0.0);
}

/// Low-rank forward: y = x·Vᵀ·Uᵀ + b through the (B, r) intermediate
/// `t` (an op-owned reusable buffer, DESIGN.md §15). Params flat
/// `[U (d_out x r) | V (r x d_in) | bias]` as laid out by
/// [`LinearOp::new`].
fn lowrank_forward_into(
    params: &[f32],
    d_in: usize,
    d_out: usize,
    rank: usize,
    x: &Mat,
    t: &mut Mat,
    out: &mut Mat,
) {
    assert_eq!(x.cols, d_in, "input width");
    let (u, rest) = params.split_at(d_out * rank);
    let (v, bias) = rest.split_at(rank * d_in);
    tensor::matmul_nt_slice_into(x, v, rank, t);
    tensor::matmul_nt_slice_into(t, u, d_out, out);
    tensor::add_bias(out, bias);
}

/// Low-rank backward: gt = gy·U, then gU += gyᵀ·t, gV += gtᵀ·x,
/// gb += column-sum(gy), gx = gt·V. `t` is the forward intermediate
/// carried by the trace; `gt` is the op-owned reusable scratch
/// (DESIGN.md §15). Parameter gradients ACCUMULATE (BPTT contract).
fn lowrank_backward_into(
    params: &[f32],
    d_in: usize,
    d_out: usize,
    rank: usize,
    x: &Mat,
    t: &Mat,
    gy: &Mat,
    gt: &mut Mat,
    grads: &mut [f32],
    gx: &mut Mat,
) {
    assert_eq!(x.cols, d_in, "input width");
    assert_eq!(gy.cols, d_out, "adjoint width");
    assert_eq!(t.rows, x.rows, "trace batch");
    let ulen = d_out * rank;
    let vlen = rank * d_in;
    tensor::matmul_slice_into(gy, &params[..ulen], rank, gt);
    let (gu, rest) = grads.split_at_mut(ulen);
    let (gv, gb) = rest.split_at_mut(vlen);
    tensor::matmul_tn_accum(gy, t, gu);
    tensor::matmul_tn_accum(gt, x, gv);
    for r in 0..gy.rows {
        for (b, v) in gb.iter_mut().zip(gy.row(r)) {
            *b += v;
        }
    }
    tensor::matmul_slice_into(gt, &params[ulen..ulen + vlen], d_in, gx);
}

/// Block-shuffle forward:
/// `y[k*bs + i] = bias[k*bs + i] + Σ_j W_k[i][j] · x[perm[k*bs + j]]` —
/// a block-diagonal matmul whose block k reads the shuffled input slots
/// `perm[k*bs..(k+1)*bs]`. Params flat `[blocks | bias]`, each block
/// row-major (bs x bs). The gather costs no arithmetic; one warm call
/// allocates nothing (DESIGN.md §15).
fn blockshuffle_forward_into(
    params: &[f32],
    n: usize,
    block: usize,
    perm: &[u32],
    x: &Mat,
    out: &mut Mat,
) {
    assert_eq!(x.cols, n, "input width");
    let (blocks, bias) = params.split_at(n * block);
    reshape_mat(out, x.rows, n);
    for row in 0..x.rows {
        let xr = x.row(row);
        let yr = out.row_mut(row);
        for k in 0..n / block {
            let base = k * block;
            let wk = &blocks[base * block..(base + block) * block];
            for i in 0..block {
                let wrow = &wk[i * block..(i + 1) * block];
                let mut acc = bias[base + i];
                for j in 0..block {
                    acc += wrow[j] * xr[perm[base + j] as usize];
                }
                yr[base + i] = acc;
            }
        }
    }
}

/// Block-shuffle backward. `perm` is a bijection, so each `gx` element
/// belongs to exactly one (block, j) pair — `gx` is reshaped (zeroed)
/// then scatter-filled in one pass. Parameter gradients ACCUMULATE.
fn blockshuffle_backward_into(
    params: &[f32],
    n: usize,
    block: usize,
    perm: &[u32],
    x: &Mat,
    gy: &Mat,
    grads: &mut [f32],
    gx: &mut Mat,
) {
    assert_eq!(x.cols, n, "input width");
    assert_eq!(gy.cols, n, "adjoint width");
    let wlen = n * block;
    let blocks = &params[..wlen];
    let (gw, gb) = grads.split_at_mut(wlen);
    reshape_mat(gx, x.rows, n);
    for row in 0..x.rows {
        let xr = x.row(row);
        let gyr = gy.row(row);
        let gxr = gx.row_mut(row);
        for k in 0..n / block {
            let base = k * block;
            let wk = &blocks[base * block..(base + block) * block];
            let gwk = &mut gw[base * block..(base + block) * block];
            for i in 0..block {
                let g = gyr[base + i];
                let wrow = &wk[i * block..(i + 1) * block];
                let gwrow = &mut gwk[i * block..(i + 1) * block];
                for j in 0..block {
                    let src = perm[base + j] as usize;
                    gwrow[j] += g * xr[src];
                    gxr[src] += wrow[j] * g;
                }
            }
        }
        for (b, v) in gb.iter_mut().zip(gyr) {
            *b += v;
        }
    }
}

/// Batch-fused training forward into caller-owned output and trace
/// buffers. One parallel region for the whole sweep: each thread walks
/// its row block tile by tile, applies all stages to the hot tile, and
/// writes the residuals `backward` needs (rotation: z_L; general: every
/// stage input) into per-stage buffers at the same row offsets via
/// `parallel::for_each_chunk_with`. Trace `Mat`s are reshaped in place
/// when the incoming `trace` already carries the right variant, so
/// steady-state training reuses them verbatim.
fn spm_forward_trace_fused_into(
    plan: &SpmPlan,
    be: &dyn StageBackend,
    params: &[f32],
    scratch: &[f32],
    x: &Mat,
    out: &mut Mat,
    trace: &mut LinearTrace,
) {
    assert_eq!(x.cols, plan.n, "input width");
    let n = plan.n;
    let rows = x.rows;
    let lay = plan.layout;
    let d_in = &params[lay.d_in()];
    let d_out = &params[lay.d_out()];
    let bias = &params[lay.bias()];
    let tile = plan.fused_rows * n;
    out.rows = rows;
    out.cols = n;
    out.data.clear();
    out.data.extend_from_slice(&x.data);
    match plan.variant {
        Variant::Rotation => {
            if !matches!(trace, LinearTrace::Rotation { .. }) {
                *trace =
                    // lint: allow(alloc): one-time trace-variant switch, not steady state (DESIGN.md §15)
                    LinearTrace::Rotation { z_last: Mat { rows: 0, cols: 0, data: Vec::new() } };
            }
            let LinearTrace::Rotation { z_last } = trace else { unreachable!() };
            reshape_mat(z_last, rows, n);
            parallel::for_each_chunk_with(
                &mut out.data,
                &mut [&mut z_last.data],
                n,
                |_f, chunk, snaps| {
                    let mut off = 0;
                    for block in chunk.chunks_mut(tile) {
                        scale_rows(block, n, d_in);
                        for l in 0..plan.num_stages {
                            be.stage_fwd_batch(plan, params, scratch, l, block);
                        }
                        snaps[0][off..off + block.len()].copy_from_slice(block);
                        finish_rows(block, n, d_out, bias);
                        off += block.len();
                    }
                },
            );
        }
        Variant::General => {
            // zs[0] = D_in x and zs[l+1] = stage-l output, all written
            // while the tile is hot — no per-stage barrier, no separate
            // scale/finish passes. The per-stage trace kernel captures
            // the stage output as part of the stage sweep.
            if !matches!(trace, LinearTrace::General { .. }) {
                // lint: allow(alloc): one-time trace-variant switch, not steady state (DESIGN.md §15)
                *trace = LinearTrace::General { zs: Vec::new() };
            }
            let LinearTrace::General { zs } = trace else { unreachable!() };
            if zs.len() != plan.num_stages + 1 {
                // lint: allow(alloc): first-call trace growth; reshape_mat reuses it afterwards (DESIGN.md §15)
                zs.resize_with(plan.num_stages + 1, || Mat { rows: 0, cols: 0, data: Vec::new() });
            }
            for m in zs.iter_mut() {
                reshape_mat(m, rows, n);
            }
            {
                // the only remaining per-call allocation on this path: a
                // Vec of L+1 slice handles (documented in DESIGN.md §15)
                let mut extras: Vec<&mut [f32]> =
                    // lint: allow(alloc): the documented per-call trace-handle Vec (DESIGN.md §15)
                    zs.iter_mut().map(|m| m.data.as_mut_slice()).collect();
                parallel::for_each_chunk_with(&mut out.data, &mut extras, n, |_f, chunk, snaps| {
                    let mut off = 0;
                    for block in chunk.chunks_mut(tile) {
                        scale_rows(block, n, d_in);
                        snaps[0][off..off + block.len()].copy_from_slice(block);
                        for l in 0..plan.num_stages {
                            let snap = &mut snaps[l + 1][off..off + block.len()];
                            be.stage_fwd_batch_trace(plan, params, scratch, l, block, snap);
                        }
                        finish_rows(block, n, d_out, bias);
                        off += block.len();
                    }
                });
            }
        }
    }
}

fn spm_forward_trace_rowwise(plan: &SpmPlan, params: &[f32], x: &Mat) -> (Mat, LinearTrace) {
    assert_eq!(x.cols, plan.n, "input width");
    let n = plan.n;
    let lay = plan.layout;
    let d_in = &params[lay.d_in()];
    let d_out = &params[lay.d_out()];
    let bias = &params[lay.bias()];
    let lone = &params[lay.lone()];
    match plan.variant {
        Variant::Rotation => {
            let trig = rotation_trig(plan, params);
            let mut z = x.clone();
            parallel::for_each_chunk(&mut z.data, n, |_f, chunk| {
                for row in chunk.chunks_mut(n) {
                    for (v, di) in row.iter_mut().zip(d_in) {
                        *v *= di;
                    }
                    for l in 0..plan.num_stages {
                        stage_fwd(plan, params, &trig, lone, l, row);
                    }
                }
            });
            let z_last = z.clone();
            parallel::for_each_chunk(&mut z.data, n, |_f, chunk| {
                for row in chunk.chunks_mut(n) {
                    for ((v, do_), b) in row.iter_mut().zip(d_out).zip(bias) {
                        *v = *v * do_ + b;
                    }
                }
            });
            (z, LinearTrace::Rotation { z_last })
        }
        Variant::General => {
            let mut zs = Vec::with_capacity(plan.num_stages + 1);
            let mut z = x.clone();
            for i in 0..z.rows {
                for (v, di) in z.row_mut(i).iter_mut().zip(d_in) {
                    *v *= di;
                }
            }
            zs.push(z.clone());
            for l in 0..plan.num_stages {
                parallel::for_each_chunk(&mut z.data, n, |_f, chunk| {
                    for row in chunk.chunks_mut(n) {
                        stage_fwd(plan, params, &[], lone, l, row);
                    }
                });
                zs.push(z.clone());
            }
            let mut y = z;
            for i in 0..y.rows {
                for ((v, do_), b) in y.row_mut(i).iter_mut().zip(d_out).zip(bias) {
                    *v = *v * do_ + b;
                }
            }
            (y, LinearTrace::General { zs })
        }
    }
}

/// Batch-fused rotation backward (paper §4, DESIGN.md §8) out of the
/// op's [`Workspace`]: per-chunk row ranges swept in `fused_rows` tiles,
/// each reverse stage pair-major over the whole tile's adjoint AND
/// recomputed-activation blocks. Chunk `t` writes its g_x rows directly
/// into the caller's (pre-sized) `gx` and its parameter-gradient partial
/// into `ws.bwd[t].grads`; the reduction afterwards sums partials in
/// chunk order into `ws.acc` and then adds `acc` to `grads` once — the
/// same two-phase arithmetic the old collect-then-reduce produced, so
/// gradients stay bit-identical.
#[allow(clippy::too_many_arguments)]
fn spm_backward_rotation_fused_into(
    plan: &SpmPlan,
    be: &dyn StageBackend,
    params: &[f32],
    scratch: &[f32],
    x: &Mat,
    z_last: &Mat,
    gy: &Mat,
    ws: &mut Workspace,
    grads: &mut [f32],
    gx: &mut Mat,
) {
    let n = plan.n;
    let ls = plan.num_stages;
    let lay = plan.layout;
    let d_in = &params[lay.d_in()];
    let d_out = &params[lay.d_out()];
    let rows = gy.rows;
    let (o_din, o_dout, o_bias) = (lay.d_in().start, lay.d_out().start, lay.bias().start);

    reshape_mat(gx, rows, n);
    let used = parallel::for_each_chunk_scratch(
        &mut gx.data,
        n,
        &mut ws.bwd,
        BwdScratch::default,
        |_t, first, gx_chunk, s| {
            let chunk_rows = gx_chunk.len() / n;
            let end = first + chunk_rows;
            s.grads.clear();
            s.grads.resize(lay.total, 0.0);
            let tile_rows = plan.fused_rows.min(chunk_rows.max(1));
            s.g.clear();
            s.g.resize(tile_rows * n, 0.0);
            s.z.clear();
            s.z.resize(tile_rows * n, 0.0);
            let grads = &mut s.grads;
            let mut r0 = first;
            while r0 < end {
                let rt = tile_rows.min(end - r0);
                let g_blk = &mut s.g[..rt * n];
                let z_blk = &mut s.z[..rt * n];
                // eqs. (15)-(17) row by row, filling the tile's blocks
                for ri in 0..rt {
                    let r = r0 + ri;
                    let gyr = gy.row(r);
                    let zl = z_last.row(r);
                    z_blk[ri * n..(ri + 1) * n].copy_from_slice(zl);
                    let grow = &mut g_blk[ri * n..(ri + 1) * n];
                    for i in 0..n {
                        grads[o_bias + i] += gyr[i];
                        grads[o_dout + i] += gyr[i] * zl[i];
                        grow[i] = gyr[i] * d_out[i];
                    }
                }
                // stages in reverse, batched over the tile
                for l in (0..ls).rev() {
                    be.stage_bwd_batch_rotation(plan, scratch, l, g_blk, z_blk, grads);
                }
                // eqs. (18)-(19)
                for ri in 0..rt {
                    let r = r0 + ri;
                    let xr = x.row(r);
                    let grow = &g_blk[ri * n..(ri + 1) * n];
                    let gxr = &mut gx_chunk[(r - first) * n..(r - first + 1) * n];
                    for i in 0..n {
                        grads[o_din + i] += grow[i] * xr[i];
                        gxr[i] = grow[i] * d_in[i];
                    }
                }
                r0 += rt;
            }
        },
    );

    reduce_workspace(ws, used, lay.total, grads);
}

fn spm_backward_rotation_rowwise(
    plan: &SpmPlan,
    params: &[f32],
    x: &Mat,
    z_last: &Mat,
    gy: &Mat,
) -> (Mat, Vec<f32>) {
    let n = plan.n;
    let ls = plan.num_stages;
    let p = plan.num_pairs();
    let lay = plan.layout;
    let d_in = &params[lay.d_in()];
    let d_out = &params[lay.d_out()];
    let trig = rotation_trig(plan, params);
    let rows = gy.rows;
    // group offsets from the one layout definition
    let (o_din, o_dout, o_bias, o_mix) =
        (lay.d_in().start, lay.d_out().start, lay.bias().start, lay.mix(0).start);
    let stride = lay.mix_stride;

    let gx = Mat::zeros(rows, n);
    let partials = parallel::map_row_ranges(rows, |_t, range| {
        let lo = range.start;
        let mut grads = vec![0.0f32; lay.total];
        // one contiguous g_x block per thread, not one Vec per row
        let mut gx_chunk = vec![0.0f32; range.len() * n];
        let mut g = vec![0.0f32; n];
        let mut z = vec![0.0f32; n];
        for r in range {
            // eqs. (15)-(17)
            let gyr = gy.row(r);
            z.copy_from_slice(z_last.row(r));
            for i in 0..n {
                grads[o_bias + i] += gyr[i];
                grads[o_dout + i] += gyr[i] * z[i];
                g[i] = gyr[i] * d_out[i];
            }
            // stages in reverse: theta grad from outputs, then transpose-
            // apply to BOTH adjoint g and activation z
            for l in (0..ls).rev() {
                let pairs = plan.stage_pairs(l);
                let cs = &trig[2 * p * l..2 * p * (l + 1)];
                let gm = o_mix + l * stride;
                for k in 0..p {
                    let (i, j) = (pairs[2 * k] as usize, pairs[2 * k + 1] as usize);
                    let (c, s) = (cs[2 * k], cs[2 * k + 1]);
                    let (y1, y2) = (z[i], z[j]);
                    let (d1, d2) = (g[i], g[j]);
                    grads[gm + k] += d2 * y1 - d1 * y2; // eq. (9) via outputs
                    g[i] = c * d1 + s * d2; // eq. (7)
                    g[j] = -s * d1 + c * d2; // eq. (8)
                    z[i] = c * y1 + s * y2; // z_{l-1} = B^T z_l
                    z[j] = -s * y1 + c * y2;
                }
            }
            // eqs. (18)-(19)
            let xr = x.row(r);
            let gxr = &mut gx_chunk[(r - lo) * n..(r - lo + 1) * n];
            for i in 0..n {
                grads[o_din + i] += g[i] * xr[i];
                gxr[i] = g[i] * d_in[i];
            }
        }
        (grads, lo, gx_chunk)
    });

    reduce_partials(lay.total, partials, gx)
}

/// Batch-fused general backward (paper §4) out of the op's
/// [`Workspace`]: per-chunk row ranges in `fused_rows` tiles; each
/// reverse stage reads the matching rows of the stage-input trace
/// (`zs[l]`) directly — the trace rows of one tile are contiguous, so no
/// copy is needed. Same in-place g_x / chunk-ordered two-phase reduction
/// contract as [`spm_backward_rotation_fused_into`].
#[allow(clippy::too_many_arguments)]
fn spm_backward_general_fused_into(
    plan: &SpmPlan,
    be: &dyn StageBackend,
    params: &[f32],
    scratch: &[f32],
    x: &Mat,
    zs: &[Mat],
    gy: &Mat,
    ws: &mut Workspace,
    grads: &mut [f32],
    gx: &mut Mat,
) {
    let n = plan.n;
    let ls = plan.num_stages;
    let lay = plan.layout;
    let d_in = &params[lay.d_in()];
    let d_out = &params[lay.d_out()];
    let rows = gy.rows;
    let (o_din, o_dout, o_bias) = (lay.d_in().start, lay.d_out().start, lay.bias().start);

    reshape_mat(gx, rows, n);
    let used = parallel::for_each_chunk_scratch(
        &mut gx.data,
        n,
        &mut ws.bwd,
        BwdScratch::default,
        |_t, first, gx_chunk, s| {
            let chunk_rows = gx_chunk.len() / n;
            let end = first + chunk_rows;
            s.grads.clear();
            s.grads.resize(lay.total, 0.0);
            let tile_rows = plan.fused_rows.min(chunk_rows.max(1));
            s.g.clear();
            s.g.resize(tile_rows * n, 0.0);
            let grads = &mut s.grads;
            let mut r0 = first;
            while r0 < end {
                let rt = tile_rows.min(end - r0);
                let g_blk = &mut s.g[..rt * n];
                for ri in 0..rt {
                    let r = r0 + ri;
                    let gyr = gy.row(r);
                    let zl = zs[ls].row(r);
                    let grow = &mut g_blk[ri * n..(ri + 1) * n];
                    for i in 0..n {
                        grads[o_bias + i] += gyr[i];
                        grads[o_dout + i] += gyr[i] * zl[i];
                        grow[i] = gyr[i] * d_out[i];
                    }
                }
                for l in (0..ls).rev() {
                    let zin = &zs[l].data[r0 * n..(r0 + rt) * n];
                    be.stage_bwd_batch(plan, params, scratch, l, g_blk, zin, grads);
                }
                for ri in 0..rt {
                    let r = r0 + ri;
                    let xr = x.row(r);
                    let grow = &g_blk[ri * n..(ri + 1) * n];
                    let gxr = &mut gx_chunk[(r - first) * n..(r - first + 1) * n];
                    for i in 0..n {
                        grads[o_din + i] += grow[i] * xr[i];
                        gxr[i] = grow[i] * d_in[i];
                    }
                }
                r0 += rt;
            }
        },
    );

    reduce_workspace(ws, used, lay.total, grads);
}

fn spm_backward_general_rowwise(
    plan: &SpmPlan,
    params: &[f32],
    x: &Mat,
    zs: &[Mat],
    gy: &Mat,
) -> (Mat, Vec<f32>) {
    let n = plan.n;
    let ls = plan.num_stages;
    let p = plan.num_pairs();
    let lay = plan.layout;
    let d_in = &params[lay.d_in()];
    let d_out = &params[lay.d_out()];
    let lone = &params[lay.lone()];
    let rows = gy.rows;
    // group offsets from the one layout definition
    let (o_din, o_dout, o_bias, o_mix) =
        (lay.d_in().start, lay.d_out().start, lay.bias().start, lay.mix(0).start);
    let stride = lay.mix_stride;
    let o_lone = lay.lone().start;

    let gx = Mat::zeros(rows, n);
    let partials = parallel::map_row_ranges(rows, |_t, range| {
        let lo = range.start;
        let mut grads = vec![0.0f32; lay.total];
        let mut gx_chunk = vec![0.0f32; range.len() * n];
        let mut g = vec![0.0f32; n];
        for r in range {
            let gyr = gy.row(r);
            let zl = zs[ls].row(r);
            for i in 0..n {
                grads[o_bias + i] += gyr[i];
                grads[o_dout + i] += gyr[i] * zl[i];
                g[i] = gyr[i] * d_out[i];
            }
            for l in (0..ls).rev() {
                let pairs = plan.stage_pairs(l);
                let m = &params[lay.mix(l)];
                let gm = o_mix + l * stride;
                let zin = zs[l].row(r); // stage INPUT
                for k in 0..p {
                    let (i, j) = (pairs[2 * k] as usize, pairs[2 * k + 1] as usize);
                    let (a, b, c, d) = (m[4 * k], m[4 * k + 1], m[4 * k + 2], m[4 * k + 3]);
                    let (x1, x2) = (zin[i], zin[j]);
                    let (d1, d2) = (g[i], g[j]);
                    // eq. (14)
                    grads[gm + 4 * k] += d1 * x1;
                    grads[gm + 4 * k + 1] += d1 * x2;
                    grads[gm + 4 * k + 2] += d2 * x1;
                    grads[gm + 4 * k + 3] += d2 * x2;
                    // eqs. (12)-(13)
                    g[i] = a * d1 + c * d2;
                    g[j] = b * d1 + d * d2;
                }
                if let Some(lv) = plan.stage_leftover(l) {
                    grads[o_lone + l] += g[lv] * zin[lv];
                    g[lv] *= lone[l];
                }
            }
            let xr = x.row(r);
            let gxr = &mut gx_chunk[(r - lo) * n..(r - lo + 1) * n];
            for i in 0..n {
                grads[o_din + i] += g[i] * xr[i];
                gxr[i] = g[i] * d_in[i];
            }
        }
        (grads, lo, gx_chunk)
    });

    reduce_partials(lay.total, partials, gx)
}

/// Phase-two reduction for the workspace-backed fused backwards: sum the
/// first `used` per-chunk partials into `ws.acc` IN CHUNK ORDER, then add
/// the accumulator to the op's gradient buffer once. Identical summation
/// order (and therefore identical f32 rounding) to [`reduce_partials`]
/// followed by the caller's `grads += partial` — starting from a zeroed
/// accumulator, `0 + p` is exactly `p`.
fn reduce_workspace(ws: &mut Workspace, used: usize, total: usize, grads: &mut [f32]) {
    ws.acc.clear();
    ws.acc.resize(total, 0.0);
    for s in &ws.bwd[..used] {
        for (a, b) in ws.acc.iter_mut().zip(&s.grads) {
            *a += b;
        }
    }
    for (g, a) in grads.iter_mut().zip(&ws.acc) {
        *g += a;
    }
}

/// (flat param-grad partial, first row index, contiguous g_x block)
type Partial = (Vec<f32>, usize, Vec<f32>);

fn reduce_partials(total: usize, partials: Vec<Partial>, mut gx: Mat) -> (Mat, Vec<f32>) {
    let n = gx.cols;
    let mut acc = vec![0.0f32; total];
    for (pg, lo, chunk) in partials {
        for (a, b) in acc.iter_mut().zip(&pg) {
            *a += b;
        }
        gx.data[lo * n..lo * n + chunk.len()].copy_from_slice(&chunk);
    }
    (gx, acc)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dense::Dense;
    use crate::optim::{Adam, SgdMomentum};
    use crate::spm::{Spm, SpmParams};
    use crate::testkit::{
        check_close, forall, numerical_grad, ALL_EXECS, ALL_SCHEDULES, ALL_VARIANTS,
    };

    /// Serializes the tests that toggle or assert on the global SIMD
    /// detection state (`backend::force_scalar` and the `SPM_EXEC`
    /// pinning assertions) so they cannot race each other.
    static EXEC_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

    /// Take [`EXEC_LOCK`] ignoring poisoning: the guarded state is
    /// restored by `ForcedScalar`'s `Drop` even across panics, so one
    /// failing test must not cascade into `PoisonError` failures in the
    /// other serialized tests.
    fn exec_lock() -> std::sync::MutexGuard<'static, ()> {
        EXEC_LOCK.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// RAII for `backend::force_scalar(true)` — restores detection even
    /// when the test body panics.
    struct ForcedScalar;

    impl ForcedScalar {
        fn new() -> ForcedScalar {
            backend::force_scalar(true);
            ForcedScalar
        }
    }

    impl Drop for ForcedScalar {
        fn drop(&mut self) {
            backend::force_scalar(false);
        }
    }

    fn mk_reference(
        n: usize,
        variant: Variant,
        schedule: Schedule,
        l: usize,
        seed: u64,
    ) -> (Spm, SpmParams) {
        let spec = SpmSpec::new(n, variant).with_schedule(schedule).with_stages(l).with_seed(seed);
        let op = Spm::new(spec);
        let mut rng = Rng::new(seed + 100);
        let p = op.init_params(&mut rng);
        (op, p)
    }

    fn randomize(p: &mut SpmParams, rng: &mut Rng) {
        for v in p.d_in.iter_mut().chain(p.d_out.iter_mut()).chain(p.bias.iter_mut()) {
            *v = 1.0 + 0.3 * rng.normal();
        }
        for m in &mut p.mix {
            for v in m.iter_mut() {
                *v += 0.3 * rng.normal();
            }
        }
        for v in &mut p.lone {
            *v = 1.0 + 0.3 * rng.normal();
        }
    }

    fn mk_planned(n: usize, variant: Variant, schedule: Schedule, l: usize, seed: u64) -> LinearOp {
        let cfg = LinearCfg::spm(n, variant).with_schedule(schedule).with_stages(l).with_seed(seed);
        let mut rng = Rng::new(seed + 100);
        let mut adam = Adam::new(1e-3);
        LinearOp::new(cfg, &mut rng, &mut adam)
    }

    #[test]
    fn flops_per_row_counts_the_structured_saving() {
        let mut rng = Rng::new(3);
        let mut adam = Adam::new(1e-3);
        let n = 64;
        let dense = LinearOp::new(LinearCfg::dense(n), &mut rng, &mut adam);
        assert_eq!(dense.flops_per_row(), (2 * n * n + n) as u64);
        // L = log2(n) stages: 3n + L * 3n, far below the dense 2n^2
        let spm = mk_planned(n, Variant::General, Schedule::Butterfly, 6, 5);
        assert_eq!(spm.flops_per_row(), (3 * n + 6 * (6 * (n / 2))) as u64);
        assert!(spm.flops_per_row() < dense.flops_per_row());
        // odd n: each stage pays 1 extra flop for the leftover scaling
        let odd = mk_planned(9, Variant::Rotation, Schedule::Shift, 2, 5);
        assert_eq!(odd.flops_per_row(), 27 + 2 * (6 * 4 + 1));
    }

    /// scalar loss L = sum(tanh(y)) for gradient checks
    fn loss_and_gy(y: &Mat) -> (f32, Mat) {
        let mut gy = y.clone();
        let mut loss = 0.0;
        for v in gy.data.iter_mut() {
            loss += v.tanh();
            let t = v.tanh();
            *v = 1.0 - t * t;
        }
        (loss, gy)
    }

    #[test]
    fn planned_forward_matches_reference() {
        forall(40, 11, |rng| {
            let n = 2 + rng.below(48);
            let l = 1 + rng.below(6);
            let variant = if rng.below(2) == 0 { Variant::Rotation } else { Variant::General };
            let sched = [Schedule::Butterfly, Schedule::Shift, Schedule::Random][rng.below(3)];
            let seed = rng.next_u64();
            let (op, mut p) = mk_reference(n, variant, sched, l, seed);
            randomize(&mut p, rng);
            let mut planned = mk_planned(n, variant, sched, l, seed);
            let packed = planned.plan().unwrap().pack_params(&p);
            planned.params_mut().copy_from_slice(&packed);
            let x = Mat::from_vec(3, n, rng.normal_vec(3 * n, 1.0));
            let want = op.forward(&p, &x);
            let got = planned.forward(&x);
            if got.max_abs_diff(&want) > 1e-5 {
                return Err(format!(
                    "forward mismatch {} (n={n} l={l} {variant:?} {sched:?})",
                    got.max_abs_diff(&want)
                ));
            }
            let (got_t, _) = planned.forward_train(&x);
            if got_t.max_abs_diff(&want) > 1e-5 {
                return Err("forward_train mismatch".into());
            }
            Ok(())
        });
    }

    #[test]
    fn planned_backward_matches_reference() {
        forall(30, 13, |rng| {
            let n = 2 + rng.below(40);
            let l = 1 + rng.below(5);
            let variant = if rng.below(2) == 0 { Variant::Rotation } else { Variant::General };
            let sched = [Schedule::Butterfly, Schedule::Shift, Schedule::Random][rng.below(3)];
            let seed = rng.next_u64();
            let (op, mut p) = mk_reference(n, variant, sched, l, seed);
            randomize(&mut p, rng);
            let mut planned = mk_planned(n, variant, sched, l, seed);
            let plan_packed = planned.plan().unwrap().pack_params(&p);
            planned.params_mut().copy_from_slice(&plan_packed);

            let x = Mat::from_vec(4, n, rng.normal_vec(4 * n, 1.0));
            let gy = Mat::from_vec(4, n, rng.normal_vec(4 * n, 1.0));

            let (_y, trace) = op.forward_trace(&p, &x);
            let (gx_ref, g_ref) = op.backward(&p, &x, &trace, &gy);
            let g_ref_flat = planned
                .plan()
                .unwrap()
                .pack(&g_ref.d_in, &g_ref.d_out, &g_ref.bias, &g_ref.mix, &g_ref.lone);

            planned.zero_grads();
            let (_yp, ptrace) = planned.forward_train(&x);
            let gx_plan = planned.backward(&x, &ptrace, &gy);

            if gx_plan.max_abs_diff(&gx_ref) > 1e-5 {
                return Err(format!("gx mismatch (n={n} l={l} {variant:?} {sched:?})"));
            }
            for (i, (a, b)) in planned.grads().iter().zip(&g_ref_flat).enumerate() {
                let scale = 1.0f32.max(a.abs()).max(b.abs());
                if (a - b).abs() > 1e-5 * scale {
                    return Err(format!(
                        "grad[{i}]: {a} vs {b} (n={n} l={l} {variant:?} {sched:?})"
                    ));
                }
            }
            Ok(())
        });
    }

    /// Every execution path (row-wise, batch-fused, simd) vs the
    /// reference, both variants x all three schedules x ragged batch
    /// sizes B in {1, 3, 97} — the remainder cases the row-block splitter
    /// and `fused_rows` tiling must get right (1 row: single-tile
    /// fallback; 3: below the thread count; 97: odd split across threads
    /// AND tiles). On builds/machines without the vectorized backend the
    /// simd column downgrades to fused (still a valid sweep member); the
    /// CI simd matrix leg is where the AVX2 kernels are guaranteed to run.
    #[test]
    fn all_exec_paths_match_reference() {
        // serialized with the force-scalar downgrade test: otherwise its
        // hook window could silently turn this sweep's Simd iterations
        // into scalar runs on the very CI leg that guarantees AVX2
        let _lock = exec_lock();
        for variant in ALL_VARIANTS {
            for sched in ALL_SCHEDULES {
                for batch in [1usize, 3, 97] {
                    let (n, l, seed) = (11, 4, 1000 + batch as u64);
                    let (op, mut p) = mk_reference(n, variant, sched, l, seed);
                    let mut rng = Rng::new(seed + 1);
                    randomize(&mut p, &mut rng);
                    let packed = SpmPlan::new(op.spec).pack_params(&p);

                    let x = Mat::from_vec(batch, n, rng.normal_vec(batch * n, 1.0));
                    let gy = Mat::from_vec(batch, n, rng.normal_vec(batch * n, 1.0));

                    let want = op.forward(&p, &x);
                    let (_y, rtrace) = op.forward_trace(&p, &x);
                    let (gx_ref, g_ref) = op.backward(&p, &x, &rtrace, &gy);
                    let g_ref_flat = SpmPlan::new(op.spec)
                        .pack(&g_ref.d_in, &g_ref.d_out, &g_ref.bias, &g_ref.mix, &g_ref.lone);

                    for exec in ALL_EXECS {
                        let mut planned = mk_planned(n, variant, sched, l, seed);
                        planned.params_mut().copy_from_slice(&packed);
                        planned.set_exec(exec);
                        let ctx = format!("{variant:?} {sched:?} B={batch} {exec:?}");
                        // on the pinned CI simd leg the vectorized backend
                        // must actually be what this iteration exercises
                        if exec == SpmExec::Simd
                            && std::env::var("SPM_EXEC").as_deref() == Ok("simd")
                            && backend::simd_compiled()
                        {
                            assert_eq!(planned.exec(), SpmExec::Simd, "{ctx}: backend lost");
                        }

                        let y = planned.forward(&x);
                        assert!(y.max_abs_diff(&want) < 1e-5, "{ctx}: fwd vs ref");
                        let (yt, trace) = planned.forward_train(&x);
                        assert!(yt.max_abs_diff(&want) < 1e-5, "{ctx}: forward_train");
                        planned.zero_grads();
                        let gx = planned.backward(&x, &trace, &gy);
                        assert!(gx.max_abs_diff(&gx_ref) < 1e-4, "{ctx}: gx");
                        check_close(planned.grads(), &g_ref_flat, 1e-3, &ctx).unwrap();
                    }
                }
            }
        }
    }

    /// Satellite: `exec = "simd"` must construct and keep full parity on
    /// builds without the vectorized backend. With detection forced off
    /// through the test hook, `set_exec` downgrades to `BatchFused` and
    /// forward/backward still match the reference; on non-simd builds the
    /// same holds without the hook.
    #[test]
    fn simd_exec_downgrades_without_support() {
        let _lock = exec_lock();
        {
            let _forced = ForcedScalar::new();
            assert!(!backend::simd_available(), "hook must disable detection");
            let (n, l, seed) = (9, 3, 77);
            for variant in ALL_VARIANTS {
                let (op, mut p) = mk_reference(n, variant, Schedule::Random, l, seed);
                let mut rng = Rng::new(seed);
                randomize(&mut p, &mut rng);
                let packed = SpmPlan::new(op.spec).pack_params(&p);
                let mut planned = mk_planned(n, variant, Schedule::Random, l, seed);
                planned.params_mut().copy_from_slice(&packed);
                planned.set_exec(SpmExec::Simd);
                assert_eq!(planned.exec(), SpmExec::BatchFused, "{variant:?}: must downgrade");

                let x = Mat::from_vec(5, n, rng.normal_vec(5 * n, 1.0));
                let gy = Mat::from_vec(5, n, rng.normal_vec(5 * n, 1.0));
                let want = op.forward(&p, &x);
                let (yt, trace) = planned.forward_train(&x);
                assert!(yt.max_abs_diff(&want) < 1e-5, "{variant:?}: downgraded fwd");
                let (_yr, rtrace) = op.forward_trace(&p, &x);
                let (gx_ref, _g_ref) = op.backward(&p, &x, &rtrace, &gy);
                planned.zero_grads();
                let gx = planned.backward(&x, &trace, &gy);
                assert!(gx.max_abs_diff(&gx_ref) < 1e-4, "{variant:?}: downgraded gx");
            }
        }
        // without the hook: on a non-simd build the downgrade is
        // compile-time; on a simd build with AVX2 the exec must stick.
        let mut op = mk_planned(8, Variant::General, Schedule::Butterfly, 2, 3);
        op.set_exec(SpmExec::Simd);
        if backend::simd_available() {
            assert_eq!(op.exec(), SpmExec::Simd);
        } else {
            assert_eq!(op.exec(), SpmExec::BatchFused);
        }
    }

    /// CI matrix hook (satellite): when `SPM_EXEC` is set, that exec path
    /// must be constructible as pinned — a simd build losing AVX2
    /// detection on a leg that exports SPM_EXEC=simd is a CI failure, not
    /// a silent fallback — and must hold forward/backward parity vs the
    /// reference. Builds without the feature compiled in are the portable
    /// downgrade case and are allowed to fall back.
    #[test]
    fn env_pinned_exec_parity() {
        let Ok(name) = std::env::var("SPM_EXEC") else { return };
        let _lock = exec_lock();
        let want = SpmExec::parse(&name)
            .unwrap_or_else(|| panic!("SPM_EXEC '{name}' is not an exec mode"));
        for variant in ALL_VARIANTS {
            let (n, l, seed) = (13, 3, 5);
            let (op, mut p) = mk_reference(n, variant, Schedule::Butterfly, l, seed);
            let mut rng = Rng::new(seed + 2);
            randomize(&mut p, &mut rng);
            let packed = SpmPlan::new(op.spec).pack_params(&p);
            let mut planned = mk_planned(n, variant, Schedule::Butterfly, l, seed);
            planned.params_mut().copy_from_slice(&packed);
            planned.set_exec(want);
            if want == SpmExec::Simd && !backend::simd_compiled() {
                assert_eq!(planned.exec(), SpmExec::BatchFused, "portable downgrade");
            } else {
                assert_eq!(planned.exec(), want, "SPM_EXEC={name} was downgraded");
            }

            let x = Mat::from_vec(6, n, rng.normal_vec(6 * n, 1.0));
            let gy = Mat::from_vec(6, n, rng.normal_vec(6 * n, 1.0));
            let want_y = op.forward(&p, &x);
            let (yt, trace) = planned.forward_train(&x);
            assert!(yt.max_abs_diff(&want_y) < 1e-5, "{variant:?}: pinned fwd");
            let (_yr, rtrace) = op.forward_trace(&p, &x);
            let (gx_ref, g_ref) = op.backward(&p, &x, &rtrace, &gy);
            let g_ref_flat = SpmPlan::new(op.spec)
                .pack(&g_ref.d_in, &g_ref.d_out, &g_ref.bias, &g_ref.mix, &g_ref.lone);
            planned.zero_grads();
            let gx = planned.backward(&x, &trace, &gy);
            assert!(gx.max_abs_diff(&gx_ref) < 1e-4, "{variant:?}: pinned gx");
            check_close(planned.grads(), &g_ref_flat, 1e-3, &format!("{variant:?} pinned"))
                .unwrap();
        }
    }

    #[test]
    fn planned_param_grads_finite_difference() {
        // central FD over every parameter group, both variants x all
        // schedules (satellite: rotation/general x butterfly/shift/random),
        // on EVERY execution path — each backward must stand on its own
        // against numerics, not just against the other paths (simd
        // downgrades to fused where the vectorized backend is absent).
        // Serialized with the force-scalar downgrade test so the Simd
        // iterations cannot silently fall back mid-sweep (see
        // all_exec_paths_match_reference).
        let _lock = exec_lock();
        for exec in ALL_EXECS {
            for variant in ALL_VARIANTS {
                for sched in ALL_SCHEDULES {
                    let n = 9;
                    let mut op = mk_planned(n, variant, sched, 3, 17);
                    op.set_exec(exec);
                    let mut rng = Rng::new(19);
                    // nudge params off the orthogonal init
                    for v in op.params_mut().iter_mut() {
                        *v += 0.1 * rng.normal();
                    }
                    let x = Mat::from_vec(3, n, rng.normal_vec(3 * n, 1.0));
                    let (y, trace) = op.forward_train(&x);
                    let (_l, gy) = loss_and_gy(&y);
                    op.zero_grads();
                    let _gx = op.backward(&x, &trace, &gy);

                    let mut pv = op.params().to_vec();
                    let total = pv.len();
                    // sample indices across all five layout groups
                    let idxs = [0, n / 2, n, 2 * n, 2 * n + 1, 3 * n, 3 * n + 2, total - 1];
                    for &idx in &idxs {
                        let got = op.grads()[idx];
                        let num = numerical_grad(&mut pv, idx, 1e-2, |v| {
                            op.forward_with(v, &x).data.iter().map(|t| t.tanh()).sum()
                        });
                        assert!(
                            (got - num).abs() < 3e-2 * (1.0f32.max(num.abs())),
                            "{exec:?} {variant:?} {sched:?} grad[{idx}]: {got} vs {num}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn planned_input_grad_finite_difference() {
        for variant in [Variant::Rotation, Variant::General] {
            let mut op = mk_planned(12, variant, Schedule::Butterfly, 3, 23);
            let mut rng = Rng::new(29);
            for v in op.params_mut().iter_mut() {
                *v += 0.1 * rng.normal();
            }
            let mut xv = rng.normal_vec(2 * 12, 1.0);
            let x = Mat::from_vec(2, 12, xv.clone());
            let (y, trace) = op.forward_train(&x);
            let (_l, gy) = loss_and_gy(&y);
            let gx = op.backward(&x, &trace, &gy);
            for idx in [0usize, 5, 13, 23] {
                let got = gx.data[idx];
                let num = numerical_grad(&mut xv, idx, 1e-2, |v| {
                    let xm = Mat::from_vec(2, 12, v.to_vec());
                    op.forward(&xm).data.iter().map(|t| t.tanh()).sum()
                });
                assert!(
                    (got - num).abs() < 3e-2 * (1.0f32.max(num.abs())),
                    "{variant:?} gx[{idx}]: {got} vs {num}"
                );
            }
        }
    }

    #[test]
    fn dense_matches_reference_dense_layer() {
        let mut rng = Rng::new(31);
        let reference = Dense::init(&mut rng, 4, 6);
        let mut adam = Adam::new(1e-3);
        let mut op =
            LinearOp::new(LinearCfg::dense_rect(4, 6), &mut Rng::new(99), &mut adam);
        // copy the reference weights into the flat [w | b] layout
        op.params_mut()[..24].copy_from_slice(&reference.w.data);
        let bvals: Vec<f32> = rng.normal_vec(4, 0.5);
        op.params_mut()[24..].copy_from_slice(&bvals);
        let mut reference = reference;
        reference.b = bvals;

        let x = Mat::from_vec(3, 6, rng.normal_vec(18, 1.0));
        let want = reference.forward(&x);
        let got = op.forward(&x);
        assert!(got.max_abs_diff(&want) < 1e-6);

        let gy = Mat::from_vec(3, 4, rng.normal_vec(12, 1.0));
        let (gx_ref, gref) = reference.backward(&x, &gy);
        let (_, trace) = op.forward_train(&x);
        let gx = op.backward(&x, &trace, &gy);
        assert!(gx.max_abs_diff(&gx_ref) < 1e-5);
        for (a, b) in op.grads()[..24].iter().zip(&gref.w.data) {
            assert!((a - b).abs() < 1e-5, "{a} vs {b}");
        }
        for (a, b) in op.grads()[24..].iter().zip(&gref.b) {
            assert!((a - b).abs() < 1e-5, "{a} vs {b}");
        }
    }

    #[test]
    fn backward_accumulates_across_calls() {
        let mut op = mk_planned(8, Variant::General, Schedule::Shift, 2, 41);
        let mut rng = Rng::new(43);
        let x = Mat::from_vec(2, 8, rng.normal_vec(16, 1.0));
        let gy = Mat::from_vec(2, 8, rng.normal_vec(16, 1.0));
        let (_y, tr) = op.forward_train(&x);
        op.zero_grads();
        let _ = op.backward(&x, &tr, &gy);
        let once = op.grads().to_vec();
        let _ = op.backward(&x, &tr, &gy);
        for (twice, one) in op.grads().iter().zip(&once) {
            assert!((twice - 2.0 * one).abs() < 1e-5 * (1.0 + one.abs()));
        }
    }

    #[test]
    fn apply_grads_descends_with_adam_and_momentum() {
        for use_momentum in [false, true] {
            let cfg = LinearCfg::spm(8, Variant::General).with_schedule(Schedule::Shift);
            let mut rng = Rng::new(2);
            let mut adam = Adam::new(0.05);
            let mut sgd = SgdMomentum::new(0.02, 0.9);
            let mut op = if use_momentum {
                LinearOp::new(cfg, &mut rng, &mut sgd)
            } else {
                LinearOp::new(cfg, &mut rng, &mut adam)
            };
            let x = Mat::from_vec(16, 8, rng.normal_vec(128, 1.0));
            let loss_of = |op: &LinearOp| {
                let y = op.forward(&x);
                y.data.iter().map(|v| v * v).sum::<f32>() / y.data.len() as f32
            };
            let before = loss_of(&op);
            for _ in 0..30 {
                let (y, trace) = op.forward_train(&x);
                let mut gy = y;
                let m = gy.data.len() as f32;
                for v in gy.data.iter_mut() {
                    *v = 2.0 * *v / m;
                }
                let _gx = op.backward(&x, &trace, &gy);
                if use_momentum {
                    op.apply_grads(&mut sgd);
                } else {
                    adam.next_step();
                    op.apply_grads(&mut adam);
                }
            }
            let after = loss_of(&op);
            assert!(after < before * 0.5, "momentum={use_momentum}: {before} -> {after}");
        }
    }

    #[test]
    fn spm_param_count_below_dense() {
        let mut adam = Adam::new(1e-3);
        let mut rng = Rng::new(3);
        let d = LinearOp::new(LinearCfg::dense(128), &mut rng, &mut adam);
        let s = LinearOp::new(LinearCfg::spm(128, Variant::General), &mut rng, &mut adam);
        assert!(s.param_count() < d.param_count() / 4);
        assert_eq!(d.param_count(), 128 * 128 + 128);
    }

    #[test]
    fn all_kinds_round_trip_shapes() {
        for kind in LinearKind::ALL {
            let cfg = LinearCfg { kind, ..LinearCfg::spm(16, Variant::General) };
            let mut adam = Adam::new(1e-3);
            let mut rng = Rng::new(1);
            let mut op = LinearOp::new(cfg, &mut rng, &mut adam);
            assert_eq!(op.kind(), kind);
            let x = Mat::from_vec(4, 16, rng.normal_vec(64, 1.0));
            let (y, trace) = op.forward_train(&x);
            assert_eq!((y.rows, y.cols), (4, 16), "{}", kind.name());
            let gx = op.backward(&x, &trace, &y);
            assert_eq!((gx.rows, gx.cols), (4, 16), "{}", kind.name());
        }
    }

    #[test]
    fn rectangular_dense_head_shapes() {
        let mut adam = Adam::new(1e-3);
        let mut rng = Rng::new(5);
        let mut head = LinearOp::new(LinearCfg::dense_rect(3, 10), &mut rng, &mut adam);
        let x = Mat::from_vec(7, 10, rng.normal_vec(70, 1.0));
        let (y, tr) = head.forward_train(&x);
        assert_eq!((y.rows, y.cols), (7, 3));
        let gy = Mat::from_vec(7, 3, rng.normal_vec(21, 1.0));
        let gx = head.backward(&x, &tr, &gy);
        assert_eq!((gx.rows, gx.cols), (7, 10));
    }

    // ---- structured-operator zoo (DESIGN.md §19) ----

    fn mk_zoo(cfg: LinearCfg, seed: u64) -> LinearOp {
        let mut rng = Rng::new(seed + 100);
        let mut adam = Adam::new(1e-3);
        LinearOp::new(cfg.with_seed(seed), &mut rng, &mut adam)
    }

    /// Satellite (bugfix): ONE FLOP convention — multiply-adds counted
    /// individually, bias included — pinned per kind so ablate FLOP
    /// columns compare like for like.
    #[test]
    fn zoo_flops_formulas_pinned() {
        let n = 16;
        assert_eq!(mk_zoo(LinearCfg::dense(n), 1).flops_per_row(), (2 * n * n + n) as u64);
        // default depth at n=16 is log2(16) = 4 stages
        let spm = mk_zoo(LinearCfg::spm(n, Variant::General), 1);
        assert_eq!(spm.flops_per_row(), (3 * n + 4 * (6 * (n / 2))) as u64);
        // butterfly = the same stage arithmetic as general SPM
        let bfly = mk_zoo(LinearCfg::butterfly(n), 1);
        assert_eq!(bfly.flops_per_row(), spm.flops_per_row());
        // default budget-matched picks at n=16: rank 5, block 8
        let lr = mk_zoo(LinearCfg::lowrank(n), 1);
        assert_eq!(lr.rank(), Some(5));
        assert_eq!(lr.flops_per_row(), (2 * 5 * (n + n) + n) as u64);
        let bsh = mk_zoo(LinearCfg::blockshuffle(n), 1);
        assert_eq!(bsh.block_size(), Some(8));
        assert_eq!(bsh.flops_per_row(), (2 * n * 8 + n) as u64);
    }

    /// Satellite: the equal-parameter-budget helpers the zoo plans lean
    /// on. Defaults land each kind as close to the default-SPM param
    /// count as its structure allows.
    #[test]
    fn zoo_equal_budget_defaults() {
        // spm_budget(16): 3n + L*4*(n/2) + L at L=4
        assert_eq!(spm_budget(16), 180);
        assert_eq!(mk_zoo(LinearCfg::spm(16, Variant::General), 7).param_count(), 180);
        assert_eq!(rank_for_budget(16, 16, 180), 5);
        assert_eq!(block_for_budget(16, 180), 8);
        // rank clamps into [1, min(d_in, d_out)]
        assert_eq!(rank_for_budget(4, 4, 1_000_000), 4);
        assert_eq!(rank_for_budget(64, 64, 0), 1);
        let lr = mk_zoo(LinearCfg::lowrank(16), 7);
        assert_eq!(lr.param_count(), 5 * 16 + 5 * 16 + 16);
        let bsh = mk_zoo(LinearCfg::blockshuffle(16), 7);
        assert_eq!(bsh.param_count(), 16 * 8 + 16);
        // butterfly param count is IDENTICAL to general SPM at the same
        // width/depth — the budget match is structural, not approximate
        let bfly = mk_zoo(LinearCfg::butterfly(16), 7);
        assert_eq!(bfly.param_count(), 180);
    }

    /// A butterfly op IS the general-SPM machinery pinned to the
    /// butterfly schedule: same seed -> bit-identical params and
    /// forwards; only the kind tag (and hence config/fingerprint
    /// identity) differs.
    #[test]
    fn butterfly_matches_spm_on_butterfly_schedule() {
        let bfly = mk_zoo(LinearCfg::butterfly(12), 3);
        let spm = mk_zoo(LinearCfg::spm(12, Variant::General).with_schedule(Schedule::Butterfly), 3);
        assert_eq!(bfly.params(), spm.params());
        assert_eq!(bfly.kind(), LinearKind::Butterfly);
        assert_eq!(spm.kind(), LinearKind::Spm);
        assert!(bfly.plan().is_some());
        let mut rng = Rng::new(9);
        let x = Mat::from_vec(5, 12, rng.normal_vec(60, 1.0));
        assert_eq!(bfly.forward(&x).data, spm.forward(&x).data);
        // a rotation-variant or shift-schedule config does not leak in:
        // butterfly_spec pins variant/schedule regardless of the cfg
        let pinned = mk_zoo(
            LinearCfg::butterfly(12).with_schedule(Schedule::Shift),
            3,
        );
        assert_eq!(pinned.params(), bfly.params());
    }

    /// Low-rank forward/backward against an explicitly materialized
    /// dense W = U·V: same y, same g_x, same bias gradient.
    #[test]
    fn lowrank_matches_materialized_dense() {
        let (d_out, d_in, r) = (6, 9, 3);
        let cfg = LinearCfg {
            kind: LinearKind::LowRank,
            ..LinearCfg::dense_rect(d_out, d_in)
        }
        .with_rank(r);
        let mut lr = mk_zoo(cfg, 11);
        assert_eq!(lr.rank(), Some(r));
        assert_eq!(lr.param_count(), d_out * r + r * d_in + d_out);
        let (u, rest) = lr.params().split_at(d_out * r);
        let (v, bias) = rest.split_at(r * d_in);
        // W[o][i] = sum_k U[o][k] * V[k][i]
        let mut w = vec![0.0f32; d_out * d_in];
        for o in 0..d_out {
            for i in 0..d_in {
                for k in 0..r {
                    w[o * d_in + i] += u[o * r + k] * v[k * d_in + i];
                }
            }
        }
        let bias = bias.to_vec();
        let mut dense = mk_zoo(LinearCfg::dense_rect(d_out, d_in), 12);
        dense.params_mut()[..d_out * d_in].copy_from_slice(&w);
        dense.params_mut()[d_out * d_in..].copy_from_slice(&bias);

        let mut rng = Rng::new(13);
        let x = Mat::from_vec(4, d_in, rng.normal_vec(4 * d_in, 1.0));
        let want = dense.forward(&x);
        assert!(lr.forward(&x).max_abs_diff(&want) < 1e-5);

        let gy = Mat::from_vec(4, d_out, rng.normal_vec(4 * d_out, 1.0));
        let (_yd, dtr) = dense.forward_train(&x);
        let gx_ref = dense.backward(&x, &dtr, &gy);
        let (_yl, ltr) = lr.forward_train(&x);
        lr.zero_grads();
        let gx = lr.backward(&x, &ltr, &gy);
        assert!(gx.max_abs_diff(&gx_ref) < 1e-4);
        let glen = lr.param_count();
        for (a, b) in lr.grads()[glen - d_out..]
            .iter()
            .zip(&dense.grads()[d_out * d_in..])
        {
            assert!((a - b).abs() < 1e-5, "bias grad {a} vs {b}");
        }
    }

    /// Block-shuffle forward/backward against the dense op whose W has
    /// each block scattered at `W[base+i][perm[base+j]]`: same y, same
    /// g_x, and each block gradient matches its scattered dense slot.
    #[test]
    fn blockshuffle_matches_materialized_dense() {
        let (n, bs) = (12, 4);
        let mut bsh = mk_zoo(LinearCfg::blockshuffle(n).with_block(bs), 21);
        assert_eq!(bsh.block_size(), Some(bs));
        let perm = bsh.shuffle().unwrap().to_vec();
        let blocks = bsh.params()[..n * bs].to_vec();
        let bias = bsh.params()[n * bs..].to_vec();
        let mut w = vec![0.0f32; n * n];
        for k in 0..n / bs {
            let base = k * bs;
            for i in 0..bs {
                for j in 0..bs {
                    let src = perm[base + j] as usize;
                    w[(base + i) * n + src] = blocks[(base * bs) + i * bs + j];
                }
            }
        }
        let mut dense = mk_zoo(LinearCfg::dense(n), 22);
        dense.params_mut()[..n * n].copy_from_slice(&w);
        dense.params_mut()[n * n..].copy_from_slice(&bias);

        let mut rng = Rng::new(23);
        let x = Mat::from_vec(5, n, rng.normal_vec(5 * n, 1.0));
        let want = dense.forward(&x);
        assert!(bsh.forward(&x).max_abs_diff(&want) < 1e-5);

        let gy = Mat::from_vec(5, n, rng.normal_vec(5 * n, 1.0));
        let (_yd, dtr) = dense.forward_train(&x);
        let gx_ref = dense.backward(&x, &dtr, &gy);
        let (_yb, btr) = bsh.forward_train(&x);
        bsh.zero_grads();
        let gx = bsh.backward(&x, &btr, &gy);
        assert!(gx.max_abs_diff(&gx_ref) < 1e-4);
        for k in 0..n / bs {
            let base = k * bs;
            for i in 0..bs {
                for j in 0..bs {
                    let src = perm[base + j] as usize;
                    let a = bsh.grads()[(base * bs) + i * bs + j];
                    let b = dense.grads()[(base + i) * n + src];
                    assert!((a - b).abs() < 1e-5, "block grad {a} vs {b}");
                }
            }
        }
        for (a, b) in bsh.grads()[n * bs..].iter().zip(&dense.grads()[n * n..]) {
            assert!((a - b).abs() < 1e-5, "bias grad {a} vs {b}");
        }
    }

    /// Satellite: central-FD parameter + input gradient checks for every
    /// new kind (dense/spm have their own suites above).
    #[test]
    fn zoo_param_and_input_grads_finite_difference() {
        let n = 8;
        let cfgs = [
            LinearCfg::lowrank(n).with_rank(3),
            LinearCfg::blockshuffle(n).with_block(4),
            LinearCfg::butterfly(n).with_stages(3),
        ];
        for cfg in cfgs {
            let kind = cfg.kind;
            let mut op = mk_zoo(cfg, 17);
            let mut rng = Rng::new(19);
            for v in op.params_mut().iter_mut() {
                *v += 0.1 * rng.normal();
            }
            let mut xv = rng.normal_vec(3 * n, 1.0);
            let x = Mat::from_vec(3, n, xv.clone());
            let (y, trace) = op.forward_train(&x);
            let (_l, gy) = loss_and_gy(&y);
            op.zero_grads();
            let gx = op.backward(&x, &trace, &gy);

            let mut pv = op.params().to_vec();
            let total = pv.len();
            // endpoints + interior samples cover every layout group of
            // every kind (U/V/bias, blocks/bias, diag/mix/lone)
            let idxs = [0, 1, total / 3, total / 2, 2 * total / 3, total - 2, total - 1];
            for &idx in &idxs {
                let got = op.grads()[idx];
                let num = numerical_grad(&mut pv, idx, 1e-2, |v| {
                    op.forward_with(v, &x).data.iter().map(|t| t.tanh()).sum()
                });
                assert!(
                    (got - num).abs() < 3e-2 * (1.0f32.max(num.abs())),
                    "{} grad[{idx}]: {got} vs {num}",
                    kind.name()
                );
            }
            for idx in [0usize, 7, 12, 23] {
                let got = gx.data[idx];
                let num = numerical_grad(&mut xv, idx, 1e-2, |v| {
                    let xm = Mat::from_vec(3, n, v.to_vec());
                    op.forward(&xm).data.iter().map(|t| t.tanh()).sum()
                });
                assert!(
                    (got - num).abs() < 3e-2 * (1.0f32.max(num.abs())),
                    "{} gx[{idx}]: {got} vs {num}",
                    kind.name()
                );
            }
        }
    }

    /// Satellite: forward/backward parity across ALL exec paths and
    /// ragged B in {1, 3, 97} for the new kinds. Low-rank and
    /// block-shuffle have a single kernel (exec is a no-op) — every exec
    /// must be bit-identical; butterfly rides the SPM rowwise/fused/simd
    /// paths and must agree within SPM's parity tolerance.
    #[test]
    fn zoo_exec_and_batch_parity() {
        let _lock = exec_lock();
        let n = 11;
        let cfgs = [
            LinearCfg::lowrank(n).with_rank(4),
            LinearCfg::blockshuffle(n).with_block(11),
            LinearCfg::butterfly(n).with_stages(4),
        ];
        for cfg in cfgs {
            let kind = cfg.kind;
            for batch in [1usize, 3, 97] {
                let mut rng = Rng::new(2000 + batch as u64);
                let x = Mat::from_vec(batch, n, rng.normal_vec(batch * n, 1.0));
                let gy = Mat::from_vec(batch, n, rng.normal_vec(batch * n, 1.0));
                let mut want_y: Option<Mat> = None;
                let mut want_gx: Option<Mat> = None;
                let mut want_g: Option<Vec<f32>> = None;
                for exec in ALL_EXECS {
                    let mut op = mk_zoo(cfg, 31);
                    op.set_exec(exec);
                    let ctx = format!("{} B={batch} {exec:?}", kind.name());
                    let y = op.forward(&x);
                    let (yt, trace) = op.forward_train(&x);
                    assert!(yt.max_abs_diff(&y) < 1e-6, "{ctx}: train fwd");
                    let yw = op.forward_with(&op.params().to_vec(), &x);
                    assert!(yw.max_abs_diff(&y) < 1e-6, "{ctx}: forward_with");
                    op.zero_grads();
                    let gx = op.backward(&x, &trace, &gy);
                    match (&want_y, &want_gx, &want_g) {
                        (Some(wy), Some(wgx), Some(wg)) => {
                            assert!(y.max_abs_diff(wy) < 1e-5, "{ctx}: fwd parity");
                            assert!(gx.max_abs_diff(wgx) < 1e-4, "{ctx}: gx parity");
                            check_close(op.grads(), wg, 1e-3, &ctx).unwrap();
                        }
                        _ => {
                            want_y = Some(y);
                            want_gx = Some(gx);
                            want_g = Some(op.grads().to_vec());
                        }
                    }
                }
            }
        }
    }

    /// Rectangular low-rank read-out heads work like rectangular dense
    /// ones (the two kinds the factory allows off the square path).
    #[test]
    fn rectangular_lowrank_head_shapes() {
        let cfg = LinearCfg {
            kind: LinearKind::LowRank,
            ..LinearCfg::dense_rect(3, 10)
        }
        .with_rank(2);
        let mut head = mk_zoo(cfg, 41);
        assert_eq!(head.param_count(), 3 * 2 + 2 * 10 + 3);
        let mut rng = Rng::new(42);
        let x = Mat::from_vec(7, 10, rng.normal_vec(70, 1.0));
        let (y, tr) = head.forward_train(&x);
        assert_eq!((y.rows, y.cols), (7, 3));
        let gy = Mat::from_vec(7, 3, rng.normal_vec(21, 1.0));
        let gx = head.backward(&x, &tr, &gy);
        assert_eq!((gx.rows, gx.cols), (7, 10));
    }
}
