//! Tiny scoped-thread data-parallel helper (rayon is not in the offline
//! vendor set, and we want explicit control over thread count anyway: the
//! paper's timings are quoted at a fixed CPU thread budget).

use std::cell::Cell;
use std::sync::atomic::{AtomicUsize, Ordering};

static THREADS: AtomicUsize = AtomicUsize::new(0);

thread_local! {
    /// Per-thread worker-budget override (0 = defer to the global
    /// setting). Engine workers set this via [`with_thread_budget`] so R
    /// replicas split one core budget instead of each claiming
    /// `available_parallelism()` (R-fold oversubscription).
    static THREAD_BUDGET: Cell<usize> = const { Cell::new(0) };
}

/// Override the worker count (0 = auto). Mirrors the paper's "OpenMP with
/// two threads" setting when the coordinator pins `--threads 2`.
pub fn set_threads(n: usize) {
    THREADS.store(n, Ordering::Relaxed);
}

/// Run `f` with THIS thread's worker budget pinned to `n` (0 = defer to
/// the process-global [`set_threads`] setting). The override is
/// thread-local and restored on exit — even across panics — so engines
/// that run replicas on worker threads can give each replica
/// `floor(budget / R)` cores without touching the global static (which
/// would race between engines and leak into unrelated callers).
///
/// The budget does NOT propagate into threads spawned inside `f`: the
/// parallel helpers read it on the thread that CALLS them, which is
/// exactly where an engine worker drives its model's kernels.
pub fn with_thread_budget<R>(n: usize, f: impl FnOnce() -> R) -> R {
    struct Restore(usize);
    impl Drop for Restore {
        fn drop(&mut self) {
            THREAD_BUDGET.with(|c| c.set(self.0));
        }
    }
    let _restore = Restore(THREAD_BUDGET.with(|c| c.replace(n)));
    f()
}

pub fn num_threads() -> usize {
    let local = THREAD_BUDGET.with(|c| c.get());
    if local > 0 {
        return local;
    }
    let n = THREADS.load(Ordering::Relaxed);
    if n > 0 {
        return n;
    }
    std::thread::available_parallelism().map(|v| v.get()).unwrap_or(1)
}

/// Split `data` (logically `data.len()/row_len` rows) into per-thread row
/// chunks and run `f(first_row_index, chunk)` on each in parallel.
pub fn for_each_chunk<F>(data: &mut [f32], row_len: usize, f: F)
where
    F: Fn(usize, &mut [f32]) + Sync,
{
    let rows = if row_len == 0 { 0 } else { data.len() / row_len };
    let nt = num_threads().min(rows.max(1));
    if nt <= 1 || rows < 2 {
        f(0, data);
        return;
    }
    let rows_per = rows.div_ceil(nt);
    std::thread::scope(|scope| {
        let mut rest = data;
        let mut start_row = 0;
        while !rest.is_empty() {
            let take = (rows_per * row_len).min(rest.len());
            let (chunk, tail) = rest.split_at_mut(take);
            let fr = &f;
            let sr = start_row;
            scope.spawn(move || fr(sr, chunk));
            start_row += take / row_len;
            rest = tail;
        }
    });
}

/// Like [`for_each_chunk`], but hands each thread the matching row chunk
/// of every buffer in `extras` alongside its chunk of `data` (all buffers
/// logically `rows x row_len`, identical length). This is what the
/// batch-fused SPM `forward_train` needs: one parallel region that sweeps
/// all stages over a row block while writing per-stage trace snapshots
/// into separate buffers at the same row offsets.
pub fn for_each_chunk_with<F>(data: &mut [f32], extras: &mut [&mut [f32]], row_len: usize, f: F)
where
    F: Fn(usize, &mut [f32], &mut [&mut [f32]]) + Sync,
{
    for e in extras.iter() {
        assert_eq!(e.len(), data.len(), "extra buffer shape");
    }
    let rows = if row_len == 0 { 0 } else { data.len() / row_len };
    let nt = num_threads().min(rows.max(1));
    if nt <= 1 || rows < 2 {
        f(0, data, extras);
        return;
    }
    let rows_per = rows.div_ceil(nt);
    std::thread::scope(|scope| {
        let mut rest = data;
        let mut rest_extras: Vec<&mut [f32]> = extras.iter_mut().map(|e| &mut **e).collect();
        let mut start_row = 0;
        while !rest.is_empty() {
            let take = (rows_per * row_len).min(rest.len());
            let (chunk, tail) = rest.split_at_mut(take);
            rest = tail;
            let mut echunks: Vec<&mut [f32]> = Vec::with_capacity(rest_extras.len());
            let mut etails: Vec<&mut [f32]> = Vec::with_capacity(rest_extras.len());
            for e in rest_extras {
                let (c, t) = e.split_at_mut(take);
                echunks.push(c);
                etails.push(t);
            }
            rest_extras = etails;
            let fr = &f;
            let sr = start_row;
            scope.spawn(move || fr(sr, chunk, &mut echunks));
            start_row += take / row_len;
        }
    });
}

/// Like [`for_each_chunk`], but hands chunk `t` exclusive access to
/// `scratch[t]` alongside its rows: the zero-allocation replacement for
/// [`map_row_ranges`] in the fused backward. Callers pre-size `data` to
/// the full output, keep one scratch slot per chunk alive across calls,
/// and reduce `scratch[..returned]` afterwards in chunk order — the same
/// deterministic order [`map_row_ranges`] joined its partials in, so the
/// two-phase gradient reduction stays bit-identical. The row split is the
/// same `rows.div_ceil(nt)` partition both other helpers use. Scratch
/// slots are created with `mk` on demand and never shrunk. Returns the
/// number of chunks actually run.
pub fn for_each_chunk_scratch<S, F>(
    data: &mut [f32],
    row_len: usize,
    scratch: &mut Vec<S>,
    mk: impl FnMut() -> S,
    f: F,
) -> usize
where
    S: Send,
    F: Fn(usize, usize, &mut [f32], &mut S) + Sync,
{
    let rows = if row_len == 0 { 0 } else { data.len() / row_len };
    let nt = num_threads().min(rows.max(1));
    if scratch.len() < nt {
        scratch.resize_with(nt, mk);
    }
    if nt <= 1 {
        f(0, 0, data, &mut scratch[0]);
        return 1;
    }
    let rows_per = rows.div_ceil(nt);
    let mut used = 0;
    std::thread::scope(|scope| {
        let mut rest = data;
        let mut srest = &mut scratch[..];
        let mut start_row = 0;
        while !rest.is_empty() {
            let take = (rows_per * row_len).min(rest.len());
            let (chunk, tail) = rest.split_at_mut(take);
            rest = tail;
            let (slot, stail) = srest.split_first_mut().unwrap();
            srest = stail;
            let fr = &f;
            let sr = start_row;
            let ti = used;
            scope.spawn(move || fr(ti, sr, chunk, slot));
            start_row += take / row_len;
            used += 1;
        }
    });
    used
}

/// Run `f(thread_idx, row_range)` over `rows` rows in parallel and collect
/// one partial result per thread (for gradient-accumulator reduction).
pub fn map_row_ranges<T, F>(rows: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize, std::ops::Range<usize>) -> T + Sync,
{
    let nt = num_threads().min(rows.max(1));
    if nt <= 1 {
        return vec![f(0, 0..rows)];
    }
    let rows_per = rows.div_ceil(nt);
    std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for t in 0..nt {
            let lo = t * rows_per;
            if lo >= rows {
                break;
            }
            let hi = (lo + rows_per).min(rows);
            let fr = &f;
            handles.push(scope.spawn(move || fr(t, lo..hi)));
        }
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunks_cover_all_rows() {
        let mut data = vec![0.0f32; 103 * 4];
        for_each_chunk(&mut data, 4, |first, chunk| {
            for (i, row) in chunk.chunks_mut(4).enumerate() {
                row[0] = (first + i) as f32;
            }
        });
        for r in 0..103 {
            assert_eq!(data[r * 4], r as f32);
        }
    }

    #[test]
    fn map_ranges_disjoint_and_total() {
        let parts = map_row_ranges(57, |_, r| r);
        let mut seen = vec![false; 57];
        for r in parts {
            for i in r {
                assert!(!seen[i]);
                seen[i] = true;
            }
        }
        assert!(seen.iter().all(|&b| b));
    }

    #[test]
    fn chunk_with_extras_stays_row_aligned() {
        // ragged row count: every thread must see the same rows of `data`
        // and of each extra buffer, at the same chunk-relative offsets
        let mut data = vec![0.0f32; 103 * 4];
        let mut e0 = vec![0.0f32; 103 * 4];
        let mut e1 = vec![0.0f32; 103 * 4];
        for_each_chunk_with(&mut data, &mut [&mut e0, &mut e1], 4, |first, chunk, extras| {
            assert_eq!(chunk.len() % 4, 0, "chunk not row aligned");
            for e in extras.iter() {
                assert_eq!(e.len(), chunk.len(), "extra chunk shape");
            }
            for (i, row) in chunk.chunks_mut(4).enumerate() {
                row[0] = (first + i) as f32;
                extras[0][i * 4] = (first + i) as f32 + 0.5;
                extras[1][i * 4 + 1] = (first + i) as f32 + 0.25;
            }
        });
        for r in 0..103 {
            assert_eq!(data[r * 4], r as f32);
            assert_eq!(e0[r * 4], r as f32 + 0.5);
            assert_eq!(e1[r * 4 + 1], r as f32 + 0.25);
        }
    }

    #[test]
    fn chunk_with_no_extras_matches_plain() {
        let mut data = vec![0.0f32; 7 * 3];
        for_each_chunk_with(&mut data, &mut [], 3, |first, chunk, _extras| {
            for (i, row) in chunk.chunks_mut(3).enumerate() {
                row[2] = (first + i) as f32;
            }
        });
        for r in 0..7 {
            assert_eq!(data[r * 3 + 2], r as f32);
        }
    }

    #[test]
    fn thread_budget_overrides_on_this_thread_only() {
        let before = num_threads();
        let (inside, nested) = with_thread_budget(2, || {
            let nested = with_thread_budget(5, num_threads);
            (num_threads(), nested)
        });
        assert_eq!(inside, 2, "override must be visible inside the closure");
        assert_eq!(nested, 5, "nested override wins, then restores");
        assert_eq!(num_threads(), before, "override must not outlive the closure");
        // another thread never sees this thread's budget
        let other = with_thread_budget(2, || std::thread::spawn(num_threads).join().unwrap());
        assert_eq!(other, before, "budget is thread-local, not global");
    }

    #[test]
    fn thread_budget_restores_across_panics() {
        let before = num_threads();
        let caught = std::panic::catch_unwind(|| {
            with_thread_budget(3, || panic!("boom"));
        });
        assert!(caught.is_err());
        assert_eq!(num_threads(), before, "budget must restore even on unwind");
    }

    #[test]
    fn for_each_chunk_honours_the_budget() {
        // 8 rows under a budget of 2 must split into exactly 2 chunks
        let calls = std::sync::atomic::AtomicUsize::new(0);
        let mut data = vec![0.0f32; 8 * 4];
        with_thread_budget(2, || {
            for_each_chunk(&mut data, 4, |_first, chunk| {
                calls.fetch_add(1, Ordering::SeqCst);
                assert_eq!(chunk.len(), 4 * 4, "even split under budget 2");
            });
        });
        assert_eq!(calls.load(Ordering::SeqCst), 2);
    }

    #[test]
    fn map_row_ranges_honours_the_budget() {
        let parts = with_thread_budget(3, || map_row_ranges(9, |_t, r| r));
        assert_eq!(parts.len(), 3, "budget 3 over 9 rows = 3 ranges");
        assert_eq!(parts.iter().map(|r| r.len()).sum::<usize>(), 9);
    }

    #[test]
    fn chunk_scratch_splits_like_map_ranges_and_reuses_slots() {
        let mut data = vec![0.0f32; 9 * 2];
        let mut scratch: Vec<Vec<usize>> = Vec::new();
        let used = with_thread_budget(3, || {
            for_each_chunk_scratch(&mut data, 2, &mut scratch, Vec::new, |t, first, chunk, s| {
                s.push(t);
                s.push(first);
                s.push(chunk.len() / 2);
            })
        });
        assert_eq!(used, 3, "budget 3 over 9 rows = 3 chunks");
        assert_eq!(scratch.len(), 3);
        for (t, slot) in scratch.iter().enumerate() {
            assert_eq!(slot, &vec![t, t * 3, 3], "chunk {t} rows/order");
        }
        let used2 = with_thread_budget(1, || {
            for_each_chunk_scratch(&mut data, 2, &mut scratch, Vec::new, |t, first, chunk, s| {
                assert_eq!((t, first), (0, 0));
                assert_eq!(chunk.len(), 9 * 2, "single chunk sees everything");
                s.push(99);
            })
        });
        assert_eq!(used2, 1);
        assert_eq!(scratch.len(), 3, "slots are never shrunk");
        assert_eq!(scratch[0].last(), Some(&99), "slot 0 was reused in place");
    }

    #[test]
    fn single_row_fallback() {
        let mut data = vec![0.0f32; 8];
        for_each_chunk(&mut data, 8, |first, chunk| {
            assert_eq!(first, 0);
            chunk[0] = 1.0;
        });
        assert_eq!(data[0], 1.0);
    }
}
