//! Tiny scoped-thread data-parallel helper (rayon is not in the offline
//! vendor set, and we want explicit control over thread count anyway: the
//! paper's timings are quoted at a fixed CPU thread budget).

use std::sync::atomic::{AtomicUsize, Ordering};

static THREADS: AtomicUsize = AtomicUsize::new(0);

/// Override the worker count (0 = auto). Mirrors the paper's "OpenMP with
/// two threads" setting when the coordinator pins `--threads 2`.
pub fn set_threads(n: usize) {
    THREADS.store(n, Ordering::Relaxed);
}

pub fn num_threads() -> usize {
    let n = THREADS.load(Ordering::Relaxed);
    if n > 0 {
        return n;
    }
    std::thread::available_parallelism().map(|v| v.get()).unwrap_or(1)
}

/// Split `data` (logically `data.len()/row_len` rows) into per-thread row
/// chunks and run `f(first_row_index, chunk)` on each in parallel.
pub fn for_each_chunk<F>(data: &mut [f32], row_len: usize, f: F)
where
    F: Fn(usize, &mut [f32]) + Sync,
{
    let rows = if row_len == 0 { 0 } else { data.len() / row_len };
    let nt = num_threads().min(rows.max(1));
    if nt <= 1 || rows < 2 {
        f(0, data);
        return;
    }
    let rows_per = rows.div_ceil(nt);
    std::thread::scope(|scope| {
        let mut rest = data;
        let mut start_row = 0;
        while !rest.is_empty() {
            let take = (rows_per * row_len).min(rest.len());
            let (chunk, tail) = rest.split_at_mut(take);
            let fr = &f;
            let sr = start_row;
            scope.spawn(move || fr(sr, chunk));
            start_row += take / row_len;
            rest = tail;
        }
    });
}

/// Run `f(thread_idx, row_range)` over `rows` rows in parallel and collect
/// one partial result per thread (for gradient-accumulator reduction).
pub fn map_row_ranges<T, F>(rows: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize, std::ops::Range<usize>) -> T + Sync,
{
    let nt = num_threads().min(rows.max(1));
    if nt <= 1 {
        return vec![f(0, 0..rows)];
    }
    let rows_per = rows.div_ceil(nt);
    std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for t in 0..nt {
            let lo = t * rows_per;
            if lo >= rows {
                break;
            }
            let hi = (lo + rows_per).min(rows);
            let fr = &f;
            handles.push(scope.spawn(move || fr(t, lo..hi)));
        }
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunks_cover_all_rows() {
        let mut data = vec![0.0f32; 103 * 4];
        for_each_chunk(&mut data, 4, |first, chunk| {
            for (i, row) in chunk.chunks_mut(4).enumerate() {
                row[0] = (first + i) as f32;
            }
        });
        for r in 0..103 {
            assert_eq!(data[r * 4], r as f32);
        }
    }

    #[test]
    fn map_ranges_disjoint_and_total() {
        let parts = map_row_ranges(57, |_, r| r);
        let mut seen = vec![false; 57];
        for r in parts {
            for i in r {
                assert!(!seen[i]);
                seen[i] = true;
            }
        }
        assert!(seen.iter().all(|&b| b));
    }

    #[test]
    fn single_row_fallback() {
        let mut data = vec![0.0f32; 8];
        for_each_chunk(&mut data, 8, |first, chunk| {
            assert_eq!(first, 0);
            chunk[0] = 1.0;
        });
        assert_eq!(data[0], 1.0);
    }
}
