//! Tiny scoped-thread data-parallel helper (rayon is not in the offline
//! vendor set, and we want explicit control over thread count anyway: the
//! paper's timings are quoted at a fixed CPU thread budget).

use std::sync::atomic::{AtomicUsize, Ordering};

static THREADS: AtomicUsize = AtomicUsize::new(0);

/// Override the worker count (0 = auto). Mirrors the paper's "OpenMP with
/// two threads" setting when the coordinator pins `--threads 2`.
pub fn set_threads(n: usize) {
    THREADS.store(n, Ordering::Relaxed);
}

pub fn num_threads() -> usize {
    let n = THREADS.load(Ordering::Relaxed);
    if n > 0 {
        return n;
    }
    std::thread::available_parallelism().map(|v| v.get()).unwrap_or(1)
}

/// Split `data` (logically `data.len()/row_len` rows) into per-thread row
/// chunks and run `f(first_row_index, chunk)` on each in parallel.
pub fn for_each_chunk<F>(data: &mut [f32], row_len: usize, f: F)
where
    F: Fn(usize, &mut [f32]) + Sync,
{
    let rows = if row_len == 0 { 0 } else { data.len() / row_len };
    let nt = num_threads().min(rows.max(1));
    if nt <= 1 || rows < 2 {
        f(0, data);
        return;
    }
    let rows_per = rows.div_ceil(nt);
    std::thread::scope(|scope| {
        let mut rest = data;
        let mut start_row = 0;
        while !rest.is_empty() {
            let take = (rows_per * row_len).min(rest.len());
            let (chunk, tail) = rest.split_at_mut(take);
            let fr = &f;
            let sr = start_row;
            scope.spawn(move || fr(sr, chunk));
            start_row += take / row_len;
            rest = tail;
        }
    });
}

/// Like [`for_each_chunk`], but hands each thread the matching row chunk
/// of every buffer in `extras` alongside its chunk of `data` (all buffers
/// logically `rows x row_len`, identical length). This is what the
/// batch-fused SPM `forward_train` needs: one parallel region that sweeps
/// all stages over a row block while writing per-stage trace snapshots
/// into separate buffers at the same row offsets.
pub fn for_each_chunk_with<F>(data: &mut [f32], extras: &mut [&mut [f32]], row_len: usize, f: F)
where
    F: Fn(usize, &mut [f32], &mut [&mut [f32]]) + Sync,
{
    for e in extras.iter() {
        assert_eq!(e.len(), data.len(), "extra buffer shape");
    }
    let rows = if row_len == 0 { 0 } else { data.len() / row_len };
    let nt = num_threads().min(rows.max(1));
    if nt <= 1 || rows < 2 {
        f(0, data, extras);
        return;
    }
    let rows_per = rows.div_ceil(nt);
    std::thread::scope(|scope| {
        let mut rest = data;
        let mut rest_extras: Vec<&mut [f32]> = extras.iter_mut().map(|e| &mut **e).collect();
        let mut start_row = 0;
        while !rest.is_empty() {
            let take = (rows_per * row_len).min(rest.len());
            let (chunk, tail) = rest.split_at_mut(take);
            rest = tail;
            let mut echunks: Vec<&mut [f32]> = Vec::with_capacity(rest_extras.len());
            let mut etails: Vec<&mut [f32]> = Vec::with_capacity(rest_extras.len());
            for e in rest_extras {
                let (c, t) = e.split_at_mut(take);
                echunks.push(c);
                etails.push(t);
            }
            rest_extras = etails;
            let fr = &f;
            let sr = start_row;
            scope.spawn(move || fr(sr, chunk, &mut echunks));
            start_row += take / row_len;
        }
    });
}

/// Run `f(thread_idx, row_range)` over `rows` rows in parallel and collect
/// one partial result per thread (for gradient-accumulator reduction).
pub fn map_row_ranges<T, F>(rows: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize, std::ops::Range<usize>) -> T + Sync,
{
    let nt = num_threads().min(rows.max(1));
    if nt <= 1 {
        return vec![f(0, 0..rows)];
    }
    let rows_per = rows.div_ceil(nt);
    std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for t in 0..nt {
            let lo = t * rows_per;
            if lo >= rows {
                break;
            }
            let hi = (lo + rows_per).min(rows);
            let fr = &f;
            handles.push(scope.spawn(move || fr(t, lo..hi)));
        }
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunks_cover_all_rows() {
        let mut data = vec![0.0f32; 103 * 4];
        for_each_chunk(&mut data, 4, |first, chunk| {
            for (i, row) in chunk.chunks_mut(4).enumerate() {
                row[0] = (first + i) as f32;
            }
        });
        for r in 0..103 {
            assert_eq!(data[r * 4], r as f32);
        }
    }

    #[test]
    fn map_ranges_disjoint_and_total() {
        let parts = map_row_ranges(57, |_, r| r);
        let mut seen = vec![false; 57];
        for r in parts {
            for i in r {
                assert!(!seen[i]);
                seen[i] = true;
            }
        }
        assert!(seen.iter().all(|&b| b));
    }

    #[test]
    fn chunk_with_extras_stays_row_aligned() {
        // ragged row count: every thread must see the same rows of `data`
        // and of each extra buffer, at the same chunk-relative offsets
        let mut data = vec![0.0f32; 103 * 4];
        let mut e0 = vec![0.0f32; 103 * 4];
        let mut e1 = vec![0.0f32; 103 * 4];
        for_each_chunk_with(&mut data, &mut [&mut e0, &mut e1], 4, |first, chunk, extras| {
            assert_eq!(chunk.len() % 4, 0, "chunk not row aligned");
            for e in extras.iter() {
                assert_eq!(e.len(), chunk.len(), "extra chunk shape");
            }
            for (i, row) in chunk.chunks_mut(4).enumerate() {
                row[0] = (first + i) as f32;
                extras[0][i * 4] = (first + i) as f32 + 0.5;
                extras[1][i * 4 + 1] = (first + i) as f32 + 0.25;
            }
        });
        for r in 0..103 {
            assert_eq!(data[r * 4], r as f32);
            assert_eq!(e0[r * 4], r as f32 + 0.5);
            assert_eq!(e1[r * 4 + 1], r as f32 + 0.25);
        }
    }

    #[test]
    fn chunk_with_no_extras_matches_plain() {
        let mut data = vec![0.0f32; 7 * 3];
        for_each_chunk_with(&mut data, &mut [], 3, |first, chunk, _extras| {
            for (i, row) in chunk.chunks_mut(3).enumerate() {
                row[2] = (first + i) as f32;
            }
        });
        for r in 0..7 {
            assert_eq!(data[r * 3 + 2], r as f32);
        }
    }

    #[test]
    fn single_row_fallback() {
        let mut data = vec![0.0f32; 8];
        for_each_chunk(&mut data, 8, |first, chunk| {
            assert_eq!(first, 0);
            chunk[0] = 1.0;
        });
        assert_eq!(data[0], 1.0);
    }
}
