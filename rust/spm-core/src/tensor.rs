//! Minimal row-major f32 matrix plus the blocked matmul the dense baseline
//! needs. No external BLAS: the paper's dense comparator on the *native*
//! path is this hand-blocked kernel (the XLA path uses Eigen; both engines
//! are reported separately in EXPERIMENTS.md).

use crate::parallel;

/// Row-major (rows x cols) f32 matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "shape/data mismatch");
        Mat { rows, cols, data }
    }

    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f32) -> Self {
        let mut m = Mat::zeros(rows, cols);
        for i in 0..rows {
            for j in 0..cols {
                m.data[i * cols + j] = f(i, j);
            }
        }
        m
    }

    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f32 {
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn at_mut(&mut self, i: usize, j: usize) -> &mut f32 {
        &mut self.data[i * self.cols + j]
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn transpose(&self) -> Mat {
        let mut t = Mat::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                t.data[j * self.rows + i] = self.data[i * self.cols + j];
            }
        }
        t
    }

    pub fn frobenius_norm(&self) -> f32 {
        self.data.iter().map(|v| v * v).sum::<f32>().sqrt()
    }

    pub fn max_abs_diff(&self, other: &Mat) -> f32 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }
}

/// C = A (m,k) * B (k,n).  Blocked over k with a vectorizable j-inner loop,
/// parallelized over row chunks of A.
pub fn matmul(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.cols, b.rows, "matmul inner dims");
    matmul_slice(a, &b.data, b.cols)
}

/// [`matmul`] against a flat row-major (a.cols, n) right operand — the
/// shape the flat-buffer `ops::LinearOp` stores its dense weights in.
pub fn matmul_slice(a: &Mat, b: &[f32], n: usize) -> Mat {
    let mut c = Mat { rows: 0, cols: 0, data: Vec::new() };
    matmul_slice_into(a, b, n, &mut c);
    c
}

/// [`matmul_slice`] into a caller-owned output, reshaped and zeroed in
/// place so repeated calls with a stable shape never allocate.
pub fn matmul_slice_into(a: &Mat, b: &[f32], n: usize, c: &mut Mat) {
    let (m, k) = (a.rows, a.cols);
    assert_eq!(b.len(), k * n, "matmul_slice inner dims");
    c.rows = m;
    c.cols = n;
    c.data.clear();
    c.data.resize(m * n, 0.0);
    const KB: usize = 64;
    parallel::for_each_chunk(&mut c.data, n, |i0, crows| {
        for (di, crow) in crows.chunks_mut(n).enumerate() {
            let i = i0 + di;
            let arow = a.row(i);
            for k0 in (0..k).step_by(KB) {
                let kend = (k0 + KB).min(k);
                for kk in k0..kend {
                    let aik = arow[kk];
                    if aik == 0.0 {
                        continue;
                    }
                    let brow = &b[kk * n..kk * n + n];
                    for j in 0..n {
                        crow[j] += aik * brow[j];
                    }
                }
            }
        }
    });
}

/// C = A (m,k) * B^T where B is (n,k): the "x @ W^T" shape of a linear layer.
/// Dot-product kernel over contiguous rows of both operands.
pub fn matmul_nt(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.cols, b.cols, "matmul_nt inner dims");
    matmul_nt_slice(a, &b.data, b.rows)
}

/// [`matmul_nt`] against a flat row-major (n, a.cols) weight slice.
pub fn matmul_nt_slice(a: &Mat, w: &[f32], n: usize) -> Mat {
    let mut c = Mat { rows: 0, cols: 0, data: Vec::new() };
    matmul_nt_slice_into(a, w, n, &mut c);
    c
}

/// [`matmul_nt_slice`] into a caller-owned output, reshaped in place so
/// repeated calls with a stable shape never allocate.
pub fn matmul_nt_slice_into(a: &Mat, w: &[f32], n: usize, c: &mut Mat) {
    let (m, k) = (a.rows, a.cols);
    assert_eq!(w.len(), n * k, "matmul_nt_slice inner dims");
    c.rows = m;
    c.cols = n;
    c.data.clear();
    c.data.resize(m * n, 0.0);
    parallel::for_each_chunk(&mut c.data, n, |i0, crows| {
        for (di, crow) in crows.chunks_mut(n).enumerate() {
            let arow = a.row(i0 + di);
            for j in 0..n {
                let brow = &w[j * k..j * k + k];
                let mut acc0 = 0.0f32;
                let mut acc1 = 0.0f32;
                let mut acc2 = 0.0f32;
                let mut acc3 = 0.0f32;
                let mut t = 0;
                while t + 4 <= k {
                    acc0 += arow[t] * brow[t];
                    acc1 += arow[t + 1] * brow[t + 1];
                    acc2 += arow[t + 2] * brow[t + 2];
                    acc3 += arow[t + 3] * brow[t + 3];
                    t += 4;
                }
                let mut acc = acc0 + acc1 + acc2 + acc3;
                while t < k {
                    acc += arow[t] * brow[t];
                    t += 1;
                }
                crow[j] = acc;
            }
        }
    });
}

/// C = A^T (k,m)^T=(m,k)... precisely: A is (k,m), B is (k,n), returns (m,n)
/// — the "gW = gy^T @ x" shape of a linear-layer backward.
pub fn matmul_tn(a: &Mat, b: &Mat) -> Mat {
    let mut c = Mat::zeros(a.cols, b.cols);
    matmul_tn_accum(a, b, &mut c.data);
    c
}

/// out += A^T B into a flat row-major (a.cols, b.cols) slice — lets the
/// flat-buffer dense backward accumulate straight into its gradient
/// buffer with no intermediate allocation.
pub fn matmul_tn_accum(a: &Mat, b: &Mat, out: &mut [f32]) {
    assert_eq!(a.rows, b.rows, "matmul_tn inner dims");
    let (k, m, n) = (a.rows, a.cols, b.cols);
    assert_eq!(out.len(), m * n, "matmul_tn_accum output size");
    // accumulate rank-1 updates; parallel over output row chunks
    parallel::for_each_chunk(out, n, |i0, crows| {
        let rows_here = crows.len() / n;
        for kk in 0..k {
            let arow = a.row(kk);
            let brow = b.row(kk);
            for di in 0..rows_here {
                let aik = arow[i0 + di];
                if aik == 0.0 {
                    continue;
                }
                let crow = &mut crows[di * n..(di + 1) * n];
                for j in 0..n {
                    crow[j] += aik * brow[j];
                }
            }
        }
    });
}

/// y += bias broadcast over rows.
pub fn add_bias(y: &mut Mat, bias: &[f32]) {
    assert_eq!(y.cols, bias.len());
    for i in 0..y.rows {
        let row = y.row_mut(i);
        for j in 0..row.len() {
            row[j] += bias[j];
        }
    }
}

/// Column-wise sum (the bias gradient).
pub fn col_sum(m: &Mat) -> Vec<f32> {
    let mut s = Vec::new();
    col_sum_into(m, &mut s);
    s
}

/// [`col_sum`] into a caller-owned buffer, resized in place.
pub fn col_sum_into(m: &Mat, s: &mut Vec<f32>) {
    s.clear();
    s.resize(m.cols, 0.0);
    for i in 0..m.rows {
        let row = m.row(i);
        for j in 0..row.len() {
            s[j] += row[j];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn naive(a: &Mat, b: &Mat) -> Mat {
        let mut c = Mat::zeros(a.rows, b.cols);
        for i in 0..a.rows {
            for j in 0..b.cols {
                let mut s = 0.0;
                for t in 0..a.cols {
                    s += a.at(i, t) * b.at(t, j);
                }
                *c.at_mut(i, j) = s;
            }
        }
        c
    }

    #[test]
    fn matmul_matches_naive() {
        let a = Mat::from_fn(7, 13, |i, j| (i * 13 + j) as f32 * 0.01 - 0.3);
        let b = Mat::from_fn(13, 5, |i, j| (i + j) as f32 * 0.1 - 0.7);
        assert!(matmul(&a, &b).max_abs_diff(&naive(&a, &b)) < 1e-4);
    }

    #[test]
    fn matmul_nt_matches_naive() {
        let a = Mat::from_fn(6, 9, |i, j| (i as f32 - j as f32) * 0.05);
        let w = Mat::from_fn(4, 9, |i, j| (i * j) as f32 * 0.02 - 0.1);
        let got = matmul_nt(&a, &w);
        let want = naive(&a, &w.transpose());
        assert!(got.max_abs_diff(&want) < 1e-4);
    }

    #[test]
    fn matmul_tn_matches_naive() {
        let g = Mat::from_fn(8, 3, |i, j| (i + 2 * j) as f32 * 0.03);
        let x = Mat::from_fn(8, 5, |i, j| (i * j) as f32 * 0.01 - 0.2);
        let got = matmul_tn(&g, &x);
        let want = naive(&g.transpose(), &x);
        assert!(got.max_abs_diff(&want) < 1e-4);
    }

    #[test]
    fn bias_and_colsum() {
        let mut y = Mat::from_fn(3, 2, |i, j| (i + j) as f32);
        add_bias(&mut y, &[1.0, -1.0]);
        assert_eq!(y.at(0, 0), 1.0);
        assert_eq!(y.at(0, 1), 0.0);
        let s = col_sum(&y);
        assert_eq!(s, vec![6.0, 3.0]);
    }

    #[test]
    fn large_parallel_path() {
        let a = Mat::from_fn(130, 64, |i, j| ((i * 7 + j * 3) % 11) as f32 * 0.1);
        let b = Mat::from_fn(64, 70, |i, j| ((i + j) % 5) as f32 * 0.2 - 0.3);
        assert!(matmul(&a, &b).max_abs_diff(&naive(&a, &b)) < 1e-3);
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        let a = Mat::zeros(2, 3);
        let b = Mat::zeros(4, 2);
        let _ = matmul(&a, &b);
    }
}
