//! The SPM operator (paper §2-§5), native CPU implementation.
//!
//! ``SPM(x) = D_out (B_L … B_1) D_in x + bias`` where each stage B_l applies
//! independent 2x2 blocks to disjoint coordinate pairs. Exact closed-form
//! forward AND backward (the paper's eqs. 2-19); no autodiff anywhere.
//!
//! Implementation notes
//! * Stages are applied **in place** on a per-row scratch copy: the pairs of
//!   a stage are disjoint, so `(z[i], z[j]) <- M_k (z[i], z[j])` never
//!   conflicts. One pass per stage => O(nL) work, O(Bn) live memory.
//! * Rotation backward is O(Bn) memory total: stage inputs are *recomputed*
//!   from outputs via the orthogonal transpose (z_{l-1} = B_l^T z_l) while
//!   the adjoint propagates, and eq. (9) is evaluated in its output form
//!   `dL/dtheta = d2*y1 - d1*y2` (see DESIGN.md §8).
//! * General backward stores the stage-input trace (O(BnL)), like the paper.
//! * Batch rows are processed in parallel; per-thread parameter-gradient
//!   accumulators are reduced at the end (paper §4 "batch setting").

use crate::pairing::{self, Schedule, StagePairing};
use crate::parallel;
use crate::rng::Rng;
use crate::tensor::Mat;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Variant {
    /// §3.1: one angle per pair, orthogonal by construction.
    Rotation,
    /// §3.2: four free scalars per pair.
    General,
}

impl Variant {
    pub fn parse(s: &str) -> Option<Variant> {
        match s {
            "rotation" => Some(Variant::Rotation),
            "general" => Some(Variant::General),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Variant::Rotation => "rotation",
            Variant::General => "general",
        }
    }
}

#[derive(Clone, Copy, Debug)]
pub struct SpmSpec {
    pub n: usize,
    pub num_stages: usize,
    pub variant: Variant,
    pub schedule: Schedule,
    pub seed: u64,
}

impl SpmSpec {
    pub fn new(n: usize, variant: Variant) -> Self {
        SpmSpec {
            n,
            num_stages: pairing::default_num_stages(n),
            variant,
            schedule: Schedule::Butterfly,
            seed: 0,
        }
    }

    pub fn with_stages(mut self, l: usize) -> Self {
        self.num_stages = l;
        self
    }

    pub fn with_schedule(mut self, s: Schedule) -> Self {
        self.schedule = s;
        self
    }

    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }
}

/// Trainable parameters. `mix[l]` holds `P` thetas (rotation) or `4P`
/// interleaved `[a,b,c,d]` scalars (general); `lone[l]` is the learned 1x1
/// scale for the odd-n leftover coordinate (general variant, paper §5 (ii);
/// the rotation variant passes the leftover through to stay orthogonal).
#[derive(Clone, Debug)]
pub struct SpmParams {
    pub d_in: Vec<f32>,
    pub d_out: Vec<f32>,
    pub bias: Vec<f32>,
    pub mix: Vec<Vec<f32>>,
    pub lone: Vec<f32>,
}

impl SpmParams {
    pub fn num_scalars(&self) -> usize {
        3 * self.d_in.len() + self.mix.iter().map(|m| m.len()).sum::<usize>() + self.lone.len()
    }
}

/// Gradients, same shapes as the parameters.
#[derive(Clone, Debug)]
pub struct SpmGrads {
    pub d_in: Vec<f32>,
    pub d_out: Vec<f32>,
    pub bias: Vec<f32>,
    pub mix: Vec<Vec<f32>>,
    pub lone: Vec<f32>,
}

impl SpmGrads {
    fn zeros_like(p: &SpmParams) -> Self {
        SpmGrads {
            d_in: vec![0.0; p.d_in.len()],
            d_out: vec![0.0; p.d_out.len()],
            bias: vec![0.0; p.bias.len()],
            mix: p.mix.iter().map(|m| vec![0.0; m.len()]).collect(),
            lone: vec![0.0; p.lone.len()],
        }
    }

    fn add_assign(&mut self, other: &SpmGrads) {
        fn add(a: &mut [f32], b: &[f32]) {
            for (x, y) in a.iter_mut().zip(b) {
                *x += y;
            }
        }
        add(&mut self.d_in, &other.d_in);
        add(&mut self.d_out, &other.d_out);
        add(&mut self.bias, &other.bias);
        for (m, o) in self.mix.iter_mut().zip(&other.mix) {
            add(m, o);
        }
        add(&mut self.lone, &other.lone);
    }
}

/// Residuals saved by `forward_trace` for the backward pass.
pub enum Trace {
    /// rotation: only the final pre-D_out activation z_L (O(Bn))
    Rotation { z_last: Mat },
    /// general: every stage input z_0..z_L (O(BnL))
    General { zs: Vec<Mat> },
}

/// The operator: spec + precomputed pairing schedule (+ cached cos/sin view
/// of rotation parameters is computed per call — params may change between
/// calls during training).
pub struct Spm {
    pub spec: SpmSpec,
    pub stages: Vec<StagePairing>,
}

impl Spm {
    pub fn new(spec: SpmSpec) -> Self {
        assert!(spec.n >= 2, "n must be >= 2");
        assert!(spec.num_stages >= 1, "need at least one stage");
        let stages = pairing::make_schedule(spec.schedule, spec.n, spec.num_stages, spec.seed);
        Spm { spec, stages }
    }

    /// Orthogonal-at-init parameters (matches python/compile/spm.py):
    /// every stage starts as a product of random planar rotations, identity
    /// diagonals, zero bias — exactly norm-preserving at init (§8.4).
    pub fn init_params(&self, rng: &mut Rng) -> SpmParams {
        let n = self.spec.n;
        let p = n / 2;
        let mut mix = Vec::with_capacity(self.spec.num_stages);
        for _ in 0..self.spec.num_stages {
            match self.spec.variant {
                Variant::Rotation => {
                    mix.push(rng.uniform_vec(p, -std::f32::consts::PI, std::f32::consts::PI));
                }
                Variant::General => {
                    let mut m = vec![0.0; 4 * p];
                    for k in 0..p {
                        let th = rng.uniform_in(-std::f32::consts::PI, std::f32::consts::PI);
                        let (s, c) = th.sin_cos();
                        m[4 * k] = c;
                        m[4 * k + 1] = -s;
                        m[4 * k + 2] = s;
                        m[4 * k + 3] = c;
                    }
                    mix.push(m);
                }
            }
        }
        SpmParams {
            d_in: vec![1.0; n],
            d_out: vec![1.0; n],
            bias: vec![0.0; n],
            mix,
            lone: vec![1.0; self.spec.num_stages],
        }
    }

    pub fn param_count(&self, params: &SpmParams) -> usize {
        params.num_scalars()
    }

    /// Per-stage cos/sin tables for the rotation variant.
    fn trig(&self, params: &SpmParams) -> Vec<Vec<(f32, f32)>> {
        params
            .mix
            .iter()
            .map(|thetas| thetas.iter().map(|t| { let (s, c) = t.sin_cos(); (c, s) }).collect())
            .collect()
    }

    /// Apply stage `l` in place on one row.
    #[inline]
    fn stage_row_fwd(
        &self,
        l: usize,
        params: &SpmParams,
        trig: &[Vec<(f32, f32)>],
        row: &mut [f32],
    ) {
        let st = &self.stages[l];
        match self.spec.variant {
            Variant::Rotation => {
                let cs = &trig[l];
                for k in 0..st.left.len() {
                    let (i, j) = (st.left[k] as usize, st.right[k] as usize);
                    let (c, s) = cs[k];
                    let x1 = row[i];
                    let x2 = row[j];
                    row[i] = c * x1 - s * x2; // eq. (5)
                    row[j] = s * x1 + c * x2; // eq. (6)
                }
                // leftover passes through (keeps the stage orthogonal)
            }
            Variant::General => {
                let m = &params.mix[l];
                for k in 0..st.left.len() {
                    let (i, j) = (st.left[k] as usize, st.right[k] as usize);
                    let (a, b, c, d) = (m[4 * k], m[4 * k + 1], m[4 * k + 2], m[4 * k + 3]);
                    let x1 = row[i];
                    let x2 = row[j];
                    row[i] = a * x1 + b * x2; // eq. (10)
                    row[j] = c * x1 + d * x2; // eq. (11)
                }
                if let Some(lv) = st.leftover {
                    row[lv as usize] *= params.lone[l];
                }
            }
        }
    }

    /// y = SPM(x); x is (B, n).
    pub fn forward(&self, params: &SpmParams, x: &Mat) -> Mat {
        assert_eq!(x.cols, self.spec.n, "input width");
        let trig = match self.spec.variant {
            Variant::Rotation => self.trig(params),
            Variant::General => Vec::new(),
        };
        let mut z = x.clone();
        let n = self.spec.n;
        let this = &self;
        let p = params;
        let tg = &trig;
        parallel::for_each_chunk(&mut z.data, n, |_first, chunk| {
            for row in chunk.chunks_mut(n) {
                for (v, di) in row.iter_mut().zip(&p.d_in) {
                    *v *= di; // eq. (2)
                }
                for l in 0..this.spec.num_stages {
                    this.stage_row_fwd(l, p, tg, row); // eq. (3)
                }
                for ((v, do_), b) in row.iter_mut().zip(&p.d_out).zip(&p.bias) {
                    *v = *v * do_ + b; // eq. (4)
                }
            }
        });
        z
    }

    /// Forward keeping the residuals needed by `backward`.
    pub fn forward_trace(&self, params: &SpmParams, x: &Mat) -> (Mat, Trace) {
        assert_eq!(x.cols, self.spec.n, "input width");
        let n = self.spec.n;
        match self.spec.variant {
            Variant::Rotation => {
                let trig = self.trig(params);
                let mut z = x.clone();
                let this = &self;
                let p = params;
                let tg = &trig;
                parallel::for_each_chunk(&mut z.data, n, |_f, chunk| {
                    for row in chunk.chunks_mut(n) {
                        for (v, di) in row.iter_mut().zip(&p.d_in) {
                            *v *= di;
                        }
                        for l in 0..this.spec.num_stages {
                            this.stage_row_fwd(l, p, tg, row);
                        }
                    }
                });
                let z_last = z.clone();
                // finish: y = d_out * z + bias
                parallel::for_each_chunk(&mut z.data, n, |_f, chunk| {
                    for row in chunk.chunks_mut(n) {
                        for ((v, do_), b) in row.iter_mut().zip(&p.d_out).zip(&p.bias) {
                            *v = *v * do_ + b;
                        }
                    }
                });
                (z, Trace::Rotation { z_last })
            }
            Variant::General => {
                let mut zs = Vec::with_capacity(self.spec.num_stages + 1);
                let mut z = x.clone();
                for i in 0..z.rows {
                    let row = z.row_mut(i);
                    for (v, di) in row.iter_mut().zip(&params.d_in) {
                        *v *= di;
                    }
                }
                zs.push(z.clone());
                for l in 0..self.spec.num_stages {
                    let p = params;
                    let this = &self;
                    parallel::for_each_chunk(&mut z.data, n, |_f, chunk| {
                        for row in chunk.chunks_mut(n) {
                            this.stage_row_fwd(l, p, &[], row);
                        }
                    });
                    zs.push(z.clone());
                }
                let mut y = z;
                for i in 0..y.rows {
                    let row = y.row_mut(i);
                    for ((v, do_), b) in row.iter_mut().zip(&params.d_out).zip(&params.bias) {
                        *v = *v * do_ + b;
                    }
                }
                (y, Trace::General { zs })
            }
        }
    }

    /// Exact backward (paper §4). Returns (g_x, grads).
    /// `x` is the layer input that produced `trace`.
    pub fn backward(
        &self,
        params: &SpmParams,
        x: &Mat,
        trace: &Trace,
        gy: &Mat,
    ) -> (Mat, SpmGrads) {
        assert_eq!(gy.cols, self.spec.n);
        assert_eq!(gy.rows, x.rows);
        match trace {
            Trace::Rotation { z_last } => self.backward_rotation(params, x, z_last, gy),
            Trace::General { zs } => self.backward_general(params, x, zs, gy),
        }
    }

    fn backward_rotation(
        &self,
        params: &SpmParams,
        x: &Mat,
        z_last: &Mat,
        gy: &Mat,
    ) -> (Mat, SpmGrads) {
        let n = self.spec.n;
        let ls = self.spec.num_stages;
        let trig = self.trig(params);
        let rows = gy.rows;

        // per-thread partial grads, reduced below
        let mut gx = Mat::zeros(rows, n);
        let partials = parallel::map_row_ranges(rows, |_t, range| {
            let mut grads = SpmGrads::zeros_like(params);
            let mut gx_rows: Vec<(usize, Vec<f32>)> = Vec::with_capacity(range.len());
            let mut g = vec![0.0f32; n];
            let mut z = vec![0.0f32; n];
            for r in range {
                // eqs. (15)-(17)
                let gyr = gy.row(r);
                z.copy_from_slice(z_last.row(r));
                for i in 0..n {
                    grads.bias[i] += gyr[i];
                    grads.d_out[i] += gyr[i] * z[i];
                    g[i] = gyr[i] * params.d_out[i];
                }
                // stages in reverse: theta grad from outputs, then transpose-
                // apply to BOTH adjoint g and activation z
                for l in (0..ls).rev() {
                    let st = &self.stages[l];
                    let cs = &trig[l];
                    let gm = &mut grads.mix[l];
                    for k in 0..st.left.len() {
                        let (i, j) = (st.left[k] as usize, st.right[k] as usize);
                        let (c, s) = cs[k];
                        let (y1, y2) = (z[i], z[j]);
                        let (d1, d2) = (g[i], g[j]);
                        gm[k] += d2 * y1 - d1 * y2; // eq. (9) via outputs
                        g[i] = c * d1 + s * d2; // eq. (7)
                        g[j] = -s * d1 + c * d2; // eq. (8)
                        z[i] = c * y1 + s * y2; // z_{l-1} = B^T z_l
                        z[j] = -s * y1 + c * y2;
                    }
                }
                // eqs. (18)-(19)
                let xr = x.row(r);
                let mut gxr = vec![0.0f32; n];
                for i in 0..n {
                    grads.d_in[i] += g[i] * xr[i];
                    gxr[i] = g[i] * params.d_in[i];
                }
                gx_rows.push((r, gxr));
            }
            (grads, gx_rows)
        });

        let mut grads = SpmGrads::zeros_like(params);
        for (pg, gx_rows) in partials {
            grads.add_assign(&pg);
            for (r, rowv) in gx_rows {
                gx.row_mut(r).copy_from_slice(&rowv);
            }
        }
        (gx, grads)
    }

    fn backward_general(
        &self,
        params: &SpmParams,
        x: &Mat,
        zs: &[Mat],
        gy: &Mat,
    ) -> (Mat, SpmGrads) {
        let n = self.spec.n;
        let ls = self.spec.num_stages;
        let rows = gy.rows;
        let mut gx = Mat::zeros(rows, n);

        let partials = parallel::map_row_ranges(rows, |_t, range| {
            let mut grads = SpmGrads::zeros_like(params);
            let mut gx_rows: Vec<(usize, Vec<f32>)> = Vec::with_capacity(range.len());
            let mut g = vec![0.0f32; n];
            for r in range {
                let gyr = gy.row(r);
                let zl = zs[ls].row(r);
                for i in 0..n {
                    grads.bias[i] += gyr[i];
                    grads.d_out[i] += gyr[i] * zl[i];
                    g[i] = gyr[i] * params.d_out[i];
                }
                for l in (0..ls).rev() {
                    let st = &self.stages[l];
                    let m = &params.mix[l];
                    let gm = &mut grads.mix[l];
                    let zin = zs[l].row(r); // stage INPUT
                    for k in 0..st.left.len() {
                        let (i, j) = (st.left[k] as usize, st.right[k] as usize);
                        let (a, b, c, d) = (m[4 * k], m[4 * k + 1], m[4 * k + 2], m[4 * k + 3]);
                        let (x1, x2) = (zin[i], zin[j]);
                        let (d1, d2) = (g[i], g[j]);
                        // eq. (14)
                        gm[4 * k] += d1 * x1;
                        gm[4 * k + 1] += d1 * x2;
                        gm[4 * k + 2] += d2 * x1;
                        gm[4 * k + 3] += d2 * x2;
                        // eqs. (12)-(13)
                        g[i] = a * d1 + c * d2;
                        g[j] = b * d1 + d * d2;
                    }
                    if let Some(lv) = st.leftover {
                        let lvi = lv as usize;
                        grads.lone[l] += g[lvi] * zin[lvi];
                        g[lvi] *= params.lone[l];
                    }
                }
                let xr = x.row(r);
                let mut gxr = vec![0.0f32; n];
                for i in 0..n {
                    grads.d_in[i] += g[i] * xr[i];
                    gxr[i] = g[i] * params.d_in[i];
                }
                gx_rows.push((r, gxr));
            }
            (grads, gx_rows)
        });

        let mut grads = SpmGrads::zeros_like(params);
        for (pg, gx_rows) in partials {
            grads.add_assign(&pg);
            for (r, rowv) in gx_rows {
                gx.row_mut(r).copy_from_slice(&rowv);
            }
        }
        (gx, grads)
    }

    /// Materialize the full n x n matrix (test/analysis only, O(n^2 L)).
    pub fn materialize(&self, params: &SpmParams) -> Mat {
        let n = self.spec.n;
        let eye = Mat::from_fn(n, n, |i, j| if i == j { 1.0 } else { 0.0 });
        let mut cols = self.forward(params, &eye);
        for i in 0..n {
            let row = cols.row_mut(i);
            for (v, b) in row.iter_mut().zip(&params.bias) {
                *v -= b;
            }
        }
        cols.transpose()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::{assert_close, check_close, forall, numerical_grad};

    fn mk(n: usize, variant: Variant, schedule: Schedule, l: usize, seed: u64) -> (Spm, SpmParams) {
        let spec = SpmSpec::new(n, variant).with_schedule(schedule).with_stages(l).with_seed(seed);
        let op = Spm::new(spec);
        let mut rng = Rng::new(seed + 100);
        let p = op.init_params(&mut rng);
        (op, p)
    }

    fn randomize(p: &mut SpmParams, rng: &mut Rng) {
        for v in p.d_in.iter_mut().chain(p.d_out.iter_mut()).chain(p.bias.iter_mut()) {
            *v = 1.0 + 0.3 * rng.normal();
        }
        for m in &mut p.mix {
            for v in m.iter_mut() {
                *v += 0.3 * rng.normal();
            }
        }
        for v in &mut p.lone {
            *v = 1.0 + 0.3 * rng.normal();
        }
    }

    #[test]
    fn rotation_norm_preserving() {
        let (op, p) = mk(64, Variant::Rotation, Schedule::Butterfly, 6, 1);
        let mut rng = Rng::new(2);
        let x = Mat::from_vec(8, 64, rng.normal_vec(8 * 64, 1.0));
        let y = op.forward(&p, &x);
        for r in 0..8 {
            let nx: f32 = x.row(r).iter().map(|v| v * v).sum::<f32>().sqrt();
            let ny: f32 = y.row(r).iter().map(|v| v * v).sum::<f32>().sqrt();
            assert!((nx - ny).abs() < 1e-3 * nx.max(1.0), "row {r}: {nx} vs {ny}");
        }
    }

    #[test]
    fn rotation_materialized_orthogonal() {
        let (op, p) = mk(16, Variant::Rotation, Schedule::Shift, 5, 3);
        let w = op.materialize(&p);
        let wt = w.transpose();
        let prod = crate::tensor::matmul(&w, &wt);
        for i in 0..16 {
            for j in 0..16 {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((prod.at(i, j) - want).abs() < 1e-4, "({i},{j})");
            }
        }
    }

    #[test]
    fn linearity() {
        let (op, mut p) = mk(33, Variant::General, Schedule::Shift, 4, 4);
        let mut rng = Rng::new(5);
        randomize(&mut p, &mut rng);
        let x = Mat::from_vec(3, 33, rng.normal_vec(3 * 33, 1.0));
        let y = Mat::from_vec(3, 33, rng.normal_vec(3 * 33, 1.0));
        let mix = Mat::from_vec(
            3,
            33,
            x.data.iter().zip(&y.data).map(|(a, b)| 2.0 * a - 0.5 * b).collect(),
        );
        let f = |m: &Mat| {
            let mut out = op.forward(&p, m);
            for i in 0..out.rows {
                let row = out.row_mut(i);
                for (v, b) in row.iter_mut().zip(&p.bias) {
                    *v -= b;
                }
            }
            out
        };
        let (fx, fy, fm) = (f(&x), f(&y), f(&mix));
        for i in 0..fm.data.len() {
            let want = 2.0 * fx.data[i] - 0.5 * fy.data[i];
            assert!((fm.data[i] - want).abs() < 1e-3, "{i}");
        }
    }

    #[test]
    fn dense_equivalence_via_materialize() {
        let (op, mut p) = mk(24, Variant::General, Schedule::Random, 5, 6);
        let mut rng = Rng::new(7);
        randomize(&mut p, &mut rng);
        let x = Mat::from_vec(5, 24, rng.normal_vec(5 * 24, 1.0));
        let w = op.materialize(&p);
        let mut want = crate::tensor::matmul_nt(&x, &w);
        crate::tensor::add_bias(&mut want, &p.bias);
        let got = op.forward(&p, &x);
        assert!(got.max_abs_diff(&want) < 1e-3);
    }

    #[test]
    fn forward_trace_matches_forward() {
        for variant in [Variant::Rotation, Variant::General] {
            let (op, mut p) = mk(17, variant, Schedule::Shift, 4, 8);
            let mut rng = Rng::new(9);
            randomize(&mut p, &mut rng);
            let x = Mat::from_vec(6, 17, rng.normal_vec(6 * 17, 1.0));
            let y1 = op.forward(&p, &x);
            let (y2, _) = op.forward_trace(&p, &x);
            assert!(y1.max_abs_diff(&y2) < 1e-5, "{variant:?}");
        }
    }

    /// scalar loss L = sum(tanh(y)) for gradient checks
    fn loss_and_gy(y: &Mat) -> (f32, Mat) {
        let mut gy = y.clone();
        let mut loss = 0.0;
        for v in gy.data.iter_mut() {
            loss += v.tanh();
            let t = v.tanh();
            *v = 1.0 - t * t;
        }
        (loss, gy)
    }

    #[test]
    fn backward_input_grad_finite_difference() {
        for variant in [Variant::Rotation, Variant::General] {
            let (op, mut p) = mk(12, variant, Schedule::Butterfly, 3, 10);
            let mut rng = Rng::new(11);
            randomize(&mut p, &mut rng);
            let mut xv = rng.normal_vec(2 * 12, 1.0);
            let x = Mat::from_vec(2, 12, xv.clone());
            let (y, trace) = op.forward_trace(&p, &x);
            let (_l, gy) = loss_and_gy(&y);
            let (gx, _g) = op.backward(&p, &x, &trace, &gy);
            for idx in [0usize, 5, 13, 23] {
                let got = gx.data[idx];
                let num = numerical_grad(&mut xv, idx, 1e-2, |v| {
                    let xm = Mat::from_vec(2, 12, v.to_vec());
                    let y = op.forward(&p, &xm);
                    y.data.iter().map(|t| t.tanh()).sum()
                });
                assert!(
                    (got - num).abs() < 3e-2 * (1.0f32.max(num.abs())),
                    "{variant:?} gx[{idx}]: {got} vs {num}"
                );
            }
        }
    }

    #[test]
    fn backward_param_grads_finite_difference() {
        for variant in [Variant::Rotation, Variant::General] {
            let (op, mut p) = mk(9, variant, Schedule::Shift, 3, 12);
            let mut rng = Rng::new(13);
            randomize(&mut p, &mut rng);
            let x = Mat::from_vec(3, 9, rng.normal_vec(27, 1.0));
            let (y, trace) = op.forward_trace(&p, &x);
            let (_l, gy) = loss_and_gy(&y);
            let (_gx, grads) = op.backward(&p, &x, &trace, &gy);

            let eval = |p: &SpmParams| -> f32 {
                op.forward(p, &x).data.iter().map(|t| t.tanh()).sum()
            };

            // d_in / d_out / bias / mix[1] / lone spot checks
            let mut q = p.clone();
            for (field, gvec) in [("d_in", &grads.d_in), ("d_out", &grads.d_out), ("bias", &grads.bias)] {
                for idx in [0usize, 4, 8] {
                    let vecref: &mut Vec<f32> = match field {
                        "d_in" => &mut q.d_in,
                        "d_out" => &mut q.d_out,
                        _ => &mut q.bias,
                    };
                    let orig = vecref[idx];
                    vecref[idx] = orig + 1e-2;
                    let up = eval(&q);
                    {
                        let vecref: &mut Vec<f32> = match field {
                            "d_in" => &mut q.d_in,
                            "d_out" => &mut q.d_out,
                            _ => &mut q.bias,
                        };
                        vecref[idx] = orig - 1e-2;
                    }
                    let down = eval(&q);
                    {
                        let vecref: &mut Vec<f32> = match field {
                            "d_in" => &mut q.d_in,
                            "d_out" => &mut q.d_out,
                            _ => &mut q.bias,
                        };
                        vecref[idx] = orig;
                    }
                    let num = (up - down) / 2e-2;
                    let got = gvec[idx];
                    assert!(
                        (got - num).abs() < 3e-2 * (1.0f32.max(num.abs())),
                        "{variant:?} {field}[{idx}]: {got} vs {num}"
                    );
                }
            }
            for idx in 0..p.mix[1].len().min(6) {
                let orig = q.mix[1][idx];
                q.mix[1][idx] = orig + 1e-2;
                let up = eval(&q);
                q.mix[1][idx] = orig - 1e-2;
                let down = eval(&q);
                q.mix[1][idx] = orig;
                let num = (up - down) / 2e-2;
                let got = grads.mix[1][idx];
                assert!(
                    (got - num).abs() < 3e-2 * (1.0f32.max(num.abs())),
                    "{variant:?} mix[1][{idx}]: {got} vs {num}"
                );
            }
            if variant == Variant::General {
                let orig = q.lone[0];
                q.lone[0] = orig + 1e-2;
                let up = eval(&q);
                q.lone[0] = orig - 1e-2;
                let down = eval(&q);
                q.lone[0] = orig;
                let num = (up - down) / 2e-2;
                assert!(
                    (grads.lone[0] - num).abs() < 3e-2 * (1.0f32.max(num.abs())),
                    "lone[0]: {} vs {num}", grads.lone[0]
                );
            }
        }
    }

    #[test]
    fn adjoint_consistency_property() {
        // <SPM_lin(x), d> == <x, SPM_lin^T(d)> where SPM_lin = SPM - bias
        forall(30, 77, |rng| {
            let n = 2 + rng.below(40);
            let l = 1 + rng.below(5);
            let variant = if rng.below(2) == 0 { Variant::Rotation } else { Variant::General };
            let sched = [Schedule::Butterfly, Schedule::Shift, Schedule::Random][rng.below(3)];
            let (op, mut p) = mk(n, variant, sched, l, rng.next_u64());
            randomize(&mut p, rng);
            let x = Mat::from_vec(2, n, rng.normal_vec(2 * n, 1.0));
            let d = Mat::from_vec(2, n, rng.normal_vec(2 * n, 1.0));
            let (y, trace) = op.forward_trace(&p, &x);
            let (gx, _) = op.backward(&p, &x, &trace, &d);
            let mut lhs = 0.0f32;
            for i in 0..y.data.len() {
                let ylin = y.data[i] - p.bias[i % n];
                lhs += ylin * d.data[i];
            }
            let rhs: f32 = x.data.iter().zip(&gx.data).map(|(a, b)| a * b).sum();
            let scale = lhs.abs().max(rhs.abs()).max(1.0);
            if (lhs - rhs).abs() > 2e-3 * scale {
                return Err(format!("adjoint mismatch: {lhs} vs {rhs} (n={n} l={l} {variant:?})"));
            }
            Ok(())
        });
    }

    #[test]
    fn rotation_general_agree_when_blocks_are_rotations() {
        let (op_r, p_r) = mk(20, Variant::Rotation, Schedule::Butterfly, 4, 21);
        let spec_g = SpmSpec::new(20, Variant::General).with_stages(4).with_seed(21);
        let op_g = Spm::new(spec_g);
        // build general params from the rotation angles
        let mut mix = Vec::new();
        for thetas in &p_r.mix {
            let mut m = vec![0.0; 4 * thetas.len()];
            for (k, t) in thetas.iter().enumerate() {
                let (s, c) = t.sin_cos();
                m[4 * k] = c;
                m[4 * k + 1] = -s;
                m[4 * k + 2] = s;
                m[4 * k + 3] = c;
            }
            mix.push(m);
        }
        let p_g = SpmParams { mix, ..p_r.clone() };
        let mut rng = Rng::new(22);
        let x = Mat::from_vec(4, 20, rng.normal_vec(80, 1.0));
        let (ya, yb) = (op_r.forward(&p_r, &x), op_g.forward(&p_g, &x));
        assert!(ya.max_abs_diff(&yb) < 1e-4);
    }

    #[test]
    fn param_count_near_linear() {
        for n in [64usize, 256, 1024] {
            let (op, p) = mk(n, Variant::General, Schedule::Butterfly,
                             pairing::default_num_stages(n), 1);
            assert!(op.param_count(&p) < n * n / 4, "n={n}");
        }
    }

    #[test]
    fn check_close_helper() {
        assert_close(&[1.0, 2.0], &[1.0, 2.0], 1e-6, "exact");
        assert!(check_close(&[1.0], &[2.0], 1e-3, "x").is_err());
    }
}
