//! Integration tests: every layer composed — manifest -> PJRT sessions ->
//! data substrates -> experiment drivers -> serving router.
//!
//! These use the small "test" artifact set (built by `make artifacts`)
//! and require the XLA vendor set; the offline-native equivalents live in
//! spm-coordinator/tests/native.rs.

use spm_coordinator::config::RunConfig;
use spm_coordinator::experiments::{self, DataSource};
use spm_core::ops::LinearCfg;
use spm_core::spm::Variant;
use spm_runtime::drivers::{self, serve_demo};
use spm_runtime::{Engine, HostTensor, Manifest, TrainSession};

fn artifacts() -> String {
    format!("{}/../../artifacts", env!("CARGO_MANIFEST_DIR"))
}

fn quick_cfg() -> RunConfig {
    RunConfig {
        steps: 6,
        eval_batches: 2,
        warmup: 1,
        artifacts: artifacts(),
        ..Default::default()
    }
}

#[test]
fn every_manifest_entry_loads_and_inits() {
    let engine = Engine::cpu().unwrap();
    let man = Manifest::load(artifacts()).unwrap();
    // compile + init every SMALL entry (large ones are exercised by benches)
    for (name, e) in &man.entries {
        if e.meta_usize("n").unwrap_or(9999) > 64 {
            continue;
        }
        let mut sess = TrainSession::new(&engine, &man, name, &["init"]).unwrap();
        sess.init(3).unwrap_or_else(|e| panic!("init {name}: {e}"));
        let leaves = sess.params_host().unwrap();
        assert_eq!(leaves.len(), sess.entry.nleaves, "{name}");
        for (leaf, spec) in leaves.iter().zip(&sess.entry.leaves) {
            assert!(
                leaf.iter().all(|v| v.is_finite()),
                "{name}: non-finite init in {}",
                spec.name
            );
        }
    }
}

#[test]
fn clf_trains_via_experiment_driver() {
    let engine = Engine::cpu().unwrap();
    let man = Manifest::load(artifacts()).unwrap();
    let data = DataSource::Teacher { n: 64, classes: 10, seed: 5 };
    let cfg = quick_cfg();
    let out = drivers::run_clf_xla(&engine, &man, "clf_spm_small", &data, &cfg).unwrap();
    assert_eq!(out.n, 64);
    assert!(out.loss.is_finite());
    assert!(out.ms_per_step > 0.0);
    assert!((0.0..=1.0).contains(&out.acc));
}

#[test]
fn charlm_small_runs_and_reports_bpc() {
    let engine = Engine::cpu().unwrap();
    let man = Manifest::load(artifacts()).unwrap();
    let cfg = RunConfig { steps: 4, eval_every: 2, eval_batches: 2, warmup: 1,
                          artifacts: artifacts(), ..Default::default() };
    let rows = drivers::run_charlm(&engine, &man, "charlm_spm_small", &cfg).unwrap();
    assert!(!rows.is_empty());
    for r in &rows {
        assert!(r.valid_nll.is_finite());
        assert!((r.valid_bpc - r.valid_nll / std::f32::consts::LN_2).abs() < 1e-5);
    }
    // untrained char-LM should start near uniform over 256 bytes
    assert!(rows[0].valid_nll < 7.0, "nll {}", rows[0].valid_nll);
}

#[test]
fn native_and_xla_teacher_tasks_agree_roughly() {
    // both engines should learn the same small teacher task to similar
    // accuracy under the same budget — a cross-engine consistency check
    let engine = Engine::cpu().unwrap();
    let man = Manifest::load(artifacts()).unwrap();
    let data = DataSource::Teacher { n: 64, classes: 10, seed: 9 };
    let cfg = RunConfig { steps: 150, eval_batches: 4, warmup: 1,
                          artifacts: artifacts(), ..Default::default() };
    let xla = drivers::run_clf_xla(&engine, &man, "clf_spm_small", &data, &cfg).unwrap();
    let native = experiments::run_clf_native(
        "native",
        LinearCfg::spm(64, Variant::General),
        10,
        32,
        &data,
        &cfg,
    )
    .unwrap();
    assert!(xla.acc > 0.15, "xla acc {}", xla.acc);
    assert!(native.acc > 0.15, "native acc {}", native.acc);
    assert!((xla.acc - native.acc).abs() < 0.4, "{} vs {}", xla.acc, native.acc);
}

#[test]
fn gru_and_attention_artifacts_train() {
    let engine = Engine::cpu().unwrap();
    let man = Manifest::load(artifacts()).unwrap();
    // GRU: (B, T, n) f32 -> 4 classes; shapes come from the manifest
    let mut gru = TrainSession::new(&engine, &man, "gru_spm_small", &["init", "train"]).unwrap();
    gru.init(0).unwrap();
    let t = gru.entry.meta_usize("seq_len").unwrap();
    let x = HostTensor::F32((0..32 * t * 64).map(|i| ((i % 13) as f32 - 6.0) * 0.1).collect());
    let y = HostTensor::I32((0..32).map(|i| (i % 4) as i32).collect());
    let (l1, _) = gru.train_step(&x, &y).unwrap();
    let (l2, _) = gru.train_step(&x, &y).unwrap();
    assert!(l1.is_finite() && l2.is_finite());
    assert!(l2 <= l1 + 0.5);

    // attention: (B=8, T=32, d=64) -> same-shape regression
    let mut attn =
        TrainSession::new(&engine, &man, "attn_spm_small", &["init", "train"]).unwrap();
    attn.init(0).unwrap();
    let xv: Vec<f32> = (0..8 * 32 * 64).map(|i| ((i % 7) as f32 - 3.0) * 0.2).collect();
    let x = HostTensor::F32(xv.clone());
    let y = HostTensor::F32(xv);
    let (a1, _) = attn.train_step(&x, &y).unwrap();
    for _ in 0..5 {
        attn.train_step(&x, &y).unwrap();
    }
    let (a2, _) = attn.train_step(&x, &y).unwrap();
    assert!(a2 < a1, "attention mse {a1} -> {a2}");
}

#[test]
fn serving_router_end_to_end() {
    let engine = Engine::cpu().unwrap();
    let man = Manifest::load(artifacts()).unwrap();
    // 97 requests over 3 clients: the router must serve the remainder too
    let report = serve_demo(&engine, &man, "clf_spm_small", 97, 3, 2).unwrap();
    assert_eq!(report.requests, 97);
    assert!(report.batches >= 4); // 97 requests can't fit three 32-batches
    assert!(report.p99_ms >= report.p50_ms);
    assert!(report.throughput_rps > 0.0);
}
