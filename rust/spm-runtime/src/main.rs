//! `spm` — the experiment launcher.
//!
//! Subcommands (hand-rolled CLI; clap is not in the offline vendor set):
//!   spm list                              list manifest entries
//!   spm info                              platform / artifact summary
//!   spm run <experiment> [opts]           run a paper experiment
//!   spm train <entry> [opts]              generic train loop (+checkpoints)
//!   spm serve <entry> [opts]              batched serving demo
//!
//! Experiments: table1, table2, table3, table4, table1-native,
//! table2-native, abl-depth, abl-pairing, abl-variant, core-scaling.
//! The *-native experiments run the pure-rust LinearOp engine; the rest
//! replay the AOT artifacts on the PJRT path.
//!
//! Common options:
//!   --steps N --eval-every N --eval-batches N --seed N --warmup N
//!   --csv PATH --config FILE.toml --artifacts DIR --threads N
//!   --widths 256,512 (table1/2)

use spm_coordinator::bail;
use spm_coordinator::config::RunConfig;
use spm_coordinator::error::{Context, Result};
use spm_coordinator::experiments;
use spm_runtime::{drivers, Engine, Manifest};

fn usage() -> ! {
    eprintln!(
        "usage: spm <list|info|run <experiment>|serve <entry>> [options]\n\
         experiments: table1 table2 table3 table4 table1-native table2-native\n\
                      abl-depth abl-pairing abl-variant core-scaling\n\
         options: --steps N --eval-every N --eval-batches N --seed N --warmup N\n\
                  --csv PATH --config FILE --artifacts DIR --threads N --widths a,b\n\
                  --requests N --clients N (serve)"
    );
    std::process::exit(2);
}

struct Args {
    positional: Vec<String>,
    options: std::collections::BTreeMap<String, String>,
}

fn parse_args() -> Args {
    let mut positional = Vec::new();
    let mut options = std::collections::BTreeMap::new();
    let mut it = std::env::args().skip(1);
    while let Some(a) = it.next() {
        if let Some(key) = a.strip_prefix("--") {
            let val = it.next().unwrap_or_else(|| {
                eprintln!("option --{key} needs a value");
                std::process::exit(2);
            });
            options.insert(key.to_string(), val);
        } else {
            positional.push(a);
        }
    }
    Args { positional, options }
}

fn build_config(args: &Args) -> Result<RunConfig> {
    let mut cfg = RunConfig::default();
    if let Some(path) = args.options.get("config") {
        cfg.load_file(path)?;
    }
    let get_usize = |key: &str| -> Result<Option<usize>> {
        match args.options.get(key) {
            Some(v) => Ok(Some(v.parse::<usize>().with_context(|| format!("--{key}"))?)),
            None => Ok(None),
        }
    };
    if let Some(v) = get_usize("steps")? {
        cfg.steps = v;
    }
    if let Some(v) = get_usize("eval-every")? {
        cfg.eval_every = v;
    }
    if let Some(v) = get_usize("eval-batches")? {
        cfg.eval_batches = v;
    }
    if let Some(v) = get_usize("seed")? {
        cfg.seed = v as u64;
    }
    if let Some(v) = get_usize("warmup")? {
        cfg.warmup = v;
    }
    if let Some(v) = get_usize("threads")? {
        cfg.threads = v;
    }
    if let Some(v) = args.options.get("csv") {
        cfg.out_csv = v.clone();
    }
    if let Some(v) = args.options.get("artifacts") {
        cfg.artifacts = v.clone();
    }
    if cfg.threads > 0 {
        spm_core::parallel::set_threads(cfg.threads);
    }
    Ok(cfg)
}

fn parse_widths(args: &Args, default: &[usize]) -> Result<Vec<usize>> {
    match args.options.get("widths") {
        None => Ok(default.to_vec()),
        Some(s) => s
            .split(',')
            .map(|w| w.trim().parse::<usize>().context("--widths"))
            .collect(),
    }
}

fn main() -> Result<()> {
    let args = parse_args();
    if args.positional.is_empty() {
        usage();
    }
    let cfg = build_config(&args)?;
    match args.positional[0].as_str() {
        "list" => {
            let man = Manifest::load(&cfg.artifacts)?;
            println!("{:<28} {:>8} {:>10} {:>7}  artifacts", "entry", "n", "params", "kind");
            for (name, e) in &man.entries {
                println!(
                    "{:<28} {:>8} {:>10} {:>7}  {}",
                    name,
                    e.meta_str("n"),
                    e.meta_str("param_count"),
                    e.meta_str("kind"),
                    e.artifacts.keys().cloned().collect::<Vec<_>>().join(",")
                );
            }
        }
        "info" => {
            let engine = Engine::cpu()?;
            let man = Manifest::load(&cfg.artifacts)?;
            println!("platform : {}", engine.platform());
            println!("entries  : {}", man.entries.len());
            println!("artifacts: {}", cfg.artifacts);
            println!("threads  : {}", spm_core::parallel::num_threads());
        }
        "run" => {
            if args.positional.len() < 2 {
                usage();
            }
            let exp = args.positional[1].as_str();
            let report = match exp {
                "table1" | "table2" => {
                    let engine = Engine::cpu()?;
                    let man = Manifest::load(&cfg.artifacts)?;
                    if exp == "table1" {
                        let widths = parse_widths(&args, &[256, 512, 1024, 2048])?;
                        drivers::run_table1(&engine, &man, &widths, &cfg)?
                    } else {
                        let widths = parse_widths(&args, &[2048, 4096])?;
                        drivers::run_table2(&engine, &man, &widths, &cfg)?
                    }
                }
                "table1-native" => {
                    let widths = parse_widths(&args, &[256, 512, 1024, 2048])?;
                    experiments::run_table1_native(&widths, &cfg)?
                }
                "table2-native" => {
                    let widths = parse_widths(&args, &[2048, 4096])?;
                    experiments::run_table2_native(&widths, &cfg)?
                }
                "table3" | "table4" => {
                    let engine = Engine::cpu()?;
                    let man = Manifest::load(&cfg.artifacts)?;
                    let entry =
                        if exp == "table3" { "charlm_dense_d4096" } else { "charlm_spm_d4096" };
                    let rows = drivers::run_charlm(&engine, &man, entry, &cfg)?;
                    experiments::render_charlm_table(
                        &format!(
                            "{} — char-LM {} (d=4096)",
                            if exp == "table3" { "Table 3" } else { "Table 4" },
                            entry
                        ),
                        &rows,
                    )
                }
                "abl-depth" | "abl-pairing" | "abl-variant" => {
                    let engine = Engine::cpu()?;
                    let man = Manifest::load(&cfg.artifacts)?;
                    drivers::run_ablation(&engine, &man, &exp[4..], &cfg)?
                }
                "core-scaling" => {
                    let widths = parse_widths(&args, &[256, 512, 1024, 2048, 4096])?;
                    experiments::run_core_scaling(&widths, 64)
                }
                other => bail!("unknown experiment '{other}'"),
            };
            println!("{report}");
        }
        "train" => {
            // generic training with checkpoint save/resume:
            //   spm train <entry> --steps N [--save ckpt] [--load ckpt]
            if args.positional.len() < 2 {
                usage();
            }
            let entry_name = args.positional[1].as_str();
            let engine = Engine::cpu()?;
            let man = Manifest::load(&cfg.artifacts)?;
            let mut sess = spm_runtime::TrainSession::new(
                &engine, &man, entry_name, &["init", "train", "eval"])?;
            if let Some(path) = args.options.get("load") {
                let ck = spm_runtime::checkpoint::load(std::path::Path::new(path))?;
                spm_runtime::checkpoint::validate(&ck, &sess.entry)?;
                let leaves: Vec<Vec<f32>> = ck.leaves.into_iter().map(|(_, d)| d).collect();
                sess.load_params(&leaves)?;
                println!("resumed from {path}");
            } else {
                sess.init(cfg.seed as i32)?;
            }
            let n = sess.entry.meta_usize("n")?;
            let batch = sess.entry.meta_usize("batch")?;
            let classes = sess.entry.meta_usize("num_classes").unwrap_or(10);
            let data = experiments::DataSource::Teacher { n, classes, seed: 7 + n as u64 };
            for step in 0..cfg.steps {
                let (x, y) = data.batch(step, batch, true);
                let (loss, metric) = sess.train_step(
                    &spm_runtime::HostTensor::F32(x.data),
                    &spm_runtime::HostTensor::from_labels(&y))?;
                if step % 20 == 0 || step + 1 == cfg.steps {
                    println!("step {step:>5}: loss {loss:.4} metric {metric:.4}");
                }
            }
            if let Some(path) = args.options.get("save") {
                let leaves = sess.params_host()?;
                spm_runtime::checkpoint::save(
                    std::path::Path::new(path), &sess.entry, &leaves)?;
                println!("saved checkpoint to {path}");
            }
        }
        "serve" => {
            if args.positional.len() < 2 {
                usage();
            }
            let entry = args.positional[1].as_str();
            let requests: usize = args
                .options
                .get("requests")
                .map(|v| v.parse())
                .transpose()
                .context("--requests")?
                .unwrap_or(512);
            let clients: usize = args
                .options
                .get("clients")
                .map(|v| v.parse())
                .transpose()
                .context("--clients")?
                .unwrap_or(4);
            let engine = Engine::cpu()?;
            let man = Manifest::load(&cfg.artifacts)?;
            let report = drivers::serve_demo(&engine, &man, entry, requests, clients, cfg.seed)?;
            println!("{report}");
        }
        _ => usage(),
    }
    Ok(())
}
