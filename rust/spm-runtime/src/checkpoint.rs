//! Checkpointing: save/restore the full device-resident training state
//! (parameter leaves) to a self-describing binary file, so long runs
//! (Tables 3/4 at full step counts) can be resumed and trained models can
//! be served later.
//!
//! Format (little-endian):
//!   magic "SPMCKPT1" | u32 entry-name len | name bytes
//!   | u32 leaf count | per leaf: u32 name len, name, u32 elems, f32 data[]
//!
//! Only f32 leaves are stored (all current models); the manifest leaf list
//! is the schema against which a load is validated.

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::manifest::{Entry, TensorSpec};

const MAGIC: &[u8; 8] = b"SPMCKPT1";

pub struct Checkpoint {
    pub entry_name: String,
    pub leaves: Vec<(String, Vec<f32>)>,
}

fn w_u32(f: &mut impl Write, v: u32) -> Result<()> {
    f.write_all(&v.to_le_bytes())?;
    Ok(())
}

fn r_u32(f: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    f.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

pub fn save(path: &Path, entry: &Entry, leaves: &[Vec<f32>]) -> Result<()> {
    if leaves.len() != entry.leaves.len() {
        bail!("leaf count {} != manifest {}", leaves.len(), entry.leaves.len());
    }
    let mut f = std::fs::File::create(path)
        .with_context(|| format!("creating checkpoint {}", path.display()))?;
    f.write_all(MAGIC)?;
    w_u32(&mut f, entry.name.len() as u32)?;
    f.write_all(entry.name.as_bytes())?;
    w_u32(&mut f, leaves.len() as u32)?;
    for (spec, data) in entry.leaves.iter().zip(leaves) {
        if data.len() != spec.elements() {
            bail!("{}: {} values, want {}", spec.name, data.len(), spec.elements());
        }
        w_u32(&mut f, spec.name.len() as u32)?;
        f.write_all(spec.name.as_bytes())?;
        w_u32(&mut f, data.len() as u32)?;
        for v in data {
            f.write_all(&v.to_le_bytes())?;
        }
    }
    Ok(())
}

pub fn load(path: &Path) -> Result<Checkpoint> {
    let mut f = std::fs::File::open(path)
        .with_context(|| format!("opening checkpoint {}", path.display()))?;
    let mut magic = [0u8; 8];
    f.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("{} is not an SPM checkpoint", path.display());
    }
    let nlen = r_u32(&mut f)? as usize;
    let mut name = vec![0u8; nlen];
    f.read_exact(&mut name)?;
    let entry_name = String::from_utf8(name).context("entry name not utf-8")?;
    let count = r_u32(&mut f)? as usize;
    let mut leaves = Vec::with_capacity(count);
    for _ in 0..count {
        let ln = r_u32(&mut f)? as usize;
        let mut lname = vec![0u8; ln];
        f.read_exact(&mut lname)?;
        let elems = r_u32(&mut f)? as usize;
        let mut raw = vec![0u8; elems * 4];
        f.read_exact(&mut raw)?;
        let data = raw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        leaves.push((String::from_utf8(lname).context("leaf name")?, data));
    }
    Ok(Checkpoint { entry_name, leaves })
}

/// Validate a checkpoint against a manifest entry (names, order, sizes).
pub fn validate(ckpt: &Checkpoint, entry: &Entry) -> Result<()> {
    if ckpt.entry_name != entry.name {
        bail!("checkpoint is for '{}', not '{}'", ckpt.entry_name, entry.name);
    }
    if ckpt.leaves.len() != entry.leaves.len() {
        bail!("leaf count mismatch");
    }
    for ((cn, cd), spec) in ckpt.leaves.iter().zip(&entry.leaves) {
        if cn != &spec.name {
            bail!("leaf order mismatch: {} vs {}", cn, spec.name);
        }
        if cd.len() != spec.elements() {
            bail!("{}: {} values, want {}", cn, cd.len(), spec.elements());
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::manifest::DType;
    use std::collections::BTreeMap;

    fn toy_entry() -> Entry {
        Entry {
            name: "toy".into(),
            nleaves: 2,
            leaves: vec![
                TensorSpec { name: "w".into(), shape: vec![2, 3], dtype: DType::F32 },
                TensorSpec { name: "b".into(), shape: vec![3], dtype: DType::F32 },
            ],
            artifacts: BTreeMap::new(),
            meta: BTreeMap::new(),
        }
    }

    #[test]
    fn roundtrip() {
        let entry = toy_entry();
        let leaves = vec![vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0], vec![-1.0, 0.5, 2.25]];
        let path = std::env::temp_dir().join("spm_ckpt_test.bin");
        save(&path, &entry, &leaves).unwrap();
        let ck = load(&path).unwrap();
        validate(&ck, &entry).unwrap();
        assert_eq!(ck.entry_name, "toy");
        assert_eq!(ck.leaves[0].1, leaves[0]);
        assert_eq!(ck.leaves[1].1, leaves[1]);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn rejects_wrong_entry() {
        let entry = toy_entry();
        let leaves = vec![vec![0.0; 6], vec![0.0; 3]];
        let path = std::env::temp_dir().join("spm_ckpt_test2.bin");
        save(&path, &entry, &leaves).unwrap();
        let ck = load(&path).unwrap();
        let mut other = toy_entry();
        other.name = "other".into();
        assert!(validate(&ck, &other).is_err());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn rejects_garbage_file() {
        let path = std::env::temp_dir().join("spm_ckpt_test3.bin");
        std::fs::write(&path, b"not a checkpoint").unwrap();
        assert!(load(&path).is_err());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn rejects_bad_sizes() {
        let entry = toy_entry();
        let leaves = vec![vec![0.0; 5], vec![0.0; 3]]; // 5 != 6
        let path = std::env::temp_dir().join("spm_ckpt_test4.bin");
        assert!(save(&path, &entry, &leaves).is_err());
    }
}
