//! Checkpointing: save/restore the full device-resident training state
//! (parameter leaves) to a self-describing binary file, so long runs
//! (Tables 3/4 at full step counts) can be resumed and trained models can
//! be served later.
//!
//! Format (little-endian):
//!   magic "SPMCKPT1" | u32 entry-name len | name bytes
//!   | u32 leaf count | per leaf: u32 name len, name, u32 elems, f32 data[]
//!
//! Only f32 leaves are stored (all current models); the manifest leaf list
//! is the schema against which a load is validated.
//!
//! Loading treats every length field as UNTRUSTED: names, leaf counts,
//! and element counts are validated against sane caps AND the bytes
//! actually remaining in the file BEFORE any buffer is allocated (the
//! same hardening the spm-core native checkpoints got in PR 4 — a
//! corrupt or truncated file must error, never demand a multi-GiB
//! allocation), and trailing bytes after the last leaf are rejected.

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::manifest::{Entry, TensorSpec};

const MAGIC: &[u8; 8] = b"SPMCKPT1";

/// Cap on entry/leaf name lengths. Real names are tens of bytes; a
/// length field beyond this is corruption, not a name.
const MAX_NAME_LEN: usize = 4096;

/// Cap on the leaf count. Every current model has < 20 leaves; a count
/// beyond this is corruption.
const MAX_LEAVES: usize = 1 << 16;

pub struct Checkpoint {
    pub entry_name: String,
    pub leaves: Vec<(String, Vec<f32>)>,
}

fn w_u32(f: &mut impl Write, v: u32) -> Result<()> {
    f.write_all(&v.to_le_bytes())?;
    Ok(())
}

pub fn save(path: &Path, entry: &Entry, leaves: &[Vec<f32>]) -> Result<()> {
    if leaves.len() != entry.leaves.len() {
        bail!("leaf count {} != manifest {}", leaves.len(), entry.leaves.len());
    }
    let mut f = std::fs::File::create(path)
        .with_context(|| format!("creating checkpoint {}", path.display()))?;
    f.write_all(MAGIC)?;
    w_u32(&mut f, entry.name.len() as u32)?;
    f.write_all(entry.name.as_bytes())?;
    w_u32(&mut f, leaves.len() as u32)?;
    for (spec, data) in entry.leaves.iter().zip(leaves) {
        if data.len() != spec.elements() {
            bail!("{}: {} values, want {}", spec.name, data.len(), spec.elements());
        }
        w_u32(&mut f, spec.name.len() as u32)?;
        f.write_all(spec.name.as_bytes())?;
        w_u32(&mut f, data.len() as u32)?;
        for v in data {
            f.write_all(&v.to_le_bytes())?;
        }
    }
    Ok(())
}

/// `read_exact` that accounts against the bytes known to remain in the
/// file, so a corrupt length field is caught BEFORE any allocation or
/// read happens.
fn r_exact(f: &mut impl Read, remaining: &mut u64, buf: &mut [u8]) -> Result<()> {
    if buf.len() as u64 > *remaining {
        bail!("checkpoint truncated: need {} bytes, {} remain", buf.len(), remaining);
    }
    f.read_exact(buf)?;
    *remaining -= buf.len() as u64;
    Ok(())
}

fn r_u32_bounded(f: &mut impl Read, remaining: &mut u64) -> Result<u32> {
    let mut b = [0u8; 4];
    r_exact(f, remaining, &mut b)?;
    Ok(u32::from_le_bytes(b))
}

/// Length-validated name read: the untrusted u32 is checked against the
/// name cap and the remaining file size before the buffer exists.
fn r_name(f: &mut impl Read, remaining: &mut u64, what: &str) -> Result<String> {
    let len = r_u32_bounded(f, remaining)? as usize;
    if len > MAX_NAME_LEN {
        bail!("{what} name length {len} exceeds the {MAX_NAME_LEN}-byte cap");
    }
    if len as u64 > *remaining {
        bail!("{what} name length {len} exceeds the {remaining} bytes remaining");
    }
    let mut buf = vec![0u8; len];
    r_exact(f, remaining, &mut buf)?;
    String::from_utf8(buf).with_context(|| format!("{what} name not utf-8"))
}

pub fn load(path: &Path) -> Result<Checkpoint> {
    let mut f = std::fs::File::open(path)
        .with_context(|| format!("opening checkpoint {}", path.display()))?;
    // every subsequent length field is validated against this budget
    // before its buffer is allocated
    let mut remaining = f
        .metadata()
        .with_context(|| format!("stat checkpoint {}", path.display()))?
        .len();
    let mut magic = [0u8; 8];
    r_exact(&mut f, &mut remaining, &mut magic)?;
    if &magic != MAGIC {
        bail!("{} is not an SPM checkpoint", path.display());
    }
    let entry_name = r_name(&mut f, &mut remaining, "entry")?;
    let count = r_u32_bounded(&mut f, &mut remaining)? as usize;
    if count > MAX_LEAVES {
        bail!("leaf count {count} exceeds the {MAX_LEAVES} cap");
    }
    // each leaf carries at least its two u32 length fields
    if (count as u64) * 8 > remaining {
        bail!("leaf count {count} cannot fit in the {remaining} bytes remaining");
    }
    let mut leaves = Vec::with_capacity(count);
    for _ in 0..count {
        let lname = r_name(&mut f, &mut remaining, "leaf")?;
        let elems = r_u32_bounded(&mut f, &mut remaining)? as usize;
        let bytes = elems as u64 * 4;
        if bytes > remaining {
            bail!(
                "leaf '{lname}' claims {elems} f32s ({bytes} bytes) but only {remaining} \
                 bytes remain"
            );
        }
        let mut raw = vec![0u8; elems * 4];
        r_exact(&mut f, &mut remaining, &mut raw)?;
        let data = raw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        leaves.push((lname, data));
    }
    if remaining != 0 {
        bail!("checkpoint has {remaining} trailing bytes after the last leaf");
    }
    Ok(Checkpoint { entry_name, leaves })
}

/// Validate a checkpoint against a manifest entry (names, order, sizes).
pub fn validate(ckpt: &Checkpoint, entry: &Entry) -> Result<()> {
    if ckpt.entry_name != entry.name {
        bail!("checkpoint is for '{}', not '{}'", ckpt.entry_name, entry.name);
    }
    if ckpt.leaves.len() != entry.leaves.len() {
        bail!("leaf count mismatch");
    }
    for ((cn, cd), spec) in ckpt.leaves.iter().zip(&entry.leaves) {
        if cn != &spec.name {
            bail!("leaf order mismatch: {} vs {}", cn, spec.name);
        }
        if cd.len() != spec.elements() {
            bail!("{}: {} values, want {}", cn, cd.len(), spec.elements());
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::manifest::DType;
    use std::collections::BTreeMap;

    fn toy_entry() -> Entry {
        Entry {
            name: "toy".into(),
            nleaves: 2,
            leaves: vec![
                TensorSpec { name: "w".into(), shape: vec![2, 3], dtype: DType::F32 },
                TensorSpec { name: "b".into(), shape: vec![3], dtype: DType::F32 },
            ],
            artifacts: BTreeMap::new(),
            meta: BTreeMap::new(),
        }
    }

    #[test]
    fn roundtrip() {
        let entry = toy_entry();
        let leaves = vec![vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0], vec![-1.0, 0.5, 2.25]];
        let path = std::env::temp_dir().join("spm_ckpt_test.bin");
        save(&path, &entry, &leaves).unwrap();
        let ck = load(&path).unwrap();
        validate(&ck, &entry).unwrap();
        assert_eq!(ck.entry_name, "toy");
        assert_eq!(ck.leaves[0].1, leaves[0]);
        assert_eq!(ck.leaves[1].1, leaves[1]);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn rejects_wrong_entry() {
        let entry = toy_entry();
        let leaves = vec![vec![0.0; 6], vec![0.0; 3]];
        let path = std::env::temp_dir().join("spm_ckpt_test2.bin");
        save(&path, &entry, &leaves).unwrap();
        let ck = load(&path).unwrap();
        let mut other = toy_entry();
        other.name = "other".into();
        assert!(validate(&ck, &other).is_err());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn rejects_garbage_file() {
        let path = std::env::temp_dir().join("spm_ckpt_test3.bin");
        std::fs::write(&path, b"not a checkpoint").unwrap();
        assert!(load(&path).is_err());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn rejects_bad_sizes() {
        let entry = toy_entry();
        let leaves = vec![vec![0.0; 5], vec![0.0; 3]]; // 5 != 6
        let path = std::env::temp_dir().join("spm_ckpt_test4.bin");
        assert!(save(&path, &entry, &leaves).is_err());
    }

    // ---- corrupt-file suite: every untrusted length field must be
    // rejected BEFORE it can provoke an allocation, and the errors must
    // be errors — never panics ----

    fn write_tmp(name: &str, bytes: &[u8]) -> std::path::PathBuf {
        let path = std::env::temp_dir().join(name);
        std::fs::write(&path, bytes).unwrap();
        path
    }

    fn valid_bytes() -> Vec<u8> {
        let entry = toy_entry();
        let leaves = vec![vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0], vec![-1.0, 0.5, 2.25]];
        let path = std::env::temp_dir().join("spm_ckpt_valid_src.bin");
        save(&path, &entry, &leaves).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        let _ = std::fs::remove_file(&path);
        bytes
    }

    #[test]
    fn rejects_truncated_header() {
        // any prefix of a valid file must error cleanly
        let bytes = valid_bytes();
        for cut in [0, 4, 8, 10, bytes.len() - 1] {
            let path = write_tmp("spm_ckpt_trunc.bin", &bytes[..cut]);
            assert!(load(&path).is_err(), "prefix of {cut} bytes must be rejected");
            let _ = std::fs::remove_file(&path);
        }
    }

    #[test]
    fn rejects_oversized_name_len_without_allocating() {
        // magic + u32::MAX entry-name length: must error on the length
        // field, not attempt a 4 GiB name buffer
        let mut bytes = MAGIC.to_vec();
        bytes.extend_from_slice(&u32::MAX.to_le_bytes());
        let path = write_tmp("spm_ckpt_badname.bin", &bytes);
        let err = load(&path).unwrap_err();
        assert!(err.to_string().contains("name length"), "{err}");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn rejects_oversized_leaf_count() {
        // plausible header, then a u32::MAX leaf count in a tiny file
        let mut bytes = MAGIC.to_vec();
        bytes.extend_from_slice(&3u32.to_le_bytes());
        bytes.extend_from_slice(b"toy");
        bytes.extend_from_slice(&u32::MAX.to_le_bytes());
        let path = write_tmp("spm_ckpt_badcount.bin", &bytes);
        let err = load(&path).unwrap_err();
        assert!(err.to_string().contains("leaf count"), "{err}");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn rejects_oversized_leaf_elems_without_allocating() {
        // one leaf claiming ~4 billion f32s: the element count must be
        // checked against the bytes remaining before any data buffer
        let mut bytes = MAGIC.to_vec();
        bytes.extend_from_slice(&3u32.to_le_bytes());
        bytes.extend_from_slice(b"toy");
        bytes.extend_from_slice(&1u32.to_le_bytes()); // one leaf
        bytes.extend_from_slice(&1u32.to_le_bytes());
        bytes.push(b'w');
        bytes.extend_from_slice(&u32::MAX.to_le_bytes()); // elems
        bytes.extend_from_slice(&[0u8; 16]); // a few real bytes
        let path = write_tmp("spm_ckpt_badelems.bin", &bytes);
        let err = load(&path).unwrap_err();
        assert!(err.to_string().contains("bytes remain"), "{err}");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn rejects_trailing_garbage() {
        let mut bytes = valid_bytes();
        bytes.extend_from_slice(b"junk");
        let path = write_tmp("spm_ckpt_trailing.bin", &bytes);
        let err = load(&path).unwrap_err();
        assert!(err.to_string().contains("trailing"), "{err}");
        let _ = std::fs::remove_file(&path);
    }
}
