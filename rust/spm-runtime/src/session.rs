//! Buffer-resident training session: the L3 hot loop.
//!
//! `TrainSession` holds parameters, Adam moments and the step counter as
//! **device buffers** for the whole run; each `train_step` uploads only the
//! batch, executes the AOT-compiled train artifact via `execute_b`, swaps
//! the returned state buffers in, and downloads two scalars (loss, metric).
//! Python never runs; the only per-step host work is batch upload.

use anyhow::{anyhow, bail, Context, Result};
use xla::{PjRtBuffer, PjRtLoadedExecutable};

use crate::engine::Engine;
use crate::manifest::{DType, Entry, Manifest, TensorSpec};

/// A typed host batch matching one artifact input.
pub enum HostTensor {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl HostTensor {
    pub fn from_labels(labels: &[u32]) -> HostTensor {
        HostTensor::I32(labels.iter().map(|&v| v as i32).collect())
    }

    pub fn from_bytes(bytes: &[u8]) -> HostTensor {
        HostTensor::I32(bytes.iter().map(|&v| v as i32).collect())
    }
}

pub struct TrainSession<'e> {
    pub engine: &'e Engine,
    pub entry: Entry,
    init_exe: Option<PjRtLoadedExecutable>,
    train_exe: Option<PjRtLoadedExecutable>,
    eval_exe: Option<PjRtLoadedExecutable>,
    forward_exe: Option<PjRtLoadedExecutable>,
    params: Vec<PjRtBuffer>,
    m: Vec<PjRtBuffer>,
    v: Vec<PjRtBuffer>,
    step: Option<PjRtBuffer>,
    pub steps_done: u64,
}

impl<'e> TrainSession<'e> {
    /// Compile the requested artifact kinds ("init", "train", "eval",
    /// "forward") for `name`. Compilation cost is paid once, up front.
    pub fn new(engine: &'e Engine, manifest: &Manifest, name: &str, kinds: &[&str]) -> Result<Self> {
        let entry = manifest.entry(name)?.clone();
        let load = |kind: &str| -> Result<Option<PjRtLoadedExecutable>> {
            if kinds.contains(&kind) {
                Ok(Some(engine.load(&entry.artifact(kind)?.file)?))
            } else {
                Ok(None)
            }
        };
        Ok(TrainSession {
            engine,
            init_exe: load("init")?,
            train_exe: load("train")?,
            eval_exe: load("eval")?,
            forward_exe: load("forward")?,
            entry,
            params: Vec::new(),
            m: Vec::new(),
            v: Vec::new(),
            step: None,
            steps_done: 0,
        })
    }

    /// Run the init artifact: parameters land on device; Adam moments are
    /// zero-initialized to matching shapes; step counter = 0.
    pub fn init(&mut self, seed: i32) -> Result<()> {
        let exe = self.init_exe.as_ref().ok_or_else(|| anyhow!("init not compiled"))?;
        let seed_buf = self.engine.upload_scalar_i32(seed)?;
        let mut outs = exe
            .execute_b::<&PjRtBuffer>(&[&seed_buf])
            .context("running init")?;
        let leaves = std::mem::take(&mut outs[0]);
        if leaves.len() != self.entry.nleaves {
            bail!("init returned {} buffers, want {}", leaves.len(), self.entry.nleaves);
        }
        self.m = self
            .entry
            .leaves
            .iter()
            .map(|l| self.engine.upload_zeros(l))
            .collect::<Result<_>>()?;
        self.v = self
            .entry
            .leaves
            .iter()
            .map(|l| self.engine.upload_zeros(l))
            .collect::<Result<_>>()?;
        self.params = leaves;
        self.step = Some(self.engine.upload_scalar_f32(0.0)?);
        self.steps_done = 0;
        Ok(())
    }

    fn upload_batch(&self, spec: &TensorSpec, t: &HostTensor) -> Result<PjRtBuffer> {
        match (t, &spec.dtype) {
            (HostTensor::F32(d), DType::F32) => self.engine.upload_f32(spec, d),
            (HostTensor::I32(d), DType::I32) => self.engine.upload_i32(spec, d),
            _ => bail!("batch dtype mismatch for {}", spec.name),
        }
    }

    /// One buffer-resident training step; returns (loss, metric).
    pub fn train_step(&mut self, x: &HostTensor, y: &HostTensor) -> Result<(f32, f32)> {
        let exe = self.train_exe.as_ref().ok_or_else(|| anyhow!("train not compiled"))?;
        if self.params.is_empty() {
            bail!("session not initialized (call init)");
        }
        let nl = self.entry.nleaves;
        let art = self.entry.artifact("train")?;
        let x_buf = self.upload_batch(&art.inputs[3 * nl + 1], x)?;
        let y_buf = self.upload_batch(&art.inputs[3 * nl + 2], y)?;
        let mut args: Vec<&PjRtBuffer> = Vec::with_capacity(3 * nl + 3);
        args.extend(self.params.iter());
        args.extend(self.m.iter());
        args.extend(self.v.iter());
        args.push(self.step.as_ref().unwrap());
        args.push(&x_buf);
        args.push(&y_buf);
        let mut outs = exe.execute_b::<&PjRtBuffer>(&args).context("train step")?;
        let mut bufs = std::mem::take(&mut outs[0]);
        if bufs.len() != 3 * nl + 3 {
            bail!("train returned {} outputs, want {}", bufs.len(), 3 * nl + 3);
        }
        // outputs in order: params', m', v', step', loss, metric
        let metric_buf = bufs.pop().unwrap();
        let loss_buf = bufs.pop().unwrap();
        let step_buf = bufs.pop().unwrap();
        let v_new = bufs.split_off(2 * nl);
        let m_new = bufs.split_off(nl);
        self.params = bufs;
        self.m = m_new;
        self.v = v_new;
        self.step = Some(step_buf);
        self.steps_done += 1;
        let loss = self.engine.read_f32(&loss_buf)?[0];
        let metric = self.engine.read_f32(&metric_buf)?[0];
        Ok((loss, metric))
    }

    /// Evaluation pass at current parameters; returns (loss, metric).
    pub fn eval(&self, x: &HostTensor, y: &HostTensor) -> Result<(f32, f32)> {
        let exe = self.eval_exe.as_ref().ok_or_else(|| anyhow!("eval not compiled"))?;
        let nl = self.entry.nleaves;
        let art = self.entry.artifact("eval")?;
        let x_buf = self.upload_batch(&art.inputs[nl], x)?;
        let y_buf = self.upload_batch(&art.inputs[nl + 1], y)?;
        let mut args: Vec<&PjRtBuffer> = Vec::with_capacity(nl + 2);
        args.extend(self.params.iter());
        args.push(&x_buf);
        args.push(&y_buf);
        let outs = exe.execute_b::<&PjRtBuffer>(&args).context("eval")?;
        let loss = self.engine.read_f32(&outs[0][0])?[0];
        let metric = self.engine.read_f32(&outs[0][1])?[0];
        Ok((loss, metric))
    }

    /// Forward pass (serving); returns the raw f32 output of the first
    /// output tensor.
    pub fn forward(&self, x: &HostTensor) -> Result<Vec<f32>> {
        let exe = self
            .forward_exe
            .as_ref()
            .ok_or_else(|| anyhow!("forward not compiled"))?;
        let nl = self.entry.nleaves;
        let art = self.entry.artifact("forward")?;
        let x_buf = self.upload_batch(&art.inputs[nl], x)?;
        let mut args: Vec<&PjRtBuffer> = Vec::with_capacity(nl + 1);
        args.extend(self.params.iter());
        args.push(&x_buf);
        let outs = exe.execute_b::<&PjRtBuffer>(&args).context("forward")?;
        self.engine.read_f32(&outs[0][0])
    }

    /// Forward for models whose output is integer (e.g. teacher labels).
    pub fn forward_i32(&self, x: &HostTensor) -> Result<Vec<i32>> {
        let exe = self
            .forward_exe
            .as_ref()
            .ok_or_else(|| anyhow!("forward not compiled"))?;
        let nl = self.entry.nleaves;
        let art = self.entry.artifact("forward")?;
        let x_buf = self.upload_batch(&art.inputs[nl], x)?;
        let mut args: Vec<&PjRtBuffer> = Vec::with_capacity(nl + 1);
        args.extend(self.params.iter());
        args.push(&x_buf);
        let outs = exe.execute_b::<&PjRtBuffer>(&args).context("forward")?;
        self.engine.read_i32(&outs[0][0])
    }

    /// Download all parameter leaves (checkpointing).
    pub fn params_host(&self) -> Result<Vec<Vec<f32>>> {
        self.params.iter().map(|b| self.engine.read_f32(b)).collect()
    }

    /// Restore parameters from host leaves (checkpoint resume). Optimizer
    /// moments and the step counter are reset — matching common
    /// fine-tune-from-checkpoint semantics.
    pub fn load_params(&mut self, leaves: &[Vec<f32>]) -> Result<()> {
        if leaves.len() != self.entry.nleaves {
            bail!("checkpoint has {} leaves, model wants {}", leaves.len(), self.entry.nleaves);
        }
        let mut bufs = Vec::with_capacity(leaves.len());
        for (spec, data) in self.entry.leaves.iter().zip(leaves) {
            bufs.push(self.engine.upload_f32(spec, data)?);
        }
        self.m = self
            .entry
            .leaves
            .iter()
            .map(|l| self.engine.upload_zeros(l))
            .collect::<Result<_>>()?;
        self.v = self
            .entry
            .leaves
            .iter()
            .map(|l| self.engine.upload_zeros(l))
            .collect::<Result<_>>()?;
        self.params = bufs;
        self.step = Some(self.engine.upload_scalar_f32(0.0)?);
        self.steps_done = 0;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn artifacts_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("../../artifacts")
    }

    fn rand_batch(n: usize, seed: u64) -> Vec<f32> {
        // cheap deterministic pseudo-noise
        let mut state = seed.wrapping_add(1);
        (0..n)
            .map(|_| {
                state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
                ((state >> 40) as f32 / (1u64 << 24) as f32) * 2.0 - 1.0
            })
            .collect()
    }

    #[test]
    fn full_train_loop_reduces_loss() {
        let engine = Engine::cpu().unwrap();
        let man = Manifest::load(artifacts_dir()).unwrap();
        let mut sess =
            TrainSession::new(&engine, &man, "clf_spm_small", &["init", "train", "eval"]).unwrap();
        sess.init(0).unwrap();
        // learnable rule: label = sign structure of first coords
        let xv = rand_batch(32 * 64, 7);
        let labels: Vec<u32> = (0..32)
            .map(|i| {
                let row = &xv[i * 64..i * 64 + 10];
                let mut best = 0;
                for j in 1..10 {
                    if row[j] > row[best] {
                        best = j;
                    }
                }
                best as u32
            })
            .collect();
        let x = HostTensor::F32(xv);
        let y = HostTensor::from_labels(&labels);
        let (first, _) = sess.train_step(&x, &y).unwrap();
        let mut last = first;
        for _ in 0..199 {
            last = sess.train_step(&x, &y).unwrap().0;
        }
        assert!(last < first - 0.1, "loss {first} -> {last}");
        assert_eq!(sess.steps_done, 200);
        let (eloss, eacc) = sess.eval(&x, &y).unwrap();
        assert!(eloss.is_finite() && (0.0..=1.0).contains(&eacc));
    }

    #[test]
    fn teacher_forward_labels() {
        let engine = Engine::cpu().unwrap();
        let man = Manifest::load(artifacts_dir()).unwrap();
        let mut sess =
            TrainSession::new(&engine, &man, "teacher_small", &["init", "forward"]).unwrap();
        sess.init(7).unwrap();
        let x = HostTensor::F32(rand_batch(32 * 64, 3));
        let labels = sess.forward_i32(&x).unwrap();
        assert_eq!(labels.len(), 32);
        assert!(labels.iter().all(|&l| (0..10).contains(&l)));
        // deterministic given params
        let labels2 = sess.forward_i32(&x).unwrap();
        assert_eq!(labels, labels2);
    }

    #[test]
    fn uninitialized_session_errors() {
        let engine = Engine::cpu().unwrap();
        let man = Manifest::load(artifacts_dir()).unwrap();
        let mut sess =
            TrainSession::new(&engine, &man, "clf_dense_small", &["train"]).unwrap();
        let x = HostTensor::F32(vec![0.0; 32 * 64]);
        let y = HostTensor::I32(vec![0; 32]);
        assert!(sess.train_step(&x, &y).is_err());
    }

    #[test]
    fn params_host_roundtrip_shapes() {
        let engine = Engine::cpu().unwrap();
        let man = Manifest::load(artifacts_dir()).unwrap();
        let mut sess = TrainSession::new(&engine, &man, "clf_spm_small", &["init"]).unwrap();
        sess.init(1).unwrap();
        let leaves = sess.params_host().unwrap();
        assert_eq!(leaves.len(), sess.entry.nleaves);
        for (leaf, spec) in leaves.iter().zip(&sess.entry.leaves) {
            assert_eq!(leaf.len(), spec.elements(), "{}", spec.name);
        }
    }
}
