//! Typed view of `artifacts/manifest.json` — the contract between the
//! python AOT compiler (python/compile/aot.py) and this runtime. Every
//! artifact's input/output signature and the parameter-leaf layout is
//! checked here, never assumed.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::json::{parse, Json};

#[derive(Clone, Debug, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    fn parse(s: &str) -> Result<DType> {
        match s {
            "float32" => Ok(DType::F32),
            "int32" => Ok(DType::I32),
            other => bail!("unsupported dtype {other}"),
        }
    }
}

#[derive(Clone, Debug)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: DType,
}

impl TensorSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }
}

#[derive(Clone, Debug)]
pub struct Artifact {
    pub file: PathBuf,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

#[derive(Clone, Debug)]
pub struct Entry {
    pub name: String,
    pub nleaves: usize,
    pub leaves: Vec<TensorSpec>,
    pub artifacts: BTreeMap<String, Artifact>,
    /// free-form metadata from aot.py (model kind, n, batch, schedule, ...)
    pub meta: BTreeMap<String, String>,
}

impl Entry {
    pub fn artifact(&self, kind: &str) -> Result<&Artifact> {
        self.artifacts
            .get(kind)
            .ok_or_else(|| anyhow!("entry {} has no '{kind}' artifact", self.name))
    }

    pub fn meta_usize(&self, key: &str) -> Result<usize> {
        self.meta
            .get(key)
            .and_then(|v| v.parse::<f64>().ok())
            .map(|v| v as usize)
            .ok_or_else(|| anyhow!("entry {} missing meta '{key}'", self.name))
    }

    pub fn meta_str(&self, key: &str) -> &str {
        self.meta.get(key).map(|s| s.as_str()).unwrap_or("")
    }
}

#[derive(Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub entries: BTreeMap<String, Entry>,
}

fn tensor_spec(j: &Json, default_name: &str) -> Result<TensorSpec> {
    let name = j
        .get("name")
        .and_then(|v| v.as_str())
        .unwrap_or(default_name)
        .to_string();
    let shape = j
        .get("shape")
        .and_then(|v| v.as_arr())
        .ok_or_else(|| anyhow!("tensor missing shape"))?
        .iter()
        .map(|v| v.as_usize().ok_or_else(|| anyhow!("bad shape entry")))
        .collect::<Result<Vec<_>>>()?;
    let dtype = DType::parse(
        j.get("dtype")
            .and_then(|v| v.as_str())
            .ok_or_else(|| anyhow!("tensor missing dtype"))?,
    )?;
    Ok(TensorSpec { name, shape, dtype })
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts` first)", path.display()))?;
        let root = parse(&text).map_err(|e| anyhow!("parsing manifest: {e}"))?;
        let version = root
            .get("format_version")
            .and_then(|v| v.as_usize())
            .unwrap_or(0);
        if version != 1 {
            bail!("unsupported manifest format_version {version}");
        }
        let mut entries = BTreeMap::new();
        for (name, e) in root
            .get("entries")
            .and_then(|v| v.as_obj())
            .ok_or_else(|| anyhow!("manifest missing entries"))?
        {
            let nleaves = e
                .get("nleaves")
                .and_then(|v| v.as_usize())
                .ok_or_else(|| anyhow!("{name}: missing nleaves"))?;
            let leaves = e
                .get("leaves")
                .and_then(|v| v.as_arr())
                .ok_or_else(|| anyhow!("{name}: missing leaves"))?
                .iter()
                .map(|l| tensor_spec(l, "leaf"))
                .collect::<Result<Vec<_>>>()?;
            if leaves.len() != nleaves {
                bail!("{name}: nleaves {} != leaves {}", nleaves, leaves.len());
            }
            let mut artifacts = BTreeMap::new();
            for (kind, a) in e
                .get("artifacts")
                .and_then(|v| v.as_obj())
                .ok_or_else(|| anyhow!("{name}: missing artifacts"))?
            {
                let file = dir.join(
                    a.get("file")
                        .and_then(|v| v.as_str())
                        .ok_or_else(|| anyhow!("{name}.{kind}: missing file"))?,
                );
                let inputs = a
                    .get("inputs")
                    .and_then(|v| v.as_arr())
                    .unwrap_or(&[])
                    .iter()
                    .map(|t| tensor_spec(t, "arg"))
                    .collect::<Result<Vec<_>>>()?;
                let outputs = a
                    .get("outputs")
                    .and_then(|v| v.as_arr())
                    .unwrap_or(&[])
                    .iter()
                    .map(|t| tensor_spec(t, "out"))
                    .collect::<Result<Vec<_>>>()?;
                artifacts.insert(kind.clone(), Artifact { file, inputs, outputs });
            }
            let mut meta = BTreeMap::new();
            if let Some(m) = e.get("meta").and_then(|v| v.as_obj()) {
                for (k, v) in m {
                    let s = match v {
                        Json::Str(s) => s.clone(),
                        Json::Num(n) => format!("{n}"),
                        Json::Bool(b) => format!("{b}"),
                        Json::Null => String::new(),
                        other => format!("{other:?}"),
                    };
                    meta.insert(k.clone(), s);
                }
            }
            entries.insert(
                name.clone(),
                Entry { name: name.clone(), nleaves, leaves, artifacts, meta },
            );
        }
        Ok(Manifest { dir, entries })
    }

    pub fn entry(&self, name: &str) -> Result<&Entry> {
        self.entries
            .get(name)
            .ok_or_else(|| anyhow!("no manifest entry '{name}' (have: {:?})",
                                   self.entries.keys().take(8).collect::<Vec<_>>()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("../../artifacts")
    }

    #[test]
    fn loads_real_manifest() {
        let m = Manifest::load(artifacts_dir()).expect("manifest (run make artifacts)");
        assert!(m.entries.len() >= 9);
        let e = m.entry("clf_spm_small").unwrap();
        assert_eq!(e.nleaves, e.leaves.len());
        let train = e.artifact("train").unwrap();
        assert_eq!(train.inputs.len(), 3 * e.nleaves + 3);
        assert!(train.file.exists());
        assert_eq!(e.meta_str("model"), "classifier");
        assert_eq!(e.meta_usize("n").unwrap(), 64);
    }

    #[test]
    fn dtype_parsing() {
        assert!(DType::parse("float64").is_err());
        assert_eq!(DType::parse("int32").unwrap(), DType::I32);
    }

    #[test]
    fn missing_entry_is_error() {
        let m = Manifest::load(artifacts_dir()).unwrap();
        assert!(m.entry("nonexistent").is_err());
    }
}
