//! # spm-runtime
//!
//! The PJRT execution layer of the three-layer architecture: loads the
//! HLO-text artifacts that `python/compile/aot.py` produced at build time,
//! compiles them once on the CPU PJRT client, and drives buffer-resident
//! training/eval/serving from rust. Python is never on this path.
//!
//! Modules:
//! * [`json`]       — dependency-free JSON parser for the manifest.
//! * [`manifest`]   — typed artifact manifest (the python<->rust contract).
//! * [`engine`]     — PJRT client wrapper + literal/buffer helpers.
//! * [`session`]    — buffer-resident train/eval/forward sessions.
//! * [`drivers`]    — XLA experiment drivers (tables, ablations, serving).
//! * [`checkpoint`] — save/restore of device-resident training state.
pub mod checkpoint;
pub mod drivers;
pub mod engine;
pub mod json;
pub mod manifest;
pub mod session;

pub use engine::Engine;
pub use manifest::{Artifact, DType, Entry, Manifest, TensorSpec};
pub use session::{HostTensor, TrainSession};
