//! XLA/PJRT experiment drivers regenerating the paper's §9 tables (plus
//! the DESIGN.md §9 ablations Abl-L / Abl-P / Abl-V) from AOT artifacts.
//! Each driver:
//!
//!   1. builds its workload through spm-data (prefetched, backpressured),
//!   2. trains via the PJRT path (`TrainSession`, buffer-resident),
//!   3. reports paper-style rows through spm-coordinator's metrics and
//!      renderers, so native and XLA numbers share one source of truth.
//!
//! The native counterparts (`run_table1_native`, ...) live in
//! `spm_coordinator::experiments`; this module only adds the PJRT glue.

use std::sync::Arc;

use spm_coordinator::config::RunConfig;
use spm_coordinator::error::Result;
use spm_coordinator::experiments::{CharLmRow, ClfOutcome, DataSource, render_pair_table};
use spm_coordinator::metrics::{fmt_f, Csv, StepTimer, Table};
use spm_coordinator::serve::{Executor, ServeEngine, ServeReport, Workload};
use spm_core::rng::Rng;
use spm_data::batch::Prefetcher;
use spm_data::charcorpus::Corpus;

use crate::{Engine, HostTensor, Manifest, TrainSession};

/// Train + evaluate one AOT-compiled classifier entry on a data source.
pub fn run_clf_xla(
    engine: &Engine,
    manifest: &Manifest,
    entry_name: &str,
    data: &DataSource,
    cfg: &RunConfig,
) -> Result<ClfOutcome> {
    let mut sess = TrainSession::new(engine, manifest, entry_name, &["init", "train", "eval"])?;
    let entry_batch = sess.entry.meta_usize("batch")?;
    let n = sess.entry.meta_usize("n")?;
    sess.init(cfg.seed as i32)?;

    // prefetch training batches on a worker thread (backpressure depth 4)
    let data_cl = data.clone();
    let steps = cfg.steps;
    let mut feed = Prefetcher::new(steps, 4, move |i| {
        let (x, y) = data_cl.batch(i, entry_batch, true);
        (x.data, y)
    });

    let mut timer = StepTimer::new(cfg.warmup.min(steps.saturating_sub(1)));
    let mut last_loss = f32::NAN;
    while let Some((xv, yv)) = feed.next() {
        let x = HostTensor::F32(xv);
        let y = HostTensor::from_labels(&yv);
        timer.start();
        let (loss, _acc) = sess.train_step(&x, &y)?;
        timer.stop();
        last_loss = loss;
    }

    // held-out evaluation
    let mut acc_sum = 0.0f64;
    let mut loss_sum = 0.0f64;
    for i in 0..cfg.eval_batches {
        let (x, y) = data.batch(i, entry_batch, false);
        let (l, a) = sess.eval(&HostTensor::F32(x.data), &HostTensor::from_labels(&y))?;
        acc_sum += a as f64;
        loss_sum += l as f64;
    }
    let k = cfg.eval_batches.max(1) as f64;
    let _ = last_loss;
    Ok(ClfOutcome {
        label: entry_name.to_string(),
        n,
        acc: (acc_sum / k) as f32,
        loss: (loss_sum / k) as f32,
        ms_per_step: timer.ms_per_step(),
        steps,
    })
}

/// Table 1 (paper §9.1), XLA engine: teacher-student width sweep.
pub fn run_table1(
    engine: &Engine,
    manifest: &Manifest,
    widths: &[usize],
    cfg: &RunConfig,
) -> Result<String> {
    let mut pairs = Vec::new();
    for &n in widths {
        let data = DataSource::Teacher { n, classes: 10, seed: 7 + n as u64 };
        let d = run_clf_xla(engine, manifest, &format!("table1_dense_n{n}"), &data, cfg)?;
        let s = run_clf_xla(engine, manifest, &format!("table1_spm_n{n}"), &data, cfg)?;
        eprintln!(
            "[table1 n={n}] dense acc {:.4} ({:.1} ms/step) | spm acc {:.4} ({:.1} ms/step)",
            d.acc, d.ms_per_step, s.acc, s.ms_per_step
        );
        pairs.push((d, s));
    }
    render_pair_table(
        &format!("Table 1 — compositional teacher (xla engine, {} steps)", cfg.steps),
        &pairs,
        &cfg.out_csv,
    )
}

/// Table 2 (paper §9.2), XLA engine: AG-News-proxy at L=12.
pub fn run_table2(
    engine: &Engine,
    manifest: &Manifest,
    widths: &[usize],
    cfg: &RunConfig,
) -> Result<String> {
    let mut pairs = Vec::new();
    for &n in widths {
        let data = DataSource::AgNews { n };
        let d = run_clf_xla(engine, manifest, &format!("table2_dense_n{n}"), &data, cfg)?;
        let s = run_clf_xla(engine, manifest, &format!("table2_spm_n{n}"), &data, cfg)?;
        eprintln!(
            "[table2 n={n}] dense acc {:.4} ({:.1} ms/step) | spm acc {:.4} ({:.1} ms/step)",
            d.acc, d.ms_per_step, s.acc, s.ms_per_step
        );
        pairs.push((d, s));
    }
    render_pair_table(
        &format!("Table 2 — AG-News proxy, L=12 (xla engine, {} steps)", cfg.steps),
        &pairs,
        &cfg.out_csv,
    )
}

/// Tables 3/4 (paper §9.3): char-level LM on the Shakespeare-like corpus.
/// `entry_name` selects dense (Table 3) or SPM (Table 4).
pub fn run_charlm(
    engine: &Engine,
    manifest: &Manifest,
    entry_name: &str,
    cfg: &RunConfig,
) -> Result<Vec<CharLmRow>> {
    let mut sess = TrainSession::new(engine, manifest, entry_name, &["init", "train", "eval"])?;
    let batch = sess.entry.meta_usize("batch")?;
    let seq_len = sess.entry.meta_usize("seq_len")?;
    sess.init(cfg.seed as i32)?;

    let corpus = Arc::new(if cfg.steps <= 100 {
        // CI-profile corpus keeps tests fast
        Corpus::generate_sized(cfg.seed, 200_000, 30_000)
    } else {
        Corpus::generate(cfg.seed)
    });

    let c2 = corpus.clone();
    let seed = cfg.seed;
    let mut feed = Prefetcher::new(cfg.steps, 4, move |i| {
        let mut rng = Rng::new(seed ^ 0xBA7C4 ^ (i as u64).wrapping_mul(0x9E37));
        Corpus::sample_batch(&c2.train, batch, seq_len, &mut rng)
    });

    let eval_every = if cfg.eval_every == 0 { cfg.steps } else { cfg.eval_every };
    let mut rows = Vec::new();
    let mut timer = StepTimer::new(cfg.warmup.min(cfg.steps.saturating_sub(1)));
    let mut csv = Csv::create(&cfg.out_csv, "step,train_nll,valid_nll,valid_bpc,ms_per_step")?;

    let mut evaluate = |sess: &TrainSession, step: usize, train_nll: f32, ms: f64,
                        rows: &mut Vec<CharLmRow>, csv: &mut Csv|
     -> Result<()> {
        let mut vsum = 0.0f64;
        for i in 0..cfg.eval_batches {
            let mut rng = Rng::new(0xEA1 ^ (i as u64 + 1).wrapping_mul(0x1234_5678));
            let (inp, tgt) = Corpus::sample_batch(&corpus.valid, batch, seq_len, &mut rng);
            let (l, _m) = sess.eval(&HostTensor::from_bytes(&inp), &HostTensor::from_bytes(&tgt))?;
            vsum += l as f64;
        }
        let valid_nll = (vsum / cfg.eval_batches.max(1) as f64) as f32;
        let row = CharLmRow {
            step,
            train_nll,
            valid_nll,
            valid_bpc: valid_nll / std::f32::consts::LN_2,
            ms_per_step: ms,
        };
        eprintln!(
            "[{entry_name}] step {step}: train NLL {:.3} valid NLL {:.3} BPC {:.3} ({:.0} ms/step)",
            row.train_nll, row.valid_nll, row.valid_bpc, row.ms_per_step
        );
        csv.row(&[
            step.to_string(),
            train_nll.to_string(),
            valid_nll.to_string(),
            row.valid_bpc.to_string(),
            ms.to_string(),
        ])?;
        rows.push(row);
        Ok(())
    };

    let mut step = 0usize;
    let mut train_nll = f32::NAN;
    while let Some((inp, tgt)) = feed.next() {
        step += 1;
        let x = HostTensor::from_bytes(&inp);
        let y = HostTensor::from_bytes(&tgt);
        timer.start();
        let (loss, _m) = sess.train_step(&x, &y)?;
        timer.stop();
        train_nll = loss;
        if step == 1 || step % eval_every == 0 {
            evaluate(&sess, step, train_nll, timer.ms_per_step(), &mut rows, &mut csv)?;
        }
    }
    if rows.last().map(|r| r.step) != Some(step) {
        evaluate(&sess, step, train_nll, timer.ms_per_step(), &mut rows, &mut csv)?;
    }
    Ok(rows)
}

/// Ablations (DESIGN.md §9: Abl-L / Abl-P / Abl-V): depth, pairing,
/// variant at n=1024 on the teacher task. Entries must exist in the
/// manifest.
pub fn run_ablation(
    engine: &Engine,
    manifest: &Manifest,
    which: &str,
    cfg: &RunConfig,
) -> Result<String> {
    let n = 1024;
    let data = DataSource::Teacher { n, classes: 10, seed: 7 + n as u64 };
    let entries: Vec<String> = match which {
        "depth" => [1usize, 2, 5, 10, 20].iter().map(|l| format!("abl_depth_L{l}")).collect(),
        "pairing" => ["butterfly", "shift", "random"]
            .iter()
            .map(|s| format!("abl_sched_{s}"))
            .collect(),
        "variant" => ["rotation", "general"]
            .iter()
            .map(|v| format!("abl_variant_{v}"))
            .collect(),
        other => spm_coordinator::bail!("unknown ablation '{other}' (depth|pairing|variant)"),
    };
    let mut t = Table::new(&["config", "L", "params", "acc", "ms/step"]);
    let mut csv = Csv::create(&cfg.out_csv, "config,num_stages,param_count,acc,ms_per_step")?;
    for name in &entries {
        let out = run_clf_xla(engine, manifest, name, &data, cfg)?;
        let entry = manifest.entry(name)?;
        let stages = entry.meta_usize("num_stages").unwrap_or(0);
        let params = entry.meta_usize("param_count").unwrap_or(0);
        eprintln!("[abl {which}] {name}: acc {:.4} ({:.1} ms/step)", out.acc, out.ms_per_step);
        t.row(vec![
            name.clone(),
            stages.to_string(),
            params.to_string(),
            fmt_f(out.acc as f64, 4),
            fmt_f(out.ms_per_step, 3),
        ]);
        csv.row(&[
            name.clone(),
            stages.to_string(),
            params.to_string(),
            out.acc.to_string(),
            out.ms_per_step.to_string(),
        ])?;
    }
    Ok(format!("Ablation: {which} (n=1024, {} steps)\n{}", cfg.steps, t.render()))
}

/// One AOT-compiled forward executable behind the serving engine's
/// [`Executor`] contract. The compiled executable has a FIXED batch
/// shape, so ragged fills are padded here — inside the executor, which
/// is exactly where the engine's true-fill contract puts that cost —
/// and only the filled rows are returned.
struct XlaExecutor<'e> {
    sess: TrainSession<'e>,
    batch: usize,
    n: usize,
    is_teacher: bool,
}

impl Executor for XlaExecutor<'_> {
    fn width(&self) -> usize {
        self.n
    }

    fn max_batch(&self) -> usize {
        self.batch
    }

    fn forward(&mut self, rows: usize, flat: Vec<f32>) -> Result<Vec<f32>> {
        let mut padded = flat;
        padded.resize(self.batch * self.n, 0.0);
        let out: Vec<f32> = if self.is_teacher {
            // teacher forward returns i32 labels
            self.sess
                .forward_i32(&HostTensor::F32(padded))?
                .into_iter()
                .map(|v| v as f32)
                .collect()
        } else {
            self.sess.forward(&HostTensor::F32(padded))?
        };
        let per_row = out.len() / self.batch.max(1);
        Ok(out[..rows * per_row].to_vec())
    }
}

/// Run the serving demo against one manifest entry's `forward` artifact,
/// through the coordinator's deadline-batched engine. PJRT clients are
/// not `Send`, so the executor runs on the calling thread via
/// [`ServeEngine::run_inline`]. `entry_name` must be a
/// classifier/teacher-style model taking (B, n) f32.
pub fn serve_demo(
    engine: &Engine,
    manifest: &Manifest,
    entry_name: &str,
    num_requests: usize,
    num_clients: usize,
    seed: u64,
) -> Result<ServeReport> {
    let mut sess = TrainSession::new(engine, manifest, entry_name, &["init", "forward"])?;
    sess.init(seed as i32)?;
    let batch = sess.entry.meta_usize("batch")?;
    let n = sess.entry.meta_usize("n")?;
    let is_teacher = sess.entry.meta_str("model") == "teacher";
    let mut exec = XlaExecutor { sess, batch, n, is_teacher };
    let workload = Workload { num_requests, num_clients, seed };
    ServeEngine::run_inline(&workload, &mut exec, 200)
}
