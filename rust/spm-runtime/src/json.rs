//! Minimal JSON parser (serde_json is not in the offline vendor set).
//! Supports the full JSON grammar the artifact manifest uses: objects,
//! arrays, strings (with escapes), numbers, booleans, null.

use std::collections::BTreeMap;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }
}

pub fn parse(input: &str) -> Result<Json, String> {
    let mut p = Parser { bytes: input.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(format!("trailing data at byte {}", p.pos));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Result<u8, String> {
        let b = self.peek().ok_or("unexpected end of input")?;
        self.pos += 1;
        Ok(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        let b = self.bump()?;
        if b != c {
            return Err(format!("expected '{}' at byte {}, got '{}'", c as char, self.pos - 1, b as char));
        }
        Ok(())
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek().ok_or("unexpected end of input")? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.literal("true", Json::Bool(true)),
            b'f' => self.literal("false", Json::Bool(false)),
            b'n' => self.literal("null", Json::Null),
            _ => self.number(),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(format!("invalid literal at byte {}", self.pos))
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump()? {
                b',' => continue,
                b'}' => return Ok(Json::Obj(map)),
                c => return Err(format!("expected ',' or '}}', got '{}'", c as char)),
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut arr = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(arr));
        }
        loop {
            arr.push(self.value()?);
            self.skip_ws();
            match self.bump()? {
                b',' => continue,
                b']' => return Ok(Json::Arr(arr)),
                c => return Err(format!("expected ',' or ']', got '{}'", c as char)),
            }
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump()? {
                b'"' => return Ok(out),
                b'\\' => match self.bump()? {
                    b'"' => out.push('"'),
                    b'\\' => out.push('\\'),
                    b'/' => out.push('/'),
                    b'b' => out.push('\u{8}'),
                    b'f' => out.push('\u{c}'),
                    b'n' => out.push('\n'),
                    b'r' => out.push('\r'),
                    b't' => out.push('\t'),
                    b'u' => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump()? as char;
                            code = code * 16
                                + c.to_digit(16).ok_or("bad \\u escape")?;
                        }
                        out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    c => return Err(format!("bad escape '\\{}'", c as char)),
                },
                c => {
                    // collect UTF-8 continuation bytes verbatim
                    if c < 0x80 {
                        out.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let width = match c {
                            0xC0..=0xDF => 2,
                            0xE0..=0xEF => 3,
                            _ => 4,
                        };
                        self.pos = start + width;
                        out.push_str(
                            std::str::from_utf8(&self.bytes[start..self.pos])
                                .map_err(|e| e.to_string())?,
                        );
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        while matches!(
            self.peek(),
            Some(b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')
        ) {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| format!("bad number '{s}' at byte {start}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_like_document() {
        let doc = r#"{
  "format_version": 1,
  "entries": {
    "m": {"nleaves": 2, "leaves": [{"name": "w", "shape": [3, 4], "dtype": "float32"}],
          "artifacts": {"train": {"file": "m.train.hlo.txt", "inputs": [], "outputs": []}}}
  }
}"#;
        let v = parse(doc).unwrap();
        assert_eq!(v.get("format_version").unwrap().as_usize(), Some(1));
        let m = v.get("entries").unwrap().get("m").unwrap();
        assert_eq!(m.get("nleaves").unwrap().as_usize(), Some(2));
        let leaf = &m.get("leaves").unwrap().as_arr().unwrap()[0];
        assert_eq!(leaf.get("name").unwrap().as_str(), Some("w"));
        assert_eq!(
            leaf.get("shape").unwrap().as_arr().unwrap().iter()
                .map(|x| x.as_usize().unwrap()).collect::<Vec<_>>(),
            vec![3, 4]
        );
    }

    #[test]
    fn escapes_and_unicode() {
        let v = parse(r#""a\n\t\"b\" A""#).unwrap();
        assert_eq!(v.as_str(), Some("a\n\t\"b\" A"));
    }

    #[test]
    fn numbers() {
        assert_eq!(parse("-1.5e3").unwrap().as_f64(), Some(-1500.0));
        assert_eq!(parse("0").unwrap().as_f64(), Some(0.0));
    }

    #[test]
    fn literals() {
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("null").unwrap(), Json::Null);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{,}").is_err());
        assert!(parse("[1, 2").is_err());
        assert!(parse("hello").is_err());
        assert!(parse("{} trailing").is_err());
    }

    #[test]
    fn empty_containers() {
        assert_eq!(parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(parse("{}").unwrap(), Json::Obj(Default::default()));
    }
}
