//! PJRT engine: wraps the `xla` crate's CPU client, loads HLO-text
//! artifacts (the AOT interchange format — see python/compile/aot.py for
//! why text, not serialized protos) and provides typed literal/buffer
//! helpers.

use std::path::Path;

use anyhow::{bail, Context, Result};
use xla::{Literal, PjRtBuffer, PjRtClient, PjRtLoadedExecutable};

use crate::manifest::{DType, TensorSpec};

pub struct Engine {
    pub client: PjRtClient,
}

impl Engine {
    pub fn cpu() -> Result<Engine> {
        let client = PjRtClient::cpu().context("creating PJRT CPU client")?;
        // The bundled TfrtCpuClient (xla_extension 0.5.1) segfaults when a
        // process destroys a client and later creates another (shared
        // thread-pool teardown). Engines are created a handful of times per
        // process (tests, benches), so we deliberately leak each client:
        // clone the Rc and forget it, keeping the refcount >= 1 forever.
        std::mem::forget(client.clone());
        Ok(Engine { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Parse + compile one HLO-text artifact.
    pub fn load(&self, path: &Path) -> Result<PjRtLoadedExecutable> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("artifact path not utf-8")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client
            .compile(&comp)
            .with_context(|| format!("XLA-compiling {}", path.display()))
    }

    /// Host f32 data -> device buffer with the spec's shape.
    pub fn upload_f32(&self, spec: &TensorSpec, data: &[f32]) -> Result<PjRtBuffer> {
        if spec.dtype != DType::F32 {
            bail!("{}: expected f32 tensor", spec.name);
        }
        if data.len() != spec.elements() {
            bail!("{}: got {} values, want {}", spec.name, data.len(), spec.elements());
        }
        let dims: Vec<i64> = spec.shape.iter().map(|&d| d as i64).collect();
        let lit = Literal::vec1(data).reshape(&dims)?;
        Ok(self.client.buffer_from_host_literal(None, &lit)?)
    }

    /// Host i32 data -> device buffer with the spec's shape.
    pub fn upload_i32(&self, spec: &TensorSpec, data: &[i32]) -> Result<PjRtBuffer> {
        if spec.dtype != DType::I32 {
            bail!("{}: expected i32 tensor", spec.name);
        }
        if data.len() != spec.elements() {
            bail!("{}: got {} values, want {}", spec.name, data.len(), spec.elements());
        }
        let dims: Vec<i64> = spec.shape.iter().map(|&d| d as i64).collect();
        let lit = Literal::vec1(data).reshape(&dims)?;
        Ok(self.client.buffer_from_host_literal(None, &lit)?)
    }

    pub fn upload_scalar_f32(&self, v: f32) -> Result<PjRtBuffer> {
        Ok(self.client.buffer_from_host_literal(None, &Literal::scalar(v))?)
    }

    pub fn upload_scalar_i32(&self, v: i32) -> Result<PjRtBuffer> {
        Ok(self.client.buffer_from_host_literal(None, &Literal::scalar(v))?)
    }

    /// Zero-filled buffer of the given shape (optimizer-state init).
    pub fn upload_zeros(&self, spec: &TensorSpec) -> Result<PjRtBuffer> {
        match spec.dtype {
            DType::F32 => self.upload_f32(spec, &vec![0.0; spec.elements()]),
            DType::I32 => self.upload_i32(spec, &vec![0; spec.elements()]),
        }
    }

    /// Download a buffer to host f32 (works for rank-0 scalars too).
    pub fn read_f32(&self, buf: &PjRtBuffer) -> Result<Vec<f32>> {
        let lit = buf.to_literal_sync()?;
        if lit.element_count() == 1 {
            return Ok(vec![lit.get_first_element::<f32>()?]);
        }
        Ok(lit.to_vec::<f32>()?)
    }

    pub fn read_i32(&self, buf: &PjRtBuffer) -> Result<Vec<i32>> {
        let lit = buf.to_literal_sync()?;
        if lit.element_count() == 1 {
            return Ok(vec![lit.get_first_element::<i32>()?]);
        }
        Ok(lit.to_vec::<i32>()?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::manifest::Manifest;
    use std::path::PathBuf;

    fn artifacts_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("../../artifacts")
    }

    #[test]
    fn engine_loads_and_runs_init() {
        let engine = Engine::cpu().unwrap();
        assert!(!engine.platform().is_empty());
        let man = Manifest::load(artifacts_dir()).unwrap();
        let e = man.entry("clf_spm_small").unwrap();
        let init = engine.load(&e.artifact("init").unwrap().file).unwrap();
        let seed = engine.upload_scalar_i32(0).unwrap();
        let outs = init.execute_b::<&PjRtBuffer>(&[&seed]).unwrap();
        // untupled: one buffer per parameter leaf
        assert_eq!(outs[0].len(), e.nleaves);
    }

    #[test]
    fn upload_shape_mismatch_is_error() {
        let engine = Engine::cpu().unwrap();
        let spec = TensorSpec { name: "x".into(), shape: vec![2, 3], dtype: DType::F32 };
        assert!(engine.upload_f32(&spec, &[0.0; 5]).is_err());
        assert!(engine.upload_f32(&spec, &[0.0; 6]).is_ok());
    }

    #[test]
    fn scalar_roundtrip() {
        let engine = Engine::cpu().unwrap();
        let b = engine.upload_scalar_f32(3.5).unwrap();
        assert_eq!(engine.read_f32(&b).unwrap(), vec![3.5]);
    }
}
