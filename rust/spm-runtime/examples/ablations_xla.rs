//! The XLA/PJRT half of the ablation story: the DESIGN.md §9 sweeps —
//! stage depth L, pairing schedule, and block variant at n=1024 on the
//! teacher task — through the `spm-runtime` drivers.
//! Results -> results/abl_{depth,pairing,variant}.csv.
//!
//! The CI-gated, dependency-free ablation harness is `benches/ablate.rs`
//! in the default workspace (DESIGN.md §17); this wrapper only runs
//! where the XLA vendor set is installed:
//!
//! ```text
//! cd rust/spm-runtime && cargo run --release --example ablations_xla
//! ```

use spm_coordinator::RunConfig;
use spm_runtime::{drivers, Engine, Manifest};

fn repo_path(rel: &str) -> String {
    format!("{}/../../{}", env!("CARGO_MANIFEST_DIR"), rel)
}

fn env_steps(default: usize) -> usize {
    std::env::var("SPM_BENCH_STEPS").ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() -> spm_coordinator::error::Result<()> {
    let engine = Engine::cpu()?;
    let man = Manifest::load(repo_path("artifacts"))?;
    for which in ["depth", "pairing", "variant"] {
        let cfg = RunConfig {
            steps: env_steps(120),
            eval_batches: 10,
            out_csv: repo_path(&format!("results/abl_{which}.csv")),
            ..Default::default()
        };
        let report = drivers::run_ablation(&engine, &man, which, &cfg)?;
        println!("{report}\n");
    }
    Ok(())
}
