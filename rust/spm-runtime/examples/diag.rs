use spm_runtime::{Engine, HostTensor, Manifest, TrainSession};
use std::io::Write;
fn main() -> anyhow::Result<()> {
    let engine = Engine::cpu()?;
    let man = Manifest::load("artifacts")?;
    let mut sess = TrainSession::new(&engine, &man, "table2_spm_n2048", &["init", "forward"])?;
    sess.init(0)?;
    let xb: Vec<f32> = std::fs::read("/tmp/agnews_x.bin")?
        .chunks(4).map(|c| f32::from_le_bytes([c[0],c[1],c[2],c[3]])).collect();
    let logits = sess.forward(&HostTensor::F32(xb))?;
    let mut f = std::fs::File::create("/tmp/rust_logits.bin")?;
    for v in &logits { f.write_all(&v.to_le_bytes())?; }
    println!("rust logits[0..4] = {:?}", &logits[..4]);
    Ok(())
}
