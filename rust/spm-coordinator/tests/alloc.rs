//! Steady-state allocation gates (DESIGN.md §15): after a short warmup,
//! one served request batch and one train microbatch group must run the
//! reusable-workspace hot paths without touching the allocator.
//!
//! Counting strategy: a `#[global_allocator]` that increments a
//! CONST-INITIALIZED THREAD-LOCAL counter on every alloc/realloc/
//! alloc_zeroed. Const-init `Cell<u64>` TLS never allocates and has no
//! destructor, so it is safe to touch from inside the allocator; and
//! because every measured path runs under `with_thread_budget(1)` (no
//! worker spawns), the calling thread sees EVERY allocation of its own
//! work while libtest's harness threads cannot pollute the count.

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;

use spm_core::models::api::{build_model, ModelCfg, ModelKind};
use spm_core::ops::{LinearCfg, LinearOp};
use spm_core::optim::Adam;
use spm_core::parallel;
use spm_core::rng::Rng;
use spm_core::spm::Variant;
use spm_core::tensor::Mat;
// lint: allow(hygiene): Executor is imported for method resolution (`exec.forward`)
use spm_coordinator::serve::{Executor, NativeExecutor};
use spm_coordinator::train::{TrainBatch, TrainEngine};

thread_local! {
    static ALLOC_CALLS: Cell<u64> = const { Cell::new(0) };
}

struct CountingAlloc;

// SAFETY: every method forwards verbatim to `System`; the only extra
// work is bumping a const-initialized `Cell<u64>` thread-local, which
// never allocates, has no destructor, and cannot unwind — safe to touch
// from inside the allocator (see the module doc).
unsafe impl GlobalAlloc for CountingAlloc {
    // SAFETY: caller upholds `GlobalAlloc::alloc`'s contract; forwarded
    // unchanged to `System.alloc`.
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.with(|c| c.set(c.get() + 1));
        System.alloc(layout)
    }

    // SAFETY: forwarded unchanged to `System.alloc_zeroed`.
    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.with(|c| c.set(c.get() + 1));
        System.alloc_zeroed(layout)
    }

    // SAFETY: forwarded unchanged to `System.realloc`.
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOC_CALLS.with(|c| c.set(c.get() + 1));
        System.realloc(ptr, layout, new_size)
    }

    // SAFETY: forwarded unchanged to `System.dealloc`.
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static COUNTER: CountingAlloc = CountingAlloc;

/// Allocator calls made BY THIS THREAD while running `f`.
fn allocs_during(f: impl FnOnce()) -> u64 {
    let before = ALLOC_CALLS.with(|c| c.get());
    f();
    ALLOC_CALLS.with(|c| c.get()) - before
}

const KINDS: [ModelKind; 4] =
    [ModelKind::Mlp, ModelKind::CharLm, ModelKind::Gru, ModelKind::Attention];

/// One small config per kind; the SPM General mixer is the variant with
/// the richest trace, i.e. the worst case for steady-state reuse.
fn small_cfg(kind: ModelKind) -> ModelCfg {
    ModelCfg::new(kind, LinearCfg::spm(8, Variant::General))
        .with_classes(4)
        .with_heads(2)
        .with_seq_len(2)
        .with_seed(21)
}

/// Deterministic feature for flat index `i` (charlm rows carry byte
/// tokens, everything else small reals).
fn feature(kind: ModelKind, i: usize) -> f32 {
    match kind {
        ModelKind::CharLm => 97.0 + (i % 3) as f32,
        _ => ((i * 37 % 11) as f32) * 0.1 - 0.5,
    }
}

/// One router iteration against a native executor, mimicking the serve
/// engine's batch-assembly ping-pong: take the pool, refill it with the
/// batch's rows, forward, and keep the returned buffer as the next pool.
fn serve_iter(kind: ModelKind, exec: &mut NativeExecutor, rows: usize, pool: &mut Vec<f32>) {
    let width = exec.width();
    let mut flat = std::mem::take(pool);
    flat.clear();
    flat.resize(rows * width, 0.0);
    for (i, v) in flat.iter_mut().enumerate() {
        *v = feature(kind, i);
    }
    let out = exec.forward(rows, flat).expect("executor forward");
    *pool = out;
}

/// TOLERANCE: a warmed serve iteration performs ZERO allocations for
/// every model kind — the request/output buffer pair ping-pongs with the
/// executor, all activations live in model-owned scratch, and the
/// trace-free SPM forward runs off the cached prepared coefficients.
#[test]
fn serve_iteration_steady_state_is_allocation_free() {
    for kind in KINDS {
        let mut exec = NativeExecutor::new(build_model(&small_cfg(kind)), 32);
        let mut pool: Vec<f32> = Vec::new();
        parallel::with_thread_budget(1, || {
            // warmup: grows scratch + lets the pool/output pair converge
            // (the pair needs ~3 swaps when d_out < d_in)
            for _ in 0..4 {
                serve_iter(kind, &mut exec, 6, &mut pool);
            }
            let a1 = allocs_during(|| serve_iter(kind, &mut exec, 6, &mut pool));
            let a2 = allocs_during(|| serve_iter(kind, &mut exec, 6, &mut pool));
            assert_eq!(a1, 0, "{kind:?}: warmed serve iteration allocated {a1} times");
            assert_eq!(a2, 0, "{kind:?}: serve steady state drifted ({a2} allocs)");
        });
    }
}

/// A 2-microbatch group for `kind` (labels for classifiers, value
/// targets for attention), exercising the single-replica multi-microbatch
/// in-place reduce path.
fn train_group(kind: ModelKind, rows: usize) -> Vec<TrainBatch> {
    let probe = build_model(&small_cfg(kind));
    let d = probe.d_in();
    drop(probe);
    (0..2)
        .map(|g| {
            let x = Mat::from_vec(
                rows,
                d,
                (0..rows * d).map(|i| feature(kind, i + g)).collect(),
            );
            if kind == ModelKind::Attention {
                let t = x.clone();
                TrainBatch::values(x, t)
            } else {
                let y = (0..rows)
                    .map(|r| match kind {
                        ModelKind::CharLm => 97 + (x.at(r, 0) as u32) % 2,
                        _ => u32::from(x.at(r, 0) > 0.0),
                    })
                    .collect();
                TrainBatch::labels(x, y)
            }
        })
        .collect()
}

/// TOLERANCES (documented per kind):
///
/// - mlp / charlm: at most 8 allocator calls per step. The expected
///   count is exactly 2 — the SPM General `forward_train` builds one
///   Vec of L+1 trace-slice handles per microbatch (DESIGN.md §15);
///   everything else (activations, traces, backward workspace, the
///   engine's accumulator and metric slots) is reused in place.
/// - gru / attention: their TRAINING paths (BPTT / per-head attention
///   backward) intentionally remain allocating, so the gate is a sanity
///   ceiling only. The equality assertion below is the real guard.
///
/// In ALL kinds two consecutive warmed steps must allocate IDENTICAL
/// counts: any step-over-step drift means a workspace is leaking back to
/// per-call allocation.
#[test]
fn train_step_steady_state_allocations_are_bounded_and_stable() {
    for (kind, cap) in [
        (ModelKind::Mlp, 8u64),
        (ModelKind::CharLm, 8),
        (ModelKind::Gru, 100_000),
        (ModelKind::Attention, 100_000),
    ] {
        let group = train_group(kind, 5);
        let mut engine =
            TrainEngine::new(build_model(&small_cfg(kind))).with_threads_per_replica(1);
        for _ in 0..3 {
            engine.step(&group);
        }
        let a1 = allocs_during(|| {
            engine.step(&group);
        });
        let a2 = allocs_during(|| {
            engine.step(&group);
        });
        assert_eq!(a1, a2, "{kind:?}: step allocation drift ({a1} then {a2})");
        assert!(a1 <= cap, "{kind:?}: warmed step allocated {a1} times (cap {cap})");
    }
}

/// The prepared-coefficient cache must NEVER serve coefficients from an
/// older parameter version: after `params_mut` edits, a warm op (cache
/// populated) must produce bit-identical outputs to a fresh op given the
/// same edit. Rotation is the variant where staleness is visible — its
/// prepare bakes the angle parameters into trig tables (General's scalar
/// prepare is empty, so a stale cache there would be undetectable).
#[test]
fn stale_prepared_cache_cannot_survive_param_edits() {
    let cfg = LinearCfg::spm(16, Variant::Rotation);
    let mk = || {
        let mut adam = Adam::new(1e-3);
        let mut rng = Rng::new(7);
        LinearOp::new(cfg, &mut rng, &mut adam)
    };
    let x = Mat::from_vec(4, 16, (0..64).map(|i| ((i * 13 % 17) as f32) * 0.1 - 0.8).collect());

    let mut warm = mk();
    let before = warm.forward(&x); // populates the prepared trig cache
    for v in warm.params_mut() {
        *v += 0.125; // bumps the params version
    }
    let after = warm.forward(&x);
    assert_ne!(before, after, "the parameter edit must change the output");

    let mut fresh = mk();
    for v in fresh.params_mut() {
        *v += 0.125;
    }
    assert_eq!(
        after,
        fresh.forward(&x),
        "cached prepare served stale rotation coefficients after a param edit"
    );
}
