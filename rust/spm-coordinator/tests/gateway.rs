//! Gateway integration tests: the full wire path — `GatewayClient` over
//! loopback TCP into `Gateway` -> admission -> deadline-batched replicas
//! -> framed replies — plus the wire hot-swap and stats opcodes. The
//! in-process admission/swap edge cases live in `serve.rs` unit tests;
//! these cover what only the socket layer can: framing, request
//! validation at the trust boundary, and connection survival after a
//! bad request.

use spm_coordinator::gateway::{Gateway, GatewayClient, InferOutcome};
use spm_coordinator::serve::{Lane, ServeEngine, Shed};
use spm_core::models::api::{build_model, save_checkpoint, ModelCfg, ModelKind};
use spm_core::ops::LinearCfg;
use spm_core::spm::Variant;
use spm_core::tensor::Mat;

const N: usize = 16;

fn mlp_cfg(seed: u64) -> ModelCfg {
    ModelCfg::new(ModelKind::Mlp, LinearCfg::spm(N, Variant::General))
        .with_classes(4)
        .with_seed(seed)
}

fn start_gateway(replicas: usize) -> Gateway {
    let mut engine = ServeEngine::new();
    for _ in 0..replicas {
        engine = engine.with_replica(build_model(&mlp_cfg(7)));
    }
    let session = engine.with_max_wait_us(100).start().expect("engine start");
    Gateway::start(session, "127.0.0.1:0").expect("gateway start")
}

fn features(tag: f32) -> Vec<f32> {
    (0..N).map(|i| (i as f32) * 0.05 + tag).collect()
}

#[test]
fn both_lanes_round_trip_over_loopback() {
    let gw = start_gateway(1);
    let mut c = GatewayClient::connect(gw.addr()).expect("connect");
    // reference logits straight from an identical model, no sockets
    let reference = build_model(&mlp_cfg(7));
    for (i, lane) in [Lane::Interactive, Lane::Batch, Lane::Interactive].iter().enumerate() {
        let x = features(i as f32 * 0.3);
        let out = match c.infer(*lane, &x, 0).expect("infer") {
            InferOutcome::Ok(out) => out,
            InferOutcome::Shed(s) => panic!("unbounded lane shed a request: {s}"),
        };
        let want = reference.forward(&Mat::from_vec(1, N, x));
        assert_eq!(out, want.data, "wire logits must match the in-process model ({lane:?})");
    }
    let report = gw.stop().expect("stop");
    assert_eq!(report.requests, 3);
    assert_eq!(report.submitted, 3);
    assert_eq!(report.shed(), 0);
}

#[test]
fn wire_hot_swap_lands_on_every_replica() {
    let gw = start_gateway(2);
    let mut c = GatewayClient::connect(gw.addr()).expect("connect");
    let x = features(0.1);
    let before = match c.infer(Lane::Interactive, &x, 0).expect("infer") {
        InferOutcome::Ok(out) => out,
        InferOutcome::Shed(s) => panic!("shed: {s}"),
    };

    // same architecture, different seed -> same fingerprint, new params
    let path = std::env::temp_dir().join("spm_test_gateway_swap.ckpt");
    save_checkpoint(build_model(&mlp_cfg(13)).as_ref(), &path).expect("save ckpt");
    let image = std::fs::read(&path).expect("read ckpt");
    let _ = std::fs::remove_file(&path);
    let notified = c.hot_swap(&image).expect("wire hot swap");
    assert_eq!(notified, 2, "swap must be queued on every live replica");

    // every reply after the swap ack must come from the new params
    let after = match c.infer(Lane::Interactive, &x, 0).expect("infer") {
        InferOutcome::Ok(out) => out,
        InferOutcome::Shed(s) => panic!("shed: {s}"),
    };
    let want = build_model(&mlp_cfg(13)).forward(&Mat::from_vec(1, N, x));
    assert_ne!(before, after, "params must actually change");
    assert_eq!(after, want.data, "post-swap logits must match the seed-13 model");

    let report = gw.stop().expect("stop");
    assert_eq!(report.swaps_applied, 2);
    assert_eq!(report.requests, 2);
    assert_eq!(report.failed, 0);
}

#[test]
fn stats_opcode_reports_live_admission_counters() {
    let gw = start_gateway(1);
    let mut c = GatewayClient::connect(gw.addr()).expect("connect");
    for i in 0..5 {
        match c.infer(Lane::Batch, &features(i as f32), 0).expect("infer") {
            InferOutcome::Ok(_) => {}
            InferOutcome::Shed(s) => panic!("shed: {s}"),
        }
    }
    let stats = c.stats().expect("stats");
    assert_eq!(stats.submitted, 5);
    assert_eq!(stats.served, 5);
    assert_eq!(stats.shed_queue, 0);
    assert_eq!(stats.shed_expired, 0);
    assert_eq!(stats.failed, 0);
    assert_eq!(stats.in_flight, 0);
    assert_eq!(stats.replicas, 1);
    gw.stop().expect("stop");
}

#[test]
fn bad_width_request_errors_without_killing_the_connection() {
    let gw = start_gateway(1);
    let mut c = GatewayClient::connect(gw.addr()).expect("connect");
    // wrong feature width: the gateway must reply ST_BAD_REQUEST (an Err
    // from the client's perspective), not crash or hang
    let err = c.infer(Lane::Interactive, &features(0.0)[..N - 3], 0).unwrap_err();
    assert!(err.to_string().contains("feature floats"), "unexpected error: {err}");
    // the same connection keeps serving well-formed requests
    match c.infer(Lane::Interactive, &features(0.2), 0).expect("infer after bad request") {
        InferOutcome::Ok(out) => assert_eq!(out.len(), 4),
        InferOutcome::Shed(s) => panic!("shed: {s}"),
    }
    let report = gw.stop().expect("stop");
    assert_eq!(report.requests, 1, "the malformed frame must never reach admission");
}

#[test]
fn malformed_hot_swap_is_rejected_and_serving_continues() {
    let gw = start_gateway(1);
    let mut c = GatewayClient::connect(gw.addr()).expect("connect");
    let err = c.hot_swap(b"not a checkpoint").unwrap_err();
    assert!(!err.to_string().is_empty());
    match c.infer(Lane::Interactive, &features(0.4), 0).expect("infer after bad swap") {
        InferOutcome::Ok(out) => assert_eq!(out.len(), 4),
        InferOutcome::Shed(s) => panic!("shed: {s}"),
    }
    let report = gw.stop().expect("stop");
    assert_eq!(report.swaps_applied, 0);
    assert_eq!(report.requests, 1);
}

#[test]
fn zero_capacity_lane_sheds_over_the_wire() {
    let session = ServeEngine::native(build_model(&mlp_cfg(7)))
        .with_max_wait_us(100)
        .with_queue_depth(Lane::Batch, 0)
        .start()
        .expect("engine start");
    let gw = Gateway::start(session, "127.0.0.1:0").expect("gateway start");
    let mut c = GatewayClient::connect(gw.addr()).expect("connect");
    match c.infer(Lane::Batch, &features(0.0), 0).expect("infer") {
        InferOutcome::Ok(_) => panic!("zero-capacity lane must shed"),
        InferOutcome::Shed(s) => assert_eq!(s, Shed::QueueFull),
    }
    // the interactive lane is untouched by the batch lane's cap
    match c.infer(Lane::Interactive, &features(0.0), 0).expect("infer") {
        InferOutcome::Ok(out) => assert_eq!(out.len(), 4),
        InferOutcome::Shed(s) => panic!("interactive lane shed: {s}"),
    }
    let report = gw.stop().expect("stop");
    assert_eq!(report.shed_queue, 1);
    assert_eq!(report.requests, 1);
}
