//! Native integration tests: config -> Model factory -> experiments ->
//! serving engine, with no PJRT/XLA anywhere (the default offline
//! workspace).

use spm_coordinator::config::{parse_toml, RunConfig};
use spm_coordinator::experiments::{self, DataSource};
use spm_coordinator::serve::{client_shares, Lane, ServeEngine, Workload};
use spm_core::models::api::{build_model, save_checkpoint, ModelCfg, ModelKind};
use spm_core::ops::{LinearCfg, LinearKind};
use spm_core::pairing::Schedule;
use spm_core::spm::Variant;
use spm_core::tensor::Mat;

fn quick_cfg() -> RunConfig {
    RunConfig { steps: 4, eval_batches: 2, warmup: 1, ..Default::default() }
}

#[test]
fn native_table1_driver_end_to_end() {
    let report = experiments::run_table1_native(&[16], &quick_cfg()).unwrap();
    assert!(report.contains("Table 1"), "{report}");
    assert!(report.contains("16"), "{report}");
}

#[test]
fn native_clf_driver_reports_sane_outcome() {
    let data = DataSource::Teacher { n: 32, classes: 10, seed: 5 };
    let cfg = RunConfig { steps: 6, ..quick_cfg() };
    let out = experiments::run_clf_native(
        "native_spm",
        LinearCfg::spm(32, Variant::General),
        10,
        32,
        &data,
        &cfg,
    )
    .unwrap();
    assert_eq!(out.n, 32);
    assert!(out.loss.is_finite());
    assert!(out.ms_per_step > 0.0);
    assert!((0.0..=1.0).contains(&out.acc));
}

#[test]
fn op_config_drives_native_student() {
    let doc =
        parse_toml("[op]\nvariant = \"rotation\"\nschedule = \"shift\"\nstages = 3\n").unwrap();
    let mut cfg = quick_cfg();
    cfg.apply_toml(&doc).unwrap();
    let student = cfg.op.to_linear_cfg(16, cfg.seed);
    assert_eq!(student.kind, LinearKind::Spm);
    assert_eq!(student.variant, Variant::Rotation);
    assert_eq!(student.schedule, Schedule::Shift);
    // and it trains through the native driver
    let data = DataSource::Teacher { n: 16, classes: 4, seed: 1 };
    let out = experiments::run_clf_native("cfg_student", student, 4, 16, &data, &cfg).unwrap();
    assert!(out.loss.is_finite());
}

#[test]
fn op_config_simd_exec_trains_on_any_build() {
    // `exec = "simd"` must construct and train everywhere: on builds or
    // machines without the vectorized backend the op downgrades to the
    // fused path at set_exec time (DESIGN.md §12) instead of failing.
    let doc = parse_toml("[op]\nexec = \"simd\"\nstages = 2\n").unwrap();
    let mut cfg = quick_cfg();
    cfg.apply_toml(&doc).unwrap();
    let student = cfg.op.to_linear_cfg(16, cfg.seed);
    let data = DataSource::Teacher { n: 16, classes: 4, seed: 2 };
    let out = experiments::run_clf_native("simd_student", student, 4, 16, &data, &cfg).unwrap();
    assert!(out.loss.is_finite());
    assert!((0.0..=1.0).contains(&out.acc));
}

#[test]
fn serving_engine_serves_remainder_workload() {
    // 97 requests over 4 clients: the pre-PR-1 num_requests / num_clients
    // split dropped 1 request; the engine must see all 97.
    let model = build_model(
        &ModelCfg::new(ModelKind::Mlp, LinearCfg::dense(8)).with_classes(3).with_seed(1),
    );
    let mut engine = ServeEngine::native(model).with_max_batch(16);
    let report = engine.run(&Workload { num_requests: 97, num_clients: 4, seed: 2 }).unwrap();
    assert_eq!(report.requests, 97);
    assert!(report.batches >= 7); // 97 requests can't fit six 16-batches
    assert!(report.p99_ms >= report.p50_ms);
    assert!(report.throughput_rps > 0.0);
}

#[test]
fn serving_session_serves_every_model_kind() {
    // the acceptance bar: all four architectures through the SAME
    // session API — start(), per-thread SubmitHandles, drained shutdown
    for kind in ModelKind::ALL {
        let cfg = ModelCfg::new(kind, LinearCfg::spm(8, Variant::General))
            .with_classes(3)
            .with_heads(2)
            .with_seq_len(2)
            .with_seed(7);
        let session =
            ServeEngine::native(build_model(&cfg)).with_max_wait_us(300).start().unwrap();
        let width = session.width();
        let clients: Vec<_> = (0..3)
            .map(|c| {
                let handle = session.handle();
                std::thread::spawn(move || {
                    for i in 0..8usize {
                        let lane = if i % 2 == 0 { Lane::Interactive } else { Lane::Batch };
                        let features =
                            (0..width).map(|j| (c * 8 + i + j) as f32 * 0.1).collect();
                        let row = handle
                            .submit_to(lane, features, None)
                            .expect("submit")
                            .wait()
                            .expect("serve");
                        assert!(!row.is_empty());
                    }
                })
            })
            .collect();
        for c in clients {
            c.join().unwrap();
        }
        let report = session.shutdown().unwrap();
        assert_eq!(report.requests, 24, "{kind:?}");
        assert_eq!(report.submitted, 24, "{kind:?}");
        assert_eq!(report.shed(), 0, "{kind:?}");
        assert!(report.batches >= 1, "{kind:?}");
        assert!(report.throughput_rps > 0.0, "{kind:?}");
        assert!(report.p99_ms >= report.p50_ms, "{kind:?}");
    }
}

#[test]
fn serving_session_replicates_any_model_kind() {
    // two gru replicas sharding one request stream through the session API
    let cfg = ModelCfg::new(ModelKind::Gru, LinearCfg::spm(8, Variant::Rotation))
        .with_classes(3)
        .with_seq_len(2)
        .with_seed(9);
    let session = ServeEngine::native(build_model(&cfg))
        .with_replica(build_model(&cfg))
        .with_max_batch(2)
        .with_max_wait_us(0)
        .start()
        .unwrap();
    assert_eq!(session.replica_count(), 2);
    let handle = session.handle();
    let width = session.width();
    let pending: Vec<_> = (0..12)
        .map(|i| {
            let features = (0..width).map(|j| (i + j) as f32 * 0.05).collect();
            handle.submit(features).expect("submit")
        })
        .collect();
    for p in pending {
        p.wait().expect("serve");
    }
    let report = session.shutdown().unwrap();
    assert_eq!(report.requests, 12);
    assert_eq!(report.replica_batches.len(), 2);
    assert!(report.replica_batches.iter().all(|&b| b > 0), "{:?}", report.replica_batches);
}

#[test]
fn model_config_serves_from_toml() {
    // [model] + [op] all the way to a serving run, no code in between
    let doc = parse_toml(
        "[op]\nvariant = \"general\"\n[model]\nkind = \"attention\"\nn = 8\nheads = 2\nseq_len = 2\n",
    )
    .unwrap();
    let mut cfg = quick_cfg();
    cfg.apply_toml(&doc).unwrap();
    let model = cfg.model.build(&cfg.op, cfg.seed).unwrap();
    assert_eq!(model.kind(), ModelKind::Attention);
    assert_eq!(model.d_in(), 2 * 8);
    let mut engine = ServeEngine::native(model);
    let report = engine.run(&Workload { num_requests: 9, num_clients: 2, seed: 3 }).unwrap();
    assert_eq!(report.requests, 9);
}

#[test]
fn serve_config_drives_a_gateway_session_from_toml() {
    // [serve] all the way to a live TCP gateway: replicas, lane caps, and
    // the listen address come from config, requests go over loopback
    use spm_coordinator::gateway::{Gateway, GatewayClient, InferOutcome};
    let doc = parse_toml(
        "[serve]\nreplicas = 2\nmax_batch = 4\nmax_wait_us = 100\nqueue_depth = 64\n\
         listen_addr = \"127.0.0.1:0\"\n",
    )
    .unwrap();
    let mut cfg = quick_cfg();
    cfg.apply_toml(&doc).unwrap();
    assert_eq!(cfg.serve.replicas, 2);
    assert_eq!(cfg.serve.listen_addr, "127.0.0.1:0");

    let mcfg = ModelCfg::new(ModelKind::Mlp, LinearCfg::spm(8, Variant::General))
        .with_classes(3)
        .with_seed(11);
    let session = cfg.serve.to_engine(|_i| build_model(&mcfg)).start().unwrap();
    assert_eq!(session.replica_count(), 2);
    let gw = Gateway::start(session, &cfg.serve.listen_addr).unwrap();
    let mut client = GatewayClient::connect(gw.addr()).unwrap();
    for i in 0..6 {
        let x: Vec<f32> = (0..8).map(|j| (i + j) as f32 * 0.1).collect();
        match client.infer(Lane::Interactive, &x, 0).unwrap() {
            InferOutcome::Ok(row) => assert_eq!(row.len(), 3),
            InferOutcome::Shed(s) => panic!("shed under no load: {s}"),
        }
    }
    let report = gw.stop().unwrap();
    assert_eq!(report.requests, 6);
    assert_eq!(report.shed(), 0);
}

#[test]
fn served_model_warm_starts_from_checkpoint() {
    // save a trained-ish model, point [model] checkpoint at it, and the
    // config-built model must produce identical logits
    let mcfg = ModelCfg::new(ModelKind::Mlp, LinearCfg::spm(8, Variant::General))
        .with_classes(3)
        .with_seed(quick_cfg().seed ^ 0xC1A55);
    let src = build_model(&mcfg);
    let path = std::env::temp_dir().join("spm_test_native_warmstart.ckpt");
    save_checkpoint(src.as_ref(), &path).unwrap();

    let doc = parse_toml(&format!(
        "[model]\nkind = \"mlp\"\nn = 8\nclasses = 3\ncheckpoint = \"{}\"\n",
        path.display()
    ))
    .unwrap();
    let mut cfg = quick_cfg();
    cfg.apply_toml(&doc).unwrap();
    let warm = cfg.model.build(&cfg.op, cfg.seed).unwrap();
    let x = Mat::from_vec(4, 8, (0..32).map(|i| (i as f32) * 0.1 - 1.5).collect());
    assert_eq!(warm.forward(&x).data, src.forward(&x).data);
    let _ = std::fs::remove_file(&path);
}

#[test]
fn shares_match_router_accounting() {
    for clients in 1..6 {
        let shares = client_shares(23, clients);
        assert_eq!(shares.iter().sum::<usize>(), 23);
    }
}

#[test]
fn datasource_batches_are_deterministic_and_split() {
    let d = DataSource::AgNews { n: 128 };
    let (x1, y1) = d.batch(3, 16, true);
    let (x2, y2) = d.batch(3, 16, true);
    assert_eq!(x1.data, x2.data);
    assert_eq!(y1, y2);
    let (xt, _yt) = d.batch(3, 16, false);
    assert_ne!(x1.data, xt.data, "train/test streams must differ");

    let t = DataSource::Teacher { n: 32, classes: 10, seed: 1 };
    let (a1, b1) = t.batch(0, 8, true);
    let (a2, b2) = t.batch(0, 8, true);
    assert_eq!(a1.data, a2.data);
    assert_eq!(b1, b2);
}

#[test]
fn toml_config_drives_runconfig() {
    let doc = parse_toml("[run]\nsteps = 9\neval_batches = 3\nseed = 4\n").unwrap();
    let mut cfg = RunConfig::default();
    cfg.apply_toml(&doc).unwrap();
    assert_eq!((cfg.steps, cfg.eval_batches, cfg.seed), (9, 3, 4));
}

#[test]
fn core_scaling_renders() {
    let report = experiments::run_core_scaling(&[32], 4);
    assert!(report.contains("Core op scaling"), "{report}");
}
