//! Native integration tests: config -> LinearOp experiments -> serving
//! router, with no PJRT/XLA anywhere (the default offline workspace).

use spm_coordinator::config::{parse_toml, RunConfig};
use spm_coordinator::experiments::{self, DataSource};
use spm_coordinator::serve::{client_shares, serve_native, serve_with, ServeSpec};
use spm_core::models::mlp::Classifier;
use spm_core::ops::{LinearCfg, LinearKind};
use spm_core::pairing::Schedule;
use spm_core::spm::Variant;

fn quick_cfg() -> RunConfig {
    RunConfig { steps: 4, eval_batches: 2, warmup: 1, ..Default::default() }
}

#[test]
fn native_table1_driver_end_to_end() {
    let report = experiments::run_table1_native(&[16], &quick_cfg()).unwrap();
    assert!(report.contains("Table 1"), "{report}");
    assert!(report.contains("16"), "{report}");
}

#[test]
fn native_clf_driver_reports_sane_outcome() {
    let data = DataSource::Teacher { n: 32, classes: 10, seed: 5 };
    let cfg = RunConfig { steps: 6, ..quick_cfg() };
    let out = experiments::run_clf_native(
        "native_spm",
        LinearCfg::spm(32, Variant::General),
        10,
        32,
        &data,
        &cfg,
    )
    .unwrap();
    assert_eq!(out.n, 32);
    assert!(out.loss.is_finite());
    assert!(out.ms_per_step > 0.0);
    assert!((0.0..=1.0).contains(&out.acc));
}

#[test]
fn op_config_drives_native_student() {
    let doc =
        parse_toml("[op]\nvariant = \"rotation\"\nschedule = \"shift\"\nstages = 3\n").unwrap();
    let mut cfg = quick_cfg();
    cfg.apply_toml(&doc).unwrap();
    let student = cfg.op.to_linear_cfg(16, cfg.seed);
    assert_eq!(student.kind, LinearKind::Spm);
    assert_eq!(student.variant, Variant::Rotation);
    assert_eq!(student.schedule, Schedule::Shift);
    // and it trains through the native driver
    let data = DataSource::Teacher { n: 16, classes: 4, seed: 1 };
    let out = experiments::run_clf_native("cfg_student", student, 4, 16, &data, &cfg).unwrap();
    assert!(out.loss.is_finite());
}

#[test]
fn op_config_simd_exec_trains_on_any_build() {
    // `exec = "simd"` must construct and train everywhere: on builds or
    // machines without the vectorized backend the op downgrades to the
    // fused path at set_exec time (DESIGN.md §12) instead of failing.
    let doc = parse_toml("[op]\nexec = \"simd\"\nstages = 2\n").unwrap();
    let mut cfg = quick_cfg();
    cfg.apply_toml(&doc).unwrap();
    let student = cfg.op.to_linear_cfg(16, cfg.seed);
    let data = DataSource::Teacher { n: 16, classes: 4, seed: 2 };
    let out = experiments::run_clf_native("simd_student", student, 4, 16, &data, &cfg).unwrap();
    assert!(out.loss.is_finite());
    assert!((0.0..=1.0).contains(&out.acc));
}

#[test]
fn serving_router_native_end_to_end_serves_remainder() {
    // 97 requests over 4 clients: the old num_requests / num_clients split
    // dropped 1 request; the router must see all 97.
    let clf = Classifier::new(LinearCfg::dense(8), 3, 1e-3, 1);
    let report = serve_native(&clf, 16, 97, 4, 2).unwrap();
    assert_eq!(report.requests, 97);
    assert!(report.batches >= 7); // 97 requests can't fit six 16-batches
    assert!(report.p99_ms >= report.p50_ms);
    assert!(report.throughput_rps > 0.0);
}

#[test]
fn serve_with_custom_executor_pads_tail_batches() {
    let spec = ServeSpec { batch: 8, n: 3, num_requests: 10, num_clients: 2, seed: 7 };
    let mut calls = 0usize;
    let report = serve_with(&spec, |flat| {
        calls += 1;
        assert_eq!(flat.len(), 8 * 3); // always padded to full batch
        Ok(vec![0.0; 8])
    })
    .unwrap();
    assert_eq!(report.requests, 10);
    assert_eq!(report.batches, calls);
}

#[test]
fn shares_match_router_accounting() {
    for clients in 1..6 {
        let shares = client_shares(23, clients);
        assert_eq!(shares.iter().sum::<usize>(), 23);
    }
}

#[test]
fn datasource_batches_are_deterministic_and_split() {
    let d = DataSource::AgNews { n: 128 };
    let (x1, y1) = d.batch(3, 16, true);
    let (x2, y2) = d.batch(3, 16, true);
    assert_eq!(x1.data, x2.data);
    assert_eq!(y1, y2);
    let (xt, _yt) = d.batch(3, 16, false);
    assert_ne!(x1.data, xt.data, "train/test streams must differ");

    let t = DataSource::Teacher { n: 32, classes: 10, seed: 1 };
    let (a1, b1) = t.batch(0, 8, true);
    let (a2, b2) = t.batch(0, 8, true);
    assert_eq!(a1.data, a2.data);
    assert_eq!(b1, b2);
}

#[test]
fn toml_config_drives_runconfig() {
    let doc = parse_toml("[run]\nsteps = 9\neval_batches = 3\nseed = 4\n").unwrap();
    let mut cfg = RunConfig::default();
    cfg.apply_toml(&doc).unwrap();
    assert_eq!((cfg.steps, cfg.eval_batches, cfg.seed), (9, 3, 4));
}

#[test]
fn core_scaling_renders() {
    let report = experiments::run_core_scaling(&[32], 4);
    assert!(report.contains("Core op scaling"), "{report}");
}
