//! Integration tests for the ablation harness (DESIGN.md §17): plan
//! round-trips, the append-only registry contract, the regression
//! check, and — the core promise — pinned-seed determinism: the same
//! plan run twice produces bit-identical exact KPIs, per exec backend.
//!
//! Deliberately NO `#[global_allocator]` here: the counting allocator
//! is process-global, and parallel test threads allocating inside a
//! measurement window would make `allocs_per_step` flaky. In this
//! binary the KPI reads 0 everywhere — trivially deterministic — and
//! the real measurement lives in the single-threaded bench binary.

use std::path::PathBuf;

use spm_core::ops::backend;
use spm_coordinator::ablate::{
    check_against_registry, exact_rows, registry_append, registry_load, registry_path,
    report_json, run_plan, Gates, Plan,
};

fn temp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("spm_ablate_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).expect("mkdir");
    dir
}

/// A plan small enough to train in milliseconds, pinned like a real one.
fn tiny_plan(execs: &str) -> Plan {
    Plan::parse(&format!(
        "[plan]\n\
         name = \"tiny\"\n\
         seed = 11\n\
         steps = 2\n\
         rows = 4\n\
         n = 8\n\
         \n\
         [axes]\n\
         op = [\"spm\", \"dense\"]\n\
         variant = [\"general\"]\n\
         schedule = [\"butterfly\"]\n\
         stages = [2]\n\
         exec = [{execs}]\n\
         model = [\"mlp\"]\n"
    ))
    .expect("tiny plan parses")
}

#[test]
fn pinned_seeds_are_deterministic_per_exec_backend() {
    // both scalar backends always exist; the simd backend joins the
    // matrix only where it actually runs (never silently downgraded)
    let mut execs = vec!["\"fused\", \"rowwise\""];
    if backend::simd_available() {
        execs.push("\"fused\", \"rowwise\", \"simd\"");
    }
    for execs in execs {
        let plan = tiny_plan(execs);
        let a = run_plan(&plan).expect("first run");
        let b = run_plan(&plan).expect("second run");
        assert!(a.skipped.is_empty(), "no cell may skip here: {:?}", a.skipped);
        assert_eq!(
            exact_rows(&a),
            exact_rows(&b),
            "same plan, same process, different exact KPIs ({execs})"
        );
        // loss/acc really trained (not a stub): finite, and every
        // exec backend of the same cell agrees bit-for-bit too, since
        // the stage kernels are deterministic reorderings
        assert!(a.cells.iter().all(|c| c.kpis[0].is_finite()));
    }
}

#[test]
fn registry_is_append_only_with_a_validated_header() {
    let dir = temp_dir("registry");
    let path = registry_path(&dir, "tiny");
    let plan = tiny_plan("\"fused\"");
    let report = run_plan(&plan).expect("run");

    assert_eq!(registry_load(&path).expect("missing file is bootstrap"), vec![]);
    let wrote = registry_append(&path, &report).expect("first append");
    assert_eq!(wrote, report.cells.len());
    let after_first = std::fs::read_to_string(&path).expect("read");
    assert!(after_first.starts_with("# spm-ablate-registry v1\n"), "magic line");
    assert!(after_first.lines().nth(1).unwrap().starts_with("git_sha,exec,schema_version,"));

    registry_append(&path, &report).expect("second append");
    let after_second = std::fs::read_to_string(&path).expect("read");
    assert!(
        after_second.starts_with(&after_first),
        "append must extend the file, never rewrite history"
    );

    let rows = registry_load(&path).expect("load");
    assert_eq!(rows.len(), 2 * report.cells.len());
    assert!(rows.iter().all(|r| r.plan_hash == report.plan_hash));
    assert!(rows.iter().all(|r| r.schema_version == 1));

    // a foreign or tampered header is refused outright, both ways
    let bogus = dir.join("bogus.csv");
    std::fs::write(&bogus, "just,some,csv\n1,2,3\n").expect("write");
    assert!(registry_append(&bogus, &report).is_err(), "append must not adopt foreign files");
    assert!(registry_load(&bogus).is_err(), "load must not trust foreign files");

    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn check_gates_regressions_and_bootstraps_new_cells() {
    let dir = temp_dir("check");
    let path = registry_path(&dir, "tiny");
    let plan = tiny_plan("\"fused\"");
    let report = run_plan(&plan).expect("run");

    // no baseline yet: every cell bootstraps, the gate passes
    let empty = check_against_registry(&plan, &report, &[]);
    assert!(empty.passed());
    assert_eq!(empty.bootstrapped, report.cells.len());
    assert_eq!(empty.compared, 0);

    // a committed baseline from the same run: compared, in tolerance
    registry_append(&path, &report).expect("append");
    let rows = registry_load(&path).expect("load");
    let clean = check_against_registry(&plan, &report, &rows);
    assert!(clean.passed(), "identical run must pass: {:?}", clean.failures);
    assert_eq!(clean.compared, report.cells.len());
    assert_eq!(clean.bootstrapped, 0);

    // tamper with the baseline loss: the fresh run now reads as a
    // regression (fresh > base is the worse direction for loss)
    let mut tampered = rows.clone();
    tampered[0].kpis[0] -= 0.25;
    let caught = check_against_registry(&plan, &report, &tampered);
    assert!(!caught.passed(), "a worse loss must trip the zero-tolerance exact gate");
    assert!(caught.failures[0].contains("loss"), "{:?}", caught.failures);

    // ...but drift in the IMPROVING direction passes the one-sided gate
    let mut improved = rows.clone();
    improved[0].kpis[0] += 0.25;
    assert!(check_against_registry(&plan, &report, &improved).passed());

    // a different plan hash never matches: everything bootstraps again
    let mut foreign = rows;
    for r in &mut foreign {
        r.plan_hash = "ffffffffffffffff".into();
    }
    let unmatched = check_against_registry(&plan, &report, &foreign);
    assert_eq!(unmatched.bootstrapped, report.cells.len());

    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn every_model_kind_runs_through_the_harness() {
    let plan = Plan::parse(
        "[plan]\n\
         name = \"zoo\"\n\
         seed = 3\n\
         steps = 1\n\
         rows = 2\n\
         n = 8\n\
         heads = 2\n\
         seq_len = 2\n\
         \n\
         [axes]\n\
         op = [\"spm\"]\n\
         exec = [\"fused\"]\n\
         model = [\"mlp\", \"gru\", \"charlm\", \"attention\"]\n",
    )
    .expect("zoo plan");
    let report = run_plan(&plan).expect("run");
    assert_eq!(report.cells.len(), 4);
    for c in &report.cells {
        assert!(c.kpis[0].is_finite(), "{}: loss", c.cell.id());
        assert!(c.kpis[2] > 0.0, "{}: param_count", c.cell.id());
        assert!(c.kpis[3] > 0.0, "{}: flops_per_row", c.cell.id());
    }
    // the JSON artifact carries the full schema
    let json = report_json(&plan, &report);
    for needle in
        ["\"bench\": \"ablate\"", "\"plan\": \"zoo\"", "\"plan_hash\"", "\"registry_schema_version\": 1", "\"flops_per_row\""]
    {
        assert!(json.contains(needle), "missing {needle} in:\n{json}");
    }
}

#[test]
fn committed_gates_file_matches_the_compiled_defaults() {
    // the committed ablate/gates.toml is documentation-as-config: it
    // must stay in lockstep with the builtin fallback so a checkout
    // without the file gates identically
    let committed = spm_coordinator::ablate::repo_root().join("ablate").join("gates.toml");
    assert!(committed.exists(), "ablate/gates.toml must be committed at the repo root");
    let loaded = Gates::load(&committed).expect("parse committed gates");
    let defaults = Gates::default();
    assert_eq!(loaded.core_ops, defaults.core_ops);
    assert_eq!(loaded.serve, defaults.serve);
    assert_eq!(loaded.train, defaults.train);
    assert_ne!(loaded.source, defaults.source, "source must say where values came from");
}

#[test]
fn committed_zoo_plan_covers_every_op_kind_at_matched_budgets() {
    let root = spm_coordinator::ablate::repo_root();
    let mut plan = Plan::load(&root.join("ablate").join("zoo.toml")).expect("zoo plan");
    assert_eq!(plan.name, "zoo");
    assert_eq!(plan.ops, {
        use spm_core::ops::LinearKind;
        LinearKind::ALL.to_vec()
    });
    // the shipped header-only registry must satisfy the loader
    let rows = registry_load(&root.join("registry").join("zoo.csv")).expect("zoo registry");
    assert!(rows.is_empty(), "zoo.csv ships header-only; baselines are appended per machine class");

    // run a reduced grid (CI-smoke sized): every kind still present
    plan.steps = 1;
    plan.rows = 2;
    plan.models.truncate(1);
    let report = run_plan(&plan).expect("run");
    assert_eq!(report.cells.len(), 5, "one cell per LinearKind on the mlp");
    for c in &report.cells {
        assert!(c.kpis[0].is_finite(), "{}: loss", c.cell.id());
    }
    // equal-parameter-budget contract (DESIGN.md §19): lowrank and
    // blockshuffle land within 25% of the spm cell's parameter count at
    // n = 16, while dense sits strictly above all structured kinds
    let params = |needle: &str| -> f64 {
        report
            .cells
            .iter()
            .find(|c| c.cell.id().contains(needle))
            .unwrap_or_else(|| panic!("no {needle} cell"))
            .kpis[2]
    };
    let spm = params("op=spm");
    for kind in ["op=lowrank", "op=blockshuffle", "op=butterfly"] {
        let p = params(kind);
        assert!((p - spm).abs() <= 0.25 * spm, "{kind}: {p} vs spm {spm}");
        assert!(p < params("op=dense"), "{kind} must undercut dense");
    }
}

#[test]
fn committed_smoke_plan_parses_and_registry_header_is_valid() {
    let root = spm_coordinator::ablate::repo_root();
    let plan = Plan::load(&root.join("ablate").join("smoke.toml")).expect("smoke plan");
    assert_eq!(plan.name, "smoke");
    let design9 = Plan::load(&root.join("ablate").join("design9.toml")).expect("design9 plan");
    assert_eq!(design9.stages, vec![1, 2, 5, 10, 20]);
    // the shipped header-only registry must satisfy the loader
    let rows = registry_load(&root.join("registry").join("smoke.csv")).expect("smoke registry");
    assert!(rows.is_empty(), "smoke.csv ships header-only; baselines are appended per machine class");
}
