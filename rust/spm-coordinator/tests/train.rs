//! Data-parallel TrainEngine integration tests (DESIGN.md §14): real
//! models, real kernels, no PJRT/XLA anywhere. The headline property is
//! REPLICA-COUNT INVARIANCE: with the microbatch group size and the
//! per-replica thread budget pinned, R=1 and R=4 must produce
//! bit-identical parameter trajectories — the deterministic all-reduce
//! sums per-microbatch gradients in global microbatch order, so the
//! only thing replicas change is wall-clock.

use spm_core::models::api::{build_model, Model, ModelCfg, ModelKind};
use spm_core::ops::LinearCfg;
use spm_core::rng::Rng;
use spm_core::spm::Variant;
use spm_core::tensor::Mat;
use spm_coordinator::train::{TrainBatch, TrainEngine};

fn small_cfg(kind: ModelKind) -> ModelCfg {
    ModelCfg::new(kind, LinearCfg::spm(8, Variant::General))
        .with_classes(4)
        .with_heads(2)
        .with_seq_len(2)
        .with_seed(17)
}

/// A deterministic microbatch stream for any classifier kind (labels
/// derived from the features so the task is learnable, not noise).
fn label_batches(model: &dyn Model, count: usize, rows: usize, seed: u64) -> Vec<TrainBatch> {
    let d = model.d_in();
    let mut rng = Rng::new(seed);
    (0..count)
        .map(|_| {
            let x = match model.kind() {
                ModelKind::CharLm => Mat::from_vec(
                    rows,
                    d,
                    (0..rows * d).map(|i| 97.0 + (i % 3) as f32).collect(),
                ),
                _ => Mat::from_vec(rows, d, rng.normal_vec(rows * d, 1.0)),
            };
            let y: Vec<u32> = (0..rows)
                .map(|r| {
                    if model.kind() == ModelKind::CharLm {
                        // next-byte target derived from the (single) token
                        97 + (x.at(r, 0) as u32) % 2
                    } else {
                        u32::from(x.at(r, 0) > x.at(r, 1))
                    }
                })
                .collect();
            TrainBatch::labels(x, y)
        })
        .collect()
}

fn flat_params(model: &dyn Model) -> Vec<f32> {
    let mut out = Vec::new();
    model.visit_params(&mut |_n, p| out.extend_from_slice(p));
    out
}

/// The acceptance bar: R=1 vs R=4 on a fixed seed produce IDENTICAL
/// post-step params (deterministic reduction) for mlp and gru.
#[test]
fn r1_and_r4_trajectories_are_bit_identical_mlp_and_gru() {
    for kind in [ModelKind::Mlp, ModelKind::Gru] {
        let cfg = small_cfg(kind);
        let run = |replicas: usize| -> Vec<f32> {
            let probe = build_model(&cfg);
            let batches = label_batches(probe.as_ref(), 8, 6, 99);
            drop(probe);
            let mut engine = TrainEngine::from_cfg(&cfg, replicas)
                .with_accum(4)
                .with_threads_per_replica(1);
            let report = engine.train_epoch(&batches);
            assert_eq!(report.steps, 2, "{kind:?}: 8 microbatches / accum 4");
            assert_eq!(report.microbatches, 8, "{kind:?}");
            flat_params(engine.model())
        };
        let p1 = run(1);
        let p4 = run(4);
        assert_eq!(p1, p4, "{kind:?}: R=4 must reproduce the R=1 trajectory exactly");
    }
}

/// The same invariance holds for the remaining kinds (charlm labels,
/// attention value targets) — the engine is architecture-agnostic.
#[test]
fn r_invariance_extends_to_charlm_and_attention() {
    // charlm through the label path
    let cfg = small_cfg(ModelKind::CharLm);
    let run = |replicas: usize| -> Vec<f32> {
        let probe = build_model(&cfg);
        let batches = label_batches(probe.as_ref(), 4, 5, 7);
        drop(probe);
        let mut engine = TrainEngine::from_cfg(&cfg, replicas)
            .with_accum(2)
            .with_threads_per_replica(1);
        engine.train_epoch(&batches);
        flat_params(engine.model())
    };
    assert_eq!(run(1), run(2), "charlm");

    // attention through the value-target path
    let cfg = small_cfg(ModelKind::Attention);
    let run = |replicas: usize| -> Vec<f32> {
        let d_in = build_model(&cfg).d_in();
        let mut rng = Rng::new(11);
        let batches: Vec<TrainBatch> = (0..4)
            .map(|_| {
                let x = Mat::from_vec(3, d_in, rng.normal_vec(3 * d_in, 1.0));
                let t = x.clone();
                TrainBatch::values(x, t)
            })
            .collect();
        let mut engine = TrainEngine::from_cfg(&cfg, replicas)
            .with_accum(2)
            .with_threads_per_replica(1);
        engine.train_epoch(&batches);
        flat_params(engine.model())
    };
    assert_eq!(run(1), run(2), "attention");
}

/// Multi-replica training must actually learn: loss decreases from the
/// cold-init evaluation after a few engine steps.
#[test]
fn multi_replica_training_reduces_loss() {
    let cfg = small_cfg(ModelKind::Mlp);
    let probe = build_model(&cfg);
    let batches = label_batches(probe.as_ref(), 24, 32, 3);
    let eval = &batches[0];
    let (l0, _a0) = probe.evaluate(&eval.x, &eval.target.as_target());
    drop(probe);

    let mut engine = TrainEngine::from_cfg(&cfg, 2);
    let report = engine.train_epoch(&batches);
    assert_eq!(report.microbatches, 24);
    assert!(report.replica_microbatches.iter().all(|&m| m > 0), "idle replica");
    let (l1, _a1) = engine.model().evaluate(&eval.x, &eval.target.as_target());
    assert!(l1 < l0, "loss did not decrease from init: {l0} -> {l1}");
    assert!(report.rows_per_sec > 0.0);
}

/// A warm-started primary wins: replicas built from the same config
/// adopt the primary's (different) parameters before the first step.
#[test]
fn replicas_sync_from_a_warm_primary() {
    let cfg = small_cfg(ModelKind::Mlp);
    let mut primary = build_model(&cfg);
    let mut rng = Rng::new(5);
    primary.visit_params_mut(&mut |_n, p| {
        for v in p.iter_mut() {
            *v += 0.1 * rng.normal();
        }
    });
    let warm = flat_params(primary.as_ref());

    // engine A: warm primary + cold replica, one step
    let batches = label_batches(primary.as_ref(), 2, 4, 13);
    let mut a = TrainEngine::new(primary)
        .with_replica(build_model(&cfg))
        .with_accum(2)
        .with_threads_per_replica(1);
    a.step(&batches);

    // engine B: warm single replica, same stream
    let mut warm_primary = build_model(&cfg);
    let mut off = 0usize;
    warm_primary.visit_params_mut(&mut |_n, p| {
        p.copy_from_slice(&warm[off..off + p.len()]);
        off += p.len();
    });
    let mut b = TrainEngine::new(warm_primary).with_accum(2).with_threads_per_replica(1);
    b.step(&batches);

    assert_eq!(
        flat_params(a.model()),
        flat_params(b.model()),
        "cold replica must adopt the warm primary, not poison the reduce"
    );
}
