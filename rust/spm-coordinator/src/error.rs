//! Dependency-free error plumbing (anyhow is not in the offline vendor
//! set for the default workspace): a boxed error alias plus the `bail!` /
//! `.context(..)` helpers the coordinator uses. `anyhow::Error` converts
//! into [`Error`] via `From`, so the XLA-side callers in `spm-runtime`
//! can `?` their results straight into these signatures.

/// Boxed dynamic error; everything `Display`-able converts in.
pub type Error = Box<dyn std::error::Error + Send + Sync + 'static>;

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Early-return with a formatted boxed error (the shape of anyhow::bail).
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err(format!($($arg)*).into())
    };
}

/// `.context(..)` / `.with_context(..)` on Results and Options.
pub trait Context<T> {
    fn context<C: std::fmt::Display>(self, c: C) -> Result<T>;
    fn with_context<C: std::fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: std::fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: std::fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| format!("{c}: {e}").into())
    }

    fn with_context<C: std::fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| format!("{}: {e}", f()).into())
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: std::fmt::Display>(self, c: C) -> Result<T> {
        self.ok_or_else(|| format!("{c}").into())
    }

    fn with_context<C: std::fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| format!("{}", f()).into())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<()> {
        bail!("code {} failed", 7)
    }

    #[test]
    fn bail_formats() {
        assert_eq!(fails().unwrap_err().to_string(), "code 7 failed");
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), String> = Err("inner".into());
        assert_eq!(r.context("outer").unwrap_err().to_string(), "outer: inner");
        let o: Option<u32> = None;
        assert_eq!(o.with_context(|| "missing").unwrap_err().to_string(), "missing");
        let some: Option<u32> = Some(3);
        assert_eq!(some.context("x").unwrap(), 3);
    }

    #[test]
    fn io_errors_convert() {
        fn read() -> Result<String> {
            Ok(std::fs::read_to_string("/nonexistent/spm")?)
        }
        assert!(read().is_err());
    }
}
