//! Shared flag parsing + JSON conventions for the bench/example binaries
//! (`benches/{core_ops,serve_bench,train_bench}.rs`). Every bench used
//! to carry its own copy-pasted `--json`/`--check`/`--sizes`/`--batch`
//! scanner; this module is the one implementation, plus the
//! `schema_version` stamp every emitted `BENCH_*.json` carries so the
//! perf-trajectory tooling can tell at a glance which layout it holds.

use spm_core::ops::SpmExec;

/// Version of the BENCH_*.json layout. Bump when a bench renames or
/// restructures its emitted fields (additive fields do not need a bump).
///
/// - 1: the implicit pre-stamp layout (no `schema_version` field)
/// - 2: `schema_version` added everywhere; serve rows gained the
///   admission counters and BENCH_gateway.json exists
/// - 3: ABLATE_<plan>.json exists (the ablation harness artifact, with
///   its own `registry_schema_version` stamp for the committed
///   registry/*.csv layout); bench thresholds moved into the
///   declarative `ablate/gates.toml` schema
pub const SCHEMA_VERSION: u32 = 3;

/// A parsed argv: positional lookups over `--key value` pairs and bare
/// `--switch` flags, shared by every bench binary.
pub struct BenchArgs {
    argv: Vec<String>,
}

impl BenchArgs {
    /// Parse the process argv.
    pub fn parse() -> BenchArgs {
        BenchArgs { argv: std::env::args().collect() }
    }

    /// Parse an explicit argv (tests).
    pub fn from_vec(argv: Vec<String>) -> BenchArgs {
        BenchArgs { argv }
    }

    /// Is the bare switch present? (`--check`-style flags.)
    pub fn has(&self, key: &str) -> bool {
        self.argv.iter().any(|a| a == key)
    }

    /// The value following `--key`, if any.
    pub fn str_opt(&self, key: &str) -> Option<&str> {
        self.argv
            .iter()
            .position(|a| a == key)
            .and_then(|i| self.argv.get(i + 1))
            .map(|s| s.as_str())
    }

    /// `--key N` as usize, `default` when absent; a malformed value is a
    /// loud error, never a silent default.
    pub fn usize_flag(&self, key: &str, default: usize) -> usize {
        match self.str_opt(key) {
            Some(s) => s.parse().unwrap_or_else(|_| panic!("{key}: bad count '{s}'")),
            None => default,
        }
    }

    /// `--key N` as u64 (micros-style flags), `default` when absent.
    pub fn u64_flag(&self, key: &str, default: u64) -> u64 {
        match self.str_opt(key) {
            Some(s) => s.parse().unwrap_or_else(|_| panic!("{key}: bad value '{s}'")),
            None => default,
        }
    }

    /// `--sizes a,b,c` as widths, `None` when absent (each bench keeps
    /// its own default sweep).
    pub fn sizes(&self) -> Option<Vec<usize>> {
        self.str_opt("--sizes").map(|s| {
            s.split(',')
                .map(|w| w.parse().unwrap_or_else(|_| panic!("--sizes: bad width '{w}'")))
                .collect()
        })
    }

    /// `--json <path>`: where to write the machine-readable artifact.
    pub fn json_path(&self) -> Option<String> {
        self.str_opt("--json").map(|s| s.to_string())
    }

    /// `--check`: run the CI gate and exit non-zero on failure.
    pub fn check(&self) -> bool {
        self.has("--check")
    }
}

/// The exec path a bench runs with: `SPM_EXEC` when set (the CI matrix
/// contract — bad names are an error, not a silent default), otherwise
/// the fused default.
pub fn env_exec() -> SpmExec {
    match std::env::var("SPM_EXEC") {
        Ok(name) => SpmExec::parse(&name)
            .unwrap_or_else(|| panic!("SPM_EXEC '{name}' is not an exec mode")),
        Err(_) => SpmExec::default(),
    }
}

/// JSON number or `null` — non-finite floats (a NaN parity diff from a
/// broken kernel, an inf ratio) must not corrupt the artifact that is
/// supposed to explain the failure.
pub fn json_num(v: f64) -> String {
    if v.is_finite() {
        format!("{v:.6}")
    } else {
        "null".into()
    }
}

/// The opening of every BENCH_*.json object: `{`, the bench name, and
/// the schema stamp — so no bench can forget the version field.
pub fn json_header(bench: &str) -> String {
    format!("{{\n  \"bench\": \"{bench}\",\n  \"schema_version\": {SCHEMA_VERSION},\n")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &[&str]) -> BenchArgs {
        BenchArgs::from_vec(s.iter().map(|s| s.to_string()).collect())
    }

    #[test]
    fn flags_parse_with_defaults() {
        let a = args(&["bench", "--requests", "97", "--json", "out.json", "--check"]);
        assert_eq!(a.usize_flag("--requests", 256), 97);
        assert_eq!(a.usize_flag("--clients", 8), 8);
        assert_eq!(a.u64_flag("--wait-us", 200), 200);
        assert_eq!(a.json_path().as_deref(), Some("out.json"));
        assert!(a.check());
        assert!(!a.has("--gateway"));
    }

    #[test]
    fn sizes_split_on_commas() {
        let a = args(&["bench", "--sizes", "256,1024,4096"]);
        assert_eq!(a.sizes(), Some(vec![256, 1024, 4096]));
        assert_eq!(args(&["bench"]).sizes(), None);
    }

    #[test]
    #[should_panic(expected = "--requests: bad count")]
    fn malformed_count_is_loud() {
        args(&["bench", "--requests", "many"]).usize_flag("--requests", 1);
    }

    #[test]
    fn json_header_stamps_the_schema() {
        let h = json_header("serve");
        assert!(h.contains("\"bench\": \"serve\""));
        assert!(h.contains(&format!("\"schema_version\": {SCHEMA_VERSION}")));
    }

    #[test]
    fn json_num_nulls_non_finite() {
        assert_eq!(json_num(1.5), "1.500000");
        assert_eq!(json_num(f64::NAN), "null");
        assert_eq!(json_num(f64::INFINITY), "null");
    }
}
