//! # spm-coordinator
//!
//! L3 of the three-layer stack: the experiment coordinator. Owns the
//! config system, CLI launcher (`spm`), metrics, the prefetching data
//! pipeline, every table/ablation driver, and the batched-serving demo.
//! Examples and benches call into this library so every reported number has
//! a single source of truth.

pub mod checkpoint;
pub mod config;
pub mod experiments;
pub mod metrics;
pub mod serve;

pub use config::RunConfig;
