//! # spm-coordinator
//!
//! L3 of the three-layer stack: the experiment coordinator. Owns the
//! config system (the `[op]` LinearOp student config, the `[model]`
//! section building any network from the unified model zoo, and the
//! `[train]` data-parallel shape), metrics, the native experiment
//! drivers, the deadline-batched serving engine (`ServeEngine` over the
//! `Executor` trait — DESIGN.md §13), and the data-parallel training
//! engine (`TrainEngine` with its deterministic gradient all-reduce —
//! DESIGN.md §14). Fully dependency-free so the default workspace
//! builds and tests offline; the PJRT/XLA drivers and the `spm` CLI
//! live in `spm-runtime` (excluded from the default members) and call
//! back into this crate so every reported number has a single source of
//! truth.

pub mod ablate;
pub mod allocs;
pub mod bench_args;
pub mod config;
pub mod error;
pub mod experiments;
pub mod gateway;
pub mod metrics;
pub mod serve;
pub mod train;

pub use ablate::{Gates, Plan, PlanReport};
pub use config::{ModelConfig, OpConfig, RunConfig, ServeConfig, TrainConfig};
pub use error::Result;
pub use gateway::{Gateway, GatewayClient};
pub use serve::{ServeEngine, ServeSession, SubmitHandle};
pub use train::{TrainBatch, TrainEngine, TrainReport, TrainTarget};
