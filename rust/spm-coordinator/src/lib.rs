//! # spm-coordinator
//!
//! L3 of the three-layer stack: the experiment coordinator. Owns the
//! config system (the `[op]` LinearOp student config and the `[model]`
//! section building any network from the unified model zoo), metrics,
//! the native experiment drivers, and the deadline-batched serving
//! engine (`ServeEngine` over the `Executor` trait — DESIGN.md §13).
//! Fully dependency-free so the default workspace builds and tests
//! offline; the PJRT/XLA drivers and the `spm` CLI live in `spm-runtime`
//! (excluded from the default members) and call back into this crate so
//! every reported number has a single source of truth.

pub mod config;
pub mod error;
pub mod experiments;
pub mod metrics;
pub mod serve;

pub use config::{ModelConfig, OpConfig, RunConfig};
pub use error::Result;
