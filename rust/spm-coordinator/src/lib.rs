//! # spm-coordinator
//!
//! L3 of the three-layer stack: the experiment coordinator. Owns the
//! config system (including the `[op]` LinearOp student config), metrics,
//! the native experiment drivers, and the engine-agnostic batched-serving
//! router. Fully dependency-free so the default workspace builds and
//! tests offline; the PJRT/XLA drivers, checkpointing and the `spm` CLI
//! live in `spm-runtime` (excluded from the default members) and call
//! back into this crate so every reported number has a single source of
//! truth.

pub mod config;
pub mod error;
pub mod experiments;
pub mod metrics;
pub mod serve;

pub use config::{OpConfig, RunConfig};
pub use error::Result;
