//! The network front-end (DESIGN.md §16): a dependency-free TCP gateway
//! over the [`ServeSession`] — length-prefixed binary frames, blocking
//! I/O, one OS thread per connection. This is where the repro's serving
//! story leaves the process boundary: admission control, lane selection,
//! checkpoint hot-swap, and the stats counters are all reachable on the
//! wire, with zero protocol dependencies (the workspace ships no serde,
//! no tokio — a frame is a `u32` length plus bytes).
//!
//! # Wire frame layout
//!
//! Every message, both directions, is one frame:
//!
//! ```text
//! [len: u32 LE] [payload: len bytes]        len <= MAX_FRAME
//! ```
//!
//! A request payload is `[op: u8][body]`:
//!
//! | op | name        | body                                         |
//! |----|-------------|----------------------------------------------|
//! | 1  | infer (interactive lane) | `[deadline_us: u32 LE][features: f32 LE xW]` |
//! | 2  | infer (batch lane)       | same as op 1                       |
//! | 3  | hot-swap    | a complete `SPMCKPT1` checkpoint image        |
//! | 4  | stats       | empty                                         |
//!
//! `deadline_us == 0` means no deadline. A response payload is
//! `[status: u8][body]`:
//!
//! | status | meaning           | body                                  |
//! |--------|-------------------|---------------------------------------|
//! | 0      | ok                | op-specific (below)                   |
//! | 1      | shed: queue full  | empty                                 |
//! | 2      | shed: deadline    | empty                                 |
//! | 3      | engine down       | empty                                 |
//! | 4      | bad request       | utf-8 error message                   |
//!
//! An ok infer body is the output row (`f32 LE x d_out`); an ok
//! hot-swap body is `[replicas_notified: u64 LE]`; an ok stats body is
//! the eight [`SessionStats`] counters as `u64 LE` in declaration
//! order (replicas, in_flight, submitted, served, shed_queue,
//! shed_expired, failed, swaps_applied).
//!
//! Requests on one connection are served strictly in order (the
//! connection thread blocks on each reply); concurrency comes from
//! opening more connections, which is also how the closed-loop bench
//! models independent clients.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use spm_core::models::api::CkptData;

use crate::error::Result;
use crate::serve::{Lane, ServeReport, ServeSession, SessionStats, Shed, SubmitHandle};

/// Hard cap on one frame (requests AND responses): a 4 MiB frame holds a
/// ~1M-float checkpoint image, far past any model in the zoo, while a
/// garbage length prefix fails fast instead of allocating gigabytes.
pub const MAX_FRAME: usize = 4 << 20;

/// Request opcodes.
pub const OP_INFER_INTERACTIVE: u8 = 1;
pub const OP_INFER_BATCH: u8 = 2;
pub const OP_HOT_SWAP: u8 = 3;
pub const OP_STATS: u8 = 4;

/// Response status bytes.
pub const ST_OK: u8 = 0;
pub const ST_SHED_QUEUE: u8 = 1;
pub const ST_SHED_DEADLINE: u8 = 2;
pub const ST_ENGINE_DOWN: u8 = 3;
pub const ST_BAD_REQUEST: u8 = 4;

fn shed_status(s: Shed) -> u8 {
    match s {
        Shed::QueueFull => ST_SHED_QUEUE,
        Shed::DeadlineExpired => ST_SHED_DEADLINE,
        Shed::EngineDown => ST_ENGINE_DOWN,
    }
}

// ---------------------------------------------------------------------------
// Frame codec: shared by the server loop and the client.
// ---------------------------------------------------------------------------

/// Write one `[len][payload]` frame.
fn write_frame(stream: &mut TcpStream, payload: &[u8]) -> std::io::Result<()> {
    assert!(payload.len() <= MAX_FRAME, "frame too large to send");
    stream.write_all(&(payload.len() as u32).to_le_bytes())?;
    stream.write_all(payload)?;
    stream.flush()
}

/// Read exactly `buf.len()` bytes. A read-timeout wakeup polls `stop`
/// when one is given (the server loop) and is a hard error otherwise
/// (the client: a silent peer means the gateway is gone). Returns
/// `false` on a clean EOF at a frame boundary or a stop-flag exit.
fn read_full(
    stream: &mut TcpStream,
    buf: &mut [u8],
    stop: Option<&AtomicBool>,
) -> std::io::Result<bool> {
    let mut got = 0;
    while got < buf.len() {
        match stream.read(&mut buf[got..]) {
            Ok(0) => {
                if got == 0 {
                    return Ok(false);
                }
                return Err(std::io::Error::new(
                    std::io::ErrorKind::UnexpectedEof,
                    "connection closed mid-frame",
                ));
            }
            Ok(n) => got += n,
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                match stop {
                    Some(s) if !s.load(Ordering::SeqCst) => {}
                    Some(_) => return Ok(false),
                    None => return Err(e),
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return Err(e),
        }
    }
    Ok(true)
}

/// Read one frame; `Ok(None)` means clean EOF or a stop-flag exit.
fn read_frame(
    stream: &mut TcpStream,
    stop: Option<&AtomicBool>,
) -> std::io::Result<Option<Vec<u8>>> {
    let mut len4 = [0u8; 4];
    if !read_full(stream, &mut len4, stop)? {
        return Ok(None);
    }
    let len = u32::from_le_bytes(len4) as usize;
    if len > MAX_FRAME {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            format!("frame of {len} bytes exceeds the {MAX_FRAME}-byte cap"),
        ));
    }
    let mut payload = vec![0u8; len];
    if !read_full(stream, &mut payload, stop)? {
        return Err(std::io::Error::new(
            std::io::ErrorKind::UnexpectedEof,
            "connection closed mid-frame",
        ));
    }
    Ok(Some(payload))
}

fn f32s_to_bytes(vals: &[f32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(vals.len() * 4);
    for v in vals {
        out.extend_from_slice(&v.to_le_bytes());
    }
    out
}

fn bytes_to_f32s(bytes: &[u8]) -> Vec<f32> {
    bytes.chunks_exact(4).map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect()
}

fn bad_request(msg: &str) -> Vec<u8> {
    let mut payload = Vec::with_capacity(1 + msg.len());
    payload.push(ST_BAD_REQUEST);
    payload.extend_from_slice(msg.as_bytes());
    payload
}

// ---------------------------------------------------------------------------
// Server side.
// ---------------------------------------------------------------------------

/// Handle one request payload against the session. Every malformed input
/// becomes a `ST_BAD_REQUEST` response — a bad client never takes the
/// gateway down.
fn handle_request(payload: &[u8], handle: &SubmitHandle, session: &ServeSession) -> Vec<u8> {
    let Some((&op, body)) = payload.split_first() else {
        return bad_request("empty frame");
    };
    match op {
        OP_INFER_INTERACTIVE | OP_INFER_BATCH => {
            let lane = if op == OP_INFER_INTERACTIVE { Lane::Interactive } else { Lane::Batch };
            if body.len() < 4 {
                return bad_request("infer body shorter than its deadline header");
            }
            let deadline_us = u32::from_le_bytes([body[0], body[1], body[2], body[3]]);
            let feat_bytes = &body[4..];
            if feat_bytes.len() != handle.width() * 4 {
                return bad_request(&format!(
                    "expected {} feature floats, got {} bytes",
                    handle.width(),
                    feat_bytes.len()
                ));
            }
            let features = bytes_to_f32s(feat_bytes);
            let deadline = (deadline_us > 0).then(|| Duration::from_micros(deadline_us as u64));
            // the gateway is the untrusted edge: everything goes through
            // the admission-control path
            match handle.try_submit(lane, features, deadline) {
                Ok(pending) => match pending.wait() {
                    Ok(row) => {
                        let mut payload = Vec::with_capacity(1 + row.len() * 4);
                        payload.push(ST_OK);
                        payload.extend_from_slice(&f32s_to_bytes(&row));
                        payload
                    }
                    Err(shed) => vec![shed_status(shed)],
                },
                Err(shed) => vec![shed_status(shed)],
            }
        }
        OP_HOT_SWAP => match CkptData::from_bytes(body) {
            Ok(data) => match session.hot_swap(data) {
                Ok(notified) => {
                    let mut payload = Vec::with_capacity(9);
                    payload.push(ST_OK);
                    payload.extend_from_slice(&(notified as u64).to_le_bytes());
                    payload
                }
                Err(e) => bad_request(&e.to_string()),
            },
            Err(e) => bad_request(&format!("malformed checkpoint image: {e}")),
        },
        OP_STATS => {
            let s = session.stats();
            let mut payload = Vec::with_capacity(1 + 8 * 8);
            payload.push(ST_OK);
            for v in [
                s.replicas,
                s.in_flight,
                s.submitted,
                s.served,
                s.shed_queue,
                s.shed_expired,
                s.failed,
                s.swaps_applied,
            ] {
                payload.extend_from_slice(&(v as u64).to_le_bytes());
            }
            payload
        }
        other => bad_request(&format!("unknown opcode {other}")),
    }
}

fn serve_connection(
    mut stream: TcpStream,
    handle: SubmitHandle,
    session: Arc<ServeSession>,
    stop: Arc<AtomicBool>,
) {
    // short read timeout so the thread notices a gateway stop even on an
    // idle connection
    let _ = stream.set_read_timeout(Some(Duration::from_millis(50)));
    let _ = stream.set_nodelay(true);
    while !stop.load(Ordering::SeqCst) {
        let payload = match read_frame(&mut stream, Some(&stop)) {
            Ok(Some(p)) => p,
            Ok(None) => break,
            Err(_) => break,
        };
        let response = handle_request(&payload, &handle, &session);
        if write_frame(&mut stream, &response).is_err() {
            break;
        }
    }
}

/// The TCP front-end: owns the [`ServeSession`], accepts connections on
/// a loopback/LAN address, and serves the frame protocol until
/// [`Gateway::stop`] — which drains the engine and returns its
/// [`ServeReport`].
pub struct Gateway {
    session: Arc<ServeSession>,
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept: Option<std::thread::JoinHandle<()>>,
    conns: Arc<Mutex<Vec<std::thread::JoinHandle<()>>>>,
}

impl Gateway {
    /// Bind `addr` (e.g. `"127.0.0.1:0"` for an OS-assigned port) and
    /// start accepting. The session keeps serving in-process handles too;
    /// the gateway is just another producer.
    pub fn start(session: ServeSession, addr: &str) -> Result<Gateway> {
        let listener = TcpListener::bind(addr)
            .map_err(|e| crate::error::Error::from(format!("binding gateway to {addr}: {e}")))?;
        let local = listener.local_addr()?;
        let session = Arc::new(session);
        let stop = Arc::new(AtomicBool::new(false));
        let conns: Arc<Mutex<Vec<std::thread::JoinHandle<()>>>> =
            Arc::new(Mutex::new(Vec::new()));
        let accept = {
            let (session, stop, conns) = (session.clone(), stop.clone(), conns.clone());
            std::thread::spawn(move || {
                for stream in listener.incoming() {
                    if stop.load(Ordering::SeqCst) {
                        break;
                    }
                    let Ok(stream) = stream else { continue };
                    let handle = session.handle();
                    let (session, stop) = (session.clone(), stop.clone());
                    crate::serve::plock(&conns).push(std::thread::spawn(move || {
                        serve_connection(stream, handle, session, stop);
                    }));
                }
            })
        };
        Ok(Gateway { session, addr: local, stop, accept: Some(accept), conns })
    }

    /// The bound address (resolves `:0` to the real port).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// The live session, for in-process producers and counters.
    pub fn session(&self) -> &ServeSession {
        &self.session
    }

    /// Stop accepting, close every connection, drain the engine, report.
    pub fn stop(mut self) -> Result<ServeReport> {
        self.stop.store(true, Ordering::SeqCst);
        // unblock the accept loop with a throwaway self-connection
        let _ = TcpStream::connect(self.addr);
        if let Some(a) = self.accept.take() {
            let _ = a.join();
        }
        let conns = std::mem::take(&mut *crate::serve::plock(&self.conns));
        for c in conns {
            let _ = c.join();
        }
        let session = Arc::try_unwrap(self.session)
            .map_err(|_| crate::error::Error::from("gateway session still shared at stop".to_string()))?;
        session.shutdown()
    }
}

// ---------------------------------------------------------------------------
// Client side: the same codec, packaged for the bench and tests.
// ---------------------------------------------------------------------------

/// What a wire infer came back as.
#[derive(Debug, Clone, PartialEq)]
pub enum InferOutcome {
    Ok(Vec<f32>),
    Shed(Shed),
}

impl InferOutcome {
    pub fn is_ok(&self) -> bool {
        matches!(self, InferOutcome::Ok(_))
    }

    pub fn shed(&self) -> Option<Shed> {
        match self {
            InferOutcome::Ok(_) => None,
            InferOutcome::Shed(s) => Some(*s),
        }
    }
}

/// A blocking client for the gateway protocol: one connection, strictly
/// ordered request/reply. Open one per concurrent load-generator client.
pub struct GatewayClient {
    stream: TcpStream,
}

impl GatewayClient {
    pub fn connect(addr: SocketAddr) -> Result<GatewayClient> {
        let stream = TcpStream::connect(addr)
            .map_err(|e| crate::error::Error::from(format!("connecting to gateway {addr}: {e}")))?;
        let _ = stream.set_nodelay(true);
        // generous: a response must arrive or the peer is gone
        stream.set_read_timeout(Some(Duration::from_secs(30)))?;
        Ok(GatewayClient { stream })
    }

    fn roundtrip(&mut self, request: &[u8]) -> Result<Vec<u8>> {
        write_frame(&mut self.stream, request)?;
        match read_frame(&mut self.stream, None)? {
            Some(p) if !p.is_empty() => Ok(p),
            _ => crate::bail!("gateway closed the connection"),
        }
    }

    fn expect_ok<'a>(&self, payload: &'a [u8], what: &str) -> Result<&'a [u8]> {
        match payload[0] {
            ST_OK => Ok(&payload[1..]),
            ST_BAD_REQUEST => crate::bail!(
                "{what} rejected: {}",
                String::from_utf8_lossy(&payload[1..])
            ),
            other => crate::bail!("{what} failed with status {other}"),
        }
    }

    /// One inference round trip. Shed responses are an `Ok(Shed)`
    /// outcome, not an error — load shedding is the protocol working.
    pub fn infer(
        &mut self,
        lane: Lane,
        features: &[f32],
        deadline_us: u32,
    ) -> Result<InferOutcome> {
        let op = match lane {
            Lane::Interactive => OP_INFER_INTERACTIVE,
            Lane::Batch => OP_INFER_BATCH,
        };
        let mut req = Vec::with_capacity(5 + features.len() * 4);
        req.push(op);
        req.extend_from_slice(&deadline_us.to_le_bytes());
        req.extend_from_slice(&f32s_to_bytes(features));
        let resp = self.roundtrip(&req)?;
        match resp[0] {
            ST_OK => Ok(InferOutcome::Ok(bytes_to_f32s(&resp[1..]))),
            ST_SHED_QUEUE => Ok(InferOutcome::Shed(Shed::QueueFull)),
            ST_SHED_DEADLINE => Ok(InferOutcome::Shed(Shed::DeadlineExpired)),
            ST_ENGINE_DOWN => Ok(InferOutcome::Shed(Shed::EngineDown)),
            ST_BAD_REQUEST => crate::bail!(
                "infer rejected: {}",
                String::from_utf8_lossy(&resp[1..])
            ),
            other => crate::bail!("unknown response status {other}"),
        }
    }

    /// Push a checkpoint image through the wire hot-swap; returns how
    /// many replicas were notified.
    pub fn hot_swap(&mut self, ckpt_image: &[u8]) -> Result<usize> {
        let mut req = Vec::with_capacity(1 + ckpt_image.len());
        req.push(OP_HOT_SWAP);
        req.extend_from_slice(ckpt_image);
        let resp = self.roundtrip(&req)?;
        let body = self.expect_ok(&resp, "hot swap")?;
        if body.len() != 8 {
            crate::bail!("hot swap response body of {} bytes", body.len());
        }
        let mut b = [0u8; 8];
        b.copy_from_slice(body);
        Ok(u64::from_le_bytes(b) as usize)
    }

    /// Fetch the session counters.
    pub fn stats(&mut self) -> Result<SessionStats> {
        let resp = self.roundtrip(&[OP_STATS])?;
        let body = self.expect_ok(&resp, "stats")?;
        if body.len() != 8 * 8 {
            crate::bail!("stats response body of {} bytes", body.len());
        }
        let word = |i: usize| {
            let mut b = [0u8; 8];
            b.copy_from_slice(&body[i * 8..(i + 1) * 8]);
            u64::from_le_bytes(b) as usize
        };
        Ok(SessionStats {
            replicas: word(0),
            in_flight: word(1),
            submitted: word(2),
            served: word(3),
            shed_queue: word(4),
            shed_expired: word(5),
            failed: word(6),
            swaps_applied: word(7),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f32_codec_round_trips() {
        let vals = [0.0f32, -1.5, 3.25, f32::MAX, f32::MIN_POSITIVE];
        assert_eq!(bytes_to_f32s(&f32s_to_bytes(&vals)), vals);
    }

    #[test]
    fn frame_codec_round_trips_over_loopback() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let echo = std::thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            let p = read_frame(&mut s, None).unwrap().unwrap();
            write_frame(&mut s, &p).unwrap();
        });
        let mut c = TcpStream::connect(addr).unwrap();
        write_frame(&mut c, b"hello frames").unwrap();
        assert_eq!(read_frame(&mut c, None).unwrap().unwrap(), b"hello frames");
        echo.join().unwrap();
    }

    #[test]
    fn oversized_length_prefix_is_rejected_without_allocating() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server = std::thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            read_frame(&mut s, None)
        });
        let mut c = TcpStream::connect(addr).unwrap();
        c.write_all(&(u32::MAX).to_le_bytes()).unwrap();
        let err = server.join().unwrap().unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::InvalidData);
    }

    #[test]
    fn clean_eof_reads_as_none() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server = std::thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            read_frame(&mut s, None)
        });
        drop(TcpStream::connect(addr).unwrap());
        assert!(server.join().unwrap().unwrap().is_none());
    }

    #[test]
    fn mid_frame_eof_is_an_error() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server = std::thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            read_frame(&mut s, None)
        });
        let mut c = TcpStream::connect(addr).unwrap();
        c.write_all(&8u32.to_le_bytes()).unwrap();
        c.write_all(&[1, 2, 3]).unwrap(); // promise 8, deliver 3
        drop(c);
        let err = server.join().unwrap().unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::UnexpectedEof);
    }
}
