//! Serving: a batched request router in front of ANY forward executor
//! (the §7 "projection layers dominate serving cost" story).
//!
//! Client threads submit single-row requests through an mpsc channel; the
//! router (on the calling thread — PJRT clients are not Send) drains up
//! to the executor's batch size, pads the tail, runs one forward, and
//! fans the rows back out through per-request reply channels. Latency
//! percentiles and throughput are reported.
//!
//! The router core ([`serve_with`]) is engine-agnostic: [`serve_native`]
//! drives a `LinearOp` classifier with no PJRT anywhere, and
//! `spm-runtime::drivers::serve_demo` plugs in an AOT-compiled forward.
//!
//! Requests are split across clients by [`client_shares`], which spreads
//! the remainder of `num_requests / num_clients` over the first clients —
//! the old integer division silently dropped up to `num_clients - 1`
//! requests, under-reporting the requested load.

use std::sync::mpsc;
use std::time::{Duration, Instant};

use spm_core::models::mlp::Classifier;
use spm_core::rng::Rng;
use spm_core::tensor::Mat;

use crate::error::Result;

pub struct Request {
    pub features: Vec<f32>,
    pub reply: mpsc::Sender<Vec<f32>>,
    pub submitted: Instant,
}

#[derive(Debug, Clone)]
pub struct ServeReport {
    pub requests: usize,
    pub batches: usize,
    pub mean_batch_fill: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    pub throughput_rps: f64,
}

impl std::fmt::Display for ServeReport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        writeln!(f, "requests      : {}", self.requests)?;
        writeln!(f, "batches       : {} (mean fill {:.1})", self.batches, self.mean_batch_fill)?;
        writeln!(f, "latency p50   : {:.2} ms", self.p50_ms)?;
        writeln!(f, "latency p95   : {:.2} ms", self.p95_ms)?;
        writeln!(f, "latency p99   : {:.2} ms", self.p99_ms)?;
        write!(f, "throughput    : {:.0} req/s", self.throughput_rps)
    }
}

/// Shape of one serving run: executor batch/width + client workload.
#[derive(Clone, Copy, Debug)]
pub struct ServeSpec {
    /// executor batch size (tail batches are zero-padded up to this)
    pub batch: usize,
    /// feature width per request
    pub n: usize,
    pub num_requests: usize,
    pub num_clients: usize,
    pub seed: u64,
}

/// Split `num_requests` across `num_clients`, spreading the remainder over
/// the first clients so every request is issued (no silent drop).
pub fn client_shares(num_requests: usize, num_clients: usize) -> Vec<usize> {
    assert!(num_clients > 0, "need at least one client");
    let base = num_requests / num_clients;
    let rem = num_requests % num_clients;
    (0..num_clients).map(|c| base + usize::from(c < rem)).collect()
}

/// Run the batched serving loop against `forward`, which maps one padded
/// (batch * n) row-major feature buffer to (batch * out_width) outputs.
pub fn serve_with<F>(spec: &ServeSpec, mut forward: F) -> Result<ServeReport>
where
    F: FnMut(Vec<f32>) -> Result<Vec<f32>>,
{
    let ServeSpec { batch, n, num_requests, num_clients, seed } = *spec;
    let (tx, rx) = mpsc::channel::<Request>();
    // client threads: generate feature rows and wait for replies
    let handles: Vec<_> = client_shares(num_requests, num_clients)
        .into_iter()
        .enumerate()
        .map(|(c, per_client)| {
            let tx = tx.clone();
            std::thread::spawn(move || {
                let mut rng = Rng::new(seed ^ (c as u64 + 1) * 0xABCD);
                let mut latencies = Vec::with_capacity(per_client);
                for _ in 0..per_client {
                    let features = rng.normal_vec(n, 1.0);
                    let (rtx, rrx) = mpsc::channel();
                    let started = Instant::now();
                    tx.send(Request { features, reply: rtx, submitted: started })
                        .expect("router gone");
                    let _out = rrx.recv().expect("no reply");
                    latencies.push(started.elapsed().as_secs_f64() * 1e3);
                    // small jitter so batching has something to do
                    if c % 2 == 0 {
                        std::thread::sleep(Duration::from_micros(200));
                    }
                }
                latencies
            })
        })
        .collect();
    drop(tx);

    // router loop (executor thread)
    let t0 = Instant::now();
    let mut batches = 0usize;
    let mut served = 0usize;
    let mut fill_sum = 0usize;
    loop {
        // block for the first request, then drain greedily up to `batch`
        let first = match rx.recv() {
            Ok(r) => r,
            Err(_) => break,
        };
        let mut pending = vec![first];
        while pending.len() < batch {
            match rx.try_recv() {
                Ok(r) => pending.push(r),
                Err(_) => break,
            }
        }
        let fill = pending.len();
        let mut flat = vec![0.0f32; batch * n];
        for (i, r) in pending.iter().enumerate() {
            flat[i * n..(i + 1) * n].copy_from_slice(&r.features);
        }
        let out = forward(flat)?;
        let per_row = out.len() / batch.max(1);
        for (i, r) in pending.into_iter().enumerate() {
            let row = out[i * per_row..(i + 1) * per_row].to_vec();
            let _ = r.reply.send(row);
        }
        batches += 1;
        served += fill;
        fill_sum += fill;
    }
    let wall = t0.elapsed().as_secs_f64();

    let mut latencies: Vec<f64> = handles
        .into_iter()
        .flat_map(|h| h.join().expect("client panicked"))
        .collect();
    latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pct = |p: f64| -> f64 {
        if latencies.is_empty() {
            return 0.0;
        }
        let idx = ((latencies.len() as f64 - 1.0) * p).round() as usize;
        latencies[idx]
    };
    Ok(ServeReport {
        requests: served,
        batches,
        mean_batch_fill: fill_sum as f64 / batches.max(1) as f64,
        p50_ms: pct(0.50),
        p95_ms: pct(0.95),
        p99_ms: pct(0.99),
        throughput_rps: served as f64 / wall.max(1e-9),
    })
}

/// Serve a native `LinearOp` classifier — the same router with zero PJRT:
/// executor = `Classifier::logits` over the padded batch.
pub fn serve_native(
    clf: &Classifier,
    batch: usize,
    num_requests: usize,
    num_clients: usize,
    seed: u64,
) -> Result<ServeReport> {
    let n = clf.mixer.d_in();
    let spec = ServeSpec { batch, n, num_requests, num_clients, seed };
    serve_with(&spec, |flat| {
        let x = Mat::from_vec(batch, n, flat);
        Ok(clf.logits(&x).data)
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shares_cover_every_request() {
        for (reqs, clients) in [(96, 3), (97, 4), (100, 7), (5, 8), (0, 3), (1, 1)] {
            let shares = client_shares(reqs, clients);
            assert_eq!(shares.len(), clients);
            assert_eq!(shares.iter().sum::<usize>(), reqs, "{reqs}/{clients}");
            let (mn, mx) = (shares.iter().min().unwrap(), shares.iter().max().unwrap());
            assert!(mx - mn <= 1, "{reqs}/{clients}: uneven {shares:?}");
        }
    }

    #[test]
    fn remainder_goes_to_leading_clients() {
        assert_eq!(client_shares(97, 4), vec![25, 24, 24, 24]);
        assert_eq!(client_shares(10, 3), vec![4, 3, 3]);
    }

    #[test]
    fn serve_with_echo_executor_serves_all() {
        let spec = ServeSpec { batch: 4, n: 2, num_requests: 11, num_clients: 3, seed: 1 };
        let report = serve_with(&spec, |flat| Ok(flat)).unwrap();
        assert_eq!(report.requests, 11);
        assert!(report.batches >= 3); // 11 requests can't fit two 4-batches
        assert!(report.p99_ms >= report.p50_ms);
        assert!((report.mean_batch_fill - 11.0 / report.batches as f64).abs() < 1e-9);
    }
}
