//! Serving demo: a batched request router in front of a PJRT forward
//! executable (the §7 "projection layers dominate serving cost" story).
//!
//! Client threads submit single-row requests through an mpsc channel; the
//! router (on the engine thread — PJRT clients are not Send) drains up to
//! the artifact's batch size, pads the tail, runs one forward, and fans the
//! rows back out through per-request reply channels. Latency percentiles
//! and throughput are reported.

use std::sync::mpsc;
use std::time::{Duration, Instant};

use anyhow::Result;

use spm_core::rng::Rng;
use spm_runtime::{Engine, HostTensor, Manifest, TrainSession};

pub struct Request {
    pub features: Vec<f32>,
    pub reply: mpsc::Sender<Vec<f32>>,
    pub submitted: Instant,
}

#[derive(Debug, Clone)]
pub struct ServeReport {
    pub requests: usize,
    pub batches: usize,
    pub mean_batch_fill: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    pub throughput_rps: f64,
}

impl std::fmt::Display for ServeReport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        writeln!(f, "requests      : {}", self.requests)?;
        writeln!(f, "batches       : {} (mean fill {:.1})", self.batches, self.mean_batch_fill)?;
        writeln!(f, "latency p50   : {:.2} ms", self.p50_ms)?;
        writeln!(f, "latency p95   : {:.2} ms", self.p95_ms)?;
        writeln!(f, "latency p99   : {:.2} ms", self.p99_ms)?;
        write!(f, "throughput    : {:.0} req/s", self.throughput_rps)
    }
}

/// Run the serving demo against one manifest entry's `forward` artifact.
/// `entry_name` must be a classifier/teacher-style model taking (B, n) f32.
pub fn serve_demo(
    engine: &Engine,
    manifest: &Manifest,
    entry_name: &str,
    num_requests: usize,
    num_clients: usize,
    seed: u64,
) -> Result<ServeReport> {
    let mut sess = TrainSession::new(engine, manifest, entry_name, &["init", "forward"])?;
    sess.init(seed as i32)?;
    let batch = sess.entry.meta_usize("batch")?;
    let n = sess.entry.meta_usize("n")?;
    let out_width = {
        let art = sess.entry.artifact("forward")?;
        let shape = &art.outputs[0].shape;
        if shape.len() >= 2 { shape[1..].iter().product() } else { 1 }
    };

    let (tx, rx) = mpsc::channel::<Request>();
    // client threads: generate feature rows and wait for replies
    let per_client = num_requests / num_clients;
    let handles: Vec<_> = (0..num_clients)
        .map(|c| {
            let tx = tx.clone();
            std::thread::spawn(move || {
                let mut rng = Rng::new(seed ^ (c as u64 + 1) * 0xABCD);
                let mut latencies = Vec::with_capacity(per_client);
                for _ in 0..per_client {
                    let features = rng.normal_vec(n, 1.0);
                    let (rtx, rrx) = mpsc::channel();
                    let started = Instant::now();
                    tx.send(Request { features, reply: rtx, submitted: started })
                        .expect("router gone");
                    let _out = rrx.recv().expect("no reply");
                    latencies.push(started.elapsed().as_secs_f64() * 1e3);
                    // small jitter so batching has something to do
                    if c % 2 == 0 {
                        std::thread::sleep(Duration::from_micros(200));
                    }
                }
                latencies
            })
        })
        .collect();
    drop(tx);

    // router loop (engine thread)
    let t0 = Instant::now();
    let mut batches = 0usize;
    let mut served = 0usize;
    let mut fill_sum = 0usize;
    loop {
        // block for the first request, then drain greedily up to `batch`
        let first = match rx.recv() {
            Ok(r) => r,
            Err(_) => break,
        };
        let mut pending = vec![first];
        while pending.len() < batch {
            match rx.try_recv() {
                Ok(r) => pending.push(r),
                Err(_) => break,
            }
        }
        let fill = pending.len();
        let mut flat = vec![0.0f32; batch * n];
        for (i, r) in pending.iter().enumerate() {
            flat[i * n..(i + 1) * n].copy_from_slice(&r.features);
        }
        let out = if sess.entry.meta_str("model") == "teacher" {
            // teacher forward returns i32 labels
            sess.forward_i32(&HostTensor::F32(flat))?
                .into_iter()
                .map(|v| v as f32)
                .collect::<Vec<f32>>()
        } else {
            sess.forward(&HostTensor::F32(flat))?
        };
        let per_row = out.len() / batch.max(1);
        debug_assert!(per_row == out_width || per_row == 1);
        for (i, r) in pending.into_iter().enumerate() {
            let row = out[i * per_row..(i + 1) * per_row].to_vec();
            let _ = r.reply.send(row);
        }
        batches += 1;
        served += fill;
        fill_sum += fill;
    }
    let wall = t0.elapsed().as_secs_f64();

    let mut latencies: Vec<f64> = handles
        .into_iter()
        .flat_map(|h| h.join().expect("client panicked"))
        .collect();
    latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pct = |p: f64| -> f64 {
        if latencies.is_empty() {
            return 0.0;
        }
        let idx = ((latencies.len() as f64 - 1.0) * p).round() as usize;
        latencies[idx]
    };
    Ok(ServeReport {
        requests: served,
        batches,
        mean_batch_fill: fill_sum as f64 / batches.max(1) as f64,
        p50_ms: pct(0.50),
        p95_ms: pct(0.95),
        p99_ms: pct(0.99),
        throughput_rps: served as f64 / wall.max(1e-9),
    })
}
