//! The serving engine (DESIGN.md §13, §16): a deadline-batched request
//! router in front of N executor replicas — the §7 "projection layers
//! dominate serving cost" story, for EVERY model in the zoo.
//!
//! PR 7 turned the closed-batch `run(&Workload)` driver into a
//! long-lived **session**: [`ServeEngine::start`] moves the replicas
//! onto their own worker threads and returns a [`ServeSession`] whose
//! cloneable [`SubmitHandle`] feeds requests in from anywhere (the TCP
//! gateway, bench load generators, tests). `run(&Workload)` survives as
//! a thin wrapper over the session API.
//!
//! Request flow: a handle submits a single row into one of two
//! **lanes** — [`Lane::Interactive`] (short batching window, tight SLO)
//! or [`Lane::Batch`] (long window, throughput-oriented). `try_submit`
//! is the admission-control hook: it sheds [`Shed::QueueFull`] when the
//! lane's in-flight depth is at its configured cap and
//! [`Shed::DeadlineExpired`] when the request's deadline budget is
//! already spent; `submit` is the trusted path that only counts. The
//! router opens a micro-batch per lane at its first request and keeps
//! collecting until the batch is full OR the lane's wait has elapsed,
//! shedding queued requests whose deadline (or the engine-wide
//! `shed_deadline` budget) expired BEFORE dispatch. Batches go
//! round-robin to worker threads, one per [`Executor`] replica, and
//! ragged tails are forwarded at their TRUE fill.
//!
//! The worker pool is **elastic** when a spawner is configured: a
//! scaler thread watches the in-flight depth signal, hot-adds replicas
//! past `scale_up_depth`, and retires surplus ones after an idle
//! streak — the serving analogue of `TrainEngine` absorbing freed
//! cores. And checkpoints **hot-swap** without a restart:
//! [`ServeSession::hot_swap_file`] parses an `SPMCKPT1` image once,
//! validates kind/widths/arch-fingerprint against the live model, then
//! enqueues the swap on every worker's job queue — each replica applies
//! it *between* batches, so no in-flight request is ever dropped, and
//! batches dispatched after the call always see the new params.
//!
//! Replica workers split one core budget: each runs its forwards under
//! `parallel::with_thread_budget(floor(threads / R))`, with R the
//! elastic maximum, so replicas never fan out to R x
//! `available_parallelism()` between them.
//!
//! [`ServeEngine::native`] wraps any [`Model`] (mlp, gru, charlm,
//! attention) as an executor; [`ServeEngine::run_inline`] runs the same
//! loop single-replica on the calling thread for executors that are not
//! `Send` (PJRT clients must stay on the thread that built them — see
//! `spm-runtime::drivers::serve_demo`).
//!
//! The [`ServeReport`] splits request latency into queue wait (submit ->
//! forward start) and exec time (the forward itself), and accounts for
//! every submission: `submitted == requests + shed_queue + shed_expired
//! + failed` once a session has been shut down.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

use spm_core::models::api::{arch_fingerprint, CkptData, Model};
use spm_core::parallel;
use spm_core::rng::Rng;
use spm_core::tensor::Mat;

use crate::error::Result;
use crate::metrics::summarize;

/// Poison-recovering mutex lock for the serving threads (DESIGN.md §16):
/// a panicking holder poisons the mutex, but every guarded structure here
/// (job rosters, join handles, worker-done lists, the master sender) is
/// valid after any partial update, so waiters recover the guard instead
/// of propagating the panic and wedging the session.
pub(crate) fn plock<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

/// Default micro-batch cap for native executors.
pub const DEFAULT_BATCH: usize = 32;

/// Default deadline before a partial interactive batch is flushed.
pub const DEFAULT_MAX_WAIT_US: u64 = 200;

/// Default deadline before a partial batch-lane batch is flushed.
pub const DEFAULT_BATCH_WAIT_US: u64 = 2000;

/// Request class: which queue, which batching window, which SLO.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Lane {
    /// Latency-sensitive: short batching window, shed early.
    Interactive,
    /// Throughput-oriented: long batching window, deep queue.
    Batch,
}

impl Lane {
    pub const ALL: [Lane; 2] = [Lane::Interactive, Lane::Batch];

    fn idx(self) -> usize {
        match self {
            Lane::Interactive => 0,
            Lane::Batch => 1,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Lane::Interactive => "interactive",
            Lane::Batch => "batch",
        }
    }
}

/// Why a request was not served.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Shed {
    /// The lane's in-flight depth was at its cap at admission.
    QueueFull,
    /// The request's deadline (or the engine shed budget) expired before
    /// its batch was dispatched.
    DeadlineExpired,
    /// The engine failed or shut down before the request could be served.
    EngineDown,
}

impl std::fmt::Display for Shed {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Shed::QueueFull => write!(f, "queue full"),
            Shed::DeadlineExpired => write!(f, "deadline expired"),
            Shed::EngineDown => write!(f, "engine down"),
        }
    }
}

/// What a client gets back: the output row, or the shed reason.
pub type Reply = std::result::Result<Vec<f32>, Shed>;

pub struct Request {
    pub features: Vec<f32>,
    pub reply: mpsc::Sender<Reply>,
    pub submitted: Instant,
    pub deadline: Option<Instant>,
    pub lane: Lane,
}

/// One forward engine the router can dispatch micro-batches to.
pub trait Executor {
    /// Feature width of one request row.
    fn width(&self) -> usize;
    /// Hard cap on rows per `forward` call.
    fn max_batch(&self) -> usize;
    /// Forward `rows` filled rows (`1 <= rows <= max_batch()`,
    /// `flat.len() == rows * width()`); returns `rows * d_out` outputs.
    /// The buffer is owned (no copy on the hot path — a native executor
    /// wraps it straight into a `Mat`) and the router always passes the
    /// true fill: if the underlying engine needs a fixed shape, padding
    /// (and un-padding) is this executor's private business.
    fn forward(&mut self, rows: usize, flat: Vec<f32>) -> Result<Vec<f32>>;
    /// The live model, for executors that can hot-swap parameters in
    /// place (`None` — the default — opts out of checkpoint hot-swap).
    fn model_mut(&mut self) -> Option<&mut dyn Model> {
        None
    }
}

/// Any [`Model`] as an executor: one `Mat` forward per micro-batch, at
/// the batch's true row count.
pub struct NativeExecutor {
    model: Box<dyn Model>,
    max_batch: usize,
    // reusable output matrix: each forward writes here, then swaps its
    // buffer out for the spent request buffer (DESIGN.md §15) — the pair
    // ping-pongs with the router's batch pool so the steady state never
    // allocates
    y: Mat,
}

impl NativeExecutor {
    pub fn new(model: Box<dyn Model>, max_batch: usize) -> Self {
        assert!(max_batch >= 1, "max_batch must be >= 1");
        NativeExecutor { model, max_batch, y: Mat { rows: 0, cols: 0, data: Vec::new() } }
    }
}

impl Executor for NativeExecutor {
    fn width(&self) -> usize {
        self.model.d_in()
    }

    fn max_batch(&self) -> usize {
        self.max_batch
    }

    fn forward(&mut self, rows: usize, flat: Vec<f32>) -> Result<Vec<f32>> {
        let x = Mat::from_vec(rows, self.model.d_in(), flat);
        self.model.forward_into(&x, &mut self.y);
        // hand the result out and keep the request buffer as the next
        // call's output scratch (`forward_into` reshapes it)
        Ok(std::mem::replace(&mut self.y.data, x.data))
    }

    fn model_mut(&mut self) -> Option<&mut dyn Model> {
        Some(self.model.as_mut())
    }
}

/// Synthetic serving workload: how many requests, from how many
/// concurrent client threads, under which feature seed.
#[derive(Clone, Copy, Debug)]
pub struct Workload {
    pub num_requests: usize,
    pub num_clients: usize,
    pub seed: u64,
}

#[derive(Debug, Clone, Default)]
pub struct ServeReport {
    /// Requests actually served (rows forwarded through an executor).
    pub requests: usize,
    pub batches: usize,
    pub mean_batch_fill: f64,
    /// Mean submit -> forward-start time per request (batching delay +
    /// dispatch queueing).
    pub mean_queue_wait_ms: f64,
    /// Mean forward wall time per request (the whole micro-batch's exec
    /// attributed to each of its rows).
    pub mean_exec_ms: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    pub throughput_rps: f64,
    /// Batches each replica executed, in replica order (elastic replicas
    /// appear after the initial ones).
    pub replica_batches: Vec<usize>,
    /// Every submit/try_submit this session saw, served or not.
    pub submitted: usize,
    /// Requests shed at admission because the lane queue was full.
    pub shed_queue: usize,
    /// Requests shed because their deadline budget expired first.
    pub shed_expired: usize,
    /// Requests that hit a failed or shut-down engine.
    pub failed: usize,
    /// Replica param applications from checkpoint hot-swaps.
    pub swaps_applied: usize,
}

impl ServeReport {
    /// Total load-shed requests (admission + deadline).
    pub fn shed(&self) -> usize {
        self.shed_queue + self.shed_expired
    }

    /// Shed fraction of everything submitted.
    pub fn shed_rate(&self) -> f64 {
        self.shed() as f64 / self.submitted.max(1) as f64
    }
}

impl std::fmt::Display for ServeReport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        writeln!(f, "requests      : {}", self.requests)?;
        if self.shed() > 0 || self.failed > 0 {
            writeln!(
                f,
                "admission     : {} submitted, shed {} (queue {}, deadline {}), failed {}",
                self.submitted,
                self.shed(),
                self.shed_queue,
                self.shed_expired,
                self.failed
            )?;
        }
        writeln!(f, "batches       : {} (mean fill {:.1})", self.batches, self.mean_batch_fill)?;
        if self.replica_batches.len() > 1 {
            writeln!(f, "replicas      : {:?} batches", self.replica_batches)?;
        }
        if self.swaps_applied > 0 {
            writeln!(f, "hot swaps     : {} replica applications", self.swaps_applied)?;
        }
        writeln!(f, "queue wait    : {:.2} ms mean", self.mean_queue_wait_ms)?;
        writeln!(f, "exec          : {:.2} ms mean", self.mean_exec_ms)?;
        writeln!(f, "latency p50   : {:.2} ms", self.p50_ms)?;
        writeln!(f, "latency p95   : {:.2} ms", self.p95_ms)?;
        writeln!(f, "latency p99   : {:.2} ms", self.p99_ms)?;
        write!(f, "throughput    : {:.0} req/s", self.throughput_rps)
    }
}

/// Split `num_requests` across `num_clients`, spreading the remainder over
/// the first clients so every request is issued (no silent drop).
pub fn client_shares(num_requests: usize, num_clients: usize) -> Vec<usize> {
    assert!(num_clients > 0, "need at least one client");
    let base = num_requests / num_clients;
    let rem = num_requests % num_clients;
    (0..num_clients).map(|c| base + usize::from(c < rem)).collect()
}

// ---------------------------------------------------------------------------
// Admission accounting: one set of atomics shared by every handle, the
// router, and the workers. `depth` counts admitted-but-unreplied
// requests per lane — incremented when a handle admits, decremented in
// `finish_request` when the reply (served OR shed) goes out — so the
// queue-full check sees exactly the in-flight population and burst shed
// counts are deterministic under a pinned config.
// ---------------------------------------------------------------------------

#[derive(Default)]
struct Admission {
    depth: [AtomicUsize; 2],
    submitted: AtomicUsize,
    served: AtomicUsize,
    shed_queue: AtomicUsize,
    shed_expired: AtomicUsize,
    failed: AtomicUsize,
}

/// Send the terminal reply for `req` and settle its accounting. Every
/// admitted request funnels through here exactly once.
fn finish_request(adm: &Admission, req: Request, result: Reply) {
    adm.depth[req.lane.idx()].fetch_sub(1, Ordering::SeqCst);
    match &result {
        Ok(_) => adm.served.fetch_add(1, Ordering::SeqCst),
        Err(Shed::QueueFull) => adm.shed_queue.fetch_add(1, Ordering::SeqCst),
        Err(Shed::DeadlineExpired) => adm.shed_expired.fetch_add(1, Ordering::SeqCst),
        Err(Shed::EngineDown) => adm.failed.fetch_add(1, Ordering::SeqCst),
    };
    let _ = req.reply.send(result);
}

enum Msg {
    Req(Request),
    Shutdown,
}

/// A reply in flight: blocks on [`PendingReply::wait`] until the engine
/// serves or sheds the request.
pub struct PendingReply {
    rx: mpsc::Receiver<Reply>,
}

impl PendingReply {
    /// Block until the terminal reply. A session that died without
    /// replying reads as [`Shed::EngineDown`].
    pub fn wait(self) -> Reply {
        self.rx.recv().unwrap_or(Err(Shed::EngineDown))
    }
}

/// Cloneable submission side of a [`ServeSession`]. Cheap to clone; each
/// clone is an independent producer (one per client thread/connection).
#[derive(Clone)]
pub struct SubmitHandle {
    tx: mpsc::Sender<Msg>,
    width: usize,
    caps: [usize; 2],
    adm: Arc<Admission>,
}

impl SubmitHandle {
    /// Feature width every request row must have.
    pub fn width(&self) -> usize {
        self.width
    }

    /// Trusted interactive submit: counts toward depth but never sheds
    /// at admission (the `run(&Workload)` wrapper and tests use this).
    pub fn submit(&self, features: Vec<f32>) -> std::result::Result<PendingReply, Shed> {
        self.submit_to(Lane::Interactive, features, None)
    }

    /// Trusted submit into a specific lane with an optional deadline
    /// budget (relative to now). Skips the queue-depth and expiry checks;
    /// the router still sheds if the deadline passes before dispatch.
    pub fn submit_to(
        &self,
        lane: Lane,
        features: Vec<f32>,
        deadline: Option<Duration>,
    ) -> std::result::Result<PendingReply, Shed> {
        self.adm.submitted.fetch_add(1, Ordering::SeqCst);
        let now = Instant::now();
        self.adm.depth[lane.idx()].fetch_add(1, Ordering::SeqCst);
        self.send(lane, features, deadline.map(|d| now + d), now)
    }

    /// The admission-control hook: sheds [`Shed::QueueFull`] when the
    /// lane's in-flight depth is at its cap, [`Shed::DeadlineExpired`]
    /// when the budget is already spent — BEFORE the request costs the
    /// router anything. The gateway routes every wire request through
    /// here.
    pub fn try_submit(
        &self,
        lane: Lane,
        features: Vec<f32>,
        deadline: Option<Duration>,
    ) -> std::result::Result<PendingReply, Shed> {
        self.adm.submitted.fetch_add(1, Ordering::SeqCst);
        let now = Instant::now();
        let deadline = deadline.map(|d| now + d);
        if let Some(dl) = deadline {
            if dl <= Instant::now() {
                self.adm.shed_expired.fetch_add(1, Ordering::SeqCst);
                return Err(Shed::DeadlineExpired);
            }
        }
        let l = lane.idx();
        // reserve an in-flight slot, or shed: compare-exchange so two
        // racing submits can never both squeeze past the cap
        let mut cur = self.adm.depth[l].load(Ordering::SeqCst);
        loop {
            if cur >= self.caps[l] {
                self.adm.shed_queue.fetch_add(1, Ordering::SeqCst);
                return Err(Shed::QueueFull);
            }
            match self.adm.depth[l].compare_exchange(
                cur,
                cur + 1,
                Ordering::SeqCst,
                Ordering::SeqCst,
            ) {
                Ok(_) => break,
                Err(actual) => cur = actual,
            }
        }
        self.send(lane, features, deadline, now)
    }

    fn send(
        &self,
        lane: Lane,
        features: Vec<f32>,
        deadline: Option<Instant>,
        submitted: Instant,
    ) -> std::result::Result<PendingReply, Shed> {
        assert_eq!(features.len(), self.width, "request feature width");
        let (rtx, rrx) = mpsc::channel();
        let req = Request { features, reply: rtx, submitted, deadline, lane };
        if self.tx.send(Msg::Req(req)).is_err() {
            self.adm.depth[lane.idx()].fetch_sub(1, Ordering::SeqCst);
            self.adm.failed.fetch_add(1, Ordering::SeqCst);
            return Err(Shed::EngineDown);
        }
        Ok(PendingReply { rx: rrx })
    }
}

// ---------------------------------------------------------------------------
// Per-replica accounting + batch execution, accumulated where the
// forwards run.
// ---------------------------------------------------------------------------

#[derive(Default)]
struct ExecStats {
    batches: usize,
    rows: usize,
    queue_wait_ms: f64,
    exec_ms: f64,
    /// Served-request latencies (submit -> reply), ms.
    latencies: Vec<f64>,
    error: Option<crate::error::Error>,
}

/// Run one micro-batch through `exec` at its true fill and fan the rows
/// back out. On executor failure every row is shed as
/// [`Shed::EngineDown`] (clients unblock with the reason) and the error
/// is surfaced through the stats.
///
/// `pool` is the worker's reusable batch-assembly buffer (DESIGN.md §15):
/// it is moved into [`Executor::forward`] and refilled from the returned
/// output, so the steady state recycles capacity instead of allocating —
/// only the per-reply `to_vec` remains (each reply is owned by a client).
fn exec_batch(
    exec: &mut dyn Executor,
    pending: Vec<Request>,
    stats: &mut ExecStats,
    pool: &mut Vec<f32>,
    adm: &Admission,
) {
    let width = exec.width();
    let fill = pending.len();
    let mut flat = std::mem::take(pool);
    flat.clear();
    flat.resize(fill * width, 0.0);
    for (row, r) in flat.chunks_mut(width).zip(&pending) {
        assert_eq!(r.features.len(), width, "request feature width");
        row.copy_from_slice(&r.features);
    }
    let t0 = Instant::now();
    let out = match exec.forward(fill, flat) {
        Ok(out) => out,
        Err(e) => {
            stats.error = Some(e);
            for r in pending {
                finish_request(adm, r, Err(Shed::EngineDown));
            }
            return;
        }
    };
    let done = Instant::now();
    let exec_ms = done.duration_since(t0).as_secs_f64() * 1e3;
    let per_row = out.len() / fill.max(1);
    for (i, r) in pending.into_iter().enumerate() {
        stats.queue_wait_ms += t0.duration_since(r.submitted).as_secs_f64() * 1e3;
        stats.exec_ms += exec_ms;
        stats.latencies.push(done.duration_since(r.submitted).as_secs_f64() * 1e3);
        let row = out[i * per_row..(i + 1) * per_row].to_vec();
        finish_request(adm, r, Ok(row));
    }
    *pool = out;
    stats.batches += 1;
    stats.rows += fill;
}

// ---------------------------------------------------------------------------
// Worker pool: one thread per replica, fed through a job queue so the
// router, the hot-swap path, and the elastic scaler all speak the same
// ordered language — a swap enqueued before a batch is applied before
// that batch executes, and never in the middle of one.
// ---------------------------------------------------------------------------

enum Job {
    Batch(Vec<Request>),
    /// Apply a validated checkpoint between batches; bump the counter on
    /// success so the session can confirm full propagation.
    Swap(Arc<CkptData>, Arc<AtomicUsize>),
    /// Elastic scale-down: finish what is queued, then exit.
    Retire,
}

struct WorkerDone {
    index: usize,
    exec: Box<dyn Executor + Send>,
    stats: ExecStats,
}

/// Senders to the live workers, shared so the elastic scaler can grow
/// and shrink the pool while the router round-robins over it.
#[derive(Default)]
struct Pool {
    jobs: Mutex<Vec<mpsc::Sender<Job>>>,
}

fn spawn_worker(
    index: usize,
    mut exec: Box<dyn Executor + Send>,
    jrx: mpsc::Receiver<Job>,
    threads: usize,
    adm: Arc<Admission>,
    done: Arc<Mutex<Vec<WorkerDone>>>,
) -> std::thread::JoinHandle<()> {
    std::thread::spawn(move || {
        let stats = parallel::with_thread_budget(threads, || {
            let mut st = ExecStats::default();
            // per-worker batch buffer, recycled across batches
            let mut pool = Vec::new();
            while let Ok(job) = jrx.recv() {
                match job {
                    Job::Batch(pending) => {
                        if st.error.is_some() {
                            // a failed replica sheds instead of serving
                            // stale work; clients unblock with the reason
                            for r in pending {
                                finish_request(&adm, r, Err(Shed::EngineDown));
                            }
                            continue;
                        }
                        exec_batch(exec.as_mut(), pending, &mut st, &mut pool, &adm);
                    }
                    Job::Swap(data, applied) => {
                        if let Some(model) = exec.model_mut() {
                            match data.apply_to(model) {
                                Ok(()) => {
                                    applied.fetch_add(1, Ordering::SeqCst);
                                }
                                Err(e) => st.error = Some(e.into()),
                            }
                        }
                    }
                    Job::Retire => break,
                }
            }
            st
        });
        plock(&done).push(WorkerDone { index, exec, stats });
    })
}

// ---------------------------------------------------------------------------
// The lane-aware deadline router.
// ---------------------------------------------------------------------------

struct RouterCfg {
    batch: usize,
    waits: [Duration; 2],
    shed_deadline: Option<Duration>,
}

/// Has this queued request outlived its own deadline or the engine-wide
/// shed budget?
fn request_expired(r: &Request, now: Instant, shed_deadline: Option<Duration>) -> bool {
    if r.deadline.map_or(false, |dl| dl <= now) {
        return true;
    }
    shed_deadline.map_or(false, |budget| now.duration_since(r.submitted) > budget)
}

/// Close a lane's batching window: shed what expired while queued, then
/// dispatch the survivors as one micro-batch.
fn flush_lane(
    lane: usize,
    lanes: &mut [Vec<Request>; 2],
    deadlines: &mut [Option<Instant>; 2],
    shed_deadline: Option<Duration>,
    adm: &Admission,
    dispatch: &mut dyn FnMut(Vec<Request>),
) {
    deadlines[lane] = None;
    if lanes[lane].is_empty() {
        return;
    }
    let pending = std::mem::take(&mut lanes[lane]);
    let now = Instant::now();
    let mut live = Vec::with_capacity(pending.len());
    for r in pending {
        if request_expired(&r, now, shed_deadline) {
            finish_request(adm, r, Err(Shed::DeadlineExpired));
        } else {
            live.push(r);
        }
    }
    if !live.is_empty() {
        dispatch(live);
    }
}

/// Put one request into its lane's open micro-batch (opening the window
/// if it is the first), shedding up front if it is already expired.
fn admit_into(
    r: Request,
    cfg: &RouterCfg,
    lanes: &mut [Vec<Request>; 2],
    deadlines: &mut [Option<Instant>; 2],
    adm: &Admission,
    dispatch: &mut dyn FnMut(Vec<Request>),
) {
    let now = Instant::now();
    if request_expired(&r, now, cfg.shed_deadline) {
        finish_request(adm, r, Err(Shed::DeadlineExpired));
        return;
    }
    let l = r.lane.idx();
    if lanes[l].is_empty() && !cfg.waits[l].is_zero() {
        deadlines[l] = Some(now + cfg.waits[l]);
    }
    lanes[l].push(r);
    if lanes[l].len() >= cfg.batch {
        flush_lane(l, lanes, deadlines, cfg.shed_deadline, adm, dispatch);
    }
}

/// The deadline-batching core, one open micro-batch per lane: collect
/// until a lane's batch is full or its wait has elapsed since it opened
/// (wait 0 degenerates to greedy draining). Returns when a shutdown
/// sentinel arrives or every producer has hung up; either way the tail
/// is flushed — interactive first — so shutdown drains in-flight work.
fn route(
    rx: &mpsc::Receiver<Msg>,
    cfg: &RouterCfg,
    adm: &Admission,
    mut dispatch: impl FnMut(Vec<Request>),
) {
    let mut lanes: [Vec<Request>; 2] = [Vec::new(), Vec::new()];
    let mut deadlines: [Option<Instant>; 2] = [None, None];
    let mut shutdown = false;
    while !shutdown {
        let next_deadline = deadlines.iter().flatten().copied().min();
        let msg = match next_deadline {
            None => match rx.recv() {
                Ok(m) => Some(m),
                Err(_) => break,
            },
            Some(dl) => {
                let now = Instant::now();
                if dl <= now {
                    for l in 0..2 {
                        if deadlines[l].map_or(false, |d| d <= now) {
                            flush_lane(
                                l,
                                &mut lanes,
                                &mut deadlines,
                                cfg.shed_deadline,
                                adm,
                                &mut dispatch,
                            );
                        }
                    }
                    continue;
                }
                match rx.recv_timeout(dl - now) {
                    Ok(m) => Some(m),
                    // Timeout: a lane's window closed on a partial batch.
                    Err(mpsc::RecvTimeoutError::Timeout) => None,
                    Err(mpsc::RecvTimeoutError::Disconnected) => break,
                }
            }
        };
        match msg {
            Some(Msg::Req(r)) => {
                admit_into(r, cfg, &mut lanes, &mut deadlines, adm, &mut dispatch);
                // greedy lanes (wait 0): drain the backlog, then flush
                // whatever is already queued — the old router's behavior
                if (0..2).any(|l| cfg.waits[l].is_zero() && !lanes[l].is_empty()) {
                    loop {
                        match rx.try_recv() {
                            Ok(Msg::Req(r)) => {
                                admit_into(r, cfg, &mut lanes, &mut deadlines, adm, &mut dispatch);
                            }
                            Ok(Msg::Shutdown) => {
                                shutdown = true;
                                break;
                            }
                            Err(_) => break,
                        }
                    }
                    for l in 0..2 {
                        if cfg.waits[l].is_zero() {
                            flush_lane(
                                l,
                                &mut lanes,
                                &mut deadlines,
                                cfg.shed_deadline,
                                adm,
                                &mut dispatch,
                            );
                        }
                    }
                }
            }
            Some(Msg::Shutdown) => shutdown = true,
            None => {
                let now = Instant::now();
                for l in 0..2 {
                    if deadlines[l].map_or(false, |d| d <= now) {
                        flush_lane(
                            l,
                            &mut lanes,
                            &mut deadlines,
                            cfg.shed_deadline,
                            adm,
                            &mut dispatch,
                        );
                    }
                }
            }
        }
    }
    // drain the tail: everything submitted before shutdown still ships
    for l in 0..2 {
        flush_lane(l, &mut lanes, &mut deadlines, cfg.shed_deadline, adm, &mut dispatch);
    }
}

fn assemble(
    mut stats: Vec<ExecStats>,
    adm: &Admission,
    swaps_applied: usize,
    wall_secs: f64,
) -> (Result<ServeReport>, Vec<ExecStats>) {
    for st in stats.iter_mut() {
        if let Some(e) = st.error.take() {
            return (Err(e), stats);
        }
    }
    let mut latencies: Vec<f64> =
        stats.iter().flat_map(|s| s.latencies.iter().copied()).collect();
    let digest = summarize(&mut latencies);
    let served: usize = stats.iter().map(|s| s.rows).sum();
    let batches: usize = stats.iter().map(|s| s.batches).sum();
    let per_req = 1.0 / served.max(1) as f64;
    let report = ServeReport {
        requests: served,
        batches,
        mean_batch_fill: served as f64 / batches.max(1) as f64,
        mean_queue_wait_ms: stats.iter().map(|s| s.queue_wait_ms).sum::<f64>() * per_req,
        mean_exec_ms: stats.iter().map(|s| s.exec_ms).sum::<f64>() * per_req,
        p50_ms: digest.p50,
        p95_ms: digest.p95,
        p99_ms: digest.p99,
        throughput_rps: served as f64 / wall_secs.max(1e-9),
        replica_batches: stats.iter().map(|s| s.batches).collect(),
        submitted: adm.submitted.load(Ordering::SeqCst),
        shed_queue: adm.shed_queue.load(Ordering::SeqCst),
        shed_expired: adm.shed_expired.load(Ordering::SeqCst),
        failed: adm.failed.load(Ordering::SeqCst),
        swaps_applied,
    };
    (Ok(report), stats)
}

/// Spawn the synthetic client threads for `run(&Workload)`: each submits
/// its share of single-row requests through the handle and waits for
/// every reply (latencies are recorded engine-side at reply time).
fn spawn_clients(w: &Workload, handle: &SubmitHandle) -> Vec<std::thread::JoinHandle<()>> {
    client_shares(w.num_requests, w.num_clients)
        .into_iter()
        .enumerate()
        .map(|(c, per_client)| {
            let h = handle.clone();
            let seed = w.seed;
            std::thread::spawn(move || {
                let mut rng = Rng::new(seed ^ (c as u64 + 1).wrapping_mul(0xABCD));
                for _ in 0..per_client {
                    let features = rng.normal_vec(h.width(), 1.0);
                    match h.submit(features) {
                        Ok(pending) => {
                            let _ = pending.wait();
                        }
                        Err(_) => break,
                    }
                }
            })
        })
        .collect()
}

/// How a live session spawns a fresh replica (elastic scale-up). Gets
/// the new replica's index; must produce an executor with the same
/// feature width as the initial ones.
pub type Spawner = Box<dyn FnMut(usize) -> Box<dyn Executor + Send> + Send>;

/// The live model's identity, captured at session start so hot-swap can
/// validate a checkpoint ONCE before fanning it to the replicas.
struct ArchSnapshot {
    kind: String,
    d_in: usize,
    d_out: usize,
    arch: u64,
    bufs: Vec<(String, usize)>,
}

impl ArchSnapshot {
    fn of(model: &dyn Model) -> ArchSnapshot {
        let mut bufs = Vec::new();
        model.visit_params(&mut |n, p| bufs.push((n.to_string(), p.len())));
        ArchSnapshot {
            kind: model.kind().name().to_string(),
            d_in: model.d_in(),
            d_out: model.d_out(),
            arch: arch_fingerprint(model),
            bufs,
        }
    }

    fn check(&self, data: &CkptData) -> Result<()> {
        if data.kind != self.kind {
            crate::bail!("checkpoint holds a '{}' model but the session serves '{}'", data.kind, self.kind);
        }
        if (data.d_in, data.d_out) != (self.d_in, self.d_out) {
            crate::bail!(
                "checkpoint shape ({} -> {}) does not match the live model ({} -> {})",
                data.d_in,
                data.d_out,
                self.d_in,
                self.d_out
            );
        }
        if data.arch != self.arch {
            crate::bail!(
                "checkpoint arch fingerprint mismatch: the file binds its stage params to a \
                 different op config or pairing than the live model — refusing to swap"
            );
        }
        if data.bufs.len() != self.bufs.len() {
            crate::bail!(
                "checkpoint has {} buffers, live model has {}",
                data.bufs.len(),
                self.bufs.len()
            );
        }
        for ((name, vals), (want_name, want_len)) in data.bufs.iter().zip(&self.bufs) {
            if name != want_name || vals.len() != *want_len {
                crate::bail!(
                    "checkpoint buffer '{name}' ({}) does not line up with live '{want_name}' \
                     ({want_len})",
                    vals.len()
                );
            }
        }
        Ok(())
    }
}

/// Builder for a serving deployment: executor replicas, the batching and
/// admission policy, then either [`ServeEngine::start`] for a long-lived
/// session or [`ServeEngine::run`] against a closed [`Workload`].
pub struct ServeEngine {
    executors: Vec<Box<dyn Executor + Send>>,
    waits: [Duration; 2],
    max_batch: Option<usize>,
    threads: usize,
    queue_depth: [usize; 2],
    shed_deadline: Option<Duration>,
    elastic_max: usize,
    scale_up_depth: usize,
    scale_idle_polls: usize,
    scale_interval: Duration,
    spawner: Option<Spawner>,
}

impl Default for ServeEngine {
    fn default() -> Self {
        ServeEngine {
            executors: Vec::new(),
            waits: [
                Duration::from_micros(DEFAULT_MAX_WAIT_US),
                Duration::from_micros(DEFAULT_BATCH_WAIT_US),
            ],
            max_batch: None,
            threads: 0,
            queue_depth: [usize::MAX, usize::MAX],
            shed_deadline: None,
            elastic_max: 0,
            scale_up_depth: 0,
            scale_idle_polls: 50,
            scale_interval: Duration::from_millis(1),
            spawner: None,
        }
    }
}

impl ServeEngine {
    #[must_use]
    pub fn new() -> Self {
        Self::default()
    }

    /// One native replica serving `model` — works for every `ModelKind`
    /// (this replaces the old closure-bound `serve_native`).
    #[must_use]
    pub fn native(model: Box<dyn Model>) -> Self {
        Self::new().with_executor(Box::new(NativeExecutor::new(model, DEFAULT_BATCH)))
    }

    /// Add an executor replica. All replicas must agree on the feature
    /// width (they serve the same request stream).
    #[must_use]
    pub fn with_executor(mut self, exec: Box<dyn Executor + Send>) -> Self {
        if let Some(first) = self.executors.first() {
            assert_eq!(first.width(), exec.width(), "replica feature width");
        }
        self.executors.push(exec);
        self
    }

    /// Add another native replica (its own model copy, its own worker
    /// thread) — shard the request stream for multi-worker throughput.
    #[must_use]
    pub fn with_replica(self, model: Box<dyn Model>) -> Self {
        let batch = self.executors.first().map_or(DEFAULT_BATCH, |e| e.max_batch());
        self.with_executor(Box::new(NativeExecutor::new(model, batch)))
    }

    /// Interactive-lane deadline before a partial micro-batch is flushed
    /// (0 = greedy).
    #[must_use]
    pub fn with_max_wait_us(mut self, us: u64) -> Self {
        self.waits[Lane::Interactive.idx()] = Duration::from_micros(us);
        self
    }

    /// Batch-lane deadline before a partial micro-batch is flushed
    /// (0 = greedy). Defaults to [`DEFAULT_BATCH_WAIT_US`].
    #[must_use]
    pub fn with_batch_wait_us(mut self, us: u64) -> Self {
        self.waits[Lane::Batch.idx()] = Duration::from_micros(us);
        self
    }

    /// Cap the micro-batch size below the executors' own maximum.
    #[must_use]
    pub fn with_max_batch(mut self, batch: usize) -> Self {
        assert!(batch >= 1, "max_batch must be >= 1");
        self.max_batch = Some(batch);
        self
    }

    /// Total worker-thread budget the replicas split between them
    /// (0 = the global `parallel::num_threads()` setting). Each replica
    /// worker runs its forwards under `floor(budget / replicas)`
    /// threads, min 1 — without the split every replica's kernels
    /// default to `available_parallelism()` and R replicas contend for
    /// R x the machine.
    #[must_use]
    pub fn with_threads(mut self, threads: usize) -> Self {
        self.threads = threads;
        self
    }

    /// Cap a lane's in-flight depth: `try_submit` sheds
    /// [`Shed::QueueFull`] past it (0 = shed everything, the drain
    /// valve; the default is unbounded).
    #[must_use]
    pub fn with_queue_depth(mut self, lane: Lane, depth: usize) -> Self {
        self.queue_depth[lane.idx()] = depth;
        self
    }

    /// Engine-wide deadline budget: a queued request older than this is
    /// shed instead of dispatched (0 disables — the default).
    #[must_use]
    pub fn with_shed_deadline_us(mut self, us: u64) -> Self {
        self.shed_deadline = (us > 0).then(|| Duration::from_micros(us));
        self
    }

    /// How a live session builds a fresh replica for elastic scale-up.
    #[must_use]
    pub fn with_spawner(mut self, spawner: Spawner) -> Self {
        self.spawner = Some(spawner);
        self
    }

    /// Allow the session to grow the pool up to `max_replicas` against
    /// the queue-depth signal (requires [`ServeEngine::with_spawner`];
    /// the initial replica count is the floor it retires back to).
    #[must_use]
    pub fn with_elastic(mut self, max_replicas: usize) -> Self {
        self.elastic_max = max_replicas;
        self
    }

    /// Tune the elastic signal: scale up when in-flight depth exceeds
    /// `up_depth` (0 = auto: 2x the effective batch), retire one replica
    /// after `idle_polls` consecutive empty polls, polling every
    /// `interval_us` microseconds.
    #[must_use]
    pub fn with_scale_policy(mut self, up_depth: usize, idle_polls: usize, interval_us: u64) -> Self {
        self.scale_up_depth = up_depth;
        self.scale_idle_polls = idle_polls.max(1);
        self.scale_interval = Duration::from_micros(interval_us.max(1));
        self
    }

    fn effective_batch(&self) -> usize {
        let hw = self.executors.iter().map(|e| e.max_batch()).min().unwrap_or(1);
        self.max_batch.map_or(hw, |b| b.min(hw))
    }

    /// Start the long-lived session: workers spawn, the router thread
    /// starts batching, and the returned [`ServeSession`] hands out
    /// [`SubmitHandle`]s until [`ServeSession::shutdown`].
    pub fn start(mut self) -> Result<ServeSession> {
        if self.executors.is_empty() {
            crate::bail!("serve engine has no executors");
        }
        let width = self.executors[0].width();
        let initial = self.executors.len();
        // elastic scale-up needs a spawner; without one the pool is fixed
        let elastic_max = if self.spawner.is_some() { self.elastic_max.max(initial) } else { initial };
        let cfg = RouterCfg {
            batch: self.effective_batch(),
            waits: self.waits,
            shed_deadline: self.shed_deadline,
        };
        let up_depth = if self.scale_up_depth > 0 { self.scale_up_depth } else { 2 * cfg.batch };
        // partition the core budget by the elastic MAX so a scaled-up
        // pool never oversubscribes
        let budget = if self.threads > 0 { self.threads } else { parallel::num_threads() };
        let threads_per = (budget / elastic_max.max(1)).max(1);

        // hot-swap validates against the first swappable replica; pools
        // of swap-opaque executors simply reject hot_swap
        let arch = self.executors.iter_mut().find_map(|e| e.model_mut().map(|m| ArchSnapshot::of(&*m)));

        let adm = Arc::new(Admission::default());
        let pool = Arc::new(Pool::default());
        let done = Arc::new(Mutex::new(Vec::new()));
        let joins = Arc::new(Mutex::new(Vec::new()));
        let stop = Arc::new(AtomicBool::new(false));
        let swap: Arc<Mutex<Option<SwapState>>> = Arc::new(Mutex::new(None));
        let (tx, rx) = mpsc::channel::<Msg>();

        for (i, exec) in self.executors.drain(..).enumerate() {
            let (jtx, jrx) = mpsc::channel::<Job>();
            plock(&pool.jobs).push(jtx);
            plock(&joins)
                .push(spawn_worker(i, exec, jrx, threads_per, adm.clone(), done.clone()));
        }

        let router = {
            let pool = pool.clone();
            let adm = adm.clone();
            std::thread::spawn(move || {
                let mut next = 0usize;
                let dispatch = |pending: Vec<Request>| {
                    let jobs = plock(&pool.jobs);
                    if jobs.is_empty() {
                        for r in pending {
                            finish_request(&adm, r, Err(Shed::EngineDown));
                        }
                        return;
                    }
                    let i = next % jobs.len();
                    next = next.wrapping_add(1);
                    if let Err(mpsc::SendError(Job::Batch(pending))) =
                        jobs[i].send(Job::Batch(pending))
                    {
                        for r in pending {
                            finish_request(&adm, r, Err(Shed::EngineDown));
                        }
                    }
                };
                route(&rx, &cfg, &adm, dispatch);
                // hang up the worker queues: each drains what is already
                // enqueued, deposits its stats, and exits
                plock(&pool.jobs).clear();
            })
        };

        let scaler = if elastic_max > initial {
            let Some(mut spawner) = self.spawner.take() else {
                crate::bail!("elastic pool requires a spawner (with_spawner)");
            };
            let (pool, adm, done, joins, stop, swap) = (
                pool.clone(),
                adm.clone(),
                done.clone(),
                joins.clone(),
                stop.clone(),
                swap.clone(),
            );
            let (idle_polls, interval) = (self.scale_idle_polls, self.scale_interval);
            Some(std::thread::spawn(move || {
                let mut idle = 0usize;
                let mut next_index = initial;
                while !stop.load(Ordering::SeqCst) {
                    std::thread::sleep(interval);
                    if stop.load(Ordering::SeqCst) {
                        break;
                    }
                    let depth = adm.depth[0].load(Ordering::SeqCst)
                        + adm.depth[1].load(Ordering::SeqCst);
                    let active = plock(&pool.jobs).len();
                    if depth > up_depth && active < elastic_max {
                        let mut exec = spawner(next_index);
                        // a replica born after a hot-swap starts on the
                        // swapped params, not the spawner's init
                        if let Some(sw) = plock(&swap).as_ref() {
                            if let Some(m) = exec.model_mut() {
                                let _ = sw.data.apply_to(m);
                            }
                        }
                        let (jtx, jrx) = mpsc::channel::<Job>();
                        plock(&joins).push(spawn_worker(
                            next_index,
                            exec,
                            jrx,
                            threads_per,
                            adm.clone(),
                            done.clone(),
                        ));
                        plock(&pool.jobs).push(jtx);
                        next_index += 1;
                        idle = 0;
                    } else if depth == 0 && active > initial {
                        idle += 1;
                        if idle >= idle_polls {
                            // retire the most recently added replica
                            let retired = plock(&pool.jobs).pop();
                            if let Some(jtx) = retired {
                                let _ = jtx.send(Job::Retire);
                            }
                            idle = 0;
                        }
                    } else {
                        idle = 0;
                    }
                }
            }))
        } else {
            None
        };

        Ok(ServeSession {
            master: Mutex::new(tx),
            width,
            caps: self.queue_depth,
            adm,
            pool,
            done,
            joins,
            router: Some(router),
            scaler,
            stop,
            swap,
            arch,
            t0: Instant::now(),
        })
    }

    /// Drive a closed `workload` through the replicas: start a session,
    /// fan the synthetic clients over it, shut down, and give the
    /// executors back to the engine for the next run. (A spawner does
    /// not survive the round trip — elastic pools should use
    /// [`ServeEngine::start`] directly.)
    pub fn run(&mut self, workload: &Workload) -> Result<ServeReport> {
        if self.executors.is_empty() {
            crate::bail!("serve engine has no executors");
        }
        let engine = std::mem::take(self);
        // remember the policy knobs; the session returns the executors
        self.waits = engine.waits;
        self.max_batch = engine.max_batch;
        self.threads = engine.threads;
        self.queue_depth = engine.queue_depth;
        self.shed_deadline = engine.shed_deadline;
        self.elastic_max = engine.elastic_max;
        self.scale_up_depth = engine.scale_up_depth;
        self.scale_idle_polls = engine.scale_idle_polls;
        self.scale_interval = engine.scale_interval;
        let session = engine.start()?;
        let handle = session.handle();
        let mut client_panic = false;
        for c in spawn_clients(workload, &handle) {
            client_panic |= c.join().is_err();
        }
        drop(handle);
        let (report, executors) = session.finish();
        self.executors = executors;
        if client_panic {
            crate::bail!("serve client thread panicked");
        }
        report
    }

    /// The same deadline-batched loop with ONE executor on the calling
    /// thread — for executors that are not `Send` (PJRT clients must stay
    /// on the thread that built them). Forwards run inside the router, so
    /// a batch's queue wait includes the previous batch's exec time.
    pub fn run_inline(
        workload: &Workload,
        exec: &mut dyn Executor,
        max_wait_us: u64,
    ) -> Result<ServeReport> {
        let adm = Arc::new(Admission::default());
        let (tx, rx) = mpsc::channel::<Msg>();
        let handle = SubmitHandle {
            tx,
            width: exec.width(),
            caps: [usize::MAX, usize::MAX],
            adm: adm.clone(),
        };
        let clients = spawn_clients(workload, &handle);
        drop(handle);

        let t0 = Instant::now();
        let mut st = ExecStats::default();
        let mut pool = Vec::new();
        let cfg = RouterCfg {
            batch: exec.max_batch(),
            waits: [Duration::from_micros(max_wait_us); 2],
            shed_deadline: None,
        };
        route(&rx, &cfg, &adm, |pending| {
            if st.error.is_none() {
                exec_batch(exec, pending, &mut st, &mut pool, &adm);
            } else {
                for r in pending {
                    finish_request(&adm, r, Err(Shed::EngineDown));
                }
            }
        });
        let wall = t0.elapsed().as_secs_f64();

        let mut client_panic = false;
        for c in clients {
            client_panic |= c.join().is_err();
        }
        if client_panic {
            crate::bail!("serve client thread panicked");
        }
        assemble(vec![st], &adm, 0, wall).0
    }
}

struct SwapState {
    data: Arc<CkptData>,
    applied: Arc<AtomicUsize>,
}

/// Point-in-time counters for a live session (the gateway's `stats`
/// opcode serializes exactly this).
#[derive(Debug, Clone, Copy, Default)]
pub struct SessionStats {
    pub replicas: usize,
    pub in_flight: usize,
    pub submitted: usize,
    pub served: usize,
    pub shed_queue: usize,
    pub shed_expired: usize,
    pub failed: usize,
    pub swaps_applied: usize,
}

/// A live serving deployment: worker threads per replica, the router,
/// and (when configured) the elastic scaler. Hand out [`SubmitHandle`]s
/// with [`ServeSession::handle`]; finish with [`ServeSession::shutdown`],
/// which drains everything already submitted before reporting.
pub struct ServeSession {
    // mpsc senders are not Sync, so the master lives behind a lock and
    // every producer thread clones its own handle off it
    master: Mutex<mpsc::Sender<Msg>>,
    width: usize,
    caps: [usize; 2],
    adm: Arc<Admission>,
    pool: Arc<Pool>,
    done: Arc<Mutex<Vec<WorkerDone>>>,
    joins: Arc<Mutex<Vec<std::thread::JoinHandle<()>>>>,
    router: Option<std::thread::JoinHandle<()>>,
    scaler: Option<std::thread::JoinHandle<()>>,
    stop: Arc<AtomicBool>,
    swap: Arc<Mutex<Option<SwapState>>>,
    arch: Option<ArchSnapshot>,
    t0: Instant,
}

impl ServeSession {
    /// A fresh submission handle (cheap; clone freely per thread).
    pub fn handle(&self) -> SubmitHandle {
        SubmitHandle {
            tx: plock(&self.master).clone(),
            width: self.width,
            caps: self.caps,
            adm: self.adm.clone(),
        }
    }

    /// Feature width every request row must have.
    pub fn width(&self) -> usize {
        self.width
    }

    /// Live replicas (initial + elastic - retired).
    pub fn replica_count(&self) -> usize {
        plock(&self.pool.jobs).len()
    }

    /// Admitted-but-unreplied requests across both lanes — the elastic
    /// scaling signal.
    pub fn in_flight(&self) -> usize {
        self.adm.depth[0].load(Ordering::SeqCst) + self.adm.depth[1].load(Ordering::SeqCst)
    }

    /// Replica param applications from the most recent hot-swap.
    pub fn swaps_applied(&self) -> usize {
        plock(&self.swap).as_ref().map_or(0, |s| s.applied.load(Ordering::SeqCst))
    }

    /// Snapshot of the admission counters.
    pub fn stats(&self) -> SessionStats {
        SessionStats {
            replicas: self.replica_count(),
            in_flight: self.in_flight(),
            submitted: self.adm.submitted.load(Ordering::SeqCst),
            served: self.adm.served.load(Ordering::SeqCst),
            shed_queue: self.adm.shed_queue.load(Ordering::SeqCst),
            shed_expired: self.adm.shed_expired.load(Ordering::SeqCst),
            failed: self.adm.failed.load(Ordering::SeqCst),
            swaps_applied: self.swaps_applied(),
        }
    }

    /// Validate `data` against the live model, then enqueue the swap on
    /// every worker. Each replica applies it BETWEEN batches (never
    /// mid-forward), so no in-flight request is dropped; batches
    /// dispatched after this call execute on the new params. Returns how
    /// many replicas were notified; poll [`ServeSession::swaps_applied`]
    /// for confirmation.
    pub fn hot_swap(&self, data: CkptData) -> Result<usize> {
        let arch = match &self.arch {
            Some(a) => a,
            None => crate::bail!("no hot-swappable (native) replica in this session"),
        };
        arch.check(&data)?;
        let state =
            SwapState { data: Arc::new(data), applied: Arc::new(AtomicUsize::new(0)) };
        let (data, applied) = (state.data.clone(), state.applied.clone());
        // publish first so elastic replicas spawned from now on catch up
        *plock(&self.swap) = Some(state);
        let jobs = plock(&self.pool.jobs);
        for jtx in jobs.iter() {
            let _ = jtx.send(Job::Swap(data.clone(), applied.clone()));
        }
        Ok(jobs.len())
    }

    /// [`ServeSession::hot_swap`] from an `SPMCKPT1` file on disk — the
    /// watcher entry point: parse once, validate once, fan out.
    pub fn hot_swap_file(&self, path: impl AsRef<std::path::Path>) -> Result<usize> {
        let data = CkptData::load(path.as_ref()).map_err(|e| {
            crate::error::Error::from(format!(
                "loading checkpoint {}: {e}",
                path.as_ref().display()
            ))
        })?;
        self.hot_swap(data)
    }

    /// Stop accepting, drain everything already submitted, join every
    /// thread, and report.
    pub fn shutdown(self) -> Result<ServeReport> {
        self.finish().0
    }

    /// [`ServeSession::shutdown`], also handing the executors back (in
    /// replica-index order) so `run(&Workload)` can restore its engine.
    fn finish(mut self) -> (Result<ServeReport>, Vec<Box<dyn Executor + Send>>) {
        // the sentinel drains the router FIFO: everything submitted
        // before this call is batched (or shed by policy) first
        let _ = plock(&self.master).send(Msg::Shutdown);
        self.stop.store(true, Ordering::SeqCst);
        if let Some(r) = self.router.take() {
            let _ = r.join();
        }
        if let Some(s) = self.scaler.take() {
            let _ = s.join();
        }
        // a scaler mid-poll may have added a worker after the router
        // cleared the pool — hang up any straggler queue
        plock(&self.pool.jobs).clear();
        let joins = std::mem::take(&mut *plock(&self.joins));
        let mut worker_panic = false;
        for j in joins {
            worker_panic |= j.join().is_err();
        }
        let wall = self.t0.elapsed().as_secs_f64();
        let mut done = std::mem::take(&mut *plock(&self.done));
        done.sort_by_key(|d| d.index);
        let swaps = self.swaps_applied();
        let mut stats = Vec::with_capacity(done.len());
        let mut execs = Vec::with_capacity(done.len());
        for d in done {
            stats.push(d.stats);
            execs.push(d.exec);
        }
        let (report, _stats) = assemble(stats, &self.adm, swaps, wall);
        // a panicked worker forfeits its stats slot; surface that instead
        // of reporting a partial run as clean
        let report = if worker_panic {
            Err("serve worker thread panicked (partial stats discarded)".into())
        } else {
            report
        };
        (report, execs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Arc;

    use spm_core::models::api::{build_model, save_checkpoint, ModelCfg, ModelKind};
    use spm_core::ops::LinearCfg;
    use spm_core::pairing::Schedule;
    use spm_core::spm::Variant;

    /// Echoes its input rows back; counts what the engine forwarded so
    /// tests can assert on the TRUE fill contract.
    struct EchoExecutor {
        width: usize,
        max_batch: usize,
        rows_seen: Arc<AtomicUsize>,
        floats_seen: Arc<AtomicUsize>,
        max_fill_seen: Arc<AtomicUsize>,
    }

    impl EchoExecutor {
        fn new(width: usize, max_batch: usize) -> Self {
            EchoExecutor {
                width,
                max_batch,
                rows_seen: Arc::new(AtomicUsize::new(0)),
                floats_seen: Arc::new(AtomicUsize::new(0)),
                max_fill_seen: Arc::new(AtomicUsize::new(0)),
            }
        }
    }

    impl Executor for EchoExecutor {
        fn width(&self) -> usize {
            self.width
        }

        fn max_batch(&self) -> usize {
            self.max_batch
        }

        fn forward(&mut self, rows: usize, flat: Vec<f32>) -> Result<Vec<f32>> {
            assert_eq!(flat.len(), rows * self.width, "true-fill contract");
            assert!((1..=self.max_batch).contains(&rows), "fill {rows}");
            self.rows_seen.fetch_add(rows, Ordering::SeqCst);
            self.floats_seen.fetch_add(flat.len(), Ordering::SeqCst);
            self.max_fill_seen.fetch_max(rows, Ordering::SeqCst);
            Ok(flat)
        }
    }

    /// Echoes its rows back while recording the worker-thread budget
    /// (`parallel::num_threads()`) each forward observed.
    struct ThreadProbeExecutor {
        width: usize,
        seen: Arc<std::sync::Mutex<Vec<usize>>>,
    }

    impl Executor for ThreadProbeExecutor {
        fn width(&self) -> usize {
            self.width
        }

        fn max_batch(&self) -> usize {
            4
        }

        fn forward(&mut self, _rows: usize, flat: Vec<f32>) -> Result<Vec<f32>> {
            self.seen.lock().unwrap().push(parallel::num_threads());
            Ok(flat)
        }
    }

    struct SleepExecutor {
        width: usize,
        sleep: Duration,
    }

    impl Executor for SleepExecutor {
        fn width(&self) -> usize {
            self.width
        }

        fn max_batch(&self) -> usize {
            8
        }

        fn forward(&mut self, rows: usize, flat: Vec<f32>) -> Result<Vec<f32>> {
            std::thread::sleep(self.sleep);
            let _ = rows;
            Ok(flat)
        }
    }

    struct FailingExecutor;

    impl Executor for FailingExecutor {
        fn width(&self) -> usize {
            2
        }

        fn max_batch(&self) -> usize {
            4
        }

        fn forward(&mut self, _rows: usize, _flat: Vec<f32>) -> Result<Vec<f32>> {
            Err("forward exploded".into())
        }
    }

    /// Blocks every forward until `open` flips — pins the in-flight
    /// population so overload tests are deterministic.
    struct GateExecutor {
        width: usize,
        open: Arc<AtomicBool>,
        rows_seen: Arc<AtomicUsize>,
    }

    impl Executor for GateExecutor {
        fn width(&self) -> usize {
            self.width
        }

        fn max_batch(&self) -> usize {
            8
        }

        fn forward(&mut self, rows: usize, flat: Vec<f32>) -> Result<Vec<f32>> {
            while !self.open.load(Ordering::SeqCst) {
                std::thread::sleep(Duration::from_micros(200));
            }
            self.rows_seen.fetch_add(rows, Ordering::SeqCst);
            Ok(flat)
        }
    }

    fn wait_until(what: &str, mut cond: impl FnMut() -> bool) {
        let t0 = Instant::now();
        while !cond() {
            assert!(t0.elapsed() < Duration::from_secs(10), "timed out waiting for {what}");
            std::thread::sleep(Duration::from_millis(1));
        }
    }

    #[test]
    fn shares_cover_every_request() {
        for (reqs, clients) in [(96, 3), (97, 4), (100, 7), (5, 8), (0, 3), (1, 1)] {
            let shares = client_shares(reqs, clients);
            assert_eq!(shares.len(), clients);
            assert_eq!(shares.iter().sum::<usize>(), reqs, "{reqs}/{clients}");
            let (mn, mx) = (shares.iter().min().unwrap(), shares.iter().max().unwrap());
            assert!(mx - mn <= 1, "{reqs}/{clients}: uneven {shares:?}");
        }
    }

    #[test]
    fn remainder_goes_to_leading_clients() {
        assert_eq!(client_shares(97, 4), vec![25, 24, 24, 24]);
        assert_eq!(client_shares(10, 3), vec![4, 3, 3]);
    }

    #[test]
    fn engine_serves_every_request() {
        let mut engine = ServeEngine::new()
            .with_executor(Box::new(EchoExecutor::new(3, 4)))
            .with_max_wait_us(500);
        let report = engine.run(&Workload { num_requests: 11, num_clients: 3, seed: 1 }).unwrap();
        assert_eq!(report.requests, 11);
        assert_eq!(report.submitted, 11);
        assert_eq!(report.shed(), 0);
        assert!(report.batches >= 3, "11 requests cannot fit two 4-batches");
        assert!(report.p99_ms >= report.p50_ms);
        assert!(report.throughput_rps > 0.0);
        assert!((report.mean_batch_fill - 11.0 / report.batches as f64).abs() < 1e-9);
    }

    /// Satellite regression: exec cost must scale with the true fill. The
    /// old router forwarded a full zero-padded `batch * n` buffer even at
    /// fill 1; the engine must hand the executor exactly `requests * n`
    /// floats across the whole run, ragged tails included.
    #[test]
    fn ragged_fills_forward_only_filled_rows() {
        let exec = EchoExecutor::new(5, 4);
        let (rows, floats, max_fill) =
            (exec.rows_seen.clone(), exec.floats_seen.clone(), exec.max_fill_seen.clone());
        let mut engine = ServeEngine::new().with_executor(Box::new(exec));
        let report = engine.run(&Workload { num_requests: 11, num_clients: 2, seed: 3 }).unwrap();
        assert_eq!(report.requests, 11);
        assert_eq!(rows.load(Ordering::SeqCst), 11, "row count must equal requests");
        assert_eq!(
            floats.load(Ordering::SeqCst),
            11 * 5,
            "exec cost must scale with fill — no zero-padded rows"
        );
        assert!(max_fill.load(Ordering::SeqCst) <= 4);
        // 11 requests in 4-caps cannot come out even: some batch was ragged
        assert!(report.batches * 4 > 11, "sweep must include a ragged tail");
    }

    /// A lone in-flight request must be flushed when the deadline
    /// expires, not held hostage for a full batch.
    #[test]
    fn deadline_flushes_partial_batches() {
        let mut engine = ServeEngine::new()
            .with_executor(Box::new(EchoExecutor::new(2, 64)))
            .with_max_wait_us(20_000);
        let report = engine.run(&Workload { num_requests: 2, num_clients: 1, seed: 5 }).unwrap();
        // one synchronous client: each request waits out the 20ms window
        // alone, then flushes at fill 1
        assert_eq!(report.requests, 2);
        assert_eq!(report.batches, 2);
        assert!(report.p50_ms >= 15.0, "deadline flush came too early: {}", report.p50_ms);
        assert!(report.mean_queue_wait_ms >= 15.0, "{}", report.mean_queue_wait_ms);
    }

    /// With many concurrent clients inside one deadline window, the
    /// engine must aggregate — the greedy old router degraded to fill ~1
    /// whenever the queue momentarily emptied.
    #[test]
    fn deadline_window_aggregates_concurrent_requests() {
        let mut engine = ServeEngine::new()
            .with_executor(Box::new(EchoExecutor::new(2, 8)))
            .with_max_wait_us(30_000);
        let report = engine.run(&Workload { num_requests: 32, num_clients: 8, seed: 7 }).unwrap();
        assert_eq!(report.requests, 32);
        assert!(
            report.mean_batch_fill > 1.5,
            "deadline batching failed to aggregate: fill {}",
            report.mean_batch_fill
        );
        assert!(report.batches < 32);
    }

    /// Satellite regression (thread oversubscription): each of R replica
    /// workers must see `floor(budget / R)` kernel threads, not the whole
    /// machine — before the fix every replica's `for_each_chunk` defaulted
    /// to `available_parallelism()` and R replicas contended for R x the
    /// cores.
    #[test]
    fn replica_workers_split_the_thread_budget() {
        let seen = Arc::new(std::sync::Mutex::new(Vec::new()));
        let mut engine = ServeEngine::new()
            .with_executor(Box::new(ThreadProbeExecutor { width: 2, seen: seen.clone() }))
            .with_executor(Box::new(ThreadProbeExecutor { width: 2, seen: seen.clone() }))
            .with_threads(4)
            .with_max_wait_us(0);
        let report = engine.run(&Workload { num_requests: 8, num_clients: 2, seed: 21 }).unwrap();
        assert_eq!(report.requests, 8);
        let seen = seen.lock().unwrap();
        assert!(!seen.is_empty());
        assert!(
            seen.iter().all(|&t| t == 2),
            "2 replicas must split a 4-thread budget as 2 each, saw {seen:?}"
        );
    }

    #[test]
    fn single_replica_keeps_the_whole_budget() {
        let seen = Arc::new(std::sync::Mutex::new(Vec::new()));
        let mut engine = ServeEngine::new()
            .with_executor(Box::new(ThreadProbeExecutor { width: 2, seen: seen.clone() }))
            .with_threads(3);
        engine.run(&Workload { num_requests: 4, num_clients: 2, seed: 23 }).unwrap();
        let seen = seen.lock().unwrap();
        assert!(!seen.is_empty());
        assert!(seen.iter().all(|&t| t == 3), "lone replica keeps the budget, saw {seen:?}");
    }

    #[test]
    fn two_replicas_share_the_batches() {
        let mut engine = ServeEngine::new()
            .with_executor(Box::new(EchoExecutor::new(3, 2)))
            .with_executor(Box::new(EchoExecutor::new(3, 2)))
            .with_max_wait_us(0);
        let report = engine.run(&Workload { num_requests: 16, num_clients: 4, seed: 9 }).unwrap();
        assert_eq!(report.requests, 16);
        assert_eq!(report.replica_batches.len(), 2);
        assert_eq!(report.replica_batches.iter().sum::<usize>(), report.batches);
        assert!(
            report.replica_batches.iter().all(|&b| b > 0),
            "round-robin must reach both replicas: {:?}",
            report.replica_batches
        );
    }

    #[test]
    fn report_splits_queue_wait_from_exec_time() {
        let mut engine = ServeEngine::new()
            .with_executor(Box::new(SleepExecutor { width: 2, sleep: Duration::from_millis(5) }))
            .with_max_wait_us(10_000);
        let report = engine.run(&Workload { num_requests: 4, num_clients: 1, seed: 11 }).unwrap();
        assert_eq!(report.requests, 4);
        // one synchronous client: every batch waits out the 10ms window
        assert!(report.mean_queue_wait_ms >= 8.0, "{}", report.mean_queue_wait_ms);
        assert!(report.mean_exec_ms >= 4.0, "{}", report.mean_exec_ms);
        // the recorded latency covers both components: the max latency
        // dominates the mean of (queue + exec) by construction
        assert!(
            report.p99_ms + 0.5 >= report.mean_queue_wait_ms + report.mean_exec_ms,
            "p99 {} vs wait {} + exec {}",
            report.p99_ms,
            report.mean_queue_wait_ms,
            report.mean_exec_ms
        );
    }

    #[test]
    fn executor_error_propagates_without_hanging() {
        let mut engine = ServeEngine::new().with_executor(Box::new(FailingExecutor));
        let err = engine
            .run(&Workload { num_requests: 6, num_clients: 2, seed: 13 })
            .unwrap_err();
        assert!(err.to_string().contains("exploded"), "{err}");
    }

    #[test]
    fn run_inline_matches_the_engine_contract() {
        let mut exec = EchoExecutor::new(4, 8);
        let rows = exec.rows_seen.clone();
        let report = ServeEngine::run_inline(
            &Workload { num_requests: 10, num_clients: 3, seed: 15 },
            &mut exec,
            500,
        )
        .unwrap();
        assert_eq!(report.requests, 10);
        assert_eq!(rows.load(Ordering::SeqCst), 10);
        assert_eq!(report.replica_batches.len(), 1);
        assert!(report.p99_ms >= report.p50_ms);
    }

    #[test]
    fn empty_workload_reports_zeroes() {
        let mut engine = ServeEngine::new().with_executor(Box::new(EchoExecutor::new(2, 4)));
        let report = engine.run(&Workload { num_requests: 0, num_clients: 2, seed: 17 }).unwrap();
        assert_eq!(report.requests, 0);
        assert_eq!(report.batches, 0);
        assert_eq!(report.p99_ms, 0.0);
    }

    // -- session API ------------------------------------------------------

    #[test]
    fn session_serves_both_lanes_with_exact_accounting() {
        let exec = EchoExecutor::new(3, 4);
        let rows = exec.rows_seen.clone();
        let session = ServeEngine::new()
            .with_executor(Box::new(exec))
            .with_max_wait_us(0)
            .with_batch_wait_us(0)
            .start()
            .unwrap();
        let h = session.handle();
        let mut pending = Vec::new();
        for i in 0..4 {
            pending.push(h.submit(vec![i as f32, 0.0, 1.0]).unwrap());
        }
        for i in 0..2 {
            pending.push(h.submit_to(Lane::Batch, vec![i as f32, 5.0, 1.0], None).unwrap());
        }
        for p in pending {
            let out = p.wait().unwrap();
            assert_eq!(out.len(), 3);
        }
        assert_eq!(rows.load(Ordering::SeqCst), 6);
        let report = session.shutdown().unwrap();
        assert_eq!(report.requests, 6);
        assert_eq!(report.submitted, 6);
        assert_eq!(report.shed(), 0);
        assert_eq!(report.failed, 0);
    }

    #[test]
    fn zero_capacity_queue_sheds_immediately() {
        let exec = EchoExecutor::new(2, 4);
        let rows = exec.rows_seen.clone();
        let session = ServeEngine::new()
            .with_executor(Box::new(exec))
            .with_queue_depth(Lane::Interactive, 0)
            .start()
            .unwrap();
        let h = session.handle();
        for _ in 0..3 {
            assert_eq!(
                h.try_submit(Lane::Interactive, vec![1.0, 2.0], None).unwrap_err(),
                Shed::QueueFull
            );
        }
        // the trusted path bypasses the cap, so the engine still serves
        assert!(h.submit(vec![3.0, 4.0]).unwrap().wait().is_ok());
        let report = session.shutdown().unwrap();
        assert_eq!(report.shed_queue, 3);
        assert_eq!(report.requests, 1);
        assert_eq!(report.submitted, 4);
        assert_eq!(rows.load(Ordering::SeqCst), 1, "shed requests must never reach the executor");
    }

    #[test]
    fn expired_deadline_rejected_at_admission() {
        let exec = EchoExecutor::new(2, 4);
        let rows = exec.rows_seen.clone();
        let session = ServeEngine::new().with_executor(Box::new(exec)).start().unwrap();
        let h = session.handle();
        assert_eq!(
            h.try_submit(Lane::Interactive, vec![1.0, 2.0], Some(Duration::ZERO)).unwrap_err(),
            Shed::DeadlineExpired
        );
        let report = session.shutdown().unwrap();
        assert_eq!(report.shed_expired, 1);
        assert_eq!(rows.load(Ordering::SeqCst), 0);
    }

    /// A request whose deadline is spent by the time the router sees it
    /// must be shed BEFORE dispatch — the executor never sees the row and
    /// the client gets the reason, not a stale answer.
    #[test]
    fn expired_deadline_shed_before_dispatch() {
        let exec = EchoExecutor::new(2, 4);
        let rows = exec.rows_seen.clone();
        let session = ServeEngine::new()
            .with_executor(Box::new(exec))
            .with_max_wait_us(0)
            .start()
            .unwrap();
        let h = session.handle();
        // the trusted path skips the admission expiry check, so the
        // router is the first to see the dead deadline
        let pending = h.submit_to(Lane::Interactive, vec![1.0, 2.0], Some(Duration::ZERO)).unwrap();
        assert_eq!(pending.wait().unwrap_err(), Shed::DeadlineExpired);
        let report = session.shutdown().unwrap();
        assert_eq!(report.shed_expired, 1);
        assert_eq!(report.requests, 0);
        assert_eq!(rows.load(Ordering::SeqCst), 0, "expired request must never dispatch");
    }

    /// Burst overload: with the executor gated shut and a depth cap of 3,
    /// exactly 3 of 10 submits are admitted and exactly 7 shed — the
    /// count is deterministic because depth only falls at reply time.
    #[test]
    fn burst_overload_shed_count_is_deterministic() {
        let open = Arc::new(AtomicBool::new(false));
        let rows = Arc::new(AtomicUsize::new(0));
        let session = ServeEngine::new()
            .with_executor(Box::new(GateExecutor {
                width: 2,
                open: open.clone(),
                rows_seen: rows.clone(),
            }))
            .with_max_wait_us(0)
            .with_queue_depth(Lane::Interactive, 3)
            .start()
            .unwrap();
        let h = session.handle();
        let mut admitted = Vec::new();
        let mut shed = 0usize;
        for i in 0..10 {
            match h.try_submit(Lane::Interactive, vec![i as f32, 0.0], None) {
                Ok(p) => admitted.push(p),
                Err(Shed::QueueFull) => shed += 1,
                Err(other) => panic!("unexpected shed reason {other:?}"),
            }
        }
        assert_eq!(admitted.len(), 3);
        assert_eq!(shed, 7);
        open.store(true, Ordering::SeqCst);
        for p in admitted {
            assert!(p.wait().is_ok());
        }
        let report = session.shutdown().unwrap();
        assert_eq!(report.requests, 3);
        assert_eq!(report.shed_queue, 7);
        assert_eq!(report.submitted, 10);
    }

    #[test]
    fn shutdown_drains_in_flight_requests() {
        let open = Arc::new(AtomicBool::new(false));
        let rows = Arc::new(AtomicUsize::new(0));
        let session = ServeEngine::new()
            .with_executor(Box::new(GateExecutor {
                width: 2,
                open: open.clone(),
                rows_seen: rows.clone(),
            }))
            .with_max_wait_us(0)
            .start()
            .unwrap();
        let h = session.handle();
        let pending: Vec<_> = (0..5).map(|i| h.submit(vec![i as f32, 1.0]).unwrap()).collect();
        let opener = {
            let open = open.clone();
            std::thread::spawn(move || {
                std::thread::sleep(Duration::from_millis(30));
                open.store(true, Ordering::SeqCst);
            })
        };
        // shutdown must block until the gated batches drain, then report
        // every submitted request as served — zero drops
        let report = session.shutdown().unwrap();
        opener.join().unwrap();
        assert_eq!(report.requests, 5);
        assert_eq!(report.submitted, 5);
        assert_eq!(report.failed, 0);
        for p in pending {
            assert!(p.wait().is_ok(), "drained replies must reach their clients");
        }
        assert_eq!(rows.load(Ordering::SeqCst), 5);
    }

    // -- checkpoint hot-swap ----------------------------------------------

    fn mlp_cfg(seed: u64) -> ModelCfg {
        ModelCfg::new(ModelKind::Mlp, LinearCfg::spm(8, Variant::General))
            .with_classes(4)
            .with_seed(seed)
    }

    #[test]
    fn hot_swap_replaces_params_on_every_replica_without_drops() {
        let session = ServeEngine::new()
            .with_executor(Box::new(NativeExecutor::new(build_model(&mlp_cfg(7)), 8)))
            .with_replica(build_model(&mlp_cfg(7)))
            .with_max_wait_us(0)
            .start()
            .unwrap();
        let h = session.handle();
        let x: Vec<f32> = (0..8).map(|i| 0.1 * i as f32 - 0.3).collect();
        let before = h.submit(x.clone()).unwrap().wait().unwrap();

        // same arch (butterfly pairing is seed-independent), new params
        let src = build_model(&mlp_cfg(13));
        let path = std::env::temp_dir().join("spm_test_serve_hotswap.ckpt");
        save_checkpoint(src.as_ref(), &path).unwrap();
        let notified = session.hot_swap_file(&path).unwrap();
        assert_eq!(notified, 2);
        wait_until("both replicas to apply the swap", || session.swaps_applied() == 2);
        let _ = std::fs::remove_file(&path);

        let want = src.forward(&Mat::from_vec(1, 8, x.clone())).data;
        // hit both replicas (round-robin): every post-swap forward must
        // run on the NEW params, bit-identical to the source model
        for _ in 0..4 {
            let got = h.submit(x.clone()).unwrap().wait().unwrap();
            assert_eq!(got, want, "post-swap output must match the checkpoint source");
        }
        assert_ne!(before, want, "swap must actually change the params");
        let report = session.shutdown().unwrap();
        assert_eq!(report.swaps_applied, 2);
        assert_eq!(report.failed, 0);
        assert_eq!(report.requests, report.submitted, "hot swap must not drop a request");
    }

    #[test]
    fn hot_swap_rejects_fingerprint_mismatch_while_serving_continues() {
        // random-schedule pairings differ across op seeds: every buffer
        // shape matches, only the fingerprint catches the mismatch
        let cfg_a = ModelCfg::new(
            ModelKind::Mlp,
            LinearCfg::spm(8, Variant::General).with_schedule(Schedule::Random).with_seed(1),
        )
        .with_classes(4);
        let cfg_b = ModelCfg {
            op: LinearCfg::spm(8, Variant::General).with_schedule(Schedule::Random).with_seed(2),
            ..cfg_a
        };
        let session = ServeEngine::native(build_model(&cfg_a)).start().unwrap();
        let path = std::env::temp_dir().join("spm_test_serve_hotswap_bad.ckpt");
        save_checkpoint(build_model(&cfg_b).as_ref(), &path).unwrap();
        let err = session.hot_swap_file(&path).unwrap_err();
        assert!(err.to_string().contains("fingerprint"), "{err}");
        let _ = std::fs::remove_file(&path);
        assert_eq!(session.swaps_applied(), 0);
        // the rejected swap must not take the session down
        let h = session.handle();
        let x: Vec<f32> = (0..8).map(|i| i as f32 * 0.01).collect();
        assert!(h.submit(x).unwrap().wait().is_ok());
        let report = session.shutdown().unwrap();
        assert_eq!(report.failed, 0);
        assert_eq!(report.swaps_applied, 0);
    }

    // -- elastic scaling ---------------------------------------------------

    #[test]
    fn elastic_pool_grows_under_load_and_retires_when_idle() {
        let session = ServeEngine::new()
            .with_executor(Box::new(SleepExecutor { width: 2, sleep: Duration::from_millis(2) }))
            .with_max_wait_us(0)
            .with_threads(2)
            .with_spawner(Box::new(|_i| {
                Box::new(SleepExecutor { width: 2, sleep: Duration::from_millis(2) })
            }))
            .with_elastic(3)
            .with_scale_policy(2, 5, 500)
            .start()
            .unwrap();
        let h = session.handle();
        let pending: Vec<_> =
            (0..48).map(|i| h.submit(vec![i as f32, 1.0]).unwrap()).collect();
        wait_until("the queue-depth signal to add a replica", || session.replica_count() >= 2);
        for p in pending {
            assert!(p.wait().is_ok());
        }
        wait_until("idle streak to retire back to the floor", || session.replica_count() == 1);
        let report = session.shutdown().unwrap();
        assert_eq!(report.requests, 48);
        assert!(
            report.replica_batches.len() >= 2,
            "an elastic replica must have joined: {:?}",
            report.replica_batches
        );
        assert_eq!(report.failed, 0);
    }
}
