//! The serving engine (DESIGN.md §13): a deadline-batched request router
//! in front of N executor replicas — the §7 "projection layers dominate
//! serving cost" story, for EVERY model in the zoo.
//!
//! Client threads submit single-row requests through an mpsc channel.
//! The router opens a micro-batch at the first request and keeps
//! collecting until the batch is full OR `max_wait_us` has elapsed
//! (deadline flush — the old router flushed on an empty `try_recv`, so
//! under a trickle of traffic every batch had fill 1). Batches are
//! dispatched round-robin to worker threads, one per [`Executor`]
//! replica, and ragged tails are forwarded at their TRUE fill: the
//! native models take any row count down to the fused stage kernels, so
//! the router never zero-pads (executors that need fixed shapes — AOT
//! XLA executables — pad privately inside [`Executor::forward`]).
//!
//! Replica workers split one core budget: each runs its forwards under
//! `parallel::with_thread_budget(floor(threads / R))`, so R replicas
//! never fan out to R x `available_parallelism()` worker threads
//! between them (`ServeEngine::with_threads` overrides the global
//! budget they divide).
//!
//! [`ServeEngine::native`] wraps any [`Model`] (mlp, gru, charlm,
//! attention) as an executor; [`ServeEngine::run_inline`] runs the same
//! loop single-replica on the calling thread for executors that are not
//! `Send` (PJRT clients must stay on the thread that built them — see
//! `spm-runtime::drivers::serve_demo`).
//!
//! The [`ServeReport`] splits request latency into queue wait (submit ->
//! forward start) and exec time (the forward itself), on top of the
//! nearest-rank latency percentiles and throughput.
//!
//! Requests are split across clients by [`client_shares`], which spreads
//! the remainder of `num_requests / num_clients` over the first clients
//! so every request is issued (no silent drop).

use std::sync::mpsc;
use std::time::{Duration, Instant};

use spm_core::models::api::Model;
use spm_core::parallel;
use spm_core::rng::Rng;
use spm_core::tensor::Mat;

use crate::error::Result;
use crate::metrics::percentile;

/// Default micro-batch cap for native executors.
pub const DEFAULT_BATCH: usize = 32;

/// Default deadline before a partial batch is flushed.
pub const DEFAULT_MAX_WAIT_US: u64 = 200;

pub struct Request {
    pub features: Vec<f32>,
    pub reply: mpsc::Sender<Vec<f32>>,
    pub submitted: Instant,
}

/// One forward engine the router can dispatch micro-batches to.
pub trait Executor {
    /// Feature width of one request row.
    fn width(&self) -> usize;
    /// Hard cap on rows per `forward` call.
    fn max_batch(&self) -> usize;
    /// Forward `rows` filled rows (`1 <= rows <= max_batch()`,
    /// `flat.len() == rows * width()`); returns `rows * d_out` outputs.
    /// The buffer is owned (no copy on the hot path — a native executor
    /// wraps it straight into a `Mat`) and the router always passes the
    /// true fill: if the underlying engine needs a fixed shape, padding
    /// (and un-padding) is this executor's private business.
    fn forward(&mut self, rows: usize, flat: Vec<f32>) -> Result<Vec<f32>>;
}

/// Any [`Model`] as an executor: one `Mat` forward per micro-batch, at
/// the batch's true row count.
pub struct NativeExecutor {
    model: Box<dyn Model>,
    max_batch: usize,
    // reusable output matrix: each forward writes here, then swaps its
    // buffer out for the spent request buffer (DESIGN.md §15) — the pair
    // ping-pongs with the router's batch pool so the steady state never
    // allocates
    y: Mat,
}

impl NativeExecutor {
    pub fn new(model: Box<dyn Model>, max_batch: usize) -> Self {
        assert!(max_batch >= 1, "max_batch must be >= 1");
        NativeExecutor { model, max_batch, y: Mat { rows: 0, cols: 0, data: Vec::new() } }
    }
}

impl Executor for NativeExecutor {
    fn width(&self) -> usize {
        self.model.d_in()
    }

    fn max_batch(&self) -> usize {
        self.max_batch
    }

    fn forward(&mut self, rows: usize, flat: Vec<f32>) -> Result<Vec<f32>> {
        let x = Mat::from_vec(rows, self.model.d_in(), flat);
        self.model.forward_into(&x, &mut self.y);
        // hand the result out and keep the request buffer as the next
        // call's output scratch (`forward_into` reshapes it)
        Ok(std::mem::replace(&mut self.y.data, x.data))
    }
}

/// Synthetic serving workload: how many requests, from how many
/// concurrent client threads, under which feature seed.
#[derive(Clone, Copy, Debug)]
pub struct Workload {
    pub num_requests: usize,
    pub num_clients: usize,
    pub seed: u64,
}

#[derive(Debug, Clone, Default)]
pub struct ServeReport {
    pub requests: usize,
    pub batches: usize,
    pub mean_batch_fill: f64,
    /// Mean submit -> forward-start time per request (batching delay +
    /// dispatch queueing).
    pub mean_queue_wait_ms: f64,
    /// Mean forward wall time per request (the whole micro-batch's exec
    /// attributed to each of its rows).
    pub mean_exec_ms: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    pub throughput_rps: f64,
    /// Batches each replica executed, in replica order.
    pub replica_batches: Vec<usize>,
}

impl std::fmt::Display for ServeReport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        writeln!(f, "requests      : {}", self.requests)?;
        writeln!(f, "batches       : {} (mean fill {:.1})", self.batches, self.mean_batch_fill)?;
        if self.replica_batches.len() > 1 {
            writeln!(f, "replicas      : {:?} batches", self.replica_batches)?;
        }
        writeln!(f, "queue wait    : {:.2} ms mean", self.mean_queue_wait_ms)?;
        writeln!(f, "exec          : {:.2} ms mean", self.mean_exec_ms)?;
        writeln!(f, "latency p50   : {:.2} ms", self.p50_ms)?;
        writeln!(f, "latency p95   : {:.2} ms", self.p95_ms)?;
        writeln!(f, "latency p99   : {:.2} ms", self.p99_ms)?;
        write!(f, "throughput    : {:.0} req/s", self.throughput_rps)
    }
}

/// Split `num_requests` across `num_clients`, spreading the remainder over
/// the first clients so every request is issued (no silent drop).
pub fn client_shares(num_requests: usize, num_clients: usize) -> Vec<usize> {
    assert!(num_clients > 0, "need at least one client");
    let base = num_requests / num_clients;
    let rem = num_requests % num_clients;
    (0..num_clients).map(|c| base + usize::from(c < rem)).collect()
}

/// Per-replica accounting, accumulated where the forwards run.
#[derive(Default)]
struct ExecStats {
    batches: usize,
    rows: usize,
    queue_wait_ms: f64,
    exec_ms: f64,
    error: Option<crate::error::Error>,
}

/// Run one micro-batch through `exec` at its true fill and fan the rows
/// back out. On executor failure the replies are dropped, which unblocks
/// the waiting clients; the error is surfaced through the stats.
///
/// `pool` is the worker's reusable batch-assembly buffer (DESIGN.md §15):
/// it is moved into [`Executor::forward`] and refilled from the returned
/// output, so the steady state recycles capacity instead of allocating —
/// only the per-reply `to_vec` remains (each reply is owned by a client).
fn exec_batch(
    exec: &mut dyn Executor,
    pending: Vec<Request>,
    stats: &mut ExecStats,
    pool: &mut Vec<f32>,
) {
    let width = exec.width();
    let fill = pending.len();
    let mut flat = std::mem::take(pool);
    flat.clear();
    flat.resize(fill * width, 0.0);
    for (row, r) in flat.chunks_mut(width).zip(&pending) {
        assert_eq!(r.features.len(), width, "request feature width");
        row.copy_from_slice(&r.features);
    }
    let t0 = Instant::now();
    let out = match exec.forward(fill, flat) {
        Ok(out) => out,
        Err(e) => {
            stats.error = Some(e);
            return;
        }
    };
    let exec_ms = t0.elapsed().as_secs_f64() * 1e3;
    let per_row = out.len() / fill.max(1);
    for (i, r) in pending.into_iter().enumerate() {
        stats.queue_wait_ms += t0.duration_since(r.submitted).as_secs_f64() * 1e3;
        stats.exec_ms += exec_ms;
        let _ = r.reply.send(out[i * per_row..(i + 1) * per_row].to_vec());
    }
    *pool = out;
    stats.batches += 1;
    stats.rows += fill;
}

/// Spawn the synthetic client threads: each submits its share of
/// single-row requests, waits for every reply, and returns its observed
/// latencies (ms). A closed channel means the engine failed — the client
/// aborts quietly and the engine surfaces the executor error instead.
fn spawn_clients(
    w: &Workload,
    width: usize,
    tx: mpsc::Sender<Request>,
) -> Vec<std::thread::JoinHandle<Vec<f64>>> {
    let handles = client_shares(w.num_requests, w.num_clients)
        .into_iter()
        .enumerate()
        .map(|(c, per_client)| {
            let tx = tx.clone();
            let seed = w.seed;
            std::thread::spawn(move || {
                let mut rng = Rng::new(seed ^ (c as u64 + 1).wrapping_mul(0xABCD));
                let mut latencies = Vec::with_capacity(per_client);
                for _ in 0..per_client {
                    let features = rng.normal_vec(width, 1.0);
                    let (rtx, rrx) = mpsc::channel();
                    let started = Instant::now();
                    if tx.send(Request { features, reply: rtx, submitted: started }).is_err() {
                        break;
                    }
                    if rrx.recv().is_err() {
                        break;
                    }
                    latencies.push(started.elapsed().as_secs_f64() * 1e3);
                }
                latencies
            })
        })
        .collect();
    drop(tx);
    handles
}

/// The deadline-batching core: open a micro-batch at the first request,
/// then keep collecting until it is full or `max_wait` has elapsed since
/// it opened. `max_wait = 0` degenerates to greedy draining (flush
/// whatever is already queued). Returns when every client has hung up.
fn route(
    rx: &mpsc::Receiver<Request>,
    batch: usize,
    max_wait: Duration,
    mut dispatch: impl FnMut(Vec<Request>),
) {
    loop {
        let first = match rx.recv() {
            Ok(r) => r,
            Err(_) => break,
        };
        let mut pending = vec![first];
        if max_wait.is_zero() {
            while pending.len() < batch {
                match rx.try_recv() {
                    Ok(r) => pending.push(r),
                    Err(_) => break,
                }
            }
        } else {
            let deadline = Instant::now() + max_wait;
            while pending.len() < batch {
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                match rx.recv_timeout(deadline - now) {
                    Ok(r) => pending.push(r),
                    // Timeout: the deadline expired on a partial batch.
                    // Disconnected: the workload is over — flush the tail
                    // immediately instead of sleeping out the deadline.
                    Err(_) => break,
                }
            }
        }
        dispatch(pending);
    }
}

fn assemble(
    mut stats: Vec<ExecStats>,
    mut latencies: Vec<f64>,
    wall_secs: f64,
) -> Result<ServeReport> {
    for st in stats.iter_mut() {
        if let Some(e) = st.error.take() {
            return Err(e);
        }
    }
    latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let served: usize = stats.iter().map(|s| s.rows).sum();
    let batches: usize = stats.iter().map(|s| s.batches).sum();
    let per_req = 1.0 / served.max(1) as f64;
    Ok(ServeReport {
        requests: served,
        batches,
        mean_batch_fill: served as f64 / batches.max(1) as f64,
        mean_queue_wait_ms: stats.iter().map(|s| s.queue_wait_ms).sum::<f64>() * per_req,
        mean_exec_ms: stats.iter().map(|s| s.exec_ms).sum::<f64>() * per_req,
        p50_ms: percentile(&latencies, 0.50),
        p95_ms: percentile(&latencies, 0.95),
        p99_ms: percentile(&latencies, 0.99),
        throughput_rps: served as f64 / wall_secs.max(1e-9),
        replica_batches: stats.iter().map(|s| s.batches).collect(),
    })
}

/// Builder + driver for a serving run: executor replicas, the batching
/// policy, then [`ServeEngine::run`] against a [`Workload`].
pub struct ServeEngine {
    executors: Vec<Box<dyn Executor + Send>>,
    max_wait: Duration,
    max_batch: Option<usize>,
    threads: usize,
}

impl Default for ServeEngine {
    fn default() -> Self {
        ServeEngine {
            executors: Vec::new(),
            max_wait: Duration::from_micros(DEFAULT_MAX_WAIT_US),
            max_batch: None,
            threads: 0,
        }
    }
}

impl ServeEngine {
    pub fn new() -> Self {
        Self::default()
    }

    /// One native replica serving `model` — works for every `ModelKind`
    /// (this replaces the old closure-bound `serve_native`).
    pub fn native(model: Box<dyn Model>) -> Self {
        Self::new().with_executor(Box::new(NativeExecutor::new(model, DEFAULT_BATCH)))
    }

    /// Add an executor replica. All replicas must agree on the feature
    /// width (they serve the same request stream).
    pub fn with_executor(mut self, exec: Box<dyn Executor + Send>) -> Self {
        if let Some(first) = self.executors.first() {
            assert_eq!(first.width(), exec.width(), "replica feature width");
        }
        self.executors.push(exec);
        self
    }

    /// Add another native replica (its own model copy, its own worker
    /// thread) — shard the request stream for multi-worker throughput.
    pub fn with_replica(self, model: Box<dyn Model>) -> Self {
        let batch = self.executors.first().map_or(DEFAULT_BATCH, |e| e.max_batch());
        self.with_executor(Box::new(NativeExecutor::new(model, batch)))
    }

    /// Deadline before a partial micro-batch is flushed (0 = greedy).
    pub fn with_max_wait_us(mut self, us: u64) -> Self {
        self.max_wait = Duration::from_micros(us);
        self
    }

    /// Cap the micro-batch size below the executors' own maximum.
    pub fn with_max_batch(mut self, batch: usize) -> Self {
        assert!(batch >= 1, "max_batch must be >= 1");
        self.max_batch = Some(batch);
        self
    }

    /// Total worker-thread budget the replicas split between them
    /// (0 = the global `parallel::num_threads()` setting). Each replica
    /// worker runs its forwards under `floor(budget / replicas)`
    /// threads, min 1 — without the split every replica's kernels
    /// default to `available_parallelism()` and R replicas contend for
    /// R x the machine.
    pub fn with_threads(mut self, threads: usize) -> Self {
        self.threads = threads;
        self
    }

    fn effective_batch(&self) -> usize {
        let hw = self.executors.iter().map(|e| e.max_batch()).min().unwrap_or(1);
        self.max_batch.map_or(hw, |b| b.min(hw))
    }

    /// Worker threads each replica's kernels may use.
    fn threads_per_replica(&self) -> usize {
        let budget = if self.threads > 0 { self.threads } else { parallel::num_threads() };
        (budget / self.executors.len().max(1)).max(1)
    }

    /// Drive `workload` through the replicas: one worker thread per
    /// executor, deadline-batched dispatch round-robin across them.
    pub fn run(&mut self, workload: &Workload) -> Result<ServeReport> {
        if self.executors.is_empty() {
            crate::bail!("serve engine has no executors");
        }
        let width = self.executors[0].width();
        let batch = self.effective_batch();
        let max_wait = self.max_wait;
        // partition the core budget: R replicas at the full
        // `available_parallelism()` each would oversubscribe R-fold
        let threads_per_replica = self.threads_per_replica();

        let (tx, rx) = mpsc::channel::<Request>();
        let clients = spawn_clients(workload, width, tx);

        let t0 = Instant::now();
        let mut stats: Vec<ExecStats> = Vec::new();
        std::thread::scope(|s| {
            let mut jobs = Vec::new();
            let mut workers = Vec::new();
            for exec in self.executors.iter_mut() {
                let (jtx, jrx) = mpsc::channel::<Vec<Request>>();
                jobs.push(jtx);
                workers.push(s.spawn(move || {
                    parallel::with_thread_budget(threads_per_replica, || {
                        let mut st = ExecStats::default();
                        // per-worker batch buffer, recycled across batches
                        let mut pool = Vec::new();
                        while let Ok(pending) = jrx.recv() {
                            if st.error.is_some() {
                                // dropping the batch closes its reply
                                // channels, so clients unblock instead
                                // of hanging
                                continue;
                            }
                            exec_batch(exec.as_mut(), pending, &mut st, &mut pool);
                        }
                        st
                    })
                }));
            }
            let mut next = 0usize;
            route(&rx, batch, max_wait, |pending| {
                let _ = jobs[next].send(pending);
                next = (next + 1) % jobs.len();
            });
            drop(jobs);
            stats = workers.into_iter().map(|w| w.join().expect("serve worker panicked")).collect();
        });
        let wall = t0.elapsed().as_secs_f64();

        let latencies: Vec<f64> =
            clients.into_iter().flat_map(|h| h.join().expect("client panicked")).collect();
        assemble(stats, latencies, wall)
    }

    /// The same deadline-batched loop with ONE executor on the calling
    /// thread — for executors that are not `Send` (PJRT clients must stay
    /// on the thread that built them). Forwards run inside the router, so
    /// a batch's queue wait includes the previous batch's exec time.
    pub fn run_inline(
        workload: &Workload,
        exec: &mut dyn Executor,
        max_wait_us: u64,
    ) -> Result<ServeReport> {
        let width = exec.width();
        let batch = exec.max_batch();
        let (tx, rx) = mpsc::channel::<Request>();
        let clients = spawn_clients(workload, width, tx);

        let t0 = Instant::now();
        let mut st = ExecStats::default();
        let mut pool = Vec::new();
        route(&rx, batch, Duration::from_micros(max_wait_us), |pending| {
            if st.error.is_none() {
                exec_batch(exec, pending, &mut st, &mut pool);
            }
        });
        let wall = t0.elapsed().as_secs_f64();

        let latencies: Vec<f64> =
            clients.into_iter().flat_map(|h| h.join().expect("client panicked")).collect();
        assemble(vec![st], latencies, wall)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Arc;

    /// Echoes its input rows back; counts what the engine forwarded so
    /// tests can assert on the TRUE fill contract.
    struct EchoExecutor {
        width: usize,
        max_batch: usize,
        rows_seen: Arc<AtomicUsize>,
        floats_seen: Arc<AtomicUsize>,
        max_fill_seen: Arc<AtomicUsize>,
    }

    impl EchoExecutor {
        fn new(width: usize, max_batch: usize) -> Self {
            EchoExecutor {
                width,
                max_batch,
                rows_seen: Arc::new(AtomicUsize::new(0)),
                floats_seen: Arc::new(AtomicUsize::new(0)),
                max_fill_seen: Arc::new(AtomicUsize::new(0)),
            }
        }
    }

    impl Executor for EchoExecutor {
        fn width(&self) -> usize {
            self.width
        }

        fn max_batch(&self) -> usize {
            self.max_batch
        }

        fn forward(&mut self, rows: usize, flat: Vec<f32>) -> Result<Vec<f32>> {
            assert_eq!(flat.len(), rows * self.width, "true-fill contract");
            assert!((1..=self.max_batch).contains(&rows), "fill {rows}");
            self.rows_seen.fetch_add(rows, Ordering::SeqCst);
            self.floats_seen.fetch_add(flat.len(), Ordering::SeqCst);
            self.max_fill_seen.fetch_max(rows, Ordering::SeqCst);
            Ok(flat)
        }
    }

    /// Echoes its rows back while recording the worker-thread budget
    /// (`parallel::num_threads()`) each forward observed.
    struct ThreadProbeExecutor {
        width: usize,
        seen: Arc<std::sync::Mutex<Vec<usize>>>,
    }

    impl Executor for ThreadProbeExecutor {
        fn width(&self) -> usize {
            self.width
        }

        fn max_batch(&self) -> usize {
            4
        }

        fn forward(&mut self, _rows: usize, flat: Vec<f32>) -> Result<Vec<f32>> {
            self.seen.lock().unwrap().push(parallel::num_threads());
            Ok(flat)
        }
    }

    struct SleepExecutor {
        width: usize,
        sleep: Duration,
    }

    impl Executor for SleepExecutor {
        fn width(&self) -> usize {
            self.width
        }

        fn max_batch(&self) -> usize {
            8
        }

        fn forward(&mut self, rows: usize, flat: Vec<f32>) -> Result<Vec<f32>> {
            std::thread::sleep(self.sleep);
            let _ = rows;
            Ok(flat)
        }
    }

    struct FailingExecutor;

    impl Executor for FailingExecutor {
        fn width(&self) -> usize {
            2
        }

        fn max_batch(&self) -> usize {
            4
        }

        fn forward(&mut self, _rows: usize, _flat: Vec<f32>) -> Result<Vec<f32>> {
            Err("forward exploded".into())
        }
    }

    #[test]
    fn shares_cover_every_request() {
        for (reqs, clients) in [(96, 3), (97, 4), (100, 7), (5, 8), (0, 3), (1, 1)] {
            let shares = client_shares(reqs, clients);
            assert_eq!(shares.len(), clients);
            assert_eq!(shares.iter().sum::<usize>(), reqs, "{reqs}/{clients}");
            let (mn, mx) = (shares.iter().min().unwrap(), shares.iter().max().unwrap());
            assert!(mx - mn <= 1, "{reqs}/{clients}: uneven {shares:?}");
        }
    }

    #[test]
    fn remainder_goes_to_leading_clients() {
        assert_eq!(client_shares(97, 4), vec![25, 24, 24, 24]);
        assert_eq!(client_shares(10, 3), vec![4, 3, 3]);
    }

    #[test]
    fn engine_serves_every_request() {
        let mut engine = ServeEngine::new()
            .with_executor(Box::new(EchoExecutor::new(3, 4)))
            .with_max_wait_us(500);
        let report = engine.run(&Workload { num_requests: 11, num_clients: 3, seed: 1 }).unwrap();
        assert_eq!(report.requests, 11);
        assert!(report.batches >= 3, "11 requests cannot fit two 4-batches");
        assert!(report.p99_ms >= report.p50_ms);
        assert!(report.throughput_rps > 0.0);
        assert!((report.mean_batch_fill - 11.0 / report.batches as f64).abs() < 1e-9);
    }

    /// Satellite regression: exec cost must scale with the true fill. The
    /// old router forwarded a full zero-padded `batch * n` buffer even at
    /// fill 1; the engine must hand the executor exactly `requests * n`
    /// floats across the whole run, ragged tails included.
    #[test]
    fn ragged_fills_forward_only_filled_rows() {
        let exec = EchoExecutor::new(5, 4);
        let (rows, floats, max_fill) =
            (exec.rows_seen.clone(), exec.floats_seen.clone(), exec.max_fill_seen.clone());
        let mut engine = ServeEngine::new().with_executor(Box::new(exec));
        let report = engine.run(&Workload { num_requests: 11, num_clients: 2, seed: 3 }).unwrap();
        assert_eq!(report.requests, 11);
        assert_eq!(rows.load(Ordering::SeqCst), 11, "row count must equal requests");
        assert_eq!(
            floats.load(Ordering::SeqCst),
            11 * 5,
            "exec cost must scale with fill — no zero-padded rows"
        );
        assert!(max_fill.load(Ordering::SeqCst) <= 4);
        // 11 requests in 4-caps cannot come out even: some batch was ragged
        assert!(report.batches * 4 > 11, "sweep must include a ragged tail");
    }

    /// A lone in-flight request must be flushed when the deadline
    /// expires, not held hostage for a full batch.
    #[test]
    fn deadline_flushes_partial_batches() {
        let mut engine = ServeEngine::new()
            .with_executor(Box::new(EchoExecutor::new(2, 64)))
            .with_max_wait_us(20_000);
        let report = engine.run(&Workload { num_requests: 2, num_clients: 1, seed: 5 }).unwrap();
        // one synchronous client: each request waits out the 20ms window
        // alone, then flushes at fill 1
        assert_eq!(report.requests, 2);
        assert_eq!(report.batches, 2);
        assert!(report.p50_ms >= 15.0, "deadline flush came too early: {}", report.p50_ms);
        assert!(report.mean_queue_wait_ms >= 15.0, "{}", report.mean_queue_wait_ms);
    }

    /// With many concurrent clients inside one deadline window, the
    /// engine must aggregate — the greedy old router degraded to fill ~1
    /// whenever the queue momentarily emptied.
    #[test]
    fn deadline_window_aggregates_concurrent_requests() {
        let mut engine = ServeEngine::new()
            .with_executor(Box::new(EchoExecutor::new(2, 8)))
            .with_max_wait_us(30_000);
        let report = engine.run(&Workload { num_requests: 32, num_clients: 8, seed: 7 }).unwrap();
        assert_eq!(report.requests, 32);
        assert!(
            report.mean_batch_fill > 1.5,
            "deadline batching failed to aggregate: fill {}",
            report.mean_batch_fill
        );
        assert!(report.batches < 32);
    }

    /// Satellite regression (thread oversubscription): each of R replica
    /// workers must see `floor(budget / R)` kernel threads, not the whole
    /// machine — before the fix every replica's `for_each_chunk` defaulted
    /// to `available_parallelism()` and R replicas contended for R x the
    /// cores.
    #[test]
    fn replica_workers_split_the_thread_budget() {
        let seen = Arc::new(std::sync::Mutex::new(Vec::new()));
        let mut engine = ServeEngine::new()
            .with_executor(Box::new(ThreadProbeExecutor { width: 2, seen: seen.clone() }))
            .with_executor(Box::new(ThreadProbeExecutor { width: 2, seen: seen.clone() }))
            .with_threads(4)
            .with_max_wait_us(0);
        let report = engine.run(&Workload { num_requests: 8, num_clients: 2, seed: 21 }).unwrap();
        assert_eq!(report.requests, 8);
        let seen = seen.lock().unwrap();
        assert!(!seen.is_empty());
        assert!(
            seen.iter().all(|&t| t == 2),
            "2 replicas must split a 4-thread budget as 2 each, saw {seen:?}"
        );
    }

    #[test]
    fn single_replica_keeps_the_whole_budget() {
        let seen = Arc::new(std::sync::Mutex::new(Vec::new()));
        let mut engine = ServeEngine::new()
            .with_executor(Box::new(ThreadProbeExecutor { width: 2, seen: seen.clone() }))
            .with_threads(3);
        engine.run(&Workload { num_requests: 4, num_clients: 2, seed: 23 }).unwrap();
        let seen = seen.lock().unwrap();
        assert!(!seen.is_empty());
        assert!(seen.iter().all(|&t| t == 3), "lone replica keeps the budget, saw {seen:?}");
    }

    #[test]
    fn two_replicas_share_the_batches() {
        let mut engine = ServeEngine::new()
            .with_executor(Box::new(EchoExecutor::new(3, 2)))
            .with_executor(Box::new(EchoExecutor::new(3, 2)))
            .with_max_wait_us(0);
        let report = engine.run(&Workload { num_requests: 16, num_clients: 4, seed: 9 }).unwrap();
        assert_eq!(report.requests, 16);
        assert_eq!(report.replica_batches.len(), 2);
        assert_eq!(report.replica_batches.iter().sum::<usize>(), report.batches);
        assert!(
            report.replica_batches.iter().all(|&b| b > 0),
            "round-robin must reach both replicas: {:?}",
            report.replica_batches
        );
    }

    #[test]
    fn report_splits_queue_wait_from_exec_time() {
        let mut engine = ServeEngine::new()
            .with_executor(Box::new(SleepExecutor { width: 2, sleep: Duration::from_millis(5) }))
            .with_max_wait_us(10_000);
        let report = engine.run(&Workload { num_requests: 4, num_clients: 1, seed: 11 }).unwrap();
        assert_eq!(report.requests, 4);
        // one synchronous client: every batch waits out the 10ms window
        assert!(report.mean_queue_wait_ms >= 8.0, "{}", report.mean_queue_wait_ms);
        assert!(report.mean_exec_ms >= 4.0, "{}", report.mean_exec_ms);
        // the client-observed latency covers both components: the max
        // latency dominates the mean of (queue + exec) by construction
        assert!(
            report.p99_ms + 0.5 >= report.mean_queue_wait_ms + report.mean_exec_ms,
            "p99 {} vs wait {} + exec {}",
            report.p99_ms,
            report.mean_queue_wait_ms,
            report.mean_exec_ms
        );
    }

    #[test]
    fn executor_error_propagates_without_hanging() {
        let mut engine = ServeEngine::new().with_executor(Box::new(FailingExecutor));
        let err = engine
            .run(&Workload { num_requests: 6, num_clients: 2, seed: 13 })
            .unwrap_err();
        assert!(err.to_string().contains("exploded"), "{err}");
    }

    #[test]
    fn run_inline_matches_the_engine_contract() {
        let mut exec = EchoExecutor::new(4, 8);
        let rows = exec.rows_seen.clone();
        let report = ServeEngine::run_inline(
            &Workload { num_requests: 10, num_clients: 3, seed: 15 },
            &mut exec,
            500,
        )
        .unwrap();
        assert_eq!(report.requests, 10);
        assert_eq!(rows.load(Ordering::SeqCst), 10);
        assert_eq!(report.replica_batches.len(), 1);
        assert!(report.p99_ms >= report.p50_ms);
    }

    #[test]
    fn empty_workload_reports_zeroes() {
        let mut engine = ServeEngine::new().with_executor(Box::new(EchoExecutor::new(2, 4)));
        let report = engine.run(&Workload { num_requests: 0, num_clients: 2, seed: 17 }).unwrap();
        assert_eq!(report.requests, 0);
        assert_eq!(report.batches, 0);
        assert_eq!(report.p99_ms, 0.0);
    }
}
