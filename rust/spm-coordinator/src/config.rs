//! Experiment configuration: a TOML-subset parser (toml is not in the
//! offline vendor set) + typed run configs with file/CLI overrides.
//!
//! Supported TOML subset — exactly what experiment configs need:
//! `[section]` headers, `key = value` with string/int/float/bool values,
//! single-line `[a, b, c]` arrays of those scalars (no commas inside
//! quoted elements), `#` comments, blank lines.
//!
//! The `[op]` section configures the student's planned `LinearOp` (kind,
//! variant, pairing schedule, stage depth); [`OpConfig::to_linear_cfg`]
//! lowers it to a `spm_core::ops::LinearCfg` at any width.
//!
//! The `[model]` section picks a network from the unified model zoo
//! (DESIGN.md §13): [`ModelConfig::build`] lowers it (together with the
//! `[op]` student) through `spm_core::models::api::build_model` and
//! optionally warm-starts it from a native checkpoint, so the serving
//! engine and any model-generic driver construct from config alone.
//!
//! The `[train]` section shapes the data-parallel `TrainEngine`
//! (DESIGN.md §14): replica count, the per-replica thread budget, and
//! the microbatches-per-step accumulation.
//!
//! The `[serve]` section shapes the serving deployment (DESIGN.md §13,
//! §16): replica pool, per-lane batching windows and admission caps, the
//! engine-wide shed budget, and the gateway's listen address;
//! [`ServeConfig::to_engine`] lowers it onto a `ServeEngine` builder.

use std::collections::BTreeMap;

use spm_core::models::api::{build_model, load_checkpoint, Model, ModelCfg, ModelKind};
use spm_core::ops::{LinearCfg, LinearKind, SpmExec};
use spm_core::pairing::Schedule;
use spm_core::spm::Variant;

use crate::bail;
use crate::error::{Context, Result};

#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    /// Single-line `[a, b, c]` array of scalars (never nested).
    List(Vec<Value>),
}

impl Value {
    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Value::Int(i) if *i >= 0 => Some(*i as usize),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_list(&self) -> Option<&[Value]> {
        match self {
            Value::List(items) => Some(items),
            _ => None,
        }
    }
}

/// Parse one scalar literal (no arrays); shared by `parse_toml` for both
/// bare values and array elements.
fn parse_scalar(val: &str) -> Option<Value> {
    if let Some(s) = val.strip_prefix('"').and_then(|s| s.strip_suffix('"')) {
        Some(Value::Str(s.to_string()))
    } else if val == "true" {
        Some(Value::Bool(true))
    } else if val == "false" {
        Some(Value::Bool(false))
    } else if let Ok(i) = val.parse::<i64>() {
        Some(Value::Int(i))
    } else if let Ok(f) = val.parse::<f64>() {
        Some(Value::Float(f))
    } else {
        None
    }
}

/// section -> key -> value ("" = top level section)
pub type Toml = BTreeMap<String, BTreeMap<String, Value>>;

pub fn parse_toml(text: &str) -> Result<Toml> {
    let mut out: Toml = BTreeMap::new();
    let mut section = String::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = raw.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        if let Some(name) = line.strip_prefix('[').and_then(|s| s.strip_suffix(']')) {
            section = name.trim().to_string();
            out.entry(section.clone()).or_default();
            continue;
        }
        let (key, val) = line
            .split_once('=')
            .with_context(|| format!("line {}: expected key = value", lineno + 1))?;
        let key = key.trim().to_string();
        let val = val.trim();
        // strip trailing comment outside quotes/brackets (quoted strings
        // and array elements must not themselves contain '#')
        let val = if val.starts_with('"') {
            val
        } else if val.starts_with('[') {
            match val.rfind(']') {
                Some(end) => val[..=end].trim(),
                None => bail!("line {}: unterminated array value", lineno + 1),
            }
        } else {
            val.split('#').next().unwrap().trim()
        };
        let parsed = if let Some(inner) =
            val.strip_prefix('[').and_then(|s| s.strip_suffix(']'))
        {
            let inner = inner.trim();
            let mut items = Vec::new();
            if !inner.is_empty() {
                for part in inner.split(',') {
                    let part = part.trim();
                    let item = parse_scalar(part).with_context(|| {
                        format!("line {}: cannot parse array element '{part}'", lineno + 1)
                    })?;
                    items.push(item);
                }
            }
            Value::List(items)
        } else {
            parse_scalar(val)
                .with_context(|| format!("line {}: cannot parse value '{val}'", lineno + 1))?
        };
        out.entry(section.clone()).or_default().insert(key, parsed);
    }
    Ok(out)
}

/// The student operator an experiment trains, lowered to `LinearCfg` at
/// the experiment's width. Defaults match the paper: SPM, general blocks,
/// butterfly pairing, L = log2(n).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct OpConfig {
    pub kind: LinearKind,
    pub variant: Variant,
    pub schedule: Schedule,
    /// None = paper default log2(n)
    pub num_stages: Option<usize>,
    /// Low-rank factor width; None = matched to the default-SPM
    /// parameter budget at the experiment's width (DESIGN.md §19)
    pub rank: Option<usize>,
    /// Block-shuffle block size (must divide the width); None = matched
    /// to the default-SPM parameter budget
    pub block: Option<usize>,
    /// SPM stage-loop execution path (`"fused"` default, `"rowwise"` for
    /// the PR-1 comparison path, `"simd"` for the vectorized backend);
    /// applied by the native drivers via `LinearOp::set_exec` after
    /// construction. `"simd"` auto-downgrades to the fused path on builds
    /// or machines without the vectorized backend (DESIGN.md §12), so
    /// configs carrying it stay portable.
    pub exec: SpmExec,
}

impl Default for OpConfig {
    fn default() -> Self {
        OpConfig {
            kind: LinearKind::Spm,
            variant: Variant::General,
            schedule: Schedule::Butterfly,
            num_stages: None,
            rank: None,
            block: None,
            exec: SpmExec::BatchFused,
        }
    }
}

impl OpConfig {
    /// Apply `[op]` keys; unknown values are rejected. Prefer
    /// [`OpConfig::apply_toml_with_text`] when the raw config text is at
    /// hand — errors then carry the offending line number.
    pub fn apply_toml(&mut self, doc: &Toml) -> Result<()> {
        self.apply_toml_with_text(doc, "")
    }

    /// [`OpConfig::apply_toml`] with the raw config text for strict
    /// line-context errors, matching the ablate.rs plan-parse style: an
    /// unknown `[op] kind` reports its line and enumerates every valid
    /// kind instead of surfacing as a bare parse failure.
    pub fn apply_toml_with_text(&mut self, doc: &Toml, text: &str) -> Result<()> {
        let Some(map) = doc.get("op") else {
            return Ok(());
        };
        if let Some(v) = map.get("kind") {
            let s = v.as_str().context("[op] kind must be a string")?;
            self.kind = LinearKind::parse(s).with_context(|| {
                let names: Vec<&str> = LinearKind::ALL.iter().map(|k| k.name()).collect();
                format!(
                    "{}[op] kind '{s}' is not an op kind (valid kinds: {})",
                    at_line(text, "op", "kind"),
                    names.join(", ")
                )
            })?;
        }
        if let Some(v) = map.get("variant") {
            let s = v.as_str().context("[op] variant must be a string")?;
            self.variant = Variant::parse(s).with_context(|| {
                format!("{}[op] variant '{s}'", at_line(text, "op", "variant"))
            })?;
        }
        if let Some(v) = map.get("schedule") {
            let s = v.as_str().context("[op] schedule must be a string")?;
            self.schedule = Schedule::parse(s).with_context(|| {
                format!("{}[op] schedule '{s}'", at_line(text, "op", "schedule"))
            })?;
        }
        if let Some(v) = map.get("stages") {
            let l = v.as_usize().context("[op] stages must be a non-negative int")?;
            if l == 0 {
                bail!("[op] stages must be >= 1");
            }
            self.num_stages = Some(l);
        }
        if let Some(v) = map.get("rank") {
            let r = v.as_usize().context("[op] rank must be a non-negative int")?;
            if r == 0 {
                bail!("[op] rank must be >= 1");
            }
            self.rank = Some(r);
        }
        if let Some(v) = map.get("block") {
            let b = v.as_usize().context("[op] block must be a non-negative int")?;
            if b == 0 {
                bail!("[op] block must be >= 1");
            }
            self.block = Some(b);
        }
        if let Some(v) = map.get("exec") {
            let s = v.as_str().context("[op] exec must be a string")?;
            self.exec = SpmExec::parse(s)
                .with_context(|| format!("{}[op] exec '{s}'", at_line(text, "op", "exec")))?;
        }
        Ok(())
    }

    /// Lower to a width-`n` `LinearCfg`. Unset rank/block fall back to
    /// the equal-parameter-budget defaults inside `LinearOp::new`
    /// (DESIGN.md §19); an explicit block that does not divide `n` is
    /// rejected there at construction.
    pub fn to_linear_cfg(&self, n: usize, seed: u64) -> LinearCfg {
        let mut cfg = match self.kind {
            LinearKind::Dense => LinearCfg::dense(n),
            LinearKind::Spm => LinearCfg::spm(n, self.variant).with_schedule(self.schedule),
            LinearKind::LowRank => LinearCfg::lowrank(n),
            LinearKind::BlockShuffle => LinearCfg::blockshuffle(n),
            LinearKind::Butterfly => LinearCfg::butterfly(n),
        };
        if let Some(r) = self.rank {
            cfg = cfg.with_rank(r);
        }
        if let Some(b) = self.block {
            cfg = cfg.with_block(b);
        }
        if let Some(l) = self.num_stages {
            cfg = cfg.with_stages(l);
        }
        cfg.with_seed(seed)
    }
}

/// `"line N: "` prefix for a key in the raw config text, or empty when
/// the caller has no text (the doc-only [`OpConfig::apply_toml`] path).
fn at_line(text: &str, section: &str, key: &str) -> String {
    match line_of(text, section, key) {
        0 => String::new(),
        n => format!("line {n}: "),
    }
}

/// 1-based line of `key = ...` inside `[section]`, 0 if absent — shared
/// with the ablate.rs plan parser's strict error style.
pub fn line_of(text: &str, section: &str, key: &str) -> usize {
    let mut cur = String::new();
    for (i, raw) in text.lines().enumerate() {
        let line = raw.trim();
        if let Some(name) = line.strip_prefix('[').and_then(|s| s.strip_suffix(']')) {
            cur = name.trim().to_string();
        } else if cur == section {
            if let Some((k, _)) = line.split_once('=') {
                if k.trim() == key {
                    return i + 1;
                }
            }
        }
    }
    0
}

/// 1-based line of the `[section]` header, 0 if absent.
pub fn line_of_section(text: &str, section: &str) -> usize {
    for (i, raw) in text.lines().enumerate() {
        let line = raw.trim();
        if let Some(name) = line.strip_prefix('[').and_then(|s| s.strip_suffix(']')) {
            if name.trim() == section {
                return i + 1;
            }
        }
    }
    0
}

/// The `[model]` section: which network to build, at which width, with
/// which head/sequence shape. Defaults describe the Table 1 student
/// (mlp at n=64, 10 classes).
#[derive(Clone, Debug, PartialEq)]
pub struct ModelConfig {
    pub kind: ModelKind,
    /// Mixing width n — every SPM-replaceable square map's dimension.
    pub n: usize,
    /// Head width for the classifiers (mlp, gru).
    pub classes: usize,
    /// Attention heads (must divide `n`).
    pub heads: usize,
    /// Timesteps per request row (gru, attention).
    pub seq_len: usize,
    pub lr: f32,
    /// Native checkpoint path to warm-start from ("" = cold init).
    pub checkpoint: String,
}

impl Default for ModelConfig {
    fn default() -> Self {
        ModelConfig {
            kind: ModelKind::Mlp,
            n: 64,
            classes: 10,
            heads: 4,
            seq_len: 8,
            lr: 1e-3,
            checkpoint: String::new(),
        }
    }
}

impl ModelConfig {
    /// Apply `[model]` keys; unknown values are rejected.
    pub fn apply_toml(&mut self, doc: &Toml) -> Result<()> {
        let Some(map) = doc.get("model") else {
            return Ok(());
        };
        if let Some(v) = map.get("kind") {
            let s = v.as_str().context("[model] kind must be a string")?;
            self.kind = ModelKind::parse(s).with_context(|| format!("[model] kind '{s}'"))?;
        }
        for (key, dst) in [
            ("n", &mut self.n),
            ("classes", &mut self.classes),
            ("heads", &mut self.heads),
            ("seq_len", &mut self.seq_len),
        ] {
            if let Some(v) = map.get(key) {
                let u = v
                    .as_usize()
                    .with_context(|| format!("[model] {key} must be a non-negative int"))?;
                if u == 0 {
                    bail!("[model] {key} must be >= 1");
                }
                *dst = u;
            }
        }
        if let Some(v) = map.get("lr") {
            let f = v.as_f64().context("[model] lr must be a number")?;
            if !(f.is_finite() && f > 0.0) {
                bail!("[model] lr must be a positive number");
            }
            self.lr = f as f32;
        }
        if let Some(v) = map.get("checkpoint") {
            self.checkpoint = v.as_str().context("[model] checkpoint must be a string")?.into();
        }
        Ok(())
    }

    /// Lower to the spm-core factory config (the `[op]` section supplies
    /// the student operator at this model's width).
    pub fn to_model_cfg(&self, op: &OpConfig, seed: u64) -> ModelCfg {
        ModelCfg::new(self.kind, op.to_linear_cfg(self.n, seed))
            .with_classes(self.classes)
            .with_heads(self.heads)
            .with_seq_len(self.seq_len)
            .with_lr(self.lr)
            .with_seed(seed ^ 0xC1A55)
            .with_exec(op.exec)
    }

    /// Build the configured model and, when `checkpoint` is set,
    /// warm-start it from disk (rejecting wrong-architecture files).
    pub fn build(&self, op: &OpConfig, seed: u64) -> Result<Box<dyn Model>> {
        if self.kind == ModelKind::Attention && self.n % self.heads != 0 {
            bail!("[model] heads = {} must divide n = {}", self.heads, self.n);
        }
        let mut model = build_model(&self.to_model_cfg(op, seed));
        if !self.checkpoint.is_empty() {
            load_checkpoint(model.as_mut(), &self.checkpoint)
                .with_context(|| format!("loading checkpoint {}", self.checkpoint))?;
        }
        Ok(model)
    }
}

/// The `[train]` section: the data-parallel TrainEngine shape
/// (DESIGN.md §14). Defaults reproduce single-replica training exactly.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TrainConfig {
    /// Replica models the microbatch stream fans out across.
    pub replicas: usize,
    /// Worker threads EACH replica's kernels may use (0 = split the
    /// global thread budget evenly: floor(budget / replicas), min 1).
    /// Pin this explicitly when parameter trajectories must be
    /// comparable across replica counts.
    pub threads_per_replica: usize,
    /// Microbatches reduced into ONE optimizer step (0 = one per
    /// replica). Pin together with `threads_per_replica` for
    /// replica-count-invariant trajectories.
    pub accum: usize,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig { replicas: 1, threads_per_replica: 0, accum: 0 }
    }
}

impl TrainConfig {
    /// Apply `[train]` keys; unknown values are rejected.
    pub fn apply_toml(&mut self, doc: &Toml) -> Result<()> {
        let Some(map) = doc.get("train") else {
            return Ok(());
        };
        if let Some(v) = map.get("replicas") {
            let u = v.as_usize().context("[train] replicas must be a non-negative int")?;
            if u == 0 {
                bail!("[train] replicas must be >= 1");
            }
            self.replicas = u;
        }
        if let Some(v) = map.get("threads_per_replica") {
            self.threads_per_replica =
                v.as_usize().context("[train] threads_per_replica must be a non-negative int")?;
        }
        if let Some(v) = map.get("accum") {
            self.accum = v.as_usize().context("[train] accum must be a non-negative int")?;
        }
        Ok(())
    }
}

/// The `[serve]` section: the serving deployment shape (DESIGN.md §13,
/// §16) — replica pool, batching windows, admission caps, and where the
/// gateway listens. Defaults reproduce the engine builder defaults.
#[derive(Clone, Debug, PartialEq)]
pub struct ServeConfig {
    /// Native executor replicas sharding the request stream.
    pub replicas: usize,
    /// Interactive-lane deadline (us) before a partial batch flushes.
    pub max_wait_us: u64,
    /// Batch-lane deadline (us) before a partial batch flushes.
    pub batch_wait_us: u64,
    /// Micro-batch row cap per forward.
    pub max_batch: usize,
    /// Interactive-lane in-flight cap for `try_submit` (0 = unbounded
    /// here; the engine treats it as "no cap").
    pub queue_depth: usize,
    /// Batch-lane in-flight cap (0 = unbounded).
    pub batch_queue_depth: usize,
    /// Engine-wide shed budget (us): queued requests older than this are
    /// shed before dispatch (0 = off).
    pub shed_deadline_us: u64,
    /// Where the TCP gateway binds ("" = no gateway; ":0" picks a port).
    pub listen_addr: String,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            replicas: 1,
            max_wait_us: crate::serve::DEFAULT_MAX_WAIT_US,
            batch_wait_us: crate::serve::DEFAULT_BATCH_WAIT_US,
            max_batch: crate::serve::DEFAULT_BATCH,
            queue_depth: 0,
            batch_queue_depth: 0,
            shed_deadline_us: 0,
            listen_addr: String::new(),
        }
    }
}

impl ServeConfig {
    /// Apply `[serve]` keys; unknown values are rejected.
    pub fn apply_toml(&mut self, doc: &Toml) -> Result<()> {
        let Some(map) = doc.get("serve") else {
            return Ok(());
        };
        if let Some(v) = map.get("replicas") {
            let u = v.as_usize().context("[serve] replicas must be a non-negative int")?;
            if u == 0 {
                bail!("[serve] replicas must be >= 1");
            }
            self.replicas = u;
        }
        if let Some(v) = map.get("max_batch") {
            let u = v.as_usize().context("[serve] max_batch must be a non-negative int")?;
            if u == 0 {
                bail!("[serve] max_batch must be >= 1");
            }
            self.max_batch = u;
        }
        for (key, dst) in [
            ("max_wait_us", &mut self.max_wait_us),
            ("batch_wait_us", &mut self.batch_wait_us),
            ("shed_deadline_us", &mut self.shed_deadline_us),
        ] {
            if let Some(v) = map.get(key) {
                *dst = v
                    .as_usize()
                    .with_context(|| format!("[serve] {key} must be a non-negative int"))?
                    as u64;
            }
        }
        for (key, dst) in [
            ("queue_depth", &mut self.queue_depth),
            ("batch_queue_depth", &mut self.batch_queue_depth),
        ] {
            if let Some(v) = map.get(key) {
                *dst = v
                    .as_usize()
                    .with_context(|| format!("[serve] {key} must be a non-negative int"))?;
            }
        }
        if let Some(v) = map.get("listen_addr") {
            self.listen_addr = v.as_str().context("[serve] listen_addr must be a string")?.into();
        }
        Ok(())
    }

    /// Lower to a `ServeEngine` over `replicas` native copies built from
    /// `build` (called once per replica index). The engine honours every
    /// `[serve]` knob except `listen_addr`, which belongs to the gateway.
    pub fn to_engine(
        &self,
        mut build: impl FnMut(usize) -> Box<dyn Model>,
    ) -> crate::serve::ServeEngine {
        use crate::serve::{Lane, NativeExecutor, ServeEngine};
        let mut engine = ServeEngine::new()
            .with_max_wait_us(self.max_wait_us)
            .with_batch_wait_us(self.batch_wait_us)
            .with_max_batch(self.max_batch)
            .with_shed_deadline_us(self.shed_deadline_us);
        if self.queue_depth > 0 {
            engine = engine.with_queue_depth(Lane::Interactive, self.queue_depth);
        }
        if self.batch_queue_depth > 0 {
            engine = engine.with_queue_depth(Lane::Batch, self.batch_queue_depth);
        }
        for i in 0..self.replicas {
            engine = engine.with_executor(Box::new(NativeExecutor::new(build(i), self.max_batch)));
        }
        engine
    }
}

/// Run-level knobs every experiment honours. Training hyper-parameters
/// (lr, batch) are baked into the drivers/artifacts; the run config
/// controls duration, cadence, seeds, reporting, and — for the *native*
/// drivers only — the student op via `[op]` (the XLA drivers replay
/// AOT-baked students and ignore `[op]`).
#[derive(Clone, Debug)]
pub struct RunConfig {
    /// training steps per model
    pub steps: usize,
    /// evaluate every k steps (0 = only at the end)
    pub eval_every: usize,
    /// number of eval batches
    pub eval_batches: usize,
    /// timing warmup steps excluded from ms/step
    pub warmup: usize,
    /// data/init seed
    pub seed: u64,
    /// CSV output path ("" = none)
    pub out_csv: String,
    /// worker threads for the native engine (0 = all cores)
    pub threads: usize,
    /// artifacts directory
    pub artifacts: String,
    /// the student LinearOp ([op] section)
    pub op: OpConfig,
    /// the network to build/serve ([model] section)
    pub model: ModelConfig,
    /// the data-parallel engine shape ([train] section)
    pub train: TrainConfig,
    /// the serving deployment shape ([serve] section)
    pub serve: ServeConfig,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            steps: 200,
            eval_every: 0,
            eval_batches: 10,
            warmup: 3,
            seed: 0,
            out_csv: String::new(),
            threads: 0,
            artifacts: "artifacts".into(),
            op: OpConfig::default(),
            model: ModelConfig::default(),
            train: TrainConfig::default(),
            serve: ServeConfig::default(),
        }
    }
}

impl RunConfig {
    /// Apply `[run]` (or top-level) and `[op]` keys from a TOML file.
    pub fn apply_toml(&mut self, doc: &Toml) -> Result<()> {
        self.apply_toml_with_text(doc, "")
    }

    /// [`RunConfig::apply_toml`] with the raw text threaded through so
    /// section errors (notably `[op] kind`) carry line context.
    pub fn apply_toml_with_text(&mut self, doc: &Toml, text: &str) -> Result<()> {
        for section in ["", "run"] {
            if let Some(map) = doc.get(section) {
                if let Some(v) = map.get("steps").and_then(Value::as_usize) {
                    self.steps = v;
                }
                if let Some(v) = map.get("eval_every").and_then(Value::as_usize) {
                    self.eval_every = v;
                }
                if let Some(v) = map.get("eval_batches").and_then(Value::as_usize) {
                    self.eval_batches = v;
                }
                if let Some(v) = map.get("warmup").and_then(Value::as_usize) {
                    self.warmup = v;
                }
                if let Some(v) = map.get("seed").and_then(Value::as_usize) {
                    self.seed = v as u64;
                }
                if let Some(v) = map.get("out_csv").and_then(Value::as_str) {
                    self.out_csv = v.to_string();
                }
                if let Some(v) = map.get("threads").and_then(Value::as_usize) {
                    self.threads = v;
                }
                if let Some(v) = map.get("artifacts").and_then(Value::as_str) {
                    self.artifacts = v.to_string();
                }
            }
        }
        self.op.apply_toml_with_text(doc, text)?;
        self.model.apply_toml(doc)?;
        self.train.apply_toml(doc)?;
        self.serve.apply_toml(doc)
    }

    pub fn load_file(&mut self, path: &str) -> Result<()> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {path}"))?;
        let doc = parse_toml(&text)?;
        self.apply_toml_with_text(&doc, &text)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_types() {
        let doc = parse_toml(
            r#"
# comment
steps = 100
[run]
eval_every = 25   # inline comment
out_csv = "results.csv"
lr = 0.001
fast = true
"#,
        )
        .unwrap();
        assert_eq!(doc[""]["steps"], Value::Int(100));
        assert_eq!(doc["run"]["eval_every"], Value::Int(25));
        assert_eq!(doc["run"]["out_csv"], Value::Str("results.csv".into()));
        assert_eq!(doc["run"]["lr"], Value::Float(0.001));
        assert_eq!(doc["run"]["fast"], Value::Bool(true));
    }

    #[test]
    fn run_config_applies() {
        let doc = parse_toml("[run]\nsteps = 42\nseed = 7\nout_csv = \"x.csv\"\n").unwrap();
        let mut rc = RunConfig::default();
        rc.apply_toml(&doc).unwrap();
        assert_eq!(rc.steps, 42);
        assert_eq!(rc.seed, 7);
        assert_eq!(rc.out_csv, "x.csv");
    }

    #[test]
    fn op_config_applies_and_lowers() {
        let doc = parse_toml(
            "[op]\nkind = \"spm\"\nvariant = \"rotation\"\nschedule = \"shift\"\nstages = 4\n",
        )
        .unwrap();
        let mut rc = RunConfig::default();
        rc.apply_toml(&doc).unwrap();
        assert_eq!(rc.op.kind, LinearKind::Spm);
        assert_eq!(rc.op.variant, Variant::Rotation);
        assert_eq!(rc.op.schedule, Schedule::Shift);
        assert_eq!(rc.op.num_stages, Some(4));
        let cfg = rc.op.to_linear_cfg(32, 9);
        assert_eq!(cfg.n(), 32);
        assert_eq!(cfg.kind, LinearKind::Spm);
        assert_eq!(cfg.variant, Variant::Rotation);
        assert_eq!(cfg.schedule, Schedule::Shift);
        assert_eq!(cfg.num_stages, Some(4));
        assert_eq!(cfg.seed, 9);
    }

    #[test]
    fn op_config_rejects_unknown_values() {
        let doc = parse_toml("[op]\nvariant = \"diagonal\"\n").unwrap();
        let mut rc = RunConfig::default();
        assert!(rc.apply_toml(&doc).is_err());
    }

    #[test]
    fn op_config_exec_path() {
        let doc = parse_toml("[op]\nexec = \"rowwise\"\n").unwrap();
        let mut rc = RunConfig::default();
        assert_eq!(rc.op.exec, SpmExec::BatchFused);
        rc.apply_toml(&doc).unwrap();
        assert_eq!(rc.op.exec, SpmExec::RowWise);
        // "simd" parses on EVERY build (portability contract): whether it
        // actually runs vectorized is decided at LinearOp::set_exec time
        let simd = parse_toml("[op]\nexec = \"simd\"\n").unwrap();
        rc.apply_toml(&simd).unwrap();
        assert_eq!(rc.op.exec, SpmExec::Simd);
        let bad = parse_toml("[op]\nexec = \"gpu\"\n").unwrap();
        assert!(rc.apply_toml(&bad).is_err());
    }

    #[test]
    fn op_config_rejects_zero_stages() {
        // stages = 0 would panic at SpmPlan construction; reject it here
        let doc = parse_toml("[op]\nstages = 0\n").unwrap();
        let mut rc = RunConfig::default();
        assert!(rc.apply_toml(&doc).is_err());
    }

    #[test]
    fn op_config_dense_lowering() {
        let mut op = OpConfig::default();
        op.kind = LinearKind::Dense;
        let cfg = op.to_linear_cfg(16, 1);
        assert_eq!(cfg.kind, LinearKind::Dense);
        assert_eq!((cfg.d_in, cfg.d_out), (16, 16));
    }

    /// Satellite (zoo): every kind round-trips through `[op] kind`, and
    /// rank/block knobs lower onto the `LinearCfg`.
    #[test]
    fn op_config_zoo_kinds_lower() {
        for kind in LinearKind::ALL {
            let doc = parse_toml(&format!("[op]\nkind = \"{}\"\n", kind.name())).unwrap();
            let mut rc = RunConfig::default();
            rc.apply_toml(&doc).unwrap();
            assert_eq!(rc.op.kind, kind);
            assert_eq!(rc.op.to_linear_cfg(16, 3).kind, kind);
        }
        let doc =
            parse_toml("[op]\nkind = \"lowrank\"\nrank = 6\n").unwrap();
        let mut rc = RunConfig::default();
        rc.apply_toml(&doc).unwrap();
        assert_eq!(rc.op.rank, Some(6));
        assert_eq!(rc.op.to_linear_cfg(16, 3).rank, Some(6));
        let doc = parse_toml("[op]\nkind = \"blockshuffle\"\nblock = 4\n").unwrap();
        let mut rc = RunConfig::default();
        rc.apply_toml(&doc).unwrap();
        assert_eq!(rc.op.block, Some(4));
        assert_eq!(rc.op.to_linear_cfg(16, 3).block, Some(4));
        // zero knobs are rejected before they can panic at construction
        for bad in ["[op]\nrank = 0\n", "[op]\nblock = 0\n"] {
            let doc = parse_toml(bad).unwrap();
            assert!(RunConfig::default().apply_toml(&doc).is_err(), "{bad}");
        }
    }

    /// Satellite: an unknown `[op] kind` must name the offending line and
    /// enumerate every valid kind — not surface as a bare parse failure.
    #[test]
    fn op_config_unknown_kind_reports_line_and_candidates() {
        let text = "# experiment\n[op]\nvariant = \"general\"\nkind = \"monarch\"\n";
        let doc = parse_toml(text).unwrap();
        let mut rc = RunConfig::default();
        let err = format!("{:#}", rc.apply_toml_with_text(&doc, text).unwrap_err());
        assert!(err.contains("line 4"), "{err}");
        assert!(err.contains("'monarch'"), "{err}");
        for kind in LinearKind::ALL {
            assert!(err.contains(kind.name()), "{err} missing {}", kind.name());
        }
        // the doc-only path still enumerates candidates, just without a line
        let err2 = format!("{:#}", rc.apply_toml(&doc).unwrap_err());
        assert!(!err2.contains("line "), "{err2}");
        assert!(err2.contains("valid kinds"), "{err2}");
    }

    #[test]
    fn model_config_applies_and_builds_every_kind() {
        for kind in ModelKind::ALL {
            let doc = parse_toml(&format!(
                "[model]\nkind = \"{}\"\nn = 8\nclasses = 3\nheads = 2\nseq_len = 2\nlr = 0.002\n",
                kind.name()
            ))
            .unwrap();
            let mut rc = RunConfig::default();
            rc.apply_toml(&doc).unwrap();
            assert_eq!(rc.model.kind, kind);
            assert_eq!((rc.model.n, rc.model.classes), (8, 3));
            assert_eq!((rc.model.heads, rc.model.seq_len), (2, 2));
            assert!((rc.model.lr - 0.002).abs() < 1e-9);
            let model = rc.model.build(&rc.op, 5).unwrap();
            assert_eq!(model.kind(), kind);
            assert!(model.param_count() > 0);
        }
    }

    #[test]
    fn model_config_lowers_op_section_into_the_student() {
        let doc = parse_toml(
            "[op]\nvariant = \"rotation\"\nschedule = \"shift\"\n[model]\nkind = \"gru\"\nn = 16\n",
        )
        .unwrap();
        let mut rc = RunConfig::default();
        rc.apply_toml(&doc).unwrap();
        let mcfg = rc.model.to_model_cfg(&rc.op, 9);
        assert_eq!(mcfg.kind, ModelKind::Gru);
        assert_eq!(mcfg.op.n(), 16);
        assert_eq!(mcfg.op.variant, Variant::Rotation);
        assert_eq!(mcfg.op.schedule, Schedule::Shift);
    }

    #[test]
    fn model_config_rejects_bad_values() {
        let mut rc = RunConfig::default();
        for bad in [
            "[model]\nkind = \"transformer\"\n",
            "[model]\nn = 0\n",
            "[model]\nseq_len = 0\n",
            "[model]\nlr = -0.1\n",
        ] {
            let doc = parse_toml(bad).unwrap();
            assert!(rc.apply_toml(&doc).is_err(), "{bad}");
        }
        // attention heads must divide n — caught at build time
        let doc =
            parse_toml("[model]\nkind = \"attention\"\nn = 10\nheads = 4\n").unwrap();
        rc.apply_toml(&doc).unwrap();
        assert!(rc.model.build(&rc.op, 1).is_err());
    }

    #[test]
    fn model_config_missing_checkpoint_fails_loudly() {
        let doc =
            parse_toml("[model]\nkind = \"mlp\"\nn = 8\ncheckpoint = \"/nonexistent/x.ckpt\"\n")
                .unwrap();
        let mut rc = RunConfig::default();
        rc.apply_toml(&doc).unwrap();
        let err = rc.model.build(&rc.op, 1).unwrap_err();
        assert!(err.to_string().contains("checkpoint"), "{err}");
    }

    #[test]
    fn train_config_applies_and_defaults() {
        let mut rc = RunConfig::default();
        assert_eq!(rc.train, TrainConfig { replicas: 1, threads_per_replica: 0, accum: 0 });
        let doc =
            parse_toml("[train]\nreplicas = 4\nthreads_per_replica = 2\naccum = 8\n").unwrap();
        rc.apply_toml(&doc).unwrap();
        assert_eq!(rc.train, TrainConfig { replicas: 4, threads_per_replica: 2, accum: 8 });
    }

    #[test]
    fn train_config_rejects_bad_values() {
        let mut rc = RunConfig::default();
        for bad in [
            "[train]\nreplicas = 0\n",
            "[train]\nreplicas = -1\n",
            "[train]\nthreads_per_replica = \"all\"\n",
            "[train]\naccum = -2\n",
        ] {
            let doc = parse_toml(bad).unwrap();
            assert!(rc.apply_toml(&doc).is_err(), "{bad}");
        }
    }

    #[test]
    fn serve_config_applies_and_defaults() {
        let mut rc = RunConfig::default();
        assert_eq!(rc.serve, ServeConfig::default());
        let doc = parse_toml(
            "[serve]\nreplicas = 3\nmax_wait_us = 150\nbatch_wait_us = 4000\nmax_batch = 8\n\
             queue_depth = 64\nbatch_queue_depth = 512\nshed_deadline_us = 20000\n\
             listen_addr = \"127.0.0.1:0\"\n",
        )
        .unwrap();
        rc.apply_toml(&doc).unwrap();
        assert_eq!(rc.serve.replicas, 3);
        assert_eq!(rc.serve.max_wait_us, 150);
        assert_eq!(rc.serve.batch_wait_us, 4000);
        assert_eq!(rc.serve.max_batch, 8);
        assert_eq!(rc.serve.queue_depth, 64);
        assert_eq!(rc.serve.batch_queue_depth, 512);
        assert_eq!(rc.serve.shed_deadline_us, 20000);
        assert_eq!(rc.serve.listen_addr, "127.0.0.1:0");
    }

    #[test]
    fn serve_config_rejects_bad_values() {
        let mut rc = RunConfig::default();
        for bad in [
            "[serve]\nreplicas = 0\n",
            "[serve]\nmax_batch = 0\n",
            "[serve]\nqueue_depth = -1\n",
            "[serve]\nmax_wait_us = \"fast\"\n",
            "[serve]\nlisten_addr = 8080\n",
        ] {
            let doc = parse_toml(bad).unwrap();
            assert!(rc.apply_toml(&doc).is_err(), "{bad}");
        }
    }

    #[test]
    fn serve_config_lowers_onto_a_working_engine() {
        use spm_core::ops::LinearCfg;
        use spm_core::spm::Variant;
        let doc = parse_toml("[serve]\nreplicas = 2\nmax_batch = 4\nmax_wait_us = 0\n").unwrap();
        let mut rc = RunConfig::default();
        rc.apply_toml(&doc).unwrap();
        let mcfg = ModelCfg::new(ModelKind::Mlp, LinearCfg::spm(8, Variant::General))
            .with_classes(3)
            .with_seed(5);
        let mut engine = rc.serve.to_engine(|_i| build_model(&mcfg));
        let report = engine
            .run(&crate::serve::Workload { num_requests: 9, num_clients: 3, seed: 1 })
            .unwrap();
        assert_eq!(report.requests, 9);
        assert_eq!(report.replica_batches.len(), 2);
    }

    #[test]
    fn rejects_bad_lines() {
        assert!(parse_toml("this is not toml").is_err());
        assert!(parse_toml("x = @@@").is_err());
    }

    #[test]
    fn parses_arrays() {
        let doc = parse_toml(
            "[axes]\nop = [\"spm\", \"dense\"]\nstages = [2, 4]   # comment\nempty = []\n",
        )
        .unwrap();
        assert_eq!(
            doc["axes"]["op"],
            Value::List(vec![Value::Str("spm".into()), Value::Str("dense".into())])
        );
        assert_eq!(doc["axes"]["stages"], Value::List(vec![Value::Int(2), Value::Int(4)]));
        assert_eq!(doc["axes"]["empty"], Value::List(vec![]));
        assert_eq!(doc["axes"]["stages"].as_list().map(<[Value]>::len), Some(2));
        assert_eq!(doc["axes"]["op"].as_str(), None);
    }

    #[test]
    fn rejects_bad_arrays() {
        let err = parse_toml("x = [1, 2").unwrap_err().to_string();
        assert!(err.contains("line 1") && err.contains("unterminated"), "{err}");
        let err = parse_toml("a = 1\nx = [1, @]\n").unwrap_err().to_string();
        assert!(err.contains("line 2") && err.contains("array element"), "{err}");
        assert!(parse_toml("x = [1, ]").is_err());
    }

    #[test]
    fn value_coercions() {
        assert_eq!(Value::Int(3).as_f64(), Some(3.0));
        assert_eq!(Value::Int(-1).as_usize(), None);
        assert_eq!(Value::Bool(true).as_bool(), Some(true));
    }
}
