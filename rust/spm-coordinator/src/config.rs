//! Experiment configuration: a TOML-subset parser (toml is not in the
//! offline vendor set) + typed run configs with file/CLI overrides.
//!
//! Supported TOML subset — exactly what experiment configs need:
//! `[section]` headers, `key = value` with string/int/float/bool values,
//! `#` comments, blank lines.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
}

impl Value {
    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Value::Int(i) if *i >= 0 => Some(*i as usize),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// section -> key -> value ("" = top level section)
pub type Toml = BTreeMap<String, BTreeMap<String, Value>>;

pub fn parse_toml(text: &str) -> Result<Toml> {
    let mut out: Toml = BTreeMap::new();
    let mut section = String::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = raw.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        if let Some(name) = line.strip_prefix('[').and_then(|s| s.strip_suffix(']')) {
            section = name.trim().to_string();
            out.entry(section.clone()).or_default();
            continue;
        }
        let (key, val) = line
            .split_once('=')
            .with_context(|| format!("line {}: expected key = value", lineno + 1))?;
        let key = key.trim().to_string();
        let val = val.trim();
        // strip trailing comment outside quotes
        let val = if val.starts_with('"') {
            val
        } else {
            val.split('#').next().unwrap().trim()
        };
        let parsed = if let Some(s) = val.strip_prefix('"').and_then(|s| s.strip_suffix('"')) {
            Value::Str(s.to_string())
        } else if val == "true" {
            Value::Bool(true)
        } else if val == "false" {
            Value::Bool(false)
        } else if let Ok(i) = val.parse::<i64>() {
            Value::Int(i)
        } else if let Ok(f) = val.parse::<f64>() {
            Value::Float(f)
        } else {
            bail!("line {}: cannot parse value '{val}'", lineno + 1);
        };
        out.entry(section.clone()).or_default().insert(key, parsed);
    }
    Ok(out)
}

/// Run-level knobs every experiment honours. Training hyper-parameters
/// (lr, batch, L, schedule) are baked into the AOT artifacts; the run config
/// controls duration, cadence, seeds and reporting.
#[derive(Clone, Debug)]
pub struct RunConfig {
    /// training steps per model
    pub steps: usize,
    /// evaluate every k steps (0 = only at the end)
    pub eval_every: usize,
    /// number of eval batches
    pub eval_batches: usize,
    /// timing warmup steps excluded from ms/step
    pub warmup: usize,
    /// data/init seed
    pub seed: u64,
    /// CSV output path ("" = none)
    pub out_csv: String,
    /// worker threads for the native engine (0 = all cores)
    pub threads: usize,
    /// artifacts directory
    pub artifacts: String,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            steps: 200,
            eval_every: 0,
            eval_batches: 10,
            warmup: 3,
            seed: 0,
            out_csv: String::new(),
            threads: 0,
            artifacts: "artifacts".into(),
        }
    }
}

impl RunConfig {
    /// Apply `[run]` (or top-level) keys from a TOML file.
    pub fn apply_toml(&mut self, doc: &Toml) {
        for section in ["", "run"] {
            if let Some(map) = doc.get(section) {
                if let Some(v) = map.get("steps").and_then(Value::as_usize) {
                    self.steps = v;
                }
                if let Some(v) = map.get("eval_every").and_then(Value::as_usize) {
                    self.eval_every = v;
                }
                if let Some(v) = map.get("eval_batches").and_then(Value::as_usize) {
                    self.eval_batches = v;
                }
                if let Some(v) = map.get("warmup").and_then(Value::as_usize) {
                    self.warmup = v;
                }
                if let Some(v) = map.get("seed").and_then(Value::as_usize) {
                    self.seed = v as u64;
                }
                if let Some(v) = map.get("out_csv").and_then(Value::as_str) {
                    self.out_csv = v.to_string();
                }
                if let Some(v) = map.get("threads").and_then(Value::as_usize) {
                    self.threads = v;
                }
                if let Some(v) = map.get("artifacts").and_then(Value::as_str) {
                    self.artifacts = v.to_string();
                }
            }
        }
    }

    pub fn load_file(&mut self, path: &str) -> Result<()> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {path}"))?;
        let doc = parse_toml(&text)?;
        self.apply_toml(&doc);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_types() {
        let doc = parse_toml(
            r#"
# comment
steps = 100
[run]
eval_every = 25   # inline comment
out_csv = "results.csv"
lr = 0.001
fast = true
"#,
        )
        .unwrap();
        assert_eq!(doc[""]["steps"], Value::Int(100));
        assert_eq!(doc["run"]["eval_every"], Value::Int(25));
        assert_eq!(doc["run"]["out_csv"], Value::Str("results.csv".into()));
        assert_eq!(doc["run"]["lr"], Value::Float(0.001));
        assert_eq!(doc["run"]["fast"], Value::Bool(true));
    }

    #[test]
    fn run_config_applies() {
        let doc = parse_toml("[run]\nsteps = 42\nseed = 7\nout_csv = \"x.csv\"\n").unwrap();
        let mut rc = RunConfig::default();
        rc.apply_toml(&doc);
        assert_eq!(rc.steps, 42);
        assert_eq!(rc.seed, 7);
        assert_eq!(rc.out_csv, "x.csv");
    }

    #[test]
    fn rejects_bad_lines() {
        assert!(parse_toml("this is not toml").is_err());
        assert!(parse_toml("x = @@@").is_err());
    }

    #[test]
    fn value_coercions() {
        assert_eq!(Value::Int(3).as_f64(), Some(3.0));
        assert_eq!(Value::Int(-1).as_usize(), None);
        assert_eq!(Value::Bool(true).as_bool(), Some(true));
    }
}
