//! Engine-agnostic experiment core + native drivers for the paper's §9
//! tables (plus the DESIGN.md §9 ablation names). This module owns the
//! data sources, outcome rows, and table renderers; everything trains
//! through the planned `spm_core::ops::LinearOp` layer. The XLA/PJRT
//! drivers that replay the same tables against AOT artifacts live in
//! `spm-runtime::drivers` (the crate that owns the PJRT dependency) and
//! reuse these types, so every reported number keeps one source of truth.

use std::sync::Arc;

use spm_core::models::api::{ModelCfg, ModelKind, Target};
use spm_core::ops::{LinearCfg, LinearOp};
use spm_core::optim::Adam;
use spm_core::rng::Rng;
use spm_core::spm::Variant;
use spm_core::tensor::Mat;
use spm_data::agnews;
use spm_data::batch::Prefetcher;
use spm_data::teacher::Teacher;

use crate::config::RunConfig;
use crate::error::Result;
use crate::metrics::{fmt_f, Csv, StepTimer, Table};
use crate::train::{TrainBatch, TrainEngine};

/// Where classification batches come from.
#[derive(Clone)]
pub enum DataSource {
    /// §9.1 compositional teacher at width n
    Teacher { n: usize, classes: usize, seed: u64 },
    /// §9.2 AG-News-proxy, hashed to width n
    AgNews { n: usize },
}

impl DataSource {
    pub fn batch(&self, index: usize, batch: usize, train: bool) -> (Mat, Vec<u32>) {
        match self {
            DataSource::Teacher { n, classes, seed } => {
                let stream = if train { 0x7121 } else { 0xEA1 };
                let mut rng = Rng::new(seed ^ stream ^ (index as u64).wrapping_mul(0x9E37));
                let teacher = TEACHERS.with(|c| {
                    let mut cache = c.borrow_mut();
                    cache
                        .entry((*n, *classes, *seed))
                        .or_insert_with(|| Arc::new(Teacher::new(*n, *classes, *seed)))
                        .clone()
                });
                teacher.sample(batch, &mut rng)
            }
            DataSource::AgNews { n } => {
                let (split_seed, limit) = if train {
                    (agnews::TRAIN_SEED, agnews::TRAIN_SIZE)
                } else {
                    (agnews::TEST_SEED, agnews::TEST_SIZE)
                };
                let start = (index * batch) % limit.saturating_sub(batch).max(1);
                agnews::batch(split_seed, start, batch, *n)
            }
        }
    }
}

thread_local! {
    static TEACHERS: std::cell::RefCell<
        std::collections::HashMap<(usize, usize, u64), Arc<Teacher>>,
    > = std::cell::RefCell::new(std::collections::HashMap::new());
}

/// One classifier result row (either engine).
#[derive(Clone, Debug)]
pub struct ClfOutcome {
    pub label: String,
    pub n: usize,
    pub acc: f32,
    pub loss: f32,
    pub ms_per_step: f64,
    pub steps: usize,
}

/// Train + evaluate a native classifier on a data source, through the
/// unified `Model` trait (DESIGN.md §13) and the data-parallel
/// `TrainEngine` (DESIGN.md §14) — the driver no longer knows which
/// architecture it is holding or how many replicas train it. `cfg.steps`
/// counts MINIBATCHES: with the default `[train]` section (1 replica,
/// accum 0 -> 1) every minibatch is one optimizer step, exactly the
/// pre-engine trajectory; `[train] replicas = R` fans groups of `accum`
/// minibatches across R replicas per optimizer step.
pub fn run_clf_native(
    label: &str,
    op_cfg: LinearCfg,
    classes: usize,
    batch: usize,
    data: &DataSource,
    cfg: &RunConfig,
) -> Result<ClfOutcome> {
    let n = op_cfg.n();
    // `[op] exec` selects the SPM stage-loop path on every owned op
    // (fused default; "simd" downgrades to fused where the vectorized
    // backend is unavailable); dense heads ignore it.
    let mcfg = ModelCfg::new(ModelKind::Mlp, op_cfg)
        .with_classes(classes)
        .with_seed(cfg.seed ^ 0xC1A55)
        .with_exec(cfg.op.exec);
    let mut engine = TrainEngine::from_cfg(&mcfg, cfg.train.replicas.max(1))
        .with_threads_per_replica(cfg.train.threads_per_replica)
        .with_accum(cfg.train.accum);
    let accum = engine.accum_per_step();
    let data_cl = data.clone();
    let steps = cfg.steps;
    let mut feed = Prefetcher::new(steps, 4, move |i| data_cl.batch(i, batch, true));
    // the timer brackets OPTIMIZER steps (one group of `accum`
    // minibatches each), so the warmup count converts from minibatch
    // units and stays below the group count — otherwise accum > 1 could
    // swallow every timed interval and report 0 ms/step
    let groups = steps.div_ceil(accum).max(1);
    let mut timer = StepTimer::new((cfg.warmup / accum).min(groups - 1));
    let mut group: Vec<TrainBatch> = Vec::with_capacity(accum);
    while let Some((x, y)) = feed.next() {
        group.push(TrainBatch::labels(x, y));
        if group.len() == accum {
            timer.start();
            engine.step(&group);
            timer.stop();
            group.clear();
        }
    }
    if !group.is_empty() {
        // ragged tail group: step at its true size
        timer.start();
        engine.step(&group);
        timer.stop();
    }
    let model = engine.model();
    let mut acc_sum = 0.0f64;
    let mut loss_sum = 0.0f64;
    for i in 0..cfg.eval_batches {
        let (x, y) = data.batch(i, batch, false);
        let (l, a) = model.evaluate(&x, &Target::Labels(&y));
        acc_sum += a as f64;
        loss_sum += l as f64;
    }
    let k = cfg.eval_batches.max(1) as f64;
    Ok(ClfOutcome {
        label: label.to_string(),
        n,
        acc: (acc_sum / k) as f32,
        loss: (loss_sum / k) as f32,
        ms_per_step: timer.ms_per_step(),
        steps,
    })
}

/// Render a dense-vs-SPM pair sweep as the paper's Table 1/2 layout.
pub fn render_pair_table(
    title: &str,
    pairs: &[(ClfOutcome, ClfOutcome)],
    csv_path: &str,
) -> Result<String> {
    let mut t = Table::new(&[
        "n",
        "Dense acc",
        "SPM acc",
        "Δacc",
        "Dense ms/step",
        "SPM ms/step",
        "Speedup",
    ]);
    let mut csv = Csv::create(
        csv_path,
        "n,dense_acc,spm_acc,delta_acc,dense_ms,spm_ms,speedup",
    )?;
    for (d, s) in pairs {
        let speedup = if s.ms_per_step > 0.0 { d.ms_per_step / s.ms_per_step } else { 0.0 };
        t.row(vec![
            d.n.to_string(),
            fmt_f(d.acc as f64, 4),
            fmt_f(s.acc as f64, 4),
            format!("{:+.4}", s.acc - d.acc),
            fmt_f(d.ms_per_step, 3),
            fmt_f(s.ms_per_step, 3),
            format!("{speedup:.2}x"),
        ]);
        csv.row(&[
            d.n.to_string(),
            d.acc.to_string(),
            s.acc.to_string(),
            (s.acc - d.acc).to_string(),
            d.ms_per_step.to_string(),
            s.ms_per_step.to_string(),
            speedup.to_string(),
        ])?;
    }
    Ok(format!("{title}\n{}", t.render()))
}

/// Table 1 (paper §9.1), native engine: teacher-student width sweep. The
/// SPM student comes from the run config's `[op]` section (paper defaults
/// when unset).
pub fn run_table1_native(widths: &[usize], cfg: &RunConfig) -> Result<String> {
    let mut pairs = Vec::new();
    for &n in widths {
        let data = DataSource::Teacher { n, classes: 10, seed: 7 + n as u64 };
        let dense = run_clf_native(
            &format!("native_dense_n{n}"),
            LinearCfg::dense(n),
            10,
            256,
            &data,
            cfg,
        )?;
        let spm = run_clf_native(
            &format!("native_spm_n{n}"),
            cfg.op.to_linear_cfg(n, cfg.seed),
            10,
            256,
            &data,
            cfg,
        )?;
        eprintln!(
            "[table1 n={n}] dense acc {:.4} ({:.1} ms/step) | spm acc {:.4} ({:.1} ms/step)",
            dense.acc, dense.ms_per_step, spm.acc, spm.ms_per_step
        );
        pairs.push((dense, spm));
    }
    render_pair_table(
        &format!("Table 1 — compositional teacher (native engine, {} steps)", cfg.steps),
        &pairs,
        &cfg.out_csv,
    )
}

/// Table 2 (paper §9.2), native engine: AG-News-proxy. Defaults to the
/// paper's L=12 unless `[op] stages` overrides it.
pub fn run_table2_native(widths: &[usize], cfg: &RunConfig) -> Result<String> {
    let stage_label = cfg.op.num_stages.unwrap_or(12);
    let mut pairs = Vec::new();
    for &n in widths {
        let data = DataSource::AgNews { n };
        let dense = run_clf_native(
            &format!("native_dense_n{n}"),
            LinearCfg::dense(n),
            4,
            256,
            &data,
            cfg,
        )?;
        let mut student = cfg.op.to_linear_cfg(n, cfg.seed);
        if cfg.op.num_stages.is_none() {
            student = student.with_stages(12);
        }
        let spm = run_clf_native(&format!("native_spm_n{n}"), student, 4, 256, &data, cfg)?;
        eprintln!(
            "[table2 n={n}] dense acc {:.4} ({:.1} ms/step) | spm acc {:.4} ({:.1} ms/step)",
            dense.acc, dense.ms_per_step, spm.acc, spm.ms_per_step
        );
        pairs.push((dense, spm));
    }
    render_pair_table(
        &format!(
            "Table 2 — AG-News proxy, L={stage_label} (native engine, {} steps)",
            cfg.steps
        ),
        &pairs,
        &cfg.out_csv,
    )
}

/// One char-LM eval checkpoint row (Tables 3 & 4 layout). Produced by the
/// XLA driver in spm-runtime; rendered here.
#[derive(Clone, Debug)]
pub struct CharLmRow {
    pub step: usize,
    pub train_nll: f32,
    pub valid_nll: f32,
    pub valid_bpc: f32,
    pub ms_per_step: f64,
}

pub fn render_charlm_table(title: &str, rows: &[CharLmRow]) -> String {
    let mut t = Table::new(&["Step", "Train NLL", "Valid NLL", "Valid BPC", "ms/step"]);
    for r in rows {
        t.row(vec![
            r.step.to_string(),
            fmt_f(r.train_nll as f64, 2),
            fmt_f(r.valid_nll as f64, 2),
            fmt_f(r.valid_bpc as f64, 2),
            fmt_f(r.ms_per_step, 0),
        ]);
    }
    format!("{title}\n{}", t.render())
}

/// One row of the §5 operator-scaling micro-benchmark — structured so the
/// bench's `--json` mode can serialize the perf trajectory instead of only
/// printing it.
#[derive(Clone, Debug)]
pub struct ScalingRow {
    pub n: usize,
    pub dense_ms: f64,
    pub spm_ms: f64,
}

/// Native micro-benchmark of the raw operator complexity claim (§5):
/// SPM stage cost O(nL) vs dense O(n^2) forward, single thread, both
/// through the planned `LinearOp` layer.
pub fn core_scaling_rows(widths: &[usize], batch: usize) -> Vec<ScalingRow> {
    spm_core::parallel::set_threads(1);
    let mut rows = Vec::with_capacity(widths.len());
    for &n in widths {
        let mut rng = Rng::new(1);
        let x = Mat::from_vec(batch, n, rng.normal_vec(batch * n, 1.0));
        let mut adam = Adam::new(1e-3);
        let dense = LinearOp::new(LinearCfg::dense(n), &mut rng, &mut adam);
        let spm = LinearOp::new(LinearCfg::spm(n, Variant::General), &mut rng, &mut adam);
        let time_it = |m: &LinearOp| {
            let reps = (200_000_000 / (batch * n * n).max(1)).clamp(3, 50);
            let t0 = std::time::Instant::now();
            for _ in 0..reps {
                let _ = m.forward(&x);
            }
            t0.elapsed().as_secs_f64() * 1e3 / reps as f64
        };
        let dense_ms = time_it(&dense);
        let spm_ms = time_it(&spm);
        rows.push(ScalingRow { n, dense_ms, spm_ms });
    }
    spm_core::parallel::set_threads(0);
    rows
}

/// Render [`core_scaling_rows`] as the paper's scaling table.
pub fn render_scaling_table(rows: &[ScalingRow], batch: usize) -> String {
    let mut t = Table::new(&["n", "dense fwd ms", "spm fwd ms (L=log2 n)", "ratio"]);
    for r in rows {
        t.row(vec![
            r.n.to_string(),
            fmt_f(r.dense_ms, 3),
            fmt_f(r.spm_ms, 3),
            fmt_f(r.dense_ms / r.spm_ms, 2),
        ]);
    }
    format!("Core op scaling (batch={batch}, single thread)\n{}", t.render())
}

/// [`core_scaling_rows`] + [`render_scaling_table`] in one call (the XLA
/// drivers and tests that only want the printable table).
pub fn run_core_scaling(widths: &[usize], batch: usize) -> String {
    render_scaling_table(&core_scaling_rows(widths, batch), batch)
}
