//! Experiment drivers regenerating every table in the paper's §9 (plus the
//! ablations DESIGN.md adds). Each driver:
//!
//!   1. builds its workload through spm-data (prefetched, backpressured),
//!   2. trains via the PJRT path (`TrainSession`, buffer-resident) and/or
//!      the native spm-core engine,
//!   3. reports paper-style rows through metrics::Table and optional CSV.
//!
//! The same functions back the CLI (`spm run ...`), the examples and the
//! benches, so every number in EXPERIMENTS.md has exactly one source.

use std::sync::Arc;

use anyhow::Result;

use spm_core::models::mixer::{Mixer, MixerCfg, MixerKind};
use spm_core::models::mlp::Classifier;
use spm_core::pairing::Schedule;
use spm_core::rng::Rng;
use spm_core::spm::Variant;
use spm_core::tensor::Mat;
use spm_data::batch::Prefetcher;
use spm_data::charcorpus::Corpus;
use spm_data::teacher::Teacher;
use spm_data::agnews;
use spm_runtime::{Engine, HostTensor, Manifest, TrainSession};

use crate::config::RunConfig;
use crate::metrics::{fmt_f, Csv, StepTimer, Table};

/// Where classification batches come from.
#[derive(Clone)]
pub enum DataSource {
    /// §9.1 compositional teacher at width n
    Teacher { n: usize, classes: usize, seed: u64 },
    /// §9.2 AG-News-proxy, hashed to width n
    AgNews { n: usize },
}

impl DataSource {
    pub fn batch(&self, index: usize, batch: usize, train: bool) -> (Mat, Vec<u32>) {
        match self {
            DataSource::Teacher { n, classes, seed } => {
                let stream = if train { 0x7121 } else { 0xEA1 };
                let mut rng = Rng::new(seed ^ stream ^ (index as u64).wrapping_mul(0x9E37));
                let teacher = TEACHERS.with(|c| {
                    let mut cache = c.borrow_mut();
                    cache
                        .entry((*n, *classes, *seed))
                        .or_insert_with(|| Arc::new(Teacher::new(*n, *classes, *seed)))
                        .clone()
                });
                teacher.sample(batch, &mut rng)
            }
            DataSource::AgNews { n } => {
                let (split_seed, limit) = if train {
                    (agnews::TRAIN_SEED, agnews::TRAIN_SIZE)
                } else {
                    (agnews::TEST_SEED, agnews::TEST_SIZE)
                };
                let start = (index * batch) % limit.saturating_sub(batch).max(1);
                agnews::batch(split_seed, start, batch, *n)
            }
        }
    }
}

thread_local! {
    static TEACHERS: std::cell::RefCell<
        std::collections::HashMap<(usize, usize, u64), Arc<Teacher>>,
    > = std::cell::RefCell::new(std::collections::HashMap::new());
}

/// One classifier result row (either engine).
#[derive(Clone, Debug)]
pub struct ClfOutcome {
    pub label: String,
    pub n: usize,
    pub acc: f32,
    pub loss: f32,
    pub ms_per_step: f64,
    pub steps: usize,
}

/// Train + evaluate one AOT-compiled classifier entry on a data source.
pub fn run_clf_xla(
    engine: &Engine,
    manifest: &Manifest,
    entry_name: &str,
    data: &DataSource,
    cfg: &RunConfig,
) -> Result<ClfOutcome> {
    let mut sess = TrainSession::new(engine, manifest, entry_name, &["init", "train", "eval"])?;
    let entry_batch = sess.entry.meta_usize("batch")?;
    let n = sess.entry.meta_usize("n")?;
    sess.init(cfg.seed as i32)?;

    // prefetch training batches on a worker thread (backpressure depth 4)
    let data_cl = data.clone();
    let steps = cfg.steps;
    let mut feed = Prefetcher::new(steps, 4, move |i| {
        let (x, y) = data_cl.batch(i, entry_batch, true);
        (x.data, y)
    });

    let mut timer = StepTimer::new(cfg.warmup.min(steps.saturating_sub(1)));
    let mut last_loss = f32::NAN;
    while let Some((xv, yv)) = feed.next() {
        let x = HostTensor::F32(xv);
        let y = HostTensor::from_labels(&yv);
        timer.start();
        let (loss, _acc) = sess.train_step(&x, &y)?;
        timer.stop();
        last_loss = loss;
    }

    // held-out evaluation
    let mut acc_sum = 0.0f64;
    let mut loss_sum = 0.0f64;
    for i in 0..cfg.eval_batches {
        let (x, y) = data.batch(i, entry_batch, false);
        let (l, a) = sess.eval(&HostTensor::F32(x.data), &HostTensor::from_labels(&y))?;
        acc_sum += a as f64;
        loss_sum += l as f64;
    }
    let k = cfg.eval_batches.max(1) as f64;
    let _ = last_loss;
    Ok(ClfOutcome {
        label: entry_name.to_string(),
        n,
        acc: (acc_sum / k) as f32,
        loss: (loss_sum / k) as f32,
        ms_per_step: timer.ms_per_step(),
        steps,
    })
}

/// Train + evaluate a native spm-core classifier on a data source.
pub fn run_clf_native(
    label: &str,
    mixer_cfg: MixerCfg,
    classes: usize,
    batch: usize,
    data: &DataSource,
    cfg: &RunConfig,
) -> Result<ClfOutcome> {
    let mut clf = Classifier::new(mixer_cfg, classes, 1e-3, cfg.seed ^ 0xC1A55);
    let data_cl = data.clone();
    let steps = cfg.steps;
    let mut feed = Prefetcher::new(steps, 4, move |i| data_cl.batch(i, batch, true));
    let mut timer = StepTimer::new(cfg.warmup.min(steps.saturating_sub(1)));
    let mut last_loss = f32::NAN;
    while let Some((x, y)) = feed.next() {
        timer.start();
        let (loss, _acc) = clf.train_step(&x, &y);
        timer.stop();
        last_loss = loss;
    }
    let mut acc_sum = 0.0f64;
    let mut loss_sum = 0.0f64;
    for i in 0..cfg.eval_batches {
        let (x, y) = data.batch(i, batch, false);
        let (l, a) = clf.evaluate(&x, &y);
        acc_sum += a as f64;
        loss_sum += l as f64;
    }
    let k = cfg.eval_batches.max(1) as f64;
    let _ = last_loss;
    Ok(ClfOutcome {
        label: label.to_string(),
        n: mixer_cfg.n,
        acc: (acc_sum / k) as f32,
        loss: (loss_sum / k) as f32,
        ms_per_step: timer.ms_per_step(),
        steps,
    })
}

/// Render a dense-vs-SPM pair sweep as the paper's Table 1/2 layout.
pub fn render_pair_table(title: &str, pairs: &[(ClfOutcome, ClfOutcome)], csv_path: &str) -> Result<String> {
    let mut t = Table::new(&["n", "Dense acc", "SPM acc", "Δacc", "Dense ms/step", "SPM ms/step", "Speedup"]);
    let mut csv = Csv::create(
        csv_path,
        "n,dense_acc,spm_acc,delta_acc,dense_ms,spm_ms,speedup",
    )?;
    for (d, s) in pairs {
        let speedup = if s.ms_per_step > 0.0 { d.ms_per_step / s.ms_per_step } else { 0.0 };
        t.row(vec![
            d.n.to_string(),
            fmt_f(d.acc as f64, 4),
            fmt_f(s.acc as f64, 4),
            format!("{:+.4}", s.acc - d.acc),
            fmt_f(d.ms_per_step, 3),
            fmt_f(s.ms_per_step, 3),
            format!("{speedup:.2}x"),
        ]);
        csv.row(&[
            d.n.to_string(),
            d.acc.to_string(),
            s.acc.to_string(),
            (s.acc - d.acc).to_string(),
            d.ms_per_step.to_string(),
            s.ms_per_step.to_string(),
            speedup.to_string(),
        ])?;
    }
    Ok(format!("{title}\n{}", t.render()))
}

/// Table 1 (paper §9.1): teacher-student width sweep.
pub fn run_table1(
    engine: Option<&Engine>,
    manifest: Option<&Manifest>,
    widths: &[usize],
    cfg: &RunConfig,
    native: bool,
) -> Result<String> {
    let mut pairs = Vec::new();
    for &n in widths {
        let data = DataSource::Teacher { n, classes: 10, seed: 7 + n as u64 };
        let (d, s) = if native {
            let dense = run_clf_native(
                &format!("native_dense_n{n}"),
                MixerCfg::dense(n),
                10,
                256,
                &data,
                cfg,
            )?;
            let spm = run_clf_native(
                &format!("native_spm_n{n}"),
                MixerCfg::spm(n, Variant::General).with_schedule(Schedule::Butterfly),
                10,
                256,
                &data,
                cfg,
            )?;
            (dense, spm)
        } else {
            let engine = engine.expect("engine required for XLA path");
            let manifest = manifest.expect("manifest required for XLA path");
            (
                run_clf_xla(engine, manifest, &format!("table1_dense_n{n}"), &data, cfg)?,
                run_clf_xla(engine, manifest, &format!("table1_spm_n{n}"), &data, cfg)?,
            )
        };
        eprintln!(
            "[table1 n={n}] dense acc {:.4} ({:.1} ms/step) | spm acc {:.4} ({:.1} ms/step)",
            d.acc, d.ms_per_step, s.acc, s.ms_per_step
        );
        pairs.push((d, s));
    }
    let engine_tag = if native { "native" } else { "xla" };
    render_pair_table(
        &format!("Table 1 — compositional teacher ({engine_tag} engine, {} steps)", cfg.steps),
        &pairs,
        &cfg.out_csv,
    )
}

/// Table 2 (paper §9.2): AG-News-proxy at L=12.
pub fn run_table2(
    engine: Option<&Engine>,
    manifest: Option<&Manifest>,
    widths: &[usize],
    cfg: &RunConfig,
    native: bool,
) -> Result<String> {
    let mut pairs = Vec::new();
    for &n in widths {
        let data = DataSource::AgNews { n };
        let (d, s) = if native {
            let dense = run_clf_native(
                &format!("native_dense_n{n}"),
                MixerCfg::dense(n),
                4,
                256,
                &data,
                cfg,
            )?;
            let spm = run_clf_native(
                &format!("native_spm_n{n}"),
                MixerCfg::spm(n, Variant::General)
                    .with_schedule(Schedule::Butterfly)
                    .with_stages(12),
                4,
                256,
                &data,
                cfg,
            )?;
            (dense, spm)
        } else {
            let engine = engine.expect("engine required");
            let manifest = manifest.expect("manifest required");
            (
                run_clf_xla(engine, manifest, &format!("table2_dense_n{n}"), &data, cfg)?,
                run_clf_xla(engine, manifest, &format!("table2_spm_n{n}"), &data, cfg)?,
            )
        };
        eprintln!(
            "[table2 n={n}] dense acc {:.4} ({:.1} ms/step) | spm acc {:.4} ({:.1} ms/step)",
            d.acc, d.ms_per_step, s.acc, s.ms_per_step
        );
        pairs.push((d, s));
    }
    let engine_tag = if native { "native" } else { "xla" };
    render_pair_table(
        &format!("Table 2 — AG-News proxy, L=12 ({engine_tag} engine, {} steps)", cfg.steps),
        &pairs,
        &cfg.out_csv,
    )
}

/// One char-LM eval checkpoint row (Tables 3 & 4 layout).
#[derive(Clone, Debug)]
pub struct CharLmRow {
    pub step: usize,
    pub train_nll: f32,
    pub valid_nll: f32,
    pub valid_bpc: f32,
    pub ms_per_step: f64,
}

/// Tables 3/4 (paper §9.3): char-level LM on the Shakespeare-like corpus.
/// `entry_name` selects dense (Table 3) or SPM (Table 4).
pub fn run_charlm(
    engine: &Engine,
    manifest: &Manifest,
    entry_name: &str,
    cfg: &RunConfig,
) -> Result<Vec<CharLmRow>> {
    let mut sess = TrainSession::new(engine, manifest, entry_name, &["init", "train", "eval"])?;
    let batch = sess.entry.meta_usize("batch")?;
    let seq_len = sess.entry.meta_usize("seq_len")?;
    sess.init(cfg.seed as i32)?;

    let corpus = Arc::new(if cfg.steps <= 100 {
        // CI-profile corpus keeps tests fast
        Corpus::generate_sized(cfg.seed, 200_000, 30_000)
    } else {
        Corpus::generate(cfg.seed)
    });

    let c2 = corpus.clone();
    let seed = cfg.seed;
    let mut feed = Prefetcher::new(cfg.steps, 4, move |i| {
        let mut rng = Rng::new(seed ^ 0xBA7C4 ^ (i as u64).wrapping_mul(0x9E37));
        Corpus::sample_batch(&c2.train, batch, seq_len, &mut rng)
    });

    let eval_every = if cfg.eval_every == 0 { cfg.steps } else { cfg.eval_every };
    let mut rows = Vec::new();
    let mut timer = StepTimer::new(cfg.warmup.min(cfg.steps.saturating_sub(1)));
    let mut csv = Csv::create(&cfg.out_csv, "step,train_nll,valid_nll,valid_bpc,ms_per_step")?;

    let mut evaluate = |sess: &TrainSession, step: usize, train_nll: f32, ms: f64,
                        rows: &mut Vec<CharLmRow>, csv: &mut Csv|
     -> Result<()> {
        let mut vsum = 0.0f64;
        for i in 0..cfg.eval_batches {
            let mut rng = Rng::new(0xEA1 ^ (i as u64 + 1).wrapping_mul(0x1234_5678));
            let (inp, tgt) = Corpus::sample_batch(&corpus.valid, batch, seq_len, &mut rng);
            let (l, _m) = sess.eval(&HostTensor::from_bytes(&inp), &HostTensor::from_bytes(&tgt))?;
            vsum += l as f64;
        }
        let valid_nll = (vsum / cfg.eval_batches.max(1) as f64) as f32;
        let row = CharLmRow {
            step,
            train_nll,
            valid_nll,
            valid_bpc: valid_nll / std::f32::consts::LN_2,
            ms_per_step: ms,
        };
        eprintln!(
            "[{entry_name}] step {step}: train NLL {:.3} valid NLL {:.3} BPC {:.3} ({:.0} ms/step)",
            row.train_nll, row.valid_nll, row.valid_bpc, row.ms_per_step
        );
        csv.row(&[
            step.to_string(),
            train_nll.to_string(),
            valid_nll.to_string(),
            row.valid_bpc.to_string(),
            ms.to_string(),
        ])?;
        rows.push(row);
        Ok(())
    };

    let mut step = 0usize;
    let mut train_nll = f32::NAN;
    while let Some((inp, tgt)) = feed.next() {
        step += 1;
        let x = HostTensor::from_bytes(&inp);
        let y = HostTensor::from_bytes(&tgt);
        timer.start();
        let (loss, _m) = sess.train_step(&x, &y)?;
        timer.stop();
        train_nll = loss;
        if step == 1 || step % eval_every == 0 {
            evaluate(&sess, step, train_nll, timer.ms_per_step(), &mut rows, &mut csv)?;
        }
    }
    if rows.last().map(|r| r.step) != Some(step) {
        evaluate(&sess, step, train_nll, timer.ms_per_step(), &mut rows, &mut csv)?;
    }
    Ok(rows)
}

pub fn render_charlm_table(title: &str, rows: &[CharLmRow]) -> String {
    let mut t = Table::new(&["Step", "Train NLL", "Valid NLL", "Valid BPC", "ms/step"]);
    for r in rows {
        t.row(vec![
            r.step.to_string(),
            fmt_f(r.train_nll as f64, 2),
            fmt_f(r.valid_nll as f64, 2),
            fmt_f(r.valid_bpc as f64, 2),
            fmt_f(r.ms_per_step, 0),
        ]);
    }
    format!("{title}\n{}", t.render())
}

/// Ablations (DESIGN.md Abl-L / Abl-P / Abl-V): depth, pairing, variant at
/// n=1024 on the teacher task. Entries must exist in the manifest.
pub fn run_ablation(
    engine: &Engine,
    manifest: &Manifest,
    which: &str,
    cfg: &RunConfig,
) -> Result<String> {
    let n = 1024;
    let data = DataSource::Teacher { n, classes: 10, seed: 7 + n as u64 };
    let entries: Vec<String> = match which {
        "depth" => [1usize, 2, 5, 10, 20].iter().map(|l| format!("abl_depth_L{l}")).collect(),
        "pairing" => ["butterfly", "shift", "random"]
            .iter()
            .map(|s| format!("abl_sched_{s}"))
            .collect(),
        "variant" => ["rotation", "general"]
            .iter()
            .map(|v| format!("abl_variant_{v}"))
            .collect(),
        other => anyhow::bail!("unknown ablation '{other}' (depth|pairing|variant)"),
    };
    let mut t = Table::new(&["config", "L", "params", "acc", "ms/step"]);
    let mut csv = Csv::create(&cfg.out_csv, "config,num_stages,param_count,acc,ms_per_step")?;
    for name in &entries {
        let out = run_clf_xla(engine, manifest, name, &data, cfg)?;
        let entry = manifest.entry(name)?;
        let stages = entry.meta_usize("num_stages").unwrap_or(0);
        let params = entry.meta_usize("param_count").unwrap_or(0);
        eprintln!("[abl {which}] {name}: acc {:.4} ({:.1} ms/step)", out.acc, out.ms_per_step);
        t.row(vec![
            name.clone(),
            stages.to_string(),
            params.to_string(),
            fmt_f(out.acc as f64, 4),
            fmt_f(out.ms_per_step, 3),
        ]);
        csv.row(&[
            name.clone(),
            stages.to_string(),
            params.to_string(),
            out.acc.to_string(),
            out.ms_per_step.to_string(),
        ])?;
    }
    Ok(format!("Ablation: {which} (n=1024, {} steps)\n{}", cfg.steps, t.render()))
}

/// Native micro-benchmark of the raw operator complexity claim (§5):
/// SPM stage cost O(nL) vs dense O(n^2) forward, single thread.
pub fn run_core_scaling(widths: &[usize], batch: usize) -> String {
    spm_core::parallel::set_threads(1);
    let mut t = Table::new(&["n", "dense fwd ms", "spm fwd ms (L=log2 n)", "ratio"]);
    for &n in widths {
        let mut rng = Rng::new(1);
        let x = Mat::from_vec(batch, n, rng.normal_vec(batch * n, 1.0));
        let mut adam = spm_core::optim::Adam::new(1e-3);
        let dense = Mixer::new(MixerCfg::dense(n), &mut rng, &mut adam);
        let spm = Mixer::new(
            MixerCfg { kind: MixerKind::Spm, ..MixerCfg::spm(n, Variant::General) },
            &mut rng,
            &mut adam,
        );
        let time_it = |m: &Mixer| {
            let reps = (200_000_000 / (batch * n * n).max(1)).clamp(3, 50);
            let t0 = std::time::Instant::now();
            for _ in 0..reps {
                let _ = m.forward(&x);
            }
            t0.elapsed().as_secs_f64() * 1e3 / reps as f64
        };
        let dm = time_it(&dense);
        let sm = time_it(&spm);
        t.row(vec![
            n.to_string(),
            fmt_f(dm, 3),
            fmt_f(sm, 3),
            fmt_f(dm / sm, 2),
        ]);
    }
    spm_core::parallel::set_threads(0);
    format!("Core op scaling (batch={batch}, single thread)\n{}", t.render())
}
