//! Allocator-call counting for the bench binaries (DESIGN.md §15): each
//! bench installs [`CountingAlloc`] as its `#[global_allocator]` and
//! reports steady-state `allocs_per_iter` next to its timings, so the
//! zero-allocation hot-path claim is a measured, gated number — not a
//! comment.
//!
//! The counter is a process-global atomic: measurement windows must be
//! quiet (no live worker threads), which every bench guarantees by
//! measuring single-threaded warm iterations outside engine runs. The
//! `#[global_allocator]` attribute itself stays in each binary — the
//! library must never hijack its consumers' allocator.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

static ALLOC_CALLS: AtomicU64 = AtomicU64::new(0);

/// System allocator that counts every `alloc`/`alloc_zeroed`/`realloc`
/// call (frees are not counted: the gated number is "how often does the
/// hot path ask for memory").
pub struct CountingAlloc;

// SAFETY: every method forwards verbatim to `System`, which upholds the
// `GlobalAlloc` contract; the only extra work is a relaxed atomic add,
// which never allocates, never unwinds, and is reentrancy-safe.
unsafe impl GlobalAlloc for CountingAlloc {
    // SAFETY: caller upholds `GlobalAlloc::alloc`'s contract; forwarded
    // unchanged to `System.alloc`.
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    // SAFETY: forwarded unchanged to `System.alloc_zeroed`.
    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    // SAFETY: forwarded unchanged to `System.realloc`.
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    // SAFETY: forwarded unchanged to `System.dealloc`.
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

/// Allocator calls made process-wide while running `f`. Only meaningful
/// when [`CountingAlloc`] is the binary's global allocator (otherwise it
/// returns 0) and no unrelated threads are allocating concurrently.
pub fn allocs_during(f: impl FnOnce()) -> u64 {
    let before = ALLOC_CALLS.load(Ordering::Relaxed);
    f();
    ALLOC_CALLS.load(Ordering::Relaxed) - before
}

/// Mean allocator calls per iteration over `iters` runs of `f` (callers
/// warm the path first so growth allocations are not amortized into the
/// steady-state figure).
pub fn allocs_per_iter(iters: u64, mut f: impl FnMut()) -> f64 {
    assert!(iters > 0, "need at least one iteration");
    let total = allocs_during(|| {
        for _ in 0..iters {
            f();
        }
    });
    total as f64 / iters as f64
}
