//! Metrics: step timing (warmup-aware), CSV sink, and paper-style table
//! rendering. Every experiment reports through this module so EXPERIMENTS.md
//! rows are regenerated identically.

use std::fmt::Write as _;
use std::io::Write as _;
use std::time::Instant;

use crate::error::{Context, Result};

/// Mean ms/step excluding the first `warmup` steps (compile/cache effects).
pub struct StepTimer {
    warmup: usize,
    count: usize,
    total_ms: f64,
    last_start: Option<Instant>,
}

impl StepTimer {
    pub fn new(warmup: usize) -> Self {
        StepTimer { warmup, count: 0, total_ms: 0.0, last_start: None }
    }

    pub fn start(&mut self) {
        self.last_start = Some(Instant::now());
    }

    pub fn stop(&mut self) {
        let t = self.last_start.take().expect("stop without start");
        let ms = t.elapsed().as_secs_f64() * 1e3;
        self.count += 1;
        if self.count > self.warmup {
            self.total_ms += ms;
        }
    }

    pub fn steps_timed(&self) -> usize {
        self.count.saturating_sub(self.warmup)
    }

    pub fn ms_per_step(&self) -> f64 {
        if self.steps_timed() == 0 {
            0.0
        } else {
            self.total_ms / self.steps_timed() as f64
        }
    }
}

/// Append-only CSV writer.
pub struct Csv {
    file: Option<std::fs::File>,
}

impl Csv {
    /// `path` empty => disabled sink.
    pub fn create(path: &str, header: &str) -> Result<Csv> {
        if path.is_empty() {
            return Ok(Csv { file: None });
        }
        if let Some(dir) = std::path::Path::new(path).parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        let mut f = std::fs::File::create(path).with_context(|| format!("creating {path}"))?;
        writeln!(f, "{header}")?;
        Ok(Csv { file: Some(f) })
    }

    pub fn row(&mut self, fields: &[String]) -> Result<()> {
        if let Some(f) = &mut self.file {
            writeln!(f, "{}", fields.join(","))?;
        }
        Ok(())
    }
}

/// Fixed-width table printer (paper-style rows on stdout).
pub struct Table {
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (w, c) in widths.iter_mut().zip(row) {
                *w = (*w).max(c.len());
            }
        }
        let mut out = String::new();
        let line = |out: &mut String, cells: &[String], widths: &[usize]| {
            let mut first = true;
            for (c, w) in cells.iter().zip(widths) {
                if !first {
                    let _ = write!(out, "  ");
                }
                let _ = write!(out, "{c:>w$}", w = w);
                first = false;
            }
            let _ = writeln!(out);
        };
        line(&mut out, &self.headers, &widths);
        let total: usize = widths.iter().sum::<usize>() + 2 * (widths.len() - 1);
        let _ = writeln!(out, "{}", "-".repeat(total));
        for row in &self.rows {
            line(&mut out, row, &widths);
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

pub fn fmt_f(v: f64, prec: usize) -> String {
    format!("{v:.prec$}")
}

/// Nearest-rank percentile of an ascending-sorted sample: the smallest
/// element with at least `p` (in `0.0..=1.0`) of the sample at or below
/// it, i.e. `sorted[ceil(p * N) - 1]`. An empty sample reports 0.0;
/// `p = 0.0` reports the minimum, `p = 1.0` the maximum, and for N <= 100
/// the p99 IS the maximum (there is no element with exactly 99% below
/// it, so nearest-rank rounds up — the conservative tail for a latency
/// report).
pub fn percentile(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let n = sorted.len();
    let rank = (p * n as f64).ceil() as usize;
    sorted[rank.clamp(1, n) - 1]
}

/// Latency digest over a set of samples (ms): count, mean, and the
/// nearest-rank tail the serving gates check.
#[derive(Debug, Clone, Copy, Default)]
pub struct Summary {
    pub count: usize,
    pub mean: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
    pub max: f64,
}

/// Digest `samples` (sorted in place; order on entry does not matter).
pub fn summarize(samples: &mut [f64]) -> Summary {
    if samples.is_empty() {
        return Summary::default();
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    Summary {
        count: samples.len(),
        mean: samples.iter().sum::<f64>() / samples.len() as f64,
        p50: percentile(samples, 0.50),
        p95: percentile(samples, 0.95),
        p99: percentile(samples, 0.99),
        max: *samples.last().unwrap(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summarize_orders_and_digests() {
        let mut xs = [3.0, 1.0, 2.0, 10.0];
        let s = summarize(&mut xs);
        assert_eq!(s.count, 4);
        assert_eq!(s.mean, 4.0);
        assert_eq!(s.p50, 2.0);
        assert_eq!(s.p99, 10.0);
        assert_eq!(s.max, 10.0);
        assert!(s.p50 <= s.p95 && s.p95 <= s.p99);
    }

    #[test]
    fn summarize_empty_is_zero() {
        let s = summarize(&mut []);
        assert_eq!(s.count, 0);
        assert_eq!(s.p99, 0.0);
    }

    #[test]
    fn timer_excludes_warmup() {
        let mut t = StepTimer::new(2);
        for _ in 0..5 {
            t.start();
            std::thread::sleep(std::time::Duration::from_millis(2));
            t.stop();
        }
        assert_eq!(t.steps_timed(), 3);
        assert!(t.ms_per_step() >= 1.0);
    }

    #[test]
    fn timer_empty_is_zero() {
        let t = StepTimer::new(0);
        assert_eq!(t.ms_per_step(), 0.0);
    }

    #[test]
    fn csv_disabled_is_noop() {
        let mut c = Csv::create("", "a,b").unwrap();
        c.row(&["1".into(), "2".into()]).unwrap();
    }

    #[test]
    fn csv_writes_rows() {
        let path = std::env::temp_dir().join("spm_test_metrics.csv");
        let p = path.to_str().unwrap();
        let mut c = Csv::create(p, "a,b").unwrap();
        c.row(&["1".into(), "2".into()]).unwrap();
        drop(c);
        let text = std::fs::read_to_string(p).unwrap();
        assert_eq!(text, "a,b\n1,2\n");
        let _ = std::fs::remove_file(p);
    }

    #[test]
    fn percentile_empty_sample_is_zero() {
        for p in [0.0, 0.5, 0.99, 1.0] {
            assert_eq!(percentile(&[], p), 0.0);
        }
    }

    #[test]
    fn percentile_single_sample_is_that_sample() {
        for p in [0.0, 0.5, 0.99, 1.0] {
            assert_eq!(percentile(&[42.5], p), 42.5);
        }
    }

    #[test]
    fn percentile_p99_is_max_for_small_samples() {
        // nearest-rank: for N <= 100, ceil(0.99 * N) == N -> the max
        for n in [2usize, 10, 50, 100] {
            let xs: Vec<f64> = (1..=n).map(|i| i as f64).collect();
            assert_eq!(percentile(&xs, 0.99), n as f64, "N={n}");
        }
        // and just past that boundary it stops being the max
        let xs: Vec<f64> = (1..=101).map(|i| i as f64).collect();
        assert_eq!(percentile(&xs, 0.99), 100.0);
    }

    #[test]
    fn percentile_nearest_rank_interior() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0); // rank clamps to the min
        assert_eq!(percentile(&xs, 0.25), 1.0);
        assert_eq!(percentile(&xs, 0.5), 2.0);
        assert_eq!(percentile(&xs, 0.51), 3.0);
        assert_eq!(percentile(&xs, 1.0), 4.0);
    }

    #[test]
    fn percentile_is_monotone_in_p() {
        let xs = [0.5, 1.0, 2.5, 7.0, 7.0, 9.0, 12.0];
        let mut last = f64::MIN;
        for i in 0..=100 {
            let v = percentile(&xs, i as f64 / 100.0);
            assert!(v >= last, "p={i}%: {v} < {last}");
            last = v;
        }
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["n", "acc"]);
        t.row(vec!["256".into(), "0.99".into()]);
        let s = t.render();
        assert!(s.contains("n"));
        assert!(s.contains("256"));
        assert!(s.lines().count() == 3);
    }
}
